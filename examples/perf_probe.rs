// perf probe: per-phase timing of the screen + sort comparisons, plus the
// sharded-vs-streaming backend race (first point of the bench trajectory)
use std::time::Instant;
use tspm_plus::dbmart::NumericDbMart;
use tspm_plus::json::Json;
use tspm_plus::mining::{self, MiningConfig, SeqRecord};
use tspm_plus::pipeline::{self, PipelineConfig};
use tspm_plus::sparsity::{self, SparsityConfig};
use tspm_plus::synthea::SyntheaConfig;

fn main() {
    let db = NumericDbMart::encode(&SyntheaConfig::synthea_covid_like(0.02).generate());
    // dbmart sort alone
    for _ in 0..3 {
        let mut e = db.entries.clone();
        let t = Instant::now();
        let b = mining::sort_and_chunk(&mut e, 1);
        println!("sort_and_chunk: {:?} ({} patients)", t.elapsed(), b.len()-1);
    }
    let set = mining::mine_sequences(&db, &MiningConfig::default()).unwrap();
    println!("mined {}", set.len());
    // screen sort alone (radix by (seq,pid))
    for _ in 0..2 {
        let mut recs = set.records.clone();
        let t = Instant::now();
        tspm_plus::psort::par_sort_by_radix_key(&mut recs, |r| ((r.seq as u128) << 32) | r.pid as u128, 1);
        println!("radix sort 46M recs: {:?}", t.elapsed());
        let t = Instant::now();
        let mut recs2 = set.records.clone();
        recs2.sort_unstable_by_key(|r| ((r.seq as u128) << 32) | r.pid as u128);
        println!("std sort 46M recs:   {:?}", t.elapsed());
    }
    // full screen
    for _ in 0..2 {
        let mut recs = set.records.clone();
        let t = Instant::now();
        sparsity::screen(&mut recs, &SparsityConfig{min_patients: 7, threads: 1});
        println!("screen total: {:?}", t.elapsed());
    }
    // mine timing
    for _ in 0..3 {
        let t = Instant::now();
        let s = mining::mine_sequences(&db, &MiningConfig::default()).unwrap();
        println!("mine: {:.2} M/s", s.len() as f64 / t.elapsed().as_secs_f64()/1e6);
    }

    // sharded vs streaming: same synthetic mart, best-of-3 wall time each.
    // Written to BENCH_sharded_vs_streaming.json so the bench trajectory
    // has a machine-readable first data point.
    let mut sharded_best = f64::MAX;
    for _ in 0..3 {
        let t = Instant::now();
        let s = mining::mine_sequences_sharded(&db, &MiningConfig::default()).unwrap();
        let secs = t.elapsed().as_secs_f64();
        println!("sharded backend: {:?} ({} records)", t.elapsed(), s.len());
        sharded_best = sharded_best.min(secs);
    }
    let mut streaming_best = f64::MAX;
    for _ in 0..3 {
        let t = Instant::now();
        let s = pipeline::run(&db, &PipelineConfig { chunk_cap: 4_000_000, ..Default::default() })
            .unwrap();
        let secs = t.elapsed().as_secs_f64();
        println!("streaming backend: {:?} ({} records)", t.elapsed(), s.sequences.len());
        streaming_best = streaming_best.min(secs);
    }
    println!(
        "sharded vs streaming: {:.3}s vs {:.3}s ({:.2}x)",
        sharded_best,
        streaming_best,
        streaming_best / sharded_best
    );
    let bench = Json::obj(vec![
        ("bench", Json::from("sharded_vs_streaming".to_string())),
        ("patients", Json::from(db.num_patients() as u64)),
        ("entries", Json::from(db.len() as u64)),
        ("sequences", Json::from(set.len() as u64)),
        ("sharded_best_secs", Json::from(sharded_best)),
        ("streaming_best_secs", Json::from(streaming_best)),
        ("speedup_sharded_over_streaming", Json::from(streaming_best / sharded_best)),
    ]);
    std::fs::write("BENCH_sharded_vs_streaming.json", bench.to_string_pretty())
        .expect("write BENCH_sharded_vs_streaming.json");
    println!("wrote BENCH_sharded_vs_streaming.json");

    // query layer: index-build throughput + cold vs LRU-cached point-query
    // latency on the screened set. Written to BENCH_query.json.
    let mut screened = set.records.clone();
    sparsity::screen(&mut screened, &SparsityConfig { min_patients: 7, threads: 1 });
    screened.sort_unstable_by_key(|r| (r.seq, r.pid, r.duration));
    if screened.is_empty() {
        println!("screened set empty — skipping query bench");
        return;
    }
    let qdir = std::env::temp_dir().join("tspm_perf_query");
    let _ = std::fs::remove_dir_all(&qdir);
    std::fs::create_dir_all(&qdir).unwrap();
    let spill_path = qdir.join("screened_0000.tspm");
    tspm_plus::seqstore::write_file(&spill_path, &screened).unwrap();
    let files = tspm_plus::seqstore::SeqFileSet {
        files: vec![spill_path],
        total_records: screened.len() as u64,
        num_patients: db.num_patients() as u32,
        num_phenx: 0,
    };
    let t = Instant::now();
    let idx = tspm_plus::query::index::build(
        &files,
        &qdir.join("idx"),
        &tspm_plus::query::IndexConfig::default(),
        None,
    )
    .unwrap();
    let build_secs = t.elapsed().as_secs_f64();
    println!(
        "index build: {build_secs:.3}s ({} records → {} blocks, {} seqs)",
        idx.total_records,
        idx.blocks.len(),
        idx.seqs.len()
    );
    let svc = tspm_plus::query::QueryService::from_index(idx, 32 << 20);
    let probe_seq = screened[screened.len() / 2].seq;
    let t = Instant::now();
    let cold = svc.by_sequence(probe_seq).unwrap();
    let cold_secs = t.elapsed().as_secs_f64();
    let t = Instant::now();
    let warm = svc.by_sequence(probe_seq).unwrap();
    let cached_secs = t.elapsed().as_secs_f64();
    assert_eq!(cold.len(), warm.len());
    let st = svc.stats();
    println!(
        "query seq {probe_seq}: cold {:.3}ms vs cached {:.3}ms ({} records, {} cache hit)",
        cold_secs * 1e3,
        cached_secs * 1e3,
        cold.len(),
        st.hits
    );
    let qbench = Json::obj(vec![
        ("bench", Json::from("query_cold_vs_cached".to_string())),
        ("records_indexed", Json::from(screened.len())),
        ("result_records", Json::from(cold.len())),
        ("index_build_secs", Json::from(build_secs)),
        ("cold_query_secs", Json::from(cold_secs)),
        ("cached_query_secs", Json::from(cached_secs)),
        ("cache_hits", Json::from(st.hits)),
        ("speedup_cached_over_cold", Json::from(cold_secs / cached_secs.max(1e-9))),
    ]);
    std::fs::write("BENCH_query.json", qbench.to_string_pretty())
        .expect("write BENCH_query.json");
    println!("wrote BENCH_query.json");

    // pid-major secondary index: by_patient scan-vs-index latency, plus
    // the index-fed vs in-memory CSR build. Written to BENCH_pid_index.json.
    let probe_pid = screened[screened.len() / 2].pid;
    let t = Instant::now();
    let fast = svc.by_patient(probe_pid).unwrap();
    let fast_secs = t.elapsed().as_secs_f64();
    let t = Instant::now();
    let scanned = svc.by_patient_scan(probe_pid).unwrap();
    let scan_secs = t.elapsed().as_secs_f64();
    assert_eq!(*fast, scanned, "fast path and scan path must agree");
    println!(
        "by_patient pid {probe_pid}: indexed {:.3}ms vs scan {:.3}ms ({} records, {:.1}x)",
        fast_secs * 1e3,
        scan_secs * 1e3,
        fast.len(),
        scan_secs / fast_secs.max(1e-9)
    );
    let num_patients = db.num_patients() as u32;
    let t = Instant::now();
    let direct = tspm_plus::matrix::SeqMatrix::build(&screened, num_patients).unwrap();
    let matrix_mem_secs = t.elapsed().as_secs_f64();
    let t = Instant::now();
    let streamed =
        tspm_plus::matrix::SeqMatrix::from_index(svc.index(), num_patients).unwrap();
    let matrix_idx_secs = t.elapsed().as_secs_f64();
    assert_eq!(streamed, direct, "index-fed CSR must be bit-identical");
    println!(
        "matrix {}×{} ({} nnz): in-memory {:.3}s vs index-fed {:.3}s",
        num_patients,
        direct.num_cols(),
        direct.nnz(),
        matrix_mem_secs,
        matrix_idx_secs
    );
    let pbench = Json::obj(vec![
        ("bench", Json::from("pid_index".to_string())),
        ("records_indexed", Json::from(screened.len())),
        ("probe_pid", Json::from(probe_pid as u64)),
        ("patient_records", Json::from(fast.len())),
        ("by_patient_indexed_secs", Json::from(fast_secs)),
        ("by_patient_scan_secs", Json::from(scan_secs)),
        ("speedup_indexed_over_scan", Json::from(scan_secs / fast_secs.max(1e-9))),
        ("matrix_nnz", Json::from(direct.nnz())),
        ("matrix_in_memory_secs", Json::from(matrix_mem_secs)),
        ("matrix_from_index_secs", Json::from(matrix_idx_secs)),
    ]);
    std::fs::write("BENCH_pid_index.json", pbench.to_string_pretty())
        .expect("write BENCH_pid_index.json");
    println!("wrote BENCH_pid_index.json");

    // serve layer: loopback daemon over the same artifact, mixed
    // by_sequence/by_patient/patients_with/top_k/histogram workload from
    // concurrent persistent clients. Sustained QPS + per-kind p50/p99
    // to BENCH_serve.json.
    use tspm_plus::serve::{client::run_mixed_workload, Registry, ServeConfig, Server, WorkloadConfig};
    let registry = std::sync::Arc::new(Registry::new(32 << 20));
    registry
        .register("perf", std::sync::Arc::new(svc))
        .expect("register the already-open service");
    let server = Server::bind(
        "127.0.0.1:0",
        registry,
        ServeConfig { max_conns: 16, ..ServeConfig::default() },
    )
    .expect("bind loopback server");
    let addr = server.local_addr().to_string();
    let (handle, join) = server.spawn();
    let wl = WorkloadConfig { requests: 4000, concurrency: 8, seed: 42, artifact: None };
    let report = run_mixed_workload(&addr, &wl).expect("loopback workload");
    handle.shutdown();
    let summary = join.join().unwrap().expect("server drains cleanly");
    println!(
        "serve workload: {:.0} QPS over {} requests ({} conns served, {} shed, {} errors)",
        report.qps, report.total_requests, summary.served, summary.shed, report.errors
    );
    for k in &report.kinds {
        println!(
            "  {:>14}: n={:<6} p50 {:>6}us  p99 {:>6}us",
            k.kind, k.count, k.p50_us, k.p99_us
        );
    }
    let mut sbench = match report.to_json() {
        Json::Obj(o) => o,
        _ => unreachable!("workload report serializes to an object"),
    };
    sbench.insert("bench".to_string(), Json::from("serve_loopback_mixed".to_string()));
    sbench.insert("records_indexed".to_string(), Json::from(screened.len()));
    sbench.insert("max_conns".to_string(), Json::from(16u64));
    sbench.insert("concurrency".to_string(), Json::from(wl.concurrency));
    sbench.insert("connections_served".to_string(), Json::from(summary.served));
    sbench.insert("connections_shed".to_string(), Json::from(summary.shed));
    std::fs::write("BENCH_serve.json", Json::Obj(sbench).to_string_pretty())
        .expect("write BENCH_serve.json");
    println!("wrote BENCH_serve.json");

    // ingest layer: appending a delta segment vs re-indexing the whole
    // cohort from scratch, then merged-view vs compacted-artifact
    // point-query latency (the read cost a compaction buys back).
    // Written to BENCH_ingest.json.
    use tspm_plus::ingest::{compact, CompactConfig, MergedView, SegmentSet};
    use tspm_plus::query::{IndexConfig, QuerySurface};
    let ing_dir = std::env::temp_dir().join("tspm_perf_ingest");
    let _ = std::fs::remove_dir_all(&ing_dir);
    std::fs::create_dir_all(&ing_dir).unwrap();
    let make_run = |name: &str, recs: &[SeqRecord]| {
        let path = ing_dir.join(name);
        tspm_plus::seqstore::write_file(&path, recs).unwrap();
        tspm_plus::seqstore::SeqFileSet {
            files: vec![path],
            total_records: recs.len() as u64,
            num_patients,
            num_phenx: 0,
        }
    };
    // Split the screened cohort into a base half and a delta half at a
    // patient boundary — the pid-partition contract segments live under.
    let split_pid = num_patients / 2;
    let base_half: Vec<SeqRecord> =
        screened.iter().copied().filter(|r| r.pid < split_pid).collect();
    let delta_half: Vec<SeqRecord> =
        screened.iter().copied().filter(|r| r.pid >= split_pid).collect();
    let set_dir = ing_dir.join("segset");
    let mut segset = SegmentSet::init(&set_dir).unwrap();
    segset
        .add_segment(&make_run("base.tspm", &base_half), &IndexConfig::default(), None)
        .unwrap();
    let t = Instant::now();
    segset
        .add_segment(&make_run("delta.tspm", &delta_half), &IndexConfig::default(), None)
        .unwrap();
    let delta_ingest_secs = t.elapsed().as_secs_f64();
    let t = Instant::now();
    tspm_plus::query::index::build(
        &make_run("full.tspm", &screened),
        &ing_dir.join("full_idx"),
        &IndexConfig::default(),
        None,
    )
    .unwrap();
    let full_reindex_secs = t.elapsed().as_secs_f64();
    println!(
        "delta ingest ({} records): {:.3}s vs full re-index ({} records): {:.3}s ({:.1}x)",
        delta_half.len(),
        delta_ingest_secs,
        screened.len(),
        full_reindex_secs,
        full_reindex_secs / delta_ingest_secs.max(1e-9)
    );
    let view = MergedView::open(&set_dir, 32 << 20).unwrap();
    let t = Instant::now();
    let merged_ans = view.by_sequence(probe_seq).unwrap();
    let merged_query_secs = t.elapsed().as_secs_f64();
    let t = Instant::now();
    let compacted = compact(&mut segset, &CompactConfig::default(), None).unwrap();
    let compact_secs = t.elapsed().as_secs_f64();
    let csvc = tspm_plus::query::QueryService::from_index(compacted, 32 << 20);
    let t = Instant::now();
    let compact_ans = csvc.by_sequence(probe_seq).unwrap();
    let compacted_query_secs = t.elapsed().as_secs_f64();
    assert_eq!(*merged_ans, *compact_ans, "merged view and compacted artifact must agree");
    println!(
        "query seq {probe_seq}: merged view {:.3}ms vs compacted {:.3}ms (compact took {:.3}s)",
        merged_query_secs * 1e3,
        compacted_query_secs * 1e3,
        compact_secs
    );
    let ibench = Json::obj(vec![
        ("bench", Json::from("ingest_delta_vs_full".to_string())),
        ("records_total", Json::from(screened.len())),
        ("records_delta", Json::from(delta_half.len())),
        ("delta_ingest_secs", Json::from(delta_ingest_secs)),
        ("full_reindex_secs", Json::from(full_reindex_secs)),
        (
            "speedup_delta_over_full",
            Json::from(full_reindex_secs / delta_ingest_secs.max(1e-9)),
        ),
        ("compact_secs", Json::from(compact_secs)),
        ("merged_query_secs", Json::from(merged_query_secs)),
        ("compacted_query_secs", Json::from(compacted_query_secs)),
        (
            "merged_read_penalty",
            Json::from(merged_query_secs / compacted_query_secs.max(1e-9)),
        ),
    ]);
    std::fs::write("BENCH_ingest.json", ibench.to_string_pretty())
        .expect("write BENCH_ingest.json");
    println!("wrote BENCH_ingest.json");

    // predicate pushdown: targeted vs full mine+screen on the same
    // cohort, best-of-3 each. The targeted run prunes non-matching pairs
    // inside the per-patient inner loop before duration encoding, so it
    // should win on wall time AND on the tracker's peak logical bytes —
    // the two numbers a cohort-scale target query cares about. Written
    // to BENCH_targeted.json.
    use tspm_plus::engine::Engine;
    use tspm_plus::target::{TargetPos, TargetSpec};
    let mut freq = vec![0u64; db.num_phenx()];
    for e in &db.entries {
        freq[e.phenx as usize] += 1;
    }
    let mut by_freq: Vec<u32> = (0..db.num_phenx() as u32).collect();
    by_freq.sort_unstable_by_key(|&c| std::cmp::Reverse(freq[c as usize]));
    let targets: Vec<u32> = by_freq.into_iter().take(2).collect();
    let spec = TargetSpec::for_codes(targets.clone()).with_pos(TargetPos::Either);
    let race_sc = SparsityConfig { min_patients: 7, threads: 0 };
    let race = |target: Option<&TargetSpec>| {
        let mut best = f64::MAX;
        let mut records = 0u64;
        let mut peak = 0u64;
        for _ in 0..3 {
            let t = Instant::now();
            let mut eng =
                Engine::from_dbmart(db.clone()).mine(MiningConfig::default()).screen(race_sc);
            if let Some(s) = target {
                eng = eng.target(s.clone());
            }
            let out = eng.run().unwrap();
            best = best.min(t.elapsed().as_secs_f64());
            records = out.sequences.len() as u64;
            peak = out.report.peak_logical_bytes;
        }
        (best, records, peak)
    };
    let (full_secs, full_records, full_peak) = race(None);
    let (tgt_secs, tgt_records, tgt_peak) = race(Some(&spec));
    println!(
        "targeted ({}) vs full: {:.3}s vs {:.3}s ({:.1}x), peak {} vs {} bytes, \
         {} vs {} records",
        spec.render(),
        tgt_secs,
        full_secs,
        full_secs / tgt_secs.max(1e-9),
        tgt_peak,
        full_peak,
        tgt_records,
        full_records
    );
    let tbench = Json::obj(vec![
        ("bench", Json::from("targeted_vs_full".to_string())),
        ("target", Json::from(spec.render())),
        ("full_best_secs", Json::from(full_secs)),
        ("targeted_best_secs", Json::from(tgt_secs)),
        ("speedup_targeted_over_full", Json::from(full_secs / tgt_secs.max(1e-9))),
        ("full_peak_logical_bytes", Json::from(full_peak)),
        ("targeted_peak_logical_bytes", Json::from(tgt_peak)),
        ("full_records", Json::from(full_records)),
        ("targeted_records", Json::from(tgt_records)),
    ]);
    std::fs::write("BENCH_targeted.json", tbench.to_string_pretty())
        .expect("write BENCH_targeted.json");
    println!("wrote BENCH_targeted.json");
}
