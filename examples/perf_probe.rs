// perf probe: per-phase timing of the screen + sort comparisons
use std::time::Instant;
use tspm_plus::dbmart::NumericDbMart;
use tspm_plus::mining::{self, MiningConfig};
use tspm_plus::sparsity::{self, SparsityConfig};
use tspm_plus::synthea::SyntheaConfig;

fn main() {
    let db = NumericDbMart::encode(&SyntheaConfig::synthea_covid_like(0.02).generate());
    // dbmart sort alone
    for _ in 0..3 {
        let mut e = db.entries.clone();
        let t = Instant::now();
        let b = mining::sort_and_chunk(&mut e, 1);
        println!("sort_and_chunk: {:?} ({} patients)", t.elapsed(), b.len()-1);
    }
    let set = mining::mine_sequences(&db, &MiningConfig::default()).unwrap();
    println!("mined {}", set.len());
    // screen sort alone (radix by (seq,pid))
    for _ in 0..2 {
        let mut recs = set.records.clone();
        let t = Instant::now();
        tspm_plus::psort::par_sort_by_radix_key(&mut recs, |r| ((r.seq as u128) << 32) | r.pid as u128, 1);
        println!("radix sort 46M recs: {:?}", t.elapsed());
        let t = Instant::now();
        let mut recs2 = set.records.clone();
        recs2.sort_unstable_by_key(|r| ((r.seq as u128) << 32) | r.pid as u128);
        println!("std sort 46M recs:   {:?}", t.elapsed());
    }
    // full screen
    for _ in 0..2 {
        let mut recs = set.records.clone();
        let t = Instant::now();
        sparsity::screen(&mut recs, &SparsityConfig{min_patients: 7, threads: 1});
        println!("screen total: {:?}", t.elapsed());
    }
    // mine timing
    for _ in 0..3 {
        let t = Instant::now();
        let s = mining::mine_sequences(&db, &MiningConfig::default()).unwrap();
        println!("mine: {:.2} M/s", s.len() as f64 / t.elapsed().as_secs_f64()/1e6);
    }
}
