//! Quickstart: the 60-second tour of the tSPM+ public API.
//!
//! Generates a small synthetic clinical cohort, mines all transitive
//! sequences with durations, sparsity-screens them, and shows how a
//! numeric sequence translates back to human-readable form (paper
//! Fig. 2).
//!
//! Run with: `cargo run --release --example quickstart`

use tspm_plus::dbmart::{decode_seq, format_seq, NumericDbMart};
use tspm_plus::metrics::fmt_bytes;
use tspm_plus::mining::{mine_sequences, MiningConfig};
use tspm_plus::sparsity::{screen, SparsityConfig};
use tspm_plus::synthea::SyntheaConfig;
use tspm_plus::util;

fn main() {
    // 1. A cohort. Real use: DbMart::read_csv("my_ehr_export.csv").
    let cohort = SyntheaConfig::small().generate();
    println!("cohort: {} rows", cohort.len());

    // 2. Numeric encoding with lookup tables (the paper's preprocessing).
    let db = NumericDbMart::encode(&cohort);
    println!(
        "encoded: {} patients, {} distinct phenX, {} per entry",
        db.num_patients(),
        db.num_phenx(),
        fmt_bytes(db.byte_size() / db.len().max(1) as u64),
    );

    // 3. Mine every transitive sequence, with durations in days.
    let cfg = MiningConfig::default();
    let mined = mine_sequences(&db, &cfg).expect("mining");
    println!("mined: {} sequences ({})", mined.len(), fmt_bytes(mined.byte_size()));

    // 4. Sparsity screen: keep sequences seen in ≥ 5 distinct patients.
    let mut records = mined.records;
    let stats = screen(&mut records, &SparsityConfig { min_patients: 5, threads: 0 });
    println!(
        "screened: {} → {} records, {} → {} distinct sequences",
        stats.records_before, stats.records_after, stats.distinct_before, stats.distinct_after
    );

    // 5. A sequence is a reversible decimal hash (paper Fig. 2).
    let sample = records[records.len() / 2];
    let (start, end) = decode_seq(sample.seq);
    println!(
        "\nexample record: seq={} ({}) duration={}d patient={}",
        sample.seq,
        format_seq(sample.seq),
        sample.duration,
        db.lookup.patient_name(sample.pid),
    );
    println!(
        "  translates to: {} -> {}",
        db.lookup.phenx_name(start),
        db.lookup.phenx_name(end)
    );

    // 6. Utility functions: everything downstream of one phenX.
    let from_start = util::filter_by_start(&records, start);
    let long_ones = util::filter_min_duration(&from_start, 90);
    println!(
        "\nsequences starting with {}: {} total, {} lasting ≥ 90 days",
        db.lookup.phenx_name(start),
        from_start.len(),
        long_ones.len()
    );
}
