//! Quickstart: the 60-second tour of the tSPM+ public API.
//!
//! One fluent [`Engine`] chain runs the paper's pipeline — generate a
//! small synthetic clinical cohort, mine all transitive sequences with
//! durations, sparsity-screen them — on an automatically selected
//! execution backend, then shows how a numeric sequence translates back
//! to human-readable form (paper Fig. 2). The per-stage free functions
//! remain available as the expert layer (see the crate docs).
//!
//! Run with: `cargo run --release --example quickstart`

use tspm_plus::dbmart::{decode_seq, format_seq};
use tspm_plus::engine::{Engine, TspmError};
use tspm_plus::metrics::fmt_bytes;
use tspm_plus::mining::MiningConfig;
use tspm_plus::sparsity::SparsityConfig;
use tspm_plus::synthea::SyntheaConfig;
use tspm_plus::util;

fn main() -> Result<(), TspmError> {
    // 1. A cohort. Real use: DbMart::read_csv("my_ehr_export.csv").
    let cohort = SyntheaConfig::small().generate();
    println!("cohort: {} rows", cohort.len());

    // 2–4. Encode → mine → screen, as one validated engine plan. The
    // backend (in-memory / file-backed / streaming) is auto-selected
    // from the output-size forecast; errors are one unified type.
    let out = Engine::from_raw(&cohort)?
        .mine(MiningConfig::default())
        .screen(SparsityConfig { min_patients: 5, threads: 0 })
        .run()?;

    let db = &out.db;
    println!(
        "encoded: {} patients, {} distinct phenX",
        db.num_patients(),
        db.num_phenx()
    );
    let stats = out.screen_stats.expect("screen stage ran");
    println!(
        "mined {} sequences ({}), screened to {} ({} distinct) on the {} backend",
        stats.records_before,
        fmt_bytes(out.report.stages[0].bytes_out),
        stats.records_after,
        stats.distinct_after,
        out.report.backend,
    );
    println!("\nper-stage report:\n{}", out.report.render());

    // 5. A sequence is a reversible decimal hash (paper Fig. 2). The
    // engine result is spill-aware (`SequenceOutput`) — materialize()
    // hands back the in-memory set, a no-op on this small run.
    let sequences = out.sequences.materialize()?;
    let records = &sequences.records;
    let sample = records[records.len() / 2];
    let (start, end) = decode_seq(sample.seq);
    println!(
        "example record: seq={} ({}) duration={}d patient={}",
        sample.seq,
        format_seq(sample.seq),
        sample.duration,
        db.lookup.patient_name(sample.pid),
    );
    println!(
        "  translates to: {} -> {}",
        db.lookup.phenx_name(start),
        db.lookup.phenx_name(end)
    );

    // 6. Utility functions: everything downstream of one phenX.
    let from_start = util::filter_by_start(records, start);
    let long_ones = util::filter_min_duration(&from_start, 90);
    println!(
        "\nsequences starting with {}: {} total, {} lasting ≥ 90 days",
        db.lookup.phenx_name(start),
        from_start.len(),
        long_ones.len()
    );
    Ok(())
}
