//! Vignette 2 — identifying Post COVID-19 patients per the WHO definition.
//!
//! Mirrors the paper's second vignette on the synthetic Synthea-like
//! COVID cohort, then goes one step further than the paper: because the
//! generator plants ground truth, the result is *validated* (precision /
//! recall / F1), not just demonstrated.
//!
//! Run with: `cargo run --release --example postcovid`

use tspm_plus::engine::Engine;
use tspm_plus::mining::MiningConfig;
use tspm_plus::postcovid::{identify, validate, PostCovidConfig};
use tspm_plus::runtime::{default_artifacts_dir, ArtifactSet};
use tspm_plus::synthea::{SyntheaConfig, COVID_CODE, SYMPTOM_CODES};

fn main() {
    // 1. Synthetic COVID cohort with ground truth.
    let mut gen_cfg = SyntheaConfig::small();
    gen_cfg.patients = 500;
    let g = gen_cfg.generate_with_truth();
    println!(
        "cohort: {} patients, {} infected, {} true Post-COVID (patient, symptom) pairs",
        gen_cfg.patients,
        g.truth.infected.len(),
        g.truth.postcovid.len()
    );

    // 2. Mine all transitive sequences (durations are the key input)
    // through the engine façade — no screening: the WHO definition needs
    // rare per-patient patterns.
    let run = Engine::from_raw(&g.dbmart)
        .expect("encode")
        .mine(MiningConfig::default())
        .run()
        .expect("mining");
    let db = run.db;
    let mined = run.sequences.materialize().expect("materialize");
    println!("mined {} sequences via the {} backend", mined.len(), run.report.backend);

    // 3. WHO definition over sequences + durations.
    let covid = db.lookup.phenx_id(COVID_CODE).expect("covid code");
    let mut cfg = PostCovidConfig::new(covid);
    cfg.candidate_filter =
        Some(SYMPTOM_CODES.iter().filter_map(|s| db.lookup.phenx_id(s)).collect());

    let artifacts = ArtifactSet::load(&default_artifacts_dir()).ok();
    if artifacts.is_some() {
        println!("correlation exclusion running on PJRT artifacts");
    }
    let result = identify(&mined.records, db.num_patients() as u32, &cfg, artifacts.as_ref())
        .expect("identify");

    println!(
        "\ncandidates {} → confirmed {} (excluded {}: pre-existing or explained)",
        result.candidates.len(),
        result.confirmed.len(),
        result.excluded.len()
    );
    for &(pid, sym) in result.confirmed.iter().take(8) {
        println!(
            "  {:10} → {}",
            db.lookup.patient_name(pid),
            db.lookup.phenx_name(sym)
        );
    }
    if result.confirmed.len() > 8 {
        println!("  … and {} more", result.confirmed.len() - 8);
    }

    // 4. Validation against planted ground truth.
    let v = validate(&result, &g.truth, &db.lookup);
    println!(
        "\nvalidation: precision {:.3}  recall {:.3}  F1 {:.3}  (tp={} fp={} fn={})",
        v.precision(),
        v.recall(),
        v.f1(),
        v.true_positives,
        v.false_positives,
        v.false_negatives
    );
    assert!(v.recall() > 0.9, "recall regression");
}
