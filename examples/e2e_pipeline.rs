//! End-to-end driver — proves all three layers compose on a real small
//! workload (the EXPERIMENTS.md §E2E run).
//!
//! Pipeline: synthetic COVID cohort → numeric encoding → streaming mining
//! with backpressure ([`tspm_plus::pipeline`]) → sparsity screen → MSMR
//! feature selection on the **PJRT co-occurrence artifacts (L1 Pallas
//! kernel inside)** → logistic-regression training via the **PJRT
//! `logreg_grad` artifact** → evaluation, plus the WHO Post-COVID
//! vignette validated against ground truth. Reports the paper's headline
//! metric (mining throughput + memory) along the way.
//!
//! Requires `make artifacts` (falls back to pure Rust with a warning).
//!
//! Run with: `cargo run --release --example e2e_pipeline`

use std::time::Instant;

use tspm_plus::dbmart::NumericDbMart;
use tspm_plus::matrix::SeqMatrix;
use tspm_plus::metrics::{fmt_bytes, fmt_duration, MemTracker};
use tspm_plus::mining::MiningConfig;
use tspm_plus::ml::{self, TrainConfig};
use tspm_plus::msmr::{self, MsmrConfig};
use tspm_plus::pipeline::{run as run_pipeline, PipelineConfig};
use tspm_plus::postcovid::{identify, validate, PostCovidConfig};
use tspm_plus::runtime::{default_artifacts_dir, ArtifactSet};
use tspm_plus::sparsity::SparsityConfig;
use tspm_plus::synthea::{SyntheaConfig, COVID_CODE, SYMPTOM_CODES};

fn main() {
    println!("=== tSPM+ end-to-end pipeline ===\n");
    let artifacts = match ArtifactSet::load(&default_artifacts_dir()) {
        Ok(set) => {
            println!(
                "[runtime] PJRT CPU client up; artifacts: {:?} (tiles {}x{})",
                set.names(),
                set.tile_rows,
                set.tile_features
            );
            Some(set)
        }
        Err(e) => {
            println!("[runtime] WARNING: {e}\n[runtime] continuing with pure-Rust analytics");
            None
        }
    };

    // ---- stage 1: workload ------------------------------------------------
    let mut gen_cfg = SyntheaConfig::synthea_covid_like(0.02); // 700 patients
    gen_cfg.vocab_size = 2_000;
    let g = gen_cfg.generate_with_truth();
    let db = NumericDbMart::encode(&g.dbmart);
    println!(
        "\n[data] {} patients, {} rows, {} distinct phenX, {} true Post-COVID pairs",
        db.num_patients(),
        db.len(),
        db.num_phenx(),
        g.truth.postcovid.len()
    );

    // ---- stage 2: streaming mining + screen -------------------------------
    let tracker = MemTracker::new();
    let t0 = Instant::now();
    let pipe_cfg = PipelineConfig {
        mining: MiningConfig::default(),
        chunk_cap: 2_000_000,
        queue_depth: 4,
        shards: 0,
        screen: Some(SparsityConfig { min_patients: 8, threads: 0 }),
    };
    let result = run_pipeline(&db, &pipe_cfg).expect("pipeline");
    let mine_elapsed = t0.elapsed();
    let mined_total = result.metrics.records.load(std::sync::atomic::Ordering::Relaxed);
    tracker.add(result.sequences.byte_size());
    println!(
        "[mine] {} sequences mined in {} ({:.1} M seq/s), screened to {} \
         ({} distinct); stage metrics: {}",
        mined_total,
        fmt_duration(mine_elapsed),
        mined_total as f64 / mine_elapsed.as_secs_f64() / 1e6,
        result.sequences.len(),
        result.screen_stats.map(|s| s.distinct_after).unwrap_or(0),
        result.metrics.report()
    );
    println!("[mine] resident sequence set: {}", fmt_bytes(result.sequences.byte_size()));

    // ---- stage 3: MSMR on PJRT --------------------------------------------
    let pc_patients: std::collections::BTreeSet<&str> =
        g.truth.postcovid.iter().map(|(p, _)| p.as_str()).collect();
    let labels: Vec<f32> = (0..db.num_patients())
        .map(|p| f32::from(pc_patients.contains(db.lookup.patient_name(p as u32))))
        .collect();
    let m = SeqMatrix::build(&result.sequences.records, db.num_patients() as u32);
    println!(
        "\n[msmr] matrix {} × {} ({} nnz)",
        m.num_patients,
        m.num_cols(),
        m.nnz()
    );
    let t1 = Instant::now();
    let sel = msmr::select(
        &m,
        &labels,
        &MsmrConfig { top_k: 200, ..Default::default() },
        artifacts.as_ref(),
    )
    .expect("msmr");
    println!(
        "[msmr] selected {} features in {} (top relevance {:.4} nats)",
        sel.columns.len(),
        fmt_duration(t1.elapsed()),
        sel.relevance.first().copied().unwrap_or(0.0)
    );
    let selected = m.select_columns(&sel.columns);

    // ---- stage 4: classifier on PJRT --------------------------------------
    let t2 = Instant::now();
    let (_, train_m, test_m) = ml::run_workflow(
        &selected,
        &labels,
        &TrainConfig { epochs: 150, ..Default::default() },
        artifacts.as_ref(),
    )
    .expect("training");
    println!(
        "\n[classify] trained in {} — train AUC {:.3}, test AUC {:.3} (n={}/{})",
        fmt_duration(t2.elapsed()),
        train_m.auc,
        test_m.auc,
        train_m.n,
        test_m.n
    );

    // ---- stage 5: Post-COVID vignette --------------------------------------
    let covid = db.lookup.phenx_id(COVID_CODE).expect("covid code");
    let mut pc_cfg = PostCovidConfig::new(covid);
    pc_cfg.candidate_filter =
        Some(SYMPTOM_CODES.iter().filter_map(|s| db.lookup.phenx_id(s)).collect());
    // The vignette needs unscreened records (rare per-patient patterns).
    let full = tspm_plus::mining::mine_sequences(&db, &MiningConfig::default()).expect("mine");
    let pc = identify(&full.records, db.num_patients() as u32, &pc_cfg, artifacts.as_ref())
        .expect("postcovid");
    let v = validate(&pc, &g.truth, &db.lookup);
    println!(
        "\n[postcovid] {} confirmed pairs — precision {:.3} recall {:.3} F1 {:.3}",
        pc.confirmed.len(),
        v.precision(),
        v.recall(),
        v.f1()
    );

    // ---- summary ------------------------------------------------------------
    println!("\n=== E2E summary ===");
    println!("mining throughput : {:.1} M seq/s", mined_total as f64 / mine_elapsed.as_secs_f64() / 1e6);
    println!("test AUC          : {:.3}", test_m.auc);
    println!("post-covid F1     : {:.3}", v.f1());
    println!(
        "layers exercised  : L3 rust pipeline ✓  L2 JAX artifacts {}  L1 Pallas kernel {}",
        if artifacts.is_some() { "✓" } else { "✗ (fallback)" },
        if artifacts.is_some() { "✓ (inside cooc artifacts)" } else { "✗" },
    );
    assert!(test_m.auc > 0.75, "E2E AUC regression: {}", test_m.auc);
    assert!(v.recall() > 0.9, "E2E recall regression");
}
