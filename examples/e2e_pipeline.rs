//! End-to-end driver — proves all three layers compose on a real small
//! workload (the EXPERIMENTS.md §E2E run).
//!
//! Pipeline, orchestrated by the **engine façade** on the **streaming
//! backend** (bounded queues + backpressure + work-stealing shards):
//! synthetic COVID cohort → numeric encoding → mining → sparsity screen
//! → patient×sequence matrix → MSMR feature selection on the **PJRT
//! co-occurrence artifacts (L1 Pallas kernel inside)** → logistic-
//! regression training via the **PJRT `logreg_grad` artifact** →
//! evaluation, plus the WHO Post-COVID vignette validated against ground
//! truth. Reports the paper's headline metric (mining throughput +
//! memory) along the way.
//!
//! Requires `make artifacts` + the `pjrt` cargo feature (falls back to
//! pure Rust with a warning).
//!
//! Run with: `cargo run --release --example e2e_pipeline`

use std::time::Instant;

use tspm_plus::dbmart::NumericDbMart;
use tspm_plus::engine::{BackendChoice, Engine};
use tspm_plus::metrics::{fmt_bytes, fmt_duration};
use tspm_plus::mining::MiningConfig;
use tspm_plus::ml::{self, TrainConfig};
use tspm_plus::postcovid::{identify, validate, PostCovidConfig};
use tspm_plus::runtime::{default_artifacts_dir, ArtifactSet};
use tspm_plus::sparsity::SparsityConfig;
use tspm_plus::synthea::{SyntheaConfig, COVID_CODE, SYMPTOM_CODES};

fn main() {
    println!("=== tSPM+ end-to-end pipeline ===\n");
    let artifacts = match ArtifactSet::load(&default_artifacts_dir()) {
        Ok(set) => {
            println!(
                "[runtime] PJRT CPU client up; artifacts: {:?} (tiles {}x{})",
                set.names(),
                set.tile_rows,
                set.tile_features
            );
            Some(set)
        }
        Err(e) => {
            println!("[runtime] WARNING: {e}\n[runtime] continuing with pure-Rust analytics");
            None
        }
    };

    // ---- stage 1: workload ------------------------------------------------
    let mut gen_cfg = SyntheaConfig::synthea_covid_like(0.02); // 700 patients
    gen_cfg.vocab_size = 2_000;
    let g = gen_cfg.generate_with_truth();
    let db = NumericDbMart::encode(&g.dbmart);
    println!(
        "\n[data] {} patients, {} rows, {} distinct phenX, {} true Post-COVID pairs",
        db.num_patients(),
        db.len(),
        db.num_phenx(),
        g.truth.postcovid.len()
    );
    let pc_patients: std::collections::BTreeSet<&str> =
        g.truth.postcovid.iter().map(|(p, _)| p.as_str()).collect();
    let labels: Vec<f32> = (0..db.num_patients())
        .map(|p| f32::from(pc_patients.contains(db.lookup.patient_name(p as u32))))
        .collect();

    // ---- stage 2: the engine runs mine → screen → matrix → msmr -----------
    // Streaming backend pinned; the 32 MiB budget forces real partitioning
    // (≈2M-record chunks) so backpressure is actually exercised.
    let out = Engine::from_dbmart(db)
        .backend(BackendChoice::Streaming)
        .memory_budget(32 << 20)
        .mine(MiningConfig::default())
        .screen(SparsityConfig { min_patients: 8, threads: 0 })
        .matrix()
        .msmr(200)
        .labels(labels.clone())
        .run_with(artifacts.as_ref())
        .expect("engine run");
    let db = &out.db;
    // Actual mined count from the mine stage (the forecast is an upper
    // bound once self-pairs are excluded or first-occurrence filtering is
    // on).
    let mined_total = out.report.stages[0].records_out;
    let mine_elapsed = out.report.stages[0].elapsed;
    println!(
        "[mine] {} sequences mined in {} ({:.1} M seq/s) on the {} backend, \
         screened to {} ({} distinct)",
        mined_total,
        fmt_duration(mine_elapsed),
        mined_total as f64 / mine_elapsed.as_secs_f64() / 1e6,
        out.report.backend,
        out.sequences.len(),
        out.screen_stats.map(|s| s.distinct_after).unwrap_or(0),
    );
    println!("[mine] resident sequence set: {}", fmt_bytes(out.sequences.byte_size()));
    println!("\n[engine] per-stage report:\n{}", out.report.render());

    // ---- stage 3: MSMR results --------------------------------------------
    let m = out.matrix.as_ref().expect("matrix stage");
    let sel = out.selection.as_ref().expect("msmr stage");
    println!(
        "[msmr] matrix {} × {} ({} nnz) → selected {} features (top relevance {:.4} nats)",
        m.num_patients,
        m.num_cols(),
        m.nnz(),
        sel.columns.len(),
        sel.relevance.first().copied().unwrap_or(0.0)
    );
    let selected = m.select_columns(&sel.columns);

    // ---- stage 4: classifier on PJRT --------------------------------------
    let t2 = Instant::now();
    let (_, train_m, test_m) = ml::run_workflow(
        &selected,
        &labels,
        &TrainConfig { epochs: 150, ..Default::default() },
        artifacts.as_ref(),
    )
    .expect("training");
    println!(
        "\n[classify] trained in {} — train AUC {:.3}, test AUC {:.3} (n={}/{})",
        fmt_duration(t2.elapsed()),
        train_m.auc,
        test_m.auc,
        train_m.n,
        test_m.n
    );

    // ---- stage 5: Post-COVID vignette --------------------------------------
    let covid = db.lookup.phenx_id(COVID_CODE).expect("covid code");
    let mut pc_cfg = PostCovidConfig::new(covid);
    pc_cfg.candidate_filter =
        Some(SYMPTOM_CODES.iter().filter_map(|s| db.lookup.phenx_id(s)).collect());
    // The vignette needs unscreened records (rare per-patient patterns):
    // a second, mine-only engine run on the auto-selected backend.
    let full = Engine::from_dbmart(out.db.clone())
        .mine(MiningConfig::default())
        .run()
        .expect("mine");
    let full_set = full.sequences.materialize().expect("materialize");
    let pc = identify(&full_set.records, db.num_patients() as u32, &pc_cfg, artifacts.as_ref())
        .expect("postcovid");
    let v = validate(&pc, &g.truth, &db.lookup);
    println!(
        "\n[postcovid] {} confirmed pairs — precision {:.3} recall {:.3} F1 {:.3}",
        pc.confirmed.len(),
        v.precision(),
        v.recall(),
        v.f1()
    );

    // ---- summary ------------------------------------------------------------
    println!("\n=== E2E summary ===");
    println!(
        "mining throughput : {:.1} M seq/s",
        mined_total as f64 / mine_elapsed.as_secs_f64() / 1e6
    );
    println!("test AUC          : {:.3}", test_m.auc);
    println!("post-covid F1     : {:.3}", v.f1());
    println!(
        "layers exercised  : L3 rust engine (streaming backend) ✓  L2 JAX artifacts {}  L1 Pallas kernel {}",
        if artifacts.is_some() { "✓" } else { "✗ (fallback)" },
        if artifacts.is_some() { "✓ (inside cooc artifacts)" } else { "✗" },
    );
    assert!(test_m.auc > 0.75, "E2E AUC regression: {}", test_m.auc);
    assert!(v.recall() > 0.9, "E2E recall regression");
}
