//! Vignette 1 — integrating tSPM+ into an MLHO-style ML workflow.
//!
//! Mirrors the paper's first vignette: mine sequences, sparsity-screen,
//! MSMR-select the most informative 200, train a classifier on the
//! selected sequences (instead of raw EHR entries), and translate the
//! significant sequences back to readable descriptions.
//!
//! Uses the AOT-compiled PJRT artifacts when `artifacts/manifest.json`
//! exists (build with `make artifacts`); otherwise falls back to the
//! pure-Rust analytics path.
//!
//! Run with: `cargo run --release --example mlho_workflow`

use tspm_plus::ml;
use tspm_plus::runtime::{default_artifacts_dir, ArtifactSet};

fn main() {
    let artifacts = match ArtifactSet::load(&default_artifacts_dir()) {
        Ok(set) => {
            println!("using PJRT artifacts: {:?}", set.names());
            Some(set)
        }
        Err(e) => {
            println!("no PJRT artifacts ({e}); using pure-Rust analytics");
            None
        }
    };
    let report = ml::mlho_vignette(400, 200, 200, artifacts.as_ref()).expect("vignette");
    print!("{report}");
}
