//! Vignette 1 — integrating tSPM+ into an MLHO-style ML workflow.
//!
//! Mirrors the paper's first vignette: mine sequences, sparsity-screen,
//! MSMR-select the most informative 200, train a classifier on the
//! selected sequences (instead of raw EHR entries), and translate the
//! significant sequences back to readable descriptions.
//!
//! The packaged driver [`tspm_plus::ml::mlho_vignette`] runs the whole
//! thing — its front half (mine → screen → matrix → msmr) is one
//! [`tspm_plus::engine::Engine`] chain internally. Before invoking it,
//! this example shows the engine's dry-run surface: the validated plan
//! and the output-size forecast that drives backend auto-selection,
//! both computed without mining a single sequence.
//!
//! Uses the AOT-compiled PJRT artifacts when `artifacts/manifest.json`
//! exists (build with `make artifacts` and the `pjrt` cargo feature);
//! otherwise falls back to the pure-Rust analytics path.
//!
//! Run with: `cargo run --release --example mlho_workflow`

use tspm_plus::dbmart::NumericDbMart;
use tspm_plus::engine::Engine;
use tspm_plus::metrics::fmt_bytes;
use tspm_plus::mining::MiningConfig;
use tspm_plus::ml;
use tspm_plus::runtime::{default_artifacts_dir, ArtifactSet};
use tspm_plus::sparsity::SparsityConfig;
use tspm_plus::synthea::SyntheaConfig;

fn main() {
    let artifacts = match ArtifactSet::load(&default_artifacts_dir()) {
        Ok(set) => {
            println!("using PJRT artifacts: {:?}", set.names());
            Some(set)
        }
        Err(e) => {
            println!("no PJRT artifacts ({e}); using pure-Rust analytics");
            None
        }
    };

    // Dry-run surface: assemble and validate a stage chain mirroring the
    // vignette's defaults (same cohort size, threshold_for screen), and
    // forecast its mining output, before any work happens. This is
    // illustrative — the vignette below builds its own chain internally.
    let patients = 400u64;
    let mut gen_cfg = SyntheaConfig::small();
    gen_cfg.patients = patients;
    let db = NumericDbMart::encode(&gen_cfg.generate());
    let engine = Engine::from_dbmart(db)
        .mine(MiningConfig::default())
        .screen(SparsityConfig {
            min_patients: tspm_plus::bench_util::experiments::threshold_for(patients),
            threads: 0,
        })
        .matrix();
    let plan = engine.plan().expect("valid plan");
    let forecast = engine.forecast().expect("forecast");
    println!(
        "engine plan: {}  (forecast: {} sequences, {})\n",
        plan.describe(),
        forecast.total_sequences,
        fmt_bytes(forecast.total_bytes)
    );

    // The packaged vignette (engine-backed internally): mine → screen →
    // matrix → MSMR → train → evaluate → translate top sequences.
    let report =
        ml::mlho_vignette(patients, 200, 200, artifacts.as_ref()).expect("vignette");
    print!("{report}");
}
