//! `cargo xtask lint` — static enforcement of the repository's
//! compatibility and determinism contracts.
//!
//! Six checks, all source-level (no compilation, no dependencies):
//!
//! 1. **Append-only wire protocol** — the `ErrorCode` and `Request`
//!    enums in `rust/src/serve/protocol.rs` must extend the committed
//!    snapshot (`xtask/snapshots/wire.txt`) by appending at the end
//!    only; reordering, renaming, or removing a variant breaks every
//!    deployed client and fails the lint. The protocol version
//!    constants are pinned the same way. `--bless` rewrites the
//!    snapshot after an intentional extension.
//! 2. **Artifact format constants agree with their docs** — the
//!    `FORMAT`/`VERSION` constants in `query::index` and `ingest` must
//!    be internally coherent (min ≤ current) and the literals quoted in
//!    module docs (`"tspm-seqindex"`, `"tspm-spill"`, `"tspm-segset"`,
//!    "currently N" in the serve docs) must match the constants, so the
//!    documented contract can never drift from the enforced one.
//! 2b. **Append-only manifest keys** — the top-level keys that
//!    `query::index::write_tables_and_manifest` writes into
//!    `manifest.json` must be a superset of the committed snapshot
//!    (`xtask/snapshots/manifest_keys.txt`) whenever
//!    `INDEX_FORMAT_VERSION` is unchanged: readers parse keys by name
//!    and ignore unknown ones, so *adding* a key (e.g. `target`) is
//!    compatible without a version bump, while dropping or renaming an
//!    existing key is a silent format break and fails the lint. Key
//!    sets are compared, never positions. `--bless` records additions.
//! 3. **Determinism bans** — the deterministic-output modules
//!    (`mining`, `sparsity`, `query`, `ingest`) may not iterate a
//!    `HashMap` (iteration order is randomized per process — the exact
//!    failure mode the byte-identical-output contract forbids) nor call
//!    `SystemTime::now`. Provably order-insensitive sites are annotated
//!    `// lint:allow(hashmap_iter)` within the five lines above.
//! 4. **Unsafe audit** — every `unsafe` in `rust/src` must sit in
//!    `xtask/snapshots/unsafe_allowlist.txt` (per-file occurrence
//!    budget) and carry a `// SAFETY:` comment in the five lines above
//!    it.
//! 5. **Append-only metric names** — the exposition-name constants in
//!    `rust/src/obs/names.rs` must match `[a-z][a-z0-9_]*` and extend
//!    the committed snapshot (`xtask/snapshots/metrics.txt`) by
//!    appending at the end only; renaming or removing a name breaks
//!    every dashboard and alert scraping it. `--bless` rewrites the
//!    snapshot after an intentional extension.
//!
//! The checks operate on comment/string-stripped source lines, so
//! mentioning `unsafe` or `HashMap` in docs never trips them. Test
//! modules (everything at and after the first `#[cfg(test…)]` line — a
//! repo convention: tests sit at the bottom of each file) are exempt
//! from the determinism bans but not from the unsafe audit.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// The deterministic-output modules (check 3's scope), as path prefixes
/// relative to the repo root.
const DETERMINISTIC_DIRS: [&str; 4] =
    ["rust/src/mining", "rust/src/sparsity", "rust/src/query", "rust/src/ingest"];

const WIRE_SNAPSHOT: &str = "xtask/snapshots/wire.txt";
const METRICS_SNAPSHOT: &str = "xtask/snapshots/metrics.txt";
const MANIFEST_SNAPSHOT: &str = "xtask/snapshots/manifest_keys.txt";
const UNSAFE_ALLOWLIST: &str = "xtask/snapshots/unsafe_allowlist.txt";
const PROTOCOL_RS: &str = "rust/src/serve/protocol.rs";
const NAMES_RS: &str = "rust/src/obs/names.rs";
const INDEX_RS: &str = "rust/src/query/index.rs";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => {
            let bless = args.iter().any(|a| a == "--bless");
            match run_lint(&repo_root(), bless) {
                Ok(0) => {
                    println!("xtask lint: all invariants hold");
                    ExitCode::SUCCESS
                }
                Ok(n) => {
                    eprintln!("xtask lint: {n} violation(s)");
                    ExitCode::FAILURE
                }
                Err(e) => {
                    eprintln!("xtask lint: error: {e}");
                    ExitCode::FAILURE
                }
            }
        }
        _ => {
            eprintln!("usage: cargo xtask lint [--bless]");
            ExitCode::FAILURE
        }
    }
}

/// Repo root = the parent of xtask's manifest dir.
fn repo_root() -> PathBuf {
    let manifest = std::env::var("CARGO_MANIFEST_DIR").unwrap_or_else(|_| ".".into());
    let p = PathBuf::from(manifest);
    p.parent().map(Path::to_path_buf).unwrap_or(p)
}

fn run_lint(root: &Path, bless: bool) -> Result<usize, String> {
    let files = load_tree(root)?;
    let mut violations = Vec::new();

    // 1. wire snapshot (or bless it)
    let rendered = render_wire_snapshot(&files, &mut violations);
    if let Some(rendered) = rendered {
        let snap_path = root.join(WIRE_SNAPSHOT);
        if bless {
            std::fs::write(&snap_path, &rendered)
                .map_err(|e| format!("cannot write {}: {e}", snap_path.display()))?;
            println!("xtask lint: blessed {WIRE_SNAPSHOT}");
        } else {
            match std::fs::read_to_string(&snap_path) {
                Ok(committed) => {
                    check_wire_append_only(&committed, &files, &mut violations)
                }
                Err(_) => violations.push(Violation {
                    file: WIRE_SNAPSHOT.into(),
                    line: 0,
                    rule: "wire-snapshot",
                    msg: "snapshot missing; run `cargo xtask lint --bless` and commit it"
                        .into(),
                }),
            }
        }
    }

    // 2. format/version constants vs docs
    check_format_constants(&files, &mut violations);

    // 2b. seqindex manifest key set (or bless it): append-only WITHOUT a
    // version bump — readers parse by name and ignore unknown keys, so
    // adding a key is compatible; dropping or renaming one is not.
    let rendered = render_manifest_snapshot(&files, &mut violations);
    if let Some(rendered) = rendered {
        let snap_path = root.join(MANIFEST_SNAPSHOT);
        if bless {
            std::fs::write(&snap_path, &rendered)
                .map_err(|e| format!("cannot write {}: {e}", snap_path.display()))?;
            println!("xtask lint: blessed {MANIFEST_SNAPSHOT}");
        } else {
            match std::fs::read_to_string(&snap_path) {
                Ok(committed) => {
                    check_manifest_append_only(&committed, &files, &mut violations)
                }
                Err(_) => violations.push(Violation {
                    file: MANIFEST_SNAPSHOT.into(),
                    line: 0,
                    rule: "manifest-keys",
                    msg: "snapshot missing; run `cargo xtask lint --bless` and commit it"
                        .into(),
                }),
            }
        }
    }

    // 3. determinism bans
    check_determinism(&files, &mut violations);

    // 4. unsafe audit
    let allowlist = std::fs::read_to_string(root.join(UNSAFE_ALLOWLIST)).unwrap_or_default();
    check_unsafe(&files, &allowlist, &mut violations);

    // 5. metric-name snapshot (or bless it)
    let rendered = render_metrics_snapshot(&files, &mut violations);
    if let Some(rendered) = rendered {
        let snap_path = root.join(METRICS_SNAPSHOT);
        if bless {
            std::fs::write(&snap_path, &rendered)
                .map_err(|e| format!("cannot write {}: {e}", snap_path.display()))?;
            println!("xtask lint: blessed {METRICS_SNAPSHOT}");
        } else {
            match std::fs::read_to_string(&snap_path) {
                Ok(committed) => {
                    check_metrics_append_only(&committed, &files, &mut violations)
                }
                Err(_) => violations.push(Violation {
                    file: METRICS_SNAPSHOT.into(),
                    line: 0,
                    rule: "metric-snapshot",
                    msg: "snapshot missing; run `cargo xtask lint --bless` and commit it"
                        .into(),
                }),
            }
        }
    }

    for v in &violations {
        eprintln!("xtask lint: {}:{}: [{}] {}", v.file, v.line, v.rule, v.msg);
    }
    Ok(violations.len())
}

// ---------------------------------------------------------------------------
// Source model
// ---------------------------------------------------------------------------

struct SourceFile {
    /// Repo-relative path with `/` separators.
    path: String,
    /// Raw lines, 0-indexed.
    raw: Vec<String>,
    /// Comment- and string-stripped lines, same indices as `raw`.
    code: Vec<String>,
}

#[derive(Debug)]
struct Violation {
    file: String,
    line: usize, // 1-indexed; 0 = whole file
    rule: &'static str,
    msg: String,
}

fn load_tree(root: &Path) -> Result<Vec<SourceFile>, String> {
    let mut paths = Vec::new();
    collect_rs(&root.join("rust/src"), &mut paths)
        .map_err(|e| format!("walking rust/src: {e}"))?;
    paths.sort();
    let mut files = Vec::with_capacity(paths.len());
    for p in paths {
        let text = std::fs::read_to_string(&p)
            .map_err(|e| format!("reading {}: {e}", p.display()))?;
        let rel = p
            .strip_prefix(root)
            .unwrap_or(&p)
            .to_string_lossy()
            .replace('\\', "/");
        files.push(source_file(rel, &text));
    }
    Ok(files)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

fn source_file(path: String, text: &str) -> SourceFile {
    let raw: Vec<String> = text.lines().map(str::to_string).collect();
    let code = strip_code(text);
    SourceFile { path, raw, code }
}

/// Strip `//` comments, `/* */` block comments, and the *contents* of
/// string/char literals, preserving line structure so indices map 1:1
/// onto the raw lines.
fn strip_code(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut in_block = false;
    for line in text.lines() {
        let chars: Vec<char> = line.chars().collect();
        let mut s = String::with_capacity(line.len());
        let mut i = 0;
        let mut in_str = false; // string literals in this repo never span lines
        while i < chars.len() {
            let c = chars[i];
            let next = chars.get(i + 1).copied();
            if in_block {
                if c == '*' && next == Some('/') {
                    in_block = false;
                    i += 2;
                } else {
                    i += 1;
                }
                continue;
            }
            if in_str {
                if c == '\\' {
                    i += 2; // skip the escaped char
                } else {
                    if c == '"' {
                        in_str = false;
                        s.push('"');
                    }
                    i += 1;
                }
                continue;
            }
            match c {
                '/' if next == Some('/') => break, // line comment: drop the rest
                '/' if next == Some('*') => {
                    in_block = true;
                    i += 2;
                }
                '"' => {
                    in_str = true;
                    s.push('"');
                    i += 1;
                }
                '\'' => {
                    // char literal ('x', '\n') vs lifetime ('a): skip the
                    // literal's contents, keep lifetimes as-is.
                    if next == Some('\\') && chars.get(i + 3) == Some(&'\'') {
                        s.push('\'');
                        s.push('\'');
                        i += 4;
                    } else if chars.get(i + 2) == Some(&'\'') {
                        s.push('\'');
                        s.push('\'');
                        i += 3;
                    } else {
                        s.push('\'');
                        i += 1;
                    }
                }
                _ => {
                    s.push(c);
                    i += 1;
                }
            }
        }
        out.push(s);
    }
    out
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// `haystack` contains `token` with identifier boundaries on both sides.
fn contains_token(haystack: &str, token: &str) -> bool {
    find_token(haystack, token, 0).is_some()
}

fn find_token(haystack: &str, token: &str, from: usize) -> Option<usize> {
    let mut start = from;
    while start <= haystack.len() {
        let pos = haystack[start..].find(token)? + start;
        let before_ok =
            pos == 0 || !is_ident_char(haystack[..pos].chars().next_back().unwrap());
        let after = pos + token.len();
        let after_ok =
            after >= haystack.len() || !is_ident_char(haystack[after..].chars().next().unwrap());
        if before_ok && after_ok {
            return Some(pos);
        }
        start = pos + token.len().max(1);
    }
    None
}

fn get<'a>(files: &'a [SourceFile], path: &str) -> Option<&'a SourceFile> {
    files.iter().find(|f| f.path == path)
}

// ---------------------------------------------------------------------------
// Check 1 — append-only wire snapshot
// ---------------------------------------------------------------------------

/// Parse the variant names of `enum_name` from stripped code lines:
/// lines whose brace depth (relative to the enum's opening `{`) is 1 and
/// that begin with an uppercase identifier.
fn enum_variants(code: &[String], enum_name: &str) -> Option<Vec<String>> {
    let decl = format!("enum {enum_name}");
    let start = code.iter().position(|l| l.contains(&decl))?;
    let mut depth = 0i32;
    let mut entered = false;
    let mut variants = Vec::new();
    for line in &code[start..] {
        let depth_at_start = depth;
        for ch in line.chars() {
            match ch {
                '{' => depth += 1,
                '}' => depth -= 1,
                _ => {}
            }
        }
        if !entered {
            if depth > 0 {
                entered = true;
            }
            continue;
        }
        if depth_at_start == 1 {
            if let Some(name) = leading_variant_ident(line) {
                variants.push(name);
            }
        }
        if depth <= 0 {
            break;
        }
    }
    Some(variants)
}

fn leading_variant_ident(line: &str) -> Option<String> {
    let t = line.trim_start();
    if t.starts_with('#') {
        return None; // attribute, e.g. #[non_exhaustive]
    }
    let ident: String = t.chars().take_while(|&c| is_ident_char(c)).collect();
    let first = ident.chars().next()?;
    if !first.is_ascii_uppercase() {
        return None;
    }
    let rest = t[ident.len()..].trim_start();
    if rest.is_empty()
        || rest.starts_with(',')
        || rest.starts_with('{')
        || rest.starts_with('(')
        || rest.starts_with('=')
    {
        Some(ident)
    } else {
        None
    }
}

/// The value text of `pub const NAME … = value;` — matched on stripped
/// code, extracted from the raw line (string contents survive there).
fn const_value(f: &SourceFile, name: &str) -> Option<(usize, String)> {
    for (i, code) in f.code.iter().enumerate() {
        if contains_token(code, "const") && contains_token(code, name) && code.contains('=') {
            let raw = &f.raw[i];
            let eq = raw.find('=')?;
            let v = raw[eq + 1..].trim().trim_end_matches(';').trim().to_string();
            return Some((i + 1, v));
        }
    }
    None
}

/// Current wire-protocol state rendered in the snapshot format, or
/// `None` (with violations pushed) when protocol.rs is unparseable.
fn render_wire_snapshot(files: &[SourceFile], violations: &mut Vec<Violation>) -> Option<String> {
    let Some(proto) = get(files, PROTOCOL_RS) else {
        violations.push(Violation {
            file: PROTOCOL_RS.into(),
            line: 0,
            rule: "wire-snapshot",
            msg: "file not found".into(),
        });
        return None;
    };
    let mut missing = Vec::new();
    let errors = enum_variants(&proto.code, "ErrorCode").unwrap_or_else(|| {
        missing.push("enum ErrorCode");
        Vec::new()
    });
    let requests = enum_variants(&proto.code, "Request").unwrap_or_else(|| {
        missing.push("enum Request");
        Vec::new()
    });
    let pv = const_value(proto, "PROTOCOL_VERSION").map(|(_, v)| v).unwrap_or_else(|| {
        missing.push("PROTOCOL_VERSION");
        String::new()
    });
    let mpv = const_value(proto, "MIN_PROTOCOL_VERSION").map(|(_, v)| v).unwrap_or_else(|| {
        missing.push("MIN_PROTOCOL_VERSION");
        String::new()
    });
    if !missing.is_empty() {
        violations.push(Violation {
            file: PROTOCOL_RS.into(),
            line: 0,
            rule: "wire-snapshot",
            msg: format!("cannot parse: {}", missing.join(", ")),
        });
        return None;
    }
    let mut s = String::new();
    s.push_str(
        "# Committed wire-protocol snapshot — the append-only contract for\n\
         # rust/src/serve/protocol.rs. `cargo xtask lint` fails if the live\n\
         # `ErrorCode` / `Request` enums reorder, rename, or drop anything listed\n\
         # here (appending new variants at the END is allowed), or if the\n\
         # protocol version constants drift. To intentionally extend the\n\
         # protocol: append the new variants, then re-bless this file with\n\
         # `cargo xtask lint --bless` in the same commit.\n\n",
    );
    s.push_str(&format!("protocol_version = {pv}\n"));
    s.push_str(&format!("min_protocol_version = {mpv}\n"));
    s.push_str("\n[ErrorCode]\n");
    for v in &errors {
        s.push_str(v);
        s.push('\n');
    }
    s.push_str("\n[Request]\n");
    for v in &requests {
        s.push_str(v);
        s.push('\n');
    }
    Some(s)
}

/// Parse a snapshot file into (key=value pairs, per-section variant lists).
fn parse_snapshot(text: &str) -> (BTreeMap<String, String>, BTreeMap<String, Vec<String>>) {
    let mut kv = BTreeMap::new();
    let mut sections: BTreeMap<String, Vec<String>> = BTreeMap::new();
    let mut current: Option<String> = None;
    for line in text.lines() {
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        if let Some(name) = t.strip_prefix('[').and_then(|r| r.strip_suffix(']')) {
            current = Some(name.to_string());
            sections.entry(name.to_string()).or_default();
        } else if let Some(section) = &current {
            sections.get_mut(section).expect("section exists").push(t.to_string());
        } else if let Some((k, v)) = t.split_once('=') {
            kv.insert(k.trim().to_string(), v.trim().to_string());
        }
    }
    (kv, sections)
}

fn check_wire_append_only(
    committed: &str,
    files: &[SourceFile],
    violations: &mut Vec<Violation>,
) {
    let Some(proto) = get(files, PROTOCOL_RS) else { return };
    let (kv, sections) = parse_snapshot(committed);
    for (enum_name, live) in [
        ("ErrorCode", enum_variants(&proto.code, "ErrorCode").unwrap_or_default()),
        ("Request", enum_variants(&proto.code, "Request").unwrap_or_default()),
    ] {
        let Some(snap) = sections.get(enum_name) else {
            violations.push(Violation {
                file: WIRE_SNAPSHOT.into(),
                line: 0,
                rule: "wire-append-only",
                msg: format!("snapshot has no [{enum_name}] section; re-bless"),
            });
            continue;
        };
        if live.len() < snap.len() {
            violations.push(Violation {
                file: PROTOCOL_RS.into(),
                line: 0,
                rule: "wire-append-only",
                msg: format!(
                    "{enum_name} lost variants: snapshot has {}, source has {} — \
                     removing wire variants breaks deployed clients",
                    snap.len(),
                    live.len()
                ),
            });
            continue;
        }
        for (i, want) in snap.iter().enumerate() {
            if &live[i] != want {
                violations.push(Violation {
                    file: PROTOCOL_RS.into(),
                    line: 0,
                    rule: "wire-append-only",
                    msg: format!(
                        "{enum_name} variant {i} is {:?}, snapshot says {want:?} — \
                         variants are append-only (append at the end, never \
                         reorder/rename; `--bless` only for intentional extensions)",
                        live[i]
                    ),
                });
                break;
            }
        }
    }
    for (key, const_name) in [
        ("protocol_version", "PROTOCOL_VERSION"),
        ("min_protocol_version", "MIN_PROTOCOL_VERSION"),
    ] {
        let live = const_value(proto, const_name).map(|(_, v)| v);
        let snap = kv.get(key);
        if live.as_deref() != snap.map(String::as_str) {
            violations.push(Violation {
                file: PROTOCOL_RS.into(),
                line: 0,
                rule: "wire-append-only",
                msg: format!(
                    "{const_name} is {:?} but the snapshot pins {:?} — protocol \
                     version changes must be blessed deliberately",
                    live.unwrap_or_default(),
                    snap.cloned().unwrap_or_default()
                ),
            });
        }
    }
}

// ---------------------------------------------------------------------------
// Check 2 — artifact format constants agree with docs
// ---------------------------------------------------------------------------

fn check_format_constants(files: &[SourceFile], violations: &mut Vec<Violation>) {
    let mut push = |file: &str, line: usize, msg: String| {
        violations.push(Violation { file: file.into(), line, rule: "format-constants", msg });
    };

    // Pull every constant; a missing one is itself a violation (the
    // contract lives in these names).
    let mut consts: BTreeMap<&str, (String, usize, String)> = BTreeMap::new();
    for (path, names) in [
        (PROTOCOL_RS, &["PROTOCOL_VERSION", "MIN_PROTOCOL_VERSION"][..]),
        (
            INDEX_RS,
            &[
                "INDEX_FORMAT",
                "INDEX_FORMAT_VERSION",
                "INDEX_MIN_FORMAT_VERSION",
                "SPILL_FORMAT",
                "SPILL_FORMAT_VERSION",
            ][..],
        ),
        ("rust/src/ingest/mod.rs", &["SEGSET_FORMAT", "SEGSET_FORMAT_VERSION"][..]),
    ] {
        let Some(f) = get(files, path) else {
            push(path, 0, "file not found".into());
            continue;
        };
        for name in names {
            match const_value(f, name) {
                Some((line, v)) => {
                    consts.insert(name, (path.to_string(), line, v));
                }
                None => push(path, 0, format!("constant {name} not found")),
            }
        }
    }
    let int = |name: &str| -> Option<u64> {
        consts.get(name).and_then(|(_, _, v)| v.parse().ok())
    };
    let strv = |name: &str| -> Option<String> {
        consts.get(name).map(|(_, _, v)| v.trim_matches('"').to_string())
    };

    // min ≤ current, for every versioned surface that has a min.
    for (min, cur) in [
        ("MIN_PROTOCOL_VERSION", "PROTOCOL_VERSION"),
        ("INDEX_MIN_FORMAT_VERSION", "INDEX_FORMAT_VERSION"),
    ] {
        if let (Some(lo), Some(hi)) = (int(min), int(cur)) {
            if lo > hi {
                let (path, line, _) = &consts[min];
                push(path, *line, format!("{min} ({lo}) exceeds {cur} ({hi})"));
            }
        }
    }

    // Doc claims "currently N" in the serve layer must equal
    // PROTOCOL_VERSION.
    if let Some(pv) = int("PROTOCOL_VERSION") {
        for path in [PROTOCOL_RS, "rust/src/serve/mod.rs"] {
            let Some(f) = get(files, path) else { continue };
            for (i, raw) in f.raw.iter().enumerate() {
                let Some(pos) = raw.find("currently ") else { continue };
                let digits: String = raw[pos + "currently ".len()..]
                    .chars()
                    .take_while(char::is_ascii_digit)
                    .collect();
                if let Ok(n) = digits.parse::<u64>() {
                    if n != pv {
                        push(
                            path,
                            i + 1,
                            format!(
                                "docs say the protocol version is currently {n}, \
                                 PROTOCOL_VERSION is {pv}"
                            ),
                        );
                    }
                }
            }
        }
    }

    // The format names quoted in module docs must match the constants.
    for (const_name, doc_path) in [
        ("INDEX_FORMAT", "rust/src/query/mod.rs"),
        ("SPILL_FORMAT", "rust/src/query/mod.rs"),
        ("SEGSET_FORMAT", "rust/src/ingest/mod.rs"),
    ] {
        let Some(fmt) = strv(const_name) else { continue };
        let Some(doc) = get(files, doc_path) else { continue };
        if !doc.raw.iter().any(|l| l.contains(&fmt)) {
            let (path, line, _) = &consts[const_name];
            push(
                path,
                *line,
                format!(
                    "{const_name} = {fmt:?} is never mentioned in {doc_path}'s \
                     module docs — the documented format contract drifted"
                ),
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Check 2b — seqindex manifest key set is append-only without a version bump
// ---------------------------------------------------------------------------

/// Content of the first `"…"` literal on a raw source line.
fn first_string_literal(raw: &str) -> Option<String> {
    let a = raw.find('"')?;
    let b = raw[a + 1..].find('"')? + a + 1;
    Some(raw[a + 1..b].to_string())
}

/// Top-level keys written into the seqindex `manifest.json` by
/// `write_tables_and_manifest` in `rust/src/query/index.rs`: the tuple
/// keys inside the `fields` vec literal (square-bracket depth 1 — the
/// nested per-file `Json::obj(vec![…])` keys sit at depth 2) plus every
/// later `fields.push(…)` site, up to `Json::obj(fields)`. Returned
/// sorted + deduplicated: the manifest serializes through a `BTreeMap`,
/// so key *sets*, never positions, are the contract.
fn manifest_keys(f: &SourceFile) -> Option<Vec<String>> {
    let start = f.code.iter().position(|l| l.contains("let mut fields = vec!["))?;
    let end = start
        + f.code[start..].iter().position(|l| l.contains("Json::obj(fields)"))?;
    let mut keys = Vec::new();
    let mut depth = 0i32; // square brackets only: vec! nesting
    let mut in_outer_vec = true; // until the `fields` literal's `];`
    let mut pending_push = false;
    for i in start..end {
        let code = &f.code[i];
        let depth_at_start = depth;
        for ch in code.chars() {
            match ch {
                '[' => depth += 1,
                ']' => depth -= 1,
                _ => {}
            }
        }
        if code.contains("fields.push(") {
            pending_push = true;
        }
        let t = code.trim_start();
        // Inside the vec literal: a tuple key sits at depth 1 (nested
        // per-file objects open their own vec! and sit at depth 2).
        // After it closes: keys only come from `fields.push(…)` sites,
        // read at depth 0 before any nested vec! reopens.
        let in_vec_key = in_outer_vec
            && depth_at_start == 1
            && (t.starts_with("(\"") || t.starts_with('"'));
        if in_vec_key || (pending_push && depth_at_start == 0) {
            if let Some(k) = first_string_literal(&f.raw[i]) {
                keys.push(k);
                pending_push = false;
            }
        }
        if i > start && in_outer_vec && depth == 0 {
            in_outer_vec = false;
        }
    }
    if keys.is_empty() {
        return None;
    }
    keys.sort();
    keys.dedup();
    Some(keys)
}

/// Current manifest key contract rendered in the snapshot format, or
/// `None` (with violations pushed) when index.rs is unparseable.
fn render_manifest_snapshot(
    files: &[SourceFile],
    violations: &mut Vec<Violation>,
) -> Option<String> {
    let mut fail = |msg: &str| {
        violations.push(Violation {
            file: INDEX_RS.into(),
            line: 0,
            rule: "manifest-keys",
            msg: msg.into(),
        });
    };
    let Some(idx) = get(files, INDEX_RS) else {
        fail("file not found");
        return None;
    };
    let Some(keys) = manifest_keys(idx) else {
        fail("cannot locate the manifest `fields` literal in write_tables_and_manifest");
        return None;
    };
    let Some((_, version)) = const_value(idx, "INDEX_FORMAT_VERSION") else {
        fail("INDEX_FORMAT_VERSION not found");
        return None;
    };
    let mut s = String::new();
    s.push_str(
        "# Committed seqindex manifest key set — the compatibility contract for\n\
         # manifest.json written by rust/src/query/index.rs. Readers parse keys\n\
         # by NAME and ignore unknown ones, so APPENDING a new key is allowed\n\
         # without an INDEX_FORMAT_VERSION bump (re-bless with\n\
         # `cargo xtask lint --bless` in the same commit). Dropping or renaming\n\
         # a key listed here while the version stays put breaks deployed\n\
         # readers and fails the lint; such a change demands a version bump.\n\
         # Key SETS are compared, never positions — the manifest serializes\n\
         # through a BTreeMap, so ordering carries no information.\n\n",
    );
    s.push_str(&format!("index_format_version = {version}\n"));
    s.push_str("\n[ManifestKeys]\n");
    for k in &keys {
        s.push_str(k);
        s.push('\n');
    }
    Some(s)
}

fn check_manifest_append_only(
    committed: &str,
    files: &[SourceFile],
    violations: &mut Vec<Violation>,
) {
    let Some(idx) = get(files, INDEX_RS) else { return };
    let live_keys = manifest_keys(idx).unwrap_or_default();
    let live_version =
        const_value(idx, "INDEX_FORMAT_VERSION").map(|(_, v)| v).unwrap_or_default();
    let (kv, sections) = parse_snapshot(committed);
    let Some(snap_keys) = sections.get("ManifestKeys") else {
        violations.push(Violation {
            file: MANIFEST_SNAPSHOT.into(),
            line: 0,
            rule: "manifest-keys",
            msg: "snapshot has no [ManifestKeys] section; re-bless".into(),
        });
        return;
    };
    let snap_version = kv.get("index_format_version").cloned().unwrap_or_default();
    if live_version != snap_version {
        // A deliberate format-version bump may reshape the key set
        // freely — but must be blessed in the same commit.
        violations.push(Violation {
            file: INDEX_RS.into(),
            line: 0,
            rule: "manifest-keys",
            msg: format!(
                "INDEX_FORMAT_VERSION is {live_version:?} but the snapshot pins \
                 {snap_version:?} — format version changes must be blessed deliberately"
            ),
        });
        return;
    }
    // Same version: existing keys are frozen. New keys in the source that
    // the snapshot has not seen yet are ACCEPTED without a version bump
    // (append-only evolution); a snapshot key missing from the source is
    // a silent format break.
    for want in snap_keys {
        if !live_keys.iter().any(|k| k == want) {
            violations.push(Violation {
                file: INDEX_RS.into(),
                line: 0,
                rule: "manifest-keys",
                msg: format!(
                    "manifest key {want:?} vanished while INDEX_FORMAT_VERSION stayed \
                     {live_version} — existing keys are frozen; only appending new \
                     keys is allowed without a version bump"
                ),
            });
        }
    }
}

// ---------------------------------------------------------------------------
// Check 3 — determinism bans in mining/sparsity/query/ingest
// ---------------------------------------------------------------------------

/// Index of the first test-module line (`#[cfg(test…)]`), if any. The
/// repo convention keeps test modules at the bottom of each file, so
/// everything from here on is exempt from the determinism bans.
fn first_test_line(code: &[String]) -> usize {
    code.iter()
        .position(|l| l.contains("#[cfg(test") || l.contains("#[cfg(all(test"))
        .unwrap_or(code.len())
}

/// `line` (0-indexed) carries a `lint:allow(rule)` marker on itself or
/// within the five raw lines above it.
fn suppressed(f: &SourceFile, line: usize, rule: &str) -> bool {
    let marker = format!("lint:allow({rule})");
    let lo = line.saturating_sub(5);
    f.raw[lo..=line].iter().any(|l| l.contains(&marker))
}

/// Identifiers declared with a `HashMap<…>` type in this file: the
/// identifier immediately before the `: HashMap<` type ascription
/// (covers `let`, `let mut`, struct fields, and function parameters).
fn hashmap_idents(code: &[String]) -> Vec<String> {
    let mut names = Vec::new();
    for line in code {
        let mut from = 0;
        while let Some(pos) = line[from..].find("HashMap<").map(|p| p + from) {
            from = pos + "HashMap<".len();
            let prefix = line[..pos].trim_end();
            // type ascription: `name: HashMap<…>` (reject paths `::`)
            let Some(p) = prefix.strip_suffix(':') else { continue };
            if p.ends_with(':') {
                continue;
            }
            let ident: String = p
                .chars()
                .rev()
                .take_while(|&c| is_ident_char(c))
                .collect::<String>()
                .chars()
                .rev()
                .collect();
            if !ident.is_empty() && !names.contains(&ident) {
                names.push(ident);
            }
        }
    }
    names
}

/// Iteration over `name` on this stripped line: a method whose order is
/// the map's internal order, or a `for … in name` loop.
fn iterates_map(code: &str, name: &str) -> bool {
    const METHODS: [&str; 7] =
        [".iter()", ".iter_mut()", ".values()", ".values_mut()", ".keys()", ".into_iter()", ".drain("];
    let mut from = 0;
    while let Some(pos) = find_token(code, name, from) {
        from = pos + name.len();
        let after = &code[pos + name.len()..];
        if METHODS.iter().any(|m| after.starts_with(m)) {
            return true;
        }
        let before = code[..pos].trim_end();
        let before = before.strip_suffix("&mut").unwrap_or(before).trim_end();
        let before = before.strip_suffix('&').unwrap_or(before).trim_end();
        if before.ends_with("in")
            && before[..before.len() - 2]
                .chars()
                .next_back()
                .is_none_or(|c| !is_ident_char(c))
        {
            return true;
        }
    }
    false
}

fn check_determinism(files: &[SourceFile], violations: &mut Vec<Violation>) {
    for f in files {
        if !DETERMINISTIC_DIRS.iter().any(|d| f.path.starts_with(d)) {
            continue;
        }
        let limit = first_test_line(&f.code);
        let maps = hashmap_idents(&f.code[..limit]);
        for (i, code) in f.code[..limit].iter().enumerate() {
            if contains_token(code, "SystemTime") && code.contains("SystemTime::now") {
                if !suppressed(f, i, "system_time") {
                    violations.push(Violation {
                        file: f.path.clone(),
                        line: i + 1,
                        rule: "no-system-time",
                        msg: "SystemTime::now in a deterministic-output module — \
                              output must not depend on the clock"
                            .into(),
                    });
                }
                continue;
            }
            for name in &maps {
                if iterates_map(code, name) && !suppressed(f, i, "hashmap_iter") {
                    violations.push(Violation {
                        file: f.path.clone(),
                        line: i + 1,
                        rule: "no-hashmap-iter",
                        msg: format!(
                            "iteration over HashMap `{name}` in a deterministic-output \
                             module — iteration order is randomized per process; sort \
                             first, use a BTreeMap, or annotate the line above with \
                             `// lint:allow(hashmap_iter)` and a proof of order-\
                             insensitivity"
                        ),
                    });
                    break;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Check 4 — unsafe audit
// ---------------------------------------------------------------------------

fn parse_allowlist(text: &str) -> BTreeMap<String, usize> {
    let mut out = BTreeMap::new();
    for line in text.lines() {
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        if let Some((path, n)) = t.split_once('=') {
            if let Ok(n) = n.trim().parse() {
                out.insert(path.trim().to_string(), n);
            }
        }
    }
    out
}

fn check_unsafe(files: &[SourceFile], allowlist_text: &str, violations: &mut Vec<Violation>) {
    let allow = parse_allowlist(allowlist_text);
    for f in files {
        let mut count = 0usize;
        for (i, code) in f.code.iter().enumerate() {
            if !contains_token(code, "unsafe") {
                continue;
            }
            count += 1;
            let lo = i.saturating_sub(5);
            if !f.raw[lo..=i].iter().any(|l| l.contains("SAFETY:")) {
                violations.push(Violation {
                    file: f.path.clone(),
                    line: i + 1,
                    rule: "unsafe-undocumented",
                    msg: "`unsafe` without a `// SAFETY:` comment in the five lines \
                          above it"
                        .into(),
                });
            }
        }
        let budget = allow.get(&f.path).copied().unwrap_or(0);
        if count > budget {
            violations.push(Violation {
                file: f.path.clone(),
                line: 0,
                rule: "unsafe-allowlist",
                msg: format!(
                    "{count} `unsafe` occurrence(s), allowlist budget is {budget} \
                     ({UNSAFE_ALLOWLIST}) — adding unsafe is a review decision, \
                     grow the budget in the same commit or write safe code"
                ),
            });
        }
    }
}

// ---------------------------------------------------------------------------
// Check 5 — append-only metric-name snapshot
// ---------------------------------------------------------------------------

/// `[a-z][a-z0-9_]*` — mirrors `obs::metrics::valid_metric_name`
/// (xtask is dependency-free, so the rule is restated, and check 5
/// guarantees the two can never disagree about committed names).
fn valid_metric_name(name: &str) -> bool {
    let bytes = name.as_bytes();
    match bytes.first() {
        Some(b) if b.is_ascii_lowercase() => {}
        _ => return false,
    }
    bytes[1..]
        .iter()
        .all(|b| b.is_ascii_lowercase() || b.is_ascii_digit() || *b == b'_')
}

/// The `(line, "value")` of every `pub const NAME: &str = "value";` in
/// `f`, in declaration order — declaration order IS the snapshot order.
fn metric_names(f: &SourceFile) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    for (i, code) in f.code.iter().enumerate() {
        if !(contains_token(code, "const") && code.contains("&str") && code.contains('=')) {
            continue;
        }
        let raw = &f.raw[i];
        let Some(eq) = raw.find('=') else { continue };
        let rest = &raw[eq + 1..];
        let Some(q1) = rest.find('"') else { continue };
        let Some(q2) = rest[q1 + 1..].find('"') else { continue };
        out.push((i + 1, rest[q1 + 1..q1 + 1 + q2].to_string()));
    }
    out
}

/// Current metric names rendered in the snapshot format, validating the
/// naming rule along the way; `None` (with violations pushed) when
/// names.rs is missing or empty.
fn render_metrics_snapshot(
    files: &[SourceFile],
    violations: &mut Vec<Violation>,
) -> Option<String> {
    let Some(f) = get(files, NAMES_RS) else {
        violations.push(Violation {
            file: NAMES_RS.into(),
            line: 0,
            rule: "metric-snapshot",
            msg: "file not found".into(),
        });
        return None;
    };
    let names = metric_names(f);
    if names.is_empty() {
        violations.push(Violation {
            file: NAMES_RS.into(),
            line: 0,
            rule: "metric-snapshot",
            msg: "no `pub const NAME: &str = \"…\";` metric names found".into(),
        });
        return None;
    }
    for (line, name) in &names {
        if !valid_metric_name(name) {
            violations.push(Violation {
                file: NAMES_RS.into(),
                line: *line,
                rule: "metric-name",
                msg: format!(
                    "metric name {name:?} violates the exposition naming rule \
                     [a-z][a-z0-9_]*"
                ),
            });
        }
    }
    let mut s = String::new();
    s.push_str(
        "# Committed metric-name snapshot — the append-only contract for\n\
         # rust/src/obs/names.rs. Exposition names are a public scrape surface:\n\
         # `cargo xtask lint` fails if a name listed here is renamed, removed,\n\
         # or reordered (appending new names at the END is allowed), or if any\n\
         # name violates [a-z][a-z0-9_]*. To add a metric: append its constant\n\
         # to names.rs, then re-bless this file with `cargo xtask lint --bless`\n\
         # in the same commit.\n\n",
    );
    for (_, name) in &names {
        s.push_str(name);
        s.push('\n');
    }
    Some(s)
}

fn check_metrics_append_only(
    committed: &str,
    files: &[SourceFile],
    violations: &mut Vec<Violation>,
) {
    let Some(f) = get(files, NAMES_RS) else { return };
    let live: Vec<String> = metric_names(f).into_iter().map(|(_, n)| n).collect();
    let snap: Vec<&str> = committed
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .collect();
    if live.len() < snap.len() {
        violations.push(Violation {
            file: NAMES_RS.into(),
            line: 0,
            rule: "metric-append-only",
            msg: format!(
                "lost metric names: snapshot has {}, source has {} — renaming or \
                 removing an exposition name breaks every scraper",
                snap.len(),
                live.len()
            ),
        });
        return;
    }
    for (i, want) in snap.iter().enumerate() {
        if live[i] != *want {
            violations.push(Violation {
                file: NAMES_RS.into(),
                line: 0,
                rule: "metric-append-only",
                msg: format!(
                    "metric name {i} is {:?}, snapshot says {want:?} — names are \
                     append-only (append at the end, never reorder/rename; \
                     `--bless` only for intentional extensions)",
                    live[i]
                ),
            });
            break;
        }
    }
}

// ---------------------------------------------------------------------------
// Tests — each acceptance-criteria seeded violation has a case here.
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    const PROTO_SRC: &str = r#"
//! header doc mentioning unsafe and HashMap freely.
pub const PROTOCOL_VERSION: u8 = 1;
pub const MIN_PROTOCOL_VERSION: u8 = 1;

/// Wire error codes.
pub enum ErrorCode {
    /// The frame itself was malformed.
    BadFrame,
    UnsupportedVersion,
    Internal,
}

pub enum Request {
    Ping,
    Stats { artifact: Option<String> },
    PatientsWith {
        artifact: Option<String>,
        seq: u64,
    },
    Shutdown,
}
"#;

    fn proto_file() -> SourceFile {
        source_file(PROTOCOL_RS.to_string(), PROTO_SRC)
    }

    #[test]
    fn enum_parser_reads_variants_in_order() {
        let f = proto_file();
        assert_eq!(
            enum_variants(&f.code, "ErrorCode").unwrap(),
            vec!["BadFrame", "UnsupportedVersion", "Internal"]
        );
        assert_eq!(
            enum_variants(&f.code, "Request").unwrap(),
            vec!["Ping", "Stats", "PatientsWith", "Shutdown"],
            "struct-variant fields are not variants"
        );
        assert_eq!(const_value(&f, "PROTOCOL_VERSION").unwrap().1, "1");
    }

    #[test]
    fn snapshot_round_trip_passes() {
        let files = vec![proto_file()];
        let mut v = Vec::new();
        let rendered = render_wire_snapshot(&files, &mut v).unwrap();
        assert!(v.is_empty(), "{v:?}");
        check_wire_append_only(&rendered, &files, &mut v);
        assert!(v.is_empty(), "a freshly blessed snapshot must pass: {v:?}");
        // Appending a variant at the end still passes (append-only).
        let extended = PROTO_SRC.replace("    Internal,\n", "    Internal,\n    Shed,\n");
        let files = vec![source_file(PROTOCOL_RS.to_string(), &extended)];
        let mut v = Vec::new();
        check_wire_append_only(&rendered, &files, &mut v);
        assert!(v.is_empty(), "appending at the end is allowed: {v:?}");
    }

    /// Seeded violation 1: a reordered `ErrorCode` variant fails.
    #[test]
    fn reordered_error_code_variant_fails() {
        let files = vec![proto_file()];
        let mut v = Vec::new();
        let rendered = render_wire_snapshot(&files, &mut v).unwrap();
        let reordered = PROTO_SRC.replace(
            "    BadFrame,\n    UnsupportedVersion,",
            "    UnsupportedVersion,\n    BadFrame,",
        );
        assert_ne!(reordered, PROTO_SRC, "seed applied");
        let files = vec![source_file(PROTOCOL_RS.to_string(), &reordered)];
        let mut v = Vec::new();
        check_wire_append_only(&rendered, &files, &mut v);
        assert!(
            v.iter().any(|v| v.rule == "wire-append-only" && v.msg.contains("ErrorCode")),
            "{v:?}"
        );
        // Removing a variant fails too.
        let removed = PROTO_SRC.replace("    UnsupportedVersion,\n", "");
        let files = vec![source_file(PROTOCOL_RS.to_string(), &removed)];
        let mut v = Vec::new();
        check_wire_append_only(&rendered, &files, &mut v);
        assert!(v.iter().any(|v| v.msg.contains("lost variants")), "{v:?}");
        // A version bump without a bless fails.
        let bumped = PROTO_SRC.replace("PROTOCOL_VERSION: u8 = 1", "PROTOCOL_VERSION: u8 = 2");
        let files = vec![source_file(PROTOCOL_RS.to_string(), &bumped)];
        let mut v = Vec::new();
        check_wire_append_only(&rendered, &files, &mut v);
        assert!(v.iter().any(|v| v.msg.contains("PROTOCOL_VERSION")), "{v:?}");
    }

    const INDEX_SRC: &str = r#"
pub const INDEX_FORMAT: &str = "tspm-seqindex";
pub const INDEX_FORMAT_VERSION: u64 = 2;

fn write_tables_and_manifest() {
    let mut fields = vec![
        ("format", Json::from(INDEX_FORMAT)),
        ("version", Json::from(version)),
        ("total_records", Json::from(written)),
        (
            "data",
            Json::obj(vec![
                ("name", Json::from(DATA_FILE)),
                ("checksum", Json::from(data_checksum)),
            ]),
        ),
    ];
    if let Some((entries, pdata_checksum)) = &pid_table {
        fields.push((
            "pids",
            Json::obj(vec![
                ("name", Json::from(PIDS_FILE)),
                ("checksum", Json::from(pids_checksum)),
            ]),
        ));
    }
    if let Some(t) = target {
        fields.push(("target", t.to_json()));
    }
    let manifest = Json::obj(fields);
}
"#;

    fn index_file(src: &str) -> SourceFile {
        source_file(INDEX_RS.to_string(), src)
    }

    #[test]
    fn manifest_key_parser_sees_top_level_keys_only() {
        let keys = manifest_keys(&index_file(INDEX_SRC)).unwrap();
        // Nested per-file keys (name/checksum) must NOT appear; push
        // sites (single- and multi-line) must.
        assert_eq!(keys, vec!["data", "format", "pids", "target", "total_records", "version"]);
    }

    /// Seeded violations for the manifest-key contract: an append-only
    /// key addition without a version bump passes; dropping or renaming
    /// an existing key fails; an unblessed version bump fails.
    #[test]
    fn manifest_key_set_is_append_only_without_version_bump() {
        let files = vec![index_file(INDEX_SRC)];
        let mut v = Vec::new();
        let rendered = render_manifest_snapshot(&files, &mut v).unwrap();
        assert!(v.is_empty(), "{v:?}");
        check_manifest_append_only(&rendered, &files, &mut v);
        assert!(v.is_empty(), "a freshly blessed snapshot must pass: {v:?}");

        // Appending a NEW key with the version unchanged is accepted.
        let added = INDEX_SRC.replace(
            "    let manifest = Json::obj(fields);",
            "    fields.push((\"provenance\", Json::from(1u64)));\n    \
             let manifest = Json::obj(fields);",
        );
        assert_ne!(added, INDEX_SRC, "seed applied");
        let files = vec![index_file(&added)];
        let mut v = Vec::new();
        check_manifest_append_only(&rendered, &files, &mut v);
        assert!(v.is_empty(), "append-only key addition must pass: {v:?}");

        // Renaming an existing key with the version unchanged fails.
        let renamed = INDEX_SRC.replace("(\"total_records\",", "(\"record_total\",");
        assert_ne!(renamed, INDEX_SRC, "seed applied");
        let files = vec![index_file(&renamed)];
        let mut v = Vec::new();
        check_manifest_append_only(&rendered, &files, &mut v);
        assert!(
            v.iter().any(|v| v.rule == "manifest-keys" && v.msg.contains("total_records")),
            "{v:?}"
        );

        // Dropping a push-site key fails the same way.
        let dropped = INDEX_SRC.replace(
            "    if let Some(t) = target {\n        fields.push((\"target\", t.to_json()));\n    }\n",
            "",
        );
        assert_ne!(dropped, INDEX_SRC, "seed applied");
        let files = vec![index_file(&dropped)];
        let mut v = Vec::new();
        check_manifest_append_only(&rendered, &files, &mut v);
        assert!(v.iter().any(|v| v.msg.contains("\"target\"")), "{v:?}");

        // A version bump without a bless fails.
        let bumped =
            INDEX_SRC.replace("INDEX_FORMAT_VERSION: u64 = 2", "INDEX_FORMAT_VERSION: u64 = 3");
        let files = vec![index_file(&bumped)];
        let mut v = Vec::new();
        check_manifest_append_only(&rendered, &files, &mut v);
        assert!(v.iter().any(|v| v.msg.contains("INDEX_FORMAT_VERSION")), "{v:?}");
    }

    /// Seeded violation 2: a new undocumented `unsafe` block fails both
    /// the SAFETY audit and the allowlist budget.
    #[test]
    fn undocumented_unsafe_fails() {
        let src = "fn f(p: *mut u8) {\n    unsafe { *p = 0 };\n}\n";
        let f = source_file("rust/src/par/mod.rs".into(), src);
        let mut v = Vec::new();
        check_unsafe(&[f], "", &mut v);
        assert!(v.iter().any(|v| v.rule == "unsafe-undocumented"), "{v:?}");
        assert!(v.iter().any(|v| v.rule == "unsafe-allowlist"), "{v:?}");

        // Documented AND budgeted: clean.
        let src = "fn f(p: *mut u8) {\n    // SAFETY: p is valid per the caller contract.\n    unsafe { *p = 0 };\n}\n";
        let f = source_file("rust/src/par/mod.rs".into(), src);
        let mut v = Vec::new();
        check_unsafe(&[f], "rust/src/par/mod.rs = 1\n", &mut v);
        assert!(v.is_empty(), "{v:?}");

        // Mentioning unsafe in comments or strings is NOT an occurrence.
        let src = "// unsafe is discussed here\nfn f() { let _ = \"unsafe\"; }\n";
        let f = source_file("rust/src/par/mod.rs".into(), src);
        let mut v = Vec::new();
        check_unsafe(&[f], "", &mut v);
        assert!(v.is_empty(), "{v:?}");
    }

    /// Seeded violation 3: HashMap iteration in `mining` fails, the
    /// suppression marker clears it, and test modules are exempt.
    #[test]
    fn hashmap_iteration_in_mining_fails() {
        let src = "use std::collections::HashMap;\n\
                   fn f() {\n\
                   \x20   let mut m: HashMap<u32, u32> = HashMap::new();\n\
                   \x20   for (k, v) in m {\n\
                   \x20       drop((k, v));\n\
                   \x20   }\n\
                   }\n";
        let f = source_file("rust/src/mining/mod.rs".into(), src);
        let mut v = Vec::new();
        check_determinism(&[f], &mut v);
        assert!(v.iter().any(|v| v.rule == "no-hashmap-iter"), "{v:?}");

        // .values() and .keys() and .iter() are equally banned.
        for call in ["m.values()", "m.keys()", "m.iter()", "m.drain(..)"] {
            let src = format!(
                "fn f() {{\n    let m: HashMap<u32, u32> = HashMap::new();\n    let _ = {call};\n}}\n"
            );
            let f = source_file("rust/src/mining/mod.rs".into(), &src);
            let mut v = Vec::new();
            check_determinism(&[f], &mut v);
            assert!(v.iter().any(|v| v.rule == "no-hashmap-iter"), "{call}: {v:?}");
        }

        // The suppression marker on the line above clears it.
        let src = "fn f() {\n\
                   \x20   let m: HashMap<u32, u32> = HashMap::new();\n\
                   \x20   // lint:allow(hashmap_iter) — summed, order-insensitive\n\
                   \x20   let _: u32 = m.values().sum();\n\
                   }\n";
        let f = source_file("rust/src/mining/mod.rs".into(), src);
        let mut v = Vec::new();
        check_determinism(&[f], &mut v);
        assert!(v.is_empty(), "{v:?}");

        // Test modules (bottom-of-file convention) are exempt.
        let src = "fn prod() {}\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                   \x20   use std::collections::HashMap;\n\
                   \x20   fn t() {\n\
                   \x20       let m: HashMap<u32, u32> = HashMap::new();\n\
                   \x20       for x in m.values() {}\n\
                   \x20   }\n\
                   }\n";
        let f = source_file("rust/src/mining/mod.rs".into(), src);
        let mut v = Vec::new();
        check_determinism(&[f], &mut v);
        assert!(v.is_empty(), "test modules are exempt: {v:?}");

        // Outside the deterministic dirs nothing fires.
        let src = "fn f() {\n    let m: HashMap<u32, u32> = HashMap::new();\n    for x in m {}\n}\n";
        let f = source_file("rust/src/metrics/mod.rs".into(), src);
        let mut v = Vec::new();
        check_determinism(&[f], &mut v);
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn system_time_in_deterministic_module_fails() {
        let src = "fn f() -> std::time::SystemTime {\n    std::time::SystemTime::now()\n}\n";
        let f = source_file("rust/src/ingest/mod.rs".into(), src);
        let mut v = Vec::new();
        check_determinism(&[f], &mut v);
        assert!(v.iter().any(|v| v.rule == "no-system-time"), "{v:?}");
    }

    #[test]
    fn format_doc_drift_fails() {
        let index = source_file(
            "rust/src/query/index.rs".into(),
            "pub const INDEX_FORMAT: &str = \"tspm-seqindex\";\n\
             pub const INDEX_FORMAT_VERSION: u64 = 2;\n\
             pub const INDEX_MIN_FORMAT_VERSION: u64 = 1;\n\
             pub const SPILL_FORMAT: &str = \"tspm-spill\";\n\
             pub const SPILL_FORMAT_VERSION: u64 = 1;\n",
        );
        let ingest = source_file(
            "rust/src/ingest/mod.rs".into(),
            "//! The manifest format is \"tspm-segset\".\n\
             pub const SEGSET_FORMAT: &str = \"tspm-segset\";\n\
             pub const SEGSET_FORMAT_VERSION: u64 = 1;\n",
        );
        let proto = proto_file();
        // query/mod.rs docs mention the spill format but NOT the index
        // format → exactly one drift violation.
        let query_mod = source_file(
            "rust/src/query/mod.rs".into(),
            "//! artifacts use \"tspm-spill\" spill manifests.\n",
        );
        let serve_mod = source_file(
            "rust/src/serve/mod.rs".into(),
            "//! byte  4      version        currently 1\n",
        );
        let files = vec![index, ingest, proto, query_mod, serve_mod];
        let mut v = Vec::new();
        check_format_constants(&files, &mut v);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].msg.contains("INDEX_FORMAT"), "{v:?}");

        // A doc claiming the wrong protocol version fails.
        let serve_mod = source_file(
            "rust/src/serve/mod.rs".into(),
            "//! byte  4      version        currently 3\n",
        );
        let files = vec![
            source_file(
                "rust/src/query/mod.rs".into(),
                "//! \"tspm-seqindex\" and \"tspm-spill\" are documented here.\n",
            ),
            source_file(
                "rust/src/query/index.rs".into(),
                "pub const INDEX_FORMAT: &str = \"tspm-seqindex\";\n\
                 pub const INDEX_FORMAT_VERSION: u64 = 2;\n\
                 pub const INDEX_MIN_FORMAT_VERSION: u64 = 1;\n\
                 pub const SPILL_FORMAT: &str = \"tspm-spill\";\n\
                 pub const SPILL_FORMAT_VERSION: u64 = 1;\n",
            ),
            source_file(
                "rust/src/ingest/mod.rs".into(),
                "//! \"tspm-segset\"\npub const SEGSET_FORMAT: &str = \"tspm-segset\";\n\
                 pub const SEGSET_FORMAT_VERSION: u64 = 1;\n",
            ),
            proto_file(),
            serve_mod,
        ];
        let mut v = Vec::new();
        check_format_constants(&files, &mut v);
        assert!(v.iter().any(|v| v.msg.contains("currently 3")), "{v:?}");
    }

    #[test]
    fn min_version_above_current_fails() {
        let bad = PROTO_SRC.replace(
            "pub const MIN_PROTOCOL_VERSION: u8 = 1;",
            "pub const MIN_PROTOCOL_VERSION: u8 = 9;",
        );
        let files = vec![
            source_file(PROTOCOL_RS.to_string(), &bad),
            source_file(
                "rust/src/query/index.rs".into(),
                "pub const INDEX_FORMAT: &str = \"x\";\n\
                 pub const INDEX_FORMAT_VERSION: u64 = 2;\n\
                 pub const INDEX_MIN_FORMAT_VERSION: u64 = 1;\n\
                 pub const SPILL_FORMAT: &str = \"y\";\n\
                 pub const SPILL_FORMAT_VERSION: u64 = 1;\n",
            ),
            source_file(
                "rust/src/ingest/mod.rs".into(),
                "//! \"z\"\npub const SEGSET_FORMAT: &str = \"z\";\n\
                 pub const SEGSET_FORMAT_VERSION: u64 = 1;\n",
            ),
            source_file("rust/src/query/mod.rs".into(), "//! \"x\" \"y\"\n"),
            source_file("rust/src/serve/mod.rs".into(), "//! nothing here\n"),
        ];
        let mut v = Vec::new();
        check_format_constants(&files, &mut v);
        assert!(
            v.iter().any(|v| v.msg.contains("MIN_PROTOCOL_VERSION")),
            "{v:?}"
        );
    }

    const NAMES_SRC: &str = "//! exposition names\n\
        /// hits\n\
        pub const CACHE_HITS: &str = \"tspm_cache_hits\";\n\
        /// misses\n\
        pub const CACHE_MISSES: &str = \"tspm_cache_misses\";\n\
        pub const SERVE_REQUESTS: &str = \"tspm_serve_requests\";\n";

    fn names_file() -> SourceFile {
        source_file(NAMES_RS.to_string(), NAMES_SRC)
    }

    #[test]
    fn metric_snapshot_round_trip_passes() {
        let files = vec![names_file()];
        let mut v = Vec::new();
        let rendered = render_metrics_snapshot(&files, &mut v).unwrap();
        assert!(v.is_empty(), "{v:?}");
        check_metrics_append_only(&rendered, &files, &mut v);
        assert!(v.is_empty(), "a freshly blessed snapshot must pass: {v:?}");
        // Appending a name at the end still passes (append-only).
        let extended =
            format!("{NAMES_SRC}pub const NEW_THING: &str = \"tspm_new_thing\";\n");
        let files = vec![source_file(NAMES_RS.to_string(), &extended)];
        let mut v = Vec::new();
        check_metrics_append_only(&rendered, &files, &mut v);
        assert!(v.is_empty(), "appending at the end is allowed: {v:?}");
    }

    /// Seeded violation: renaming or removing an exposition name fails.
    #[test]
    fn renamed_or_removed_metric_name_fails() {
        let files = vec![names_file()];
        let mut v = Vec::new();
        let rendered = render_metrics_snapshot(&files, &mut v).unwrap();
        let renamed = NAMES_SRC.replace("tspm_cache_misses", "tspm_cache_miss_total");
        assert_ne!(renamed, NAMES_SRC, "seed applied");
        let files = vec![source_file(NAMES_RS.to_string(), &renamed)];
        let mut v = Vec::new();
        check_metrics_append_only(&rendered, &files, &mut v);
        assert!(v.iter().any(|v| v.rule == "metric-append-only"), "{v:?}");

        let removed = NAMES_SRC
            .replace("pub const CACHE_MISSES: &str = \"tspm_cache_misses\";\n", "");
        let files = vec![source_file(NAMES_RS.to_string(), &removed)];
        let mut v = Vec::new();
        check_metrics_append_only(&rendered, &files, &mut v);
        assert!(v.iter().any(|v| v.msg.contains("lost metric names")), "{v:?}");
    }

    /// Seeded violation: a name outside `[a-z][a-z0-9_]*` fails even
    /// before the snapshot diff.
    #[test]
    fn invalid_metric_name_fails() {
        assert!(valid_metric_name("tspm_cache_hits"));
        assert!(valid_metric_name("a1_2"));
        assert!(!valid_metric_name(""));
        assert!(!valid_metric_name("1tspm"));
        assert!(!valid_metric_name("_tspm"));
        assert!(!valid_metric_name("TspmRequests"));
        assert!(!valid_metric_name("tspm-requests"));
        let bad = NAMES_SRC.replace("tspm_serve_requests", "TspmServeRequests");
        let files = vec![source_file(NAMES_RS.to_string(), &bad)];
        let mut v = Vec::new();
        let _ = render_metrics_snapshot(&files, &mut v);
        assert!(v.iter().any(|v| v.rule == "metric-name"), "{v:?}");
    }

    #[test]
    fn strip_code_removes_comments_and_string_contents() {
        let got = strip_code(
            "let s = \"unsafe in a string\"; // unsafe in a comment\nlet c = 'x';\n/* block\nunsafe\n*/ let d = 1;",
        );
        assert_eq!(got[0], "let s = \"\"; ");
        assert_eq!(got[1], "let c = '';");
        assert_eq!(got[2], "");
        assert_eq!(got[3], "");
        assert_eq!(got[4], " let d = 1;");
        assert!(!got.iter().any(|l| contains_token(l, "unsafe")));
    }

    #[test]
    fn allowlist_parser_reads_budgets() {
        let a = parse_allowlist("# comment\nrust/src/metrics/mod.rs = 1\n\nx/y.rs = 3\n");
        assert_eq!(a.get("rust/src/metrics/mod.rs"), Some(&1));
        assert_eq!(a.get("x/y.rs"), Some(&3));
        assert_eq!(a.len(), 2);
    }
}
