//! The tSPM+ mining engine (the paper's core contribution).
//!
//! Pipeline per the paper §Methods:
//!
//! 1. **Sort** the numeric dbmart by `(patient, date)` with the parallel
//!    samplesort ([`crate::psort`]) so each patient forms one contiguous,
//!    chronologically ordered chunk.
//! 2. **Sequence**: for every entry `x` of a patient, pair it with every
//!    later entry `y` (`y.date ≥ x.date`, `y` after `x` in order),
//!    emitting the reversible decimal hash `encode_seq(x.phenx, y.phenx)`
//!    plus the **duration** `(y.date − x.date) / unit` — the paper's new
//!    dimension. This mines `n(n−1)/2` sequences for a patient with `n`
//!    entries.
//! 3. Patient chunks are distributed over worker threads, each appending
//!    to a **thread-local vector** (avoids cache invalidation), merged at
//!    the end — or, in **file-based mode**, streamed to per-worker binary
//!    spill files ([`crate::seqstore`]) so the resident set stays tiny.
//!
//! The optional *first-occurrence-only* filter reproduces the protocol of
//! the paper's comparison benchmark (and of the earlier AD study): only
//! the first occurrence of each phenX per patient enters sequencing.

use crate::dbmart::{encode_seq, NumericDbMart, NumericEntry};
use crate::metrics::MemTracker;
use crate::par;
use crate::psort;
use crate::seqstore::{SeqFileSet, SeqWriter};
use std::path::PathBuf;

/// One mined sequence record — 16 bytes, the paper's "128 bit" layout:
/// 8 bytes sequence hash, 4 bytes patient id, 4 bytes duration.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(C)]
pub struct SeqRecord {
    /// `start_phenx * 10^7 + end_phenx` (see [`crate::dbmart::encode_seq`]).
    pub seq: u64,
    /// Dense patient id.
    pub pid: u32,
    /// Duration in the configured unit (default: days).
    pub duration: u32,
}

/// Operating mode (paper §Results: "two distinct operational modes").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MiningMode {
    /// Sequences returned as one in-memory vector.
    InMemory,
    /// Sequences spilled to per-worker binary files.
    FileBased,
}

/// Mining configuration.
#[derive(Clone, Debug)]
pub struct MiningConfig {
    /// Worker threads (0 = auto-detect, honouring `TSPM_THREADS`).
    pub threads: usize,
    /// Keep only the first occurrence of each phenX per patient.
    pub first_occurrence_only: bool,
    /// Duration divisor in days (1 = days, 7 = weeks, 30 = months).
    pub duration_unit_days: u32,
    pub mode: MiningMode,
    /// Spill directory for [`MiningMode::FileBased`].
    pub work_dir: PathBuf,
    /// Include same-phenX pairs (x → x at a later date). The paper keeps
    /// them; exposed for ablation.
    pub include_self_pairs: bool,
}

impl Default for MiningConfig {
    fn default() -> Self {
        MiningConfig {
            threads: 0,
            first_occurrence_only: false,
            duration_unit_days: 1,
            mode: MiningMode::InMemory,
            work_dir: std::env::temp_dir().join("tspm_work"),
            include_self_pairs: true,
        }
    }
}

/// In-memory mining result.
#[derive(Clone, Debug, Default)]
pub struct SequenceSet {
    pub records: Vec<SeqRecord>,
    /// Number of patients in the source dbmart (for matrix shapes).
    pub num_patients: u32,
    /// Number of distinct phenX codes in the source dbmart.
    pub num_phenx: u32,
}

impl SequenceSet {
    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Logical bytes held by the record buffer.
    pub fn byte_size(&self) -> u64 {
        (self.records.len() * std::mem::size_of::<SeqRecord>()) as u64
    }
}

/// Mining errors.
#[derive(Debug)]
pub enum MiningError {
    Io(std::io::Error),
    /// In-memory result would exceed the configured element cap
    /// (reproduces the paper's R 2³¹−1 failure mode; see
    /// [`crate::partition`] for the adaptive remedy).
    TooManySequences { mined: u64, cap: u64 },
}

impl std::fmt::Display for MiningError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MiningError::Io(e) => write!(f, "mining I/O error: {e}"),
            MiningError::TooManySequences { mined, cap } => write!(
                f,
                "mined {mined} sequences which exceeds the element cap {cap} \
                 (R dataframe limit 2^31-1); use file-based mode or adaptive partitioning"
            ),
        }
    }
}

impl std::error::Error for MiningError {}

impl From<std::io::Error> for MiningError {
    fn from(e: std::io::Error) -> Self {
        MiningError::Io(e)
    }
}

/// Sort entries by `(patient, date)` in place and return the per-patient
/// chunk boundaries `[start_0, start_1, …, len]`.
///
/// Requires patient ids to be dense (`< num_patients`), which
/// [`NumericDbMart::encode`] guarantees.
pub fn sort_and_chunk(entries: &mut [NumericEntry], threads: usize) -> Vec<usize> {
    // Composite key: patient, then date (shifted to unsigned), then phenX.
    // Including phenX makes the order — and therefore the orientation of
    // same-date pairs — fully deterministic regardless of thread count.
    // Adaptive sort: pdqsort on one worker, parallel radix otherwise.
    psort::sort_auto(
        entries,
        |e| {
            ((e.patient as u128) << 64)
                | (((e.date as i64 - i32::MIN as i64) as u128) << 32)
                | e.phenx as u128
        },
        threads,
    );
    let mut bounds = Vec::new();
    let mut prev = u32::MAX;
    for (i, e) in entries.iter().enumerate() {
        if e.patient != prev {
            bounds.push(i);
            prev = e.patient;
        }
    }
    bounds.push(entries.len());
    bounds
}

/// Number of sequences a patient chunk will produce (n·(n−1)/2).
#[inline]
pub fn pairs_for(n: usize) -> u64 {
    (n as u64) * (n as u64 - 1) / 2
}

/// Total sequences the sorted+filtered dbmart will produce. Used by
/// [`crate::partition`] for adaptive chunking and by callers to pre-size.
pub fn count_sequences(entries: &[NumericEntry], bounds: &[usize], cfg: &MiningConfig) -> u64 {
    let mut total = 0u64;
    for w in bounds.windows(2) {
        let chunk = &entries[w[0]..w[1]];
        let n = if cfg.first_occurrence_only {
            count_first_occurrences(chunk)
        } else {
            chunk.len()
        };
        if n >= 1 {
            total += pairs_for(n);
        }
    }
    total
}

fn count_first_occurrences(chunk: &[NumericEntry]) -> usize {
    // Chunks are small (hundreds); a sorted Vec dedupe avoids per-call
    // hashing overhead.
    let mut seen: Vec<u32> = chunk.iter().map(|e| e.phenx).collect();
    seen.sort_unstable();
    seen.dedup();
    seen.len()
}

/// Apply the first-occurrence filter to one sorted patient chunk,
/// appending survivors to `out` (cleared first).
fn first_occurrences(chunk: &[NumericEntry], out: &mut Vec<NumericEntry>) {
    out.clear();
    // Date-sorted input → linear scan with a seen-set keeps the earliest.
    let mut seen: Vec<u32> = Vec::with_capacity(chunk.len().min(64));
    for e in chunk {
        // Small-vector membership test beats HashSet for typical chunk
        // sizes; falls back gracefully for big chunks because `seen` is
        // kept sorted.
        match seen.binary_search(&e.phenx) {
            Ok(_) => {}
            Err(pos) => {
                seen.insert(pos, e.phenx);
                out.push(*e);
            }
        }
    }
}

/// Emit all transitive sequences for one (already filtered, date-sorted)
/// patient chunk into `sink`.
#[inline]
fn sequence_chunk(chunk: &[NumericEntry], cfg: &MiningConfig, mut sink: impl FnMut(SeqRecord)) {
    let unit = cfg.duration_unit_days.max(1);
    for i in 0..chunk.len() {
        let x = chunk[i];
        for y in &chunk[i + 1..] {
            if !cfg.include_self_pairs && y.phenx == x.phenx {
                continue;
            }
            debug_assert!(y.date >= x.date, "chunk must be date-sorted");
            let duration = ((y.date - x.date) as u32) / unit;
            sink(SeqRecord { seq: encode_seq(x.phenx, y.phenx), pid: x.patient, duration });
        }
    }
}

/// Mine all transitive sequences **in memory** (paper mode 2).
///
/// `tracker`, when provided, accounts the engine's logical peak memory
/// (entry copy + thread-local buffers + merged output).
pub fn mine_sequences(db: &NumericDbMart, cfg: &MiningConfig) -> Result<SequenceSet, MiningError> {
    mine_sequences_tracked(db, cfg, None)
}

/// [`mine_sequences`] with optional logical memory accounting.
pub fn mine_sequences_tracked(
    db: &NumericDbMart,
    cfg: &MiningConfig,
    tracker: Option<&MemTracker>,
) -> Result<SequenceSet, MiningError> {
    let threads = par::num_threads(Some(cfg.threads).filter(|&t| t > 0));
    let track = |b: u64| {
        if let Some(t) = tracker {
            t.add(b)
        }
    };
    let untrack = |b: u64| {
        if let Some(t) = tracker {
            t.sub(b)
        }
    };

    // Working copy of the entries (the caller keeps the original dbmart).
    let mut entries = db.entries.clone();
    let entries_bytes = (entries.len() * std::mem::size_of::<NumericEntry>()) as u64;
    track(entries_bytes);
    let bounds = sort_and_chunk(&mut entries, threads);

    let total = count_sequences(&entries, &bounds, cfg);
    let out_bytes = total * std::mem::size_of::<SeqRecord>() as u64;
    track(out_bytes);

    // Thread-local mining over contiguous ranges of patient chunks.
    // Patients are pre-aggregated into near-equal *entry* ranges so the
    // O(n²) work is balanced even with skewed chunk sizes.
    let patient_ranges = balance_patients(&bounds, threads);
    let mut results: Vec<Vec<SeqRecord>> =
        par::par_map_chunks(patient_ranges.len(), threads, |range| {
            let mut local: Vec<SeqRecord> = Vec::new();
            let mut scratch: Vec<NumericEntry> = Vec::new();
            for pr in &patient_ranges[range] {
                for w in bounds[pr.start..pr.end + 1].windows(2) {
                    let chunk = &entries[w[0]..w[1]];
                    if cfg.first_occurrence_only {
                        first_occurrences(chunk, &mut scratch);
                        local.reserve(pairs_for(scratch.len()) as usize);
                        sequence_chunk(&scratch, cfg, |r| local.push(r));
                    } else {
                        local.reserve(pairs_for(chunk.len()) as usize);
                        sequence_chunk(chunk, cfg, |r| local.push(r));
                    }
                }
            }
            local
        });

    // Merge thread-local vectors into one output buffer.
    let mut records: Vec<SeqRecord> = Vec::with_capacity(total as usize);
    for r in &mut results {
        records.append(r);
    }
    // `total` counts self-pairs; with include_self_pairs=false the actual
    // output is smaller, so `total` is an upper bound used for capacity.
    debug_assert!(records.len() as u64 <= total);
    debug_assert!(cfg.include_self_pairs == false || records.len() as u64 == total);

    untrack(entries_bytes);
    drop(entries);
    Ok(SequenceSet {
        records,
        num_patients: db.num_patients() as u32,
        num_phenx: db.num_phenx() as u32,
    })
}

/// Mine all transitive sequences to **spill files** (paper mode 1).
///
/// Each worker streams its records through a buffered [`SeqWriter`]; the
/// resident set stays at O(buffer × threads) regardless of output size —
/// this is the configuration behind the paper's "1.33 GB instead of
/// 43 GB" row in Table 1.
pub fn mine_sequences_to_files(
    db: &NumericDbMart,
    cfg: &MiningConfig,
) -> Result<SeqFileSet, MiningError> {
    mine_sequences_to_files_tracked(db, cfg, None)
}

/// [`mine_sequences_to_files`] with optional logical memory accounting.
pub fn mine_sequences_to_files_tracked(
    db: &NumericDbMart,
    cfg: &MiningConfig,
    tracker: Option<&MemTracker>,
) -> Result<SeqFileSet, MiningError> {
    let threads = par::num_threads(Some(cfg.threads).filter(|&t| t > 0));
    std::fs::create_dir_all(&cfg.work_dir)?;
    if let Some(t) = tracker {
        t.add((db.entries.len() * std::mem::size_of::<NumericEntry>()) as u64);
    }
    let mut entries = db.entries.clone();
    let bounds = sort_and_chunk(&mut entries, threads);
    let patient_ranges = balance_patients(&bounds, threads);

    let paths: Vec<Result<(PathBuf, u64), std::io::Error>> =
        par::par_map_chunks(patient_ranges.len(), threads, |range| {
            let path = cfg.work_dir.join(format!("seqs_{:04}.tspm", range.start));
            let mut writer = SeqWriter::create(&path)?;
            if let Some(t) = tracker {
                t.add(crate::seqstore::WRITER_BUFFER_BYTES as u64);
            }
            let mut scratch: Vec<NumericEntry> = Vec::new();
            for pr in &patient_ranges[range] {
                for w in bounds[pr.start..pr.end + 1].windows(2) {
                    let chunk = &entries[w[0]..w[1]];
                    let mut err: Option<std::io::Error> = None;
                    {
                        let sink = |r: SeqRecord| {
                            if err.is_none() {
                                if let Err(e) = writer.write(r) {
                                    err = Some(e);
                                }
                            }
                        };
                        if cfg.first_occurrence_only {
                            first_occurrences(chunk, &mut scratch);
                            sequence_chunk(&scratch, cfg, sink);
                        } else {
                            sequence_chunk(chunk, cfg, sink);
                        }
                    }
                    if let Some(e) = err {
                        return Err(e);
                    }
                }
            }
            let count = writer.finish()?;
            if let Some(t) = tracker {
                t.sub(crate::seqstore::WRITER_BUFFER_BYTES as u64);
            }
            Ok((path, count))
        });

    let mut fileset = SeqFileSet {
        files: Vec::new(),
        total_records: 0,
        num_patients: db.num_patients() as u32,
        num_phenx: db.num_phenx() as u32,
    };
    for p in paths {
        let (path, count) = p?;
        fileset.total_records += count;
        fileset.files.push(path);
    }
    if let Some(t) = tracker {
        t.sub((db.entries.len() * std::mem::size_of::<NumericEntry>()) as u64);
    }
    Ok(fileset)
}

/// Group patient chunks into per-worker ranges balanced by *quadratic*
/// cost (n²), since sequencing cost is quadratic in chunk length.
/// Returns ranges over indices into `bounds` windows.
fn balance_patients(bounds: &[usize], workers: usize) -> Vec<std::ops::Range<usize>> {
    let n_patients = bounds.len().saturating_sub(1);
    if n_patients == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n_patients);
    let cost = |i: usize| {
        let n = (bounds[i + 1] - bounds[i]) as u64;
        1 + n * n
    };
    let total: u64 = (0..n_patients).map(cost).sum();
    let per_worker = total / workers as u64 + 1;
    let mut ranges = Vec::with_capacity(workers);
    let mut start = 0usize;
    let mut acc = 0u64;
    for i in 0..n_patients {
        acc += cost(i);
        if acc >= per_worker && ranges.len() + 1 < workers {
            ranges.push(start..i + 1);
            start = i + 1;
            acc = 0;
        }
    }
    if start < n_patients {
        ranges.push(start..n_patients);
    }
    ranges
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dbmart::{decode_seq, DbMart, DbMartEntry};

    fn raw(p: &str, date: i32, x: &str) -> DbMartEntry {
        DbMartEntry { patient_id: p.into(), date, phenx: x.into(), description: None }
    }

    fn tiny_db() -> NumericDbMart {
        // patient A: a@1, b@3, a@7   patient B: c@2, b@2
        NumericDbMart::encode(&DbMart::new(vec![
            raw("A", 1, "a"),
            raw("A", 3, "b"),
            raw("A", 7, "a"),
            raw("B", 2, "c"),
            raw("B", 2, "b"),
        ]))
    }

    #[test]
    fn mines_all_ordered_pairs_with_durations() {
        let db = tiny_db();
        let got = mine_sequences(&db, &MiningConfig::default()).unwrap();
        // A: 3 entries → 3 pairs; B: 2 entries → 1 pair.
        assert_eq!(got.len(), 4);
        let a = db.lookup.phenx_id("a").unwrap();
        let b = db.lookup.phenx_id("b").unwrap();
        let c = db.lookup.phenx_id("c").unwrap();
        let mut set: Vec<(u64, u32, u32)> =
            got.records.iter().map(|r| (r.seq, r.pid, r.duration)).collect();
        set.sort_unstable();
        let common = vec![
            (encode_seq(a, b), 0u32, 2u32), // a@1 → b@3
            (encode_seq(a, a), 0, 6),       // a@1 → a@7 (self pair)
            (encode_seq(b, a), 0, 4),       // b@3 → a@7
        ];
        // Same-date pair direction depends on the deterministic phenX
        // tie-break; accept either orientation.
        let mut variant1 = common.clone();
        variant1.push((encode_seq(c, b), 1, 0));
        variant1.sort_unstable();
        let mut variant2 = common;
        variant2.push((encode_seq(b, c), 1, 0));
        variant2.sort_unstable();
        assert!(set == variant1 || set == variant2, "got {set:?}");
    }

    #[test]
    fn sequence_count_formula_holds() {
        // paper: ((n-1)·n)/2 sequences per patient
        let mut entries = Vec::new();
        for (p, n) in [("p1", 10), ("p2", 25), ("p3", 1), ("p4", 0)] {
            for i in 0..n {
                entries.push(raw(p, i, &format!("x{i}")));
            }
        }
        let db = NumericDbMart::encode(&DbMart::new(entries));
        let got = mine_sequences(&db, &MiningConfig::default()).unwrap();
        assert_eq!(got.len() as u64, pairs_for(10) + pairs_for(25) + pairs_for(1));
    }

    #[test]
    fn first_occurrence_filter_dedupes_phenx() {
        let db = NumericDbMart::encode(&DbMart::new(vec![
            raw("A", 1, "a"),
            raw("A", 2, "b"),
            raw("A", 3, "a"), // dropped: 'a' already seen
            raw("A", 4, "c"),
        ]));
        let cfg = MiningConfig { first_occurrence_only: true, ..Default::default() };
        let got = mine_sequences(&db, &cfg).unwrap();
        assert_eq!(got.len() as u64, pairs_for(3)); // a,b,c
        // And the dropped occurrence must not shift durations: a→c uses a@1.
        let a = db.lookup.phenx_id("a").unwrap();
        let c = db.lookup.phenx_id("c").unwrap();
        let ac = got.records.iter().find(|r| r.seq == encode_seq(a, c)).unwrap();
        assert_eq!(ac.duration, 3);
    }

    #[test]
    fn duration_unit_divides() {
        let db = NumericDbMart::encode(&DbMart::new(vec![
            raw("A", 0, "a"),
            raw("A", 21, "b"),
        ]));
        let cfg = MiningConfig { duration_unit_days: 7, ..Default::default() };
        let got = mine_sequences(&db, &cfg).unwrap();
        assert_eq!(got.records[0].duration, 3); // 21 days = 3 weeks
    }

    #[test]
    fn self_pairs_can_be_excluded() {
        let db = NumericDbMart::encode(&DbMart::new(vec![
            raw("A", 1, "a"),
            raw("A", 2, "a"),
            raw("A", 3, "b"),
        ]));
        let cfg = MiningConfig { include_self_pairs: false, ..Default::default() };
        let got = mine_sequences(&db, &cfg).unwrap();
        for r in &got.records {
            let (s, e) = decode_seq(r.seq);
            assert_ne!(s, e);
        }
        assert_eq!(got.len(), 2); // a@1→b, a@2→b
    }

    #[test]
    fn unsorted_input_is_sorted_internally() {
        let db = NumericDbMart::encode(&DbMart::new(vec![
            raw("A", 9, "c"),
            raw("A", 1, "a"),
            raw("A", 5, "b"),
        ]));
        let got = mine_sequences(&db, &MiningConfig::default()).unwrap();
        let a = db.lookup.phenx_id("a").unwrap();
        let c = db.lookup.phenx_id("c").unwrap();
        let ac = got.records.iter().find(|r| r.seq == encode_seq(a, c)).unwrap();
        assert_eq!(ac.duration, 8);
    }

    #[test]
    fn thread_counts_agree() {
        let mart = crate::synthea::SyntheaConfig::small().generate();
        let db = NumericDbMart::encode(&mart);
        let mut last: Option<Vec<SeqRecord>> = None;
        for threads in [1usize, 2, 4] {
            let cfg = MiningConfig { threads, ..Default::default() };
            let mut got = mine_sequences(&db, &cfg).unwrap().records;
            got.sort_unstable_by_key(|r| (r.seq, r.pid, r.duration));
            if let Some(prev) = &last {
                assert_eq!(prev, &got, "threads={threads} changed the result");
            }
            last = Some(got);
        }
    }

    #[test]
    fn file_mode_matches_memory_mode() {
        let mart = crate::synthea::SyntheaConfig::small().generate();
        let db = NumericDbMart::encode(&mart);
        let mem = mine_sequences(&db, &MiningConfig::default()).unwrap();

        let dir = std::env::temp_dir().join("tspm_test_filemode");
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = MiningConfig {
            mode: MiningMode::FileBased,
            work_dir: dir.clone(),
            threads: 3,
            ..Default::default()
        };
        let files = mine_sequences_to_files(&db, &cfg).unwrap();
        assert_eq!(files.total_records as usize, mem.len());
        let mut from_files = files.read_all().unwrap();
        let mut from_mem = mem.records.clone();
        from_files.sort_unstable_by_key(|r| (r.seq, r.pid, r.duration));
        from_mem.sort_unstable_by_key(|r| (r.seq, r.pid, r.duration));
        assert_eq!(from_files, from_mem);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_dbmart_yields_empty_set() {
        let db = NumericDbMart::default();
        let got = mine_sequences(&db, &MiningConfig::default()).unwrap();
        assert!(got.is_empty());
    }

    #[test]
    fn balance_patients_covers_all() {
        // bounds for 5 patients with sizes 1, 100, 2, 3, 50
        let bounds = vec![0, 1, 101, 103, 106, 156];
        for workers in [1usize, 2, 3, 8] {
            let ranges = balance_patients(&bounds, workers);
            let mut covered = Vec::new();
            for r in &ranges {
                for i in r.clone() {
                    covered.push(i);
                }
            }
            assert_eq!(covered, (0..5).collect::<Vec<_>>(), "workers={workers}");
        }
    }

    #[test]
    fn record_is_16_bytes() {
        assert_eq!(std::mem::size_of::<SeqRecord>(), 16);
    }

    #[test]
    fn memory_tracker_records_peak() {
        let mart = crate::synthea::SyntheaConfig::small().generate();
        let db = NumericDbMart::encode(&mart);
        let tracker = MemTracker::new();
        let got = mine_sequences_tracked(&db, &MiningConfig::default(), Some(&tracker)).unwrap();
        assert!(tracker.peak() >= got.byte_size());
    }
}
