//! The tSPM+ mining engine (the paper's core contribution).
//!
//! Pipeline per the paper §Methods:
//!
//! 1. **Sort** the numeric dbmart by `(patient, date)` with the parallel
//!    samplesort ([`crate::psort`]) so each patient forms one contiguous,
//!    chronologically ordered chunk.
//! 2. **Sequence**: for every entry `x` of a patient, pair it with every
//!    later entry `y` (`y.date ≥ x.date`, `y` after `x` in order),
//!    emitting the reversible decimal hash `encode_seq(x.phenx, y.phenx)`
//!    plus the **duration** `(y.date − x.date) / unit` — the paper's new
//!    dimension. This mines `n(n−1)/2` sequences for a patient with `n`
//!    entries.
//! 3. Patient chunks are distributed over worker threads, each appending
//!    to a **thread-local vector** (avoids cache invalidation), merged at
//!    the end — or, in **file-based mode**, streamed to per-worker binary
//!    spill files ([`crate::seqstore`]) so the resident set stays tiny.
//!
//! The optional *first-occurrence-only* filter reproduces the protocol of
//! the paper's comparison benchmark (and of the earlier AD study): only
//! the first occurrence of each phenX per patient enters sequencing.
//!
//! ## Targeted mining (predicate pushdown)
//!
//! Every mining path accepts a [`MineContext`] carrying an optional
//! [`TargetSpec`]. The spec's endpoint predicate is evaluated inside the
//! per-patient inner loop *before* duration encoding, and its duration
//! band right after the span division — non-matching pairs are never
//! materialized. **Pushdown safety:** the predicate is per-record and is
//! checked on exactly the pairs the full mine would enumerate, in the
//! same order, so the targeted output is the filtered full output record
//! for record (see [`crate::target`] module docs for the full argument;
//! `rust/tests/conformance.rs` enforces byte-equality across all four
//! backends). Pruning happens per pair *after* scheduling decisions:
//! the shard layout, worker ranges, and merge order depend only on the
//! cohort and configuration, never on the spec, so the sharded backend's
//! byte-determinism guarantees are unchanged.

use crate::dbmart::{encode_seq, NumericDbMart, NumericEntry};
use crate::metrics::MemTracker;
use crate::par;
use crate::psort;
use crate::seqstore::{SeqFileSet, SeqWriter};
use crate::target::TargetSpec;
use std::path::PathBuf;
use crate::sync::OnceLock;

/// Upper bound on the shard count accepted by configuration and plan
/// validation. Shards beyond this add pure bookkeeping overhead (each is
/// one slot plus one scheduling claim) with no rebalancing benefit.
pub const MAX_SHARDS: usize = 1 << 16;

/// One mined sequence record — 16 bytes, the paper's "128 bit" layout:
/// 8 bytes sequence hash, 4 bytes patient id, 4 bytes duration.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(C)]
pub struct SeqRecord {
    /// `start_phenx * 10^7 + end_phenx` (see [`crate::dbmart::encode_seq`]).
    pub seq: u64,
    /// Dense patient id.
    pub pid: u32,
    /// Duration in the configured unit (default: days).
    pub duration: u32,
}

/// Operating mode (paper §Results: "two distinct operational modes").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MiningMode {
    /// Sequences returned as one in-memory vector.
    InMemory,
    /// Sequences spilled to per-worker binary files.
    FileBased,
}

/// Mining configuration.
#[derive(Clone, Debug)]
pub struct MiningConfig {
    /// Worker threads (0 = auto-detect, honouring `TSPM_THREADS`).
    pub threads: usize,
    /// Keep only the first occurrence of each phenX per patient.
    pub first_occurrence_only: bool,
    /// Duration divisor in days (1 = days, 7 = weeks, 30 = months).
    pub duration_unit_days: u32,
    pub mode: MiningMode,
    /// Spill directory for [`MiningMode::FileBased`].
    pub work_dir: PathBuf,
    /// Include same-phenX pairs (x → x at a later date). The paper keeps
    /// them; exposed for ablation.
    pub include_self_pairs: bool,
    /// Shard count for the sharded backend (0 = auto: [`DEFAULT_SHARDS`],
    /// capped by the patient count). The layout never depends on the
    /// worker count, so sharded output is reproducible across
    /// `TSPM_THREADS` settings; oversubscribing workers keeps dynamic
    /// scheduling effective on cohorts with skewed entry counts.
    pub shards: usize,
}

impl Default for MiningConfig {
    fn default() -> Self {
        MiningConfig {
            threads: 0,
            first_occurrence_only: false,
            duration_unit_days: 1,
            mode: MiningMode::InMemory,
            work_dir: std::env::temp_dir().join("tspm_work"),
            include_self_pairs: true,
            shards: 0,
        }
    }
}

impl MiningConfig {
    /// The worker count this config resolves to: `threads` when positive,
    /// else the `TSPM_THREADS` → detected-parallelism chain, always
    /// clamped ([`crate::par::num_threads`]). The single source of truth
    /// shared by every mining path, backend auto-selection, and the
    /// streaming pipeline, so selection and execution cannot disagree.
    pub fn worker_threads(&self) -> usize {
        par::num_threads(Some(self.threads).filter(|&t| t > 0))
    }

    /// Semantic validation, run by every mining entry point. A zero
    /// `duration_unit_days` used to be silently clamped to 1, which gave
    /// programmatic callers different semantics from the validated
    /// [`crate::config::RunConfig`] / [`crate::engine::Plan`] surfaces;
    /// it is now rejected everywhere. Likewise `shards > MAX_SHARDS`:
    /// previously only `Plan::validate` rejected it (mining clamped
    /// silently) — this is now the one copy of both checks, and the
    /// plan/config layers delegate here via [`MineContext::validate`].
    pub fn validate(&self) -> Result<(), MiningError> {
        if self.duration_unit_days == 0 {
            return Err(MiningError::InvalidConfig(
                "duration_unit_days must be ≥ 1 (0 would divide by zero; use 1 for days)"
                    .into(),
            ));
        }
        if self.shards > MAX_SHARDS {
            return Err(MiningError::InvalidConfig(format!(
                "shards must be ≤ {MAX_SHARDS} (got {}); beyond that each shard is pure \
                 bookkeeping overhead",
                self.shards
            )));
        }
        Ok(())
    }
}

/// The one validated mining context: configuration plus the optional
/// targeting predicate. Threaded through every backend path
/// ([`mine_with_scheduler`], [`mine_patient_range`]) so a fifth copy of
/// config plumbing is never needed when a new dimension lands —
/// `Plan::validate` and `RunConfig::validate` both delegate to
/// [`MineContext::validate`] instead of re-validating overlapping fields.
#[derive(Clone, Copy, Debug)]
pub struct MineContext<'a> {
    pub cfg: &'a MiningConfig,
    /// The pushdown predicate; `None` mines the full multiset.
    pub target: Option<&'a TargetSpec>,
}

impl<'a> MineContext<'a> {
    /// An untargeted context — mines exactly what `cfg` alone would.
    pub fn new(cfg: &'a MiningConfig) -> MineContext<'a> {
        MineContext { cfg, target: None }
    }

    /// A context with an optional target. A spec that constrains nothing
    /// ([`TargetSpec::is_all`]) is normalized to `None`, so
    /// `TargetSpec::all()` takes the byte-identical untargeted path.
    pub fn with_target(cfg: &'a MiningConfig, target: Option<&'a TargetSpec>) -> MineContext<'a> {
        MineContext { cfg, target: target.filter(|t| !t.is_all()) }
    }

    /// The collapsed validator: config semantics
    /// ([`MiningConfig::validate`]) plus the target's structural checks
    /// (empty code set, inverted duration band). Vocabulary membership
    /// needs a cohort and stays at the engine layer
    /// (`TargetSpec::validate_vocab`).
    pub fn validate(&self) -> Result<(), MiningError> {
        self.cfg.validate()?;
        if let Some(t) = self.target {
            t.validate().map_err(MiningError::InvalidConfig)?;
        }
        Ok(())
    }
}

/// In-memory mining result.
#[derive(Clone, Debug, Default)]
pub struct SequenceSet {
    pub records: Vec<SeqRecord>,
    /// Number of patients in the source dbmart (for matrix shapes).
    pub num_patients: u32,
    /// Number of distinct phenX codes in the source dbmart.
    pub num_phenx: u32,
}

impl SequenceSet {
    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Logical bytes held by the record buffer.
    pub fn byte_size(&self) -> u64 {
        (self.records.len() * std::mem::size_of::<SeqRecord>()) as u64
    }
}

/// Mining errors.
#[derive(Debug)]
pub enum MiningError {
    Io(std::io::Error),
    /// In-memory result would exceed the configured element cap
    /// (reproduces the paper's R 2³¹−1 failure mode; see
    /// [`crate::partition`] for the adaptive remedy).
    TooManySequences { mined: u64, cap: u64 },
    /// A [`MiningConfig`] that fails [`MiningConfig::validate`].
    InvalidConfig(String),
}

impl std::fmt::Display for MiningError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MiningError::Io(e) => write!(f, "mining I/O error: {e}"),
            MiningError::TooManySequences { mined, cap } => write!(
                f,
                "mined {mined} sequences which exceeds the element cap {cap} \
                 (R dataframe limit 2^31-1); use file-based mode or adaptive partitioning"
            ),
            MiningError::InvalidConfig(msg) => write!(f, "invalid mining config: {msg}"),
        }
    }
}

impl std::error::Error for MiningError {}

impl From<std::io::Error> for MiningError {
    fn from(e: std::io::Error) -> Self {
        MiningError::Io(e)
    }
}

/// Sort entries by `(patient, date)` in place and return the per-patient
/// chunk boundaries `[start_0, start_1, …, len]`.
///
/// Requires patient ids to be dense (`< num_patients`), which
/// [`NumericDbMart::encode`] guarantees.
pub fn sort_and_chunk(entries: &mut [NumericEntry], threads: usize) -> Vec<usize> {
    // Composite key: patient, then date (shifted to unsigned), then phenX.
    // Including phenX makes the order — and therefore the orientation of
    // same-date pairs — fully deterministic regardless of thread count.
    // Adaptive sort: pdqsort on one worker, parallel radix otherwise.
    psort::sort_auto(
        entries,
        |e| {
            ((e.patient as u128) << 64)
                | (((e.date as i64 - i32::MIN as i64) as u128) << 32)
                | e.phenx as u128
        },
        threads,
    );
    let mut bounds = Vec::new();
    let mut prev = u32::MAX;
    for (i, e) in entries.iter().enumerate() {
        if e.patient != prev {
            bounds.push(i);
            prev = e.patient;
        }
    }
    bounds.push(entries.len());
    bounds
}

/// Number of sequences a patient chunk will produce (n·(n−1)/2).
#[inline]
pub fn pairs_for(n: usize) -> u64 {
    (n as u64) * (n as u64 - 1) / 2
}

/// Total sequences the sorted+filtered dbmart will produce. Used by
/// [`crate::partition`] for adaptive chunking and by callers to pre-size.
pub fn count_sequences(entries: &[NumericEntry], bounds: &[usize], cfg: &MiningConfig) -> u64 {
    let mut total = 0u64;
    for w in bounds.windows(2) {
        let chunk = &entries[w[0]..w[1]];
        let n = if cfg.first_occurrence_only {
            count_first_occurrences(chunk)
        } else {
            chunk.len()
        };
        if n >= 1 {
            total += pairs_for(n);
        }
    }
    total
}

fn count_first_occurrences(chunk: &[NumericEntry]) -> usize {
    // Chunks are small (hundreds); a sorted Vec dedupe avoids per-call
    // hashing overhead.
    let mut seen: Vec<u32> = chunk.iter().map(|e| e.phenx).collect();
    seen.sort_unstable();
    seen.dedup();
    seen.len()
}

/// Apply the first-occurrence filter to one sorted patient chunk,
/// appending survivors to `out` (cleared first).
fn first_occurrences(chunk: &[NumericEntry], out: &mut Vec<NumericEntry>) {
    out.clear();
    // Date-sorted input → linear scan with a seen-set keeps the earliest.
    let mut seen: Vec<u32> = Vec::with_capacity(chunk.len().min(64));
    for e in chunk {
        // Small-vector membership test beats HashSet for typical chunk
        // sizes; falls back gracefully for big chunks because `seen` is
        // kept sorted.
        match seen.binary_search(&e.phenx) {
            Ok(_) => {}
            Err(pos) => {
                seen.insert(pos, e.phenx);
                out.push(*e);
            }
        }
    }
}

/// Emit all transitive sequences for one (already filtered, date-sorted)
/// patient chunk into `sink`, pruning pairs the target rejects — the
/// endpoint check runs *before* duration encoding, the band check right
/// after the span division (module docs: "Targeted mining").
#[inline]
fn sequence_chunk(chunk: &[NumericEntry], ctx: MineContext<'_>, mut sink: impl FnMut(SeqRecord)) {
    // Zero is rejected by MiningConfig::validate at every entry point
    // (and by Plan::validate) — no silent clamp.
    let unit = ctx.cfg.duration_unit_days as u64;
    debug_assert!(unit > 0, "entry points must validate duration_unit_days");
    let include_self_pairs = ctx.cfg.include_self_pairs;
    for i in 0..chunk.len() {
        let x = chunk[i];
        for y in &chunk[i + 1..] {
            if !include_self_pairs && y.phenx == x.phenx {
                continue;
            }
            if let Some(t) = ctx.target {
                if !t.matches_pair(x.phenx, y.phenx) {
                    continue;
                }
            }
            debug_assert!(y.date >= x.date, "chunk must be date-sorted");
            // Widened span: an i32 subtraction overflows on adversarial
            // date ranges (i32::MIN-era sentinels vs modern dates). The
            // full i32 span is ≤ u32::MAX days, so span/unit (unit ≥ 1)
            // always converts back into u32.
            let span = (y.date as i64 - x.date as i64) as u64;
            let duration = u32::try_from(span / unit)
                .expect("i32 date span divided by a positive unit fits u32");
            if let Some(t) = ctx.target {
                if !t.matches_duration(duration) {
                    continue;
                }
            }
            sink(SeqRecord { seq: encode_seq(x.phenx, y.phenx), pid: x.patient, duration });
        }
    }
}

/// Where mined records land. `reserve` receives the upper-bound pair
/// count of the next chunk (vector sinks pre-size, streaming sinks
/// ignore it).
trait RecordSink {
    fn reserve(&mut self, _additional: u64) {}
    fn push(&mut self, r: SeqRecord);
}

impl RecordSink for Vec<SeqRecord> {
    fn reserve(&mut self, additional: u64) {
        Vec::reserve(self, additional as usize);
    }
    fn push(&mut self, r: SeqRecord) {
        Vec::push(self, r);
    }
}

/// [`SeqWriter`] sink that latches the first I/O error (later pushes
/// become no-ops); the caller re-surfaces it once the range completes.
struct WriterSink<'a> {
    writer: &'a mut SeqWriter,
    err: &'a mut Option<std::io::Error>,
}

impl RecordSink for WriterSink<'_> {
    fn push(&mut self, r: SeqRecord) {
        if self.err.is_none() {
            if let Err(e) = self.writer.write(r) {
                *self.err = Some(e);
            }
        }
    }
}

/// Mine every patient chunk of `pr` (a range over `bounds` windows) into
/// `out`, applying the optional first-occurrence filter via `scratch`.
/// The one inner loop shared by every mining path — static (in-memory),
/// dynamic (sharded), and file-backed — so the backends can never
/// diverge on filtering or pre-sizing.
fn mine_patient_range(
    entries: &[NumericEntry],
    bounds: &[usize],
    pr: &std::ops::Range<usize>,
    ctx: MineContext<'_>,
    scratch: &mut Vec<NumericEntry>,
    out: &mut impl RecordSink,
) {
    for w in bounds[pr.start..pr.end + 1].windows(2) {
        let chunk = &entries[w[0]..w[1]];
        if ctx.cfg.first_occurrence_only {
            first_occurrences(chunk, scratch);
            out.reserve(pairs_for(scratch.len()));
            sequence_chunk(scratch, ctx, |r| out.push(r));
        } else {
            out.reserve(pairs_for(chunk.len()));
            sequence_chunk(chunk, ctx, |r| out.push(r));
        }
    }
}

/// Mine all transitive sequences **in memory** (paper mode 2).
///
/// `tracker`, when provided, accounts the engine's logical peak memory
/// (entry copy + thread-local buffers + merged output).
pub fn mine_sequences(db: &NumericDbMart, cfg: &MiningConfig) -> Result<SequenceSet, MiningError> {
    mine_sequences_tracked(db, cfg, None)
}

/// [`mine_sequences`] with optional logical memory accounting.
///
/// Thread-local mining over contiguous ranges of patient chunks:
/// patients are pre-aggregated into near-equal quadratic-cost ranges
/// (one per worker) so the O(n²) work is balanced even with skewed
/// chunk sizes, and each worker appends to its own vector.
pub fn mine_sequences_tracked(
    db: &NumericDbMart,
    cfg: &MiningConfig,
    tracker: Option<&MemTracker>,
) -> Result<SequenceSet, MiningError> {
    mine_sequences_with(db, MineContext::new(cfg), tracker)
}

/// [`mine_sequences_tracked`] with a full [`MineContext`] — the targeted
/// entry point the engine backends call.
pub fn mine_sequences_with(
    db: &NumericDbMart,
    ctx: MineContext<'_>,
    tracker: Option<&MemTracker>,
) -> Result<SequenceSet, MiningError> {
    mine_with_scheduler(db, ctx, tracker, |entries, bounds, threads| {
        let patient_ranges = balance_patients(bounds, threads);
        par::par_map_chunks(patient_ranges.len(), threads, |range| {
            let mut local: Vec<SeqRecord> = Vec::new();
            let mut scratch: Vec<NumericEntry> = Vec::new();
            for pr in &patient_ranges[range] {
                mine_patient_range(entries, bounds, pr, ctx, &mut scratch, &mut local);
            }
            local
        })
    })
}

/// Shared prologue + epilogue of the in-memory scheduling paths
/// ([`mine_sequences_tracked`] static, [`mine_sequences_sharded_tracked`]
/// dynamic): clone + sort the entries, pre-size from the exact count,
/// let `schedule` produce per-bucket buffers **in a deterministic bucket
/// order**, merge them in that order, and account logical memory.
fn mine_with_scheduler<F>(
    db: &NumericDbMart,
    ctx: MineContext<'_>,
    tracker: Option<&MemTracker>,
    schedule: F,
) -> Result<SequenceSet, MiningError>
where
    F: FnOnce(&[NumericEntry], &[usize], usize) -> Vec<Vec<SeqRecord>>,
{
    ctx.validate()?;
    let cfg = ctx.cfg;
    let threads = cfg.worker_threads();
    let track = |b: u64| {
        if let Some(t) = tracker {
            t.add(b)
        }
    };
    let untrack = |b: u64| {
        if let Some(t) = tracker {
            t.sub(b)
        }
    };

    // Working copy of the entries (the caller keeps the original dbmart).
    let mut entries = db.entries.clone();
    let entries_bytes = (entries.len() * std::mem::size_of::<NumericEntry>()) as u64;
    track(entries_bytes);
    let bounds = sort_and_chunk(&mut entries, threads);

    let total = count_sequences(&entries, &bounds, cfg);
    track(total * std::mem::size_of::<SeqRecord>() as u64);

    let mut buffers = schedule(&entries, &bounds, threads);

    // Merge per-bucket vectors into one output buffer, in bucket order.
    let mut records: Vec<SeqRecord> = Vec::with_capacity(total as usize);
    for b in &mut buffers {
        records.append(b);
    }
    // `total` counts self-pairs and ignores the target; with
    // include_self_pairs=false or a target active the actual output is
    // smaller, so `total` is an upper bound used for capacity.
    debug_assert!(records.len() as u64 <= total);
    debug_assert!(
        !cfg.include_self_pairs || ctx.target.is_some() || records.len() as u64 == total
    );

    untrack(entries_bytes);
    drop(entries);
    Ok(SequenceSet {
        records,
        num_patients: db.num_patients() as u32,
        num_phenx: db.num_phenx() as u32,
    })
}

/// Mine all transitive sequences to **spill files** (paper mode 1).
///
/// Each worker streams its records through a buffered [`SeqWriter`]; the
/// resident set stays at O(buffer × threads) regardless of output size —
/// this is the configuration behind the paper's "1.33 GB instead of
/// 43 GB" row in Table 1.
pub fn mine_sequences_to_files(
    db: &NumericDbMart,
    cfg: &MiningConfig,
) -> Result<SeqFileSet, MiningError> {
    mine_sequences_to_files_tracked(db, cfg, None)
}

/// [`mine_sequences_to_files`] with optional logical memory accounting.
pub fn mine_sequences_to_files_tracked(
    db: &NumericDbMart,
    cfg: &MiningConfig,
    tracker: Option<&MemTracker>,
) -> Result<SeqFileSet, MiningError> {
    mine_sequences_to_files_with(db, MineContext::new(cfg), tracker)
}

/// [`mine_sequences_to_files_tracked`] with a full [`MineContext`] — the
/// targeted entry point for the file-backed backend.
pub fn mine_sequences_to_files_with(
    db: &NumericDbMart,
    ctx: MineContext<'_>,
    tracker: Option<&MemTracker>,
) -> Result<SeqFileSet, MiningError> {
    ctx.validate()?;
    let cfg = ctx.cfg;
    let threads = cfg.worker_threads();
    std::fs::create_dir_all(&cfg.work_dir)?;
    if let Some(t) = tracker {
        t.add((db.entries.len() * std::mem::size_of::<NumericEntry>()) as u64);
    }
    let mut entries = db.entries.clone();
    let bounds = sort_and_chunk(&mut entries, threads);
    let patient_ranges = balance_patients(&bounds, threads);

    let paths: Vec<Result<(PathBuf, u64), std::io::Error>> =
        par::par_map_chunks(patient_ranges.len(), threads, |range| {
            let path = cfg.work_dir.join(format!("seqs_{:04}.tspm", range.start));
            let mut writer = SeqWriter::create(&path)?;
            if let Some(t) = tracker {
                t.add(crate::seqstore::WRITER_BUFFER_BYTES as u64);
            }
            let mut scratch: Vec<NumericEntry> = Vec::new();
            let mut err: Option<std::io::Error> = None;
            {
                let mut sink = WriterSink { writer: &mut writer, err: &mut err };
                for pr in &patient_ranges[range] {
                    mine_patient_range(&entries, &bounds, pr, ctx, &mut scratch, &mut sink);
                }
            }
            if let Some(e) = err {
                return Err(e);
            }
            let count = writer.finish()?;
            if let Some(t) = tracker {
                t.sub(crate::seqstore::WRITER_BUFFER_BYTES as u64);
            }
            Ok((path, count))
        });

    let mut fileset = SeqFileSet {
        files: Vec::new(),
        total_records: 0,
        num_patients: db.num_patients() as u32,
        num_phenx: db.num_phenx() as u32,
    };
    for p in paths {
        let (path, count) = p?;
        fileset.total_records += count;
        fileset.files.push(path);
    }
    if let Some(t) = tracker {
        t.sub((db.entries.len() * std::mem::size_of::<NumericEntry>()) as u64);
    }
    Ok(fileset)
}

/// Auto shard count used when `MiningConfig::shards` is 0. A fixed
/// constant — deliberately *not* derived from the worker count — so the
/// shard layout, and with it the raw pre-sort record order, is identical
/// whatever `TSPM_THREADS` resolves to. 64 shards give ~4× dynamic
/// oversubscription on a 16-core machine; set `shards` explicitly to
/// trade layout stability for more concurrency on larger irons.
pub const DEFAULT_SHARDS: usize = 64;

/// Resolve the shard count for [`mine_sequences_sharded`]: an explicit
/// `shards` wins; `0` means [`DEFAULT_SHARDS`]. The result is clamped to
/// `[1, min(MAX_SHARDS, n_patients)]` (one shard floor even for empty
/// cohorts, so callers never divide by zero).
pub fn effective_shards(shards: usize, n_patients: usize) -> usize {
    let want = if shards > 0 { shards } else { DEFAULT_SHARDS };
    want.min(MAX_SHARDS).min(n_patients.max(1))
}

/// Mine all transitive sequences on the **sharded** backend.
///
/// Patients are grouped into [`effective_shards`] cost-balanced shards
/// (quadratic cost, like the batch path), but unlike
/// [`mine_sequences`]'s static range assignment, shards are claimed
/// dynamically by workers over [`crate::par::par_for_each_dynamic`] —
/// per-patient entry counts are highly skewed in clinical data, so a
/// straggler shard must not serialize the run.
///
/// **Determinism guarantee:** each shard's buffer depends only on the
/// deterministically sorted entries it covers, the shard layout depends
/// only on the cohort and the `shards` setting (never the worker count),
/// and buffers are merged in **stable shard order** — never completion
/// order. The raw output is therefore byte-identical for every thread
/// count, `TSPM_THREADS` value, and scheduling interleaving. Changing
/// `shards` itself may permute the pre-sort record order, but never the
/// multiset.
pub fn mine_sequences_sharded(
    db: &NumericDbMart,
    cfg: &MiningConfig,
) -> Result<SequenceSet, MiningError> {
    mine_sequences_sharded_tracked(db, cfg, None)
}

/// [`mine_sequences_sharded`] with optional logical memory accounting.
pub fn mine_sequences_sharded_tracked(
    db: &NumericDbMart,
    cfg: &MiningConfig,
    tracker: Option<&MemTracker>,
) -> Result<SequenceSet, MiningError> {
    mine_sequences_sharded_with(db, MineContext::new(cfg), tracker)
}

/// [`mine_sequences_sharded_tracked`] with a full [`MineContext`] — the
/// targeted entry point for the sharded backend. The shard layout and
/// merge order are computed exactly as in the untargeted path (the spec
/// only prunes pairs inside a shard), so the determinism guarantees
/// above carry over unchanged.
pub fn mine_sequences_sharded_with(
    db: &NumericDbMart,
    ctx: MineContext<'_>,
    tracker: Option<&MemTracker>,
) -> Result<SequenceSet, MiningError> {
    mine_with_scheduler(db, ctx, tracker, |entries, bounds, threads| {
        let n_patients = bounds.len().saturating_sub(1);
        let shard_ranges =
            balance_patients(bounds, effective_shards(ctx.cfg.shards, n_patients));
        // One write-once slot per shard: workers fill slots in whatever
        // order the dynamic scheduler hands out shards; the merge reads
        // them in shard order.
        let slots: Vec<OnceLock<Vec<SeqRecord>>> =
            (0..shard_ranges.len()).map(|_| OnceLock::new()).collect();
        // Observability: counters only (atomic adds — no effect on the
        // deterministic merge order or output bytes).
        let claimed = crate::obs::metrics::global().counter(crate::obs::names::MINE_SHARDS_CLAIMED);
        par::par_for_each_dynamic(shard_ranges.len(), threads, 1, |si| {
            claimed.inc();
            let mut local: Vec<SeqRecord> = Vec::new();
            let mut scratch: Vec<NumericEntry> = Vec::new();
            mine_patient_range(entries, bounds, &shard_ranges[si], ctx, &mut scratch, &mut local);
            let filled = slots[si].set(local).is_ok();
            debug_assert!(filled, "shard {si} claimed twice");
        });
        crate::obs::metrics::global()
            .counter(crate::obs::names::MINE_SHARDS_MERGED)
            .add(slots.len() as u64);
        slots.into_iter().map(|s| s.into_inner().unwrap_or_default()).collect()
    })
}

/// Group patient chunks into per-worker ranges balanced by *quadratic*
/// cost (n²), since sequencing cost is quadratic in chunk length.
/// Returns ranges over indices into `bounds` windows.
fn balance_patients(bounds: &[usize], workers: usize) -> Vec<std::ops::Range<usize>> {
    let n_patients = bounds.len().saturating_sub(1);
    if n_patients == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n_patients);
    let cost = |i: usize| {
        let n = (bounds[i + 1] - bounds[i]) as u64;
        1 + n * n
    };
    let total: u64 = (0..n_patients).map(cost).sum();
    let per_worker = total / workers as u64 + 1;
    let mut ranges = Vec::with_capacity(workers);
    let mut start = 0usize;
    let mut acc = 0u64;
    for i in 0..n_patients {
        acc += cost(i);
        if acc >= per_worker && ranges.len() + 1 < workers {
            ranges.push(start..i + 1);
            start = i + 1;
            acc = 0;
        }
    }
    if start < n_patients {
        ranges.push(start..n_patients);
    }
    ranges
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dbmart::{decode_seq, DbMart, DbMartEntry};

    fn raw(p: &str, date: i32, x: &str) -> DbMartEntry {
        DbMartEntry { patient_id: p.into(), date, phenx: x.into(), description: None }
    }

    fn tiny_db() -> NumericDbMart {
        // patient A: a@1, b@3, a@7   patient B: c@2, b@2
        NumericDbMart::encode(&DbMart::new(vec![
            raw("A", 1, "a"),
            raw("A", 3, "b"),
            raw("A", 7, "a"),
            raw("B", 2, "c"),
            raw("B", 2, "b"),
        ]))
    }

    #[test]
    fn mines_all_ordered_pairs_with_durations() {
        let db = tiny_db();
        let got = mine_sequences(&db, &MiningConfig::default()).unwrap();
        // A: 3 entries → 3 pairs; B: 2 entries → 1 pair.
        assert_eq!(got.len(), 4);
        let a = db.lookup.phenx_id("a").unwrap();
        let b = db.lookup.phenx_id("b").unwrap();
        let c = db.lookup.phenx_id("c").unwrap();
        let mut set: Vec<(u64, u32, u32)> =
            got.records.iter().map(|r| (r.seq, r.pid, r.duration)).collect();
        set.sort_unstable();
        let common = vec![
            (encode_seq(a, b), 0u32, 2u32), // a@1 → b@3
            (encode_seq(a, a), 0, 6),       // a@1 → a@7 (self pair)
            (encode_seq(b, a), 0, 4),       // b@3 → a@7
        ];
        // Same-date pair direction depends on the deterministic phenX
        // tie-break; accept either orientation.
        let mut variant1 = common.clone();
        variant1.push((encode_seq(c, b), 1, 0));
        variant1.sort_unstable();
        let mut variant2 = common;
        variant2.push((encode_seq(b, c), 1, 0));
        variant2.sort_unstable();
        assert!(set == variant1 || set == variant2, "got {set:?}");
    }

    #[test]
    fn sequence_count_formula_holds() {
        // paper: ((n-1)·n)/2 sequences per patient
        let mut entries = Vec::new();
        for (p, n) in [("p1", 10), ("p2", 25), ("p3", 1), ("p4", 0)] {
            for i in 0..n {
                entries.push(raw(p, i, &format!("x{i}")));
            }
        }
        let db = NumericDbMart::encode(&DbMart::new(entries));
        let got = mine_sequences(&db, &MiningConfig::default()).unwrap();
        assert_eq!(got.len() as u64, pairs_for(10) + pairs_for(25) + pairs_for(1));
    }

    #[test]
    fn first_occurrence_filter_dedupes_phenx() {
        let db = NumericDbMart::encode(&DbMart::new(vec![
            raw("A", 1, "a"),
            raw("A", 2, "b"),
            raw("A", 3, "a"), // dropped: 'a' already seen
            raw("A", 4, "c"),
        ]));
        let cfg = MiningConfig { first_occurrence_only: true, ..Default::default() };
        let got = mine_sequences(&db, &cfg).unwrap();
        assert_eq!(got.len() as u64, pairs_for(3)); // a,b,c
        // And the dropped occurrence must not shift durations: a→c uses a@1.
        let a = db.lookup.phenx_id("a").unwrap();
        let c = db.lookup.phenx_id("c").unwrap();
        let ac = got.records.iter().find(|r| r.seq == encode_seq(a, c)).unwrap();
        assert_eq!(ac.duration, 3);
    }

    #[test]
    fn duration_unit_divides() {
        let db = NumericDbMart::encode(&DbMart::new(vec![
            raw("A", 0, "a"),
            raw("A", 21, "b"),
        ]));
        let cfg = MiningConfig { duration_unit_days: 7, ..Default::default() };
        let got = mine_sequences(&db, &cfg).unwrap();
        assert_eq!(got.records[0].duration, 3); // 21 days = 3 weeks
    }

    #[test]
    fn zero_duration_unit_is_rejected_not_clamped() {
        // Regression: a unit of 0 used to be silently clamped to 1,
        // diverging from the validated config/plan surfaces.
        let db = tiny_db();
        let cfg = MiningConfig { duration_unit_days: 0, ..Default::default() };
        assert!(matches!(
            mine_sequences(&db, &cfg),
            Err(MiningError::InvalidConfig(_))
        ));
        assert!(matches!(
            mine_sequences_sharded(&db, &cfg),
            Err(MiningError::InvalidConfig(_))
        ));
        let file_cfg = MiningConfig {
            mode: MiningMode::FileBased,
            work_dir: std::env::temp_dir().join("tspm_test_zero_unit"),
            ..cfg
        };
        assert!(matches!(
            mine_sequences_to_files(&db, &file_cfg),
            Err(MiningError::InvalidConfig(_))
        ));
    }

    #[test]
    fn extreme_date_spans_do_not_overflow() {
        // y.date - x.date overflows an i32 here; the i64 widening must
        // produce the exact day span (2^32 - 2 fits u32).
        let db = NumericDbMart::encode(&DbMart::new(vec![
            raw("A", i32::MIN + 1, "a"),
            raw("A", i32::MAX, "b"),
        ]));
        let got = mine_sequences(&db, &MiningConfig::default()).unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got.records[0].duration, u32::MAX - 1);
        // And a coarser unit divides the widened span, not a wrapped one.
        let weekly = MiningConfig { duration_unit_days: 7, ..Default::default() };
        let got = mine_sequences(&db, &weekly).unwrap();
        assert_eq!(got.records[0].duration, (u32::MAX - 1) / 7);
    }

    #[test]
    fn self_pairs_can_be_excluded() {
        let db = NumericDbMart::encode(&DbMart::new(vec![
            raw("A", 1, "a"),
            raw("A", 2, "a"),
            raw("A", 3, "b"),
        ]));
        let cfg = MiningConfig { include_self_pairs: false, ..Default::default() };
        let got = mine_sequences(&db, &cfg).unwrap();
        for r in &got.records {
            let (s, e) = decode_seq(r.seq);
            assert_ne!(s, e);
        }
        assert_eq!(got.len(), 2); // a@1→b, a@2→b
    }

    #[test]
    fn unsorted_input_is_sorted_internally() {
        let db = NumericDbMart::encode(&DbMart::new(vec![
            raw("A", 9, "c"),
            raw("A", 1, "a"),
            raw("A", 5, "b"),
        ]));
        let got = mine_sequences(&db, &MiningConfig::default()).unwrap();
        let a = db.lookup.phenx_id("a").unwrap();
        let c = db.lookup.phenx_id("c").unwrap();
        let ac = got.records.iter().find(|r| r.seq == encode_seq(a, c)).unwrap();
        assert_eq!(ac.duration, 8);
    }

    #[test]
    fn thread_counts_agree() {
        let mart = crate::synthea::SyntheaConfig::small().generate();
        let db = NumericDbMart::encode(&mart);
        let mut last: Option<Vec<SeqRecord>> = None;
        for threads in [1usize, 2, 4] {
            let cfg = MiningConfig { threads, ..Default::default() };
            let mut got = mine_sequences(&db, &cfg).unwrap().records;
            got.sort_unstable_by_key(|r| (r.seq, r.pid, r.duration));
            if let Some(prev) = &last {
                assert_eq!(prev, &got, "threads={threads} changed the result");
            }
            last = Some(got);
        }
    }

    #[test]
    fn file_mode_matches_memory_mode() {
        let mart = crate::synthea::SyntheaConfig::small().generate();
        let db = NumericDbMart::encode(&mart);
        let mem = mine_sequences(&db, &MiningConfig::default()).unwrap();

        let dir = std::env::temp_dir().join("tspm_test_filemode");
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = MiningConfig {
            mode: MiningMode::FileBased,
            work_dir: dir.clone(),
            threads: 3,
            ..Default::default()
        };
        let files = mine_sequences_to_files(&db, &cfg).unwrap();
        assert_eq!(files.total_records as usize, mem.len());
        let mut from_files = files.read_all().unwrap();
        let mut from_mem = mem.records.clone();
        from_files.sort_unstable_by_key(|r| (r.seq, r.pid, r.duration));
        from_mem.sort_unstable_by_key(|r| (r.seq, r.pid, r.duration));
        assert_eq!(from_files, from_mem);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_dbmart_yields_empty_set() {
        let db = NumericDbMart::default();
        let got = mine_sequences(&db, &MiningConfig::default()).unwrap();
        assert!(got.is_empty());
    }

    #[test]
    fn balance_patients_covers_all() {
        // bounds for 5 patients with sizes 1, 100, 2, 3, 50
        let bounds = vec![0, 1, 101, 103, 106, 156];
        for workers in [1usize, 2, 3, 8] {
            let ranges = balance_patients(&bounds, workers);
            let mut covered = Vec::new();
            for r in &ranges {
                for i in r.clone() {
                    covered.push(i);
                }
            }
            assert_eq!(covered, (0..5).collect::<Vec<_>>(), "workers={workers}");
        }
    }

    #[test]
    fn record_is_16_bytes() {
        assert_eq!(std::mem::size_of::<SeqRecord>(), 16);
    }

    #[test]
    fn sharded_matches_batch_for_every_layout() {
        let mart = crate::synthea::SyntheaConfig::small().generate();
        let db = NumericDbMart::encode(&mart);
        let key = |r: &SeqRecord| (r.seq, r.pid, r.duration);
        let mut golden = mine_sequences(&db, &MiningConfig::default()).unwrap().records;
        golden.sort_unstable_by_key(key);
        for shards in [1usize, 2, 8, 64] {
            for threads in [1usize, 2, 4] {
                let cfg = MiningConfig { shards, threads, ..Default::default() };
                let mut got = mine_sequences_sharded(&db, &cfg).unwrap().records;
                got.sort_unstable_by_key(key);
                assert_eq!(got, golden, "shards={shards} threads={threads}");
            }
        }
    }

    #[test]
    fn sharded_respects_mining_filters() {
        let mart = crate::synthea::SyntheaConfig::small().generate();
        let db = NumericDbMart::encode(&mart);
        let key = |r: &SeqRecord| (r.seq, r.pid, r.duration);
        for (first_only, self_pairs, unit) in
            [(true, true, 1u32), (false, false, 7), (true, false, 30)]
        {
            let cfg = MiningConfig {
                first_occurrence_only: first_only,
                include_self_pairs: self_pairs,
                duration_unit_days: unit,
                ..Default::default()
            };
            let mut batch = mine_sequences(&db, &cfg).unwrap().records;
            batch.sort_unstable_by_key(key);
            let sharded_cfg = MiningConfig { shards: 5, threads: 3, ..cfg };
            let mut got = mine_sequences_sharded(&db, &sharded_cfg).unwrap().records;
            got.sort_unstable_by_key(key);
            assert_eq!(got, batch, "first_only={first_only} self_pairs={self_pairs} unit={unit}");
        }
    }

    #[test]
    fn sharded_empty_dbmart_yields_empty_set() {
        let db = NumericDbMart::default();
        let got = mine_sequences_sharded(&db, &MiningConfig::default()).unwrap();
        assert!(got.is_empty());
    }

    #[test]
    fn worker_threads_prefers_explicit_config() {
        assert_eq!(MiningConfig { threads: 3, ..Default::default() }.worker_threads(), 3);
        let auto = MiningConfig::default().worker_threads();
        assert!((1..=crate::par::MAX_THREADS).contains(&auto));
    }

    #[test]
    fn effective_shards_policy() {
        // explicit wins, clamped by patients
        assert_eq!(effective_shards(6, 100), 6);
        assert_eq!(effective_shards(200, 100), 100);
        // auto = DEFAULT_SHARDS, clamped by patients — never the worker
        // count, so the layout is TSPM_THREADS-independent
        assert_eq!(effective_shards(0, 1000), DEFAULT_SHARDS);
        assert_eq!(effective_shards(0, 3), 3);
        // never zero, even with no patients
        assert_eq!(effective_shards(0, 0), 1);
        assert_eq!(effective_shards(1, 0), 1);
        // hard cap
        assert_eq!(effective_shards(usize::MAX, usize::MAX), MAX_SHARDS);
    }

    #[test]
    fn sharded_tracker_records_peak() {
        let mart = crate::synthea::SyntheaConfig::small().generate();
        let db = NumericDbMart::encode(&mart);
        let tracker = MemTracker::new();
        let got =
            mine_sequences_sharded_tracked(&db, &MiningConfig::default(), Some(&tracker))
                .unwrap();
        assert!(tracker.peak() >= got.byte_size());
    }

    #[test]
    fn memory_tracker_records_peak() {
        let mart = crate::synthea::SyntheaConfig::small().generate();
        let db = NumericDbMart::encode(&mart);
        let tracker = MemTracker::new();
        let got = mine_sequences_tracked(&db, &MiningConfig::default(), Some(&tracker)).unwrap();
        assert!(tracker.peak() >= got.byte_size());
    }

    #[test]
    fn oversized_shard_count_is_rejected_everywhere() {
        // The shard cap used to live only in Plan::validate; the collapsed
        // MineContext validator rejects it at every mining entry point.
        let db = tiny_db();
        let cfg = MiningConfig { shards: MAX_SHARDS + 1, ..Default::default() };
        assert!(matches!(mine_sequences(&db, &cfg), Err(MiningError::InvalidConfig(_))));
        assert!(matches!(
            mine_sequences_sharded(&db, &cfg),
            Err(MiningError::InvalidConfig(_))
        ));
    }

    #[test]
    fn targeted_mine_equals_filtered_full_mine() {
        use crate::target::{TargetPos, TargetSpec};
        let mart = crate::synthea::SyntheaConfig::small().generate();
        let db = NumericDbMart::encode(&mart);
        let cfg = MiningConfig::default();
        let full = mine_sequences(&db, &cfg).unwrap();
        let specs = [
            TargetSpec::for_codes([0, 2, 5]),
            TargetSpec::for_codes([1]).with_pos(TargetPos::First),
            TargetSpec::for_codes([3, 4]).with_pos(TargetPos::Second),
            TargetSpec::all().with_duration_band(Some(1), Some(60)),
            TargetSpec::for_codes([0, 1, 2]).with_duration_band(None, Some(30)),
        ];
        for spec in &specs {
            let want: Vec<SeqRecord> = full
                .records
                .iter()
                .copied()
                .filter(|r| spec.matches_record(r))
                .collect();
            let ctx = MineContext::with_target(&cfg, Some(spec));
            let got = mine_sequences_with(&db, ctx, None).unwrap();
            // Same records in the same order — the pushdown is a pure
            // per-pair filter over the identical enumeration.
            assert_eq!(got.records, want, "spec {}", spec.render());
            let sharded_cfg = MiningConfig { shards: 7, threads: 3, ..cfg.clone() };
            let sharded = mine_sequences_sharded_with(
                &db,
                MineContext::with_target(&sharded_cfg, Some(spec)),
                None,
            )
            .unwrap();
            let key = |r: &SeqRecord| (r.seq, r.pid, r.duration);
            let mut a = sharded.records;
            let mut b = want.clone();
            a.sort_unstable_by_key(key);
            b.sort_unstable_by_key(key);
            assert_eq!(a, b, "sharded spec {}", spec.render());
        }
    }

    #[test]
    fn all_target_is_normalized_to_untargeted() {
        let db = tiny_db();
        let cfg = MiningConfig::default();
        let all = TargetSpec::all();
        let ctx = MineContext::with_target(&cfg, Some(&all));
        assert!(ctx.target.is_none(), "all() must take the untargeted path");
        let got = mine_sequences_with(&db, ctx, None).unwrap();
        let want = mine_sequences(&db, &cfg).unwrap();
        assert_eq!(got.records, want.records);
    }

    #[test]
    fn targeted_file_mode_matches_targeted_memory_mode() {
        let mart = crate::synthea::SyntheaConfig::small().generate();
        let db = NumericDbMart::encode(&mart);
        let spec = TargetSpec::for_codes([0, 3]).with_duration_band(Some(1), None);
        let cfg = MiningConfig::default();
        let mem = mine_sequences_with(&db, MineContext::with_target(&cfg, Some(&spec)), None)
            .unwrap();
        let dir = std::env::temp_dir().join("tspm_test_targeted_filemode");
        let _ = std::fs::remove_dir_all(&dir);
        let file_cfg = MiningConfig {
            mode: MiningMode::FileBased,
            work_dir: dir.clone(),
            threads: 2,
            ..Default::default()
        };
        let files = mine_sequences_to_files_with(
            &db,
            MineContext::with_target(&file_cfg, Some(&spec)),
            None,
        )
        .unwrap();
        assert_eq!(files.total_records as usize, mem.len());
        let key = |r: &SeqRecord| (r.seq, r.pid, r.duration);
        let mut from_files = files.read_all().unwrap();
        let mut from_mem = mem.records.clone();
        from_files.sort_unstable_by_key(key);
        from_mem.sort_unstable_by_key(key);
        assert_eq!(from_files, from_mem);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn invalid_target_is_rejected_by_the_context_validator() {
        let db = tiny_db();
        let cfg = MiningConfig::default();
        let empty = TargetSpec::for_codes([]);
        assert!(matches!(
            mine_sequences_with(&db, MineContext::with_target(&cfg, Some(&empty)), None),
            Err(MiningError::InvalidConfig(_))
        ));
        let inverted = TargetSpec::all().with_duration_band(Some(9), Some(2));
        assert!(matches!(
            mine_sequences_with(&db, MineContext::with_target(&cfg, Some(&inverted)), None),
            Err(MiningError::InvalidConfig(_))
        ));
    }
}

/// Exhaustive-interleaving check of the sharded merge's write-once slot
/// protocol: each worker claims a shard index from the atomic counter,
/// fills that shard's `OnceLock` slot exactly once, and the merge drains
/// the slots in shard order — so the merged output can never depend on
/// completion order. Compiled only under `RUSTFLAGS="--cfg loom"`; see
/// the crate "Verification" docs.
#[cfg(all(test, loom))]
mod loom_tests {
    use crate::sync::atomic::{AtomicUsize, Ordering};
    use crate::sync::{Arc, OnceLock};

    #[test]
    fn loom_shard_slots_are_write_once_and_merge_in_shard_order() {
        loom::model(|| {
            const SHARDS: usize = 3;
            let slots: Arc<Vec<OnceLock<Vec<u32>>>> =
                Arc::new((0..SHARDS).map(|_| OnceLock::new()).collect());
            let next = Arc::new(AtomicUsize::new(0));
            let mut handles = Vec::new();
            for _ in 0..2 {
                let slots = Arc::clone(&slots);
                let next = Arc::clone(&next);
                handles.push(loom::thread::spawn(move || loop {
                    let si = next.fetch_add(1, Ordering::Relaxed);
                    if si >= SHARDS {
                        break;
                    }
                    // "Mine" the shard: its payload is a function of the
                    // shard index alone, like the real per-shard output.
                    let filled = slots[si].set(vec![si as u32; si + 1]).is_ok();
                    assert!(filled, "shard {si} claimed twice");
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
            // Drain in shard order: on every schedule the merge sees the
            // same deterministic concatenation.
            let slots = Arc::try_unwrap(slots).unwrap_or_else(|_| panic!("slots still shared"));
            let merged: Vec<u32> =
                slots.into_iter().flat_map(|s| s.into_inner().unwrap_or_default()).collect();
            assert_eq!(merged, vec![0, 1, 1, 2, 2, 2], "completion order never leaks");
        });
    }
}
