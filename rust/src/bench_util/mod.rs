//! Benchmark harness substrate (the `criterion` stand-in).
//!
//! Reproduces the paper's measurement protocol: run each configuration for
//! N iterations (the paper uses 10), record wall-clock runtime and peak
//! memory per iteration, and report **min / max / average** — exactly the
//! columns of the paper's Tables 1 and 2. Peak memory is reported two
//! ways: the process RSS high-water mark (matches GNU `time`, but is
//! monotone across configurations in one process) and the engine's logical
//! peak from [`crate::metrics::MemTracker`] (byte-accurate per run, the
//! number we compare against the paper).

use crate::metrics::{fmt_bytes, fmt_duration};
use std::time::{Duration, Instant};

pub mod experiments;

/// One measured iteration.
#[derive(Clone, Debug)]
pub struct Sample {
    pub elapsed: Duration,
    /// Logical peak bytes held by the engine during the iteration.
    pub peak_bytes: u64,
}

/// Aggregated stats for one benchmark row.
#[derive(Clone, Debug)]
pub struct RowStats {
    pub label: String,
    pub iterations: usize,
    pub time_min: Duration,
    pub time_max: Duration,
    pub time_avg: Duration,
    pub mem_min: u64,
    pub mem_max: u64,
    pub mem_avg: u64,
}

impl RowStats {
    pub fn from_samples(label: &str, samples: &[Sample]) -> RowStats {
        assert!(!samples.is_empty(), "no samples for row {label}");
        let n = samples.len();
        let times: Vec<Duration> = samples.iter().map(|s| s.elapsed).collect();
        let mems: Vec<u64> = samples.iter().map(|s| s.peak_bytes).collect();
        RowStats {
            label: label.to_string(),
            iterations: n,
            time_min: *times.iter().min().unwrap(),
            time_max: *times.iter().max().unwrap(),
            time_avg: times.iter().sum::<Duration>() / n as u32,
            mem_min: *mems.iter().min().unwrap(),
            mem_max: *mems.iter().max().unwrap(),
            mem_avg: mems.iter().sum::<u64>() / n as u64,
        }
    }
}

/// Run `iters` timed iterations of `f`, which returns the logical peak
/// bytes it observed (0 if not tracked).
pub fn measure<F: FnMut() -> u64>(iters: usize, mut f: F) -> Vec<Sample> {
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let start = Instant::now();
        let peak = f();
        samples.push(Sample { elapsed: start.elapsed(), peak_bytes: peak });
    }
    samples
}

/// Render rows as the paper-style table:
/// memory (min/max/avg) and runtime (min/max/avg) per implementation row.
pub fn render_table(title: &str, rows: &[RowStats]) -> String {
    let mut out = String::new();
    out.push_str(&format!("\n== {title} ==\n"));
    out.push_str(&format!(
        "{:<44} | {:>10} {:>10} {:>10} | {:>12} {:>12} {:>12}\n",
        "Implementation", "Mem min", "Mem max", "Mem avg", "Time min", "Time max", "Time avg"
    ));
    out.push_str(&"-".repeat(44 + 3 + 32 + 3 + 38 + 2));
    out.push('\n');
    for r in rows {
        out.push_str(&format!(
            "{:<44} | {:>10} {:>10} {:>10} | {:>12} {:>12} {:>12}\n",
            r.label,
            fmt_bytes(r.mem_min),
            fmt_bytes(r.mem_max),
            fmt_bytes(r.mem_avg),
            fmt_duration(r.time_min),
            fmt_duration(r.time_max),
            fmt_duration(r.time_avg),
        ));
    }
    out
}

/// Compute `baseline/current` speedup and memory-reduction factors between
/// two rows (the paper's "speedup by factor ~920", "~48-fold memory").
pub fn factors(baseline: &RowStats, current: &RowStats) -> (f64, f64) {
    let speedup = baseline.time_avg.as_secs_f64() / current.time_avg.as_secs_f64().max(1e-9);
    let memfold = baseline.mem_avg as f64 / (current.mem_avg as f64).max(1.0);
    (speedup, memfold)
}

/// Write a machine-readable copy of the rows next to the human table so
/// EXPERIMENTS.md can quote exact numbers.
pub fn rows_to_json(rows: &[RowStats]) -> crate::json::Json {
    use crate::json::Json;
    Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("label", Json::from(r.label.clone())),
                    ("iterations", Json::from(r.iterations)),
                    ("time_min_s", Json::from(r.time_min.as_secs_f64())),
                    ("time_max_s", Json::from(r.time_max.as_secs_f64())),
                    ("time_avg_s", Json::from(r.time_avg.as_secs_f64())),
                    ("mem_min_bytes", Json::from(r.mem_min)),
                    ("mem_max_bytes", Json::from(r.mem_max)),
                    ("mem_avg_bytes", Json::from(r.mem_avg)),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_min_max_avg() {
        let samples = vec![
            Sample { elapsed: Duration::from_millis(10), peak_bytes: 100 },
            Sample { elapsed: Duration::from_millis(20), peak_bytes: 300 },
            Sample { elapsed: Duration::from_millis(30), peak_bytes: 200 },
        ];
        let r = RowStats::from_samples("x", &samples);
        assert_eq!(r.time_min, Duration::from_millis(10));
        assert_eq!(r.time_max, Duration::from_millis(30));
        assert_eq!(r.time_avg, Duration::from_millis(20));
        assert_eq!(r.mem_min, 100);
        assert_eq!(r.mem_max, 300);
        assert_eq!(r.mem_avg, 200);
    }

    #[test]
    fn measure_runs_exactly_n() {
        let mut count = 0;
        let samples = measure(7, || {
            count += 1;
            count as u64
        });
        assert_eq!(samples.len(), 7);
        assert_eq!(count, 7);
        assert_eq!(samples.last().unwrap().peak_bytes, 7);
    }

    #[test]
    fn factors_ratio() {
        let base = RowStats {
            label: "tSPM".into(),
            iterations: 1,
            time_min: Duration::from_secs(100),
            time_max: Duration::from_secs(100),
            time_avg: Duration::from_secs(100),
            mem_min: 48_000,
            mem_max: 48_000,
            mem_avg: 48_000,
        };
        let cur = RowStats {
            label: "tSPM+".into(),
            iterations: 1,
            time_min: Duration::from_secs(1),
            time_max: Duration::from_secs(1),
            time_avg: Duration::from_secs(1),
            mem_min: 1_000,
            mem_max: 1_000,
            mem_avg: 1_000,
        };
        let (speed, mem) = factors(&base, &cur);
        assert!((speed - 100.0).abs() < 1e-9);
        assert!((mem - 48.0).abs() < 1e-9);
    }

    #[test]
    fn table_contains_rows_and_json_roundtrips() {
        let rows = vec![RowStats::from_samples(
            "tSPM+ file no-screen",
            &[Sample { elapsed: Duration::from_millis(14), peak_bytes: 1 << 30 }],
        )];
        let table = render_table("Table 1", &rows);
        assert!(table.contains("tSPM+ file no-screen"));
        assert!(table.contains("1.00 GiB"));
        let j = rows_to_json(&rows).to_string_pretty();
        assert!(crate::json::Json::parse(&j).is_ok());
    }
}
