//! The paper's experiments as reusable drivers.
//!
//! Each function regenerates one table/figure of the evaluation section
//! (see DESIGN.md per-experiment index) and returns paper-style
//! [`RowStats`] rows. Both the `cargo bench` targets and the `tspm bench`
//! subcommand call into here, so the CLI and the bench harness can never
//! drift apart.
//!
//! Scaling: the paper's full workloads (Table 1: 4,985 patients ×471;
//! Table 2: 35,000 ×318) assume a 256 GB testbed. `scale` shrinks the
//! cohort proportionally (default 0.1–0.2 in the bench targets, full
//! size with `--scale 1.0` on adequate hardware). Speedup/memory *ratios*
//! between rows are scale-stable, which is what we reproduce (DESIGN.md
//! §Substitutions).

use super::{factors, measure, render_table, RowStats};
use crate::baseline::{self, BaselineConfig};
use crate::dbmart::NumericDbMart;
use crate::metrics::MemTracker;
use crate::mining::{self, MiningConfig, MiningMode};
use crate::sparsity::{self, SparsityConfig};
use crate::synthea::SyntheaConfig;

/// Iterations per row (paper: 10).
pub const PAPER_ITERATIONS: usize = 10;

/// Sparsity threshold used in both benchmarks, scaled with the cohort so
/// the survivor fraction stays comparable.
pub fn threshold_for(patients: u64) -> u32 {
    ((patients / 100).max(2)) as u32
}

fn work_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("tspm_bench_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create bench work dir");
    dir
}

/// One tSPM+ configuration of the comparison/performance benchmarks.
fn run_tspm_plus(
    db: &NumericDbMart,
    first_occurrence_only: bool,
    screen: bool,
    mode: MiningMode,
    threshold: u32,
    tag: &str,
) -> u64 {
    let tracker = MemTracker::new();
    let cfg = MiningConfig {
        first_occurrence_only,
        mode,
        work_dir: work_dir(tag),
        ..Default::default()
    };
    match mode {
        MiningMode::InMemory => {
            let mut set = mining::mine_sequences_tracked(db, &cfg, Some(&tracker))
                .expect("mining failed");
            if screen {
                sparsity::screen(
                    &mut set.records,
                    &SparsityConfig { min_patients: threshold, threads: cfg.threads },
                );
            }
            std::hint::black_box(set.records.len());
        }
        MiningMode::FileBased => {
            let files = mining::mine_sequences_to_files_tracked(db, &cfg, Some(&tracker))
                .expect("mining failed");
            if screen {
                // The paper observes that file-based + screening loads the
                // records back and equalizes with in-memory — reproduce
                // that faithfully.
                let mut records = files.read_all().expect("read spill files");
                tracker.add((records.len() * 16) as u64);
                sparsity::screen(
                    &mut records,
                    &SparsityConfig { min_patients: threshold, threads: cfg.threads },
                );
                std::hint::black_box(records.len());
                tracker.sub((records.capacity() * 16) as u64);
            }
            let _ = files.remove();
        }
    }
    tracker.peak()
}

/// Original tSPM (baseline) run; returns logical peak bytes.
fn run_baseline(db: &crate::dbmart::DbMart, screen: bool, threshold: u32) -> u64 {
    let cfg = BaselineConfig {
        first_occurrence_only: true,
        sparsity_screen: screen,
        min_patients: threshold,
    };
    let result = baseline::mine(db, &cfg);
    std::hint::black_box(result.sequences.len());
    result.logical_bytes
}

/// **Table 1** — comparison benchmark: original tSPM vs tSPM+ on the
/// MGB-like cohort with the first-occurrence protocol.
pub fn table1(scale: f64, iterations: usize) -> Vec<RowStats> {
    let gen_cfg = SyntheaConfig::mgb_like(scale);
    let raw = gen_cfg.generate();
    let db = NumericDbMart::encode(&raw);
    let thr = threshold_for(gen_cfg.patients);

    let rows: Vec<(&str, Box<dyn FnMut() -> u64>)> = vec![
        (
            "tSPM (baseline)            no-screen  memory",
            Box::new(|| run_baseline(&raw, false, thr)),
        ),
        (
            "tSPM (baseline)            screen     memory",
            Box::new(|| run_baseline(&raw, true, thr)),
        ),
        (
            "tSPM+                      no-screen  memory",
            Box::new(|| run_tspm_plus(&db, true, false, MiningMode::InMemory, thr, "t1m")),
        ),
        (
            "tSPM+                      screen     memory",
            Box::new(|| run_tspm_plus(&db, true, true, MiningMode::InMemory, thr, "t1ms")),
        ),
        (
            "tSPM+                      screen     file",
            Box::new(|| run_tspm_plus(&db, true, true, MiningMode::FileBased, thr, "t1fs")),
        ),
        (
            "tSPM+                      no-screen  file",
            Box::new(|| run_tspm_plus(&db, true, false, MiningMode::FileBased, thr, "t1f")),
        ),
    ];

    rows.into_iter()
        .map(|(label, mut f)| RowStats::from_samples(label, &measure(iterations, &mut f)))
        .collect()
}

/// **Table 2** — performance benchmark: tSPM+ on the Synthea-COVID-like
/// cohort, all occurrences kept (no baseline: the paper dropped it too).
pub fn table2(scale: f64, iterations: usize) -> Vec<RowStats> {
    let gen_cfg = SyntheaConfig::synthea_covid_like(scale);
    let db = NumericDbMart::encode(&gen_cfg.generate());
    let thr = threshold_for(gen_cfg.patients);

    let rows: Vec<(&str, Box<dyn FnMut() -> u64>)> = vec![
        (
            "tSPM+                      no-screen  memory",
            Box::new(|| run_tspm_plus(&db, false, false, MiningMode::InMemory, thr, "t2m")),
        ),
        (
            "tSPM+                      screen     memory",
            Box::new(|| run_tspm_plus(&db, false, true, MiningMode::InMemory, thr, "t2ms")),
        ),
        (
            "tSPM+                      screen     file",
            Box::new(|| run_tspm_plus(&db, false, true, MiningMode::FileBased, thr, "t2fs")),
        ),
        (
            "tSPM+                      no-screen  file",
            Box::new(|| run_tspm_plus(&db, false, false, MiningMode::FileBased, thr, "t2f")),
        ),
    ];
    rows.into_iter()
        .map(|(label, mut f)| RowStats::from_samples(label, &measure(iterations, &mut f)))
        .collect()
}

/// The Table-2 prologue: demonstrate the 2³¹−1 element gate that made the
/// paper's 100k-patient run fail, and that adaptive partitioning clears
/// it. Returns (predicted_sequences, cap, chunks_needed).
pub fn table2_overflow_demo(scale: f64) -> (u64, u64, usize) {
    let gen_cfg = SyntheaConfig::synthea_covid_like(scale);
    let db = NumericDbMart::encode(&gen_cfg.generate());
    let cfg = MiningConfig::default();
    let mut entries = db.entries.clone();
    let bounds = mining::sort_and_chunk(&mut entries, 0);
    let total = mining::count_sequences(&entries, &bounds, &cfg);
    // The R limit, scaled down with the workload so the demo stays
    // proportionate (at scale 1.0 this is the real 2^31-1), but never
    // below the largest single patient (no partition could fix that).
    let max_patient = bounds
        .windows(2)
        .map(|w| mining::pairs_for(w[1] - w[0]))
        .max()
        .unwrap_or(1);
    let scaled = (((1u64 << 31) - 1) as f64 * scale * scale) as u64;
    let cap = scaled.max(max_patient).min(total.saturating_sub(1).max(max_patient));
    let plan = crate::partition::plan(&db, &cfg, cap).expect("partition plan");
    (total, cap, plan.len())
}

/// §Results "Performance on end user devices": ≥1,000 patients ×~400
/// entries on ≤4 threads must finish in < 5 minutes.
pub fn enduser(iterations: usize) -> Vec<RowStats> {
    let gen_cfg = SyntheaConfig {
        patients: 1000,
        avg_entries: 400.0,
        ..SyntheaConfig::mgb_like(1.0)
    };
    let db = NumericDbMart::encode(&gen_cfg.generate());
    let thr = threshold_for(gen_cfg.patients);
    let mut rows = Vec::new();
    for threads in [1usize, 2, 4] {
        let label = format!("tSPM+ end-user device      screen     memory {threads}T");
        let samples = measure(iterations, || {
            let tracker = MemTracker::new();
            let cfg = MiningConfig { threads, ..Default::default() };
            let mut set =
                mining::mine_sequences_tracked(&db, &cfg, Some(&tracker)).expect("mine");
            sparsity::screen(
                &mut set.records,
                &SparsityConfig { min_patients: thr, threads },
            );
            std::hint::black_box(set.records.len());
            tracker.peak()
        });
        rows.push(RowStats::from_samples(&label, &samples));
    }
    rows
}

/// Render rows plus the paper's headline factors for Table 1.
pub fn table1_report(rows: &[RowStats]) -> String {
    let mut out = render_table("Table 1 — comparison benchmark (tSPM vs tSPM+)", rows);
    // rows: [tSPM ns, tSPM s, tSPM+ ns mem, tSPM+ s mem, tSPM+ s file, tSPM+ ns file]
    if rows.len() == 6 {
        let (s_file, m_file) = factors(&rows[0], &rows[5]);
        let (s_mem, m_mem) = factors(&rows[0], &rows[2]);
        let (s_scr, m_scr) = factors(&rows[1], &rows[4]);
        out.push_str(&format!(
            "\npaper-style factors (baseline / tSPM+):\n\
             \x20 no-screen file : {s_file:8.1}x speed, {m_file:6.1}x memory   (paper: ~920x, ~48x)\n\
             \x20 no-screen mem  : {s_mem:8.1}x speed, {m_mem:6.1}x memory   (paper: ~210x, ~1.4x)\n\
             \x20 screen    file : {s_scr:8.1}x speed, {m_scr:6.1}x memory   (paper: ~297x, ~8x)\n"
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_smoke_tiny_scale() {
        let rows = table1(0.002, 1); // ~10 patients
        assert_eq!(rows.len(), 6);
        let report = table1_report(&rows);
        assert!(report.contains("paper-style factors"));
        // tSPM+ file mode must use (much) less logical memory than the
        // baseline even at toy scale.
        assert!(rows[5].mem_avg <= rows[0].mem_avg);
    }

    #[test]
    fn table2_smoke_tiny_scale() {
        let rows = table2(0.0005, 1); // ~18 patients
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert!(r.time_avg.as_nanos() > 0);
        }
        // file mode without screening keeps the smallest resident set
        let file_ns = &rows[3];
        let mem_ns = &rows[0];
        assert!(file_ns.mem_avg < mem_ns.mem_avg);
    }

    #[test]
    fn overflow_demo_partitions() {
        let (total, cap, chunks) = table2_overflow_demo(0.002);
        assert!(total > cap, "demo must overflow: {total} vs {cap}");
        assert!(chunks > 1);
    }

    #[test]
    fn threshold_scales() {
        assert_eq!(threshold_for(4985), 49);
        assert_eq!(threshold_for(100), 2);
        assert_eq!(threshold_for(10), 2);
    }
}
