//! Sparsity screening — removing sequences that occur in too few patients.
//!
//! Sparse sequences (present in only a handful of patients) invite
//! overfitting in downstream ML, so tSPM+ drops every sequence whose
//! *distinct-patient* count is below a threshold. Three implementations
//! live here, all verified equivalent:
//!
//! * [`screen`] — the production path (perf pass): one adaptive sort by
//!   `(seq, pid)` + a single-pass stable in-place compaction;
//! * [`screen_paper_strategy`] — the paper's "sophisticated approach"
//!   verbatim: sort by sequence id → run start positions → parallel
//!   **mark** of sparse records (`pid = u32::MAX`) → sort by patient id
//!   → one truncation ("this strategy optimized the number of memory
//!   allocations by minimizing its frequency to one");
//! * [`screen_naive`] — hash-map counting, the correctness oracle and
//!   the ablation baseline (bench `ablations`).

use crate::mining::SeqRecord;
use crate::par;
use crate::psort;

/// Marker pid for records scheduled for removal (paper: "assigning the
/// maximal possible value to the patient number").
pub const TOMBSTONE_PID: u32 = u32::MAX;

/// Screening configuration.
#[derive(Clone, Copy, Debug)]
pub struct SparsityConfig {
    /// Minimum number of *distinct patients* a sequence must appear in.
    pub min_patients: u32,
    /// Worker threads (0 = auto).
    pub threads: usize,
}

impl Default for SparsityConfig {
    fn default() -> Self {
        SparsityConfig { min_patients: 50, threads: 0 }
    }
}

/// Outcome statistics of a screen.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ScreenStats {
    pub records_before: u64,
    pub records_after: u64,
    pub distinct_before: u64,
    pub distinct_after: u64,
}

/// The production screen: radix sort by `(seq, pid)` + run scan + one
/// stable in-place compaction (perf pass, EXPERIMENTS.md §Perf).
///
/// Semantically identical to [`screen_paper_strategy`] — same surviving
/// records, same `(seq, pid)` output order — but avoids the strategy's
/// two extra full sorts: compaction happens in a single forward pass
/// (sorted order means survivors stay sorted), so the whole screen is
/// one sort + two linear passes.
///
/// Postcondition: `records` contains exactly the records of sequences
/// occurring in ≥ `min_patients` distinct patients, sorted by
/// `(seq, pid)`.
pub fn screen(records: &mut Vec<SeqRecord>, cfg: &SparsityConfig) -> ScreenStats {
    let threads = par::num_threads(Some(cfg.threads).filter(|&t| t > 0));
    let mut stats = ScreenStats {
        records_before: records.len() as u64,
        ..Default::default()
    };
    if records.is_empty() {
        return stats;
    }

    // 1. Sort by (seq, pid) — adaptive: pdqsort on one worker, parallel
    // radix otherwise (see psort::sort_auto).
    psort::sort_auto(records, |r| ((r.seq as u128) << 32) | r.pid as u128, threads);

    // 2+3. Run scan + stable compaction in one forward pass: for each
    // distinct-sequence run, count pid transitions; dense runs are
    // copied (within the same buffer, never overlapping reads ahead of
    // writes) to the write cursor.
    let len = records.len();
    let mut write = 0usize;
    let mut i = 0usize;
    while i < len {
        let seq = records[i].seq;
        let mut distinct = 1u32;
        let mut j = i + 1;
        while j < len && records[j].seq == seq {
            if records[j].pid != records[j - 1].pid {
                distinct += 1;
            }
            j += 1;
        }
        stats.distinct_before += 1;
        if distinct >= cfg.min_patients {
            stats.distinct_after += 1;
            let run_len = j - i;
            if write != i {
                records.copy_within(i..j, write);
            }
            write += run_len;
        }
        i = j;
    }
    records.truncate(write);
    stats.records_after = records.len() as u64;
    stats
}

/// The paper's original sort–mark–truncate strategy, kept verbatim for
/// the ablation benchmark and as a second implementation to cross-check
/// [`screen`] against:
///
/// sort by sequence id → start positions → parallel mark (`pid =
/// u32::MAX`) → sort by patient id → truncate at the first tombstone →
/// restore sequence order.
pub fn screen_paper_strategy(records: &mut Vec<SeqRecord>, cfg: &SparsityConfig) -> ScreenStats {
    let threads = par::num_threads(Some(cfg.threads).filter(|&t| t > 0));
    let mut stats = ScreenStats {
        records_before: records.len() as u64,
        ..Default::default()
    };
    if records.is_empty() {
        return stats;
    }

    // 1. Sort by (seq, pid): one composite u128 key comparison.
    psort::par_sort_by_key(records, |r| ((r.seq as u128) << 32) | r.pid as u128, threads);

    // 2. Start positions of each distinct sequence.
    let mut starts: Vec<usize> = Vec::new();
    let mut prev = u64::MAX;
    for (i, r) in records.iter().enumerate() {
        if r.seq != prev {
            starts.push(i);
            prev = r.seq;
        }
    }
    starts.push(records.len());
    stats.distinct_before = (starts.len() - 1) as u64;

    // 3. Parallel mark phase over run chunks. Runs are disjoint record
    //    ranges, so handing each worker a disjoint set of runs keeps the
    //    writes race-free; chunk sizes are large enough that marking does
    //    not thrash shared cache lines (paper: "the sequence chunks are
    //    large enough to mitigate cache invalidations").
    let min_patients = cfg.min_patients;
    let n_runs = starts.len() - 1;
    let kept_counts: Vec<u64> = {
        // Split runs into contiguous worker ranges aligned on run
        // boundaries, then let each worker mark its records via raw
        // pointers into the shared buffer. The base address travels as a
        // usize (Send + Sync); safety: runs are disjoint record ranges, so
        // no two workers ever touch the same record.
        let base_addr = records.as_mut_ptr() as usize;
        par::par_map_chunks(n_runs, threads, |run_range| {
            let base = base_addr as *mut SeqRecord;
            let mut kept = 0u64;
            for run in run_range {
                let (lo, hi) = (starts[run], starts[run + 1]);
                // Distinct patients in the run: pid transitions (input is
                // pid-sorted within the run).
                let slice = unsafe { std::slice::from_raw_parts_mut(base.add(lo), hi - lo) };
                let mut distinct = 1u32;
                for w in 0..slice.len().saturating_sub(1) {
                    if slice[w].pid != slice[w + 1].pid {
                        distinct += 1;
                    }
                }
                if distinct < min_patients {
                    for r in slice.iter_mut() {
                        r.pid = TOMBSTONE_PID;
                    }
                } else {
                    kept += 1;
                }
            }
            kept
        })
    };
    stats.distinct_after = kept_counts.iter().sum();

    // 4. Sort by pid → tombstones collect at the end; truncate once.
    psort::par_sort_by_key(records, |r| r.pid, threads);
    let cut = records.partition_point(|r| r.pid != TOMBSTONE_PID);
    records.truncate(cut);
    stats.records_after = records.len() as u64;

    // Restore (seq, pid) order for downstream consumers (matrix building,
    // utilities) — the paper's pipeline also continues on sequence order.
    psort::par_sort_by_key(records, |r| ((r.seq as u128) << 32) | r.pid as u128, threads);
    stats
}

/// Naive hash-based screen (correctness oracle / ablation baseline):
/// count distinct patients per sequence with a hash map, then filter.
pub fn screen_naive(records: &mut Vec<SeqRecord>, cfg: &SparsityConfig) -> ScreenStats {
    use std::collections::HashMap;
    let mut stats = ScreenStats {
        records_before: records.len() as u64,
        ..Default::default()
    };
    // seq -> (last pid seen, distinct count); records of one (seq,pid)
    // pair may be scattered, so count via a set-like two-pass.
    let mut seen: HashMap<(u64, u32), ()> = HashMap::new();
    let mut counts: HashMap<u64, u32> = HashMap::new();
    for r in records.iter() {
        if seen.insert((r.seq, r.pid), ()).is_none() {
            *counts.entry(r.seq).or_insert(0) += 1;
        }
    }
    stats.distinct_before = counts.len() as u64;
    records.retain(|r| counts[&r.seq] >= cfg.min_patients);
    stats.records_after = records.len() as u64;
    stats.distinct_after =
        counts.values().filter(|&&c| c >= cfg.min_patients).count() as u64;
    stats
}

/// Duration-sparsity screen (paper: duration helpers "leverage this
/// feature ... e.g. when calculating duration sparsity"): a sequence
/// survives only if, additionally, its *duration-bucket* diversity is
/// wide enough — i.e. it occurs with at least `min_distinct_durations`
/// different duration buckets of width `bucket_days` across the cohort.
pub fn screen_by_duration(
    records: &mut Vec<SeqRecord>,
    bucket_days: u32,
    min_distinct_durations: u32,
) -> ScreenStats {
    use crate::dbmart::pack_duration;
    use std::collections::HashMap;
    let bucket = bucket_days.max(1);
    let mut stats = ScreenStats {
        records_before: records.len() as u64,
        ..Default::default()
    };
    let mut buckets: HashMap<u64, Vec<u64>> = HashMap::new();
    for r in records.iter() {
        // The packed form keeps (seq, bucket) as a single sortable u64 —
        // exactly what the paper's bit-shift trick is for.
        let packed = pack_duration(r.seq, r.duration / bucket);
        buckets.entry(r.seq).or_default().push(packed);
    }
    stats.distinct_before = buckets.len() as u64;
    let mut keep: HashMap<u64, bool> = HashMap::with_capacity(buckets.len());
    for (seq, mut packs) in buckets {
        packs.sort_unstable();
        packs.dedup();
        let ok = packs.len() as u32 >= min_distinct_durations;
        stats.distinct_after += u64::from(ok);
        keep.insert(seq, ok);
    }
    records.retain(|r| keep[&r.seq]);
    stats.records_after = records.len() as u64;
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rec(seq: u64, pid: u32) -> SeqRecord {
        SeqRecord { seq, pid, duration: 0 }
    }

    #[test]
    fn drops_sequences_below_threshold() {
        // seq 1 in 3 patients, seq 2 in 1 patient, seq 3 in 2 patients
        let mut records = vec![
            rec(1, 10),
            rec(1, 11),
            rec(1, 12),
            rec(2, 10),
            rec(3, 10),
            rec(3, 11),
        ];
        let stats = screen(&mut records, &SparsityConfig { min_patients: 2, threads: 1 });
        let seqs: Vec<u64> = records.iter().map(|r| r.seq).collect();
        assert!(seqs.contains(&1) && seqs.contains(&3) && !seqs.contains(&2));
        assert_eq!(stats.records_before, 6);
        assert_eq!(stats.records_after, 5);
        assert_eq!(stats.distinct_before, 3);
        assert_eq!(stats.distinct_after, 2);
    }

    #[test]
    fn counts_distinct_patients_not_occurrences() {
        // seq 7 occurs 5 times but in only 1 patient → must be dropped at
        // threshold 2.
        let mut records: Vec<SeqRecord> = (0..5).map(|_| rec(7, 42)).collect();
        records.push(rec(8, 1));
        records.push(rec(8, 2));
        screen(&mut records, &SparsityConfig { min_patients: 2, threads: 1 });
        assert!(records.iter().all(|r| r.seq == 8));
    }

    #[test]
    fn threshold_one_keeps_everything() {
        let mut records = vec![rec(1, 1), rec(2, 2), rec(3, 3)];
        let stats = screen(&mut records, &SparsityConfig { min_patients: 1, threads: 1 });
        assert_eq!(stats.records_after, 3);
        assert_eq!(stats.distinct_after, 3);
    }

    #[test]
    fn empty_input() {
        let mut records: Vec<SeqRecord> = Vec::new();
        let stats = screen(&mut records, &SparsityConfig::default());
        assert_eq!(stats, ScreenStats::default());
    }

    #[test]
    fn everything_sparse_empties_the_set() {
        let mut records = vec![rec(1, 1), rec(2, 2)];
        let stats = screen(&mut records, &SparsityConfig { min_patients: 10, threads: 1 });
        assert!(records.is_empty());
        assert_eq!(stats.distinct_after, 0);
    }

    #[test]
    fn matches_naive_oracle_on_random_input() {
        let mut meta = Rng::new(4242);
        for case in 0..20 {
            let n = 1000 + meta.gen_range(30_000) as usize;
            let n_seqs = 1 + meta.gen_range(200);
            let n_pats = 1 + meta.gen_range(100);
            let threshold = 1 + meta.gen_range(8) as u32;
            let threads = 1 + meta.gen_range(4) as usize;
            let mut r = Rng::new(case);
            let mut a: Vec<SeqRecord> = (0..n)
                .map(|_| SeqRecord {
                    seq: r.gen_range(n_seqs),
                    pid: r.gen_range(n_pats) as u32,
                    duration: r.gen_range(1000) as u32,
                })
                .collect();
            let mut b = a.clone();
            let mut c = a.clone();
            let sa = screen(&mut a, &SparsityConfig { min_patients: threshold, threads });
            let sb = screen_naive(&mut b, &SparsityConfig { min_patients: threshold, threads });
            let sc = screen_paper_strategy(
                &mut c,
                &SparsityConfig { min_patients: threshold, threads },
            );
            a.sort_unstable_by_key(|x| (x.seq, x.pid, x.duration));
            b.sort_unstable_by_key(|x| (x.seq, x.pid, x.duration));
            c.sort_unstable_by_key(|x| (x.seq, x.pid, x.duration));
            assert_eq!(a, b, "case={case}");
            assert_eq!(a, c, "case={case} (paper strategy diverged)");
            assert_eq!(sa.records_after, sb.records_after);
            assert_eq!(sa.distinct_after, sb.distinct_after);
            assert_eq!(sa.distinct_before, sb.distinct_before);
            assert_eq!(sa, sc);
        }
    }

    #[test]
    fn output_is_seq_sorted() {
        let mut r = Rng::new(1);
        let mut records: Vec<SeqRecord> = (0..10_000)
            .map(|_| SeqRecord {
                seq: r.gen_range(50),
                pid: r.gen_range(500) as u32,
                duration: 0,
            })
            .collect();
        screen(&mut records, &SparsityConfig { min_patients: 3, threads: 2 });
        assert!(records.windows(2).all(|w| (w[0].seq, w[0].pid) <= (w[1].seq, w[1].pid)));
    }

    #[test]
    fn real_pid_equal_to_tombstone_is_impossible_by_construction() {
        // Patient ids come from dense interning (< number of patients),
        // so u32::MAX can never be a real pid; this test documents the
        // invariant the marking scheme relies on.
        let mart = crate::synthea::SyntheaConfig::small().generate();
        let db = crate::dbmart::NumericDbMart::encode(&mart);
        assert!((db.num_patients() as u32) < TOMBSTONE_PID);
    }

    #[test]
    fn duration_screen_requires_bucket_diversity() {
        // seq 1: durations 0, 100, 200 (3 buckets of 30d) — survives k=2.
        // seq 2: durations 5, 10 (same bucket) — dropped at k=2.
        let mut records = vec![
            SeqRecord { seq: 1, pid: 1, duration: 0 },
            SeqRecord { seq: 1, pid: 2, duration: 100 },
            SeqRecord { seq: 1, pid: 3, duration: 200 },
            SeqRecord { seq: 2, pid: 1, duration: 5 },
            SeqRecord { seq: 2, pid: 2, duration: 10 },
        ];
        let stats = screen_by_duration(&mut records, 30, 2);
        assert!(records.iter().all(|r| r.seq == 1));
        assert_eq!(stats.distinct_after, 1);
    }
}
