//! Sparsity screening — removing sequences that occur in too few patients.
//!
//! Sparse sequences (present in only a handful of patients) invite
//! overfitting in downstream ML, so tSPM+ drops every sequence whose
//! *distinct-patient* count is below a threshold. Four implementations
//! live here, all verified equivalent:
//!
//! * [`screen`] — the production in-memory path (perf pass): one
//!   adaptive sort by `(seq, pid)` + a single-pass stable in-place
//!   compaction;
//! * [`screen_paper_strategy`] — the paper's "sophisticated approach"
//!   verbatim: sort by sequence id → run start positions → parallel
//!   **mark** of sparse records (`pid = u32::MAX`) → sort by patient id
//!   → one truncation ("this strategy optimized the number of memory
//!   allocations by minimizing its frequency to one");
//! * [`screen_naive`] — hash-map counting, the correctness oracle and
//!   the ablation baseline (bench `ablations`);
//! * [`screen_spilled`] — the out-of-core path over [`crate::seqstore`]
//!   spill files: an external merge sort by `(seq, pid, duration)` with
//!   bounded buffers, counting distinct patients per merged sequence run
//!   and streaming survivors to new spill files. Resident memory is
//!   O(buffer), never O(records) — this is what lets a file-backed or
//!   streaming engine run finish when the screened output itself does
//!   not fit RAM.
//!
//! ## Targeted screening semantics
//!
//! Every screen has a `_with` variant taking an optional
//! [`crate::target::TargetSpec`]. Support (*distinct patients*) is then
//! counted **within the targeted multiset**: records the spec rejects
//! are removed before counting, and the `*_before` fields of
//! [`ScreenStats`] describe that targeted universe, not the full mine.
//!
//! **Pushdown safety.** The spec is a per-record predicate, and each
//! `_with` variant applies it as a filter *first* and then runs the
//! untargeted algorithm unchanged — so `targeted-screen(input)` is
//! *by construction* byte-identical to `screen(filter(input))`. Combined
//! with the mining-side argument (targeted mining emits exactly the
//! filtered full multiset, see [`crate::target`] and [`crate::mining`]),
//! this proves the end-to-end contract
//! `targeted-mine → screen ≡ full-mine → filter → screen`, which
//! `rust/tests/conformance.rs` enforces byte-for-byte. When the input
//! was already mined under the same spec, the filter is a no-op pass.

use crate::metrics::MemTracker;
use crate::mining::SeqRecord;
use crate::target::TargetSpec;
use crate::par;
use crate::psort;
use crate::seqstore::{SeqFileSet, SeqReader, SeqWriter, WRITER_BUFFER_BYTES};
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::io;
use std::path::{Path, PathBuf};

/// Marker pid for records scheduled for removal (paper: "assigning the
/// maximal possible value to the patient number").
pub const TOMBSTONE_PID: u32 = u32::MAX;

/// Screening configuration.
#[derive(Clone, Copy, Debug)]
pub struct SparsityConfig {
    /// Minimum number of *distinct patients* a sequence must appear in.
    pub min_patients: u32,
    /// Worker threads (0 = auto).
    pub threads: usize,
}

impl Default for SparsityConfig {
    fn default() -> Self {
        SparsityConfig { min_patients: 50, threads: 0 }
    }
}

/// Outcome statistics of a screen.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ScreenStats {
    pub records_before: u64,
    pub records_after: u64,
    pub distinct_before: u64,
    pub distinct_after: u64,
}

/// Drop records a target rejects — the shared prologue of every `_with`
/// screen variant. A `None` (or `is_all`) spec leaves the buffer
/// untouched, so the untargeted paths stay byte-identical. Centralizing
/// the filter here is what makes "targeted screen ≡ filter → screen"
/// true by construction for all four implementations at once.
fn apply_target(records: &mut Vec<SeqRecord>, target: Option<&TargetSpec>) {
    if let Some(t) = target.filter(|t| !t.is_all()) {
        records.retain(|r| t.matches_record(r));
    }
}

/// Distinct-patient count of one sequence run whose records are sorted
/// by pid (ties adjacent): one pid-transition scan. The one survivor
/// predicate shared by [`screen`], [`screen_paper_strategy`], and the
/// tests — extracted so the targeted variants cannot diverge from the
/// untargeted ones. The streaming twin in [`screen_spilled`] counts the
/// same transitions cursor-wise (it never holds a full run).
#[inline]
pub(crate) fn run_support(run: &[SeqRecord]) -> u32 {
    if run.is_empty() {
        return 0;
    }
    let mut distinct = 1u32;
    for w in run.windows(2) {
        if w[0].pid != w[1].pid {
            distinct += 1;
        }
    }
    distinct
}

/// The production screen: radix sort by `(seq, pid)` + run scan + one
/// stable in-place compaction (perf pass, EXPERIMENTS.md §Perf).
///
/// Semantically identical to [`screen_paper_strategy`] — same surviving
/// records, same `(seq, pid)` output order — but avoids the strategy's
/// two extra full sorts: compaction happens in a single forward pass
/// (sorted order means survivors stay sorted), so the whole screen is
/// one sort + two linear passes.
///
/// Postcondition: `records` contains exactly the records of sequences
/// occurring in ≥ `min_patients` distinct patients, sorted by
/// `(seq, pid)`.
pub fn screen(records: &mut Vec<SeqRecord>, cfg: &SparsityConfig) -> ScreenStats {
    screen_with(records, cfg, None)
}

/// [`screen`] over the targeted universe: records the spec rejects are
/// dropped first, then the untargeted algorithm runs unchanged (module
/// docs: "Targeted screening semantics").
pub fn screen_with(
    records: &mut Vec<SeqRecord>,
    cfg: &SparsityConfig,
    target: Option<&TargetSpec>,
) -> ScreenStats {
    apply_target(records, target);
    let threads = par::num_threads(Some(cfg.threads).filter(|&t| t > 0));
    let mut stats = ScreenStats {
        records_before: records.len() as u64,
        ..Default::default()
    };
    if records.is_empty() {
        return stats;
    }

    // 1. Sort by (seq, pid) — adaptive: pdqsort on one worker, parallel
    // radix otherwise (see psort::sort_auto).
    psort::sort_auto(records, |r| ((r.seq as u128) << 32) | r.pid as u128, threads);

    // 2+3. Run scan + stable compaction in one forward pass: for each
    // distinct-sequence run, count pid transitions (run_support); dense
    // runs are copied (within the same buffer, never overlapping reads
    // ahead of writes) to the write cursor.
    let len = records.len();
    let mut write = 0usize;
    let mut i = 0usize;
    while i < len {
        let seq = records[i].seq;
        let mut j = i + 1;
        while j < len && records[j].seq == seq {
            j += 1;
        }
        stats.distinct_before += 1;
        if run_support(&records[i..j]) >= cfg.min_patients {
            stats.distinct_after += 1;
            let run_len = j - i;
            if write != i {
                records.copy_within(i..j, write);
            }
            write += run_len;
        }
        i = j;
    }
    records.truncate(write);
    stats.records_after = records.len() as u64;
    stats
}

/// The paper's original sort–mark–truncate strategy, kept verbatim for
/// the ablation benchmark and as a second implementation to cross-check
/// [`screen`] against:
///
/// sort by sequence id → start positions → parallel mark (`pid =
/// u32::MAX`) → sort by patient id → truncate at the first tombstone →
/// restore sequence order.
pub fn screen_paper_strategy(records: &mut Vec<SeqRecord>, cfg: &SparsityConfig) -> ScreenStats {
    screen_paper_strategy_with(records, cfg, None)
}

/// [`screen_paper_strategy`] over the targeted universe (module docs:
/// "Targeted screening semantics").
pub fn screen_paper_strategy_with(
    records: &mut Vec<SeqRecord>,
    cfg: &SparsityConfig,
    target: Option<&TargetSpec>,
) -> ScreenStats {
    apply_target(records, target);
    let threads = par::num_threads(Some(cfg.threads).filter(|&t| t > 0));
    let mut stats = ScreenStats {
        records_before: records.len() as u64,
        ..Default::default()
    };
    if records.is_empty() {
        return stats;
    }

    // 1. Sort by (seq, pid): one composite u128 key comparison.
    psort::par_sort_by_key(records, |r| ((r.seq as u128) << 32) | r.pid as u128, threads);

    // 2. Start positions of each distinct sequence.
    let mut starts: Vec<usize> = Vec::new();
    let mut prev = u64::MAX;
    for (i, r) in records.iter().enumerate() {
        if r.seq != prev {
            starts.push(i);
            prev = r.seq;
        }
    }
    starts.push(records.len());
    stats.distinct_before = (starts.len() - 1) as u64;

    // 3. Parallel mark phase over run chunks. Runs are disjoint record
    //    ranges, so handing each worker a disjoint set of runs keeps the
    //    writes race-free; chunk sizes are large enough that marking does
    //    not thrash shared cache lines (paper: "the sequence chunks are
    //    large enough to mitigate cache invalidations").
    let min_patients = cfg.min_patients;
    let n_runs = starts.len() - 1;
    let kept_counts: Vec<u64> = {
        // Split runs into contiguous worker ranges aligned on run
        // boundaries, then carve the record buffer into one disjoint
        // mutable sub-slice per worker at those boundaries
        // (`split_at_mut`). The borrow checker now proves what the
        // retired raw-pointer version merely asserted — no two workers
        // ever touch the same record. (The old code smuggled
        // `as_mut_ptr() as usize` across the closure, which is UB under
        // Miri's strict-provenance model; this formulation is
        // provenance-clean with zero `unsafe`.)
        let worker_runs = par::split_ranges(n_runs, threads);
        let mut parts: Vec<(&mut [SeqRecord], std::ops::Range<usize>)> =
            Vec::with_capacity(worker_runs.len());
        let mut rest: &mut [SeqRecord] = records;
        let mut consumed = 0usize;
        for rr in worker_runs {
            let end = starts[rr.end];
            let (head, tail) = rest.split_at_mut(end - consumed);
            consumed = end;
            parts.push((head, rr));
            rest = tail;
        }
        par::par_map_parts(parts, |_, (part, rr)| {
            // Run offsets in `starts` are absolute; this worker's slice
            // begins at its first run's start.
            let base = starts[rr.start];
            let mut kept = 0u64;
            for run in rr {
                let slice = &mut part[starts[run] - base..starts[run + 1] - base];
                // Distinct patients in the run: pid transitions (input is
                // pid-sorted within the run) — the shared run_support
                // predicate, same as screen's.
                if run_support(slice) < min_patients {
                    for r in slice.iter_mut() {
                        r.pid = TOMBSTONE_PID;
                    }
                } else {
                    kept += 1;
                }
            }
            kept
        })
    };
    stats.distinct_after = kept_counts.iter().sum();

    // 4. Sort by pid → tombstones collect at the end; truncate once.
    psort::par_sort_by_key(records, |r| r.pid, threads);
    let cut = records.partition_point(|r| r.pid != TOMBSTONE_PID);
    records.truncate(cut);
    stats.records_after = records.len() as u64;

    // Restore (seq, pid) order for downstream consumers (matrix building,
    // utilities) — the paper's pipeline also continues on sequence order.
    psort::par_sort_by_key(records, |r| ((r.seq as u128) << 32) | r.pid as u128, threads);
    stats
}

/// Naive hash-based screen (correctness oracle / ablation baseline):
/// count distinct patients per sequence with a hash map, then filter.
pub fn screen_naive(records: &mut Vec<SeqRecord>, cfg: &SparsityConfig) -> ScreenStats {
    screen_naive_with(records, cfg, None)
}

/// [`screen_naive`] over the targeted universe — the oracle for the
/// targeted conformance contract (module docs: "Targeted screening
/// semantics").
pub fn screen_naive_with(
    records: &mut Vec<SeqRecord>,
    cfg: &SparsityConfig,
    target: Option<&TargetSpec>,
) -> ScreenStats {
    use std::collections::HashMap;
    apply_target(records, target);
    let mut stats = ScreenStats {
        records_before: records.len() as u64,
        ..Default::default()
    };
    // seq -> (last pid seen, distinct count); records of one (seq,pid)
    // pair may be scattered, so count via a set-like two-pass.
    let mut seen: HashMap<(u64, u32), ()> = HashMap::new();
    let mut counts: HashMap<u64, u32> = HashMap::new();
    for r in records.iter() {
        if seen.insert((r.seq, r.pid), ()).is_none() {
            *counts.entry(r.seq).or_insert(0) += 1;
        }
    }
    stats.distinct_before = counts.len() as u64;
    records.retain(|r| counts[&r.seq] >= cfg.min_patients);
    stats.records_after = records.len() as u64;
    // lint:allow(hashmap_iter) — a count over the values; any iteration
    // order produces the same number.
    stats.distinct_after =
        counts.values().filter(|&&c| c >= cfg.min_patients).count() as u64;
    stats
}

// ---------------------------------------------------------------------------
// Out-of-core screening (external merge over seqstore spill files)
// ---------------------------------------------------------------------------

/// Options for [`screen_spilled`]: where survivors land and how much
/// buffer memory each phase may keep resident.
#[derive(Clone, Debug)]
pub struct SpillScreenConfig {
    /// Minimum number of *distinct patients* a sequence must appear in.
    pub min_patients: u32,
    /// Worker threads for the in-buffer sorts (0 = auto).
    pub threads: usize,
    /// Bound (bytes) on each phase's record buffers: the run-sort
    /// buffer, the k-way merge cursors combined, and the pending-run
    /// buffer are each capped near this size. `u64::MAX` degenerates to
    /// one in-memory run (still producing identical output).
    pub buffer_bytes: u64,
    /// Directory for the survivor file (and the transient sorted runs).
    pub out_dir: PathBuf,
}

const REC_BYTES: u64 = std::mem::size_of::<SeqRecord>() as u64;
const ZERO_REC: SeqRecord = SeqRecord { seq: 0, pid: 0, duration: 0 };

/// Total order used by the external merge: `(seq, pid, duration)`.
/// Sorting on the *full* record key makes the merged stream — and with
/// it the survivor file — byte-identical for every buffer size and run
/// layout: records with equal keys are identical, so tie order between
/// runs cannot change the output. `pub(crate)` so the segment compactor
/// ([`crate::ingest`]) merges segment data files under the same order.
pub(crate) fn spill_key(r: &SeqRecord) -> u128 {
    ((r.seq as u128) << 64) | ((r.pid as u128) << 32) | r.duration as u128
}

/// One sorted run being merged: a bounded record buffer over a
/// capacity-bounded [`SeqReader`].
struct RunCursor {
    reader: SeqReader,
    buf: Vec<SeqRecord>,
    pos: usize,
    len: usize,
}

impl RunCursor {
    fn open(path: &Path, records: usize) -> io::Result<RunCursor> {
        let records = records.max(1);
        let mut c = RunCursor {
            reader: SeqReader::open_with_capacity(path, records * REC_BYTES as usize)?,
            buf: vec![ZERO_REC; records],
            pos: 0,
            len: 0,
        };
        c.refill()?;
        Ok(c)
    }

    fn refill(&mut self) -> io::Result<()> {
        self.pos = 0;
        self.len = self.reader.read_batch(&mut self.buf)?;
        Ok(())
    }

    fn head(&self) -> Option<SeqRecord> {
        if self.pos < self.len {
            Some(self.buf[self.pos])
        } else {
            None
        }
    }

    fn advance(&mut self) -> io::Result<()> {
        self.pos += 1;
        if self.pos >= self.len {
            self.refill()?;
        }
        Ok(())
    }
}

/// Maximum sorted runs merged at once. Bounding the fan-in keeps the
/// open-file count independent of the input/buffer ratio (a ~9 GB
/// multiset under a tight budget produces thousands of runs — opening
/// them all at once hits the default 1024-fd ulimit) and keeps per-run
/// merge buffers from collapsing toward one record. Run counts beyond
/// this are compacted by intermediate merge passes first.
const MERGE_FAN_IN: usize = 64;

/// Stream the fully merged (globally `(seq, pid, duration)`-sorted)
/// record sequence of the sorted runs in `paths` to `emit`. `per_run`
/// bounds each cursor's record buffer.
fn merge_sorted_runs(
    paths: &[PathBuf],
    per_run: usize,
    emit: impl FnMut(SeqRecord) -> io::Result<()>,
) -> io::Result<()> {
    merge_sorted_runs_by(paths, per_run, spill_key, emit)
}

/// [`merge_sorted_runs`] under an arbitrary total order: the key
/// function maps each record to a `u128` and the merged stream is
/// emitted in ascending key order. Every run in `paths` must already be
/// sorted by the same key. Ties between runs break toward the
/// lower-indexed run (the heap key carries the run index), so the
/// output is deterministic for any run layout — provided equal-key
/// records are byte-identical, as they are under the full-record keys
/// this crate uses. `pub(crate)` for the segment compactor
/// ([`crate::ingest`]), which merges pid-major segment copies under a
/// `(pid, seq, duration)` order.
pub(crate) fn merge_sorted_runs_by(
    paths: &[PathBuf],
    per_run: usize,
    key: impl Fn(&SeqRecord) -> u128,
    mut emit: impl FnMut(SeqRecord) -> io::Result<()>,
) -> io::Result<()> {
    let mut cursors = Vec::with_capacity(paths.len());
    for p in paths {
        cursors.push(RunCursor::open(p, per_run)?);
    }
    let mut heap: BinaryHeap<Reverse<(u128, usize)>> = BinaryHeap::new();
    for (i, c) in cursors.iter().enumerate() {
        if let Some(r) = c.head() {
            heap.push(Reverse((key(&r), i)));
        }
    }
    while let Some(Reverse((_, i))) = heap.pop() {
        let r = cursors[i].head().expect("heap entry implies a buffered record");
        cursors[i].advance()?;
        if let Some(next) = cursors[i].head() {
            heap.push(Reverse((key(&next), i)));
        }
        emit(r)?;
    }
    Ok(())
}

/// State of the sequence run currently flowing out of the merge. Most
/// runs fit the bounded `pending` buffer; a run larger than the buffer
/// overflows to a temp spill file, so even a sequence present in every
/// record never forces the run resident.
struct PendingRun {
    pending: Vec<SeqRecord>,
    cap: usize,
    overflow: Option<(SeqWriter, u64)>,
    overflow_path: PathBuf,
    write_cap: usize,
}

impl PendingRun {
    fn push(&mut self, r: SeqRecord, tracker: Option<&MemTracker>) -> io::Result<()> {
        if self.pending.len() == self.cap {
            if self.overflow.is_none() {
                if let Some(t) = tracker {
                    t.add(self.write_cap as u64);
                }
                self.overflow = Some((
                    SeqWriter::create_with_capacity(&self.overflow_path, self.write_cap)?,
                    0,
                ));
            }
            let (w, n) = self.overflow.as_mut().expect("just inserted");
            for rec in self.pending.drain(..) {
                w.write(rec)?;
                *n += 1;
            }
        }
        self.pending.push(r);
        Ok(())
    }

    /// Close out the current sequence run: stream it to `out` when it
    /// survives, drop it otherwise. Returns the number of records kept.
    fn finalize(
        &mut self,
        survives: bool,
        out: &mut SeqWriter,
        scratch: &mut [SeqRecord],
        tracker: Option<&MemTracker>,
    ) -> io::Result<u64> {
        let mut kept = 0u64;
        if let Some((w, count)) = self.overflow.take() {
            w.finish()?;
            if let Some(t) = tracker {
                t.sub(self.write_cap as u64);
            }
            if survives {
                // Overflowed records precede the buffered tail in merge
                // order — copy them through first.
                let mut reader =
                    SeqReader::open_with_capacity(&self.overflow_path, self.write_cap)?;
                loop {
                    let n = reader.read_batch(scratch)?;
                    if n == 0 {
                        break;
                    }
                    for &r in &scratch[..n] {
                        out.write(r)?;
                    }
                }
                kept += count;
            }
            let _ = std::fs::remove_file(&self.overflow_path);
        }
        if survives {
            for &r in self.pending.iter() {
                out.write(r)?;
            }
            kept += self.pending.len() as u64;
        }
        self.pending.clear();
        Ok(kept)
    }
}

/// The out-of-core screen: externally merge-sort `input`'s spill files
/// by `(seq, pid, duration)` using buffers bounded by
/// [`SpillScreenConfig::buffer_bytes`], count distinct patients per
/// sequence run on the merged stream, and write surviving records —
/// globally sorted — to a new spill file under `out_dir`.
///
/// Semantically identical to [`screen`] (same survivors, same
/// [`ScreenStats`]); the output is additionally deterministic across
/// buffer sizes because the merge orders on the full record key. The
/// input files are left untouched; `tracker`, when provided, accounts
/// every buffer so engine runs can prove their budget was honoured.
pub fn screen_spilled(
    input: &SeqFileSet,
    cfg: &SpillScreenConfig,
    tracker: Option<&MemTracker>,
) -> io::Result<(SeqFileSet, ScreenStats)> {
    screen_spilled_with(input, cfg, None, tracker)
}

/// [`screen_spilled`] over the targeted universe: records the spec
/// rejects are dropped as each input batch is read (pass 1), before they
/// ever reach a sorted run — so `records_before` and all downstream
/// stats describe the targeted multiset, exactly as the in-memory
/// `_with` variants do (module docs: "Targeted screening semantics").
pub fn screen_spilled_with(
    input: &SeqFileSet,
    cfg: &SpillScreenConfig,
    target: Option<&TargetSpec>,
    tracker: Option<&MemTracker>,
) -> io::Result<(SeqFileSet, ScreenStats)> {
    let target = target.filter(|t| !t.is_all());
    let threads = par::num_threads(Some(cfg.threads).filter(|&t| t > 0));
    let track = |b: u64| {
        if let Some(t) = tracker {
            t.add(b)
        }
    };
    let untrack = |b: u64| {
        if let Some(t) = tracker {
            t.sub(b)
        }
    };

    std::fs::create_dir_all(&cfg.out_dir)?;
    let run_dir = cfg.out_dir.join("screen_runs");
    std::fs::create_dir_all(&run_dir)?;

    // Buffer capacity in records: bounded by the budget, floored so
    // degenerate budgets still make progress, and never sized past the
    // input itself.
    let cap = (cfg.buffer_bytes / REC_BYTES).clamp(64, input.total_records.max(64)) as usize;
    // File buffers follow the same budget, capped at the default 1 MiB.
    let write_cap =
        (cfg.buffer_bytes.min(WRITER_BUFFER_BYTES as u64) as usize).max(4096);

    let mut stats = ScreenStats::default();

    // --- pass 1: bounded chunks → sorted run files ---------------------
    let mut buf = vec![ZERO_REC; cap];
    track(cap as u64 * REC_BYTES);
    let mut runs: Vec<PathBuf> = Vec::new();
    let mut filled = 0usize;
    let flush = |buf: &mut [SeqRecord], runs: &mut Vec<PathBuf>| -> io::Result<()> {
        psort::sort_auto(buf, spill_key, threads);
        let path = run_dir.join(format!("run_{:06}.tspm", runs.len()));
        track(write_cap as u64);
        let mut w = SeqWriter::create_with_capacity(&path, write_cap)?;
        for &r in buf.iter() {
            w.write(r)?;
        }
        w.finish()?;
        untrack(write_cap as u64);
        runs.push(path);
        Ok(())
    };
    for source in &input.files {
        let mut reader = SeqReader::open_with_capacity(source, write_cap)?;
        loop {
            let n = reader.read_batch(&mut buf[filled..])?;
            if n == 0 {
                break;
            }
            // Targeted pushdown: compact the just-read batch in place so
            // only matching records count toward `filled` (and the
            // stats). Rejected records never reach a sorted run, keeping
            // every later pass identical to screening the filtered set.
            let kept = match target {
                Some(t) => {
                    let mut w = filled;
                    for i in filled..filled + n {
                        if t.matches_record(&buf[i]) {
                            buf[w] = buf[i];
                            w += 1;
                        }
                    }
                    w - filled
                }
                None => n,
            };
            filled += kept;
            stats.records_before += kept as u64;
            if filled == cap {
                flush(&mut buf[..filled], &mut runs)?;
                filled = 0;
            }
        }
    }
    if filled > 0 {
        flush(&mut buf[..filled], &mut runs)?;
    }
    drop(flush);
    drop(buf);
    untrack(cap as u64 * REC_BYTES);

    // --- pass 2: bounded-fan-in compaction ------------------------------
    // Multi-pass merge keeps at most MERGE_FAN_IN runs open at once; the
    // final screened merge below then also stays under the fd bound and
    // keeps useful per-run buffers. Multi-pass output is identical to a
    // single-pass merge (full-key order, equal keys are equal records).
    // Process-wide merge observability: counters only — atomic adds
    // that cannot perturb the deterministic merge output.
    let obs_reg = crate::obs::metrics::global();
    let mut generation = 0u32;
    while runs.len() > MERGE_FAN_IN {
        obs_reg.counter(crate::obs::names::SCREEN_SPILL_MERGE_PASSES).inc();
        // Per-pass observability: a child of the ambient span (the
        // engine's screen stage, or a test root) carrying the pass's
        // merge fan-in and byte volume. Attrs only — the span cannot
        // perturb the merge output.
        let mut pass_span = crate::obs::trace::current_span("sparsity.spill_merge_pass");
        let runs_in_pass = runs.len() as u64;
        let mut pass_bytes = 0u64;
        let per_run = (cap / MERGE_FAN_IN).max(1);
        let mut next: Vec<PathBuf> = Vec::new();
        for (gi, group) in runs.chunks(MERGE_FAN_IN).enumerate() {
            let path = run_dir.join(format!("merge_{generation:02}_{gi:06}.tspm"));
            let group_bytes =
                (group.len() * per_run) as u64 * REC_BYTES * 2 + write_cap as u64;
            track(group_bytes);
            obs_reg
                .counter(crate::obs::names::SCREEN_SPILL_RUNS_OPENED)
                .add(group.len() as u64);
            let mut w = SeqWriter::create_with_capacity(&path, write_cap)?;
            let mut pass_records = 0u64;
            merge_sorted_runs(group, per_run, |r| {
                pass_records += 1;
                w.write(r)
            })?;
            obs_reg
                .counter(crate::obs::names::SCREEN_SPILL_BYTES_MERGED)
                .add(pass_records * REC_BYTES);
            pass_bytes += pass_records * REC_BYTES;
            w.finish()?;
            untrack(group_bytes);
            next.push(path);
        }
        if let Some(s) = pass_span.as_mut() {
            s.attr("generation", u64::from(generation));
            s.attr("runs_merged", runs_in_pass);
            s.attr("bytes_merged", pass_bytes);
        }
        drop(pass_span);
        for p in &runs {
            let _ = std::fs::remove_file(p);
        }
        runs = next;
        generation += 1;
    }

    // --- pass 3: final k-way merge + streaming screen --------------------
    obs_reg.counter(crate::obs::names::SCREEN_SPILL_MERGE_PASSES).inc();
    obs_reg
        .counter(crate::obs::names::SCREEN_SPILL_RUNS_OPENED)
        .add(runs.len() as u64);
    obs_reg
        .counter(crate::obs::names::SCREEN_SPILL_BYTES_MERGED)
        .add(stats.records_before * REC_BYTES);
    let mut final_span = crate::obs::trace::current_span("sparsity.spill_merge_pass");
    if let Some(s) = final_span.as_mut() {
        s.attr("generation", u64::from(generation));
        s.attr("runs_merged", runs.len() as u64);
        s.attr("bytes_merged", stats.records_before * REC_BYTES);
        s.attr("final", true);
    }
    let per_run = (cap / runs.len().max(1)).max(1);
    // Cursor record buffers + their reader buffers.
    let merge_bytes = (runs.len() * per_run) as u64 * REC_BYTES * 2;
    track(merge_bytes);

    let out_path = cfg.out_dir.join("screened_0000.tspm");
    track(write_cap as u64);
    let mut out = SeqWriter::create_with_capacity(&out_path, write_cap)?;
    let mut scratch = vec![ZERO_REC; 4096];
    track(scratch.len() as u64 * REC_BYTES);
    let mut run = PendingRun {
        pending: Vec::with_capacity(cap),
        cap,
        overflow: None,
        overflow_path: run_dir.join("pending_overflow.tspm"),
        write_cap,
    };
    track(cap as u64 * REC_BYTES);

    let mut records_after = 0u64;
    let mut cur_seq: Option<u64> = None;
    let mut last_pid = 0u32;
    let mut distinct = 0u32;
    merge_sorted_runs(&runs, per_run, |r| {
        if cur_seq != Some(r.seq) {
            if cur_seq.is_some() {
                stats.distinct_before += 1;
                let survives = distinct >= cfg.min_patients;
                stats.distinct_after += u64::from(survives);
                records_after += run.finalize(survives, &mut out, &mut scratch, tracker)?;
            }
            cur_seq = Some(r.seq);
            distinct = 1;
            last_pid = r.pid;
        } else if r.pid != last_pid {
            distinct += 1;
            last_pid = r.pid;
        }
        run.push(r, tracker)
    })?;
    if cur_seq.is_some() {
        stats.distinct_before += 1;
        let survives = distinct >= cfg.min_patients;
        stats.distinct_after += u64::from(survives);
        records_after += run.finalize(survives, &mut out, &mut scratch, tracker)?;
    }

    let written = out.finish()?;
    debug_assert_eq!(written, records_after);
    stats.records_after = records_after;
    drop(final_span);

    untrack(write_cap as u64);
    untrack(scratch.len() as u64 * REC_BYTES);
    untrack(cap as u64 * REC_BYTES);
    untrack(merge_bytes);
    for p in &runs {
        let _ = std::fs::remove_file(p);
    }
    let _ = std::fs::remove_dir(&run_dir);

    Ok((
        SeqFileSet {
            files: vec![out_path],
            total_records: records_after,
            num_patients: input.num_patients,
            num_phenx: input.num_phenx,
        },
        stats,
    ))
}

/// Duration-sparsity screen (paper: duration helpers "leverage this
/// feature ... e.g. when calculating duration sparsity"): a sequence
/// survives only if, additionally, its *duration-bucket* diversity is
/// wide enough — i.e. it occurs with at least `min_distinct_durations`
/// different duration buckets of width `bucket_days` across the cohort.
pub fn screen_by_duration(
    records: &mut Vec<SeqRecord>,
    bucket_days: u32,
    min_distinct_durations: u32,
) -> ScreenStats {
    use crate::dbmart::pack_duration;
    use std::collections::HashMap;
    let bucket = bucket_days.max(1);
    let mut stats = ScreenStats {
        records_before: records.len() as u64,
        ..Default::default()
    };
    let mut buckets: HashMap<u64, Vec<u64>> = HashMap::new();
    for r in records.iter() {
        // The packed form keeps (seq, bucket) as a single sortable u64 —
        // exactly what the paper's bit-shift trick is for.
        let packed = pack_duration(r.seq, r.duration / bucket);
        buckets.entry(r.seq).or_default().push(packed);
    }
    stats.distinct_before = buckets.len() as u64;
    let mut keep: HashMap<u64, bool> = HashMap::with_capacity(buckets.len());
    // lint:allow(hashmap_iter) — each entry's verdict depends only on its
    // own packs; the verdicts land keyed in `keep`, so iteration order
    // cannot reach the output.
    for (seq, mut packs) in buckets {
        packs.sort_unstable();
        packs.dedup();
        let ok = packs.len() as u32 >= min_distinct_durations;
        stats.distinct_after += u64::from(ok);
        keep.insert(seq, ok);
    }
    records.retain(|r| keep[&r.seq]);
    stats.records_after = records.len() as u64;
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rec(seq: u64, pid: u32) -> SeqRecord {
        SeqRecord { seq, pid, duration: 0 }
    }

    #[test]
    fn drops_sequences_below_threshold() {
        // seq 1 in 3 patients, seq 2 in 1 patient, seq 3 in 2 patients
        let mut records = vec![
            rec(1, 10),
            rec(1, 11),
            rec(1, 12),
            rec(2, 10),
            rec(3, 10),
            rec(3, 11),
        ];
        let stats = screen(&mut records, &SparsityConfig { min_patients: 2, threads: 1 });
        let seqs: Vec<u64> = records.iter().map(|r| r.seq).collect();
        assert!(seqs.contains(&1) && seqs.contains(&3) && !seqs.contains(&2));
        assert_eq!(stats.records_before, 6);
        assert_eq!(stats.records_after, 5);
        assert_eq!(stats.distinct_before, 3);
        assert_eq!(stats.distinct_after, 2);
    }

    #[test]
    fn counts_distinct_patients_not_occurrences() {
        // seq 7 occurs 5 times but in only 1 patient → must be dropped at
        // threshold 2.
        let mut records: Vec<SeqRecord> = (0..5).map(|_| rec(7, 42)).collect();
        records.push(rec(8, 1));
        records.push(rec(8, 2));
        screen(&mut records, &SparsityConfig { min_patients: 2, threads: 1 });
        assert!(records.iter().all(|r| r.seq == 8));
    }

    #[test]
    fn threshold_one_keeps_everything() {
        let mut records = vec![rec(1, 1), rec(2, 2), rec(3, 3)];
        let stats = screen(&mut records, &SparsityConfig { min_patients: 1, threads: 1 });
        assert_eq!(stats.records_after, 3);
        assert_eq!(stats.distinct_after, 3);
    }

    #[test]
    fn empty_input() {
        let mut records: Vec<SeqRecord> = Vec::new();
        let stats = screen(&mut records, &SparsityConfig::default());
        assert_eq!(stats, ScreenStats::default());
    }

    #[test]
    fn everything_sparse_empties_the_set() {
        let mut records = vec![rec(1, 1), rec(2, 2)];
        let stats = screen(&mut records, &SparsityConfig { min_patients: 10, threads: 1 });
        assert!(records.is_empty());
        assert_eq!(stats.distinct_after, 0);
    }

    #[test]
    fn matches_naive_oracle_on_random_input() {
        let mut meta = Rng::new(4242);
        for case in 0..20 {
            let n = 1000 + meta.gen_range(30_000) as usize;
            let n_seqs = 1 + meta.gen_range(200);
            let n_pats = 1 + meta.gen_range(100);
            let threshold = 1 + meta.gen_range(8) as u32;
            let threads = 1 + meta.gen_range(4) as usize;
            let mut r = Rng::new(case);
            let mut a: Vec<SeqRecord> = (0..n)
                .map(|_| SeqRecord {
                    seq: r.gen_range(n_seqs),
                    pid: r.gen_range(n_pats) as u32,
                    duration: r.gen_range(1000) as u32,
                })
                .collect();
            let mut b = a.clone();
            let mut c = a.clone();
            let sa = screen(&mut a, &SparsityConfig { min_patients: threshold, threads });
            let sb = screen_naive(&mut b, &SparsityConfig { min_patients: threshold, threads });
            let sc = screen_paper_strategy(
                &mut c,
                &SparsityConfig { min_patients: threshold, threads },
            );
            a.sort_unstable_by_key(|x| (x.seq, x.pid, x.duration));
            b.sort_unstable_by_key(|x| (x.seq, x.pid, x.duration));
            c.sort_unstable_by_key(|x| (x.seq, x.pid, x.duration));
            assert_eq!(a, b, "case={case}");
            assert_eq!(a, c, "case={case} (paper strategy diverged)");
            assert_eq!(sa.records_after, sb.records_after);
            assert_eq!(sa.distinct_after, sb.distinct_after);
            assert_eq!(sa.distinct_before, sb.distinct_before);
            assert_eq!(sa, sc);
        }
    }

    #[test]
    fn paper_strategy_mark_phase_is_thread_count_invariant() {
        // Regression for the mark-phase rewrite (raw-pointer laundering →
        // safe split_at_mut partitioning): output and stats must be
        // byte-identical for every worker count, including counts far
        // above the run count (split_ranges clamps) and a single-run
        // input where only one worker gets work.
        let mut r = Rng::new(99);
        let mut base: Vec<SeqRecord> = (0..20_000)
            .map(|_| SeqRecord {
                seq: r.gen_range(300),
                pid: r.gen_range(80) as u32,
                duration: r.gen_range(365) as u32,
            })
            .collect();
        // One giant run at the end exercises the uneven-boundary carve.
        base.extend((0..5_000).map(|i| SeqRecord { seq: 999, pid: i % 7, duration: 0 }));
        let cfg1 = SparsityConfig { min_patients: 5, threads: 1 };
        let mut reference = base.clone();
        let ref_stats = screen_paper_strategy(&mut reference, &cfg1);
        for threads in [2usize, 3, 8, 64, 501] {
            let mut got = base.clone();
            let stats =
                screen_paper_strategy(&mut got, &SparsityConfig { min_patients: 5, threads });
            assert_eq!(got, reference, "threads={threads}");
            assert_eq!(stats, ref_stats, "threads={threads}");
        }
        // Degenerate shape: one run, many workers — split_ranges clamps
        // to a single part and the whole slice goes to one worker.
        let mut single: Vec<SeqRecord> =
            (0..100).map(|i| SeqRecord { seq: 7, pid: i % 3, duration: 0 }).collect();
        let s4 =
            screen_paper_strategy(&mut single, &SparsityConfig { min_patients: 2, threads: 4 });
        assert_eq!(s4.distinct_after, 1);
        assert_eq!(single.len(), 100);
    }

    #[test]
    fn output_is_seq_sorted() {
        let mut r = Rng::new(1);
        let mut records: Vec<SeqRecord> = (0..10_000)
            .map(|_| SeqRecord {
                seq: r.gen_range(50),
                pid: r.gen_range(500) as u32,
                duration: 0,
            })
            .collect();
        screen(&mut records, &SparsityConfig { min_patients: 3, threads: 2 });
        assert!(records.windows(2).all(|w| (w[0].seq, w[0].pid) <= (w[1].seq, w[1].pid)));
    }

    #[test]
    fn real_pid_equal_to_tombstone_is_impossible_by_construction() {
        // Patient ids come from dense interning (< number of patients),
        // so u32::MAX can never be a real pid; this test documents the
        // invariant the marking scheme relies on.
        let mart = crate::synthea::SyntheaConfig::small().generate();
        let db = crate::dbmart::NumericDbMart::encode(&mart);
        assert!((db.num_patients() as u32) < TOMBSTONE_PID);
    }

    fn spill_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("tspm_sparsity_spill_{}", std::process::id()))
            .join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn spilled_input(dir: &Path, records: &[SeqRecord], files: usize) -> SeqFileSet {
        std::fs::create_dir_all(dir).unwrap();
        let chunk = records.len().div_ceil(files.max(1)).max(1);
        let mut paths = Vec::new();
        for (i, part) in records.chunks(chunk).enumerate() {
            let p = dir.join(format!("in_{i}.tspm"));
            crate::seqstore::write_file(&p, part).unwrap();
            paths.push(p);
        }
        if paths.is_empty() {
            let p = dir.join("in_0.tspm");
            crate::seqstore::write_file(&p, &[]).unwrap();
            paths.push(p);
        }
        SeqFileSet {
            files: paths,
            total_records: records.len() as u64,
            num_patients: 0,
            num_phenx: 0,
        }
    }

    #[test]
    fn spilled_screen_matches_in_memory_across_buffer_sizes() {
        let mut meta = Rng::new(0xC0FFEE);
        for case in 0..6u64 {
            let n = 500 + meta.gen_range(20_000) as usize;
            let n_seqs = 1 + meta.gen_range(150);
            let n_pats = 1 + meta.gen_range(90);
            let threshold = 1 + meta.gen_range(6) as u32;
            let mut r = Rng::new(case);
            let records: Vec<SeqRecord> = (0..n)
                .map(|_| SeqRecord {
                    seq: r.gen_range(n_seqs),
                    pid: r.gen_range(n_pats) as u32,
                    duration: r.gen_range(700) as u32,
                })
                .collect();

            let mut expect = records.clone();
            let in_mem_stats =
                screen(&mut expect, &SparsityConfig { min_patients: threshold, threads: 2 });
            expect.sort_unstable_by_key(|x| (x.seq, x.pid, x.duration));

            let dir = spill_dir(&format!("match_{case}"));
            let input = spilled_input(&dir, &records, 3);
            let mut golden_file_bytes: Option<Vec<SeqRecord>> = None;
            for buffer_bytes in [1024u64, 64 * 1024, u64::MAX] {
                let cfg = SpillScreenConfig {
                    min_patients: threshold,
                    threads: 2,
                    buffer_bytes,
                    out_dir: dir.join(format!("out_{buffer_bytes}")),
                };
                let (out, stats) = screen_spilled(&input, &cfg, None).unwrap();
                assert_eq!(stats, in_mem_stats, "case={case} buf={buffer_bytes}");
                assert_eq!(out.total_records, in_mem_stats.records_after);
                // File order (not just multiset): the external merge is
                // fully sorted, so every buffer size writes the same file.
                let got = out.read_all().unwrap();
                assert_eq!(got, expect, "case={case} buf={buffer_bytes}");
                match &golden_file_bytes {
                    None => golden_file_bytes = Some(got),
                    Some(g) => assert_eq!(g, &got, "case={case} buf={buffer_bytes}"),
                }
            }
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn spilled_screen_handles_empty_and_all_sparse_inputs() {
        let dir = spill_dir("edge");
        let empty = spilled_input(&dir.join("e"), &[], 1);
        let cfg = SpillScreenConfig {
            min_patients: 2,
            threads: 1,
            buffer_bytes: 1024,
            out_dir: dir.join("e_out"),
        };
        let (out, stats) = screen_spilled(&empty, &cfg, None).unwrap();
        assert_eq!(stats, ScreenStats::default());
        assert_eq!(out.total_records, 0);
        assert!(out.read_all().unwrap().is_empty());

        // Every sequence below threshold → empty survivor file.
        let sparse = vec![rec(1, 1), rec(2, 2), rec(3, 3)];
        let input = spilled_input(&dir.join("s"), &sparse, 2);
        let cfg = SpillScreenConfig {
            min_patients: 5,
            threads: 1,
            buffer_bytes: 1024,
            out_dir: dir.join("s_out"),
        };
        let (out, stats) = screen_spilled(&input, &cfg, None).unwrap();
        assert_eq!(stats.records_before, 3);
        assert_eq!(stats.distinct_before, 3);
        assert_eq!(stats.distinct_after, 0);
        assert_eq!(out.total_records, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn spilled_screen_overflows_giant_runs_without_buffering_them() {
        // One sequence spans far more records than the buffer (64-record
        // cap at 1 KiB) — the pending-run overflow path must stream it.
        let mut records: Vec<SeqRecord> = (0..5_000)
            .map(|i| SeqRecord { seq: 7, pid: (i % 200) as u32, duration: i as u32 })
            .collect();
        records.push(rec(9, 1)); // sparse straggler, dropped at threshold 2
        let mut expect = records.clone();
        let in_mem = screen(&mut expect, &SparsityConfig { min_patients: 2, threads: 1 });
        expect.sort_unstable_by_key(|x| (x.seq, x.pid, x.duration));

        let dir = spill_dir("overflow");
        let input = spilled_input(&dir, &records, 2);
        let cfg = SpillScreenConfig {
            min_patients: 2,
            threads: 1,
            buffer_bytes: 1024,
            out_dir: dir.join("out"),
        };
        let tracker = MemTracker::new();
        let (out, stats) = screen_spilled(&input, &cfg, Some(&tracker)).unwrap();
        assert_eq!(stats, in_mem);
        assert_eq!(out.read_all().unwrap(), expect);
        // Bounded: nothing near the 80 KB input footprint stays resident
        // (buffers only — scratch dominates at 64 KiB).
        assert!(tracker.peak() < 200 * 1024, "peak {}", tracker.peak());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn run_support_counts_pid_transitions() {
        assert_eq!(run_support(&[]), 0);
        assert_eq!(run_support(&[rec(1, 5)]), 1);
        assert_eq!(run_support(&[rec(1, 5), rec(1, 5), rec(1, 5)]), 1);
        assert_eq!(run_support(&[rec(1, 1), rec(1, 1), rec(1, 2), rec(1, 9)]), 3);
    }

    /// All four `_with` screens must equal "filter by the spec, then run
    /// the untargeted screen" — records AND stats — which is the
    /// screen-side half of the pushdown-safety contract.
    #[test]
    fn targeted_screens_equal_filter_then_screen() {
        use crate::dbmart::encode_seq;
        let mut r = Rng::new(0x7A6E);
        let records: Vec<SeqRecord> = (0..30_000)
            .map(|_| SeqRecord {
                seq: encode_seq(r.gen_range(12) as u32, r.gen_range(12) as u32),
                pid: r.gen_range(70) as u32,
                duration: r.gen_range(400) as u32,
            })
            .collect();
        let specs = [
            TargetSpec::for_codes([3, 7, 11]),
            TargetSpec::for_codes([5]).with_pos(crate::target::TargetPos::First),
            TargetSpec::for_codes([2, 4]).with_pos(crate::target::TargetPos::Second),
            TargetSpec::all().with_duration_band(Some(10), Some(250)),
            TargetSpec::for_codes([0, 9]).with_duration_band(Some(1), None),
            TargetSpec::all(),
        ];
        let cfg = SparsityConfig { min_patients: 3, threads: 2 };
        for (si, spec) in specs.iter().enumerate() {
            // Reference: explicit filter, then the untargeted screen.
            let mut expect: Vec<SeqRecord> =
                records.iter().copied().filter(|r| spec.matches_record(r)).collect();
            let expect_stats = screen(&mut expect, &cfg);

            let mut a = records.clone();
            let sa = screen_with(&mut a, &cfg, Some(spec));
            assert_eq!(a, expect, "screen_with spec={si}");
            assert_eq!(sa, expect_stats, "screen_with stats spec={si}");

            let mut b = records.clone();
            let sb = screen_naive_with(&mut b, &cfg, Some(spec));
            b.sort_unstable_by_key(|x| (x.seq, x.pid, x.duration));
            let mut expect_sorted = expect.clone();
            expect_sorted.sort_unstable_by_key(|x| (x.seq, x.pid, x.duration));
            assert_eq!(b, expect_sorted, "screen_naive_with spec={si}");
            assert_eq!(sb.records_after, expect_stats.records_after, "spec={si}");
            assert_eq!(sb.distinct_after, expect_stats.distinct_after, "spec={si}");

            // Duration is not part of the paper strategy's sort key, so
            // compare as the untargeted oracle test does: multiset order.
            let mut c = records.clone();
            let sc = screen_paper_strategy_with(&mut c, &cfg, Some(spec));
            c.sort_unstable_by_key(|x| (x.seq, x.pid, x.duration));
            assert_eq!(c, expect_sorted, "screen_paper_strategy_with spec={si}");
            assert_eq!(sc.records_after, expect_stats.records_after, "spec={si}");
            assert_eq!(sc.distinct_after, expect_stats.distinct_after, "spec={si}");
            assert_eq!(sc.distinct_before, expect_stats.distinct_before, "spec={si}");
            assert_eq!(sc.records_before, expect_stats.records_before, "spec={si}");
        }
    }

    #[test]
    fn targeted_spilled_screen_matches_targeted_in_memory() {
        use crate::dbmart::encode_seq;
        let mut r = Rng::new(0x51D);
        let records: Vec<SeqRecord> = (0..8_000)
            .map(|_| SeqRecord {
                seq: encode_seq(r.gen_range(8) as u32, r.gen_range(8) as u32),
                pid: r.gen_range(40) as u32,
                duration: r.gen_range(300) as u32,
            })
            .collect();
        let spec = TargetSpec::for_codes([1, 4, 6]).with_duration_band(None, Some(200));
        let cfg = SparsityConfig { min_patients: 2, threads: 1 };
        let mut expect = records.clone();
        let expect_stats = screen_with(&mut expect, &cfg, Some(&spec));
        expect.sort_unstable_by_key(|x| (x.seq, x.pid, x.duration));

        let dir = spill_dir("targeted");
        let input = spilled_input(&dir, &records, 3);
        for buffer_bytes in [1024u64, u64::MAX] {
            let spill_cfg = SpillScreenConfig {
                min_patients: 2,
                threads: 1,
                buffer_bytes,
                out_dir: dir.join(format!("out_{buffer_bytes}")),
            };
            let (out, stats) =
                screen_spilled_with(&input, &spill_cfg, Some(&spec), None).unwrap();
            assert_eq!(stats, expect_stats, "buf={buffer_bytes}");
            assert_eq!(out.read_all().unwrap(), expect, "buf={buffer_bytes}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn spill_merge_passes_emit_span_attrs() {
        use crate::obs::trace::{
            push_current, Clock, ManualClock, MemorySink, TraceSink, Tracer,
        };
        use std::sync::Arc;
        let sink = Arc::new(MemorySink::new());
        let clock = Arc::new(ManualClock::new());
        let tracer = Tracer::with_sinks(
            Some(sink.clone() as Arc<dyn TraceSink>),
            Arc::new(MemorySink::new()),
            clock.clone() as Arc<dyn Clock>,
        );
        let root = tracer.span("screen");
        let guard = push_current(&root);

        // Enough records under a tiny buffer (64-record cap at 1 KiB) to
        // force > MERGE_FAN_IN sorted runs → at least one compaction
        // pass before the final merge.
        let records: Vec<SeqRecord> = (0..5_000)
            .map(|i| SeqRecord { seq: (i % 11) as u64, pid: (i % 97) as u32, duration: 0 })
            .collect();
        let dir = spill_dir("span_attrs");
        let input = spilled_input(&dir, &records, 2);
        let cfg = SpillScreenConfig {
            min_patients: 1,
            threads: 1,
            buffer_bytes: 1024,
            out_dir: dir.join("out"),
        };
        screen_spilled(&input, &cfg, None).unwrap();
        drop(guard);
        root.finish();
        let _ = std::fs::remove_dir_all(&dir);

        let passes: Vec<crate::json::Json> = sink
            .lines()
            .iter()
            .map(|l| crate::json::Json::parse(l).unwrap())
            .filter(|v| {
                v.get("name").and_then(crate::json::Json::as_str)
                    == Some("sparsity.spill_merge_pass")
            })
            .collect();
        assert!(passes.len() >= 2, "compaction pass + final pass, got {}", passes.len());
        for p in &passes {
            let attrs = p.get("attrs").expect("merge pass spans carry attrs");
            assert!(attrs.get("runs_merged").and_then(crate::json::Json::as_u64).unwrap() > 0);
            assert!(attrs.get("bytes_merged").and_then(crate::json::Json::as_u64).is_some());
            assert!(attrs.get("generation").and_then(crate::json::Json::as_u64).is_some());
        }
        // Exactly one final pass, carrying the whole multiset's bytes.
        let finals: Vec<_> = passes
            .iter()
            .filter(|p| {
                p.get("attrs")
                    .and_then(|a| a.get("final"))
                    .and_then(crate::json::Json::as_bool)
                    == Some(true)
            })
            .collect();
        assert_eq!(finals.len(), 1);
        let total_bytes = records.len() as u64 * REC_BYTES;
        assert_eq!(
            finals[0]
                .get("attrs")
                .and_then(|a| a.get("bytes_merged"))
                .and_then(crate::json::Json::as_u64),
            Some(total_bytes)
        );
    }

    #[test]
    fn duration_screen_requires_bucket_diversity() {
        // seq 1: durations 0, 100, 200 (3 buckets of 30d) — survives k=2.
        // seq 2: durations 5, 10 (same bucket) — dropped at k=2.
        let mut records = vec![
            SeqRecord { seq: 1, pid: 1, duration: 0 },
            SeqRecord { seq: 1, pid: 2, duration: 100 },
            SeqRecord { seq: 1, pid: 3, duration: 200 },
            SeqRecord { seq: 2, pid: 1, duration: 5 },
            SeqRecord { seq: 2, pid: 2, duration: 10 },
        ];
        let stats = screen_by_duration(&mut records, 30, 2);
        assert!(records.iter().all(|r| r.seq == 1));
        assert_eq!(stats.distinct_after, 1);
    }
}
