//! Deterministic pseudo-random number generation substrate.
//!
//! The offline environment has no `rand` crate, so we implement the small
//! set of primitives the project needs: a splitmix64 seeder and an
//! xoshiro256++ generator (public-domain reference algorithms), plus the
//! distribution helpers used by the synthetic data generator ([`crate::synthea`])
//! and by the property tests.

/// splitmix64 — used to expand a single `u64` seed into generator state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG. Deterministic, seedable, fast, and good enough for
/// workload synthesis and property-test case generation (not cryptography).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed via splitmix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        // All-zero state is invalid for xoshiro; splitmix cannot produce
        // four zeros from any seed, but guard anyway.
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Rng { s }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next 32-bit value (upper half of the 64-bit output).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform integer in `[0, bound)` using Lemire's multiply-shift
    /// rejection method (unbiased).
    #[inline]
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "gen_range bound must be positive");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn gen_range_inclusive(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi, "gen_range_inclusive: lo > hi");
        lo + self.gen_range(hi - lo + 1)
    }

    /// Uniform float in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with success probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple and
    /// sufficient for workload synthesis).
    pub fn gen_normal(&mut self) -> f64 {
        let mut u1 = self.gen_f64();
        if u1 <= f64::MIN_POSITIVE {
            u1 = f64::MIN_POSITIVE;
        }
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Poisson-distributed count via Knuth's method for small lambda and a
    /// normal approximation above 30 (ample for entries-per-visit draws).
    pub fn gen_poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0);
        if lambda == 0.0 {
            return 0;
        }
        if lambda > 30.0 {
            let v = lambda + lambda.sqrt() * self.gen_normal();
            return v.max(0.0).round() as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.gen_f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Zipf-like rank draw over `[0, n)` with exponent `s` via inverse-CDF
    /// rejection (Rejection-inversion, Hörmann & Derflinger). Used to model
    /// the power-law frequency of clinical codes.
    pub fn gen_zipf(&mut self, n: u64, s: f64) -> u64 {
        assert!(n > 0);
        if n == 1 {
            return 0;
        }
        // Simple inverse-transform on the truncated harmonic CDF; exact and
        // fast enough for n up to ~1e6 with caching left to the caller.
        // To avoid O(n) per draw we use the approximation by continuous
        // power-law inversion, clamped to the support.
        let u = self.gen_f64();
        if (s - 1.0).abs() < 1e-9 {
            let h = (n as f64).ln();
            return ((u * h).exp() - 1.0).min((n - 1) as f64).max(0.0) as u64;
        }
        let e = 1.0 - s;
        let h_n = ((n as f64).powf(e) - 1.0) / e;
        let x = (1.0 + u * h_n * e).powf(1.0 / e) - 1.0;
        (x.min((n - 1) as f64).max(0.0)) as u64
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            items.swap(i, j);
        }
    }

    /// Choose one element uniformly (panics on empty slice).
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.gen_range(items.len() as u64) as usize]
    }

    /// Derive an independent child generator (for per-thread streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4, "seeds 1 and 2 should produce distinct streams");
    }

    #[test]
    fn gen_range_bounds_respected() {
        let mut r = Rng::new(7);
        for bound in [1u64, 2, 3, 10, 1000, u64::MAX / 2] {
            for _ in 0..200 {
                assert!(r.gen_range(bound) < bound);
            }
        }
    }

    #[test]
    fn gen_range_covers_small_support() {
        let mut r = Rng::new(9);
        let mut seen = [false; 5];
        for _ in 0..1000 {
            seen[r.gen_range(5) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all 5 values should appear");
    }

    #[test]
    fn gen_f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.gen_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn gen_f64_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.gen_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn poisson_mean_tracks_lambda() {
        let mut r = Rng::new(5);
        for lambda in [0.5, 3.0, 12.0, 80.0] {
            let n = 5_000;
            let mean: f64 =
                (0..n).map(|_| r.gen_poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.max(1.0) * 0.1,
                "lambda={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn zipf_prefers_low_ranks() {
        let mut r = Rng::new(13);
        let n = 1000u64;
        let draws = 20_000;
        let low = (0..draws).filter(|_| r.gen_zipf(n, 1.2) < 10).count();
        // With s=1.2 the first 10 ranks should hold a large share of mass.
        assert!(low > draws / 10, "low-rank share too small: {low}/{draws}");
    }

    #[test]
    fn zipf_stays_in_support() {
        let mut r = Rng::new(17);
        for &n in &[1u64, 2, 5, 100] {
            for _ in 0..500 {
                assert!(r.gen_zipf(n, 1.1) < n);
            }
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(29);
        let n = 50_000;
        let samples: Vec<f64> = (0..n).map(|_| r.gen_normal()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut parent = Rng::new(31);
        let mut a = parent.fork();
        let mut b = parent.fork();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
