//! Minimal JSON substrate (parser + writer).
//!
//! The offline registry ships no `serde`/`serde_json`, so configuration
//! files, lookup tables and experiment reports are (de)serialized through
//! this small, strict JSON implementation. It supports the full JSON value
//! model with `f64` numbers, rejects trailing garbage, and pretty-prints
//! deterministically (object keys keep insertion order).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object. `BTreeMap` gives deterministic output ordering.
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document; trailing non-whitespace is an error.
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }

    /// Compact single-line serialization.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 2f64.powi(53) => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    // ---- constructors ----------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_nan() || n.is_infinite() {
        // JSON has no NaN/Inf; emit null like most encoders.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.pos }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("invalid literal, expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid utf8 in number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                // surrogate pair
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    if !(0xDC00..0xE000).contains(&lo) {
                                        return Err(self.err("invalid low surrogate"));
                                    }
                                    let c =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c)
                                        .ok_or_else(|| self.err("invalid codepoint"))?
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("invalid codepoint"))?
                            };
                            out.push(ch);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let ch = rest.chars().next().unwrap();
                    if (ch as u32) < 0x20 {
                        return Err(self.err("control character in string"));
                    }
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("invalid utf8 in \\u escape"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("invalid hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\"A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\"A"));
    }

    #[test]
    fn parse_surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("42 x").is_err());
        assert!(Json::parse("{} []").is_err());
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "{\"a\"}", "\"abc", "tru", "01x", "[1 2]", ""] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn roundtrip_compact() {
        let src = r#"{"arr":[1,2.5,null,true],"name":"tspm+","nested":{"k":"v"}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string_compact();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Json::obj(vec![
            ("patients", Json::from(4985u64)),
            ("avg_entries", Json::from(471.2)),
            ("mode", Json::from("file")),
        ]);
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
        assert!(pretty.contains("\n  \"avg_entries\": 471.2"));
    }

    #[test]
    fn integers_print_without_decimal() {
        assert_eq!(Json::Num(5.0).to_string_compact(), "5");
        assert_eq!(Json::Num(5.5).to_string_compact(), "5.5");
    }

    #[test]
    fn as_u64_guards() {
        assert_eq!(Json::Num(5.0).as_u64(), Some(5));
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(1.5).as_u64(), None);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"göttingen ü\"").unwrap();
        let out = v.to_string_compact();
        assert_eq!(Json::parse(&out).unwrap().as_str(), Some("göttingen ü"));
    }
}
