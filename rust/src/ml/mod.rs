//! MLHO-style machine-learning workflow (vignette 1).
//!
//! Reproduces the paper's first vignette: mined + screened sequences →
//! MSMR top-K selection → classifier → evaluation → translation of the
//! significant sequences back to human-readable descriptions. The
//! classifier is a logistic regression trained by full-batch gradient
//! descent; forward/backward run as the AOT-compiled `logreg_grad` /
//! `logreg_predict` PJRT artifacts (tiled over patients, gradients
//! accumulated in Rust — Rust owns the optimizer loop, PJRT owns the
//! compute), with a pure-Rust fallback for artifact-less runs.

use crate::engine::TspmError;
use crate::matrix::SeqMatrix;
use crate::rng::Rng;
use crate::runtime::{ArtifactSet, RuntimeError, Tensor};

/// Training configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub learning_rate: f32,
    pub epochs: usize,
    /// L2 regularisation strength.
    pub l2: f32,
    /// Train fraction of the patient split.
    pub train_fraction: f64,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { learning_rate: 0.5, epochs: 200, l2: 1e-4, train_fraction: 0.7, seed: 17 }
    }
}

/// A trained logistic-regression model.
#[derive(Clone, Debug)]
pub struct LogReg {
    pub w: Vec<f32>,
    pub b: f32,
}

impl LogReg {
    pub fn predict_one(&self, row: &[f32]) -> f32 {
        let z: f32 = self.b + row.iter().zip(&self.w).map(|(x, w)| x * w).sum::<f32>();
        1.0 / (1.0 + (-z).exp())
    }
}

/// Evaluation metrics.
#[derive(Clone, Copy, Debug, Default)]
pub struct Metrics {
    pub auc: f64,
    pub accuracy: f64,
    pub n: usize,
}

/// Patient-level train/test split (deterministic for a seed).
pub fn split_patients(num_patients: u32, train_fraction: f64, seed: u64) -> (Vec<u32>, Vec<u32>) {
    let mut ids: Vec<u32> = (0..num_patients).collect();
    Rng::new(seed).shuffle(&mut ids);
    let cut = ((num_patients as f64) * train_fraction).round() as usize;
    let (train, test) = ids.split_at(cut.min(ids.len()));
    (train.to_vec(), test.to_vec())
}

/// Area under the ROC curve (rank statistic, ties handled by midrank).
pub fn auc(scores: &[f32], labels: &[f32]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let mut order: Vec<usize> = (0..scores.len()).collect();
    order.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap());
    // midranks
    let mut ranks = vec![0f64; scores.len()];
    let mut i = 0;
    while i < order.len() {
        let mut j = i;
        while j + 1 < order.len() && scores[order[j + 1]] == scores[order[i]] {
            j += 1;
        }
        let midrank = (i + j) as f64 / 2.0 + 1.0;
        for &idx in &order[i..=j] {
            ranks[idx] = midrank;
        }
        i = j + 1;
    }
    let npos = labels.iter().filter(|&&l| l > 0.5).count() as f64;
    let nneg = labels.len() as f64 - npos;
    if npos == 0.0 || nneg == 0.0 {
        return 0.5;
    }
    let rank_sum: f64 = labels
        .iter()
        .zip(&ranks)
        .filter(|(&l, _)| l > 0.5)
        .map(|(_, &r)| r)
        .sum();
    (rank_sum - npos * (npos + 1.0) / 2.0) / (npos * nneg)
}

/// Dense design-matrix view over selected patients (row-major, F cols).
pub struct Design {
    pub x: Vec<f32>,
    pub y: Vec<f32>,
    pub rows: usize,
    pub cols: usize,
}

/// Materialise (X, y) for a patient subset from the CSR matrix.
pub fn design(m: &SeqMatrix, labels: &[f32], patients: &[u32]) -> Design {
    let cols = m.num_cols();
    let mut x = vec![0f32; patients.len() * cols];
    let mut y = vec![0f32; patients.len()];
    for (i, &pid) in patients.iter().enumerate() {
        y[i] = labels[pid as usize];
        for &c in &m.col_idx[m.row_ptr[pid as usize]..m.row_ptr[pid as usize + 1]] {
            x[i * cols + c as usize] = 1.0;
        }
    }
    Design { x, y, rows: patients.len(), cols }
}

/// Train with pure-Rust gradient descent (fallback & oracle).
pub fn train_rust(d: &Design, cfg: &TrainConfig) -> LogReg {
    let mut w = vec![0f32; d.cols];
    let mut b = 0f32;
    let n = d.rows.max(1) as f32;
    for _ in 0..cfg.epochs {
        let mut gw = vec![0f32; d.cols];
        let mut gb = 0f32;
        for r in 0..d.rows {
            let row = &d.x[r * d.cols..(r + 1) * d.cols];
            let z: f32 = b + row.iter().zip(&w).map(|(x, wv)| x * wv).sum::<f32>();
            let p = 1.0 / (1.0 + (-z).exp());
            let err = p - d.y[r];
            for (g, x) in gw.iter_mut().zip(row) {
                *g += err * x;
            }
            gb += err;
        }
        for (wv, g) in w.iter_mut().zip(&gw) {
            *wv -= cfg.learning_rate * (g / n + cfg.l2 * *wv);
        }
        b -= cfg.learning_rate * gb / n;
    }
    LogReg { w, b }
}

/// Train via the PJRT `logreg_grad` artifact, tiling patients and
/// accumulating gradient sums in Rust.
pub fn train_pjrt(d: &Design, cfg: &TrainConfig, arts: &ArtifactSet) -> Result<LogReg, RuntimeError> {
    let (tp, tf) = (arts.tile_rows, arts.tile_features);
    if d.cols > tf {
        return Err(RuntimeError(format!(
            "design has {} features; artifact tile holds {tf} — select ≤ {tf} features first",
            d.cols
        )));
    }
    let grad_art = arts.get("logreg_grad")?;

    // Pre-build the padded per-tile (X, y, mask) tensors once.
    let mut tiles: Vec<(Tensor, Tensor, Tensor)> = Vec::new();
    for row0 in (0..d.rows).step_by(tp) {
        let rows_here = tp.min(d.rows - row0);
        let mut x = vec![0f32; tp * tf];
        let mut y = vec![0f32; tp];
        let mut mask = vec![0f32; tp];
        for i in 0..rows_here {
            let src = &d.x[(row0 + i) * d.cols..(row0 + i + 1) * d.cols];
            x[i * tf..i * tf + d.cols].copy_from_slice(src);
            y[i] = d.y[row0 + i];
            mask[i] = 1.0;
        }
        tiles.push((
            Tensor::new(vec![tp, tf], x),
            Tensor::new(vec![tp, 1], y),
            Tensor::new(vec![tp, 1], mask),
        ));
    }

    let n = d.rows.max(1) as f32;
    let mut w = Tensor::zeros(vec![tf, 1]);
    let mut b = Tensor::zeros(vec![1, 1]);
    for _ in 0..cfg.epochs {
        let mut gw = vec![0f32; tf];
        let mut gb = 0f32;
        for (x, y, mask) in &tiles {
            let out =
                grad_art.run(&[w.clone(), b.clone(), x.clone(), y.clone(), mask.clone()])?;
            for (acc, g) in gw.iter_mut().zip(&out[0].data) {
                *acc += g;
            }
            gb += out[1].data[0];
        }
        for (wv, g) in w.data.iter_mut().zip(&gw) {
            *wv -= cfg.learning_rate * (g / n + cfg.l2 * *wv);
        }
        b.data[0] -= cfg.learning_rate * gb / n;
    }
    Ok(LogReg { w: w.data[..d.cols].to_vec(), b: b.data[0] })
}

/// Evaluate a model on a design.
pub fn evaluate(model: &LogReg, d: &Design) -> Metrics {
    let scores: Vec<f32> = (0..d.rows)
        .map(|r| model.predict_one(&d.x[r * d.cols..(r + 1) * d.cols]))
        .collect();
    let correct = scores
        .iter()
        .zip(&d.y)
        .filter(|(&s, &y)| (s > 0.5) == (y > 0.5))
        .count();
    Metrics {
        auc: auc(&scores, &d.y),
        accuracy: correct as f64 / d.rows.max(1) as f64,
        n: d.rows,
    }
}

/// Full MLHO-style run: split → train → evaluate.
pub fn run_workflow(
    m: &SeqMatrix,
    labels: &[f32],
    cfg: &TrainConfig,
    artifacts: Option<&ArtifactSet>,
) -> Result<(LogReg, Metrics, Metrics), RuntimeError> {
    let (train_ids, test_ids) = split_patients(m.num_patients, cfg.train_fraction, cfg.seed);
    let train_d = design(m, labels, &train_ids);
    let test_d = design(m, labels, &test_ids);
    let model = match artifacts {
        Some(a) => train_pjrt(&train_d, cfg, a)?,
        None => train_rust(&train_d, cfg),
    };
    Ok((model.clone(), evaluate(&model, &train_d), evaluate(&model, &test_d)))
}

/// Vignette 1 end-to-end driver (shared by `tspm mlho`, `tspm e2e` and
/// `examples/mlho_workflow.rs`): generate the synthetic COVID cohort,
/// mine + screen sequences, label patients by Post-COVID ground truth,
/// MSMR-select `top_k` sequences, train and evaluate the classifier, and
/// translate the most predictive sequences back to readable form.
pub fn mlho_vignette(
    patients: u64,
    top_k: usize,
    epochs: usize,
    artifacts: Option<&ArtifactSet>,
) -> Result<String, TspmError> {
    use crate::engine::Engine;
    use crate::mining::MiningConfig;
    use crate::msmr::MsmrConfig;
    use crate::sparsity::SparsityConfig;

    let mut gen_cfg = crate::synthea::SyntheaConfig::small();
    gen_cfg.patients = patients;
    let g = gen_cfg.generate_with_truth();
    let db = crate::dbmart::NumericDbMart::encode(&g.dbmart);

    // Label: does the patient develop Post-COVID (any symptom)?
    let pc_patients: std::collections::BTreeSet<&str> =
        g.truth.postcovid.iter().map(|(p, _)| p.as_str()).collect();
    let labels: Vec<f32> = (0..db.num_patients())
        .map(|p| f32::from(pc_patients.contains(db.lookup.patient_name(p as u32))))
        .collect();

    // Mine → screen → matrix → MSMR through the engine façade.
    let result = Engine::from_dbmart(db)
        .mine(MiningConfig::default())
        .screen(SparsityConfig {
            min_patients: crate::bench_util::experiments::threshold_for(patients),
            threads: 0,
        })
        .matrix()
        .msmr_with(MsmrConfig { top_k, ..Default::default() })
        .labels(labels.clone())
        .run_with(artifacts)?;
    let db = result.db;
    let stats = result.screen_stats.expect("screen stage was planned");
    let m = result.matrix.expect("matrix stage was planned");
    let sel = result.selection.expect("msmr stage was planned");

    let mut out = String::new();
    out.push_str(&format!(
        "mined {} records; screened to {} ({} distinct sequences)\n",
        stats.records_before, stats.records_after, stats.distinct_after
    ));
    out.push_str(&format!("MSMR selected {} features\n", sel.columns.len()));
    let selected = m.select_columns(&sel.columns);

    let (model, train_m, test_m) = run_workflow(
        &selected,
        &labels,
        &TrainConfig { epochs, ..Default::default() },
        artifacts,
    )?;
    out.push_str(&format!(
        "train: AUC {:.3} acc {:.3} (n={})\ntest:  AUC {:.3} acc {:.3} (n={})\n",
        train_m.auc, train_m.accuracy, train_m.n, test_m.auc, test_m.accuracy, test_m.n
    ));

    // Translate the most predictive sequences back to human-readable form
    // (the vignette's final step).
    let mut weighted: Vec<(f32, usize)> =
        model.w.iter().enumerate().map(|(i, &w)| (w, i)).collect();
    weighted.sort_by(|a, b| b.0.abs().partial_cmp(&a.0.abs()).unwrap());
    out.push_str("top predictive sequences:\n");
    for (w, col) in weighted.iter().take(5) {
        let seq = selected.seq_ids[*col];
        let (s, e) = crate::dbmart::decode_seq(seq);
        out.push_str(&format!(
            "  w={w:+.3}  {} -> {}\n",
            db.lookup.phenx_name(s),
            db.lookup.phenx_name(e)
        ));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mining::SeqRecord;

    #[test]
    fn auc_perfect_and_random() {
        assert_eq!(auc(&[0.9, 0.8, 0.2, 0.1], &[1.0, 1.0, 0.0, 0.0]), 1.0);
        assert_eq!(auc(&[0.1, 0.2, 0.8, 0.9], &[1.0, 1.0, 0.0, 0.0]), 0.0);
        assert_eq!(auc(&[0.5, 0.5, 0.5, 0.5], &[1.0, 0.0, 1.0, 0.0]), 0.5);
        assert_eq!(auc(&[0.3], &[1.0]), 0.5); // single class degenerates
    }

    #[test]
    fn auc_with_ties_uses_midranks() {
        // scores: pos {0.8, 0.5}, neg {0.5, 0.2} → AUC = (1 + 0.5 + 1 + 0)/4?
        // pairs: (0.8 vs 0.5)=1, (0.8 vs 0.2)=1, (0.5 vs 0.5)=0.5, (0.5 vs 0.2)=1 → 3.5/4
        let got = auc(&[0.8, 0.5, 0.5, 0.2], &[1.0, 1.0, 0.0, 0.0]);
        assert!((got - 3.5 / 4.0).abs() < 1e-12, "{got}");
    }

    #[test]
    fn split_is_deterministic_and_disjoint() {
        let (a1, b1) = split_patients(100, 0.7, 42);
        let (a2, b2) = split_patients(100, 0.7, 42);
        assert_eq!(a1, a2);
        assert_eq!(b1, b2);
        assert_eq!(a1.len(), 70);
        assert_eq!(b1.len(), 30);
        let mut all: Vec<u32> = a1.iter().chain(b1.iter()).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }

    fn separable_matrix() -> (SeqMatrix, Vec<f32>) {
        // 60 patients; positives carry seq 10, negatives seq 20; noise 30.
        let mut records = Vec::new();
        let mut r = Rng::new(3);
        for pid in 0..60u32 {
            if pid < 30 {
                records.push(SeqRecord { seq: 10, pid, duration: 0 });
            } else {
                records.push(SeqRecord { seq: 20, pid, duration: 0 });
            }
            if r.gen_bool(0.5) {
                records.push(SeqRecord { seq: 30, pid, duration: 0 });
            }
        }
        let labels: Vec<f32> = (0..60).map(|p| f32::from(p < 30)).collect();
        (SeqMatrix::build(&records, 60).unwrap(), labels)
    }

    #[test]
    fn rust_training_separates_separable_data() {
        let (m, labels) = separable_matrix();
        let (model, train_m, test_m) =
            run_workflow(&m, &labels, &TrainConfig::default(), None).unwrap();
        assert!(train_m.auc > 0.99, "train auc {}", train_m.auc);
        assert!(test_m.auc > 0.99, "test auc {}", test_m.auc);
        // weight on the positive marker must exceed the noise weight
        let col10 = m.seq_ids.iter().position(|&s| s == 10).unwrap();
        let col30 = m.seq_ids.iter().position(|&s| s == 30).unwrap();
        assert!(model.w[col10] > model.w[col30].abs());
    }

    // Without the `pjrt` feature ArtifactSet::load is a stub that always
    // errors, so this parity test would panic on any checkout that has
    // built artifacts; quarantine it with the rest of the PJRT suite.
    #[cfg(feature = "pjrt")]
    #[test]
    fn pjrt_training_matches_rust_when_artifacts_present() {
        let dir = crate::runtime::default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let arts = ArtifactSet::load(&dir).unwrap();
        let (m, labels) = separable_matrix();
        let cfg = TrainConfig { epochs: 50, ..Default::default() };
        let (train_ids, _) = split_patients(m.num_patients, cfg.train_fraction, cfg.seed);
        let d = design(&m, &labels, &train_ids);
        let rust_model = train_rust(&d, &cfg);
        let pjrt_model = train_pjrt(&d, &cfg, &arts).unwrap();
        assert!((rust_model.b - pjrt_model.b).abs() < 1e-3);
        for (a, b) in rust_model.w.iter().zip(&pjrt_model.w) {
            assert!((a - b).abs() < 1e-3, "rust {a} vs pjrt {b}");
        }
    }

    #[test]
    fn design_materialises_rows_in_patient_order() {
        let (m, labels) = separable_matrix();
        let d = design(&m, &labels, &[5, 45]);
        assert_eq!(d.rows, 2);
        assert_eq!(d.y, vec![1.0, 0.0]);
        let col10 = m.seq_ids.iter().position(|&s| s == 10).unwrap();
        let col20 = m.seq_ids.iter().position(|&s| s == 20).unwrap();
        assert_eq!(d.x[col10], 1.0);
        assert_eq!(d.x[d.cols + col20], 1.0);
    }

    use crate::rng::Rng;
}
