//! Synthetic clinical data generation — the Synthea™/MGB-Biobank stand-in.
//!
//! The paper benchmarks on (a) MGB Biobank data (4,985 patients, ~471
//! entries/patient) and (b) the Synthea 100k COVID-19 synthetic dataset
//! (reduced to 35k patients, ~318 entries/patient). Neither is shippable,
//! so this module generates statistically comparable cohorts (see
//! DESIGN.md §Substitutions): per-patient entry counts follow a lognormal
//! around the configured mean, visit dates follow a random timeline over a
//! configurable horizon, and code frequencies follow a Zipf power law —
//! the three properties the mining workload is actually sensitive to.
//!
//! The COVID scenario additionally plants infections and *Post COVID-19*
//! symptom trajectories per the WHO definition (symptoms present after
//! infection, persisting ≥ 2 months), together with confounders
//! (transient post-infection symptoms, pre-existing chronic symptoms, and
//! symptoms explained by an alternative diagnosis), and returns the ground
//! truth so the `postcovid` vignette can be *validated*, not just run.

use crate::dbmart::{DbMart, DbMartEntry};
use crate::rng::Rng;
use std::collections::BTreeSet;

/// The special phenX string for a COVID-19 infection event.
pub const COVID_CODE: &str = "dx:covid19";

/// Post-COVID candidate symptom codes (WHO symptom list subset).
pub const SYMPTOM_CODES: &[&str] = &[
    "sym:fatigue",
    "sym:dyspnea",
    "sym:brain_fog",
    "sym:chest_pain",
    "sym:anosmia",
    "sym:headache",
    "sym:joint_pain",
    "sym:palpitations",
];

/// Alternative diagnoses that "explain away" a symptom (WHO exclusion:
/// "if it can not be excluded by another rationale").
pub const ALT_DIAGNOSES: &[&str] = &[
    "dx:anemia",   // explains fatigue
    "dx:asthma",   // explains dyspnea
    "dx:migraine", // explains headache
    "dx:arthritis", // explains joint_pain
];

/// Which alternative diagnosis explains which symptom.
pub const ALT_EXPLAINS: &[(&str, &str)] = &[
    ("dx:anemia", "sym:fatigue"),
    ("dx:asthma", "sym:dyspnea"),
    ("dx:migraine", "sym:headache"),
    ("dx:arthritis", "sym:joint_pain"),
];

/// Scenario selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scenario {
    /// Generic EHR noise only (MGB-Biobank-like benchmark workload).
    Generic,
    /// COVID infections + Post-COVID trajectories with ground truth.
    Covid,
}

/// Generator configuration.
#[derive(Clone, Debug)]
pub struct SyntheaConfig {
    pub patients: u64,
    /// Target mean entries per patient.
    pub avg_entries: f64,
    /// Distinct background phenX codes.
    pub vocab_size: u64,
    /// Observation horizon in days.
    pub horizon_days: u32,
    /// Zipf exponent for background code frequency.
    pub zipf_s: f64,
    pub seed: u64,
    pub scenario: Scenario,
    /// Fraction of the cohort that gets a COVID infection (Covid scenario).
    pub covid_attack_rate: f64,
    /// Fraction of infected patients that develop Post-COVID.
    pub postcovid_rate: f64,
}

impl SyntheaConfig {
    /// MGB-Biobank-like comparison-benchmark cohort (paper Table 1),
    /// optionally scaled down to fit a testbed.
    pub fn mgb_like(scale: f64) -> SyntheaConfig {
        SyntheaConfig {
            patients: ((4985.0 * scale).round() as u64).max(1),
            avg_entries: 471.0,
            vocab_size: 8_000,
            horizon_days: 3650,
            zipf_s: 1.2,
            seed: 20170282, // MGB IRB protocol number, for flavour
            scenario: Scenario::Generic,
            covid_attack_rate: 0.0,
            postcovid_rate: 0.0,
        }
    }

    /// Synthea-COVID-like performance-benchmark cohort (paper Table 2).
    pub fn synthea_covid_like(scale: f64) -> SyntheaConfig {
        SyntheaConfig {
            patients: ((35_000.0 * scale).round() as u64).max(1),
            avg_entries: 318.0,
            vocab_size: 12_000,
            horizon_days: 1460,
            zipf_s: 1.15,
            seed: 100_000,
            scenario: Scenario::Covid,
            covid_attack_rate: 0.6,
            postcovid_rate: 0.25,
        }
    }

    /// A small cohort for docs, examples and tests.
    pub fn small() -> SyntheaConfig {
        SyntheaConfig {
            patients: 200,
            avg_entries: 60.0,
            vocab_size: 300,
            horizon_days: 1200,
            zipf_s: 1.1,
            seed: 7,
            scenario: Scenario::Covid,
            covid_attack_rate: 0.5,
            postcovid_rate: 0.3,
        }
    }

    /// Generate the cohort (ground truth discarded).
    pub fn generate(&self) -> DbMart {
        self.generate_with_truth().dbmart
    }

    /// Generate the cohort together with Post-COVID ground truth.
    pub fn generate_with_truth(&self) -> GeneratedCohort {
        generate_cohort(self)
    }
}

/// Ground truth emitted by the COVID scenario.
#[derive(Clone, Debug, Default)]
pub struct GroundTruth {
    /// `(patient_id, symptom_code)` pairs that are true Post-COVID
    /// symptoms under the WHO definition.
    pub postcovid: BTreeSet<(String, String)>,
    /// Patients that received a COVID infection.
    pub infected: BTreeSet<String>,
}

/// Generator output: the dbmart plus ground truth.
#[derive(Clone, Debug)]
pub struct GeneratedCohort {
    pub dbmart: DbMart,
    pub truth: GroundTruth,
}

fn patient_name(i: u64) -> String {
    format!("pat{i:06}")
}

fn code_name(i: u64) -> String {
    format!("code:{i:05}")
}

fn generate_cohort(cfg: &SyntheaConfig) -> GeneratedCohort {
    assert!(cfg.patients > 0 && cfg.avg_entries > 0.0 && cfg.vocab_size > 0);
    let mut rng = Rng::new(cfg.seed);
    let mut entries: Vec<DbMartEntry> =
        Vec::with_capacity((cfg.patients as f64 * cfg.avg_entries * 1.05) as usize);
    let mut truth = GroundTruth::default();

    // Lognormal entry counts: mean cfg.avg_entries, sigma 0.45 — matches
    // the long-tailed per-patient utilisation seen in EHR cohorts.
    let sigma: f64 = 0.45;
    let mu = cfg.avg_entries.ln() - sigma * sigma / 2.0;

    for p in 0..cfg.patients {
        let pid = patient_name(p);
        let mut prng = rng.fork();
        let n_background =
            ((mu + sigma * prng.gen_normal()).exp().round() as u64).clamp(2, 50_000);

        // Background visits: sorted random dates + zipf codes.
        let mut dates: Vec<i32> = (0..n_background)
            .map(|_| prng.gen_range(cfg.horizon_days as u64) as i32)
            .collect();
        dates.sort_unstable();
        for d in dates {
            let code = code_name(prng.gen_zipf(cfg.vocab_size, cfg.zipf_s));
            entries.push(DbMartEntry {
                patient_id: pid.clone(),
                date: d,
                phenx: code,
                description: None,
            });
        }

        if cfg.scenario == Scenario::Covid {
            plant_covid_trajectory(cfg, &mut prng, &pid, &mut entries, &mut truth);
        }
    }

    GeneratedCohort { dbmart: DbMart::new(entries), truth }
}

/// Plant the COVID arc for one patient:
///
/// * infection at a random date in the first half of the horizon;
/// * **Post-COVID** patients: 1–3 symptoms, each recurring from ≥ ~75 days
///   post infection across a span ≥ 60 days (WHO: ongoing ≥ 2 months);
/// * **transient** patients: symptoms clustered < 2 months after
///   infection (must NOT be labelled Post-COVID);
/// * confounders: chronic pre-infection symptoms, and symptoms carrying an
///   alternative diagnosis shortly before them (the vignette's exclusion
///   step must remove these).
fn plant_covid_trajectory(
    cfg: &SyntheaConfig,
    prng: &mut Rng,
    pid: &str,
    entries: &mut Vec<DbMartEntry>,
    truth: &mut GroundTruth,
) {
    // Chronic pre-existing symptom for ~15% of all patients.
    let chronic: Option<&str> = if prng.gen_bool(0.15) {
        let s = *prng.choose(SYMPTOM_CODES);
        let start = prng.gen_range((cfg.horizon_days / 4) as u64) as i32;
        let mut d = start;
        while d < cfg.horizon_days as i32 {
            entries.push(DbMartEntry {
                patient_id: pid.to_string(),
                date: d,
                phenx: s.to_string(),
                description: None,
            });
            d += 30 + prng.gen_range(60) as i32;
        }
        Some(s)
    } else {
        None
    };

    if !prng.gen_bool(cfg.covid_attack_rate) {
        return;
    }
    let infection_day = prng.gen_range((cfg.horizon_days / 2) as u64) as i32;
    entries.push(DbMartEntry {
        patient_id: pid.to_string(),
        date: infection_day,
        phenx: COVID_CODE.to_string(),
        description: Some("COVID-19 infection".to_string()),
    });
    truth.infected.insert(pid.to_string());

    let is_postcovid = prng.gen_bool(cfg.postcovid_rate);
    if is_postcovid {
        let n_sym = 1 + prng.gen_range(3) as usize;
        let mut pool: Vec<&str> =
            SYMPTOM_CODES.iter().copied().filter(|s| Some(*s) != chronic).collect();
        prng.shuffle(&mut pool);
        for &sym in pool.iter().take(n_sym) {
            // Onset ~3 months post infection (WHO: "usually 3 months from
            // onset"), persisting ≥ 2 months: 3–6 occurrences spanning
            // ≥ 60 days.
            let onset = infection_day + 75 + prng.gen_range(45) as i32;
            let n_occ = 3 + prng.gen_range(4) as i32;
            let span = 60 + prng.gen_range(120) as i32;
            for k in 0..n_occ {
                let d = onset + span * k / (n_occ - 1).max(1);
                entries.push(DbMartEntry {
                    patient_id: pid.to_string(),
                    date: d,
                    phenx: sym.to_string(),
                    description: None,
                });
            }
            truth.postcovid.insert((pid.to_string(), sym.to_string()));
        }
    } else if prng.gen_bool(0.5) {
        // Transient (acute-phase) symptoms: all within 2 months.
        let sym = *prng.choose(SYMPTOM_CODES);
        let n_occ = 1 + prng.gen_range(2) as i32;
        for _ in 0..n_occ {
            let d = infection_day + 3 + prng.gen_range(50) as i32;
            entries.push(DbMartEntry {
                patient_id: pid.to_string(),
                date: d,
                phenx: sym.to_string(),
                description: None,
            });
        }
    }

    // Alternative-diagnosis confounder for ~20% of infected patients: a
    // symptom pattern that *looks* like Post-COVID but is preceded by an
    // explaining diagnosis.
    if prng.gen_bool(0.2) {
        let (dx, sym) = *prng.choose(ALT_EXPLAINS);
        if !truth.postcovid.contains(&(pid.to_string(), sym.to_string())) {
            let dx_day = infection_day + 60 + prng.gen_range(30) as i32;
            entries.push(DbMartEntry {
                patient_id: pid.to_string(),
                date: dx_day,
                phenx: dx.to_string(),
                description: None,
            });
            let n_occ = 3 + prng.gen_range(3) as i32;
            for k in 0..n_occ {
                let d = dx_day + 10 + 80 * k / (n_occ - 1).max(1);
                entries.push(DbMartEntry {
                    patient_id: pid.to_string(),
                    date: d,
                    phenx: sym.to_string(),
                    description: None,
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let cfg = SyntheaConfig::small();
        let a = cfg.generate();
        let b = cfg.generate();
        assert_eq!(a.entries.len(), b.entries.len());
        assert_eq!(a.entries[0], b.entries[0]);
        assert_eq!(a.entries[a.len() - 1], b.entries[b.len() - 1]);
    }

    #[test]
    fn mean_entries_near_target() {
        let mut cfg = SyntheaConfig::mgb_like(0.05); // ~250 patients
        cfg.scenario = Scenario::Generic;
        let mart = cfg.generate();
        let mean = mart.len() as f64 / cfg.patients as f64;
        assert!(
            (mean - cfg.avg_entries).abs() < cfg.avg_entries * 0.15,
            "mean {mean} vs target {}",
            cfg.avg_entries
        );
    }

    #[test]
    fn generic_scenario_has_no_covid() {
        let cfg = SyntheaConfig::mgb_like(0.01);
        let g = cfg.generate_with_truth();
        assert!(g.truth.infected.is_empty());
        assert!(!g.dbmart.entries.iter().any(|e| e.phenx == COVID_CODE));
    }

    #[test]
    fn covid_scenario_plants_infections_and_truth() {
        let cfg = SyntheaConfig::small();
        let g = cfg.generate_with_truth();
        assert!(!g.truth.infected.is_empty());
        assert!(!g.truth.postcovid.is_empty());
        for (pid, _) in &g.truth.postcovid {
            assert!(g.truth.infected.contains(pid));
        }
        let covid_pats: BTreeSet<String> = g
            .dbmart
            .entries
            .iter()
            .filter(|e| e.phenx == COVID_CODE)
            .map(|e| e.patient_id.clone())
            .collect();
        assert_eq!(covid_pats, g.truth.infected);
    }

    #[test]
    fn postcovid_truth_satisfies_who_definition_in_data() {
        // For every ground-truth (patient, symptom): occurrences after the
        // infection must span >= 60 days.
        let cfg = SyntheaConfig::small();
        let g = cfg.generate_with_truth();
        for (pid, sym) in &g.truth.postcovid {
            let infection = g
                .dbmart
                .entries
                .iter()
                .filter(|e| &e.patient_id == pid && e.phenx == COVID_CODE)
                .map(|e| e.date)
                .min()
                .expect("infected");
            let post_dates: Vec<i32> = g
                .dbmart
                .entries
                .iter()
                .filter(|e| &e.patient_id == pid && &e.phenx == sym && e.date > infection)
                .map(|e| e.date)
                .collect();
            assert!(post_dates.len() >= 2, "{pid}/{sym} needs recurrences");
            let span = post_dates.iter().max().unwrap() - post_dates.iter().min().unwrap();
            assert!(span >= 60, "{pid}/{sym} span {span} < 60 days");
        }
    }

    #[test]
    fn dates_within_horizon_for_background() {
        let cfg = SyntheaConfig::mgb_like(0.01);
        let mart = cfg.generate();
        for e in &mart.entries {
            assert!(e.date >= 0 && e.date < cfg.horizon_days as i32 + 400);
        }
    }

    #[test]
    fn vocabulary_is_bounded() {
        let mut cfg = SyntheaConfig::small();
        cfg.vocab_size = 50;
        let mart = cfg.generate();
        let n = crate::dbmart::NumericDbMart::encode(&mart);
        assert!(n.num_phenx() <= 50 + 1 + SYMPTOM_CODES.len() + ALT_DIAGNOSES.len());
    }

    #[test]
    fn scale_parameter_scales_cohort() {
        assert_eq!(SyntheaConfig::mgb_like(1.0).patients, 4985);
        assert_eq!(SyntheaConfig::synthea_covid_like(1.0).patients, 35_000);
        assert!(SyntheaConfig::mgb_like(0.1).patients >= 498);
    }
}
