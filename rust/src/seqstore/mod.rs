//! Binary on-disk sequence storage — the paper's file-based mode.
//!
//! Format `TSPM1` (little-endian):
//!
//! ```text
//! magic    8 bytes  "TSPMSEQ1"
//! count    8 bytes  u64 number of records
//! records  16 bytes each: seq u64 | pid u32 | duration u32
//! ```
//!
//! Writers buffer records and stream them out so mining in file mode keeps
//! a small resident set; readers either stream ([`SeqReader`]) or bulk-load
//! ([`read_file`]). A [`SeqFileSet`] groups the per-worker spill files of
//! one mining run.

use crate::mining::SeqRecord;
use std::fs::File;
use std::io::{self, BufReader, BufWriter, Read, Seek, Write};
use std::path::{Path, PathBuf};

const MAGIC: &[u8; 8] = b"TSPMSEQ1";

/// Bytes per serialized record (the paper's 128-bit layout).
pub const RECORD_BYTES: usize = 16;

/// Bytes before the first record (magic + count).
pub const HEADER_BYTES: usize = 16;

/// The 16-byte little-endian wire encoding of one record — the one
/// byte layout shared by [`SeqWriter`], [`SeqReader`] and the
/// checksums of [`crate::query`]'s index artifacts.
#[inline]
pub fn encode_record(r: SeqRecord) -> [u8; RECORD_BYTES] {
    let mut buf = [0u8; RECORD_BYTES];
    buf[0..8].copy_from_slice(&r.seq.to_le_bytes());
    buf[8..12].copy_from_slice(&r.pid.to_le_bytes());
    buf[12..16].copy_from_slice(&r.duration.to_le_bytes());
    buf
}

/// Writer buffer size; also the per-worker resident cost of file mode.
pub const WRITER_BUFFER_BYTES: usize = 1 << 20;

/// Streaming record writer. Call [`SeqWriter::finish`] to patch the count.
pub struct SeqWriter {
    out: BufWriter<File>,
    count: u64,
}

impl SeqWriter {
    pub fn create(path: &Path) -> io::Result<SeqWriter> {
        Self::create_with_capacity(path, WRITER_BUFFER_BYTES)
    }

    /// [`SeqWriter::create`] with an explicit buffer capacity —
    /// budget-bounded consumers (the out-of-core screen) size their
    /// writers from a memory budget instead of the 1 MiB default.
    pub fn create_with_capacity(path: &Path, capacity: usize) -> io::Result<SeqWriter> {
        let file = File::create(path)?;
        let mut out = BufWriter::with_capacity(capacity.max(RECORD_BYTES), file);
        out.write_all(MAGIC)?;
        out.write_all(&0u64.to_le_bytes())?; // count patched in finish()
        Ok(SeqWriter { out, count: 0 })
    }

    #[inline]
    pub fn write(&mut self, r: SeqRecord) -> io::Result<()> {
        self.out.write_all(&encode_record(r))?;
        self.count += 1;
        Ok(())
    }

    /// Flush, patch the header count, and return the record count.
    pub fn finish(mut self) -> io::Result<u64> {
        self.out.flush()?;
        let mut file = self.out.into_inner().map_err(|e| e.into_error())?;
        file.seek(io::SeekFrom::Start(8))?;
        file.write_all(&self.count.to_le_bytes())?;
        file.sync_data().ok(); // best-effort durability
        Ok(self.count)
    }
}

/// Streaming record reader (iterator interface), with positioned reads
/// ([`SeqReader::seek_record`]) for index-driven random access.
pub struct SeqReader {
    input: BufReader<File>,
    remaining: u64,
    total: u64,
}

impl SeqReader {
    pub fn open(path: &Path) -> io::Result<SeqReader> {
        Self::open_with_capacity(path, WRITER_BUFFER_BYTES)
    }

    /// [`SeqReader::open`] with an explicit buffer capacity, for k-way
    /// merges that hold many readers open under one memory budget.
    ///
    /// Open-time validation: a missing file, a truncated file (fewer
    /// payload bytes than the header's record count claims), and a
    /// payload that is not a whole multiple of the 16-byte record size
    /// all fail *here* with a typed [`io::Error`] naming the offending
    /// path, instead of surfacing as a bare `read_exact` failure deep
    /// inside a merge.
    pub fn open_with_capacity(path: &Path, capacity: usize) -> io::Result<SeqReader> {
        let file = File::open(path).map_err(|e| {
            io::Error::new(e.kind(), format!("{}: {e}", path.display()))
        })?;
        let file_len = file.metadata()?.len();
        if file_len < HEADER_BYTES as u64 {
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                format!(
                    "{}: {file_len}-byte file is too small for a TSPMSEQ1 header",
                    path.display()
                ),
            ));
        }
        let mut input = BufReader::with_capacity(capacity.max(RECORD_BYTES), file);
        let mut magic = [0u8; 8];
        input.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("{}: not a TSPMSEQ1 file", path.display()),
            ));
        }
        let mut count_buf = [0u8; 8];
        input.read_exact(&mut count_buf)?;
        let count = u64::from_le_bytes(count_buf);
        let payload = file_len - HEADER_BYTES as u64;
        if payload % RECORD_BYTES as u64 != 0 {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!(
                    "{}: payload of {payload} bytes is not a multiple of the \
                     {RECORD_BYTES}-byte record size",
                    path.display()
                ),
            ));
        }
        let actual = payload / RECORD_BYTES as u64;
        if actual < count {
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                format!(
                    "{}: truncated TSPMSEQ1 file — header claims {count} records, \
                     payload holds {actual}",
                    path.display()
                ),
            ));
        }
        if actual > count {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!(
                    "{}: payload holds {actual} records but the header claims {count} \
                     (writer died before SeqWriter::finish?)",
                    path.display()
                ),
            ));
        }
        Ok(SeqReader { input, remaining: count, total: count })
    }

    /// Records left to read.
    pub fn remaining(&self) -> u64 {
        self.remaining
    }

    /// Total records in the file (independent of the read position).
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Position the reader on record `n` (0-based); subsequent
    /// [`SeqReader::read_batch`] calls stream from there. `n` may equal
    /// the record count (positions at EOF); anything past that is an
    /// `InvalidInput` error.
    pub fn seek_record(&mut self, n: u64) -> io::Result<()> {
        if n > self.total {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                format!("seek_record({n}) past the end of a {}-record file", self.total),
            ));
        }
        self.input
            .seek(io::SeekFrom::Start(HEADER_BYTES as u64 + n * RECORD_BYTES as u64))?;
        self.remaining = self.total - n;
        Ok(())
    }

    /// Positioned batch read: fill `buf` starting at record `n`.
    /// Equivalent to [`SeqReader::seek_record`] + [`SeqReader::read_batch`].
    pub fn read_at(&mut self, n: u64, buf: &mut [SeqRecord]) -> io::Result<usize> {
        self.seek_record(n)?;
        self.read_batch(buf)
    }

    /// Read up to `buf.len()` records into `buf`; returns how many were
    /// filled (0 at EOF). Batched form for the screening hot path.
    pub fn read_batch(&mut self, buf: &mut [SeqRecord]) -> io::Result<usize> {
        let want = (buf.len() as u64).min(self.remaining) as usize;
        if want == 0 {
            return Ok(0);
        }
        let mut raw = vec![0u8; want * RECORD_BYTES];
        self.input.read_exact(&mut raw)?;
        for (i, chunk) in raw.chunks_exact(RECORD_BYTES).enumerate() {
            buf[i] = SeqRecord {
                seq: u64::from_le_bytes(chunk[0..8].try_into().unwrap()),
                pid: u32::from_le_bytes(chunk[8..12].try_into().unwrap()),
                duration: u32::from_le_bytes(chunk[12..16].try_into().unwrap()),
            };
        }
        self.remaining -= want as u64;
        Ok(want)
    }
}

impl Iterator for SeqReader {
    type Item = io::Result<SeqRecord>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.remaining == 0 {
            return None;
        }
        let mut one = [SeqRecord { seq: 0, pid: 0, duration: 0 }];
        match self.read_batch(&mut one) {
            Ok(1) => Some(Ok(one[0])),
            Ok(_) => None,
            Err(e) => Some(Err(e)),
        }
    }
}

/// Bulk-load an entire file.
pub fn read_file(path: &Path) -> io::Result<Vec<SeqRecord>> {
    let mut reader = SeqReader::open(path)?;
    let mut out = vec![SeqRecord { seq: 0, pid: 0, duration: 0 }; reader.remaining() as usize];
    let mut filled = 0;
    while filled < out.len() {
        let n = reader.read_batch(&mut out[filled..])?;
        if n == 0 {
            return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "truncated TSPMSEQ1 file"));
        }
        filled += n;
    }
    Ok(out)
}

/// Write a whole record slice to `path`.
pub fn write_file(path: &Path, records: &[SeqRecord]) -> io::Result<()> {
    let mut w = SeqWriter::create(path)?;
    for &r in records {
        w.write(r)?;
    }
    w.finish()?;
    Ok(())
}

/// The spill files of one file-based mining run.
#[derive(Clone, Debug, Default)]
pub struct SeqFileSet {
    pub files: Vec<PathBuf>,
    pub total_records: u64,
    pub num_patients: u32,
    pub num_phenx: u32,
}

impl SeqFileSet {
    /// Logical payload size of the stored records (16 bytes each) —
    /// what the set would occupy if materialised.
    pub fn logical_bytes(&self) -> u64 {
        self.total_records * RECORD_BYTES as u64
    }

    /// Load every file into one vector (used by tests and by in-memory
    /// consumers after a file-based run).
    pub fn read_all(&self) -> io::Result<Vec<SeqRecord>> {
        let mut out = Vec::with_capacity(self.total_records as usize);
        for f in &self.files {
            out.extend(read_file(f)?);
        }
        Ok(out)
    }

    /// Stream every record to `f` without materialising the set.
    pub fn for_each(&self, mut f: impl FnMut(SeqRecord)) -> io::Result<()> {
        let mut buf = vec![SeqRecord { seq: 0, pid: 0, duration: 0 }; 64 * 1024];
        for path in &self.files {
            let mut reader = SeqReader::open(path)?;
            loop {
                let n = reader.read_batch(&mut buf)?;
                if n == 0 {
                    break;
                }
                for &r in &buf[..n] {
                    f(r);
                }
            }
        }
        Ok(())
    }

    /// Delete the spill files (cleanup after consumption).
    pub fn remove(&self) -> io::Result<()> {
        for f in &self.files {
            std::fs::remove_file(f)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("tspm_seqstore_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn recs(n: u64) -> Vec<SeqRecord> {
        (0..n)
            .map(|i| SeqRecord { seq: i * 31, pid: (i % 97) as u32, duration: (i % 400) as u32 })
            .collect()
    }

    #[test]
    fn roundtrip_bulk() {
        let path = tmp("bulk.tspm");
        let data = recs(10_000);
        write_file(&path, &data).unwrap();
        assert_eq!(read_file(&path).unwrap(), data);
    }

    #[test]
    fn roundtrip_streaming() {
        let path = tmp("stream.tspm");
        let data = recs(1234);
        write_file(&path, &data).unwrap();
        let reader = SeqReader::open(&path).unwrap();
        assert_eq!(reader.remaining(), 1234);
        let got: Vec<SeqRecord> = reader.map(|r| r.unwrap()).collect();
        assert_eq!(got, data);
    }

    #[test]
    fn empty_file_roundtrip() {
        let path = tmp("empty.tspm");
        write_file(&path, &[]).unwrap();
        assert!(read_file(&path).unwrap().is_empty());
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmp("bad.tspm");
        std::fs::write(&path, b"NOTTSPM!.............").unwrap();
        assert!(SeqReader::open(&path).is_err());
    }

    #[test]
    fn detects_truncation() {
        let path = tmp("trunc.tspm");
        write_file(&path, &recs(100)).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 8]).unwrap();
        assert!(read_file(&path).is_err());
    }

    #[test]
    fn fileset_for_each_streams_everything() {
        let p1 = tmp("fs1.tspm");
        let p2 = tmp("fs2.tspm");
        let d1 = recs(500);
        let d2 = recs(300);
        write_file(&p1, &d1).unwrap();
        write_file(&p2, &d2).unwrap();
        let fs = SeqFileSet {
            files: vec![p1, p2],
            total_records: 800,
            num_patients: 97,
            num_phenx: 0,
        };
        let mut seen = Vec::new();
        fs.for_each(|r| seen.push(r)).unwrap();
        assert_eq!(seen.len(), 800);
        assert_eq!(&seen[..500], &d1[..]);
        assert_eq!(&seen[500..], &d2[..]);
    }

    #[test]
    fn positioned_reads_match_read_batch() {
        let path = tmp("seek.tspm");
        let data = recs(1000);
        write_file(&path, &data).unwrap();

        // Streaming from every seek position equals the slice suffix the
        // plain batched read path yields.
        for &n in &[0u64, 1, 499, 997, 1000] {
            let mut reader = SeqReader::open(&path).unwrap();
            assert_eq!(reader.total(), 1000);
            reader.seek_record(n).unwrap();
            assert_eq!(reader.remaining(), 1000 - n);
            let mut got = Vec::new();
            let mut buf = vec![SeqRecord { seq: 0, pid: 0, duration: 0 }; 97];
            loop {
                let k = reader.read_batch(&mut buf).unwrap();
                if k == 0 {
                    break;
                }
                got.extend_from_slice(&buf[..k]);
            }
            assert_eq!(got, data[n as usize..], "seek to {n}");
        }

        // read_at equals the direct slice, including re-positioning
        // backwards after a forward read.
        let mut reader = SeqReader::open(&path).unwrap();
        let mut buf = vec![SeqRecord { seq: 0, pid: 0, duration: 0 }; 64];
        let k = reader.read_at(600, &mut buf).unwrap();
        assert_eq!(&buf[..k], &data[600..664]);
        let k = reader.read_at(3, &mut buf).unwrap();
        assert_eq!(&buf[..k], &data[3..67]);

        // Past-the-end seeks are typed errors; EOF-position seeks are not.
        assert!(reader.seek_record(1001).is_err());
        reader.seek_record(1000).unwrap();
        assert_eq!(reader.read_batch(&mut buf).unwrap(), 0);
    }

    #[test]
    fn open_missing_file_names_the_path() {
        let path = tmp("does_not_exist.tspm");
        let _ = std::fs::remove_file(&path);
        let err = SeqReader::open(&path).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::NotFound);
        assert!(err.to_string().contains("does_not_exist.tspm"), "got {err}");
        // The file-set bulk path surfaces the same typed error.
        let fs = SeqFileSet { files: vec![path], total_records: 0, num_patients: 0, num_phenx: 0 };
        let err = fs.read_all().unwrap_err();
        assert!(err.to_string().contains("does_not_exist.tspm"), "got {err}");
    }

    #[test]
    fn open_rejects_truncation_at_open_time() {
        let path = tmp("trunc_open.tspm");
        write_file(&path, &recs(50)).unwrap();
        let full = std::fs::read(&path).unwrap();
        // Drop exactly one record: payload stays a multiple of 16, so this
        // is the pure header-vs-payload count mismatch.
        std::fs::write(&path, &full[..full.len() - RECORD_BYTES]).unwrap();
        let err = SeqReader::open(&path).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
        assert!(err.to_string().contains("trunc_open.tspm"), "got {err}");
        assert!(err.to_string().contains("50"), "got {err}");
    }

    #[test]
    fn open_rejects_non_record_multiple_sizes() {
        let path = tmp("ragged.tspm");
        write_file(&path, &recs(10)).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(&[0xAB, 0xCD, 0xEF]);
        std::fs::write(&path, &bytes).unwrap();
        let err = SeqReader::open(&path).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("multiple"), "got {err}");
        assert!(err.to_string().contains("ragged.tspm"), "got {err}");

        // A whole unaccounted trailing record (writer died before finish
        // patched the header) is also rejected, with the counts shown.
        let path2 = tmp("unpatched.tspm");
        write_file(&path2, &recs(10)).unwrap();
        let mut bytes = std::fs::read(&path2).unwrap();
        bytes.extend_from_slice(&encode_record(SeqRecord { seq: 1, pid: 2, duration: 3 }));
        std::fs::write(&path2, &bytes).unwrap();
        let err = SeqReader::open(&path2).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("11"), "got {err}");
    }

    #[test]
    fn open_rejects_header_shorter_than_header_bytes() {
        let path = tmp("stub.tspm");
        std::fs::write(&path, b"TSPM").unwrap();
        let err = SeqReader::open(&path).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
        assert!(err.to_string().contains("stub.tspm"), "got {err}");
    }

    #[test]
    fn batched_reads_cross_boundaries() {
        let path = tmp("batch.tspm");
        let data = recs(1000);
        write_file(&path, &data).unwrap();
        let mut reader = SeqReader::open(&path).unwrap();
        let mut buf = vec![SeqRecord { seq: 0, pid: 0, duration: 0 }; 333];
        let mut got = Vec::new();
        loop {
            let n = reader.read_batch(&mut buf).unwrap();
            if n == 0 {
                break;
            }
            got.extend_from_slice(&buf[..n]);
        }
        assert_eq!(got, data);
    }
}
