//! Streaming orchestration — sharded mining with bounded queues and
//! backpressure.
//!
//! The batch entry points in [`crate::mining`] materialise everything;
//! this module is the *data-pipeline* face of the system: dbmart
//! partitions flow through a staged graph
//!
//! ```text
//!   source (partition chunks) ──▶ [bounded queue] ──▶ miner shard 0..N
//!        (backpressure)                                   │
//!                                 [bounded queue] ◀───────┘
//!                                        │
//!                                  collector (+ optional screen)
//! ```
//!
//! * **Sharding**: partition chunks are claimed by miners from a shared
//!   work queue — idle shards steal the next chunk, which *is* the
//!   rebalancing policy (no static assignment to go stale).
//! * **Backpressure**: queues are bounded; a fast producer blocks instead
//!   of ballooning the resident set, so peak memory is
//!   `O(queue_depth × chunk_output)` rather than `O(total output)`.
//! * **Metrics**: per-stage counts and blocking times are reported for
//!   the perf pass.

use crate::dbmart::NumericDbMart;
use crate::engine::{SequenceOutput, TspmError};
use crate::mining::{self, MineContext, MiningConfig, SeqRecord, SequenceSet};
use crate::partition;
use crate::seqstore::{SeqFileSet, SeqWriter};
use crate::sparsity::{self, SparsityConfig};
use crate::target::TargetSpec;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, SyncSender, TrySendError};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Pipeline configuration.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub mining: MiningConfig,
    /// Max predicted sequences per partition chunk.
    pub chunk_cap: u64,
    /// Bounded-queue depth between stages (chunks in flight).
    pub queue_depth: usize,
    /// Miner shards.
    pub shards: usize,
    /// Optional screening of the merged stream (in-memory collection
    /// only; incompatible with `spill_dir` — screen spilled output with
    /// [`crate::sparsity::screen_spilled`]).
    pub screen: Option<SparsityConfig>,
    /// When set, the collector streams record batches to one spill file
    /// in this directory instead of merging them in memory — the
    /// pipeline's resident set then never includes the output at all,
    /// and the run returns [`SequenceOutput::Spilled`].
    pub spill_dir: Option<PathBuf>,
    /// Optional targeting predicate pushed into every miner shard's
    /// inner loop ([`crate::target`]); `None` (or an `is_all` spec)
    /// streams the full multiset.
    pub target: Option<TargetSpec>,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            mining: MiningConfig::default(),
            chunk_cap: 4_000_000,
            queue_depth: 4,
            shards: 0, // auto
            screen: None,
            spill_dir: None,
            target: None,
        }
    }
}

/// Per-stage metrics.
#[derive(Debug, Default)]
pub struct StageMetrics {
    /// Chunks emitted by the source.
    pub chunks: AtomicUsize,
    /// Records that crossed the miner → collector queue.
    pub records: AtomicU64,
    /// Nanoseconds the source spent blocked on a full queue
    /// (backpressure engaged).
    pub source_blocked_ns: AtomicU64,
    /// Chunks processed per shard.
    pub per_shard: Mutex<Vec<usize>>,
}

impl StageMetrics {
    pub fn report(&self) -> String {
        let shards = self.per_shard.lock().unwrap();
        format!(
            "chunks={} records={} source_blocked={:?} shard_loads={:?}",
            self.chunks.load(Ordering::Relaxed),
            self.records.load(Ordering::Relaxed),
            Duration::from_nanos(self.source_blocked_ns.load(Ordering::Relaxed)),
            *shards,
        )
    }
}

/// Result of a streaming run: the sequences come back in memory by
/// default, or as one spill file when
/// [`PipelineConfig::spill_dir`] redirected the collector to disk.
pub struct PipelineResult {
    pub sequences: SequenceOutput,
    pub metrics: StageMetrics,
    pub screen_stats: Option<sparsity::ScreenStats>,
}

/// Blocking send that accounts backpressure time.
fn send_with_backpressure<T>(
    tx: &SyncSender<T>,
    mut item: T,
    blocked_ns: &AtomicU64,
) -> Result<(), ()> {
    loop {
        match tx.try_send(item) {
            Ok(()) => return Ok(()),
            Err(TrySendError::Full(back)) => {
                let start = Instant::now();
                item = back;
                std::thread::yield_now();
                std::thread::sleep(Duration::from_micros(50));
                blocked_ns.fetch_add(start.elapsed().as_nanos() as u64, Ordering::Relaxed);
            }
            Err(TrySendError::Disconnected(_)) => return Err(()),
        }
    }
}

/// Run the streaming pipeline over a dbmart.
pub fn run(db: &NumericDbMart, cfg: &PipelineConfig) -> Result<PipelineResult, TspmError> {
    // The collapsed validator: mining config semantics plus the target's
    // structural checks in one place. An is_all() spec normalizes to no
    // target, keeping the untargeted path byte-identical.
    let target = cfg.target.as_ref().filter(|t| !t.is_all());
    MineContext::with_target(&cfg.mining, target).validate()?;
    if cfg.spill_dir.is_some() && cfg.screen.is_some() {
        return Err(TspmError::Pipeline(
            "the in-memory screen cannot combine with spill_dir — screen spilled \
             output with sparsity::screen_spilled"
                .into(),
        ));
    }
    let shards = if cfg.shards > 0 {
        cfg.shards
    } else {
        crate::par::num_threads(None)
    };
    let plan = partition::plan(db, &cfg.mining, cfg.chunk_cap)?;
    let metrics = StageMetrics::default();
    *metrics.per_shard.lock().unwrap() = vec![0usize; shards];

    let n_chunks = plan.len();
    let (chunk_tx, chunk_rx) = std::sync::mpsc::sync_channel::<usize>(cfg.queue_depth);
    let (out_tx, out_rx) = std::sync::mpsc::sync_channel::<Vec<SeqRecord>>(cfg.queue_depth);
    let chunk_rx = SharedReceiver(Mutex::new(chunk_rx));

    let mut merged: Vec<SeqRecord> = Vec::new();
    let mut spill: Option<(PathBuf, SeqWriter)> = None;
    if let Some(dir) = &cfg.spill_dir {
        std::fs::create_dir_all(dir)?;
        let path = dir.join("streamed_0000.tspm");
        let writer = SeqWriter::create(&path)?;
        spill = Some((path, writer));
    }
    let mut spill_err: Option<std::io::Error> = None;
    let mut failed: Option<String> = None;

    std::thread::scope(|s| {
        // Source: enqueue chunk indices (bounded → backpressure).
        let metrics_ref = &metrics;
        s.spawn(move || {
            for i in 0..n_chunks {
                if send_with_backpressure(&chunk_tx, i, &metrics_ref.source_blocked_ns).is_err() {
                    break;
                }
                metrics_ref.chunks.fetch_add(1, Ordering::Relaxed);
            }
            drop(chunk_tx);
        });

        // Miner shards: claim chunks dynamically (work stealing =
        // rebalancing), mine, push record batches downstream.
        let plan_ref = &plan;
        let chunk_rx_ref = &chunk_rx;
        let mining_cfg = &cfg.mining;
        for shard in 0..shards {
            let out_tx = out_tx.clone();
            let metrics_ref = &metrics;
            s.spawn(move || {
                loop {
                    let idx = match chunk_rx_ref.recv() {
                        Some(i) => i,
                        None => break,
                    };
                    let sub = NumericDbMart {
                        entries: plan_ref.chunk_entries(idx).to_vec(),
                        lookup: Default::default(),
                    };
                    // Each shard mines its chunk single-threaded; shard-level
                    // parallelism already saturates the pool.
                    let local_cfg = MiningConfig { threads: 1, ..mining_cfg.clone() };
                    let ctx = MineContext::with_target(&local_cfg, target);
                    match mining::mine_sequences_with(&sub, ctx, None) {
                        Ok(set) => {
                            metrics_ref
                                .records
                                .fetch_add(set.records.len() as u64, Ordering::Relaxed);
                            metrics_ref.per_shard.lock().unwrap()[shard] += 1;
                            if out_tx.send(set.records).is_err() {
                                break;
                            }
                        }
                        Err(_) => break,
                    }
                }
            });
        }
        drop(out_tx); // collector sees EOF once all shards finish

        // Collector (runs on this thread): merge batches in arrival
        // order — into memory, or straight to the spill file (the first
        // I/O error latches; the queues still drain so miners finish).
        for batch in out_rx.iter() {
            match &mut spill {
                Some((_, writer)) => {
                    if spill_err.is_none() {
                        for &r in batch.iter() {
                            if let Err(e) = writer.write(r) {
                                spill_err = Some(e);
                                break;
                            }
                        }
                    }
                }
                None => merged.extend_from_slice(&batch),
            }
        }
        if metrics.chunks.load(Ordering::Relaxed) != n_chunks {
            failed = Some("source stage aborted early".to_string());
        }
    });

    if spill_err.is_some() || failed.is_some() {
        // Never leave a half-written spill file behind: its unpatched
        // count header (0) would make a later open read "no records"
        // without any error.
        if let Some((path, writer)) = spill.take() {
            drop(writer);
            let _ = std::fs::remove_file(&path);
        }
    }
    if let Some(e) = spill_err {
        return Err(TspmError::Io(e));
    }
    if let Some(f) = failed {
        return Err(TspmError::Pipeline(f));
    }

    // The merged stream is already the targeted multiset (miners pruned
    // in the inner loop), so passing the spec again is a proven no-op —
    // it keeps the screen's documented "targeted universe" semantics in
    // force even if a caller bypasses the miner pushdown.
    let screen_stats =
        cfg.screen.as_ref().map(|sc| sparsity::screen_with(&mut merged, sc, target));
    let sequences = match spill {
        Some((path, writer)) => {
            let count = writer.finish()?;
            SequenceOutput::Spilled(SeqFileSet {
                files: vec![path],
                total_records: count,
                num_patients: db.num_patients() as u32,
                num_phenx: db.num_phenx() as u32,
            })
        }
        None => SequenceOutput::InMemory(SequenceSet {
            records: merged,
            num_patients: db.num_patients() as u32,
            num_phenx: db.num_phenx() as u32,
        }),
    };
    Ok(PipelineResult { sequences, metrics, screen_stats })
}

/// mpsc `Receiver` shared across shards behind a mutex (work-queue
/// semantics: whichever shard locks first gets the next chunk).
struct SharedReceiver<T>(Mutex<Receiver<T>>);

impl<T> SharedReceiver<T> {
    fn recv(&self) -> Option<T> {
        self.0.lock().unwrap().recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dbmart::NumericDbMart;

    fn test_db() -> NumericDbMart {
        let mart = crate::synthea::SyntheaConfig::small().generate();
        NumericDbMart::encode(&mart)
    }

    #[test]
    fn streaming_matches_batch() {
        let db = test_db();
        let batch = mining::mine_sequences(&db, &MiningConfig::default()).unwrap();
        let cfg = PipelineConfig { chunk_cap: 50_000, shards: 3, ..Default::default() };
        let streamed = run(&db, &cfg).unwrap();
        assert_eq!(streamed.sequences.len(), batch.len());
        let mut a = batch.records;
        let mut b = streamed.sequences.materialize().unwrap().records;
        a.sort_unstable_by_key(|r| (r.seq, r.pid, r.duration));
        b.sort_unstable_by_key(|r| (r.seq, r.pid, r.duration));
        assert_eq!(a, b);
    }

    #[test]
    fn spilled_collection_matches_in_memory_collection() {
        let db = test_db();
        let dir = std::env::temp_dir()
            .join(format!("tspm_pipeline_spill_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = PipelineConfig {
            chunk_cap: 50_000,
            shards: 3,
            spill_dir: Some(dir.clone()),
            ..Default::default()
        };
        let result = run(&db, &cfg).unwrap();
        let files = match &result.sequences {
            crate::engine::SequenceOutput::Spilled(f) => f.clone(),
            other => panic!("expected spilled output, got {:?}", other.kind()),
        };
        assert_eq!(files.num_patients as usize, db.num_patients());
        let batch = mining::mine_sequences(&db, &MiningConfig::default()).unwrap();
        assert_eq!(files.total_records as usize, batch.len());
        let mut a = batch.records;
        let mut b = result.sequences.materialize().unwrap().records;
        let key = |r: &SeqRecord| (r.seq, r.pid, r.duration);
        a.sort_unstable_by_key(key);
        b.sort_unstable_by_key(key);
        assert_eq!(a, b);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn spill_dir_rejects_the_in_memory_screen() {
        let db = test_db();
        let cfg = PipelineConfig {
            spill_dir: Some(std::env::temp_dir().join("tspm_pipeline_bad")),
            screen: Some(SparsityConfig::default()),
            ..Default::default()
        };
        let err = run(&db, &cfg).unwrap_err();
        assert!(err.to_string().contains("spill_dir"), "got {err}");
    }

    #[test]
    fn screening_in_pipeline_matches_batch_screen() {
        let db = test_db();
        let sc = SparsityConfig { min_patients: 5, threads: 1 };
        let mut batch = mining::mine_sequences(&db, &MiningConfig::default()).unwrap();
        let batch_stats = sparsity::screen(&mut batch.records, &sc);
        let cfg = PipelineConfig {
            chunk_cap: 50_000,
            shards: 2,
            screen: Some(sc),
            ..Default::default()
        };
        let streamed = run(&db, &cfg).unwrap();
        assert_eq!(streamed.screen_stats.unwrap(), batch_stats);
        assert_eq!(streamed.sequences.len(), batch.len());
    }

    #[test]
    fn all_chunks_flow_through() {
        let db = test_db();
        let cfg = PipelineConfig { chunk_cap: 50_000, shards: 2, queue_depth: 2, ..Default::default() };
        let result = run(&db, &cfg).unwrap();
        let plan = partition::plan(&db, &cfg.mining, cfg.chunk_cap).unwrap();
        assert_eq!(result.metrics.chunks.load(Ordering::Relaxed), plan.len());
        let shard_loads = result.metrics.per_shard.lock().unwrap().clone();
        assert_eq!(shard_loads.iter().sum::<usize>(), plan.len());
        assert_eq!(
            result.metrics.records.load(Ordering::Relaxed),
            result.sequences.len() as u64
        );
    }

    #[test]
    fn tiny_queue_depth_still_completes() {
        // queue_depth=1 maximises backpressure; correctness must hold.
        let db = test_db();
        let cfg = PipelineConfig {
            chunk_cap: 50_000,
            queue_depth: 1,
            shards: 4,
            ..Default::default()
        };
        let result = run(&db, &cfg).unwrap();
        let batch = mining::mine_sequences(&db, &MiningConfig::default()).unwrap();
        assert_eq!(result.sequences.len(), batch.len());
    }

    #[test]
    fn targeted_pipeline_matches_filtered_batch() {
        let db = test_db();
        let spec = crate::target::TargetSpec::for_codes([0, 2])
            .with_duration_band(Some(1), None);
        let batch = mining::mine_sequences(&db, &MiningConfig::default()).unwrap();
        let mut want: Vec<SeqRecord> =
            batch.records.into_iter().filter(|r| spec.matches_record(r)).collect();
        let cfg = PipelineConfig {
            chunk_cap: 50_000,
            shards: 3,
            target: Some(spec),
            ..Default::default()
        };
        let streamed = run(&db, &cfg).unwrap();
        let mut got = streamed.sequences.materialize().unwrap().records;
        let key = |r: &SeqRecord| (r.seq, r.pid, r.duration);
        got.sort_unstable_by_key(key);
        want.sort_unstable_by_key(key);
        assert_eq!(got, want);
    }

    #[test]
    fn invalid_target_is_rejected_before_any_thread_spawns() {
        let db = test_db();
        let cfg = PipelineConfig {
            target: Some(crate::target::TargetSpec::for_codes([])),
            ..Default::default()
        };
        assert!(run(&db, &cfg).is_err());
    }

    #[test]
    fn metrics_report_formats() {
        let db = test_db();
        let result = run(&db, &PipelineConfig::default()).unwrap();
        let report = result.metrics.report();
        assert!(report.contains("chunks="));
        assert!(report.contains("records="));
    }
}
