//! The original tSPM algorithm (Estiri et al. 2020/2021) — the comparison
//! baseline.
//!
//! A faithful re-implementation of the R reference (paper Fig. 1):
//! string-typed sequences, per-pair allocation, a single thread, and a
//! hash-based sparsity screen. It deliberately keeps the constant-factor
//! behaviour of the original — string keys built with `format!`, one heap
//! allocation per mined sequence, scattered hash updates — because the
//! paper's headline factors (≈920× speed, ≈48× memory) are measured
//! *against exactly those sins*. Re-implementing it in Rust (rather than
//! benchmarking R itself) removes the language runtime as a confound, so
//! our measured ratios are a lower bound on the paper's (DESIGN.md
//! §Substitutions).
//!
//! Like the original, it does **not** record durations — that dimension is
//! tSPM+'s contribution.

use crate::dbmart::DbMart;
use std::collections::{HashMap, HashSet};

/// One mined baseline sequence: `(patient, "startPhenX->endPhenX")`.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct StringSeq {
    pub patient: String,
    pub sequence: String,
}

/// Baseline configuration.
#[derive(Clone, Copy, Debug)]
pub struct BaselineConfig {
    /// Keep only the first occurrence of each phenX per patient (the
    /// protocol of the paper's comparison benchmark).
    pub first_occurrence_only: bool,
    /// Apply the MSMR-style sparsity screen after mining.
    pub sparsity_screen: bool,
    /// Distinct-patient threshold for the screen.
    pub min_patients: u32,
}

impl Default for BaselineConfig {
    fn default() -> Self {
        BaselineConfig {
            first_occurrence_only: true,
            sparsity_screen: false,
            min_patients: 50,
        }
    }
}

/// Result of a baseline run, with the logical bytes the string
/// representation holds (for the paper's memory comparison).
#[derive(Clone, Debug, Default)]
pub struct BaselineResult {
    pub sequences: Vec<StringSeq>,
    /// Logical heap bytes of all strings + vec overhead.
    pub logical_bytes: u64,
}

impl BaselineResult {
    fn compute_bytes(sequences: &[StringSeq]) -> u64 {
        let mut total = (sequences.len() * std::mem::size_of::<StringSeq>()) as u64;
        for s in sequences {
            total += (s.patient.capacity() + s.sequence.capacity()) as u64;
        }
        total
    }
}

/// Run the original tSPM (paper Fig. 1 pseudocode).
pub fn mine(db: &DbMart, cfg: &BaselineConfig) -> BaselineResult {
    // sort(dbmart, by(patient_num, date)) — R's order() is a sequential
    // comparison sort over the string patient ids.
    let mut rows: Vec<(&str, i32, &str)> = db
        .entries
        .iter()
        .map(|e| (e.patient_id.as_str(), e.date, e.phenx.as_str()))
        .collect();
    rows.sort_by(|a, b| a.0.cmp(b.0).then(a.1.cmp(&b.1)));

    let mut sequences: Vec<StringSeq> = Vec::new();
    let mut i = 0;
    while i < rows.len() {
        // Patient chunk [i, j)
        let mut j = i;
        while j < rows.len() && rows[j].0 == rows[i].0 {
            j += 1;
        }
        let chunk = &rows[i..j];
        // Optional first-occurrence filter (string hash set, as the
        // original's dedupe over phenX strings).
        let filtered: Vec<(&str, i32, &str)> = if cfg.first_occurrence_only {
            let mut seen: HashSet<&str> = HashSet::new();
            chunk.iter().filter(|r| seen.insert(r.2)).copied().collect()
        } else {
            chunk.to_vec()
        };
        // for all phenx x in p: for all phenx y with y.date >= x.date:
        //   sparseSequences.add(createSequence(x, y))
        for a in 0..filtered.len() {
            for b in (a + 1)..filtered.len() {
                sequences.push(StringSeq {
                    patient: filtered[a].0.to_string(),
                    sequence: format!("{}->{}", filtered[a].2, filtered[b].2),
                });
            }
        }
        i = j;
    }

    let mut result = BaselineResult { logical_bytes: 0, sequences };
    let pre_screen_bytes = BaselineResult::compute_bytes(&result.sequences);
    if cfg.sparsity_screen {
        // The screen's hash counting holds keys + per-sequence patient
        // sets *on top of* the full sequence vector — like the R
        // implementation, whose screened runs need MORE memory than
        // unscreened ones (paper Table 1: 205 GB vs 63 GB).
        let screen_overhead = sparsity_screen(&mut result.sequences, cfg.min_patients);
        result.logical_bytes = pre_screen_bytes + screen_overhead;
    } else {
        result.logical_bytes = pre_screen_bytes;
    }
    result
}

/// MSMR-style sparsity screen over string sequences: drop sequences seen
/// in fewer than `min_patients` distinct patients (hash-map counting, as
/// the R implementation does with `dplyr::n_distinct`).
///
/// Returns the approximate logical bytes of the screening structures
/// (the hash maps of string refs) for the memory accounting.
pub fn sparsity_screen(sequences: &mut Vec<StringSeq>, min_patients: u32) -> u64 {
    let mut patients_per_seq: HashMap<&str, HashSet<&str>> = HashMap::new();
    for s in sequences.iter() {
        patients_per_seq
            .entry(s.sequence.as_str())
            .or_default()
            .insert(s.patient.as_str());
    }
    // &str entries are (ptr, len) pairs; hash sets/maps carry ~2x slack.
    let ref_bytes = 2 * std::mem::size_of::<&str>() as u64;
    let mut overhead = 0u64;
    for (k, pats) in &patients_per_seq {
        overhead += ref_bytes + k.len() as u64 + pats.len() as u64 * ref_bytes;
    }
    let keep: HashSet<String> = patients_per_seq
        .iter()
        .filter(|(_, pats)| pats.len() as u32 >= min_patients)
        .map(|(seq, _)| seq.to_string())
        .collect();
    overhead += keep.iter().map(|s| s.capacity() as u64 + ref_bytes).sum::<u64>();
    sequences.retain(|s| keep.contains(&s.sequence));
    overhead
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dbmart::{DbMartEntry, NumericDbMart};
    use crate::mining::{mine_sequences, MiningConfig};

    fn raw(p: &str, date: i32, x: &str) -> DbMartEntry {
        DbMartEntry { patient_id: p.into(), date, phenx: x.into(), description: None }
    }

    #[test]
    fn fig1_pseudocode_semantics() {
        let db = DbMart::new(vec![
            raw("A", 1, "a"),
            raw("A", 3, "b"),
            raw("B", 2, "c"),
            raw("B", 5, "d"),
            raw("B", 9, "e"),
        ]);
        let cfg = BaselineConfig { first_occurrence_only: false, ..Default::default() };
        let got = mine(&db, &cfg);
        let mut seqs: Vec<(String, String)> =
            got.sequences.iter().map(|s| (s.patient.clone(), s.sequence.clone())).collect();
        seqs.sort();
        assert_eq!(
            seqs,
            vec![
                ("A".to_string(), "a->b".to_string()),
                ("B".to_string(), "c->d".to_string()),
                ("B".to_string(), "c->e".to_string()),
                ("B".to_string(), "d->e".to_string()),
            ]
        );
    }

    #[test]
    fn matches_tspm_plus_output_modulo_representation() {
        // F1 equivalence check: baseline output == tSPM+ output translated
        // back to strings (same config, no screen).
        //
        // Same-date pairs have implementation-defined orientation (the
        // paper's pseudocode allows either), so the comparison data is
        // de-duplicated to one entry per (patient, date).
        let mut mart = crate::synthea::SyntheaConfig::small().generate();
        let mut seen = std::collections::HashSet::new();
        mart.entries.retain(|e| seen.insert((e.patient_id.clone(), e.date)));
        let base = mine(
            &mart,
            &BaselineConfig { first_occurrence_only: true, ..Default::default() },
        );
        let db = NumericDbMart::encode(&mart);
        let plus = mine_sequences(
            &db,
            &MiningConfig { first_occurrence_only: true, ..Default::default() },
        )
        .unwrap();

        let mut base_set: Vec<(String, String)> = base
            .sequences
            .iter()
            .map(|s| (s.patient.clone(), s.sequence.clone()))
            .collect();
        let mut plus_set: Vec<(String, String)> = plus
            .records
            .iter()
            .map(|r| {
                let (s, e) = crate::dbmart::decode_seq(r.seq);
                (
                    db.lookup.patient_name(r.pid).to_string(),
                    format!("{}->{}", db.lookup.phenx_name(s), db.lookup.phenx_name(e)),
                )
            })
            .collect();
        base_set.sort();
        plus_set.sort();
        assert_eq!(base_set.len(), plus_set.len());
        assert_eq!(base_set, plus_set);
    }

    #[test]
    fn sparsity_screen_thresholds_on_distinct_patients() {
        let mut seqs = vec![
            StringSeq { patient: "p1".into(), sequence: "a->b".into() },
            StringSeq { patient: "p2".into(), sequence: "a->b".into() },
            StringSeq { patient: "p1".into(), sequence: "a->c".into() },
            StringSeq { patient: "p1".into(), sequence: "a->c".into() }, // dup, same patient
        ];
        sparsity_screen(&mut seqs, 2);
        assert!(seqs.iter().all(|s| s.sequence == "a->b"));
        assert_eq!(seqs.len(), 2);
    }

    #[test]
    fn logical_bytes_counts_string_heap() {
        let db = DbMart::new(vec![raw("A", 1, "aaaa"), raw("A", 2, "bbbb")]);
        let got = mine(&db, &BaselineConfig { first_occurrence_only: false, ..Default::default() });
        assert_eq!(got.sequences.len(), 1);
        // at least: struct size + "A" + "aaaa->bbbb"
        assert!(got.logical_bytes >= (std::mem::size_of::<StringSeq>() + 1 + 10) as u64);
    }

    #[test]
    fn first_occurrence_filter_matches_plus_filter() {
        let db = DbMart::new(vec![
            raw("A", 1, "x"),
            raw("A", 2, "x"),
            raw("A", 3, "y"),
        ]);
        let got = mine(&db, &BaselineConfig { first_occurrence_only: true, ..Default::default() });
        assert_eq!(got.sequences.len(), 1);
        assert_eq!(got.sequences[0].sequence, "x->y");
    }
}
