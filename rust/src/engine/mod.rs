//! The unified engine façade — one entry object for the whole tSPM+
//! workflow.
//!
//! The paper's contribution is an end-to-end pipeline (dbmart encoding →
//! transitive-pair mining with durations → sparsity screening →
//! patient×sequence matrix → MSMR), but the expert modules expose it as
//! free functions with per-module configs and error types. [`Engine`] is
//! the composable front door: a fluent builder assembles a validated
//! [`Plan`] (typed stage chain), dispatches the mine stage to one of
//! four interchangeable [`backends`](BackendKind) — chosen explicitly or
//! auto-selected from [`crate::partition`]'s memory prediction plus the
//! resolved worker count — and
//! returns every stage's output plus a [`RunReport`] of per-stage
//! timings and sizes. All failures funnel into the single [`TspmError`].
//!
//! ```no_run
//! use tspm_plus::engine::Engine;
//! use tspm_plus::mining::MiningConfig;
//! use tspm_plus::sparsity::SparsityConfig;
//!
//! let cohort = tspm_plus::synthea::SyntheaConfig::small().generate();
//! let out = Engine::from_raw(&cohort)?
//!     .mine(MiningConfig::default())
//!     .screen(SparsityConfig { min_patients: 5, threads: 0 })
//!     .matrix()
//!     .run()?;
//! println!("{} screened sequences via the {} backend",
//!          out.sequences.len(), out.report.backend);
//! println!("{}", out.report.render());
//! # Ok::<(), tspm_plus::engine::TspmError>(())
//! ```
//!
//! The engine result is **spill-aware**: [`RunOutput::sequences`] is a
//! [`SequenceOutput`] — in-memory for ordinary runs, a durable set of
//! on-disk spill files when the (post-screen) result may not fit the
//! memory budget — with [`SequenceOutput::materialize`] as the explicit
//! escape hatch. See [`backend`] for the residency policy.
//!
//! A spilled mine → screen chain can additionally chain `.index(dir)`:
//! the run then also writes an immutable query artifact
//! ([`crate::query::SeqIndex`], returned via [`RunOutput::index`]) that
//! [`crate::query::QueryService`] serves point/range queries from —
//! the first consumer of the spilled contract that never materialises.
//! From there the ML stages ride the same contract: `.matrix()` (and
//! `.msmr(k)`) after `.index(dir)` build the patient×sequence CSR
//! **straight from the artifact**
//! ([`crate::matrix::SeqMatrix::from_index`]), so the full
//! `mine → screen → index → matrix → msmr` pipeline completes under a
//! memory budget far below the record multiset, with CSR output
//! bit-identical to the in-memory path.
//!
//! Runs can also be **targeted**: [`Engine::target`] pushes a
//! [`TargetSpec`] predicate (endpoint codes, duration band) down into
//! every backend's mining inner loop and the screens, producing — at a
//! fraction of the cost — output byte-identical to mining everything
//! and filtering afterwards. An `.index(dir)`/`.ingest(dir)` sink
//! records the spec in its manifest so artifacts answer "what was this
//! index targeted to".
//!
//! The original free functions remain available as the "expert layer"
//! (see the crate docs); the façade is the supported composition seam —
//! future scaling work (async backends, caching, sharded serving) plugs
//! in behind [`BackendKind`] without touching callers.

pub mod backend;
pub mod error;
pub mod plan;

pub use backend::{
    auto_select, execute_spilled, forecast, resolve, resolve_output, BackendChoice,
    BackendKind, MiningForecast, OutputChoice, OutputKind, DEFAULT_MEMORY_BUDGET_BYTES,
    HARD_ELEMENT_CAP,
};
pub use error::TspmError;
pub use plan::{Plan, Stage};
pub use crate::target::{TargetPos, TargetSpec};

use crate::config::RunConfig;
use crate::dbmart::{DbMart, NumericDbMart};
use crate::ingest::SegmentSet;
use crate::matrix::SeqMatrix;
use crate::metrics::{fmt_bytes, fmt_duration, MemTracker};
use crate::mining::{MineContext, MiningConfig, SeqRecord, SequenceSet};
use crate::msmr::{self, MsmrConfig, Selection};
use crate::obs::{self, names, Span, Tracer};
use crate::partition;
use crate::query::{self, SeqIndex};
use crate::runtime::ArtifactSet;
use crate::seqstore::SeqFileSet;
use crate::sparsity::{self, ScreenStats, SparsityConfig};
use std::path::PathBuf;
use std::time::Duration;

/// Timing/size record for one executed stage.
#[derive(Clone, Debug)]
pub struct StageReport {
    /// Stage name ([`Stage::name`]).
    pub stage: String,
    pub elapsed: Duration,
    /// Records flowing out of the stage (matrix: non-zeros; msmr:
    /// selected features).
    pub records_out: u64,
    /// Logical bytes of the stage output.
    pub bytes_out: u64,
}

/// What a run did: backend, result residency, per-stage breakdown, peak
/// logical memory.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// The backend the mine stage actually executed on.
    pub backend: BackendKind,
    /// Where the result landed (the resolution of [`OutputChoice`]).
    pub output: OutputKind,
    /// Output-size forecast that drove backend and residency selection.
    pub forecast: MiningForecast,
    pub stages: Vec<StageReport>,
    /// High-water mark of the engine's logical allocations
    /// ([`MemTracker`] semantics, not RSS).
    pub peak_logical_bytes: u64,
}

impl RunReport {
    /// Total wall time across stages.
    pub fn total(&self) -> Duration {
        self.stages.iter().map(|s| s.elapsed).sum()
    }

    /// Multi-line human-readable breakdown.
    pub fn render(&self) -> String {
        let mut out = format!(
            "backend: {}  output: {}  (forecast {} sequences, {})\n",
            self.backend,
            self.output,
            self.forecast.total_sequences,
            fmt_bytes(self.forecast.total_bytes)
        );
        let width =
            self.stages.iter().map(|s| s.stage.len()).max().unwrap_or(5).max(5);
        for s in &self.stages {
            out.push_str(&format!(
                "  {:<width$}  {}  {:>12} records  {:>10}\n",
                s.stage,
                fmt_duration(s.elapsed),
                s.records_out,
                fmt_bytes(s.bytes_out),
                width = width
            ));
        }
        out.push_str(&format!(
            "  {:<width$}  {}  peak logical {}\n",
            "TOTAL",
            fmt_duration(self.total()),
            fmt_bytes(self.peak_logical_bytes),
            width = width
        ));
        out
    }
}

/// The spill-aware sequence result of a run: either one in-memory
/// [`SequenceSet`] or a durable on-disk [`SeqFileSet`] (the engine's
/// contract for outputs too large to materialise). Both variants answer
/// the size/shape questions; [`SequenceOutput::materialize`] is the
/// explicit escape hatch back to memory when the caller knows the set
/// fits. Spilled files are *kept* on disk — they are the durable result
/// a caching or serving layer can consume — so callers that want them
/// gone must call [`SeqFileSet::remove`] themselves.
#[derive(Clone, Debug)]
pub enum SequenceOutput {
    /// The records are resident ([`OutputKind::InMemory`]).
    InMemory(SequenceSet),
    /// The records live in spill files ([`OutputKind::Spilled`]),
    /// sorted by `(seq, pid, duration)` when a screen stage produced
    /// them.
    Spilled(SeqFileSet),
}

impl SequenceOutput {
    /// The residency this output has.
    pub fn kind(&self) -> OutputKind {
        match self {
            SequenceOutput::InMemory(_) => OutputKind::InMemory,
            SequenceOutput::Spilled(_) => OutputKind::Spilled,
        }
    }

    /// Number of records (resident or on disk).
    pub fn len(&self) -> usize {
        match self {
            SequenceOutput::InMemory(set) => set.len(),
            SequenceOutput::Spilled(files) => files.total_records as usize,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Logical payload size (records × 16 bytes), wherever they live.
    pub fn byte_size(&self) -> u64 {
        match self {
            SequenceOutput::InMemory(set) => set.byte_size(),
            SequenceOutput::Spilled(files) => files.logical_bytes(),
        }
    }

    /// Bytes actually resident in memory: the full payload when
    /// in-memory, zero when spilled.
    pub fn resident_bytes(&self) -> u64 {
        match self {
            SequenceOutput::InMemory(set) => set.byte_size(),
            SequenceOutput::Spilled(_) => 0,
        }
    }

    /// Number of patients in the source dbmart (for matrix shapes).
    pub fn num_patients(&self) -> u32 {
        match self {
            SequenceOutput::InMemory(set) => set.num_patients,
            SequenceOutput::Spilled(files) => files.num_patients,
        }
    }

    /// Number of distinct phenX codes in the source dbmart.
    pub fn num_phenx(&self) -> u32 {
        match self {
            SequenceOutput::InMemory(set) => set.num_phenx,
            SequenceOutput::Spilled(files) => files.num_phenx,
        }
    }

    /// The resident set, when there is one.
    pub fn as_in_memory(&self) -> Option<&SequenceSet> {
        match self {
            SequenceOutput::InMemory(set) => Some(set),
            SequenceOutput::Spilled(_) => None,
        }
    }

    fn as_in_memory_mut(&mut self) -> Option<&mut SequenceSet> {
        match self {
            SequenceOutput::InMemory(set) => Some(set),
            SequenceOutput::Spilled(_) => None,
        }
    }

    /// The spill files, when the result is on disk.
    pub fn as_spilled(&self) -> Option<&SeqFileSet> {
        match self {
            SequenceOutput::Spilled(files) => Some(files),
            SequenceOutput::InMemory(_) => None,
        }
    }

    /// The explicit escape hatch: load everything into one
    /// [`SequenceSet`]. A no-op for in-memory output; for spilled output
    /// this reads every spill file (the files stay on disk). Only call
    /// this when the caller knows the set fits — it is exactly the
    /// full materialization the spilled contract exists to avoid.
    pub fn materialize(self) -> Result<SequenceSet, TspmError> {
        match self {
            SequenceOutput::InMemory(set) => Ok(set),
            SequenceOutput::Spilled(files) => {
                let records = files.read_all()?;
                Ok(SequenceSet {
                    records,
                    num_patients: files.num_patients,
                    num_phenx: files.num_phenx,
                })
            }
        }
    }
}

/// Everything a run produced. Stages that were not in the plan leave
/// their slot `None`. The encoded dbmart travels back out so callers can
/// translate numeric ids through its lookup tables.
pub struct RunOutput {
    /// The (possibly screened) mined sequences — in memory or spilled
    /// ([`SequenceOutput`]).
    pub sequences: SequenceOutput,
    /// The encoded dbmart the run consumed (lookup tables included).
    pub db: NumericDbMart,
    pub screen_stats: Option<ScreenStats>,
    pub duration_screen_stats: Option<ScreenStats>,
    pub matrix: Option<SeqMatrix>,
    pub selection: Option<Selection>,
    /// The query-index artifact, when the plan chained `.index(dir)`
    /// (already on disk; open it with [`crate::query::QueryService`]) —
    /// or the freshly committed segment when it chained `.ingest(dir)`
    /// (the two sinks are mutually exclusive, so one slot serves both).
    pub index: Option<SeqIndex>,
    pub report: RunReport,
}

/// Fluent pipeline builder over one encoded dbmart. See the module docs
/// for the canonical chain; every method returns `self` so plans read as
/// one expression. Nothing executes until [`Engine::run`].
pub struct Engine {
    db: NumericDbMart,
    stages: Vec<Stage>,
    backend: BackendChoice,
    memory_budget_bytes: Option<u64>,
    output: OutputChoice,
    out_dir: Option<PathBuf>,
    labels: Option<Vec<f32>>,
    tracer: Option<Tracer>,
    target: Option<TargetSpec>,
}

impl Engine {
    /// Start a pipeline over an already-encoded dbmart.
    pub fn from_dbmart(db: NumericDbMart) -> Engine {
        Engine {
            db,
            stages: Vec::new(),
            backend: BackendChoice::Auto,
            memory_budget_bytes: None,
            output: OutputChoice::Auto,
            out_dir: None,
            labels: None,
            tracer: None,
            target: None,
        }
    }

    /// Start a pipeline over a raw dbmart (encodes it first; surfaces
    /// vocabulary overflow as [`TspmError::Encode`] instead of
    /// panicking).
    pub fn from_raw(raw: &DbMart) -> Result<Engine, TspmError> {
        Ok(Engine::from_dbmart(NumericDbMart::try_encode(raw)?))
    }

    /// Build the canonical stage chain from a [`RunConfig`]: mine with
    /// the config's mining settings, screen when `sparsity_screen` is
    /// set, backend per `backend`/`mode`, memory budget from
    /// `max_elements_per_chunk`.
    pub fn from_config(db: NumericDbMart, cfg: &RunConfig) -> Result<Engine, TspmError> {
        cfg.validate()?;
        // Target codes in a RunConfig are *names*; resolve them through
        // the cohort's interning table before the db moves into the
        // builder. Unknown names fail here, with the name in the error —
        // the numeric vocab check in plan() could only report an id.
        let target = cfg
            .target_spec_with(|name| db.lookup.phenx_id(name))
            .map_err(TspmError::Plan)?;
        // No explicit out_dir: run_with already derives
        // `<work_dir>/engine_out` from the mining config's work_dir,
        // which from_config sets from cfg.work_dir.
        let mut engine = Engine::from_dbmart(db)
            .backend(cfg.backend_choice()?)
            .output(cfg.output_choice()?)
            .memory_budget(
                cfg.max_elements_per_chunk
                    .saturating_mul(std::mem::size_of::<SeqRecord>() as u64),
            )
            .mine(cfg.mining_config());
        if let Some(spec) = target {
            engine = engine.target(spec);
        }
        if let Some(sc) = cfg.sparsity_config() {
            engine = engine.screen(sc);
        }
        Ok(engine)
    }

    // --- fluent stage chain ------------------------------------------------

    /// Append the mine stage (required, first).
    pub fn mine(mut self, cfg: MiningConfig) -> Engine {
        self.stages.push(Stage::Mine(cfg));
        self
    }

    /// Append the distinct-patient sparsity screen.
    pub fn screen(mut self, cfg: SparsityConfig) -> Engine {
        self.stages.push(Stage::Screen(cfg));
        self
    }

    /// Append the duration-bucket diversity screen.
    pub fn screen_durations(mut self, bucket_days: u32, min_distinct_durations: u32) -> Engine {
        self.stages.push(Stage::DurationScreen { bucket_days, min_distinct_durations });
        self
    }

    /// Append the patient×sequence matrix stage.
    pub fn matrix(mut self) -> Engine {
        self.stages.push(Stage::Matrix { duration_bucket_days: None });
        self
    }

    /// Append the duration-aware matrix stage (each column is a
    /// `(sequence, duration-bucket)` pair — the paper's new dimension).
    pub fn matrix_with_durations(mut self, bucket_days: u32) -> Engine {
        self.stages.push(Stage::Matrix { duration_bucket_days: Some(bucket_days) });
        self
    }

    /// Append MSMR selection of the top-`k` features (needs
    /// [`Engine::matrix`] before it and [`Engine::labels`]).
    pub fn msmr(self, top_k: usize) -> Engine {
        self.msmr_with(MsmrConfig { top_k, ..Default::default() })
    }

    /// [`Engine::msmr`] with full control of the selection config.
    pub fn msmr_with(mut self, cfg: MsmrConfig) -> Engine {
        self.stages.push(Stage::Msmr(cfg));
        self
    }

    /// Append the index stage: turn the spilled screen output into an
    /// immutable query artifact under `out_dir` ([`crate::query`]).
    /// Requires a screen stage before it and forces spilled residency;
    /// `.matrix()` / `.msmr(k)` may follow — they then build straight
    /// from the artifact instead of materialising the records.
    pub fn index(self, out_dir: PathBuf) -> Engine {
        self.index_with(out_dir, query::DEFAULT_BLOCK_RECORDS)
    }

    /// [`Engine::index`] with an explicit block size (records per index
    /// block — the query layer's unit of IO and of resident memory).
    pub fn index_with(mut self, out_dir: PathBuf, block_records: usize) -> Engine {
        self.stages.push(Stage::Index { out_dir, block_records });
        self
    }

    /// Append the ingest stage: commit the spilled screen output as a
    /// new immutable **segment** of the segment set at `set_dir`
    /// ([`crate::ingest::SegmentSet`]), creating the set on first use.
    /// The delta-cohort counterpart of [`Engine::index`]: instead of a
    /// standalone artifact the run appends to a growing set that
    /// [`crate::ingest::MergedView`] queries as one. Requires a screen
    /// stage before it, forces spilled residency, and is terminal. The
    /// segments of one set must hold **disjoint patients** — see the
    /// [`crate::ingest`] correctness contract.
    pub fn ingest(self, set_dir: PathBuf) -> Engine {
        self.ingest_with(set_dir, query::DEFAULT_BLOCK_RECORDS)
    }

    /// [`Engine::ingest`] with an explicit block size for the new
    /// segment's index.
    pub fn ingest_with(mut self, set_dir: PathBuf, block_records: usize) -> Engine {
        self.stages.push(Stage::Ingest { set_dir, block_records });
        self
    }

    // --- execution knobs ---------------------------------------------------

    /// Restrict the mine to sequences matching `spec` ([`TargetSpec`]):
    /// endpoint-code membership and/or a duration band. The predicate is
    /// **pushed down** into every backend's per-patient inner loop —
    /// non-matching pairs are skipped before duration encoding — and the
    /// screen then counts support within the targeted multiset, so the
    /// run costs O(matching pairs), not O(all pairs). Output is
    /// byte-identical to mining everything and filtering afterwards
    /// (`rust/tests/conformance.rs` proves it per backend);
    /// [`TargetSpec::all`] is byte-identical to not calling this at all.
    pub fn target(mut self, spec: TargetSpec) -> Engine {
        self.target = Some(spec);
        self
    }

    /// Per-patient phenotype labels (`labels[pid] ∈ {0,1}`) for MSMR.
    pub fn labels(mut self, labels: Vec<f32>) -> Engine {
        self.labels = Some(labels);
        self
    }

    /// Pin the execution backend (default: [`BackendChoice::Auto`]).
    pub fn backend(mut self, choice: BackendChoice) -> Engine {
        self.backend = choice;
        self
    }

    /// Memory budget in bytes for auto-selection and streaming chunk
    /// sizing (default: [`DEFAULT_MEMORY_BUDGET_BYTES`]).
    pub fn memory_budget(mut self, bytes: u64) -> Engine {
        self.memory_budget_bytes = Some(bytes);
        self
    }

    /// Pin the result residency (default: [`OutputChoice::Auto`] — spill
    /// when the post-screen forecast exceeds the budget on an
    /// out-of-core backend). [`OutputChoice::Spilled`] is only valid for
    /// mine → screen plans.
    pub fn output(mut self, choice: OutputChoice) -> Engine {
        self.output = choice;
        self
    }

    /// Directory for spilled result files (default: `engine_out` under
    /// the mining `work_dir`).
    pub fn out_dir(mut self, dir: PathBuf) -> Engine {
        self.out_dir = Some(dir);
        self
    }

    /// Attach a tracer: every stage runs under a child span of one
    /// `engine.run` root, and [`RunReport`] stage timings are read from
    /// those spans (default: [`Tracer::from_env`], so `TSPM_TRACE=1`
    /// traces any run without code changes). Tracing never touches the
    /// data path — outputs are byte-identical with it on or off.
    pub fn tracer(mut self, tracer: Tracer) -> Engine {
        self.tracer = Some(tracer);
        self
    }

    // --- plan / run --------------------------------------------------------

    /// Assemble and validate the plan without executing it.
    pub fn plan(&self) -> Result<Plan, TspmError> {
        let plan = Plan {
            stages: self.stages.clone(),
            backend: self.backend,
            memory_budget_bytes: self.memory_budget_bytes,
            output: self.output,
            out_dir: self.out_dir.clone(),
            target: self.target.clone(),
        };
        plan.validate()?;
        // The structural spec checks ran inside plan.validate (via
        // MineContext); only the engine knows the cohort, so the vocab
        // membership check lives here.
        if let Some(t) = &self.target {
            t.validate_vocab(self.db.num_phenx() as u32).map_err(TspmError::Plan)?;
        }
        if plan.wants_msmr() {
            match &self.labels {
                None => {
                    return Err(TspmError::Plan(
                        "msmr needs per-patient labels — call .labels(...) before .run()"
                            .into(),
                    ))
                }
                Some(l) if l.len() != self.db.num_patients() => {
                    return Err(TspmError::Plan(format!(
                        "labels length {} does not match the cohort's {} patients",
                        l.len(),
                        self.db.num_patients()
                    )))
                }
                _ => {}
            }
        }
        Ok(plan)
    }

    /// Forecast the mine stage's output without running anything.
    pub fn forecast(&self) -> Result<MiningForecast, TspmError> {
        let plan = self.plan()?;
        let cfg = plan.mining_config().expect("validated plan has a mine stage");
        Ok(backend::forecast(&self.db, cfg))
    }

    /// Validate, resolve the backend, and execute the plan.
    pub fn run(self) -> Result<RunOutput, TspmError> {
        self.run_with(None)
    }

    /// [`Engine::run`] with PJRT artifacts for the analytics stages
    /// (MSMR contractions); `None` uses the pure-Rust paths.
    pub fn run_with(self, artifacts: Option<&ArtifactSet>) -> Result<RunOutput, TspmError> {
        let plan = self.plan()?;
        let Engine { db, labels, memory_budget_bytes, tracer, .. } = self;
        let tracer = tracer.unwrap_or_else(Tracer::from_env);

        let mining_cfg = plan
            .mining_config()
            .expect("validated plan has a mine stage")
            .clone();
        let budget = memory_budget_bytes.unwrap_or(DEFAULT_MEMORY_BUDGET_BYTES);
        let fc = backend::forecast(&db, &mining_cfg);
        let threads = mining_cfg.worker_threads();
        let kind = backend::resolve(plan.backend, &fc, budget, threads);
        let chunk_cap = partition::cap_from_memory(budget, HARD_ELEMENT_CAP);
        // Residency: chains with in-memory consumers (duration screen,
        // matrix, MSMR) always materialise — Plan::validate already
        // rejected an explicit Spilled there, so only Auto lands here.
        // An index or ingest stage forces spilled output whatever the
        // budget: both builders consume the screen's spill files
        // directly.
        let out_kind = if !plan.spill_capable() {
            OutputKind::InMemory
        } else if plan.index_stage().is_some() || plan.ingest_stage().is_some() {
            OutputKind::Spilled
        } else {
            backend::resolve_output(plan.output, kind, &fc, budget)
        };
        let out_dir = plan
            .out_dir
            .clone()
            .unwrap_or_else(|| mining_cfg.work_dir.join("engine_out"));
        let mine_dir = out_dir.join("mine");

        let tracker = MemTracker::new();
        let mut stages: Vec<StageReport> = Vec::new();

        // One root span covers the run; each stage runs under a child
        // span whose measured duration *is* the RunReport timing (the
        // old PhaseTimer is gone — spans are the single clock). The
        // ambient-context guard lets instrumented callees (cache, block
        // reads) link their spans into this trace without new
        // parameters.
        // The validated target travels as part of the MineContext: the
        // backends push it into the per-patient inner loop, the screens
        // re-apply it (a proven no-op on an already-targeted stream),
        // and the index manifest records it.
        let target = plan.target.as_ref().filter(|t| !t.is_all());
        let mine_ctx = MineContext::with_target(&mining_cfg, plan.target.as_ref());

        let mut run_span = tracer.span("engine.run");
        run_span.attr("backend", kind.to_string());
        run_span.attr("output", out_kind.to_string());
        run_span.attr("forecast_sequences", fc.total_sequences);
        if let Some(t) = target {
            run_span.attr("target", t.render());
        }
        let ctx = obs::trace::push_current(&run_span);

        // 1. Mine, on the resolved backend, into the resolved residency.
        let (mine_res, mine_elapsed) =
            observed_stage(&run_span, "engine.mine", &tracker, || -> Result<SequenceOutput, TspmError> {
                match out_kind {
                    OutputKind::InMemory => Ok(SequenceOutput::InMemory(backend::execute(
                        kind,
                        &db,
                        mine_ctx,
                        chunk_cap,
                        &tracker,
                    )?)),
                    OutputKind::Spilled => {
                        Ok(SequenceOutput::Spilled(backend::execute_spilled(
                            kind,
                            &db,
                            mine_ctx,
                            chunk_cap,
                            &mine_dir,
                            &tracker,
                        )?))
                    }
                }
            });
        let mut output: SequenceOutput = mine_res?;
        stages.push(StageReport {
            stage: "mine".into(),
            elapsed: mine_elapsed,
            records_out: output.len() as u64,
            bytes_out: output.byte_size(),
        });

        // 2. Sparsity screen — one stage, two residencies: the in-place
        // sort+compact for resident records, the external merge
        // (`sparsity::screen_spilled`) over spill files.
        let mut screen_stats = None;
        if let Some(sc) = plan.screen_config() {
            let (stats_res, screen_elapsed) =
                observed_stage(&run_span, "engine.screen", &tracker, || -> Result<ScreenStats, TspmError> {
                    match &mut output {
                        SequenceOutput::InMemory(set) => {
                            Ok(sparsity::screen_with(&mut set.records, &sc, target))
                        }
                        SequenceOutput::Spilled(files) => {
                            let spill_cfg = sparsity::SpillScreenConfig {
                                min_patients: sc.min_patients,
                                threads: sc.threads,
                                buffer_bytes: screen_buffer_bytes(budget),
                                out_dir: out_dir.clone(),
                            };
                            let (survivors, stats) = sparsity::screen_spilled_with(
                                files,
                                &spill_cfg,
                                target,
                                Some(&tracker),
                            )?;
                            // The mined intermediates are consumed; the
                            // survivor file is the durable result.
                            let _ = files.remove();
                            let _ = std::fs::remove_dir(&mine_dir);
                            *files = survivors;
                            Ok(stats)
                        }
                    }
                });
            let stats: ScreenStats = stats_res?;
            stages.push(StageReport {
                stage: "screen".into(),
                elapsed: screen_elapsed,
                records_out: stats.records_after,
                bytes_out: output.byte_size(),
            });
            screen_stats = Some(stats);
        }

        // 2b. Index: stream the sorted spilled screen output once into
        // the immutable query artifact (mine → screen → index chains
        // only; validated above).
        let mut index = None;
        if let Some((dir, block_records)) = plan.index_stage() {
            let files = output
                .as_spilled()
                .expect("validated: index implies spilled output")
                .clone();
            let dir = dir.to_path_buf();
            let (built_res, index_elapsed) =
                observed_stage(&run_span, "engine.index", &tracker, || -> Result<SeqIndex, TspmError> {
                    Ok(query::index::build(
                        &files,
                        &dir,
                        &query::IndexConfig {
                            block_records,
                            target: target.cloned(),
                            ..Default::default()
                        },
                        Some(&tracker),
                    )?)
                });
            let built: SeqIndex = built_res?;
            stages.push(StageReport {
                stage: "index".into(),
                elapsed: index_elapsed,
                records_out: built.total_records,
                bytes_out: built.artifact_bytes,
            });
            index = Some(built);
        }

        // 2c. Ingest: commit the sorted spilled screen output as a new
        // segment of the set (mine → screen → ingest chains only). The
        // built segment rides the index slot — the two sinks are
        // mutually exclusive, enforced by Plan::validate.
        if let Some((set_dir, block_records)) = plan.ingest_stage() {
            let files = output
                .as_spilled()
                .expect("validated: ingest implies spilled output")
                .clone();
            let set_dir = set_dir.to_path_buf();
            let (built_res, ingest_elapsed) =
                observed_stage(&run_span, "engine.ingest", &tracker, || -> Result<SeqIndex, TspmError> {
                    let mut set = SegmentSet::open_or_init(&set_dir)?;
                    Ok(set.add_segment(
                        &files,
                        &query::IndexConfig {
                            block_records,
                            target: target.cloned(),
                            ..Default::default()
                        },
                        Some(&tracker),
                    )?)
                });
            let built: SeqIndex = built_res?;
            stages.push(StageReport {
                stage: "ingest".into(),
                elapsed: ingest_elapsed,
                records_out: built.total_records,
                bytes_out: built.artifact_bytes,
            });
            index = Some(built);
        }

        // 3. Duration-diversity screen (in-memory chains only).
        let mut duration_screen_stats = None;
        if let Some((bucket, min_distinct)) = plan.duration_screen() {
            let set = output
                .as_in_memory_mut()
                .expect("validated: duration_screen implies in-memory output");
            let (stats, ds_elapsed) =
                observed_stage(&run_span, "engine.duration_screen", &tracker, || {
                    sparsity::screen_by_duration(&mut set.records, bucket, min_distinct)
                });
            let bytes = set.byte_size();
            stages.push(StageReport {
                stage: "duration_screen".into(),
                elapsed: ds_elapsed,
                records_out: stats.records_after,
                bytes_out: bytes,
            });
            duration_screen_stats = Some(stats);
        }

        // 4. Patient×sequence matrix. In-memory chains build from the
        // resident records; spilled chains stream the CSR straight from
        // the index artifact — the multiset is never materialised.
        let mut matrix = None;
        if let Some(bucket) = plan.matrix_stage() {
            let (m_res, matrix_elapsed) = observed_stage(
                &run_span,
                "engine.matrix",
                &tracker,
                || -> Result<SeqMatrix, TspmError> {
                    match &output {
                    SequenceOutput::InMemory(sequences) => Ok(match bucket {
                        Some(b) => SeqMatrix::build_with_durations(
                            &sequences.records,
                            sequences.num_patients,
                            b,
                        )?,
                        None => {
                            SeqMatrix::build(&sequences.records, sequences.num_patients)?
                        }
                    }),
                        SequenceOutput::Spilled(files) => {
                            let idx = index
                                .as_ref()
                                .expect("validated: spilled matrix implies an index stage");
                            Ok(SeqMatrix::from_index_tracked(
                                idx,
                                files.num_patients,
                                bucket,
                                Some(&tracker),
                            )?)
                        }
                    }
                },
            );
            let m = m_res?;
            let bytes = (m.nnz() * std::mem::size_of::<u32>()
                + m.row_ptr.len() * std::mem::size_of::<usize>()
                + m.seq_ids.len() * std::mem::size_of::<u64>()) as u64;
            tracker.add(bytes);
            stages.push(StageReport {
                stage: "matrix".into(),
                elapsed: matrix_elapsed,
                records_out: m.nnz() as u64,
                bytes_out: bytes,
            });
            matrix = Some(m);
        }

        // 5. MSMR feature selection.
        let mut selection = None;
        if let Some(mcfg) = plan.msmr_config() {
            let m = matrix.as_ref().expect("validated: msmr implies matrix");
            let l = labels.as_ref().expect("validated: msmr implies labels");
            let (sel_res, msmr_elapsed) = observed_stage(&run_span, "engine.msmr", &tracker, || {
                msmr::select(m, l, &mcfg, artifacts)
            });
            let sel = sel_res?;
            stages.push(StageReport {
                stage: "msmr".into(),
                elapsed: msmr_elapsed,
                records_out: sel.columns.len() as u64,
                bytes_out: (sel.columns.len()
                    * (std::mem::size_of::<u32>() + std::mem::size_of::<f64>()))
                    as u64,
            });
            selection = Some(sel);
        }

        drop(ctx);
        run_span.attr("peak_logical_bytes", tracker.peak());
        run_span.finish();

        Ok(RunOutput {
            sequences: output,
            db,
            screen_stats,
            duration_screen_stats,
            matrix,
            selection,
            index,
            report: RunReport {
                backend: kind,
                output: out_kind,
                forecast: fc,
                stages,
                peak_logical_bytes: tracker.peak(),
            },
        })
    }
}

/// Buffer bound handed to [`sparsity::screen_spilled`]: a fraction of
/// the run's memory budget (several buffers of this size coexist during
/// the merge), floored so degenerate budgets still make progress and
/// capped so huge budgets don't allocate absurd buffers.
fn screen_buffer_bytes(budget: u64) -> u64 {
    (budget / 8).clamp(1 << 16, 1 << 28)
}

/// Stage-duration histogram edges in microseconds: 1ms … 60s.
const STAGE_BUCKETS_US: &[u64] =
    &[1_000, 10_000, 100_000, 1_000_000, 10_000_000, 60_000_000];

/// Run one pipeline stage under a child span of the run root. The
/// span's measured duration is returned (and becomes the
/// [`StageReport`] timing); the global registry gets the same duration
/// as a histogram sample plus the tracker's live/peak gauges at the
/// stage boundary.
fn observed_stage<R>(
    parent: &Span,
    name: &'static str,
    tracker: &MemTracker,
    f: impl FnOnce() -> R,
) -> (R, Duration) {
    let span = parent.child(name);
    let out = f();
    let elapsed = span.finish();
    let reg = obs::metrics::global();
    reg.histogram(names::ENGINE_STAGE_DURATION_US, STAGE_BUCKETS_US)
        .observe(elapsed.as_micros() as u64);
    reg.gauge(names::MEM_LIVE_BYTES).set(tracker.live());
    reg.gauge(names::MEM_PEAK_BYTES).set(tracker.peak());
    (out, elapsed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synthea::SyntheaConfig;

    fn small_db() -> NumericDbMart {
        NumericDbMart::encode(&SyntheaConfig::small().generate())
    }

    fn sorted(mut records: Vec<crate::mining::SeqRecord>) -> Vec<crate::mining::SeqRecord> {
        records.sort_unstable_by_key(|r| (r.seq, r.pid, r.duration));
        records
    }

    #[test]
    fn builder_rejects_empty_and_ill_ordered_chains() {
        let db = small_db();
        assert!(matches!(
            Engine::from_dbmart(db.clone()).plan().unwrap_err(),
            TspmError::Plan(_)
        ));
        assert!(matches!(
            Engine::from_dbmart(db.clone())
                .screen(SparsityConfig::default())
                .plan()
                .unwrap_err(),
            TspmError::Plan(_)
        ));
        let err = Engine::from_dbmart(db)
            .mine(MiningConfig::default())
            .matrix()
            .screen(SparsityConfig::default())
            .plan()
            .unwrap_err();
        assert!(err.to_string().contains("out of order"), "got {err}");
    }

    #[test]
    fn msmr_without_labels_is_rejected_before_any_work() {
        let err = Engine::from_dbmart(small_db())
            .mine(MiningConfig::default())
            .matrix()
            .msmr(10)
            .plan()
            .unwrap_err();
        assert!(err.to_string().contains("labels"), "got {err}");

        let err = Engine::from_dbmart(small_db())
            .mine(MiningConfig::default())
            .matrix()
            .msmr(10)
            .labels(vec![0.0; 3]) // wrong length
            .plan()
            .unwrap_err();
        assert!(err.to_string().contains("labels length"), "got {err}");
    }

    /// The golden test: all four backends produce the identical screened
    /// sequence set on the small Synthea cohort — whether the result
    /// stayed resident or spilled (the tiny budget auto-spills the
    /// file-backed and streaming runs; `materialize()` must reproduce
    /// the in-memory bytes exactly).
    #[test]
    fn golden_backends_agree_on_screened_sets() {
        let db = small_db();
        let sc = SparsityConfig { min_patients: 5, threads: 2 };
        let base_dir = std::env::temp_dir().join("tspm_engine_golden");
        let _ = std::fs::remove_dir_all(&base_dir);

        let mut outputs = Vec::new();
        for (i, choice) in [
            BackendChoice::InMemory,
            BackendChoice::Sharded,
            BackendChoice::FileBacked,
            BackendChoice::Streaming,
        ]
        .into_iter()
        .enumerate()
        {
            let mine_cfg =
                MiningConfig { work_dir: base_dir.join(format!("b{i}")), ..Default::default() };
            let out = Engine::from_dbmart(db.clone())
                .mine(mine_cfg)
                .screen(sc)
                .backend(choice)
                // Small budget → the streaming run really partitions and
                // the out-of-core backends auto-spill their results.
                .memory_budget(50_000 * 16)
                .run()
                .unwrap();
            outputs.push(out);
        }
        assert_eq!(outputs[0].report.output, OutputKind::InMemory);
        assert!(
            outputs.iter().any(|o| o.report.output == OutputKind::Spilled),
            "the tiny budget must spill at least one out-of-core backend"
        );
        let golden =
            sorted(outputs[0].sequences.clone().materialize().unwrap().records);
        let golden_stats = outputs[0].screen_stats.unwrap();
        assert!(golden_stats.records_after > 0, "screen must keep something");
        for out in &outputs[1..] {
            assert_eq!(
                sorted(out.sequences.clone().materialize().unwrap().records),
                golden,
                "backend {} ({} output) diverged",
                out.report.backend,
                out.report.output
            );
            assert_eq!(out.screen_stats.unwrap(), golden_stats);
        }
        // And the façade matches the expert layer exactly.
        let expert_cfg =
            MiningConfig { work_dir: base_dir.join("expert"), ..Default::default() };
        let mut expert = crate::mining::mine_sequences(&db, &expert_cfg).unwrap().records;
        sparsity::screen(&mut expert, &sc);
        assert_eq!(sorted(expert), golden);
    }

    /// Explicit spilled output works on every backend, and the result is
    /// a durable on-disk file set that survives the run.
    #[test]
    fn explicit_spilled_output_round_trips_on_every_backend() {
        let db = small_db();
        let base_dir = std::env::temp_dir().join("tspm_engine_spill_explicit");
        let _ = std::fs::remove_dir_all(&base_dir);
        let golden = {
            let out = Engine::from_dbmart(db.clone())
                .mine(MiningConfig::default())
                .backend(BackendChoice::InMemory)
                .run()
                .unwrap();
            sorted(out.sequences.materialize().unwrap().records)
        };
        for (i, choice) in [
            BackendChoice::InMemory,
            BackendChoice::Sharded,
            BackendChoice::FileBacked,
            BackendChoice::Streaming,
        ]
        .into_iter()
        .enumerate()
        {
            let out = Engine::from_dbmart(db.clone())
                .mine(MiningConfig {
                    work_dir: base_dir.join(format!("w{i}")),
                    ..Default::default()
                })
                .backend(choice)
                .output(OutputChoice::Spilled)
                .out_dir(base_dir.join(format!("out{i}")))
                .run()
                .unwrap();
            assert_eq!(out.report.output, OutputKind::Spilled);
            let files = out.sequences.as_spilled().unwrap().clone();
            assert!(files.files.iter().all(|f| f.exists()), "spill files must persist");
            assert_eq!(out.sequences.len(), golden.len());
            assert_eq!(out.sequences.resident_bytes(), 0);
            assert_eq!(
                sorted(out.sequences.materialize().unwrap().records),
                golden,
                "backend {choice:?}"
            );
            files.remove().unwrap();
        }
    }

    /// `.index(dir)` as a plan stage: the run leaves a spilled screened
    /// result *and* a query artifact whose answers match the
    /// materialized records exactly.
    #[test]
    fn index_stage_builds_a_queryable_artifact() {
        let db = small_db();
        let base = std::env::temp_dir().join("tspm_engine_index_stage");
        let _ = std::fs::remove_dir_all(&base);
        let out = Engine::from_dbmart(db.clone())
            .mine(MiningConfig { work_dir: base.join("work"), ..Default::default() })
            .screen(SparsityConfig { min_patients: 5, threads: 2 })
            .out_dir(base.join("run"))
            .index(base.join("idx"))
            .run()
            .unwrap();
        assert_eq!(out.report.output, OutputKind::Spilled, "index forces spilled output");
        let names: Vec<&str> = out.report.stages.iter().map(|s| s.stage.as_str()).collect();
        assert_eq!(names, ["mine", "screen", "index"]);
        let built = out.index.as_ref().expect("index stage ran");
        assert_eq!(built.total_records, out.sequences.len() as u64);
        assert_eq!(built.num_patients, out.sequences.num_patients());

        // The artifact answers exactly what the spilled result holds.
        let all = out.sequences.clone().materialize().unwrap().records;
        let svc = crate::query::QueryService::open(&base.join("idx")).unwrap();
        let mut seqs: Vec<u64> = all.iter().map(|r| r.seq).collect();
        seqs.dedup();
        assert_eq!(svc.index().distinct_seqs(), seqs.len() as u64);
        for &s in seqs.iter().take(10) {
            let expect: Vec<crate::mining::SeqRecord> =
                all.iter().copied().filter(|r| r.seq == s).collect();
            assert_eq!(*svc.by_sequence(s).unwrap(), expect, "seq {s}");
        }

        // Plans that cannot feed the index are rejected up front.
        let err = Engine::from_dbmart(db.clone())
            .mine(MiningConfig::default())
            .index(base.join("idx2"))
            .plan()
            .unwrap_err();
        assert!(err.to_string().contains("screen"), "got {err}");
        let err = Engine::from_dbmart(db)
            .mine(MiningConfig::default())
            .screen(SparsityConfig { min_patients: 5, threads: 0 })
            .index(base.join("idx3"))
            .output(OutputChoice::InMemory)
            .plan()
            .unwrap_err();
        assert!(err.to_string().contains("spill"), "got {err}");
    }

    /// `.ingest(dir)` as a plan stage: each run commits one new segment
    /// into the shared set, and the merged view sees all of them.
    #[test]
    fn ingest_stage_appends_segments_to_a_shared_set() {
        use crate::query::QuerySurface;

        let db = small_db();
        let base = std::env::temp_dir().join("tspm_engine_ingest_stage");
        let _ = std::fs::remove_dir_all(&base);
        let set_dir = base.join("set");
        let mut per_run = Vec::new();
        for i in 0..2 {
            let out = Engine::from_dbmart(db.clone())
                .mine(MiningConfig {
                    work_dir: base.join(format!("work{i}")),
                    ..Default::default()
                })
                .screen(SparsityConfig { min_patients: 5, threads: 2 })
                .out_dir(base.join(format!("run{i}")))
                .ingest(set_dir.clone())
                .run()
                .unwrap();
            assert_eq!(out.report.output, OutputKind::Spilled, "ingest forces spill");
            let names: Vec<&str> =
                out.report.stages.iter().map(|s| s.stage.as_str()).collect();
            assert_eq!(names, ["mine", "screen", "ingest"]);
            let built = out.index.as_ref().expect("ingest returns the new segment");
            assert_eq!(built.total_records, out.sequences.len() as u64);
            per_run.push(built.total_records);
        }
        let set = SegmentSet::open(&set_dir).unwrap();
        assert_eq!(set.segments(), ["seg_0000", "seg_0001"]);
        let view = crate::ingest::MergedView::open(&set_dir, 0).unwrap();
        assert_eq!(view.describe().records, per_run.iter().sum::<u64>());
        let _ = std::fs::remove_dir_all(&base);
    }

    /// The out-of-core ML chain: mine → screen → index → matrix → msmr
    /// with spilled residency produces a CSR (and selection) identical
    /// to the fully in-memory chain, without materialising the records.
    #[test]
    fn index_fed_matrix_and_msmr_match_the_in_memory_chain() {
        let g = SyntheaConfig::small().generate_with_truth();
        let db = NumericDbMart::encode(&g.dbmart);
        let labels: Vec<f32> =
            (0..db.num_patients()).map(|p| f32::from(p % 3 == 0)).collect();
        let base = std::env::temp_dir().join("tspm_engine_spilled_matrix");
        let _ = std::fs::remove_dir_all(&base);

        let golden = Engine::from_dbmart(db.clone())
            .mine(MiningConfig { work_dir: base.join("mem"), ..Default::default() })
            .screen(SparsityConfig { min_patients: 5, threads: 2 })
            .matrix()
            .msmr(25)
            .labels(labels.clone())
            .run()
            .unwrap();
        let spilled = Engine::from_dbmart(db)
            .mine(MiningConfig { work_dir: base.join("spill"), ..Default::default() })
            .screen(SparsityConfig { min_patients: 5, threads: 2 })
            .out_dir(base.join("run"))
            .index(base.join("idx"))
            .matrix()
            .msmr(25)
            .labels(labels)
            .memory_budget(1 << 20) // ≪ the multiset: the chain must not materialise
            .run()
            .unwrap();

        assert_eq!(spilled.report.output, OutputKind::Spilled);
        let names: Vec<&str> =
            spilled.report.stages.iter().map(|s| s.stage.as_str()).collect();
        assert_eq!(names, ["mine", "screen", "index", "matrix", "msmr"]);
        let gm = golden.matrix.as_ref().unwrap();
        let sm = spilled.matrix.as_ref().unwrap();
        assert_eq!(sm, gm, "index-fed CSR must be bit-identical to the in-memory one");
        assert_eq!(
            spilled.selection.as_ref().unwrap().columns,
            golden.selection.as_ref().unwrap().columns
        );
        let _ = std::fs::remove_dir_all(&base);
    }

    /// The engine-level pushdown contract: a targeted run equals the
    /// full run filtered by the spec and re-screened — same records,
    /// same stats — on every backend, resident or spilled.
    #[test]
    fn targeted_run_matches_filtered_full_run_on_every_backend() {
        let db = small_db();
        let sc = SparsityConfig { min_patients: 3, threads: 2 };
        let spec = TargetSpec::for_codes([0, 2, 5]).with_duration_band(Some(1), None);
        let base = std::env::temp_dir().join("tspm_engine_targeted");
        let _ = std::fs::remove_dir_all(&base);

        // Reference: full mine → filter by the spec → screen.
        let full = Engine::from_dbmart(db.clone())
            .mine(MiningConfig::default())
            .backend(BackendChoice::InMemory)
            .run()
            .unwrap();
        let mut expect: Vec<SeqRecord> = full
            .sequences
            .materialize()
            .unwrap()
            .records
            .into_iter()
            .filter(|r| spec.matches_record(r))
            .collect();
        let expect_stats = sparsity::screen(&mut expect, &sc);
        let expect = sorted(expect);
        assert!(expect_stats.records_after > 0, "spec must keep something to compare");

        for (i, choice) in [
            BackendChoice::InMemory,
            BackendChoice::Sharded,
            BackendChoice::FileBacked,
            BackendChoice::Streaming,
        ]
        .into_iter()
        .enumerate()
        {
            let out = Engine::from_dbmart(db.clone())
                .mine(MiningConfig {
                    work_dir: base.join(format!("b{i}")),
                    ..Default::default()
                })
                .screen(sc)
                .target(spec.clone())
                .backend(choice)
                .memory_budget(50_000 * 16)
                .run()
                .unwrap();
            assert_eq!(
                sorted(out.sequences.clone().materialize().unwrap().records),
                expect,
                "backend {} ({} output) diverged from filter-then-screen",
                out.report.backend,
                out.report.output
            );
            assert_eq!(out.screen_stats.unwrap(), expect_stats, "backend {choice:?}");
        }
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn target_outside_the_vocabulary_is_rejected_at_plan_time() {
        let db = small_db();
        let vocab = db.num_phenx() as u32;
        let err = Engine::from_dbmart(db.clone())
            .mine(MiningConfig::default())
            .target(TargetSpec::for_codes([vocab + 3]))
            .plan()
            .unwrap_err();
        assert!(err.to_string().contains("outside the encoded vocabulary"), "got {err}");
        // Structurally invalid specs fail through the same gate as every
        // other stage (MineContext in Plan::validate).
        let err = Engine::from_dbmart(db.clone())
            .mine(MiningConfig::default())
            .target(TargetSpec::for_codes(std::iter::empty::<u32>()))
            .plan()
            .unwrap_err();
        assert!(err.to_string().contains("empty code set"), "got {err}");
        // A valid in-vocab spec — and the all() spec — both pass.
        assert!(Engine::from_dbmart(db.clone())
            .mine(MiningConfig::default())
            .target(TargetSpec::for_codes([0]))
            .plan()
            .is_ok());
        assert!(Engine::from_dbmart(db)
            .mine(MiningConfig::default())
            .target(TargetSpec::all())
            .plan()
            .is_ok());
    }

    /// A targeted `.index(dir)` run stamps the spec into the artifact's
    /// manifest, and reopening the index surfaces it.
    #[test]
    fn targeted_index_records_the_spec_in_the_manifest() {
        let db = small_db();
        let base = std::env::temp_dir().join("tspm_engine_targeted_index");
        let _ = std::fs::remove_dir_all(&base);
        let spec = TargetSpec::for_codes([1, 3]);
        let out = Engine::from_dbmart(db)
            .mine(MiningConfig { work_dir: base.join("work"), ..Default::default() })
            .screen(SparsityConfig { min_patients: 2, threads: 1 })
            .target(spec.clone())
            .out_dir(base.join("run"))
            .index(base.join("idx"))
            .run()
            .unwrap();
        assert_eq!(out.index.as_ref().unwrap().target.as_ref(), Some(&spec));
        let reopened = SeqIndex::open(&base.join("idx")).unwrap();
        assert_eq!(reopened.target.as_ref(), Some(&spec));
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn auto_selection_follows_the_memory_budget() {
        let db = small_db();
        let fc = backend::forecast(&db, &MiningConfig::default());
        assert!(fc.total_sequences > 0);
        // Plenty of memory, one worker → in-memory.
        let out = Engine::from_dbmart(db.clone())
            .mine(MiningConfig { threads: 1, ..Default::default() })
            .memory_budget(u64::MAX)
            .run()
            .unwrap();
        assert_eq!(out.report.backend, BackendKind::InMemory);
        // Plenty of memory, several workers → sharded.
        let out = Engine::from_dbmart(db.clone())
            .mine(MiningConfig { threads: 4, ..Default::default() })
            .memory_budget(u64::MAX)
            .run()
            .unwrap();
        assert_eq!(out.report.backend, BackendKind::Sharded);
        // Budget below the forecast but above the largest patient →
        // streaming.
        let budget = (fc.max_patient_sequences + 1) * 16;
        assert!(budget < fc.total_bytes);
        let out = Engine::from_dbmart(db)
            .mine(MiningConfig::default())
            .memory_budget(budget)
            .run()
            .unwrap();
        assert_eq!(out.report.backend, BackendKind::Streaming);
    }

    #[test]
    fn full_chain_produces_matrix_selection_and_report() {
        let g = SyntheaConfig::small().generate_with_truth();
        let db = NumericDbMart::encode(&g.dbmart);
        let pc: std::collections::BTreeSet<&str> =
            g.truth.postcovid.iter().map(|(p, _)| p.as_str()).collect();
        let labels: Vec<f32> = (0..db.num_patients())
            .map(|p| f32::from(pc.contains(db.lookup.patient_name(p as u32))))
            .collect();

        let out = Engine::from_dbmart(db)
            .mine(MiningConfig::default())
            .screen(SparsityConfig { min_patients: 8, threads: 0 })
            .matrix()
            .msmr(25)
            .labels(labels)
            .run()
            .unwrap();

        let m = out.matrix.as_ref().expect("matrix stage ran");
        assert_eq!(m.num_cols() as u64, out.screen_stats.unwrap().distinct_after);
        let sel = out.selection.as_ref().expect("msmr stage ran");
        assert!(!sel.columns.is_empty() && sel.columns.len() <= 25);

        let names: Vec<&str> =
            out.report.stages.iter().map(|s| s.stage.as_str()).collect();
        assert_eq!(names, ["mine", "screen", "matrix", "msmr"]);
        assert!(out.report.peak_logical_bytes > 0);
        let rendered = out.report.render();
        assert!(rendered.contains("mine") && rendered.contains("backend"), "{rendered}");
    }

    #[test]
    fn from_config_builds_the_canonical_chain() {
        let cfg = RunConfig::default();
        let engine = Engine::from_config(small_db(), &cfg).unwrap();
        let plan = engine.plan().unwrap();
        assert_eq!(plan.describe(), "mine → screen");
        assert_eq!(plan.backend, BackendChoice::Auto);
        assert_eq!(plan.output, OutputChoice::Auto);
        let mc = plan.mining_config().unwrap();
        assert_eq!(mc.duration_unit_days, cfg.duration_unit_days);
    }

    #[test]
    fn run_output_returns_the_lookup_tables() {
        let raw = SyntheaConfig::small().generate();
        let out = Engine::from_raw(&raw)
            .unwrap()
            .mine(MiningConfig::default())
            .run()
            .unwrap();
        // Default budget → resident output.
        assert_eq!(out.report.output, OutputKind::InMemory);
        assert_eq!(out.db.num_patients(), out.sequences.num_patients() as usize);
        let r = out.sequences.as_in_memory().unwrap().records[0];
        let (s, _) = crate::dbmart::decode_seq(r.seq);
        assert!(!out.db.lookup.phenx_name(s).is_empty());
    }

    #[test]
    fn downstream_stages_force_in_memory_output_under_auto() {
        // A tiny budget would spill a mine → screen chain, but a matrix
        // consumer forces materialisation under Auto (and Plan::validate
        // rejects an explicit Spilled on the same chain — see plan.rs).
        let out = Engine::from_dbmart(small_db())
            .mine(MiningConfig::default())
            .screen(SparsityConfig { min_patients: 5, threads: 0 })
            .matrix()
            .backend(BackendChoice::FileBacked)
            .memory_budget(1 << 16)
            .run()
            .unwrap();
        assert_eq!(out.report.output, OutputKind::InMemory);
        assert!(out.matrix.is_some());
    }

    #[test]
    fn forecast_accessor_requires_a_valid_plan() {
        assert!(Engine::from_dbmart(small_db()).forecast().is_err());
        let f = Engine::from_dbmart(small_db())
            .mine(MiningConfig::default())
            .forecast()
            .unwrap();
        assert!(f.total_sequences > 0);
    }
}
