//! The unified engine façade — one entry object for the whole tSPM+
//! workflow.
//!
//! The paper's contribution is an end-to-end pipeline (dbmart encoding →
//! transitive-pair mining with durations → sparsity screening →
//! patient×sequence matrix → MSMR), but the expert modules expose it as
//! free functions with per-module configs and error types. [`Engine`] is
//! the composable front door: a fluent builder assembles a validated
//! [`Plan`] (typed stage chain), dispatches the mine stage to one of
//! four interchangeable [`backends`](BackendKind) — chosen explicitly or
//! auto-selected from [`crate::partition`]'s memory prediction plus the
//! resolved worker count — and
//! returns every stage's output plus a [`RunReport`] of per-stage
//! timings and sizes. All failures funnel into the single [`TspmError`].
//!
//! ```no_run
//! use tspm_plus::engine::Engine;
//! use tspm_plus::mining::MiningConfig;
//! use tspm_plus::sparsity::SparsityConfig;
//!
//! let cohort = tspm_plus::synthea::SyntheaConfig::small().generate();
//! let out = Engine::from_raw(&cohort)?
//!     .mine(MiningConfig::default())
//!     .screen(SparsityConfig { min_patients: 5, threads: 0 })
//!     .matrix()
//!     .run()?;
//! println!("{} screened sequences via the {} backend",
//!          out.sequences.len(), out.report.backend);
//! println!("{}", out.report.render());
//! # Ok::<(), tspm_plus::engine::TspmError>(())
//! ```
//!
//! The original free functions remain available as the "expert layer"
//! (see the crate docs); the façade is the supported composition seam —
//! future scaling work (async backends, caching, sharded serving) plugs
//! in behind [`BackendKind`] without touching callers.

pub mod backend;
pub mod error;
pub mod plan;

pub use backend::{
    auto_select, forecast, resolve, BackendChoice, BackendKind, MiningForecast,
    DEFAULT_MEMORY_BUDGET_BYTES, HARD_ELEMENT_CAP,
};
pub use error::TspmError;
pub use plan::{Plan, Stage};

use crate::config::RunConfig;
use crate::dbmart::{DbMart, NumericDbMart};
use crate::matrix::SeqMatrix;
use crate::metrics::{fmt_bytes, fmt_duration, MemTracker, PhaseTimer};
use crate::mining::{MiningConfig, SequenceSet};
use crate::msmr::{self, MsmrConfig, Selection};
use crate::partition;
use crate::runtime::ArtifactSet;
use crate::sparsity::{self, ScreenStats, SparsityConfig};
use std::time::Duration;

/// Timing/size record for one executed stage.
#[derive(Clone, Debug)]
pub struct StageReport {
    /// Stage name ([`Stage::name`]).
    pub stage: String,
    pub elapsed: Duration,
    /// Records flowing out of the stage (matrix: non-zeros; msmr:
    /// selected features).
    pub records_out: u64,
    /// Logical bytes of the stage output.
    pub bytes_out: u64,
}

/// What a run did: backend, per-stage breakdown, peak logical memory.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// The backend the mine stage actually executed on.
    pub backend: BackendKind,
    /// Output-size forecast that drove backend selection.
    pub forecast: MiningForecast,
    pub stages: Vec<StageReport>,
    /// High-water mark of the engine's logical allocations
    /// ([`MemTracker`] semantics, not RSS).
    pub peak_logical_bytes: u64,
}

impl RunReport {
    /// Total wall time across stages.
    pub fn total(&self) -> Duration {
        self.stages.iter().map(|s| s.elapsed).sum()
    }

    /// Multi-line human-readable breakdown.
    pub fn render(&self) -> String {
        let mut out = format!(
            "backend: {}  (forecast {} sequences, {})\n",
            self.backend,
            self.forecast.total_sequences,
            fmt_bytes(self.forecast.total_bytes)
        );
        let width =
            self.stages.iter().map(|s| s.stage.len()).max().unwrap_or(5).max(5);
        for s in &self.stages {
            out.push_str(&format!(
                "  {:<width$}  {}  {:>12} records  {:>10}\n",
                s.stage,
                fmt_duration(s.elapsed),
                s.records_out,
                fmt_bytes(s.bytes_out),
                width = width
            ));
        }
        out.push_str(&format!(
            "  {:<width$}  {}  peak logical {}\n",
            "TOTAL",
            fmt_duration(self.total()),
            fmt_bytes(self.peak_logical_bytes),
            width = width
        ));
        out
    }
}

/// Everything a run produced. Stages that were not in the plan leave
/// their slot `None`. The encoded dbmart travels back out so callers can
/// translate numeric ids through its lookup tables.
pub struct RunOutput {
    /// The (possibly screened) mined sequences.
    pub sequences: SequenceSet,
    /// The encoded dbmart the run consumed (lookup tables included).
    pub db: NumericDbMart,
    pub screen_stats: Option<ScreenStats>,
    pub duration_screen_stats: Option<ScreenStats>,
    pub matrix: Option<SeqMatrix>,
    pub selection: Option<Selection>,
    pub report: RunReport,
}

/// Fluent pipeline builder over one encoded dbmart. See the module docs
/// for the canonical chain; every method returns `self` so plans read as
/// one expression. Nothing executes until [`Engine::run`].
pub struct Engine {
    db: NumericDbMart,
    stages: Vec<Stage>,
    backend: BackendChoice,
    memory_budget_bytes: Option<u64>,
    labels: Option<Vec<f32>>,
}

impl Engine {
    /// Start a pipeline over an already-encoded dbmart.
    pub fn from_dbmart(db: NumericDbMart) -> Engine {
        Engine {
            db,
            stages: Vec::new(),
            backend: BackendChoice::Auto,
            memory_budget_bytes: None,
            labels: None,
        }
    }

    /// Start a pipeline over a raw dbmart (encodes it first; surfaces
    /// vocabulary overflow as [`TspmError::Encode`] instead of
    /// panicking).
    pub fn from_raw(raw: &DbMart) -> Result<Engine, TspmError> {
        Ok(Engine::from_dbmart(NumericDbMart::try_encode(raw)?))
    }

    /// Build the canonical stage chain from a [`RunConfig`]: mine with
    /// the config's mining settings, screen when `sparsity_screen` is
    /// set, backend per `backend`/`mode`, memory budget from
    /// `max_elements_per_chunk`.
    pub fn from_config(db: NumericDbMart, cfg: &RunConfig) -> Result<Engine, TspmError> {
        cfg.validate()?;
        let mut engine = Engine::from_dbmart(db)
            .backend(cfg.backend_choice())
            .memory_budget(
                cfg.max_elements_per_chunk
                    .saturating_mul(std::mem::size_of::<crate::mining::SeqRecord>() as u64),
            )
            .mine(cfg.mining_config());
        if let Some(sc) = cfg.sparsity_config() {
            engine = engine.screen(sc);
        }
        Ok(engine)
    }

    // --- fluent stage chain ------------------------------------------------

    /// Append the mine stage (required, first).
    pub fn mine(mut self, cfg: MiningConfig) -> Engine {
        self.stages.push(Stage::Mine(cfg));
        self
    }

    /// Append the distinct-patient sparsity screen.
    pub fn screen(mut self, cfg: SparsityConfig) -> Engine {
        self.stages.push(Stage::Screen(cfg));
        self
    }

    /// Append the duration-bucket diversity screen.
    pub fn screen_durations(mut self, bucket_days: u32, min_distinct_durations: u32) -> Engine {
        self.stages.push(Stage::DurationScreen { bucket_days, min_distinct_durations });
        self
    }

    /// Append the patient×sequence matrix stage.
    pub fn matrix(mut self) -> Engine {
        self.stages.push(Stage::Matrix { duration_bucket_days: None });
        self
    }

    /// Append the duration-aware matrix stage (each column is a
    /// `(sequence, duration-bucket)` pair — the paper's new dimension).
    pub fn matrix_with_durations(mut self, bucket_days: u32) -> Engine {
        self.stages.push(Stage::Matrix { duration_bucket_days: Some(bucket_days) });
        self
    }

    /// Append MSMR selection of the top-`k` features (needs
    /// [`Engine::matrix`] before it and [`Engine::labels`]).
    pub fn msmr(self, top_k: usize) -> Engine {
        self.msmr_with(MsmrConfig { top_k, ..Default::default() })
    }

    /// [`Engine::msmr`] with full control of the selection config.
    pub fn msmr_with(mut self, cfg: MsmrConfig) -> Engine {
        self.stages.push(Stage::Msmr(cfg));
        self
    }

    // --- execution knobs ---------------------------------------------------

    /// Per-patient phenotype labels (`labels[pid] ∈ {0,1}`) for MSMR.
    pub fn labels(mut self, labels: Vec<f32>) -> Engine {
        self.labels = Some(labels);
        self
    }

    /// Pin the execution backend (default: [`BackendChoice::Auto`]).
    pub fn backend(mut self, choice: BackendChoice) -> Engine {
        self.backend = choice;
        self
    }

    /// Memory budget in bytes for auto-selection and streaming chunk
    /// sizing (default: [`DEFAULT_MEMORY_BUDGET_BYTES`]).
    pub fn memory_budget(mut self, bytes: u64) -> Engine {
        self.memory_budget_bytes = Some(bytes);
        self
    }

    // --- plan / run --------------------------------------------------------

    /// Assemble and validate the plan without executing it.
    pub fn plan(&self) -> Result<Plan, TspmError> {
        let plan = Plan {
            stages: self.stages.clone(),
            backend: self.backend,
            memory_budget_bytes: self.memory_budget_bytes,
        };
        plan.validate()?;
        if plan.wants_msmr() {
            match &self.labels {
                None => {
                    return Err(TspmError::Plan(
                        "msmr needs per-patient labels — call .labels(...) before .run()"
                            .into(),
                    ))
                }
                Some(l) if l.len() != self.db.num_patients() => {
                    return Err(TspmError::Plan(format!(
                        "labels length {} does not match the cohort's {} patients",
                        l.len(),
                        self.db.num_patients()
                    )))
                }
                _ => {}
            }
        }
        Ok(plan)
    }

    /// Forecast the mine stage's output without running anything.
    pub fn forecast(&self) -> Result<MiningForecast, TspmError> {
        let plan = self.plan()?;
        let cfg = plan.mining_config().expect("validated plan has a mine stage");
        Ok(backend::forecast(&self.db, cfg))
    }

    /// Validate, resolve the backend, and execute the plan.
    pub fn run(self) -> Result<RunOutput, TspmError> {
        self.run_with(None)
    }

    /// [`Engine::run`] with PJRT artifacts for the analytics stages
    /// (MSMR contractions); `None` uses the pure-Rust paths.
    pub fn run_with(self, artifacts: Option<&ArtifactSet>) -> Result<RunOutput, TspmError> {
        let plan = self.plan()?;
        let Engine { db, labels, memory_budget_bytes, .. } = self;

        let mining_cfg = plan
            .mining_config()
            .expect("validated plan has a mine stage")
            .clone();
        let budget = memory_budget_bytes.unwrap_or(DEFAULT_MEMORY_BUDGET_BYTES);
        let fc = backend::forecast(&db, &mining_cfg);
        let threads = mining_cfg.worker_threads();
        let kind = backend::resolve(plan.backend, &fc, budget, threads);
        let chunk_cap = partition::cap_from_memory(budget, HARD_ELEMENT_CAP);

        let mut timer = PhaseTimer::new();
        let tracker = MemTracker::new();
        let mut stages: Vec<StageReport> = Vec::new();

        // 1. Mine, on the resolved backend.
        let mut sequences =
            timer.run("mine", || backend::execute(kind, &db, &mining_cfg, chunk_cap, &tracker))?;
        stages.push(StageReport {
            stage: "mine".into(),
            elapsed: timer.elapsed("mine").unwrap_or_default(),
            records_out: sequences.len() as u64,
            bytes_out: sequences.byte_size(),
        });

        // 2. Sparsity screen (shared code path for every backend).
        let mut screen_stats = None;
        if let Some(sc) = plan.screen_config() {
            let stats = timer.run("screen", || sparsity::screen(&mut sequences.records, &sc));
            stages.push(StageReport {
                stage: "screen".into(),
                elapsed: timer.elapsed("screen").unwrap_or_default(),
                records_out: stats.records_after,
                bytes_out: sequences.byte_size(),
            });
            screen_stats = Some(stats);
        }

        // 3. Duration-diversity screen.
        let mut duration_screen_stats = None;
        if let Some((bucket, min_distinct)) = plan.duration_screen() {
            let stats = timer.run("duration_screen", || {
                sparsity::screen_by_duration(&mut sequences.records, bucket, min_distinct)
            });
            stages.push(StageReport {
                stage: "duration_screen".into(),
                elapsed: timer.elapsed("duration_screen").unwrap_or_default(),
                records_out: stats.records_after,
                bytes_out: sequences.byte_size(),
            });
            duration_screen_stats = Some(stats);
        }

        // 4. Patient×sequence matrix.
        let mut matrix = None;
        if let Some(bucket) = plan.matrix_stage() {
            let m = timer.run("matrix", || match bucket {
                Some(b) => SeqMatrix::build_with_durations(
                    &sequences.records,
                    sequences.num_patients,
                    b,
                ),
                None => SeqMatrix::build(&sequences.records, sequences.num_patients),
            });
            let bytes = (m.nnz() * std::mem::size_of::<u32>()
                + m.row_ptr.len() * std::mem::size_of::<usize>()
                + m.seq_ids.len() * std::mem::size_of::<u64>()) as u64;
            tracker.add(bytes);
            stages.push(StageReport {
                stage: "matrix".into(),
                elapsed: timer.elapsed("matrix").unwrap_or_default(),
                records_out: m.nnz() as u64,
                bytes_out: bytes,
            });
            matrix = Some(m);
        }

        // 5. MSMR feature selection.
        let mut selection = None;
        if let Some(mcfg) = plan.msmr_config() {
            let m = matrix.as_ref().expect("validated: msmr implies matrix");
            let l = labels.as_ref().expect("validated: msmr implies labels");
            let sel = timer.run("msmr", || msmr::select(m, l, &mcfg, artifacts))?;
            stages.push(StageReport {
                stage: "msmr".into(),
                elapsed: timer.elapsed("msmr").unwrap_or_default(),
                records_out: sel.columns.len() as u64,
                bytes_out: (sel.columns.len()
                    * (std::mem::size_of::<u32>() + std::mem::size_of::<f64>()))
                    as u64,
            });
            selection = Some(sel);
        }

        Ok(RunOutput {
            sequences,
            db,
            screen_stats,
            duration_screen_stats,
            matrix,
            selection,
            report: RunReport {
                backend: kind,
                forecast: fc,
                stages,
                peak_logical_bytes: tracker.peak(),
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synthea::SyntheaConfig;

    fn small_db() -> NumericDbMart {
        NumericDbMart::encode(&SyntheaConfig::small().generate())
    }

    fn sorted(mut records: Vec<crate::mining::SeqRecord>) -> Vec<crate::mining::SeqRecord> {
        records.sort_unstable_by_key(|r| (r.seq, r.pid, r.duration));
        records
    }

    #[test]
    fn builder_rejects_empty_and_ill_ordered_chains() {
        let db = small_db();
        assert!(matches!(
            Engine::from_dbmart(db.clone()).plan().unwrap_err(),
            TspmError::Plan(_)
        ));
        assert!(matches!(
            Engine::from_dbmart(db.clone())
                .screen(SparsityConfig::default())
                .plan()
                .unwrap_err(),
            TspmError::Plan(_)
        ));
        let err = Engine::from_dbmart(db)
            .mine(MiningConfig::default())
            .matrix()
            .screen(SparsityConfig::default())
            .plan()
            .unwrap_err();
        assert!(err.to_string().contains("out of order"), "got {err}");
    }

    #[test]
    fn msmr_without_labels_is_rejected_before_any_work() {
        let err = Engine::from_dbmart(small_db())
            .mine(MiningConfig::default())
            .matrix()
            .msmr(10)
            .plan()
            .unwrap_err();
        assert!(err.to_string().contains("labels"), "got {err}");

        let err = Engine::from_dbmart(small_db())
            .mine(MiningConfig::default())
            .matrix()
            .msmr(10)
            .labels(vec![0.0; 3]) // wrong length
            .plan()
            .unwrap_err();
        assert!(err.to_string().contains("labels length"), "got {err}");
    }

    /// The golden test: all four backends produce the identical screened
    /// sequence set on the small Synthea cohort.
    #[test]
    fn golden_backends_agree_on_screened_sets() {
        let db = small_db();
        let sc = SparsityConfig { min_patients: 5, threads: 2 };
        let work_dir = std::env::temp_dir().join("tspm_engine_golden");
        let _ = std::fs::remove_dir_all(&work_dir);
        let mine_cfg = MiningConfig { work_dir, ..Default::default() };

        let mut outputs = Vec::new();
        for choice in [
            BackendChoice::InMemory,
            BackendChoice::Sharded,
            BackendChoice::FileBacked,
            BackendChoice::Streaming,
        ] {
            let out = Engine::from_dbmart(db.clone())
                .mine(mine_cfg.clone())
                .screen(sc)
                .backend(choice)
                // Small budget → the streaming run really partitions.
                .memory_budget(50_000 * 16)
                .run()
                .unwrap();
            outputs.push(out);
        }
        let golden = sorted(outputs[0].sequences.records.clone());
        let golden_stats = outputs[0].screen_stats.unwrap();
        assert!(golden_stats.records_after > 0, "screen must keep something");
        for out in &outputs[1..] {
            assert_eq!(sorted(out.sequences.records.clone()), golden);
            assert_eq!(out.screen_stats.unwrap(), golden_stats);
        }
        // And the façade matches the expert layer exactly.
        let mut expert = crate::mining::mine_sequences(&db, &mine_cfg).unwrap().records;
        sparsity::screen(&mut expert, &sc);
        assert_eq!(sorted(expert), golden);
    }

    #[test]
    fn auto_selection_follows_the_memory_budget() {
        let db = small_db();
        let fc = backend::forecast(&db, &MiningConfig::default());
        assert!(fc.total_sequences > 0);
        // Plenty of memory, one worker → in-memory.
        let out = Engine::from_dbmart(db.clone())
            .mine(MiningConfig { threads: 1, ..Default::default() })
            .memory_budget(u64::MAX)
            .run()
            .unwrap();
        assert_eq!(out.report.backend, BackendKind::InMemory);
        // Plenty of memory, several workers → sharded.
        let out = Engine::from_dbmart(db.clone())
            .mine(MiningConfig { threads: 4, ..Default::default() })
            .memory_budget(u64::MAX)
            .run()
            .unwrap();
        assert_eq!(out.report.backend, BackendKind::Sharded);
        // Budget below the forecast but above the largest patient →
        // streaming.
        let budget = (fc.max_patient_sequences + 1) * 16;
        assert!(budget < fc.total_bytes);
        let out = Engine::from_dbmart(db)
            .mine(MiningConfig::default())
            .memory_budget(budget)
            .run()
            .unwrap();
        assert_eq!(out.report.backend, BackendKind::Streaming);
    }

    #[test]
    fn full_chain_produces_matrix_selection_and_report() {
        let g = SyntheaConfig::small().generate_with_truth();
        let db = NumericDbMart::encode(&g.dbmart);
        let pc: std::collections::BTreeSet<&str> =
            g.truth.postcovid.iter().map(|(p, _)| p.as_str()).collect();
        let labels: Vec<f32> = (0..db.num_patients())
            .map(|p| f32::from(pc.contains(db.lookup.patient_name(p as u32))))
            .collect();

        let out = Engine::from_dbmart(db)
            .mine(MiningConfig::default())
            .screen(SparsityConfig { min_patients: 8, threads: 0 })
            .matrix()
            .msmr(25)
            .labels(labels)
            .run()
            .unwrap();

        let m = out.matrix.as_ref().expect("matrix stage ran");
        assert_eq!(m.num_cols() as u64, out.screen_stats.unwrap().distinct_after);
        let sel = out.selection.as_ref().expect("msmr stage ran");
        assert!(!sel.columns.is_empty() && sel.columns.len() <= 25);

        let names: Vec<&str> =
            out.report.stages.iter().map(|s| s.stage.as_str()).collect();
        assert_eq!(names, ["mine", "screen", "matrix", "msmr"]);
        assert!(out.report.peak_logical_bytes > 0);
        let rendered = out.report.render();
        assert!(rendered.contains("mine") && rendered.contains("backend"), "{rendered}");
    }

    #[test]
    fn from_config_builds_the_canonical_chain() {
        let cfg = RunConfig::default();
        let engine = Engine::from_config(small_db(), &cfg).unwrap();
        let plan = engine.plan().unwrap();
        assert_eq!(plan.describe(), "mine → screen");
        assert_eq!(plan.backend, BackendChoice::Auto);
        let mc = plan.mining_config().unwrap();
        assert_eq!(mc.duration_unit_days, cfg.duration_unit_days);
    }

    #[test]
    fn run_output_returns_the_lookup_tables() {
        let raw = SyntheaConfig::small().generate();
        let out = Engine::from_raw(&raw)
            .unwrap()
            .mine(MiningConfig::default())
            .run()
            .unwrap();
        assert_eq!(out.db.num_patients(), out.sequences.num_patients as usize);
        let r = out.sequences.records[0];
        let (s, _) = crate::dbmart::decode_seq(r.seq);
        assert!(!out.db.lookup.phenx_name(s).is_empty());
    }

    #[test]
    fn forecast_accessor_requires_a_valid_plan() {
        assert!(Engine::from_dbmart(small_db()).forecast().is_err());
        let f = Engine::from_dbmart(small_db())
            .mine(MiningConfig::default())
            .forecast()
            .unwrap();
        assert!(f.total_sequences > 0);
    }
}
