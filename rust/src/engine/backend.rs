//! Pluggable execution backends for the mine stage.
//!
//! All four backends produce the *same* sequence multiset
//! (conformance-tested in `rust/tests/conformance.rs`, golden-tested in
//! the engine tests and `rust/tests/integration.rs`); they differ only
//! in how the work is scheduled and the output materialised:
//!
//! * [`BackendKind::InMemory`] — [`crate::mining::mine_sequences`]:
//!   static near-equal ranges, thread-local vectors merged into one
//!   buffer. The simple single-threaded-friendly path.
//! * [`BackendKind::Sharded`] — [`crate::mining::mine_sequences_sharded`]:
//!   the paper's OpenMP parallel-for shape. Patients are grouped into
//!   cost-balanced shards claimed **dynamically** by workers
//!   ([`crate::par::par_for_each_dynamic`]) — per-patient entry counts
//!   are highly skewed, so dynamic scheduling keeps stragglers from
//!   serializing the run. Per-shard buffers are merged in **stable shard
//!   order** (never completion order), so the output is deterministic
//!   for every thread count and `TSPM_THREADS` value. Fastest multi-core
//!   path when the whole output fits the memory budget.
//! * [`BackendKind::FileBacked`] — [`crate::mining::mine_sequences_to_files`]
//!   + [`crate::seqstore`]: per-worker spill files, resident set
//!   O(buffer × threads) during mining (the paper's "1.33 GB instead of
//!   43 GB" mode).
//! * [`BackendKind::Streaming`] — [`crate::pipeline::run`]: partition
//!   chunks flow through bounded queues with backpressure and
//!   work-stealing shards; intermediate memory is
//!   O(queue_depth × chunk output).
//!
//! The engine contract is **spill-aware**: a run's sequences come back
//! as a [`crate::engine::SequenceOutput`] — either one in-memory
//! [`SequenceSet`] or a durable on-disk [`SeqFileSet`] of spill files
//! ([`OutputKind::Spilled`]), with
//! [`materialize()`](crate::engine::SequenceOutput::materialize) as the
//! explicit escape hatch back to memory. FileBacked and Streaming runs
//! therefore never need to hold the full record multiset resident: the
//! mine stage leaves it on disk and the screen stage runs out of core
//! ([`crate::sparsity::screen_spilled`]). The paper's "1.33 GB instead
//! of 43 GB" figure thus extends from the mining phase to the whole
//! end-to-end run. Spilled results are also what the query subsystem
//! indexes ([`crate::query::index::build`]) — a serving layer answers
//! point/range queries from them without ever materialising, and the
//! index in turn feeds the out-of-core matrix builder
//! ([`crate::matrix::SeqMatrix::from_index`]), so even matrix → MSMR
//! chains stay under the budget when they follow an index stage.
//!
//! Auto-selection uses [`crate::partition`]'s exact per-patient output
//! prediction (`n·(n−1)/2` after the optional first-occurrence filter)
//! plus the resolved worker count: the whole output fits the budget →
//! `Sharded` with more than one worker, `InMemory` otherwise (dynamic
//! scheduling buys nothing on one thread); it doesn't fit, but every
//! partition chunk can → `Streaming`; even a single patient overflows a
//! chunk (no partition can help) → `FileBacked`, whose mining phase
//! keeps only O(write-buffer × threads) resident. Output residency is
//! resolved separately ([`resolve_output`]): with [`OutputChoice::Auto`]
//! the run spills exactly when the forecast post-screen footprint (the
//! mine forecast is its upper bound — screening only removes records)
//! exceeds the budget on a backend that already produces its result out
//! of core.

use super::error::TspmError;
use crate::dbmart::NumericDbMart;
use crate::metrics::MemTracker;
use crate::mining::{self, MineContext, MiningConfig, MiningMode, SeqRecord, SequenceSet};
use crate::partition;
use crate::pipeline::{self, PipelineConfig};
use crate::seqstore::SeqFileSet;
use std::path::Path;

/// Hard per-chunk element cap mirroring the R ecosystem's 2³¹−1 vector
/// limit that motivated the paper's adaptive partitioning.
pub const HARD_ELEMENT_CAP: u64 = (1u64 << 31) - 1;

/// Default memory budget for auto-selection when the caller sets none:
/// 4 GiB of sequence records, a laptop-safe figure (paper §"Performance
/// on End User devices").
pub const DEFAULT_MEMORY_BUDGET_BYTES: u64 = 4 << 30;

/// Backend requested at plan-build time.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum BackendChoice {
    /// Pick from the memory forecast at run time (the default).
    #[default]
    Auto,
    InMemory,
    Sharded,
    FileBacked,
    Streaming,
}

/// Backend actually executed (the resolution of [`BackendChoice`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    InMemory,
    Sharded,
    FileBacked,
    Streaming,
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            BackendKind::InMemory => "in-memory",
            BackendKind::Sharded => "sharded",
            BackendKind::FileBacked => "file-backed",
            BackendKind::Streaming => "streaming",
        })
    }
}

/// One canonical name→choice mapping shared by the CLI (`--backend`) and
/// [`crate::config::RunConfig`] — keeps the accepted string set from
/// drifting between surfaces.
impl std::str::FromStr for BackendChoice {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "auto" => Ok(BackendChoice::Auto),
            "memory" => Ok(BackendChoice::InMemory),
            "sharded" => Ok(BackendChoice::Sharded),
            "file" => Ok(BackendChoice::FileBacked),
            "streaming" => Ok(BackendChoice::Streaming),
            other => Err(format!(
                "backend must be auto|memory|sharded|file|streaming, got {other:?}"
            )),
        }
    }
}

/// Result residency requested at plan-build time.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum OutputChoice {
    /// Decide at run time from the post-screen footprint forecast and
    /// the memory budget (the default; see [`resolve_output`]).
    #[default]
    Auto,
    /// Always materialise one in-memory [`SequenceSet`].
    InMemory,
    /// Always leave the result as on-disk spill files
    /// ([`SeqFileSet`]); only valid for mine → screen plans.
    Spilled,
}

/// Result residency a run actually produced (the resolution of
/// [`OutputChoice`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OutputKind {
    InMemory,
    Spilled,
}

impl std::fmt::Display for OutputKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            OutputKind::InMemory => "in-memory",
            OutputKind::Spilled => "spilled",
        })
    }
}

/// One canonical name→choice mapping shared by the CLI and
/// [`crate::config::RunConfig::output_choice`].
impl std::str::FromStr for OutputChoice {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "auto" => Ok(OutputChoice::Auto),
            "memory" => Ok(OutputChoice::InMemory),
            "spilled" => Ok(OutputChoice::Spilled),
            other => Err(format!("output must be auto|memory|spilled, got {other:?}")),
        }
    }
}

/// Exact output-size forecast for one mining configuration, computed in
/// one linear pass (dense patient ids make the per-patient counting a
/// vector index, not a hash).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MiningForecast {
    /// Σ over patients of n·(n−1)/2 (post first-occurrence filter).
    pub total_sequences: u64,
    /// The largest single patient's n·(n−1)/2 — the partitioning floor:
    /// no chunk can predict below this.
    pub max_patient_sequences: u64,
    /// `total_sequences` × 16 bytes (the paper's 128-bit record).
    pub total_bytes: u64,
}

/// Predict the mining output without mining. Matches
/// [`crate::partition::plan`]'s per-patient prediction exactly, so the
/// forecast is never an underestimate (and is exact when self-pairs are
/// included, an upper bound otherwise). The forecast deliberately
/// ignores any [`crate::target::TargetSpec`]: targeted runs emit a
/// subset of the full multiset, so the untargeted figure stays a valid
/// upper bound for backend/residency selection (predicting targeted
/// selectivity would require mining).
pub fn forecast(db: &NumericDbMart, cfg: &MiningConfig) -> MiningForecast {
    let n_patients = db.num_patients();
    if n_patients == 0 {
        return MiningForecast::default();
    }
    let mut counts = vec![0u64; n_patients];
    if cfg.first_occurrence_only {
        let mut seen = std::collections::HashSet::with_capacity(db.entries.len());
        for e in &db.entries {
            if seen.insert(((e.patient as u64) << 32) | e.phenx as u64) {
                counts[e.patient as usize] += 1;
            }
        }
    } else {
        for e in &db.entries {
            counts[e.patient as usize] += 1;
        }
    }
    let mut total = 0u64;
    let mut max = 0u64;
    for &n in &counts {
        let pairs = n * n.saturating_sub(1) / 2;
        total += pairs;
        max = max.max(pairs);
    }
    MiningForecast {
        total_sequences: total,
        max_patient_sequences: max,
        total_bytes: total * std::mem::size_of::<SeqRecord>() as u64,
    }
}

/// Resolve `Auto` against a forecast, a memory budget (bytes), and the
/// worker count the mine stage will run with.
///
/// When the whole forecast output fits the budget, the sharded backend
/// is preferred on more than one worker (dynamic scheduling absorbs the
/// per-patient skew); a single worker falls back to the plain in-memory
/// path, and an empty forecast short-circuits to it too — there is
/// nothing to shard.
pub fn auto_select(f: &MiningForecast, budget_bytes: u64, threads: usize) -> BackendKind {
    let cap = partition::cap_from_memory(budget_bytes, HARD_ELEMENT_CAP);
    if f.total_sequences <= cap {
        if threads > 1 && f.total_sequences > 0 {
            BackendKind::Sharded
        } else {
            BackendKind::InMemory
        }
    } else if f.max_patient_sequences <= cap {
        BackendKind::Streaming
    } else {
        BackendKind::FileBacked
    }
}

/// Resolve a [`BackendChoice`] to the backend that will run — the one
/// selection policy, shared by [`crate::engine::Engine::run_with`] and
/// any external scheduler. `threads` is the resolved worker count
/// ([`crate::par::num_threads`] of the mining config).
pub fn resolve(
    choice: BackendChoice,
    f: &MiningForecast,
    budget_bytes: u64,
    threads: usize,
) -> BackendKind {
    match choice {
        BackendChoice::InMemory => BackendKind::InMemory,
        BackendChoice::Sharded => BackendKind::Sharded,
        BackendChoice::FileBacked => BackendKind::FileBacked,
        BackendChoice::Streaming => BackendKind::Streaming,
        BackendChoice::Auto => auto_select(f, budget_bytes, threads),
    }
}

/// Resolve an [`OutputChoice`] against the resolved backend, the mining
/// forecast, and the memory budget — the one residency policy, shared by
/// [`crate::engine::Engine::run_with`] and external schedulers.
///
/// The sparsity screen only *removes* records, so the mine forecast is
/// the upper bound on the post-screen footprint. `Auto` spills exactly
/// when that bound exceeds the budget *and* the backend already keeps
/// its result out of core (FileBacked, Streaming) — materialising there
/// would be the contract bug this policy exists to prevent. In-memory
/// backends already committed to resident output, so `Auto` never
/// spills them.
pub fn resolve_output(
    choice: OutputChoice,
    kind: BackendKind,
    f: &MiningForecast,
    budget_bytes: u64,
) -> OutputKind {
    match choice {
        OutputChoice::InMemory => OutputKind::InMemory,
        OutputChoice::Spilled => OutputKind::Spilled,
        OutputChoice::Auto => {
            if f.total_bytes > budget_bytes
                && matches!(kind, BackendKind::FileBacked | BackendKind::Streaming)
            {
                OutputKind::Spilled
            } else {
                OutputKind::InMemory
            }
        }
    }
}

/// Execute the mine stage with a **spilled** result: the full record
/// multiset lands in spill files under `mine_dir` and is never
/// materialised. FileBacked writes its per-worker spill files straight
/// there; Streaming redirects the pipeline collector to disk; the
/// in-memory backends mine normally and then spill (they already
/// committed to resident intermediates, but the *result* still honours
/// the on-disk contract so every backend stays interchangeable).
pub fn execute_spilled(
    kind: BackendKind,
    db: &NumericDbMart,
    ctx: MineContext<'_>,
    chunk_cap: u64,
    mine_dir: &Path,
    tracker: &MemTracker,
) -> Result<SeqFileSet, TspmError> {
    let cfg = ctx.cfg;
    match kind {
        BackendKind::FileBacked => {
            let cfg = MiningConfig {
                mode: MiningMode::FileBased,
                work_dir: mine_dir.to_path_buf(),
                ..cfg.clone()
            };
            Ok(mining::mine_sequences_to_files_with(
                db,
                MineContext::with_target(&cfg, ctx.target),
                Some(tracker),
            )?)
        }
        BackendKind::Streaming => {
            let pipe_cfg = PipelineConfig {
                mining: MiningConfig { mode: MiningMode::InMemory, ..cfg.clone() },
                chunk_cap: chunk_cap.max(1),
                screen: None,
                shards: cfg.worker_threads(),
                spill_dir: Some(mine_dir.to_path_buf()),
                target: ctx.target.cloned(),
                ..Default::default()
            };
            match pipeline::run(db, &pipe_cfg)?.sequences {
                crate::engine::SequenceOutput::Spilled(files) => Ok(files),
                crate::engine::SequenceOutput::InMemory(_) => {
                    unreachable!("pipeline honours spill_dir")
                }
            }
        }
        BackendKind::InMemory | BackendKind::Sharded => {
            let set = execute(kind, db, ctx, chunk_cap, tracker)?;
            std::fs::create_dir_all(mine_dir)?;
            let path = mine_dir.join("mined_0000.tspm");
            crate::seqstore::write_file(&path, &set.records)?;
            let files = SeqFileSet {
                files: vec![path],
                total_records: set.records.len() as u64,
                num_patients: set.num_patients,
                num_phenx: set.num_phenx,
            };
            tracker.sub(set.byte_size());
            Ok(files)
        }
    }
}

/// Execute the mine stage on the chosen backend. Screening is *not*
/// fused here — the engine applies it as its own stage so all backends
/// share one screening code path (and one timing entry).
pub fn execute(
    kind: BackendKind,
    db: &NumericDbMart,
    ctx: MineContext<'_>,
    chunk_cap: u64,
    tracker: &MemTracker,
) -> Result<SequenceSet, TspmError> {
    let cfg = ctx.cfg;
    match kind {
        BackendKind::InMemory => {
            Ok(mining::mine_sequences_with(db, ctx, Some(tracker))?)
        }
        BackendKind::Sharded => {
            Ok(mining::mine_sequences_sharded_with(db, ctx, Some(tracker))?)
        }
        BackendKind::FileBacked => {
            let cfg = MiningConfig { mode: MiningMode::FileBased, ..cfg.clone() };
            let files = mining::mine_sequences_to_files_with(
                db,
                MineContext::with_target(&cfg, ctx.target),
                Some(tracker),
            )?;
            // Collection materialises the full set (the engine contract
            // returns an in-memory SequenceSet); the backend's memory win
            // is confined to the mining phase above. See the module docs
            // for the fully-streaming expert path.
            let records = files.read_all()?;
            tracker.add((records.len() * std::mem::size_of::<SeqRecord>()) as u64);
            let set = SequenceSet {
                records,
                num_patients: files.num_patients,
                num_phenx: files.num_phenx,
            };
            // Best-effort cleanup: the result is already in memory, so a
            // failed unlink (shared work_dir, NFS quirks) must not throw
            // away a completed mine.
            let _ = files.remove();
            Ok(set)
        }
        BackendKind::Streaming => {
            let pipe_cfg = PipelineConfig {
                mining: MiningConfig { mode: MiningMode::InMemory, ..cfg.clone() },
                chunk_cap: chunk_cap.max(1),
                screen: None,
                // Pin the pipeline's miner shards to the config's resolved
                // worker count; the pipeline's own auto (0) would use the
                // machine default and ignore an explicit `threads`.
                shards: cfg.worker_threads(),
                target: ctx.target.cloned(),
                ..Default::default()
            };
            match pipeline::run(db, &pipe_cfg)?.sequences {
                crate::engine::SequenceOutput::InMemory(set) => {
                    tracker.add(set.byte_size());
                    Ok(set)
                }
                crate::engine::SequenceOutput::Spilled(_) => {
                    unreachable!("no spill_dir configured")
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dbmart::{DbMart, DbMartEntry};

    fn db_with_sizes(sizes: &[usize]) -> NumericDbMart {
        let mut entries = Vec::new();
        for (p, &n) in sizes.iter().enumerate() {
            for i in 0..n {
                entries.push(DbMartEntry {
                    patient_id: format!("p{p}"),
                    date: i as i32,
                    phenx: format!("x{i}"),
                    description: None,
                });
            }
        }
        NumericDbMart::encode(&DbMart::new(entries))
    }

    #[test]
    fn forecast_matches_partition_prediction() {
        let mart = crate::synthea::SyntheaConfig::small().generate();
        let db = NumericDbMart::encode(&mart);
        for first_only in [false, true] {
            let cfg = MiningConfig { first_occurrence_only: first_only, ..Default::default() };
            let f = forecast(&db, &cfg);
            let plan = partition::plan(&db, &cfg, u64::MAX).unwrap();
            assert_eq!(f.total_sequences, plan.total_predicted(), "first_only={first_only}");
            let mined = mining::mine_sequences(&db, &cfg).unwrap();
            assert_eq!(f.total_sequences, mined.len() as u64);
        }
    }

    #[test]
    fn forecast_tracks_largest_patient() {
        let db = db_with_sizes(&[3, 10, 5]);
        let f = forecast(&db, &MiningConfig::default());
        assert_eq!(f.max_patient_sequences, 45); // 10·9/2
        assert_eq!(f.total_sequences, 3 + 45 + 10);
        assert_eq!(f.total_bytes, f.total_sequences * 16);
    }

    #[test]
    fn empty_cohort_forecast_is_zero() {
        let f = forecast(&NumericDbMart::default(), &MiningConfig::default());
        assert_eq!(f, MiningForecast::default());
    }

    #[test]
    fn auto_select_policy() {
        let f = MiningForecast {
            total_sequences: 1000,
            max_patient_sequences: 100,
            total_bytes: 16_000,
        };
        // Whole output fits → in-memory on one worker, sharded otherwise.
        assert_eq!(auto_select(&f, 1_000_000, 1), BackendKind::InMemory);
        assert_eq!(auto_select(&f, 1_000_000, 4), BackendKind::Sharded);
        // Output doesn't fit, chunks do → streaming (threads irrelevant).
        assert_eq!(auto_select(&f, 200 * 16, 1), BackendKind::Streaming);
        assert_eq!(auto_select(&f, 200 * 16, 8), BackendKind::Streaming);
        // Even one patient overflows a chunk → file-backed.
        assert_eq!(auto_select(&f, 50 * 16, 4), BackendKind::FileBacked);
    }

    #[test]
    fn auto_select_boundary_forecast_exactly_at_budget() {
        let f = MiningForecast {
            total_sequences: 1000,
            max_patient_sequences: 100,
            total_bytes: 16_000,
        };
        // A budget of exactly total_bytes still fits (≤, not <) …
        assert_eq!(auto_select(&f, f.total_bytes, 1), BackendKind::InMemory);
        assert_eq!(auto_select(&f, f.total_bytes, 2), BackendKind::Sharded);
        // … one record less tips over to streaming …
        assert_eq!(auto_select(&f, f.total_bytes - 16, 2), BackendKind::Streaming);
        // … and exactly the largest patient is the streaming floor.
        assert_eq!(
            auto_select(&f, f.max_patient_sequences * 16, 2),
            BackendKind::Streaming
        );
        assert_eq!(
            auto_select(&f, f.max_patient_sequences * 16 - 16, 2),
            BackendKind::FileBacked
        );
    }

    #[test]
    fn auto_select_boundary_zero_patient_mart() {
        // An empty cohort forecasts zero everything: nothing to shard, so
        // every thread count picks the plain in-memory path.
        let f = forecast(&NumericDbMart::default(), &MiningConfig::default());
        assert_eq!(f, MiningForecast::default());
        for threads in [1usize, 2, 64] {
            assert_eq!(auto_select(&f, 0, threads), BackendKind::InMemory);
            assert_eq!(auto_select(&f, u64::MAX, threads), BackendKind::InMemory);
        }
    }

    #[test]
    fn auto_select_boundary_overflow_sized_forecast() {
        // A forecast beyond the hard element cap can never run in memory,
        // however large the byte budget: cap_from_memory clamps at
        // HARD_ELEMENT_CAP.
        let monster = MiningForecast {
            total_sequences: u64::MAX,
            max_patient_sequences: u64::MAX,
            total_bytes: u64::MAX,
        };
        assert_eq!(auto_select(&monster, u64::MAX, 8), BackendKind::FileBacked);
        // Same total, but partitionable patients → streaming.
        let skewed = MiningForecast {
            total_sequences: u64::MAX,
            max_patient_sequences: HARD_ELEMENT_CAP,
            total_bytes: u64::MAX,
        };
        assert_eq!(auto_select(&skewed, u64::MAX, 8), BackendKind::Streaming);
        // And a zero budget degenerates to a one-element cap, not zero.
        let tiny = MiningForecast {
            total_sequences: 1,
            max_patient_sequences: 1,
            total_bytes: 16,
        };
        assert_eq!(auto_select(&tiny, 0, 1), BackendKind::InMemory);
    }

    #[test]
    fn resolve_output_policy() {
        let f = MiningForecast {
            total_sequences: 1000,
            max_patient_sequences: 100,
            total_bytes: 16_000,
        };
        // Explicit choices always win.
        for kind in [
            BackendKind::InMemory,
            BackendKind::Sharded,
            BackendKind::FileBacked,
            BackendKind::Streaming,
        ] {
            assert_eq!(
                resolve_output(OutputChoice::InMemory, kind, &f, 0),
                OutputKind::InMemory
            );
            assert_eq!(
                resolve_output(OutputChoice::Spilled, kind, &f, u64::MAX),
                OutputKind::Spilled
            );
        }
        // Auto: spill only when the forecast exceeds the budget on an
        // out-of-core backend.
        assert_eq!(
            resolve_output(OutputChoice::Auto, BackendKind::FileBacked, &f, f.total_bytes),
            OutputKind::InMemory
        );
        assert_eq!(
            resolve_output(OutputChoice::Auto, BackendKind::FileBacked, &f, f.total_bytes - 1),
            OutputKind::Spilled
        );
        assert_eq!(
            resolve_output(OutputChoice::Auto, BackendKind::Streaming, &f, 16),
            OutputKind::Spilled
        );
        // In-memory backends already committed to resident output.
        assert_eq!(
            resolve_output(OutputChoice::Auto, BackendKind::InMemory, &f, 16),
            OutputKind::InMemory
        );
        assert_eq!(
            resolve_output(OutputChoice::Auto, BackendKind::Sharded, &f, 16),
            OutputKind::InMemory
        );
    }

    #[test]
    fn output_names_parse_round() {
        assert_eq!("auto".parse::<OutputChoice>().unwrap(), OutputChoice::Auto);
        assert_eq!("memory".parse::<OutputChoice>().unwrap(), OutputChoice::InMemory);
        assert_eq!("spilled".parse::<OutputChoice>().unwrap(), OutputChoice::Spilled);
        assert!("ram".parse::<OutputChoice>().unwrap_err().contains("ram"));
        assert_eq!(OutputKind::Spilled.to_string(), "spilled");
    }

    #[test]
    fn backend_names_parse_round() {
        assert_eq!("auto".parse::<BackendChoice>().unwrap(), BackendChoice::Auto);
        assert_eq!("memory".parse::<BackendChoice>().unwrap(), BackendChoice::InMemory);
        assert_eq!("sharded".parse::<BackendChoice>().unwrap(), BackendChoice::Sharded);
        assert_eq!("file".parse::<BackendChoice>().unwrap(), BackendChoice::FileBacked);
        assert_eq!("streaming".parse::<BackendChoice>().unwrap(), BackendChoice::Streaming);
        assert!("quantum".parse::<BackendChoice>().unwrap_err().contains("quantum"));
    }

    #[test]
    fn fixed_choices_resolve_to_themselves() {
        let f = forecast(&db_with_sizes(&[4]), &MiningConfig::default());
        assert_eq!(resolve(BackendChoice::InMemory, &f, 1, 4), BackendKind::InMemory);
        assert_eq!(resolve(BackendChoice::Sharded, &f, 1, 1), BackendKind::Sharded);
        assert_eq!(
            resolve(BackendChoice::FileBacked, &f, u64::MAX, 4),
            BackendKind::FileBacked
        );
        assert_eq!(
            resolve(BackendChoice::Streaming, &f, u64::MAX, 4),
            BackendKind::Streaming
        );
        assert_eq!(resolve(BackendChoice::Auto, &f, u64::MAX, 1), BackendKind::InMemory);
        assert_eq!(resolve(BackendChoice::Auto, &f, u64::MAX, 4), BackendKind::Sharded);
    }
}
