//! Typed execution plans — the validated stage chain behind the fluent
//! [`crate::engine::Engine`] builder.
//!
//! A [`Plan`] is a linear DAG of [`Stage`]s over the paper's workflow:
//!
//! ```text
//! in-memory: Mine ─▶ (Screen) ─▶ (DurationScreen) ─▶ (Matrix) ─▶ (Msmr)
//! spilled:   Mine ─▶  Screen  ─▶ Index ─▶ (Matrix) ─▶ (Msmr)
//! ```
//!
//! The spilled chain never materializes the record multiset: the screen
//! runs out of core, the index streams the spill files once, and the
//! matrix stage builds its CSR straight from the artifact
//! ([`crate::matrix::SeqMatrix::from_index`]) — MSMR then consumes the
//! (much smaller) matrix as usual.
//!
//! Validation happens **before** any work starts, so a mis-assembled
//! pipeline fails in microseconds with a precise message instead of
//! after minutes of mining: the chain must be non-empty, start with
//! exactly one `Mine`, keep stages in dependency order, and contain at
//! most one of each downstream stage.

use super::backend::{BackendChoice, OutputChoice};
use super::error::TspmError;
use crate::mining::{MineContext, MiningConfig};
use crate::msmr::MsmrConfig;
use crate::sparsity::SparsityConfig;
use crate::target::TargetSpec;
use std::path::PathBuf;

/// One pipeline stage, with its full configuration captured at build
/// time (plans are self-contained and replayable).
#[derive(Clone, Debug)]
pub enum Stage {
    /// Transitive sequencing (the paper's core step).
    Mine(MiningConfig),
    /// Distinct-patient sparsity screen ([`crate::sparsity::screen`]).
    Screen(SparsityConfig),
    /// Duration-bucket diversity screen
    /// ([`crate::sparsity::screen_by_duration`]).
    DurationScreen { bucket_days: u32, min_distinct_durations: u32 },
    /// Patient×sequence matrix; `duration_bucket_days` switches to the
    /// duration-aware column space
    /// ([`crate::matrix::SeqMatrix::build_with_durations`]). On spilled
    /// chains (after `Index`) the CSR is built straight from the
    /// artifact ([`crate::matrix::SeqMatrix::from_index`]) — bit
    /// identical, never materialized.
    Matrix { duration_bucket_days: Option<u32> },
    /// MSMR feature selection (needs `Matrix` and labels).
    Msmr(MsmrConfig),
    /// Build a query-index artifact over the spilled screen output
    /// ([`crate::query::index::build`]). Spilled mine → screen chains
    /// only; the engine forces spilled residency. Matrix/MSMR stages may
    /// follow — they feed from the artifact.
    Index { out_dir: PathBuf, block_records: usize },
    /// Mine the cohort into a brand-new segment of the segment set at
    /// `set_dir` ([`crate::ingest::SegmentSet::add_segment`]) — the
    /// delta-cohort counterpart of `Index`. Terminal: downstream stages
    /// query the set ([`crate::ingest::MergedView`]) or compact it
    /// first.
    Ingest { set_dir: PathBuf, block_records: usize },
}

impl Stage {
    /// Stable stage name (report keys, error messages).
    pub fn name(&self) -> &'static str {
        match self {
            Stage::Mine(_) => "mine",
            Stage::Screen(_) => "screen",
            Stage::DurationScreen { .. } => "duration_screen",
            Stage::Matrix { .. } => "matrix",
            Stage::Msmr(_) => "msmr",
            Stage::Index { .. } => "index",
            Stage::Ingest { .. } => "ingest",
        }
    }

    /// Topological rank; a valid chain has strictly increasing ranks,
    /// which enforces both ordering and at-most-once per stage kind.
    /// `Index` sits between the screen and the matrix: on spilled chains
    /// the matrix is built *from* the artifact.
    fn rank(&self) -> u8 {
        match self {
            Stage::Mine(_) => 0,
            Stage::Screen(_) => 1,
            // Index and Ingest share a rank: they are alternative
            // artifact sinks, and equal ranks make a chain holding both
            // invalid — validate() reports that pair with its own
            // message before the generic duplicate error can fire.
            Stage::Index { .. } | Stage::Ingest { .. } => 2,
            Stage::DurationScreen { .. } => 3,
            Stage::Matrix { .. } => 4,
            Stage::Msmr(_) => 5,
        }
    }
}

/// A validated, backend-agnostic execution plan.
#[derive(Clone, Debug)]
pub struct Plan {
    /// Stage chain in execution order.
    pub stages: Vec<Stage>,
    /// Requested execution backend (resolved at run time when `Auto`).
    pub backend: BackendChoice,
    /// Memory budget steering auto-selection and streaming chunking.
    pub memory_budget_bytes: Option<u64>,
    /// Requested result residency (resolved at run time when `Auto`).
    pub output: OutputChoice,
    /// Destination for spilled results (`None` = under the mining
    /// `work_dir`).
    pub out_dir: Option<PathBuf>,
    /// The targeting predicate pushed into the mining inner loop and the
    /// screens ([`crate::target`]). `None` (or an
    /// [`TargetSpec::is_all`] spec) mines the full multiset — bytes
    /// identical to plans predating this field.
    pub target: Option<TargetSpec>,
}

impl Plan {
    /// Structural validation: non-empty, `Mine` first, strictly
    /// increasing stage ranks, per-stage config sanity, and `Msmr`'s
    /// dependency on `Matrix`. Label presence is checked by
    /// [`crate::engine::Engine::plan`], which knows the cohort.
    pub fn validate(&self) -> Result<(), TspmError> {
        let Some(first) = self.stages.first() else {
            return Err(TspmError::Plan(
                "plan is empty — start the chain with .mine(MiningConfig)".into(),
            ));
        };
        if !matches!(first, Stage::Mine(_)) {
            return Err(TspmError::Plan(format!(
                "plan must start with the mine stage, found {:?} first",
                first.name()
            )));
        }
        if self.index_stage().is_some() && self.ingest_stage().is_some() {
            // Both sit at the same rank, so the generic duplicate error
            // below would name only one of them — report the real
            // conflict instead.
            return Err(TspmError::Plan(
                "index and ingest are alternative artifact sinks — one chain writes \
                 a standalone artifact (.index) or a segment (.ingest), never both"
                    .into(),
            ));
        }
        let mut prev_rank = first.rank();
        for stage in &self.stages[1..] {
            let rank = stage.rank();
            if rank == prev_rank {
                return Err(TspmError::Plan(format!(
                    "stage {:?} appears more than once",
                    stage.name()
                )));
            }
            if rank < prev_rank {
                return Err(TspmError::Plan(format!(
                    "stage {:?} is out of order — stages must follow \
                     mine → screen → index → duration_screen → matrix → msmr",
                    stage.name()
                )));
            }
            prev_rank = rank;
        }
        if self.wants_msmr() && self.matrix_stage().is_none() {
            return Err(TspmError::Plan(
                "msmr needs the patient×sequence matrix — insert .matrix() before .msmr(k)"
                    .into(),
            ));
        }
        if self.output == OutputChoice::Spilled && !self.spill_capable() {
            let bad = self
                .stages
                .iter()
                .find(|s| {
                    !matches!(
                        s,
                        Stage::Mine(_)
                            | Stage::Screen(_)
                            | Stage::Index { .. }
                            | Stage::Ingest { .. }
                    )
                })
                .expect("spill_capable is false");
            return Err(TspmError::Plan(format!(
                "spilled output supports mine → screen chains (plus index-fed matrix/msmr); \
                 stage {:?} needs in-memory records — drop .output(OutputChoice::Spilled), \
                 insert .index(dir) before it, or materialize() a previous run's result \
                 yourself",
                bad.name()
            )));
        }
        if let Some((_, block_records)) = self.index_stage() {
            // The index consumes the *sorted* spill files the screen
            // writes, so it is validated like OutputChoice::Spilled plus
            // a hard dependency on the screen stage. Matrix/MSMR may
            // follow — they feed from the artifact, never from resident
            // records — but the duration screen cannot: it rewrites the
            // record multiset in memory.
            if let Some(bad) = self
                .stages
                .iter()
                .find(|s| matches!(s, Stage::DurationScreen { .. }))
            {
                return Err(TspmError::Plan(format!(
                    "stage {:?} rewrites in-memory records and cannot join an index \
                     chain — spilled plans are mine → screen → index [→ matrix → msmr]",
                    bad.name()
                )));
            }
            if self.screen_config().is_none() {
                return Err(TspmError::Plan(
                    "index needs the sorted spilled screen output — insert .screen(...) \
                     before .index(dir)"
                        .into(),
                ));
            }
            if self.output == OutputChoice::InMemory {
                // The explicit-residency conflict: `.index(dir)` forces
                // spilled residency, so an explicit InMemory request
                // must fail loudly, never be silently overridden.
                return Err(TspmError::Plan(
                    "index builds from spill files — drop .output(OutputChoice::InMemory) \
                     (index plans force spilled residency)"
                        .into(),
                ));
            }
            if block_records == 0 {
                return Err(TspmError::Plan("index: block_records must be ≥ 1".into()));
            }
        }
        if let Some((_, block_records)) = self.ingest_stage() {
            // Ingest consumes the same sorted spilled screen output as
            // Index, and is additionally *terminal*: the chain's result
            // is a new segment in the set, and downstream stages should
            // query the set (or compact it) instead of the lone delta.
            if let Some(bad) = self.stages.iter().find(|s| {
                !matches!(s, Stage::Mine(_) | Stage::Screen(_) | Stage::Ingest { .. })
            }) {
                return Err(TspmError::Plan(format!(
                    "stage {:?} cannot join an ingest chain — ingest is terminal \
                     (mine → screen → ingest); query the segment set or compact it \
                     for downstream stages",
                    bad.name()
                )));
            }
            if self.screen_config().is_none() {
                return Err(TspmError::Plan(
                    "ingest needs the sorted spilled screen output — insert .screen(...) \
                     before .ingest(dir)"
                        .into(),
                ));
            }
            if self.output == OutputChoice::InMemory {
                return Err(TspmError::Plan(
                    "ingest builds from spill files — drop .output(OutputChoice::InMemory) \
                     (ingest plans force spilled residency)"
                        .into(),
                ));
            }
            if block_records == 0 {
                return Err(TspmError::Plan("ingest: block_records must be ≥ 1".into()));
            }
        }
        for stage in &self.stages {
            match stage {
                // The one copy of mine-stage semantics: config checks
                // (duration unit, shard cap) and the target's structural
                // checks all live in MineContext::validate — the plan
                // layer no longer re-validates overlapping fields.
                Stage::Mine(cfg) => {
                    MineContext::with_target(cfg, self.target.as_ref())
                        .validate()
                        .map_err(|e| TspmError::Plan(format!("mine: {e}")))?;
                }
                Stage::Screen(cfg) if cfg.min_patients == 0 => {
                    return Err(TspmError::Plan(
                        "screen: min_patients must be ≥ 1 (0 would be a no-op)".into(),
                    ));
                }
                Stage::DurationScreen { bucket_days, .. } if *bucket_days == 0 => {
                    return Err(TspmError::Plan(
                        "duration_screen: bucket_days must be ≥ 1".into(),
                    ));
                }
                Stage::Msmr(cfg) if cfg.top_k == 0 => {
                    return Err(TspmError::Plan("msmr: top_k must be ≥ 1".into()));
                }
                _ => {}
            }
        }
        Ok(())
    }

    /// The mining configuration (present in every valid plan).
    pub fn mining_config(&self) -> Option<&MiningConfig> {
        self.stages.iter().find_map(|s| match s {
            Stage::Mine(cfg) => Some(cfg),
            _ => None,
        })
    }

    /// The sparsity-screen configuration, if the stage is present.
    pub fn screen_config(&self) -> Option<SparsityConfig> {
        self.stages.iter().find_map(|s| match s {
            Stage::Screen(cfg) => Some(*cfg),
            _ => None,
        })
    }

    /// `(bucket_days, min_distinct_durations)` of the duration screen.
    pub fn duration_screen(&self) -> Option<(u32, u32)> {
        self.stages.iter().find_map(|s| match s {
            Stage::DurationScreen { bucket_days, min_distinct_durations } => {
                Some((*bucket_days, *min_distinct_durations))
            }
            _ => None,
        })
    }

    /// `Some(duration_bucket_days)` when a matrix stage is present
    /// (`Some(None)` = plain binary matrix).
    pub fn matrix_stage(&self) -> Option<Option<u32>> {
        self.stages.iter().find_map(|s| match s {
            Stage::Matrix { duration_bucket_days } => Some(*duration_bucket_days),
            _ => None,
        })
    }

    /// The MSMR configuration, if the stage is present.
    pub fn msmr_config(&self) -> Option<MsmrConfig> {
        self.stages.iter().find_map(|s| match s {
            Stage::Msmr(cfg) => Some(*cfg),
            _ => None,
        })
    }

    /// Does the plan end in feature selection?
    pub fn wants_msmr(&self) -> bool {
        self.msmr_config().is_some()
    }

    /// `(out_dir, block_records)` of the index stage, if present.
    pub fn index_stage(&self) -> Option<(&std::path::Path, usize)> {
        self.stages.iter().find_map(|s| match s {
            Stage::Index { out_dir, block_records } => {
                Some((out_dir.as_path(), *block_records))
            }
            _ => None,
        })
    }

    /// `(set_dir, block_records)` of the ingest stage, if present.
    pub fn ingest_stage(&self) -> Option<(&std::path::Path, usize)> {
        self.stages.iter().find_map(|s| match s {
            Stage::Ingest { set_dir, block_records } => {
                Some((set_dir.as_path(), *block_records))
            }
            _ => None,
        })
    }

    /// Can this chain produce a spilled result? mine → screen chains
    /// can, and index chains can take it further: the index stage feeds
    /// matrix (and thus MSMR) straight from the artifact, so those
    /// stages no longer force materialisation. Everything else (the
    /// duration screen; matrix without an index) consumes in-memory
    /// records, so those plans always materialise.
    pub fn spill_capable(&self) -> bool {
        if self.index_stage().is_some() {
            self.stages.iter().all(|s| {
                matches!(
                    s,
                    Stage::Mine(_)
                        | Stage::Screen(_)
                        | Stage::Index { .. }
                        | Stage::Matrix { .. }
                        | Stage::Msmr(_)
                )
            })
        } else if self.ingest_stage().is_some() {
            self.stages
                .iter()
                .all(|s| matches!(s, Stage::Mine(_) | Stage::Screen(_) | Stage::Ingest { .. }))
        } else {
            self.stages
                .iter()
                .all(|s| matches!(s, Stage::Mine(_) | Stage::Screen(_)))
        }
    }

    /// Human-readable chain, e.g. `mine → screen → matrix → msmr`.
    pub fn describe(&self) -> String {
        self.stages.iter().map(Stage::name).collect::<Vec<_>>().join(" → ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan_of(stages: Vec<Stage>) -> Plan {
        Plan {
            stages,
            backend: BackendChoice::Auto,
            memory_budget_bytes: None,
            output: OutputChoice::Auto,
            out_dir: None,
            target: None,
        }
    }

    #[test]
    fn empty_plan_rejected() {
        let err = plan_of(vec![]).validate().unwrap_err();
        assert!(err.to_string().contains("empty"), "got {err}");
    }

    #[test]
    fn plan_must_start_with_mine() {
        let err = plan_of(vec![Stage::Screen(SparsityConfig::default())])
            .validate()
            .unwrap_err();
        assert!(err.to_string().contains("mine"), "got {err}");
    }

    #[test]
    fn out_of_order_stages_rejected() {
        let err = plan_of(vec![
            Stage::Mine(MiningConfig::default()),
            Stage::Matrix { duration_bucket_days: None },
            Stage::Screen(SparsityConfig::default()),
        ])
        .validate()
        .unwrap_err();
        assert!(err.to_string().contains("out of order"), "got {err}");
    }

    #[test]
    fn duplicate_stage_rejected() {
        let err = plan_of(vec![
            Stage::Mine(MiningConfig::default()),
            Stage::Screen(SparsityConfig::default()),
            Stage::Screen(SparsityConfig::default()),
        ])
        .validate()
        .unwrap_err();
        assert!(err.to_string().contains("more than once"), "got {err}");
    }

    #[test]
    fn msmr_requires_matrix() {
        let err = plan_of(vec![
            Stage::Mine(MiningConfig::default()),
            Stage::Msmr(MsmrConfig::default()),
        ])
        .validate()
        .unwrap_err();
        assert!(err.to_string().contains("matrix"), "got {err}");
    }

    #[test]
    fn degenerate_configs_rejected() {
        let err = plan_of(vec![
            Stage::Mine(MiningConfig::default()),
            Stage::Screen(SparsityConfig { min_patients: 0, threads: 0 }),
        ])
        .validate()
        .unwrap_err();
        assert!(err.to_string().contains("min_patients"), "got {err}");

        let err = plan_of(vec![
            Stage::Mine(MiningConfig::default()),
            Stage::Matrix { duration_bucket_days: None },
            Stage::Msmr(MsmrConfig { top_k: 0, ..Default::default() }),
        ])
        .validate()
        .unwrap_err();
        assert!(err.to_string().contains("top_k"), "got {err}");
    }

    #[test]
    fn full_chain_validates_and_describes() {
        let p = plan_of(vec![
            Stage::Mine(MiningConfig::default()),
            Stage::Screen(SparsityConfig::default()),
            Stage::DurationScreen { bucket_days: 30, min_distinct_durations: 2 },
            Stage::Matrix { duration_bucket_days: Some(30) },
            Stage::Msmr(MsmrConfig::default()),
        ]);
        p.validate().unwrap();
        assert_eq!(p.describe(), "mine → screen → duration_screen → matrix → msmr");
        assert!(p.wants_msmr());
        assert_eq!(p.matrix_stage(), Some(Some(30)));
        assert_eq!(p.duration_screen(), Some((30, 2)));
    }

    #[test]
    fn mine_only_is_a_valid_plan() {
        plan_of(vec![Stage::Mine(MiningConfig::default())]).validate().unwrap();
    }

    #[test]
    fn zero_duration_unit_rejected_in_plan() {
        // Companion to the mining-layer rejection: the plan surface must
        // refuse the same degenerate config before any work starts.
        let err = plan_of(vec![Stage::Mine(MiningConfig {
            duration_unit_days: 0,
            ..Default::default()
        })])
        .validate()
        .unwrap_err();
        assert!(err.to_string().contains("duration_unit_days"), "got {err}");
    }

    #[test]
    fn spilled_output_limited_to_mine_screen_chains() {
        // mine and mine → screen spill fine …
        for stages in [
            vec![Stage::Mine(MiningConfig::default())],
            vec![
                Stage::Mine(MiningConfig::default()),
                Stage::Screen(SparsityConfig::default()),
            ],
        ] {
            let mut p = plan_of(stages);
            p.output = OutputChoice::Spilled;
            assert!(p.spill_capable());
            p.validate().unwrap();
        }
        // … matrix/msmr chains cannot: they consume in-memory records.
        let mut p = plan_of(vec![
            Stage::Mine(MiningConfig::default()),
            Stage::Matrix { duration_bucket_days: None },
        ]);
        assert!(!p.spill_capable());
        p.output = OutputChoice::Spilled;
        let err = p.validate().unwrap_err();
        assert!(err.to_string().contains("spilled"), "got {err}");
        // Auto stays valid on the same chain (it resolves to in-memory).
        p.output = OutputChoice::Auto;
        p.validate().unwrap();
    }

    #[test]
    fn index_stage_validation() {
        let idx = |block_records| Stage::Index {
            out_dir: PathBuf::from("/tmp/tspm_plan_idx"),
            block_records,
        };
        // The canonical chain validates, under Auto and explicit Spilled.
        for output in [OutputChoice::Auto, OutputChoice::Spilled] {
            let mut p = plan_of(vec![
                Stage::Mine(MiningConfig::default()),
                Stage::Screen(SparsityConfig::default()),
                idx(4096),
            ]);
            p.output = output;
            p.validate().unwrap();
            assert!(p.spill_capable());
            assert_eq!(p.describe(), "mine → screen → index");
            assert_eq!(p.index_stage().unwrap().1, 4096);
        }
        // Index without the screen is rejected (mine-only spill output
        // is unsorted).
        let err = plan_of(vec![Stage::Mine(MiningConfig::default()), idx(4096)])
            .validate()
            .unwrap_err();
        assert!(err.to_string().contains("screen"), "got {err}");
        // The matrix belongs *after* the index (it feeds from the
        // artifact); putting it before is an ordering violation.
        let err = plan_of(vec![
            Stage::Mine(MiningConfig::default()),
            Stage::Screen(SparsityConfig::default()),
            Stage::Matrix { duration_bucket_days: None },
            idx(4096),
        ])
        .validate()
        .unwrap_err();
        assert!(err.to_string().contains("out of order"), "got {err}");
        // Explicit in-memory residency contradicts the index stage —
        // a validation error, never a silent override.
        let mut p = plan_of(vec![
            Stage::Mine(MiningConfig::default()),
            Stage::Screen(SparsityConfig::default()),
            idx(4096),
        ]);
        p.output = OutputChoice::InMemory;
        let err = p.validate().unwrap_err();
        assert!(err.to_string().contains("spill"), "got {err}");
        // Degenerate block size.
        let err = plan_of(vec![
            Stage::Mine(MiningConfig::default()),
            Stage::Screen(SparsityConfig::default()),
            idx(0),
        ])
        .validate()
        .unwrap_err();
        assert!(err.to_string().contains("block_records"), "got {err}");
    }

    #[test]
    fn ingest_stage_validation() {
        let ing = |block_records| Stage::Ingest {
            set_dir: PathBuf::from("/tmp/tspm_plan_ingest"),
            block_records,
        };
        // The canonical ingest chain validates, under Auto and Spilled.
        for output in [OutputChoice::Auto, OutputChoice::Spilled] {
            let mut p = plan_of(vec![
                Stage::Mine(MiningConfig::default()),
                Stage::Screen(SparsityConfig::default()),
                ing(4096),
            ]);
            p.output = output;
            p.validate().unwrap();
            assert!(p.spill_capable());
            assert_eq!(p.describe(), "mine → screen → ingest");
            assert_eq!(p.ingest_stage().unwrap().1, 4096);
        }
        // Ingest without the screen is rejected.
        let err = plan_of(vec![Stage::Mine(MiningConfig::default()), ing(4096)])
            .validate()
            .unwrap_err();
        assert!(err.to_string().contains("screen"), "got {err}");
        // Ingest is terminal: matrix after it is rejected with its own
        // message, not the generic ordering one.
        let err = plan_of(vec![
            Stage::Mine(MiningConfig::default()),
            Stage::Screen(SparsityConfig::default()),
            ing(4096),
            Stage::Matrix { duration_bucket_days: None },
        ])
        .validate()
        .unwrap_err();
        assert!(err.to_string().contains("terminal"), "got {err}");
        // Index + ingest in one chain names the real conflict.
        let err = plan_of(vec![
            Stage::Mine(MiningConfig::default()),
            Stage::Screen(SparsityConfig::default()),
            Stage::Index { out_dir: PathBuf::from("/tmp/tspm_plan_both"), block_records: 64 },
            ing(4096),
        ])
        .validate()
        .unwrap_err();
        assert!(err.to_string().contains("alternative artifact sinks"), "got {err}");
        // Explicit in-memory residency contradicts ingest.
        let mut p = plan_of(vec![
            Stage::Mine(MiningConfig::default()),
            Stage::Screen(SparsityConfig::default()),
            ing(4096),
        ]);
        p.output = OutputChoice::InMemory;
        let err = p.validate().unwrap_err();
        assert!(err.to_string().contains("spill"), "got {err}");
        // Degenerate block size.
        let err = plan_of(vec![
            Stage::Mine(MiningConfig::default()),
            Stage::Screen(SparsityConfig::default()),
            ing(0),
        ])
        .validate()
        .unwrap_err();
        assert!(err.to_string().contains("block_records"), "got {err}");
    }

    #[test]
    fn index_fed_matrix_and_msmr_chains_validate() {
        let idx = || Stage::Index {
            out_dir: PathBuf::from("/tmp/tspm_plan_idx_matrix"),
            block_records: 512,
        };
        // The full out-of-core chain is valid and spill-capable, under
        // Auto and explicit Spilled residency.
        for output in [OutputChoice::Auto, OutputChoice::Spilled] {
            let mut p = plan_of(vec![
                Stage::Mine(MiningConfig::default()),
                Stage::Screen(SparsityConfig::default()),
                idx(),
                Stage::Matrix { duration_bucket_days: None },
                Stage::Msmr(MsmrConfig::default()),
            ]);
            p.output = output;
            p.validate().unwrap();
            assert!(p.spill_capable());
            assert_eq!(p.describe(), "mine → screen → index → matrix → msmr");
        }
        // The explicit-residency conflict persists with the longer chain.
        let mut p = plan_of(vec![
            Stage::Mine(MiningConfig::default()),
            Stage::Screen(SparsityConfig::default()),
            idx(),
            Stage::Matrix { duration_bucket_days: None },
        ]);
        p.output = OutputChoice::InMemory;
        let err = p.validate().unwrap_err();
        assert!(err.to_string().contains("spill"), "got {err}");
        // The duration screen rewrites resident records — it cannot join
        // an index chain in either order.
        let err = plan_of(vec![
            Stage::Mine(MiningConfig::default()),
            Stage::Screen(SparsityConfig::default()),
            idx(),
            Stage::DurationScreen { bucket_days: 30, min_distinct_durations: 2 },
        ])
        .validate()
        .unwrap_err();
        assert!(err.to_string().contains("duration_screen"), "got {err}");
        // Without the index stage, matrix chains stay in-memory-only:
        // explicit Spilled is still rejected there.
        let mut p = plan_of(vec![
            Stage::Mine(MiningConfig::default()),
            Stage::Screen(SparsityConfig::default()),
            Stage::Matrix { duration_bucket_days: None },
        ]);
        assert!(!p.spill_capable());
        p.output = OutputChoice::Spilled;
        assert!(p.validate().is_err());
    }

    #[test]
    fn target_is_validated_like_other_stages() {
        use crate::target::TargetSpec;
        let mine = || vec![Stage::Mine(MiningConfig::default())];
        // Valid specs (including all()) pass.
        for spec in [
            TargetSpec::all(),
            TargetSpec::for_codes([3, 1]),
            TargetSpec::all().with_duration_band(Some(1), Some(9)),
        ] {
            let mut p = plan_of(mine());
            p.target = Some(spec);
            p.validate().unwrap();
        }
        // Empty code set and inverted band are plan errors, reported
        // before any work starts.
        let mut p = plan_of(mine());
        p.target = Some(TargetSpec::for_codes([]));
        let err = p.validate().unwrap_err();
        assert!(err.to_string().contains("empty code set"), "got {err}");
        let mut p = plan_of(mine());
        p.target = Some(TargetSpec::all().with_duration_band(Some(7), Some(2)));
        let err = p.validate().unwrap_err();
        assert!(err.to_string().contains("inverted"), "got {err}");
    }

    #[test]
    fn absurd_shard_count_rejected() {
        let max = crate::mining::MAX_SHARDS;
        let err = plan_of(vec![Stage::Mine(MiningConfig {
            shards: max + 1,
            ..Default::default()
        })])
        .validate()
        .unwrap_err();
        assert!(err.to_string().contains("shards"), "got {err}");
        // The boundary itself — and auto (0) — are fine.
        for shards in [0, 1, max] {
            plan_of(vec![Stage::Mine(MiningConfig { shards, ..Default::default() })])
                .validate()
                .unwrap();
        }
    }
}
