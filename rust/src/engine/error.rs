//! The one error type of the engine façade.
//!
//! The seed grew five incompatible error types (`MiningError`,
//! `PartitionError`, the `MiningErrorOrPartition` combinator,
//! `ConfigError`, `CliError`) plus `EncodeError`, `RuntimeError` and raw
//! `io::Error`s, so every caller stitched stages together with
//! `map_err(|e| e.to_string())`. [`TspmError`] absorbs all of them via
//! `From` impls: any stage result can be `?`-propagated through a façade
//! run, and `source()` preserves the underlying cause chain.

use crate::cli::CliError;
use crate::config::ConfigError;
use crate::dbmart::EncodeError;
use crate::matrix::MatrixError;
use crate::mining::MiningError;
use crate::partition::PartitionError;
use crate::query::QueryError;
use crate::runtime::RuntimeError;
use crate::serve::ServeError;
use std::fmt;

/// Unified error for every engine-orchestrated pipeline stage.
#[derive(Debug)]
pub enum TspmError {
    /// Filesystem / spill-file failures.
    Io(std::io::Error),
    /// Sequencing failures ([`crate::mining`]).
    Mining(MiningError),
    /// Adaptive-partitioning failures ([`crate::partition`]).
    Partition(PartitionError),
    /// Raw-dbmart encoding failures ([`crate::dbmart`]).
    Encode(EncodeError),
    /// Configuration loading/validation failures ([`crate::config`]).
    Config(ConfigError),
    /// Command-line parsing failures ([`crate::cli`]).
    Cli(CliError),
    /// PJRT / artifact failures ([`crate::runtime`]).
    Runtime(RuntimeError),
    /// Query-subsystem failures ([`crate::query`]): corrupt index
    /// artifacts, unsorted build input, invalid queries.
    Query(QueryError),
    /// Matrix-builder failures ([`crate::matrix`]): a pid outside the
    /// row space, or an index artifact that disagrees with its tables.
    Matrix(MatrixError),
    /// Serving-layer failures ([`crate::serve`]): socket errors,
    /// protocol violations, typed remote errors, admission shedding.
    Serve(ServeError),
    /// An [`crate::engine::Plan`] that fails validation (empty chain,
    /// ill-ordered stages, missing labels, …).
    Plan(String),
    /// Streaming-orchestrator failures ([`crate::pipeline`]).
    Pipeline(String),
}

impl fmt::Display for TspmError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TspmError::Io(e) => write!(f, "io error: {e}"),
            TspmError::Mining(e) => write!(f, "{e}"),
            TspmError::Partition(e) => write!(f, "{e}"),
            TspmError::Encode(e) => write!(f, "{e}"),
            TspmError::Config(e) => write!(f, "{e}"),
            TspmError::Cli(e) => write!(f, "{e}"),
            TspmError::Runtime(e) => write!(f, "{e}"),
            TspmError::Query(e) => write!(f, "{e}"),
            TspmError::Matrix(e) => write!(f, "{e}"),
            TspmError::Serve(e) => write!(f, "{e}"),
            TspmError::Plan(msg) => write!(f, "invalid plan: {msg}"),
            TspmError::Pipeline(msg) => write!(f, "pipeline error: {msg}"),
        }
    }
}

impl std::error::Error for TspmError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            TspmError::Io(e) => Some(e),
            TspmError::Mining(e) => Some(e),
            TspmError::Partition(e) => Some(e),
            TspmError::Encode(e) => Some(e),
            TspmError::Config(e) => Some(e),
            TspmError::Cli(e) => Some(e),
            TspmError::Runtime(e) => Some(e),
            TspmError::Query(e) => Some(e),
            TspmError::Matrix(e) => Some(e),
            TspmError::Serve(e) => Some(e),
            TspmError::Plan(_) | TspmError::Pipeline(_) => None,
        }
    }
}

impl From<std::io::Error> for TspmError {
    fn from(e: std::io::Error) -> Self {
        TspmError::Io(e)
    }
}

impl From<MiningError> for TspmError {
    fn from(e: MiningError) -> Self {
        TspmError::Mining(e)
    }
}

impl From<PartitionError> for TspmError {
    fn from(e: PartitionError) -> Self {
        TspmError::Partition(e)
    }
}

impl From<EncodeError> for TspmError {
    fn from(e: EncodeError) -> Self {
        TspmError::Encode(e)
    }
}

impl From<ConfigError> for TspmError {
    fn from(e: ConfigError) -> Self {
        TspmError::Config(e)
    }
}

impl From<CliError> for TspmError {
    fn from(e: CliError) -> Self {
        TspmError::Cli(e)
    }
}

impl From<RuntimeError> for TspmError {
    fn from(e: RuntimeError) -> Self {
        TspmError::Runtime(e)
    }
}

impl From<QueryError> for TspmError {
    fn from(e: QueryError) -> Self {
        TspmError::Query(e)
    }
}

impl From<MatrixError> for TspmError {
    fn from(e: MatrixError) -> Self {
        TspmError::Matrix(e)
    }
}

impl From<ServeError> for TspmError {
    fn from(e: ServeError) -> Self {
        TspmError::Serve(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_module_error_converts() {
        let m: TspmError = MiningError::TooManySequences { mined: 10, cap: 5 }.into();
        assert!(matches!(m, TspmError::Mining(_)));
        let p: TspmError =
            PartitionError::PatientExceedsCap { patient: 1, sequences: 10, cap: 5 }.into();
        assert!(matches!(p, TspmError::Partition(_)));
        let c: TspmError = ConfigError("bad".into()).into();
        assert!(matches!(c, TspmError::Config(_)));
        let cl: TspmError = CliError("bad flag".into()).into();
        assert!(matches!(cl, TspmError::Cli(_)));
        let r: TspmError = RuntimeError("no artifacts".into()).into();
        assert!(matches!(r, TspmError::Runtime(_)));
        let e: TspmError = EncodeError("vocab overflow".into()).into();
        assert!(matches!(e, TspmError::Encode(_)));
        let q: TspmError = QueryError::Invalid("zero buckets".into()).into();
        assert!(matches!(q, TspmError::Query(_)));
        let mx: TspmError =
            MatrixError::PidOutOfRange { pid: 9, num_patients: 3 }.into();
        assert!(matches!(mx, TspmError::Matrix(_)));
        let i: TspmError = std::io::Error::new(std::io::ErrorKind::Other, "disk").into();
        assert!(matches!(i, TspmError::Io(_)));
        let s: TspmError = ServeError::Busy.into();
        assert!(matches!(s, TspmError::Serve(_)));
        assert!(s.to_string().contains("busy"), "got {s}");
    }

    #[test]
    fn display_preserves_inner_message() {
        let e = TspmError::from(MiningError::TooManySequences { mined: 7, cap: 3 });
        let s = e.to_string();
        assert!(s.contains('7') && s.contains('3'), "got {s}");
        assert!(TspmError::Plan("empty".into()).to_string().contains("invalid plan"));
    }

    #[test]
    fn source_chain_is_preserved() {
        use std::error::Error;
        let e = TspmError::from(ConfigError("x".into()));
        assert!(e.source().is_some());
        assert!(TspmError::Plan("x".into()).source().is_none());
    }
}
