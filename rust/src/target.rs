//! Targeted mining — the canonical predicate pushed down the stage stack.
//!
//! The paper's workflows (Post COVID-19 phenotyping) almost always ask
//! focused questions: "patterns involving *these* codes in *this*
//! duration band". Mining the full transitive multiset and filtering
//! afterwards answers them at full-cohort cost; [`TargetSpec`] is the
//! predicate the engine instead threads down through
//! [`crate::engine::Plan::target`] → the mining backends
//! ([`crate::mining::MineContext`]) → the sparsity screens → the index
//! manifest, so non-matching pairs are pruned inside the per-patient
//! inner loop *before* duration encoding (Liang et al., "Targeted
//! Mining of Time-Interval Related Patterns").
//!
//! ## Pushdown safety
//!
//! The predicate is **per-record**: a mined record matches iff its
//! decoded `(first, second)` endpoint pair matches the code-set/position
//! constraint *and* its duration lies in the band. Targeted mining
//! evaluates exactly this predicate on exactly the pairs the full mine
//! would enumerate, so the targeted multiset **is** the filtered full
//! multiset — record for record, in the same order. Every downstream
//! stage (screening, indexing) is a function of that multiset, hence
//! `targeted-mine → screen ≡ full-mine → filter → screen`, byte for
//! byte. The conformance suite (`rust/tests/conformance.rs`) enforces
//! this across all four backends, adversarial cohort shapes, and both
//! residencies.
//!
//! ## Canonical form
//!
//! Specs are canonicalized on construction — the code set is sorted and
//! deduplicated — so spec equality is order- and duplicate-insensitive
//! (`properties.rs` holds the property test), and the manifest rendering
//! of a spec is a stable function of what it matches.

use crate::dbmart::decode_seq;
use crate::json::Json;
use crate::mining::SeqRecord;
use std::fmt;

/// Which sequence endpoint the target code set constrains.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TargetPos {
    /// The *first* (earlier) code of the pair must be in the set.
    First,
    /// The *second* (later) code of the pair must be in the set.
    Second,
    /// Either endpoint in the set makes the pair a match (the default).
    #[default]
    Either,
}

impl TargetPos {
    /// The CLI/config spelling of this position.
    pub fn as_str(&self) -> &'static str {
        match self {
            TargetPos::First => "first",
            TargetPos::Second => "second",
            TargetPos::Either => "either",
        }
    }
}

impl std::str::FromStr for TargetPos {
    type Err = String;
    fn from_str(s: &str) -> Result<TargetPos, String> {
        match s {
            "first" => Ok(TargetPos::First),
            "second" => Ok(TargetPos::Second),
            "either" => Ok(TargetPos::Either),
            other => Err(format!(
                "unknown target position {other:?} (expected first|second|either)"
            )),
        }
    }
}

impl fmt::Display for TargetPos {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// The canonical targeting predicate: an optional endpoint code set
/// (`None` = no code constraint) plus an optional duration band, both
/// inclusive. Construct via [`TargetSpec::all`] /
/// [`TargetSpec::for_codes`] and the `with_*` builders — the code set is
/// canonicalized (sorted, deduplicated) on every construction path, so
/// two specs built from permuted/duplicated code lists compare equal.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TargetSpec {
    /// Canonical (sorted, deduplicated) numeric phenx code set; `None`
    /// means "any code". Kept private so no path can bypass
    /// canonicalization — read it via [`TargetSpec::codes`].
    codes: Option<Vec<u32>>,
    /// Which endpoint the code set constrains.
    pub pos: TargetPos,
    /// Inclusive lower duration bound (in the mining duration unit).
    pub dur_min: Option<u32>,
    /// Inclusive upper duration bound.
    pub dur_max: Option<u32>,
}

impl TargetSpec {
    /// The untargeted spec: matches every pair and every duration.
    /// Mining under it is byte-identical to mining with no spec at all.
    pub fn all() -> TargetSpec {
        TargetSpec::default()
    }

    /// A spec matching pairs whose endpoint (per [`TargetPos::Either`])
    /// is in `codes`. The list is canonicalized: order and duplicates do
    /// not matter.
    pub fn for_codes(codes: impl IntoIterator<Item = u32>) -> TargetSpec {
        let mut v: Vec<u32> = codes.into_iter().collect();
        v.sort_unstable();
        v.dedup();
        TargetSpec { codes: Some(v), ..TargetSpec::default() }
    }

    /// This spec with the endpoint constraint moved to `pos`.
    pub fn with_pos(mut self, pos: TargetPos) -> TargetSpec {
        self.pos = pos;
        self
    }

    /// This spec with an inclusive duration band (`None` = unbounded on
    /// that side). Validation rejects inverted bands.
    pub fn with_duration_band(
        mut self,
        dur_min: Option<u32>,
        dur_max: Option<u32>,
    ) -> TargetSpec {
        self.dur_min = dur_min;
        self.dur_max = dur_max;
        self
    }

    /// The canonical code set, when the spec constrains codes.
    pub fn codes(&self) -> Option<&[u32]> {
        self.codes.as_deref()
    }

    /// True when the spec constrains nothing — no code set and no
    /// duration band. The engine treats such a spec exactly like no
    /// spec, so `TargetSpec::all()` reproduces the untargeted bytes.
    pub fn is_all(&self) -> bool {
        self.codes.is_none() && self.dur_min.is_none() && self.dur_max.is_none()
    }

    /// Structural validation (no vocabulary needed): rejects an *empty*
    /// code set (a spec that can never match is a caller bug — use
    /// [`TargetSpec::all`] for "no constraint") and inverted duration
    /// bands.
    pub fn validate(&self) -> Result<(), String> {
        if let Some(codes) = &self.codes {
            if codes.is_empty() {
                return Err(
                    "target: empty code set matches nothing — use TargetSpec::all() \
                     for an unconstrained mine"
                        .into(),
                );
            }
        }
        if let (Some(lo), Some(hi)) = (self.dur_min, self.dur_max) {
            if lo > hi {
                return Err(format!(
                    "target: inverted duration band ({lo} > {hi})"
                ));
            }
        }
        Ok(())
    }

    /// Vocabulary validation: every target code must be a phenx id the
    /// encoded dbmart actually contains (`< num_phenx`). Called where a
    /// cohort is in hand ([`crate::engine::Engine::plan`]); structural
    /// validation alone suffices elsewhere.
    pub fn validate_vocab(&self, num_phenx: u32) -> Result<(), String> {
        if let Some(codes) = &self.codes {
            if let Some(&bad) = codes.iter().find(|&&c| c >= num_phenx) {
                return Err(format!(
                    "target: code {bad} is outside the encoded vocabulary \
                     (cohort has {num_phenx} codes)"
                ));
            }
        }
        Ok(())
    }

    /// Does a `(first, second)` endpoint pair match the code/position
    /// constraint? (Duration is checked separately — the mining loop
    /// prunes on this *before* computing the duration.)
    #[inline]
    pub fn matches_pair(&self, first: u32, second: u32) -> bool {
        match &self.codes {
            None => true,
            Some(codes) => match self.pos {
                TargetPos::First => codes.binary_search(&first).is_ok(),
                TargetPos::Second => codes.binary_search(&second).is_ok(),
                TargetPos::Either => {
                    codes.binary_search(&first).is_ok()
                        || codes.binary_search(&second).is_ok()
                }
            },
        }
    }

    /// Does an already-encoded duration fall in the band?
    #[inline]
    pub fn matches_duration(&self, duration: u32) -> bool {
        self.dur_min.map_or(true, |lo| duration >= lo)
            && self.dur_max.map_or(true, |hi| duration <= hi)
    }

    /// The full per-record predicate — the filter a post-hoc pass over a
    /// full mine would apply. Pushdown safety (module docs) is exactly
    /// the statement that targeted mining emits the subset of records
    /// satisfying this.
    #[inline]
    pub fn matches_record(&self, r: &SeqRecord) -> bool {
        let (first, second) = decode_seq(r.seq);
        self.matches_pair(first, second) && self.matches_duration(r.duration)
    }

    /// Serialize for manifests and run configs. Only present fields are
    /// written, so an `all()` spec serializes to an empty object.
    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = Vec::new();
        if let Some(codes) = &self.codes {
            fields.push((
                "codes",
                Json::Arr(codes.iter().map(|&c| Json::from(c as u64)).collect()),
            ));
            fields.push(("pos", Json::from(self.pos.as_str())));
        }
        if let Some(lo) = self.dur_min {
            fields.push(("dur_min", Json::from(lo as u64)));
        }
        if let Some(hi) = self.dur_max {
            fields.push(("dur_max", Json::from(hi as u64)));
        }
        Json::obj(fields)
    }

    /// Parse a spec back from [`TargetSpec::to_json`] form. Unknown keys
    /// are ignored (manifests evolve append-only); the code list is
    /// re-canonicalized, so hand-edited manifests still yield canonical
    /// specs.
    pub fn from_json(j: &Json) -> Result<TargetSpec, String> {
        let codes = match j.get("codes") {
            None => None,
            Some(arr) => {
                let list = arr.as_arr().ok_or("target: codes must be an array")?;
                let mut v = Vec::with_capacity(list.len());
                for item in list {
                    let c = item
                        .as_u64()
                        .filter(|&c| c <= u32::MAX as u64)
                        .ok_or("target: codes must be u32 values")?;
                    v.push(c as u32);
                }
                v.sort_unstable();
                v.dedup();
                Some(v)
            }
        };
        let pos = match j.get("pos").and_then(Json::as_str) {
            None => TargetPos::Either,
            Some(s) => s.parse()?,
        };
        let parse_dur = |key: &str| -> Result<Option<u32>, String> {
            match j.get(key) {
                None => Ok(None),
                Some(v) => v
                    .as_u64()
                    .filter(|&d| d <= u32::MAX as u64)
                    .map(|d| Some(d as u32))
                    .ok_or_else(|| format!("target: {key} must be a u32")),
            }
        };
        Ok(TargetSpec {
            codes,
            pos,
            dur_min: parse_dur("dur_min")?,
            dur_max: parse_dur("dur_max")?,
        })
    }

    /// Compact human rendering for `list` / `SurfaceInfo` surfaces, e.g.
    /// `codes[3,7,9]@either dur[2..30]`. Stable because the code set is
    /// canonical.
    pub fn render(&self) -> String {
        if self.is_all() {
            return "all".into();
        }
        let mut out = String::new();
        if let Some(codes) = &self.codes {
            out.push_str("codes[");
            for (i, c) in codes.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(&c.to_string());
            }
            out.push_str(&format!("]@{}", self.pos));
        }
        if self.dur_min.is_some() || self.dur_max.is_some() {
            if !out.is_empty() {
                out.push(' ');
            }
            let lo = self.dur_min.map(|d| d.to_string()).unwrap_or_default();
            let hi = self.dur_max.map(|d| d.to_string()).unwrap_or_default();
            out.push_str(&format!("dur[{lo}..{hi}]"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dbmart::encode_seq;

    #[test]
    fn construction_is_order_and_duplicate_insensitive() {
        let a = TargetSpec::for_codes([9, 3, 7, 3, 9]);
        let b = TargetSpec::for_codes([3, 7, 9]);
        assert_eq!(a, b);
        assert_eq!(a.codes(), Some(&[3u32, 7, 9][..]));
    }

    #[test]
    fn all_matches_everything_and_validates() {
        let t = TargetSpec::all();
        assert!(t.is_all());
        t.validate().unwrap();
        t.validate_vocab(0).unwrap();
        assert!(t.matches_pair(0, 123));
        assert!(t.matches_duration(u32::MAX));
        assert!(t.matches_record(&SeqRecord { seq: encode_seq(5, 6), pid: 0, duration: 9 }));
    }

    #[test]
    fn empty_code_set_and_inverted_band_are_rejected() {
        let err = TargetSpec::for_codes([]).validate().unwrap_err();
        assert!(err.contains("empty"), "got {err}");
        let err = TargetSpec::all()
            .with_duration_band(Some(10), Some(3))
            .validate()
            .unwrap_err();
        assert!(err.contains("inverted"), "got {err}");
        // A half-open band is fine either way.
        TargetSpec::all().with_duration_band(Some(10), None).validate().unwrap();
        TargetSpec::all().with_duration_band(None, Some(3)).validate().unwrap();
    }

    #[test]
    fn vocab_validation_names_the_offending_code() {
        let t = TargetSpec::for_codes([2, 41]);
        t.validate_vocab(42).unwrap();
        let err = t.validate_vocab(41).unwrap_err();
        assert!(err.contains("41"), "got {err}");
    }

    #[test]
    fn position_constrains_the_right_endpoint() {
        let first = TargetSpec::for_codes([5]).with_pos(TargetPos::First);
        let second = TargetSpec::for_codes([5]).with_pos(TargetPos::Second);
        let either = TargetSpec::for_codes([5]);
        assert!(first.matches_pair(5, 9) && !first.matches_pair(9, 5));
        assert!(!second.matches_pair(5, 9) && second.matches_pair(9, 5));
        assert!(either.matches_pair(5, 9) && either.matches_pair(9, 5));
        assert!(!either.matches_pair(1, 2));
    }

    #[test]
    fn duration_band_is_inclusive() {
        let t = TargetSpec::all().with_duration_band(Some(2), Some(4));
        assert!(!t.matches_duration(1));
        assert!(t.matches_duration(2) && t.matches_duration(3) && t.matches_duration(4));
        assert!(!t.matches_duration(5));
    }

    #[test]
    fn matches_record_is_pair_and_band_conjunction() {
        let t = TargetSpec::for_codes([7])
            .with_pos(TargetPos::First)
            .with_duration_band(None, Some(10));
        let hit = SeqRecord { seq: encode_seq(7, 3), pid: 1, duration: 10 };
        let wrong_code = SeqRecord { seq: encode_seq(3, 7), pid: 1, duration: 5 };
        let wrong_dur = SeqRecord { seq: encode_seq(7, 3), pid: 1, duration: 11 };
        assert!(t.matches_record(&hit));
        assert!(!t.matches_record(&wrong_code));
        assert!(!t.matches_record(&wrong_dur));
    }

    #[test]
    fn json_round_trips_and_ignores_unknown_keys() {
        for spec in [
            TargetSpec::all(),
            TargetSpec::for_codes([4, 1, 4]).with_pos(TargetPos::Second),
            TargetSpec::for_codes([2]).with_duration_band(Some(1), Some(30)),
            TargetSpec::all().with_duration_band(None, Some(90)),
        ] {
            let j = spec.to_json();
            let back = TargetSpec::from_json(&j).unwrap();
            assert_eq!(back, spec, "{j:?}");
        }
        let j = Json::parse(r#"{"codes": [9, 2, 2], "pos": "first", "future_key": 1}"#)
            .unwrap();
        let t = TargetSpec::from_json(&j).unwrap();
        assert_eq!(t, TargetSpec::for_codes([2, 9]).with_pos(TargetPos::First));
        assert!(TargetSpec::from_json(
            &Json::parse(r#"{"codes": "nope"}"#).unwrap()
        )
        .is_err());
        assert!(TargetSpec::from_json(
            &Json::parse(r#"{"pos": "sideways"}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn render_is_stable_and_compact() {
        assert_eq!(TargetSpec::all().render(), "all");
        assert_eq!(
            TargetSpec::for_codes([9, 3]).with_pos(TargetPos::First).render(),
            "codes[3,9]@first"
        );
        assert_eq!(
            TargetSpec::for_codes([1])
                .with_duration_band(Some(2), Some(30))
                .render(),
            "codes[1]@either dur[2..30]"
        );
        assert_eq!(
            TargetSpec::all().with_duration_band(Some(5), None).render(),
            "dur[5..]"
        );
    }
}
