//! Adaptive dbmart partitioning — mining huge cohorts under a memory cap.
//!
//! The R package's headline utility: split the dbmart into patient chunks
//! whose *predicted* sequence output fits (a) the available memory and
//! (b) a hard element cap (R's 2³¹−1 vector limit, which made the paper's
//! 100k-patient run fail). Each chunk is sequenced separately and the
//! results are combined — trading extra sequencing passes for a bounded
//! resident set ("enables the sequencing of phenotypes on resource-
//! restrained platforms, like laptops").
//!
//! Prediction uses the exact per-patient formula `n·(n−1)/2` (after the
//! optional first-occurrence filter), so a partition plan never
//! underestimates: a chunk's real output equals its prediction.

use crate::dbmart::{NumericDbMart, NumericEntry};
use crate::engine::TspmError;
use crate::mining::{self, MiningConfig, SequenceSet};
use crate::sparsity::{self, SparsityConfig};

/// A partition plan: per-chunk patient ranges over the *sorted* dbmart.
#[derive(Clone, Debug)]
pub struct PartitionPlan {
    /// Sorted entries (by patient, date) the plan indexes into.
    pub entries: Vec<NumericEntry>,
    /// Patient chunk boundaries in `entries` (len = patients + 1).
    pub bounds: Vec<usize>,
    /// Chunks as ranges over *patient indices* (`bounds` windows).
    pub chunks: Vec<std::ops::Range<usize>>,
    /// Predicted sequences per chunk.
    pub predicted: Vec<u64>,
}

/// Partitioning errors.
#[derive(Debug)]
pub enum PartitionError {
    /// One single patient alone exceeds the cap — no partition can help.
    PatientExceedsCap { patient: u32, sequences: u64, cap: u64 },
}

impl std::fmt::Display for PartitionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PartitionError::PatientExceedsCap { patient, sequences, cap } => write!(
                f,
                "patient {patient} alone yields {sequences} sequences, above the cap {cap}"
            ),
        }
    }
}

impl std::error::Error for PartitionError {}

/// Derive the element cap from a memory budget in bytes (16 bytes per
/// sequence record), clamped by the hard element cap.
pub fn cap_from_memory(budget_bytes: u64, hard_element_cap: u64) -> u64 {
    (budget_bytes / std::mem::size_of::<crate::mining::SeqRecord>() as u64)
        .min(hard_element_cap)
        .max(1)
}

/// Build a partition plan such that every chunk's predicted sequence count
/// is ≤ `max_sequences_per_chunk`.
pub fn plan(
    db: &NumericDbMart,
    cfg: &MiningConfig,
    max_sequences_per_chunk: u64,
) -> Result<PartitionPlan, PartitionError> {
    let mut entries = db.entries.clone();
    let threads = cfg.worker_threads();
    let bounds = mining::sort_and_chunk(&mut entries, threads);
    let n_patients = bounds.len().saturating_sub(1);

    let mut chunks = Vec::new();
    let mut predicted = Vec::new();
    let mut start = 0usize;
    let mut acc = 0u64;
    for p in 0..n_patients {
        let chunk = &entries[bounds[p]..bounds[p + 1]];
        let n = if cfg.first_occurrence_only {
            let mut seen: Vec<u32> = chunk.iter().map(|e| e.phenx).collect();
            seen.sort_unstable();
            seen.dedup();
            seen.len()
        } else {
            chunk.len()
        };
        let cost = mining::pairs_for(n.max(1));
        if cost > max_sequences_per_chunk {
            return Err(PartitionError::PatientExceedsCap {
                patient: chunk[0].patient,
                sequences: cost,
                cap: max_sequences_per_chunk,
            });
        }
        if acc + cost > max_sequences_per_chunk && p > start {
            chunks.push(start..p);
            predicted.push(acc);
            start = p;
            acc = 0;
        }
        acc += cost;
    }
    if start < n_patients {
        chunks.push(start..n_patients);
        predicted.push(acc);
    }
    Ok(PartitionPlan { entries, bounds, chunks, predicted })
}

impl PartitionPlan {
    /// Number of chunks.
    pub fn len(&self) -> usize {
        self.chunks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.chunks.is_empty()
    }

    /// Total predicted sequences across all chunks.
    pub fn total_predicted(&self) -> u64 {
        self.predicted.iter().sum()
    }

    /// Materialise chunk `i` as a standalone numeric dbmart view
    /// (entries only; lookup tables stay with the parent).
    pub fn chunk_entries(&self, i: usize) -> &[NumericEntry] {
        let r = &self.chunks[i];
        &self.entries[self.bounds[r.start]..self.bounds[r.end]]
    }
}

/// Mine a whole dbmart chunk-by-chunk under the cap, screening each chunk
/// then merging — the R package's "adaptive partitioning" workflow.
///
/// Note: screening per chunk then merging is only equivalent to a global
/// screen when the threshold counts patients *within* a chunk; the R
/// package has the same semantics (it screens per partition). For a
/// global screen, pass `screen: None` and screen the merged result.
pub fn mine_partitioned(
    db: &NumericDbMart,
    cfg: &MiningConfig,
    max_sequences_per_chunk: u64,
    screen: Option<&SparsityConfig>,
) -> Result<SequenceSet, TspmError> {
    let plan = plan(db, cfg, max_sequences_per_chunk)?;
    let mut merged = SequenceSet {
        records: Vec::new(),
        num_patients: db.num_patients() as u32,
        num_phenx: db.num_phenx() as u32,
    };
    for i in 0..plan.len() {
        let sub = NumericDbMart {
            entries: plan.chunk_entries(i).to_vec(),
            lookup: Default::default(),
        };
        let mut set = mining::mine_sequences(&sub, cfg)?;
        debug_assert!(set.len() as u64 <= max_sequences_per_chunk);
        if let Some(sc) = screen {
            sparsity::screen(&mut set.records, sc);
        }
        merged.records.extend_from_slice(&set.records);
    }
    Ok(merged)
}

/// Deprecated alias kept for one release: the mining-or-partitioning
/// combinator has been absorbed into the unified
/// [`crate::engine::TspmError`] (`Mining` and `Partition` variants).
#[deprecated(since = "0.2.0", note = "use `crate::engine::TspmError` instead")]
pub type MiningErrorOrPartition = TspmError;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dbmart::{DbMart, DbMartEntry};

    fn db_with_sizes(sizes: &[usize]) -> NumericDbMart {
        let mut entries = Vec::new();
        for (p, &n) in sizes.iter().enumerate() {
            for i in 0..n {
                entries.push(DbMartEntry {
                    patient_id: format!("p{p}"),
                    date: i as i32,
                    phenx: format!("x{i}"),
                    description: None,
                });
            }
        }
        NumericDbMart::encode(&DbMart::new(entries))
    }

    #[test]
    fn respects_cap() {
        let db = db_with_sizes(&[10, 10, 10, 10]); // 45 seqs each
        let plan = plan(&db, &MiningConfig::default(), 100).unwrap();
        assert!(plan.len() >= 2);
        for (i, &p) in plan.predicted.iter().enumerate() {
            assert!(p <= 100, "chunk {i} predicted {p}");
        }
        assert_eq!(plan.total_predicted(), 4 * 45);
    }

    #[test]
    fn one_chunk_when_cap_is_large() {
        let db = db_with_sizes(&[10, 10]);
        let plan = plan(&db, &MiningConfig::default(), 1_000_000).unwrap();
        assert_eq!(plan.len(), 1);
    }

    #[test]
    fn oversized_patient_is_an_error() {
        let db = db_with_sizes(&[100]); // 4950 sequences
        let err = plan(&db, &MiningConfig::default(), 100).unwrap_err();
        match err {
            PartitionError::PatientExceedsCap { sequences, cap, .. } => {
                assert_eq!(sequences, 4950);
                assert_eq!(cap, 100);
            }
        }
    }

    #[test]
    fn partitioned_mining_equals_unpartitioned() {
        let mart = crate::synthea::SyntheaConfig::small().generate();
        let db = NumericDbMart::encode(&mart);
        let cfg = MiningConfig::default();
        let full = mining::mine_sequences(&db, &cfg).unwrap();
        let parts = mine_partitioned(&db, &cfg, 50_000, None).unwrap();
        let mut a = full.records.clone();
        let mut b = parts.records.clone();
        a.sort_unstable_by_key(|r| (r.seq, r.pid, r.duration));
        b.sort_unstable_by_key(|r| (r.seq, r.pid, r.duration));
        assert_eq!(a, b);
    }

    #[test]
    fn r_vector_limit_scenario() {
        // Reproduces Table 2's failure mode in miniature: a cap below the
        // total forces multiple chunks instead of one giant failing run.
        let db = db_with_sizes(&[50, 50, 50]); // 1225 each, 3675 total
        let plan = plan(&db, &MiningConfig::default(), 2000).unwrap();
        assert!(plan.len() >= 2);
    }

    #[test]
    fn cap_from_memory_converts_bytes() {
        assert_eq!(cap_from_memory(160, u64::MAX), 10);
        assert_eq!(cap_from_memory(u64::MAX, (1 << 31) - 1), (1 << 31) - 1);
        assert_eq!(cap_from_memory(0, 100), 1);
    }

    #[test]
    fn first_occurrence_prediction_is_exact() {
        let mut entries = Vec::new();
        for i in 0..20 {
            entries.push(DbMartEntry {
                patient_id: "p".into(),
                date: i,
                phenx: format!("x{}", i % 5), // 5 distinct
                description: None,
            });
        }
        let db = NumericDbMart::encode(&DbMart::new(entries));
        let cfg = MiningConfig { first_occurrence_only: true, ..Default::default() };
        let plan = plan(&db, &cfg, 1_000).unwrap();
        assert_eq!(plan.total_predicted(), 10); // C(5,2)
        let mined = mining::mine_sequences(&db, &cfg).unwrap();
        assert_eq!(mined.len() as u64, plan.total_predicted());
    }
}
