//! Index-artifact builder and loader — see the [module docs](crate::query)
//! for the on-disk layout and the compatibility guarantee.
//!
//! [`build`] streams a sorted [`SeqFileSet`] exactly once, copying the
//! records into the artifact's own data file while accumulating the
//! sparse block index and the per-sequence table, so the artifact is
//! self-contained (the source spill directory can be deleted afterwards)
//! and the build's resident set is one read buffer plus the two tables.
//! [`SeqIndex::open`] validates the manifest's format/version, both
//! table checksums, and the data file's record count before answering
//! anything; [`SeqIndex::verify_data`] optionally re-checksums the full
//! data file.

use super::QueryError;
use crate::json::Json;
use crate::metrics::MemTracker;
use crate::mining::SeqRecord;
use crate::seqstore::{self, SeqFileSet, SeqReader, SeqWriter, RECORD_BYTES};
use std::io;
use std::path::{Path, PathBuf};

/// Manifest `format` value of an index artifact.
pub const INDEX_FORMAT: &str = "tspm-seqindex";
/// Layout version this build reads and writes. Bump on any change to
/// the file layouts below; [`SeqIndex::open`] refuses other versions.
pub const INDEX_FORMAT_VERSION: u64 = 1;
/// Manifest `format` value of a spilled-run input manifest
/// (`tspm mine --out-dir`).
pub const SPILL_FORMAT: &str = "tspm-spill";
/// Version of the spill manifest scheme.
pub const SPILL_FORMAT_VERSION: u64 = 1;

/// Default records per index block — the query layer's unit of IO and
/// of resident memory (64 KiB of records at the 16-byte record size).
pub const DEFAULT_BLOCK_RECORDS: usize = 4096;

const MANIFEST_FILE: &str = "manifest.json";
const DATA_FILE: &str = "data_0000.tspm";
const BLOCKS_FILE: &str = "blocks.bin";
const SEQS_FILE: &str = "seqs.bin";

const BLOCKS_MAGIC: &[u8; 8] = b"TSPMBIX1";
const SEQS_MAGIC: &[u8; 8] = b"TSPMSQT1";
const TABLE_HEADER_BYTES: usize = 16; // magic + count
const BLOCK_ENTRY_BYTES: usize = 52;
const SEQ_ENTRY_BYTES: usize = 36;

const ZERO_REC: SeqRecord = SeqRecord { seq: 0, pid: 0, duration: 0 };

// ---------------------------------------------------------------------------
// Checksums
// ---------------------------------------------------------------------------

/// FNV-1a 64-bit offset basis.
pub const FNV1A64_INIT: u64 = 0xcbf2_9ce4_8422_2325;

/// Fold `bytes` into an FNV-1a 64 state.
#[inline]
pub fn fnv1a64(mut state: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        state ^= b as u64;
        state = state.wrapping_mul(0x100_0000_01b3);
    }
    state
}

fn checksum_hex(h: u64) -> String {
    format!("{h:016x}")
}

/// Stream one TSPMSEQ1 file, returning its record count and the hex
/// FNV-1a checksum over the 16-byte LE record encodings (header
/// excluded, so the checksum is a property of the record sequence, not
/// of incidental file framing).
pub fn checksum_records(path: &Path) -> Result<(u64, String), QueryError> {
    let mut reader = SeqReader::open(path)?;
    let mut buf = vec![ZERO_REC; 8192];
    let mut h = FNV1A64_INIT;
    let mut n = 0u64;
    loop {
        let got = reader.read_batch(&mut buf)?;
        if got == 0 {
            break;
        }
        for &r in &buf[..got] {
            h = fnv1a64(h, &seqstore::encode_record(r));
        }
        n += got as u64;
    }
    Ok((n, checksum_hex(h)))
}

// ---------------------------------------------------------------------------
// Spill-run input manifests (tspm mine --out-dir)
// ---------------------------------------------------------------------------

/// The verified description of a spilled run directory: the
/// reconstructed [`SeqFileSet`], whether its records are globally
/// sorted (the screen's spill order), and each file's recorded count +
/// checksum for [`SpillManifest::verify`].
#[derive(Clone, Debug)]
pub struct SpillManifest {
    pub files: SeqFileSet,
    /// Whether the records are globally `(seq, pid, duration)`-sorted —
    /// true exactly when the run included the sparsity screen.
    pub sorted: bool,
    /// `(path, records, checksum)` per spill file, as recorded at write
    /// time.
    pub per_file: Vec<(PathBuf, u64, String)>,
}

impl SpillManifest {
    /// Re-checksum every spill file against the manifest: detects
    /// deleted, truncated, or otherwise modified inputs before an index
    /// build consumes them.
    pub fn verify(&self) -> Result<(), QueryError> {
        let mut total = 0u64;
        for (path, records, checksum) in &self.per_file {
            let (n, sum) = checksum_records(path)?;
            if n != *records || sum != *checksum {
                return Err(QueryError::Artifact(format!(
                    "{}: spill file changed since its manifest was written \
                     (recorded {records} records / {checksum}, found {n} / {sum})",
                    path.display()
                )));
            }
            total += n;
        }
        if total != self.files.total_records {
            return Err(QueryError::Artifact(format!(
                "spill manifest total_records {} disagrees with the per-file sum {total}",
                self.files.total_records
            )));
        }
        Ok(())
    }
}

/// Write `manifest.json` describing a spilled run into `dir`: format +
/// version, counts, sortedness, and each file's record count + record
/// checksum. `tspm mine --out-dir` calls this so `tspm index` can
/// verify its input before building. File entries are stored relative
/// to `dir` (spill files may sit in subdirectories, e.g. the `mine/`
/// directory of an unscreened run). Computing the checksums costs one
/// sequential re-read of the spill files — the price of the integrity
/// record; [`build_verified`] then re-checks them for free during its
/// own streaming pass.
pub fn write_spill_manifest(
    dir: &Path,
    files: &SeqFileSet,
    sorted: bool,
) -> Result<(), QueryError> {
    let mut entries = Vec::with_capacity(files.files.len());
    for f in &files.files {
        // Relative to the manifest's directory when possible; an
        // absolute fallback keeps out-of-tree files resolvable
        // (`dir.join(absolute)` is the absolute path again).
        let rel = f.strip_prefix(dir).unwrap_or(f);
        let name = rel
            .to_str()
            .ok_or_else(|| {
                QueryError::Invalid(format!(
                    "{}: spill file needs a UTF-8 path for the manifest",
                    f.display()
                ))
            })?
            .to_string();
        let (n, sum) = checksum_records(f)?;
        entries.push(Json::obj(vec![
            ("name", Json::from(name)),
            ("records", Json::from(n)),
            ("checksum", Json::from(sum)),
        ]));
    }
    let j = Json::obj(vec![
        ("format", Json::from(SPILL_FORMAT)),
        ("version", Json::from(SPILL_FORMAT_VERSION)),
        ("total_records", Json::from(files.total_records)),
        ("num_patients", Json::from(files.num_patients as u64)),
        ("num_phenx", Json::from(files.num_phenx as u64)),
        ("sorted", Json::from(sorted)),
        ("files", Json::Arr(entries)),
    ]);
    std::fs::write(dir.join(MANIFEST_FILE), j.to_string_pretty())?;
    Ok(())
}

/// Read a spilled run's `manifest.json` back; file names resolve
/// relative to `dir`. Checksums are *not* re-verified here — call
/// [`SpillManifest::verify`] for that.
pub fn read_spill_manifest(dir: &Path) -> Result<SpillManifest, QueryError> {
    let path = dir.join(MANIFEST_FILE);
    let j = read_manifest_json(&path, SPILL_FORMAT, SPILL_FORMAT_VERSION)?;
    let total_records = req_u64(&j, "total_records", &path)?;
    let num_patients = req_u64(&j, "num_patients", &path)? as u32;
    let num_phenx = req_u64(&j, "num_phenx", &path)? as u32;
    let sorted = j
        .get("sorted")
        .and_then(Json::as_bool)
        .ok_or_else(|| field_err(&path, "sorted"))?;
    let list = j
        .get("files")
        .and_then(Json::as_arr)
        .ok_or_else(|| field_err(&path, "files"))?;
    let mut files = Vec::with_capacity(list.len());
    let mut per_file = Vec::with_capacity(list.len());
    for item in list {
        let name = item
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| field_err(&path, "files[].name"))?;
        let records = req_u64(item, "records", &path)?;
        let checksum = item
            .get("checksum")
            .and_then(Json::as_str)
            .ok_or_else(|| field_err(&path, "files[].checksum"))?;
        let full = dir.join(name);
        files.push(full.clone());
        per_file.push((full, records, checksum.to_string()));
    }
    Ok(SpillManifest {
        files: SeqFileSet { files, total_records, num_patients, num_phenx },
        sorted,
        per_file,
    })
}

// ---------------------------------------------------------------------------
// Index entries and configuration
// ---------------------------------------------------------------------------

/// One entry of the sparse block index: a fixed-size run of
/// `block_records` consecutive records of the data file, with the key
/// range it spans and per-block pid/duration bounds for pruning.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BlockMeta {
    /// First record (0-based offset into the data file).
    pub start: u64,
    /// Records in the block (equal to the block size except the tail).
    pub len: u32,
    pub first_seq: u64,
    pub first_pid: u32,
    pub last_seq: u64,
    pub last_pid: u32,
    /// Smallest/largest pid occurring anywhere in the block (not the
    /// first/last — sequences restart the pid order inside a block).
    pub pid_min: u32,
    pub pid_max: u32,
    /// Duration bounds over the block, for range-query pruning.
    pub dur_min: u32,
    pub dur_max: u32,
}

/// One entry of the per-sequence table: where the sequence's records
/// live and its pre-aggregated support statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SeqTableEntry {
    pub seq: u64,
    /// First record of the sequence's run in the data file.
    pub start: u64,
    /// Records in the run.
    pub count: u64,
    /// Distinct patients — the sequence's support (the same count the
    /// sparsity screen thresholds on).
    pub patients: u32,
    pub dur_min: u32,
    pub dur_max: u32,
}

/// Build-time configuration.
#[derive(Clone, Copy, Debug)]
pub struct IndexConfig {
    /// Records per index block ([`DEFAULT_BLOCK_RECORDS`]); also the
    /// query service's read-buffer size.
    pub block_records: usize,
}

impl Default for IndexConfig {
    fn default() -> Self {
        IndexConfig { block_records: DEFAULT_BLOCK_RECORDS }
    }
}

// ---------------------------------------------------------------------------
// The artifact
// ---------------------------------------------------------------------------

/// A loaded (or just-built) index artifact: the resident tables plus
/// the path of the on-disk data file they describe.
#[derive(Clone, Debug)]
pub struct SeqIndex {
    /// The artifact directory.
    pub dir: PathBuf,
    /// The TSPMSEQ1 data file all offsets refer to.
    pub data_path: PathBuf,
    pub block_records: usize,
    pub total_records: u64,
    pub num_patients: u32,
    pub num_phenx: u32,
    /// Hex FNV-1a checksum over the data file's record encodings (from
    /// the manifest; verified on demand by [`SeqIndex::verify_data`]).
    pub data_checksum: String,
    /// Total on-disk size of the artifact (data + tables + manifest).
    pub artifact_bytes: u64,
    /// The sparse block index, in data-file order.
    pub blocks: Vec<BlockMeta>,
    /// The per-sequence table, sorted by `seq`.
    pub seqs: Vec<SeqTableEntry>,
}

impl SeqIndex {
    /// Number of distinct sequences in the artifact.
    pub fn distinct_seqs(&self) -> u64 {
        self.seqs.len() as u64
    }

    /// The table entry for `seq`, if the sequence is present.
    pub fn seq_entry(&self, seq: u64) -> Option<&SeqTableEntry> {
        self.seqs
            .binary_search_by_key(&seq, |e| e.seq)
            .ok()
            .map(|i| &self.seqs[i])
    }

    /// Open an artifact directory: parse + version-check the manifest,
    /// load both tables (verifying their checksums), and cross-check
    /// the data file's header count. O(tables), not O(data) — use
    /// [`SeqIndex::verify_data`] for the full data checksum.
    pub fn open(dir: &Path) -> Result<SeqIndex, QueryError> {
        let manifest_path = dir.join(MANIFEST_FILE);
        let j = read_manifest_json(&manifest_path, INDEX_FORMAT, INDEX_FORMAT_VERSION)?;
        let block_records = req_u64(&j, "block_records", &manifest_path)? as usize;
        if block_records == 0 {
            return Err(QueryError::Artifact(format!(
                "{}: block_records must be ≥ 1",
                manifest_path.display()
            )));
        }
        let total_records = req_u64(&j, "total_records", &manifest_path)?;
        let num_patients = req_u64(&j, "num_patients", &manifest_path)? as u32;
        let num_phenx = req_u64(&j, "num_phenx", &manifest_path)? as u32;

        let (data_name, data_records, data_checksum) =
            file_section(&j, "data", &manifest_path)?;
        let (blocks_name, block_count, blocks_checksum) =
            file_section(&j, "blocks", &manifest_path)?;
        let (seqs_name, seq_count, seqs_checksum) =
            file_section(&j, "seqs", &manifest_path)?;
        if data_records != total_records {
            return Err(QueryError::Artifact(format!(
                "{}: data.records {data_records} disagrees with total_records {total_records}",
                manifest_path.display()
            )));
        }

        let blocks_bytes = read_table_file(
            &dir.join(&blocks_name),
            BLOCKS_MAGIC,
            block_count,
            BLOCK_ENTRY_BYTES,
            &blocks_checksum,
        )?;
        let mut blocks = Vec::with_capacity(block_count as usize);
        let mut off = TABLE_HEADER_BYTES;
        for _ in 0..block_count {
            blocks.push(BlockMeta {
                start: read_u64(&blocks_bytes, &mut off),
                len: read_u32(&blocks_bytes, &mut off),
                first_seq: read_u64(&blocks_bytes, &mut off),
                first_pid: read_u32(&blocks_bytes, &mut off),
                last_seq: read_u64(&blocks_bytes, &mut off),
                last_pid: read_u32(&blocks_bytes, &mut off),
                pid_min: read_u32(&blocks_bytes, &mut off),
                pid_max: read_u32(&blocks_bytes, &mut off),
                dur_min: read_u32(&blocks_bytes, &mut off),
                dur_max: read_u32(&blocks_bytes, &mut off),
            });
        }

        let seqs_bytes = read_table_file(
            &dir.join(&seqs_name),
            SEQS_MAGIC,
            seq_count,
            SEQ_ENTRY_BYTES,
            &seqs_checksum,
        )?;
        let mut seqs = Vec::with_capacity(seq_count as usize);
        let mut off = TABLE_HEADER_BYTES;
        for _ in 0..seq_count {
            seqs.push(SeqTableEntry {
                seq: read_u64(&seqs_bytes, &mut off),
                start: read_u64(&seqs_bytes, &mut off),
                count: read_u64(&seqs_bytes, &mut off),
                patients: read_u32(&seqs_bytes, &mut off),
                dur_min: read_u32(&seqs_bytes, &mut off),
                dur_max: read_u32(&seqs_bytes, &mut off),
            });
        }
        if seqs.windows(2).any(|w| w[0].seq >= w[1].seq) {
            return Err(QueryError::Artifact(format!(
                "{}: sequence table is not strictly sorted by seq",
                dir.join(&seqs_name).display()
            )));
        }

        let data_path = dir.join(&data_name);
        let reader = SeqReader::open(&data_path)?;
        if reader.total() != total_records {
            return Err(QueryError::Artifact(format!(
                "{}: data file holds {} records but the manifest claims {total_records}",
                data_path.display(),
                reader.total()
            )));
        }
        drop(reader);

        let manifest_len = std::fs::metadata(&manifest_path)?.len();
        let artifact_bytes = std::fs::metadata(&data_path)?.len()
            + blocks_bytes.len() as u64
            + seqs_bytes.len() as u64
            + manifest_len;

        Ok(SeqIndex {
            dir: dir.to_path_buf(),
            data_path,
            block_records,
            total_records,
            num_patients,
            num_phenx,
            data_checksum,
            artifact_bytes,
            blocks,
            seqs,
        })
    }

    /// Full integrity check of the data file: re-checksums every record
    /// against the manifest. O(data) — an explicit opt-in.
    pub fn verify_data(&self) -> Result<(), QueryError> {
        let (n, sum) = checksum_records(&self.data_path)?;
        if n != self.total_records || sum != self.data_checksum {
            return Err(QueryError::Artifact(format!(
                "{}: data checksum mismatch (manifest {} records / {}, found {n} / {sum})",
                self.data_path.display(),
                self.total_records,
                self.data_checksum
            )));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Build
// ---------------------------------------------------------------------------

/// Build an index artifact under `out_dir` from a **sorted** spilled
/// result (the order [`crate::sparsity::screen_spilled`] writes:
/// globally by `(seq, pid, duration)` across the file set's
/// concatenation). Streams the input exactly once; unsorted input is a
/// typed [`QueryError::Artifact`], never a silently wrong index.
/// `tracker`, when provided, accounts the build's read buffer and table
/// serialization buffers. On *any* failure the partially written
/// artifact files are removed — `out_dir` never holds a half-built (or
/// old-manifest/new-data) mix.
pub fn build(
    input: &SeqFileSet,
    out_dir: &Path,
    cfg: &IndexConfig,
    tracker: Option<&MemTracker>,
) -> Result<SeqIndex, QueryError> {
    // Validate before build_impl touches (truncates) any artifact file,
    // so a bad config cannot cost an existing artifact its data file.
    if cfg.block_records == 0 {
        return Err(QueryError::Invalid("index block_records must be ≥ 1".into()));
    }
    let result = build_impl(input, out_dir, cfg, None, tracker);
    if result.is_err() {
        remove_partial_artifact(out_dir);
    }
    result
}

/// [`build`], additionally verifying every input file against the spill
/// manifest's recorded count + checksum **during** the build's own
/// streaming pass — integrity checking without a separate read of the
/// (potentially out-of-core-sized) input.
pub fn build_verified(
    manifest: &SpillManifest,
    out_dir: &Path,
    cfg: &IndexConfig,
    tracker: Option<&MemTracker>,
) -> Result<SeqIndex, QueryError> {
    if cfg.block_records == 0 {
        return Err(QueryError::Invalid("index block_records must be ≥ 1".into()));
    }
    if manifest.per_file.len() != manifest.files.files.len() {
        return Err(QueryError::Artifact(format!(
            "spill manifest lists {} checksums for {} files",
            manifest.per_file.len(),
            manifest.files.files.len()
        )));
    }
    let result = build_impl(&manifest.files, out_dir, cfg, Some(&manifest.per_file), tracker);
    if result.is_err() {
        remove_partial_artifact(out_dir);
    }
    result
}

/// Best-effort removal of every artifact file — called on failed
/// builds so a stale manifest can never describe fresher partial data.
fn remove_partial_artifact(out_dir: &Path) {
    for name in [DATA_FILE, BLOCKS_FILE, SEQS_FILE, MANIFEST_FILE] {
        let _ = std::fs::remove_file(out_dir.join(name));
    }
}

fn build_impl(
    input: &SeqFileSet,
    out_dir: &Path,
    cfg: &IndexConfig,
    expected: Option<&[(PathBuf, u64, String)]>,
    tracker: Option<&MemTracker>,
) -> Result<SeqIndex, QueryError> {
    if cfg.block_records == 0 {
        return Err(QueryError::Invalid("index block_records must be ≥ 1".into()));
    }
    let block_records = cfg.block_records;
    std::fs::create_dir_all(out_dir)?;
    let track = |b: u64| {
        if let Some(t) = tracker {
            t.add(b)
        }
    };
    let untrack = |b: u64| {
        if let Some(t) = tracker {
            t.sub(b)
        }
    };

    let data_path = out_dir.join(DATA_FILE);
    let mut writer = SeqWriter::create(&data_path)?;

    let mut blocks: Vec<BlockMeta> = Vec::new();
    let mut seqs: Vec<SeqTableEntry> = Vec::new();
    let mut block = BlockMeta::default();
    let mut se = SeqTableEntry::default();
    let mut seq_open = false;
    let mut last_pid_in_seq = 0u32;
    let mut prev: Option<SeqRecord> = None;
    let mut data_fnv = FNV1A64_INIT;
    let mut n = 0u64;

    let read_cap = block_records.clamp(1024, 64 * 1024);
    let mut buf = vec![ZERO_REC; read_cap];
    track((read_cap * RECORD_BYTES) as u64);
    for (fi, path) in input.files.iter().enumerate() {
        let mut reader = SeqReader::open(path)?;
        let mut file_fnv = FNV1A64_INIT;
        let mut file_records = 0u64;
        loop {
            let got = reader.read_batch(&mut buf)?;
            if got == 0 {
                break;
            }
            for &r in &buf[..got] {
                if let Some(p) = prev {
                    if (p.seq, p.pid, p.duration) > (r.seq, r.pid, r.duration) {
                        return Err(QueryError::Artifact(format!(
                            "{}: records are not sorted by (seq, pid, duration) at \
                             record {n} — the index consumes the *screened* spill \
                             output (run the sparsity screen first)",
                            path.display()
                        )));
                    }
                }
                prev = Some(r);
                writer.write(r)?;
                let encoded = seqstore::encode_record(r);
                data_fnv = fnv1a64(data_fnv, &encoded);
                file_fnv = fnv1a64(file_fnv, &encoded);
                file_records += 1;

                // Block accounting (len == 0 means "no open block").
                if block.len == 0 {
                    block = BlockMeta {
                        start: n,
                        len: 0,
                        first_seq: r.seq,
                        first_pid: r.pid,
                        last_seq: r.seq,
                        last_pid: r.pid,
                        pid_min: r.pid,
                        pid_max: r.pid,
                        dur_min: r.duration,
                        dur_max: r.duration,
                    };
                }
                block.len += 1;
                block.last_seq = r.seq;
                block.last_pid = r.pid;
                block.pid_min = block.pid_min.min(r.pid);
                block.pid_max = block.pid_max.max(r.pid);
                block.dur_min = block.dur_min.min(r.duration);
                block.dur_max = block.dur_max.max(r.duration);
                if block.len as usize >= block_records {
                    blocks.push(block);
                    block.len = 0;
                }

                // Per-sequence accounting.
                if !seq_open || se.seq != r.seq {
                    if seq_open {
                        seqs.push(se);
                    }
                    se = SeqTableEntry {
                        seq: r.seq,
                        start: n,
                        count: 0,
                        patients: 1,
                        dur_min: r.duration,
                        dur_max: r.duration,
                    };
                    seq_open = true;
                    last_pid_in_seq = r.pid;
                } else if r.pid != last_pid_in_seq {
                    se.patients += 1;
                    last_pid_in_seq = r.pid;
                }
                se.count += 1;
                se.dur_min = se.dur_min.min(r.duration);
                se.dur_max = se.dur_max.max(r.duration);

                n += 1;
            }
        }
        if let Some(exp) = expected {
            let (epath, erecords, esum) = &exp[fi];
            let sum = checksum_hex(file_fnv);
            if file_records != *erecords || sum != *esum {
                return Err(QueryError::Artifact(format!(
                    "{}: spill file does not match its manifest (recorded {erecords} \
                     records / {esum}, found {file_records} / {sum})",
                    epath.display()
                )));
            }
        }
    }
    if block.len > 0 {
        blocks.push(block);
    }
    if seq_open {
        seqs.push(se);
    }
    untrack((read_cap * RECORD_BYTES) as u64);
    drop(buf);

    let written = writer.finish()?;
    if written != input.total_records {
        return Err(QueryError::Artifact(format!(
            "input file set claims {} records but {written} were read — its manifest \
             is stale",
            input.total_records
        )));
    }

    // Serialize the tables with checksums over the full file bytes.
    let blocks_bytes = {
        let mut out = Vec::with_capacity(TABLE_HEADER_BYTES + blocks.len() * BLOCK_ENTRY_BYTES);
        out.extend_from_slice(BLOCKS_MAGIC);
        out.extend_from_slice(&(blocks.len() as u64).to_le_bytes());
        for b in &blocks {
            out.extend_from_slice(&b.start.to_le_bytes());
            out.extend_from_slice(&b.len.to_le_bytes());
            out.extend_from_slice(&b.first_seq.to_le_bytes());
            out.extend_from_slice(&b.first_pid.to_le_bytes());
            out.extend_from_slice(&b.last_seq.to_le_bytes());
            out.extend_from_slice(&b.last_pid.to_le_bytes());
            out.extend_from_slice(&b.pid_min.to_le_bytes());
            out.extend_from_slice(&b.pid_max.to_le_bytes());
            out.extend_from_slice(&b.dur_min.to_le_bytes());
            out.extend_from_slice(&b.dur_max.to_le_bytes());
        }
        out
    };
    let seqs_bytes = {
        let mut out = Vec::with_capacity(TABLE_HEADER_BYTES + seqs.len() * SEQ_ENTRY_BYTES);
        out.extend_from_slice(SEQS_MAGIC);
        out.extend_from_slice(&(seqs.len() as u64).to_le_bytes());
        for e in &seqs {
            out.extend_from_slice(&e.seq.to_le_bytes());
            out.extend_from_slice(&e.start.to_le_bytes());
            out.extend_from_slice(&e.count.to_le_bytes());
            out.extend_from_slice(&e.patients.to_le_bytes());
            out.extend_from_slice(&e.dur_min.to_le_bytes());
            out.extend_from_slice(&e.dur_max.to_le_bytes());
        }
        out
    };
    track((blocks_bytes.len() + seqs_bytes.len()) as u64);
    let blocks_checksum = checksum_hex(fnv1a64(FNV1A64_INIT, &blocks_bytes));
    let seqs_checksum = checksum_hex(fnv1a64(FNV1A64_INIT, &seqs_bytes));
    std::fs::write(out_dir.join(BLOCKS_FILE), &blocks_bytes)?;
    std::fs::write(out_dir.join(SEQS_FILE), &seqs_bytes)?;
    untrack((blocks_bytes.len() + seqs_bytes.len()) as u64);
    let (blocks_len, seqs_len) = (blocks_bytes.len() as u64, seqs_bytes.len() as u64);
    drop(blocks_bytes);
    drop(seqs_bytes);

    let data_checksum = checksum_hex(data_fnv);
    let manifest = Json::obj(vec![
        ("format", Json::from(INDEX_FORMAT)),
        ("version", Json::from(INDEX_FORMAT_VERSION)),
        ("block_records", Json::from(block_records)),
        ("total_records", Json::from(written)),
        ("num_patients", Json::from(input.num_patients as u64)),
        ("num_phenx", Json::from(input.num_phenx as u64)),
        ("distinct_seqs", Json::from(seqs.len())),
        (
            "data",
            Json::obj(vec![
                ("name", Json::from(DATA_FILE)),
                ("records", Json::from(written)),
                ("checksum", Json::from(data_checksum.clone())),
            ]),
        ),
        (
            "blocks",
            Json::obj(vec![
                ("name", Json::from(BLOCKS_FILE)),
                ("count", Json::from(blocks.len())),
                ("checksum", Json::from(blocks_checksum)),
            ]),
        ),
        (
            "seqs",
            Json::obj(vec![
                ("name", Json::from(SEQS_FILE)),
                ("count", Json::from(seqs.len())),
                ("checksum", Json::from(seqs_checksum)),
            ]),
        ),
    ]);
    let manifest_text = manifest.to_string_pretty();
    std::fs::write(out_dir.join(MANIFEST_FILE), &manifest_text)?;

    let artifact_bytes = std::fs::metadata(&data_path)?.len()
        + blocks_len
        + seqs_len
        + manifest_text.len() as u64;

    Ok(SeqIndex {
        dir: out_dir.to_path_buf(),
        data_path,
        block_records,
        total_records: written,
        num_patients: input.num_patients,
        num_phenx: input.num_phenx,
        data_checksum,
        artifact_bytes,
        blocks,
        seqs,
    })
}

// ---------------------------------------------------------------------------
// Parsing helpers
// ---------------------------------------------------------------------------

fn field_err(path: &Path, field: &str) -> QueryError {
    QueryError::Artifact(format!("{}: missing or invalid field {field:?}", path.display()))
}

fn req_u64(j: &Json, field: &str, path: &Path) -> Result<u64, QueryError> {
    j.get(field).and_then(Json::as_u64).ok_or_else(|| field_err(path, field))
}

/// Parse + gate a manifest file on `(format, version)`.
fn read_manifest_json(
    path: &Path,
    want_format: &str,
    want_version: u64,
) -> Result<Json, QueryError> {
    let text = std::fs::read_to_string(path).map_err(|e| {
        QueryError::Io(io::Error::new(e.kind(), format!("{}: {e}", path.display())))
    })?;
    let j = Json::parse(&text)
        .map_err(|e| QueryError::Artifact(format!("{}: {e}", path.display())))?;
    let format = j.get("format").and_then(Json::as_str).unwrap_or("");
    if format != want_format {
        return Err(QueryError::Artifact(format!(
            "{}: format is {format:?}, expected {want_format:?}",
            path.display()
        )));
    }
    let version = j.get("version").and_then(Json::as_u64).unwrap_or(0);
    if version != want_version {
        return Err(QueryError::Artifact(format!(
            "{}: unsupported {want_format} version {version} (this build reads \
             version {want_version})",
            path.display()
        )));
    }
    Ok(j)
}

/// `(name, count, checksum)` of a manifest file section.
fn file_section(j: &Json, key: &str, path: &Path) -> Result<(String, u64, String), QueryError> {
    let sect = j.get(key).ok_or_else(|| field_err(path, key))?;
    let name = sect
        .get("name")
        .and_then(Json::as_str)
        .ok_or_else(|| field_err(path, key))?;
    let count = sect
        .get("records")
        .or_else(|| sect.get("count"))
        .and_then(Json::as_u64)
        .ok_or_else(|| field_err(path, key))?;
    let checksum = sect
        .get("checksum")
        .and_then(Json::as_str)
        .ok_or_else(|| field_err(path, key))?;
    Ok((name.to_string(), count, checksum.to_string()))
}

/// Read one binary table file, validating magic, entry count, exact
/// size, and checksum against the manifest.
fn read_table_file(
    path: &Path,
    magic: &[u8; 8],
    want_count: u64,
    entry_bytes: usize,
    want_checksum: &str,
) -> Result<Vec<u8>, QueryError> {
    let bytes = std::fs::read(path).map_err(|e| {
        QueryError::Io(io::Error::new(e.kind(), format!("{}: {e}", path.display())))
    })?;
    if checksum_hex(fnv1a64(FNV1A64_INIT, &bytes)) != want_checksum {
        return Err(QueryError::Artifact(format!(
            "{}: checksum mismatch — the artifact is corrupt or was modified",
            path.display()
        )));
    }
    if bytes.len() < TABLE_HEADER_BYTES || &bytes[..8] != magic {
        return Err(QueryError::Artifact(format!(
            "{}: bad table magic",
            path.display()
        )));
    }
    let count = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
    if count != want_count {
        return Err(QueryError::Artifact(format!(
            "{}: table holds {count} entries but the manifest claims {want_count}",
            path.display()
        )));
    }
    let expected = TABLE_HEADER_BYTES as u64 + count * entry_bytes as u64;
    if bytes.len() as u64 != expected {
        return Err(QueryError::Artifact(format!(
            "{}: table is {} bytes, expected {expected} for {count} entries",
            path.display(),
            bytes.len()
        )));
    }
    Ok(bytes)
}

fn read_u64(bytes: &[u8], off: &mut usize) -> u64 {
    let v = u64::from_le_bytes(bytes[*off..*off + 8].try_into().unwrap());
    *off += 8;
    v
}

fn read_u32(bytes: &[u8], off: &mut usize) -> u32 {
    let v = u32::from_le_bytes(bytes[*off..*off + 4].try_into().unwrap());
    *off += 4;
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("tspm_query_index_{}", std::process::id()))
            .join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn sorted_fixture() -> Vec<SeqRecord> {
        // 3 sequences, pid runs with duplicates, varied durations.
        let mut v = Vec::new();
        for (seq, pids) in [(5u64, 0u32..6), (9, 2..3), (40, 0..20)] {
            for pid in pids {
                for d in [10u32, 200, 10 + pid] {
                    v.push(SeqRecord { seq, pid, duration: d });
                }
            }
        }
        v.sort_unstable_by_key(|r| (r.seq, r.pid, r.duration));
        v
    }

    fn fileset(dir: &Path, records: &[SeqRecord], n_files: usize) -> SeqFileSet {
        std::fs::create_dir_all(dir).unwrap();
        let chunk = records.len().div_ceil(n_files.max(1)).max(1);
        let mut files = Vec::new();
        for (i, part) in records.chunks(chunk).enumerate() {
            let p = dir.join(format!("in_{i}.tspm"));
            seqstore::write_file(&p, part).unwrap();
            files.push(p);
        }
        if files.is_empty() {
            let p = dir.join("in_0.tspm");
            seqstore::write_file(&p, &[]).unwrap();
            files.push(p);
        }
        SeqFileSet {
            files,
            total_records: records.len() as u64,
            num_patients: 20,
            num_phenx: 7,
        }
    }

    #[test]
    fn build_then_open_round_trips_tables() {
        let dir = tmpdir("roundtrip");
        let data = sorted_fixture();
        let input = fileset(&dir, &data, 2);
        let built =
            build(&input, &dir.join("idx"), &IndexConfig { block_records: 7 }, None).unwrap();
        assert_eq!(built.total_records, data.len() as u64);
        assert_eq!(built.distinct_seqs(), 3);
        assert_eq!(built.blocks.len(), data.len().div_ceil(7));
        // Reopening yields the identical tables and metadata.
        let opened = SeqIndex::open(&dir.join("idx")).unwrap();
        assert_eq!(opened.blocks, built.blocks);
        assert_eq!(opened.seqs, built.seqs);
        assert_eq!(opened.total_records, built.total_records);
        assert_eq!(opened.block_records, 7);
        assert_eq!(opened.data_checksum, built.data_checksum);
        opened.verify_data().unwrap();
        // The copied data file is byte-faithful to the input records.
        assert_eq!(seqstore::read_file(&opened.data_path).unwrap(), data);
        // Per-seq entries are exact.
        let e = opened.seq_entry(5).unwrap();
        assert_eq!(e.count, 18);
        assert_eq!(e.patients, 6);
        assert_eq!((e.dur_min, e.dur_max), (10, 200));
        assert!(opened.seq_entry(6).is_none());
        // Block offsets tile the data file.
        let mut expect_start = 0u64;
        for b in &opened.blocks {
            assert_eq!(b.start, expect_start);
            expect_start += b.len as u64;
        }
        assert_eq!(expect_start, opened.total_records);
    }

    #[test]
    fn empty_input_builds_an_empty_artifact() {
        let dir = tmpdir("empty");
        let input = fileset(&dir, &[], 1);
        let built = build(&input, &dir.join("idx"), &IndexConfig::default(), None).unwrap();
        assert_eq!(built.total_records, 0);
        assert!(built.blocks.is_empty() && built.seqs.is_empty());
        let opened = SeqIndex::open(&dir.join("idx")).unwrap();
        assert_eq!(opened.total_records, 0);
        assert!(opened.seq_entry(1).is_none());
    }

    #[test]
    fn unsorted_input_is_rejected_and_leaves_no_partial_artifact() {
        let dir = tmpdir("unsorted");
        let mut data = sorted_fixture();
        data.swap(0, 10);
        let input = fileset(&dir, &data, 1);
        let idx_dir = dir.join("idx");
        let err = build(&input, &idx_dir, &IndexConfig::default(), None).unwrap_err();
        assert!(err.to_string().contains("not sorted"), "got {err}");
        // Failed builds clean up after themselves: no half-written data
        // file, no stale manifest.
        assert!(!idx_dir.join(DATA_FILE).exists());
        assert!(!idx_dir.join(MANIFEST_FILE).exists());
    }

    #[test]
    fn build_verified_checks_checksums_in_the_streaming_pass() {
        let dir = tmpdir("build_verified");
        let data = sorted_fixture();
        let input = fileset(&dir, &data, 2);
        write_spill_manifest(&dir, &input, true).unwrap();
        let manifest = read_spill_manifest(&dir).unwrap();

        // Clean input builds fine (no separate verify pass needed).
        let idx_dir = dir.join("idx");
        let built =
            build_verified(&manifest, &idx_dir, &IndexConfig { block_records: 16 }, None)
                .unwrap();
        assert_eq!(built.total_records, data.len() as u64);

        // Corrupting one spill file is caught mid-build, and the failed
        // build removes the partial artifact.
        let victim = &manifest.files.files[1];
        let mut recs = seqstore::read_file(victim).unwrap();
        recs[0].duration ^= 1;
        seqstore::write_file(victim, &recs).unwrap();
        let idx_dir2 = dir.join("idx2");
        let err =
            build_verified(&manifest, &idx_dir2, &IndexConfig { block_records: 16 }, None)
                .unwrap_err();
        assert!(err.to_string().contains("does not match"), "got {err}");
        assert!(!idx_dir2.join(DATA_FILE).exists());
    }

    #[test]
    fn spill_manifest_resolves_files_in_subdirectories() {
        // Unscreened runs leave their spill files under `<out-dir>/mine/`;
        // the manifest must record dir-relative paths, not bare names.
        let dir = tmpdir("subdir_manifest");
        let data = sorted_fixture();
        let sub = dir.join("mine");
        let input = fileset(&sub, &data, 2);
        write_spill_manifest(&dir, &input, false).unwrap();
        let m = read_spill_manifest(&dir).unwrap();
        assert!(!m.sorted);
        assert_eq!(m.files.files, input.files, "paths must resolve to the subdirectory");
        m.verify().unwrap();
    }

    #[test]
    fn zero_block_size_is_rejected() {
        let dir = tmpdir("zeroblock");
        let input = fileset(&dir, &sorted_fixture(), 1);
        let err =
            build(&input, &dir.join("idx"), &IndexConfig { block_records: 0 }, None).unwrap_err();
        assert!(matches!(err, QueryError::Invalid(_)), "got {err}");
    }

    #[test]
    fn tampered_artifacts_are_refused() {
        let dir = tmpdir("tamper");
        let data = sorted_fixture();
        let input = fileset(&dir, &data, 1);
        let idx_dir = dir.join("idx");
        build(&input, &idx_dir, &IndexConfig { block_records: 8 }, None).unwrap();

        // Flip one byte of the block table → checksum mismatch.
        let bpath = idx_dir.join(BLOCKS_FILE);
        let mut bytes = std::fs::read(&bpath).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        std::fs::write(&bpath, &bytes).unwrap();
        let err = SeqIndex::open(&idx_dir).unwrap_err();
        assert!(err.to_string().contains("checksum"), "got {err}");
        bytes[last] ^= 0xFF;
        std::fs::write(&bpath, &bytes).unwrap();
        SeqIndex::open(&idx_dir).unwrap();

        // A future version is refused with a version message.
        let mpath = idx_dir.join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&mpath).unwrap();
        std::fs::write(&mpath, text.replace("\"version\": 1", "\"version\": 99")).unwrap();
        let err = SeqIndex::open(&idx_dir).unwrap_err();
        assert!(err.to_string().contains("version 99"), "got {err}");
        std::fs::write(&mpath, text).unwrap();

        // Truncating the data file is caught at open (count mismatch).
        let opened = SeqIndex::open(&idx_dir).unwrap();
        let data_bytes = std::fs::read(&opened.data_path).unwrap();
        std::fs::write(&opened.data_path, &data_bytes[..data_bytes.len() - 16]).unwrap();
        assert!(SeqIndex::open(&idx_dir).is_err());
        std::fs::write(&opened.data_path, &data_bytes).unwrap();
        SeqIndex::open(&idx_dir).unwrap().verify_data().unwrap();
    }

    #[test]
    fn spill_manifest_round_trips_and_verifies() {
        let dir = tmpdir("spill_manifest");
        let data = sorted_fixture();
        let input = fileset(&dir, &data, 3);
        write_spill_manifest(&dir, &input, true).unwrap();
        let m = read_spill_manifest(&dir).unwrap();
        assert!(m.sorted);
        assert_eq!(m.files.total_records, data.len() as u64);
        assert_eq!(m.files.files, input.files);
        assert_eq!(m.files.num_patients, 20);
        m.verify().unwrap();

        // Appending a record to one spill file breaks verification.
        let victim = &input.files[1];
        let mut recs = seqstore::read_file(victim).unwrap();
        recs.push(SeqRecord { seq: 999, pid: 1, duration: 1 });
        seqstore::write_file(victim, &recs).unwrap();
        let err = read_spill_manifest(&dir).unwrap().verify().unwrap_err();
        assert!(err.to_string().contains("changed"), "got {err}");

        // A deleted spill file surfaces as a typed io error with the path.
        std::fs::remove_file(victim).unwrap();
        let err = read_spill_manifest(&dir).unwrap().verify().unwrap_err();
        assert!(err.to_string().contains("in_1.tspm"), "got {err}");
    }

    #[test]
    fn fnv_is_order_sensitive_and_stable() {
        let a = fnv1a64(FNV1A64_INIT, b"ab");
        let b = fnv1a64(FNV1A64_INIT, b"ba");
        assert_ne!(a, b);
        assert_eq!(a, fnv1a64(fnv1a64(FNV1A64_INIT, b"a"), b"b"));
        // Known FNV-1a 64 vector: empty input is the offset basis.
        assert_eq!(fnv1a64(FNV1A64_INIT, b""), FNV1A64_INIT);
    }
}
