//! Index-artifact builder and loader — see the [module docs](crate::query)
//! for the on-disk layout and the compatibility guarantee.
//!
//! [`build`] streams a sorted [`SeqFileSet`] exactly once, copying the
//! records into the artifact's own data file while accumulating the
//! sparse block index, the per-sequence table, and the per-pid counts,
//! then counting-sorts the copy into the pid-major secondary index
//! (a bucket shuffle — out of core, one bucket resident at a time), so
//! the artifact is self-contained (the source
//! spill directory can be deleted afterwards). [`SeqIndex::open`]
//! validates the manifest's format/version, every table checksum, and
//! the data files' record counts before answering anything;
//! [`SeqIndex::verify_data`] optionally re-checksums the full data
//! files.

use super::QueryError;
use crate::json::Json;
use crate::metrics::MemTracker;
use crate::mining::SeqRecord;
use crate::seqstore::{self, SeqFileSet, SeqReader, SeqWriter, RECORD_BYTES};
use crate::target::TargetSpec;
use std::io;
use std::path::{Path, PathBuf};

/// Manifest `format` value of an index artifact.
pub const INDEX_FORMAT: &str = "tspm-seqindex";
/// Layout version this build writes: v2 adds the pid-major secondary
/// index (`pids.bin` + `pdata_0000.tspm`). Bump on any change to the
/// file layouts below.
pub const INDEX_FORMAT_VERSION: u64 = 2;
/// Oldest layout version [`SeqIndex::open`] still reads. v1 artifacts
/// (no pid table) open fine — [`crate::query::QueryService::by_patient`]
/// falls back to the block-pruned scan for them.
pub const INDEX_MIN_FORMAT_VERSION: u64 = 1;
/// Manifest `format` value of a spilled-run input manifest
/// (`tspm mine --out-dir`).
pub const SPILL_FORMAT: &str = "tspm-spill";
/// Version of the spill manifest scheme.
pub const SPILL_FORMAT_VERSION: u64 = 1;

/// Default records per index block — the query layer's unit of IO and
/// of resident memory (64 KiB of records at the 16-byte record size).
pub const DEFAULT_BLOCK_RECORDS: usize = 4096;

const MANIFEST_FILE: &str = "manifest.json";
// The data-file names are pub(crate): the segment compactor
// ([`crate::ingest`]) streams its merge output straight into them and
// then reuses [`write_tables_and_manifest`] for everything else.
pub(crate) const DATA_FILE: &str = "data_0000.tspm";
const BLOCKS_FILE: &str = "blocks.bin";
const SEQS_FILE: &str = "seqs.bin";
pub(crate) const PDATA_FILE: &str = "pdata_0000.tspm";
const PIDS_FILE: &str = "pids.bin";

const BLOCKS_MAGIC: &[u8; 8] = b"TSPMBIX1";
const SEQS_MAGIC: &[u8; 8] = b"TSPMSQT1";
const PIDS_MAGIC: &[u8; 8] = b"TSPMPTB1";
const TABLE_HEADER_BYTES: usize = 16; // magic + count
const BLOCK_ENTRY_BYTES: usize = 52;
const SEQ_ENTRY_BYTES: usize = 36;
const PID_ENTRY_BYTES: usize = 16;

/// Upper bound on the pid-range buckets the pid-major shuffle partitions
/// into (bounds open file descriptors and, together with the block size,
/// the shuffle's resident set: one bucket of ~`total/64` records is held
/// in memory at a time while it is pid-sorted).
const MAX_PID_BUCKETS: u64 = 64;

const ZERO_REC: SeqRecord = SeqRecord { seq: 0, pid: 0, duration: 0 };

// ---------------------------------------------------------------------------
// Checksums
// ---------------------------------------------------------------------------

/// FNV-1a 64-bit offset basis.
pub const FNV1A64_INIT: u64 = 0xcbf2_9ce4_8422_2325;

/// Fold `bytes` into an FNV-1a 64 state.
#[inline]
pub fn fnv1a64(mut state: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        state ^= b as u64;
        state = state.wrapping_mul(0x100_0000_01b3);
    }
    state
}

pub(crate) fn checksum_hex(h: u64) -> String {
    format!("{h:016x}")
}

/// Stream one TSPMSEQ1 file, returning its record count and the hex
/// FNV-1a checksum over the 16-byte LE record encodings (header
/// excluded, so the checksum is a property of the record sequence, not
/// of incidental file framing).
pub fn checksum_records(path: &Path) -> Result<(u64, String), QueryError> {
    let mut reader = SeqReader::open(path)?;
    let mut buf = vec![ZERO_REC; 8192];
    let mut h = FNV1A64_INIT;
    let mut n = 0u64;
    loop {
        let got = reader.read_batch(&mut buf)?;
        if got == 0 {
            break;
        }
        for &r in &buf[..got] {
            h = fnv1a64(h, &seqstore::encode_record(r));
        }
        n += got as u64;
    }
    Ok((n, checksum_hex(h)))
}

// ---------------------------------------------------------------------------
// Spill-run input manifests (tspm mine --out-dir)
// ---------------------------------------------------------------------------

/// The verified description of a spilled run directory: the
/// reconstructed [`SeqFileSet`], whether its records are globally
/// sorted (the screen's spill order), and each file's recorded count +
/// checksum for [`SpillManifest::verify`].
#[derive(Clone, Debug)]
pub struct SpillManifest {
    pub files: SeqFileSet,
    /// Whether the records are globally `(seq, pid, duration)`-sorted —
    /// true exactly when the run included the sparsity screen.
    pub sorted: bool,
    /// `(path, records, checksum)` per spill file, as recorded at write
    /// time.
    pub per_file: Vec<(PathBuf, u64, String)>,
}

impl SpillManifest {
    /// Re-checksum every spill file against the manifest: detects
    /// deleted, truncated, or otherwise modified inputs before an index
    /// build consumes them.
    pub fn verify(&self) -> Result<(), QueryError> {
        let mut total = 0u64;
        for (path, records, checksum) in &self.per_file {
            let (n, sum) = checksum_records(path)?;
            if n != *records || sum != *checksum {
                return Err(QueryError::Artifact(format!(
                    "{}: spill file changed since its manifest was written \
                     (recorded {records} records / {checksum}, found {n} / {sum})",
                    path.display()
                )));
            }
            total += n;
        }
        if total != self.files.total_records {
            return Err(QueryError::Artifact(format!(
                "spill manifest total_records {} disagrees with the per-file sum {total}",
                self.files.total_records
            )));
        }
        Ok(())
    }
}

/// Write `manifest.json` describing a spilled run into `dir`: format +
/// version, counts, sortedness, and each file's record count + record
/// checksum. `tspm mine --out-dir` calls this so `tspm index` can
/// verify its input before building. File entries are stored relative
/// to `dir` (spill files may sit in subdirectories, e.g. the `mine/`
/// directory of an unscreened run). Computing the checksums costs one
/// sequential re-read of the spill files — the price of the integrity
/// record; [`build_verified`] then re-checks them for free during its
/// own streaming pass.
pub fn write_spill_manifest(
    dir: &Path,
    files: &SeqFileSet,
    sorted: bool,
) -> Result<(), QueryError> {
    let mut entries = Vec::with_capacity(files.files.len());
    for f in &files.files {
        // Relative to the manifest's directory when possible; an
        // absolute fallback keeps out-of-tree files resolvable
        // (`dir.join(absolute)` is the absolute path again).
        let rel = f.strip_prefix(dir).unwrap_or(f);
        let name = rel
            .to_str()
            .ok_or_else(|| {
                QueryError::Invalid(format!(
                    "{}: spill file needs a UTF-8 path for the manifest",
                    f.display()
                ))
            })?
            .to_string();
        let (n, sum) = checksum_records(f)?;
        entries.push(Json::obj(vec![
            ("name", Json::from(name)),
            ("records", Json::from(n)),
            ("checksum", Json::from(sum)),
        ]));
    }
    let j = Json::obj(vec![
        ("format", Json::from(SPILL_FORMAT)),
        ("version", Json::from(SPILL_FORMAT_VERSION)),
        ("total_records", Json::from(files.total_records)),
        ("num_patients", Json::from(files.num_patients as u64)),
        ("num_phenx", Json::from(files.num_phenx as u64)),
        ("sorted", Json::from(sorted)),
        ("files", Json::Arr(entries)),
    ]);
    std::fs::write(dir.join(MANIFEST_FILE), j.to_string_pretty())?;
    Ok(())
}

/// Read a spilled run's `manifest.json` back; file names resolve
/// relative to `dir`. Checksums are *not* re-verified here — call
/// [`SpillManifest::verify`] for that.
pub fn read_spill_manifest(dir: &Path) -> Result<SpillManifest, QueryError> {
    let path = dir.join(MANIFEST_FILE);
    let (j, _) =
        read_manifest_json(&path, SPILL_FORMAT, SPILL_FORMAT_VERSION, SPILL_FORMAT_VERSION)?;
    let total_records = req_u64(&j, "total_records", &path)?;
    let num_patients = req_u64(&j, "num_patients", &path)? as u32;
    let num_phenx = req_u64(&j, "num_phenx", &path)? as u32;
    let sorted = j
        .get("sorted")
        .and_then(Json::as_bool)
        .ok_or_else(|| field_err(&path, "sorted"))?;
    let list = j
        .get("files")
        .and_then(Json::as_arr)
        .ok_or_else(|| field_err(&path, "files"))?;
    let mut files = Vec::with_capacity(list.len());
    let mut per_file = Vec::with_capacity(list.len());
    for item in list {
        let name = item
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| field_err(&path, "files[].name"))?;
        let records = req_u64(item, "records", &path)?;
        let checksum = item
            .get("checksum")
            .and_then(Json::as_str)
            .ok_or_else(|| field_err(&path, "files[].checksum"))?;
        let full = dir.join(name);
        files.push(full.clone());
        per_file.push((full, records, checksum.to_string()));
    }
    Ok(SpillManifest {
        files: SeqFileSet { files, total_records, num_patients, num_phenx },
        sorted,
        per_file,
    })
}

// ---------------------------------------------------------------------------
// Index entries and configuration
// ---------------------------------------------------------------------------

/// One entry of the sparse block index: a fixed-size run of
/// `block_records` consecutive records of the data file, with the key
/// range it spans and per-block pid/duration bounds for pruning.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BlockMeta {
    /// First record (0-based offset into the data file).
    pub start: u64,
    /// Records in the block (equal to the block size except the tail).
    pub len: u32,
    pub first_seq: u64,
    pub first_pid: u32,
    pub last_seq: u64,
    pub last_pid: u32,
    /// Smallest/largest pid occurring anywhere in the block (not the
    /// first/last — sequences restart the pid order inside a block).
    pub pid_min: u32,
    pub pid_max: u32,
    /// Duration bounds over the block, for range-query pruning.
    pub dur_min: u32,
    pub dur_max: u32,
}

/// One entry of the per-sequence table: where the sequence's records
/// live and its pre-aggregated support statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SeqTableEntry {
    pub seq: u64,
    /// First record of the sequence's run in the data file.
    pub start: u64,
    /// Records in the run.
    pub count: u64,
    /// Distinct patients — the sequence's support (the same count the
    /// sparsity screen thresholds on).
    pub patients: u32,
    pub dur_min: u32,
    pub dur_max: u32,
}

/// One entry of the pid-major secondary index (`pids.bin`): where
/// patient `pid`'s records live in the pid-major data copy
/// (`pdata_0000.tspm`). The entries tile the copy contiguously —
/// `entries[p].start == entries[p-1].start + entries[p-1].count` — so
/// [`crate::query::QueryService::by_patient`] is exactly one positioned
/// range read of `count` records.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PidEntry {
    /// First record of the patient's run in the pid-major copy.
    pub start: u64,
    /// Records the patient owns.
    pub count: u64,
}

/// The loaded pid-major secondary index of a v2 artifact: the resident
/// per-pid offset/count table plus the pid-major record copy it indexes
/// (sorted by `(pid, seq, duration)` — within one patient the records
/// keep the seq-major `(seq, duration)` order, so the fast path returns
/// byte-identical answers to the v1 scan path).
#[derive(Clone, Debug)]
pub struct PidTable {
    /// The pid-major TSPMSEQ1 record copy all entries refer to.
    pub data_path: PathBuf,
    /// Hex FNV-1a checksum over the copy's record encodings (verified on
    /// demand by [`SeqIndex::verify_data`]).
    pub data_checksum: String,
    /// Per-pid entries, indexed by dense pid (`len == num_patients`).
    pub entries: Vec<PidEntry>,
}

/// Build-time configuration.
#[derive(Clone, Debug)]
pub struct IndexConfig {
    /// Records per index block ([`DEFAULT_BLOCK_RECORDS`]); also the
    /// query service's read-buffer size.
    pub block_records: usize,
    /// Build the pid-major secondary index (v2 artifacts; the default).
    /// `false` writes a bit-compatible v1 artifact — no `pids.bin` /
    /// `pdata_0000.tspm`, half the disk, `by_patient` scans.
    pub pid_index: bool,
    /// The [`TargetSpec`] the indexed run was mined under, recorded in
    /// the manifest (append-only `target` key, **no version bump** —
    /// readers that predate it ignore the key) so `tspm list` and
    /// [`crate::query::SurfaceInfo`] can answer "what was this index
    /// targeted to". `None` (or an `is_all` spec) writes no key at all,
    /// keeping untargeted manifests byte-identical to previous builds.
    pub target: Option<TargetSpec>,
}

impl Default for IndexConfig {
    fn default() -> Self {
        IndexConfig { block_records: DEFAULT_BLOCK_RECORDS, pid_index: true, target: None }
    }
}

// ---------------------------------------------------------------------------
// The artifact
// ---------------------------------------------------------------------------

/// A loaded (or just-built) index artifact: the resident tables plus
/// the path of the on-disk data file they describe.
#[derive(Clone, Debug)]
pub struct SeqIndex {
    /// The artifact directory.
    pub dir: PathBuf,
    /// The TSPMSEQ1 data file all offsets refer to.
    pub data_path: PathBuf,
    /// The manifest's layout version (1 or 2).
    pub version: u64,
    pub block_records: usize,
    pub total_records: u64,
    pub num_patients: u32,
    pub num_phenx: u32,
    /// Hex FNV-1a checksum over the data file's record encodings (from
    /// the manifest; verified on demand by [`SeqIndex::verify_data`]).
    pub data_checksum: String,
    /// Total on-disk size of the artifact (data + tables + manifest).
    pub artifact_bytes: u64,
    /// The sparse block index, in data-file order.
    pub blocks: Vec<BlockMeta>,
    /// The per-sequence table, sorted by `seq`.
    pub seqs: Vec<SeqTableEntry>,
    /// The pid-major secondary index — `Some` for v2 artifacts, `None`
    /// for v1 (where `by_patient` falls back to the block-pruned scan).
    pub pids: Option<PidTable>,
    /// The [`TargetSpec`] the artifact's run was mined under, when its
    /// manifest recorded one. `None` means an untargeted (full) mine —
    /// including every artifact written before the key existed.
    pub target: Option<TargetSpec>,
}

impl SeqIndex {
    /// Number of distinct sequences in the artifact.
    pub fn distinct_seqs(&self) -> u64 {
        self.seqs.len() as u64
    }

    /// The table entry for `seq`, if the sequence is present.
    pub fn seq_entry(&self, seq: u64) -> Option<&SeqTableEntry> {
        self.seqs
            .binary_search_by_key(&seq, |e| e.seq)
            .ok()
            .map(|i| &self.seqs[i])
    }

    /// Open an artifact directory: parse + version-check the manifest
    /// (v1 and v2 layouts both open; see the version constants), load
    /// every table (verifying their checksums), and cross-check the
    /// data files' header counts. O(tables), not O(data) — use
    /// [`SeqIndex::verify_data`] for the full data checksums.
    pub fn open(dir: &Path) -> Result<SeqIndex, QueryError> {
        let manifest_path = dir.join(MANIFEST_FILE);
        let (j, version) = read_manifest_json(
            &manifest_path,
            INDEX_FORMAT,
            INDEX_MIN_FORMAT_VERSION,
            INDEX_FORMAT_VERSION,
        )?;
        let block_records = req_u64(&j, "block_records", &manifest_path)? as usize;
        if block_records == 0 {
            return Err(QueryError::Artifact(format!(
                "{}: block_records must be ≥ 1",
                manifest_path.display()
            )));
        }
        let total_records = req_u64(&j, "total_records", &manifest_path)?;
        let num_patients = req_u64(&j, "num_patients", &manifest_path)? as u32;
        let num_phenx = req_u64(&j, "num_phenx", &manifest_path)? as u32;

        let (data_name, data_records, data_checksum) =
            file_section(&j, "data", &manifest_path)?;
        let (blocks_name, block_count, blocks_checksum) =
            file_section(&j, "blocks", &manifest_path)?;
        let (seqs_name, seq_count, seqs_checksum) =
            file_section(&j, "seqs", &manifest_path)?;
        if data_records != total_records {
            return Err(QueryError::Artifact(format!(
                "{}: data.records {data_records} disagrees with total_records {total_records}",
                manifest_path.display()
            )));
        }

        let blocks_bytes = read_table_file(
            &dir.join(&blocks_name),
            BLOCKS_MAGIC,
            block_count,
            BLOCK_ENTRY_BYTES,
            &blocks_checksum,
        )?;
        let mut blocks = Vec::with_capacity(block_count as usize);
        let mut off = TABLE_HEADER_BYTES;
        for _ in 0..block_count {
            blocks.push(BlockMeta {
                start: read_u64(&blocks_bytes, &mut off),
                len: read_u32(&blocks_bytes, &mut off),
                first_seq: read_u64(&blocks_bytes, &mut off),
                first_pid: read_u32(&blocks_bytes, &mut off),
                last_seq: read_u64(&blocks_bytes, &mut off),
                last_pid: read_u32(&blocks_bytes, &mut off),
                pid_min: read_u32(&blocks_bytes, &mut off),
                pid_max: read_u32(&blocks_bytes, &mut off),
                dur_min: read_u32(&blocks_bytes, &mut off),
                dur_max: read_u32(&blocks_bytes, &mut off),
            });
        }

        let seqs_bytes = read_table_file(
            &dir.join(&seqs_name),
            SEQS_MAGIC,
            seq_count,
            SEQ_ENTRY_BYTES,
            &seqs_checksum,
        )?;
        let mut seqs = Vec::with_capacity(seq_count as usize);
        let mut off = TABLE_HEADER_BYTES;
        for _ in 0..seq_count {
            seqs.push(SeqTableEntry {
                seq: read_u64(&seqs_bytes, &mut off),
                start: read_u64(&seqs_bytes, &mut off),
                count: read_u64(&seqs_bytes, &mut off),
                patients: read_u32(&seqs_bytes, &mut off),
                dur_min: read_u32(&seqs_bytes, &mut off),
                dur_max: read_u32(&seqs_bytes, &mut off),
            });
        }
        if seqs.windows(2).any(|w| w[0].seq >= w[1].seq) {
            return Err(QueryError::Artifact(format!(
                "{}: sequence table is not strictly sorted by seq",
                dir.join(&seqs_name).display()
            )));
        }

        let data_path = dir.join(&data_name);
        let reader = SeqReader::open(&data_path)?;
        if reader.total() != total_records {
            return Err(QueryError::Artifact(format!(
                "{}: data file holds {} records but the manifest claims {total_records}",
                data_path.display(),
                reader.total()
            )));
        }
        drop(reader);

        // v2: the pid-major secondary index (per-pid table + pid-major
        // record copy). v1 manifests have neither section.
        let mut pids = None;
        let mut pid_bytes = 0u64;
        if version >= 2 {
            let (pids_name, pid_count, pids_checksum) =
                file_section(&j, "pids", &manifest_path)?;
            let (pdata_name, pdata_records, pdata_checksum) =
                file_section(&j, "pdata", &manifest_path)?;
            if pid_count != num_patients as u64 {
                return Err(QueryError::Artifact(format!(
                    "{}: pid table lists {pid_count} patients but the manifest claims \
                     {num_patients}",
                    manifest_path.display()
                )));
            }
            if pdata_records != total_records {
                return Err(QueryError::Artifact(format!(
                    "{}: pdata.records {pdata_records} disagrees with total_records \
                     {total_records}",
                    manifest_path.display()
                )));
            }
            let pids_path = dir.join(&pids_name);
            let pids_bytes = read_table_file(
                &pids_path,
                PIDS_MAGIC,
                pid_count,
                PID_ENTRY_BYTES,
                &pids_checksum,
            )?;
            let mut entries = Vec::with_capacity(pid_count as usize);
            let mut off = TABLE_HEADER_BYTES;
            for _ in 0..pid_count {
                entries.push(PidEntry {
                    start: read_u64(&pids_bytes, &mut off),
                    count: read_u64(&pids_bytes, &mut off),
                });
            }
            // The entries must tile the pid-major copy contiguously.
            let mut expect = 0u64;
            for (p, e) in entries.iter().enumerate() {
                if e.start != expect {
                    return Err(QueryError::Artifact(format!(
                        "{}: pid {p} starts at record {} but the previous entries end \
                         at {expect}",
                        pids_path.display(),
                        e.start
                    )));
                }
                expect += e.count;
            }
            if expect != total_records {
                return Err(QueryError::Artifact(format!(
                    "{}: pid entries cover {expect} records but the artifact holds \
                     {total_records}",
                    pids_path.display()
                )));
            }
            let pdata_path = dir.join(&pdata_name);
            let reader = SeqReader::open(&pdata_path)?;
            if reader.total() != total_records {
                return Err(QueryError::Artifact(format!(
                    "{}: pid-major copy holds {} records but the manifest claims \
                     {total_records}",
                    pdata_path.display(),
                    reader.total()
                )));
            }
            drop(reader);
            pid_bytes = pids_bytes.len() as u64 + std::fs::metadata(&pdata_path)?.len();
            pids = Some(PidTable {
                data_path: pdata_path,
                data_checksum: pdata_checksum,
                entries,
            });
        }

        // Optional append-only key (no version bump): the spec the run
        // was targeted to. Absent on untargeted and pre-key artifacts; a
        // malformed value is a typed error, not a silent None.
        let target = match j.get("target") {
            None => None,
            Some(t) => Some(TargetSpec::from_json(t).map_err(|e| {
                QueryError::Artifact(format!("{}: {e}", manifest_path.display()))
            })?),
        };

        let manifest_len = std::fs::metadata(&manifest_path)?.len();
        let artifact_bytes = std::fs::metadata(&data_path)?.len()
            + blocks_bytes.len() as u64
            + seqs_bytes.len() as u64
            + pid_bytes
            + manifest_len;

        Ok(SeqIndex {
            dir: dir.to_path_buf(),
            data_path,
            version,
            block_records,
            total_records,
            num_patients,
            num_phenx,
            data_checksum,
            artifact_bytes,
            blocks,
            seqs,
            pids,
            target,
        })
    }

    /// Full integrity check of the data file (and, on v2 artifacts, the
    /// pid-major copy): re-checksums every record against the manifest.
    /// O(data) — an explicit opt-in.
    pub fn verify_data(&self) -> Result<(), QueryError> {
        let (n, sum) = checksum_records(&self.data_path)?;
        if n != self.total_records || sum != self.data_checksum {
            return Err(QueryError::Artifact(format!(
                "{}: data checksum mismatch (manifest {} records / {}, found {n} / {sum})",
                self.data_path.display(),
                self.total_records,
                self.data_checksum
            )));
        }
        if let Some(pt) = &self.pids {
            let (n, sum) = checksum_records(&pt.data_path)?;
            if n != self.total_records || sum != pt.data_checksum {
                return Err(QueryError::Artifact(format!(
                    "{}: pid-major copy checksum mismatch (manifest {} records / {}, \
                     found {n} / {sum})",
                    pt.data_path.display(),
                    self.total_records,
                    pt.data_checksum
                )));
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Build
// ---------------------------------------------------------------------------

/// Build an index artifact under `out_dir` from a **sorted** spilled
/// result (the order [`crate::sparsity::screen_spilled`] writes:
/// globally by `(seq, pid, duration)` across the file set's
/// concatenation). Streams the input exactly once; unsorted input is a
/// typed [`QueryError::Artifact`], never a silently wrong index.
/// `tracker`, when provided, accounts the build's read buffer and table
/// serialization buffers. On *any* failure the partially written
/// artifact files are removed — `out_dir` never holds a half-built (or
/// old-manifest/new-data) mix.
pub fn build(
    input: &SeqFileSet,
    out_dir: &Path,
    cfg: &IndexConfig,
    tracker: Option<&MemTracker>,
) -> Result<SeqIndex, QueryError> {
    // Validate before build_impl touches (truncates) any artifact file,
    // so a bad config cannot cost an existing artifact its data file.
    if cfg.block_records == 0 {
        return Err(QueryError::Invalid("index block_records must be ≥ 1".into()));
    }
    let result = build_impl(input, out_dir, cfg, None, tracker);
    if result.is_err() {
        remove_partial_artifact(out_dir);
    }
    result
}

/// [`build`], additionally verifying every input file against the spill
/// manifest's recorded count + checksum **during** the build's own
/// streaming pass — integrity checking without a separate read of the
/// (potentially out-of-core-sized) input.
pub fn build_verified(
    manifest: &SpillManifest,
    out_dir: &Path,
    cfg: &IndexConfig,
    tracker: Option<&MemTracker>,
) -> Result<SeqIndex, QueryError> {
    if cfg.block_records == 0 {
        return Err(QueryError::Invalid("index block_records must be ≥ 1".into()));
    }
    if manifest.per_file.len() != manifest.files.files.len() {
        return Err(QueryError::Artifact(format!(
            "spill manifest lists {} checksums for {} files",
            manifest.per_file.len(),
            manifest.files.files.len()
        )));
    }
    let result = build_impl(&manifest.files, out_dir, cfg, Some(&manifest.per_file), tracker);
    if result.is_err() {
        remove_partial_artifact(out_dir);
    }
    result
}

/// Best-effort removal of every artifact file — called on failed
/// builds so a stale manifest can never describe fresher partial data.
fn remove_partial_artifact(out_dir: &Path) {
    for name in [DATA_FILE, BLOCKS_FILE, SEQS_FILE, PDATA_FILE, PIDS_FILE, MANIFEST_FILE] {
        let _ = std::fs::remove_file(out_dir.join(name));
    }
    // Leftover pid-shuffle bucket files of an interrupted build.
    if let Ok(rd) = std::fs::read_dir(out_dir) {
        for entry in rd.flatten() {
            if entry.file_name().to_string_lossy().starts_with("pidsort_") {
                let _ = std::fs::remove_file(entry.path());
            }
        }
    }
}

fn build_impl(
    input: &SeqFileSet,
    out_dir: &Path,
    cfg: &IndexConfig,
    expected: Option<&[(PathBuf, u64, String)]>,
    tracker: Option<&MemTracker>,
) -> Result<SeqIndex, QueryError> {
    if cfg.block_records == 0 {
        return Err(QueryError::Invalid("index block_records must be ≥ 1".into()));
    }
    let block_records = cfg.block_records;
    std::fs::create_dir_all(out_dir)?;
    let track = |b: u64| {
        if let Some(t) = tracker {
            t.add(b)
        }
    };
    let untrack = |b: u64| {
        if let Some(t) = tracker {
            t.sub(b)
        }
    };

    let data_path = out_dir.join(DATA_FILE);
    let mut writer = SeqWriter::create(&data_path)?;

    let mut tables = TableAccum::new(block_records);
    let mut prev: Option<SeqRecord> = None;
    let mut data_fnv = FNV1A64_INIT;
    let mut n = 0u64;
    // Per-pid record counts for the pid-major secondary index — sized by
    // the input's dense pid space, accumulated during the same pass.
    let mut pid_counts: Option<Vec<u64>> =
        cfg.pid_index.then(|| vec![0u64; input.num_patients as usize]);
    if pid_counts.is_some() {
        track(input.num_patients as u64 * 8);
    }

    let read_cap = block_records.clamp(1024, 64 * 1024);
    let mut buf = vec![ZERO_REC; read_cap];
    track((read_cap * RECORD_BYTES) as u64);
    for (fi, path) in input.files.iter().enumerate() {
        let mut reader = SeqReader::open(path)?;
        let mut file_fnv = FNV1A64_INIT;
        let mut file_records = 0u64;
        loop {
            let got = reader.read_batch(&mut buf)?;
            if got == 0 {
                break;
            }
            for &r in &buf[..got] {
                if let Some(p) = prev {
                    if (p.seq, p.pid, p.duration) > (r.seq, r.pid, r.duration) {
                        return Err(QueryError::Artifact(format!(
                            "{}: records are not sorted by (seq, pid, duration) at \
                             record {n} — the index consumes the *screened* spill \
                             output (run the sparsity screen first)",
                            path.display()
                        )));
                    }
                }
                prev = Some(r);
                if let Some(counts) = pid_counts.as_mut() {
                    match counts.get_mut(r.pid as usize) {
                        Some(c) => *c += 1,
                        None => {
                            return Err(QueryError::Artifact(format!(
                                "{}: record {n} has pid {} but the input claims only \
                                 {} patients — cannot build the pid-major index",
                                path.display(),
                                r.pid,
                                input.num_patients
                            )))
                        }
                    }
                }
                writer.write(r)?;
                let encoded = seqstore::encode_record(r);
                data_fnv = fnv1a64(data_fnv, &encoded);
                file_fnv = fnv1a64(file_fnv, &encoded);
                file_records += 1;
                tables.push(r);
                n += 1;
            }
        }
        if let Some(exp) = expected {
            let (epath, erecords, esum) = &exp[fi];
            let sum = checksum_hex(file_fnv);
            if file_records != *erecords || sum != *esum {
                return Err(QueryError::Artifact(format!(
                    "{}: spill file does not match its manifest (recorded {erecords} \
                     records / {esum}, found {file_records} / {sum})",
                    epath.display()
                )));
            }
        }
    }
    let (blocks, seqs) = tables.finish();
    untrack((read_cap * RECORD_BYTES) as u64);
    drop(buf);

    let written = writer.finish()?;
    if written != input.total_records {
        return Err(QueryError::Artifact(format!(
            "input file set claims {} records but {written} were read — its manifest \
             is stale",
            input.total_records
        )));
    }

    // v2: pid-major shuffle — counting-sort the just-written data file
    // by pid into the pid-major copy, from the exact per-pid counts the
    // main pass accumulated.
    let pid_table = match pid_counts.take() {
        Some(counts) => {
            let built =
                build_pid_major(&data_path, out_dir, &counts, written, block_records, tracker)?;
            untrack(input.num_patients as u64 * 8);
            Some(built)
        }
        None => None,
    };

    write_tables_and_manifest(
        out_dir,
        block_records,
        written,
        input.num_patients,
        input.num_phenx,
        data_fnv,
        blocks,
        seqs,
        pid_table,
        cfg.target.as_ref(),
        tracker,
    )
}

/// Streaming accumulator of the sparse block index and the per-sequence
/// table: feed records in global `(seq, pid, duration)` order via
/// [`TableAccum::push`], then [`TableAccum::finish`]. Extracted from the
/// build pass so the segment compactor ([`crate::ingest`]) derives
/// **bit-identical** tables from its merge stream — any accounting drift
/// between the two producers would break the compaction ≡ fresh-build
/// contract the ingest conformance suite enforces.
pub(crate) struct TableAccum {
    block_records: usize,
    blocks: Vec<BlockMeta>,
    seqs: Vec<SeqTableEntry>,
    block: BlockMeta,
    se: SeqTableEntry,
    seq_open: bool,
    last_pid_in_seq: u32,
    n: u64,
}

impl TableAccum {
    pub(crate) fn new(block_records: usize) -> TableAccum {
        TableAccum {
            block_records,
            blocks: Vec::new(),
            seqs: Vec::new(),
            block: BlockMeta::default(),
            se: SeqTableEntry::default(),
            seq_open: false,
            last_pid_in_seq: 0,
            n: 0,
        }
    }

    pub(crate) fn push(&mut self, r: SeqRecord) {
        // Block accounting (len == 0 means "no open block").
        if self.block.len == 0 {
            self.block = BlockMeta {
                start: self.n,
                len: 0,
                first_seq: r.seq,
                first_pid: r.pid,
                last_seq: r.seq,
                last_pid: r.pid,
                pid_min: r.pid,
                pid_max: r.pid,
                dur_min: r.duration,
                dur_max: r.duration,
            };
        }
        self.block.len += 1;
        self.block.last_seq = r.seq;
        self.block.last_pid = r.pid;
        self.block.pid_min = self.block.pid_min.min(r.pid);
        self.block.pid_max = self.block.pid_max.max(r.pid);
        self.block.dur_min = self.block.dur_min.min(r.duration);
        self.block.dur_max = self.block.dur_max.max(r.duration);
        if self.block.len as usize >= self.block_records {
            self.blocks.push(self.block);
            self.block.len = 0;
        }

        // Per-sequence accounting.
        if !self.seq_open || self.se.seq != r.seq {
            if self.seq_open {
                self.seqs.push(self.se);
            }
            self.se = SeqTableEntry {
                seq: r.seq,
                start: self.n,
                count: 0,
                patients: 1,
                dur_min: r.duration,
                dur_max: r.duration,
            };
            self.seq_open = true;
            self.last_pid_in_seq = r.pid;
        } else if r.pid != self.last_pid_in_seq {
            self.se.patients += 1;
            self.last_pid_in_seq = r.pid;
        }
        self.se.count += 1;
        self.se.dur_min = self.se.dur_min.min(r.duration);
        self.se.dur_max = self.se.dur_max.max(r.duration);

        self.n += 1;
    }

    pub(crate) fn finish(mut self) -> (Vec<BlockMeta>, Vec<SeqTableEntry>) {
        if self.block.len > 0 {
            self.blocks.push(self.block);
        }
        if self.seq_open {
            self.seqs.push(self.se);
        }
        (self.blocks, self.seqs)
    }
}

/// Serialize the tables, write the manifest, and assemble the
/// [`SeqIndex`]. The data file(s) must already sit in `out_dir` under
/// their canonical names ([`DATA_FILE`], and [`PDATA_FILE`] when
/// `pid_table` is `Some`). Shared verbatim between [`build`] and the
/// segment compactor so both produce byte-identical artifacts from
/// identical record streams.
#[allow(clippy::too_many_arguments)]
pub(crate) fn write_tables_and_manifest(
    out_dir: &Path,
    block_records: usize,
    written: u64,
    num_patients: u32,
    num_phenx: u32,
    data_fnv: u64,
    blocks: Vec<BlockMeta>,
    seqs: Vec<SeqTableEntry>,
    pid_table: Option<(Vec<PidEntry>, String)>,
    target: Option<&TargetSpec>,
    tracker: Option<&MemTracker>,
) -> Result<SeqIndex, QueryError> {
    // Normalize: an is_all spec means "untargeted" and writes no key, so
    // spec presence in a manifest always carries information.
    let target = target.filter(|t| !t.is_all());
    let track = |b: u64| {
        if let Some(t) = tracker {
            t.add(b)
        }
    };
    let untrack = |b: u64| {
        if let Some(t) = tracker {
            t.sub(b)
        }
    };
    let data_path = out_dir.join(DATA_FILE);

    // Serialize the tables with checksums over the full file bytes.
    let blocks_bytes = {
        let mut out = Vec::with_capacity(TABLE_HEADER_BYTES + blocks.len() * BLOCK_ENTRY_BYTES);
        out.extend_from_slice(BLOCKS_MAGIC);
        out.extend_from_slice(&(blocks.len() as u64).to_le_bytes());
        for b in &blocks {
            out.extend_from_slice(&b.start.to_le_bytes());
            out.extend_from_slice(&b.len.to_le_bytes());
            out.extend_from_slice(&b.first_seq.to_le_bytes());
            out.extend_from_slice(&b.first_pid.to_le_bytes());
            out.extend_from_slice(&b.last_seq.to_le_bytes());
            out.extend_from_slice(&b.last_pid.to_le_bytes());
            out.extend_from_slice(&b.pid_min.to_le_bytes());
            out.extend_from_slice(&b.pid_max.to_le_bytes());
            out.extend_from_slice(&b.dur_min.to_le_bytes());
            out.extend_from_slice(&b.dur_max.to_le_bytes());
        }
        out
    };
    let seqs_bytes = {
        let mut out = Vec::with_capacity(TABLE_HEADER_BYTES + seqs.len() * SEQ_ENTRY_BYTES);
        out.extend_from_slice(SEQS_MAGIC);
        out.extend_from_slice(&(seqs.len() as u64).to_le_bytes());
        for e in &seqs {
            out.extend_from_slice(&e.seq.to_le_bytes());
            out.extend_from_slice(&e.start.to_le_bytes());
            out.extend_from_slice(&e.count.to_le_bytes());
            out.extend_from_slice(&e.patients.to_le_bytes());
            out.extend_from_slice(&e.dur_min.to_le_bytes());
            out.extend_from_slice(&e.dur_max.to_le_bytes());
        }
        out
    };
    // v2 only: the per-pid table file.
    let pids_bytes = pid_table.as_ref().map(|(entries, _)| {
        let mut out = Vec::with_capacity(TABLE_HEADER_BYTES + entries.len() * PID_ENTRY_BYTES);
        out.extend_from_slice(PIDS_MAGIC);
        out.extend_from_slice(&(entries.len() as u64).to_le_bytes());
        for e in entries {
            out.extend_from_slice(&e.start.to_le_bytes());
            out.extend_from_slice(&e.count.to_le_bytes());
        }
        out
    });
    let pids_len = pids_bytes.as_ref().map_or(0, |b| b.len() as u64);
    track((blocks_bytes.len() + seqs_bytes.len()) as u64 + pids_len);
    let blocks_checksum = checksum_hex(fnv1a64(FNV1A64_INIT, &blocks_bytes));
    let seqs_checksum = checksum_hex(fnv1a64(FNV1A64_INIT, &seqs_bytes));
    let pids_checksum =
        pids_bytes.as_ref().map(|b| checksum_hex(fnv1a64(FNV1A64_INIT, b)));
    std::fs::write(out_dir.join(BLOCKS_FILE), &blocks_bytes)?;
    std::fs::write(out_dir.join(SEQS_FILE), &seqs_bytes)?;
    if let Some(b) = &pids_bytes {
        std::fs::write(out_dir.join(PIDS_FILE), b)?;
    }
    untrack((blocks_bytes.len() + seqs_bytes.len()) as u64 + pids_len);
    let (blocks_len, seqs_len) = (blocks_bytes.len() as u64, seqs_bytes.len() as u64);
    drop(blocks_bytes);
    drop(seqs_bytes);
    drop(pids_bytes);

    let version = if pid_table.is_some() { INDEX_FORMAT_VERSION } else { 1 };
    let data_checksum = checksum_hex(data_fnv);
    let mut fields = vec![
        ("format", Json::from(INDEX_FORMAT)),
        ("version", Json::from(version)),
        ("block_records", Json::from(block_records)),
        ("total_records", Json::from(written)),
        ("num_patients", Json::from(num_patients as u64)),
        ("num_phenx", Json::from(num_phenx as u64)),
        ("distinct_seqs", Json::from(seqs.len())),
        (
            "data",
            Json::obj(vec![
                ("name", Json::from(DATA_FILE)),
                ("records", Json::from(written)),
                ("checksum", Json::from(data_checksum.clone())),
            ]),
        ),
        (
            "blocks",
            Json::obj(vec![
                ("name", Json::from(BLOCKS_FILE)),
                ("count", Json::from(blocks.len())),
                ("checksum", Json::from(blocks_checksum)),
            ]),
        ),
        (
            "seqs",
            Json::obj(vec![
                ("name", Json::from(SEQS_FILE)),
                ("count", Json::from(seqs.len())),
                ("checksum", Json::from(seqs_checksum)),
            ]),
        ),
    ];
    if let Some((entries, pdata_checksum)) = &pid_table {
        fields.push((
            "pids",
            Json::obj(vec![
                ("name", Json::from(PIDS_FILE)),
                ("count", Json::from(entries.len())),
                ("checksum", Json::from(pids_checksum.clone().expect("pids serialized"))),
            ]),
        ));
        fields.push((
            "pdata",
            Json::obj(vec![
                ("name", Json::from(PDATA_FILE)),
                ("records", Json::from(written)),
                ("checksum", Json::from(pdata_checksum.clone())),
            ]),
        ));
    }
    // Append-only manifest key, deliberately WITHOUT a version bump:
    // pre-target readers parse by name and ignore unknown keys, so an
    // old binary opens a targeted artifact fine (it just cannot report
    // the spec). `cargo xtask lint` pins this compatibility class —
    // adding keys is allowed, changing or removing existing ones is not.
    if let Some(t) = target {
        fields.push(("target", t.to_json()));
    }
    let manifest = Json::obj(fields);
    let manifest_text = manifest.to_string_pretty();
    std::fs::write(out_dir.join(MANIFEST_FILE), &manifest_text)?;

    let pdata_disk = if pid_table.is_some() {
        std::fs::metadata(out_dir.join(PDATA_FILE))?.len()
    } else {
        0
    };
    let artifact_bytes = std::fs::metadata(&data_path)?.len()
        + blocks_len
        + seqs_len
        + pids_len
        + pdata_disk
        + manifest_text.len() as u64;

    let pids = pid_table.map(|(entries, pdata_checksum)| PidTable {
        data_path: out_dir.join(PDATA_FILE),
        data_checksum: pdata_checksum,
        entries,
    });

    Ok(SeqIndex {
        dir: out_dir.to_path_buf(),
        data_path,
        version,
        block_records,
        total_records: written,
        num_patients,
        num_phenx,
        data_checksum,
        artifact_bytes,
        blocks,
        seqs,
        pids,
        target: target.cloned(),
    })
}

/// Counting-sort the just-written seq-major data file by pid into the
/// pid-major copy (`pdata_0000.tspm`), returning the per-pid entry table
/// and the copy's record checksum. Out-of-core in two passes: one scan
/// partitions the records into at most [`MAX_PID_BUCKETS`] (+1 tail)
/// pid-range bucket files whose sizes come from the exact per-pid
/// counts; each bucket is then loaded alone, stably sorted by pid
/// (records arrive in `(seq, pid, duration)` order, so the stable sort
/// preserves the `(seq, duration)` order inside every patient), and
/// appended to the copy. Resident set: one read buffer + one bucket
/// (~`max(block_records, total/64)` records, more only when a single
/// patient alone exceeds that — their run must be contiguous anyway).
fn build_pid_major(
    data_path: &Path,
    out_dir: &Path,
    pid_counts: &[u64],
    total_records: u64,
    block_records: usize,
    tracker: Option<&MemTracker>,
) -> Result<(Vec<PidEntry>, String), QueryError> {
    let track = |b: u64| {
        if let Some(t) = tracker {
            t.add(b)
        }
    };
    let untrack = |b: u64| {
        if let Some(t) = tracker {
            t.sub(b)
        }
    };

    let mut entries = Vec::with_capacity(pid_counts.len());
    let mut start = 0u64;
    for &c in pid_counts {
        entries.push(PidEntry { start, count: c });
        start += c;
    }
    debug_assert_eq!(start, total_records, "counts come from the same pass");

    let pdata_path = out_dir.join(PDATA_FILE);
    if total_records == 0 {
        let w = SeqWriter::create(&pdata_path)?;
        w.finish()?;
        return Ok((entries, checksum_hex(FNV1A64_INIT)));
    }

    // Pid ranges sized so every closed bucket holds ≥ target records —
    // at most MAX_PID_BUCKETS full buckets plus a tail, whatever the
    // pid skew.
    let target =
        (block_records as u64).max(total_records.div_ceil(MAX_PID_BUCKETS)).max(1);
    let mut ranges: Vec<(u32, u64)> = Vec::new(); // (first pid, records in range)
    {
        let mut lo = 0usize;
        let mut acc = 0u64;
        for (pid, &c) in pid_counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                ranges.push((lo as u32, acc));
                lo = pid + 1;
                acc = 0;
            }
        }
        if acc > 0 || ranges.is_empty() {
            ranges.push((lo as u32, acc));
        }
    }

    let read_cap = block_records.clamp(1024, 64 * 1024);
    let read_bytes = (read_cap * RECORD_BYTES) as u64;
    // Small per-bucket write buffers: up to ~65 writers are open at
    // once during the partition pass, so the seqstore default of 1 MiB
    // each would dwarf the data being shuffled (and the run's budget).
    let bucket_cap = 8 << 10;
    let bucket_paths: Vec<PathBuf> = (0..ranges.len())
        .map(|i| out_dir.join(format!("pidsort_{i:04}.tmp")))
        .collect();
    let mut buf = vec![ZERO_REC; read_cap];
    track(read_bytes);
    let result = (|| -> Result<String, QueryError> {
        // Pass 1: partition the data file into one bucket per pid range.
        let mut writers = Vec::with_capacity(ranges.len());
        for p in &bucket_paths {
            writers.push(SeqWriter::create_with_capacity(p, bucket_cap)?);
        }
        track((ranges.len() * bucket_cap) as u64);
        let mut reader = SeqReader::open_with_capacity(data_path, read_cap * RECORD_BYTES)?;
        loop {
            let got = reader.read_batch(&mut buf)?;
            if got == 0 {
                break;
            }
            for &r in &buf[..got] {
                let i = ranges.partition_point(|&(lo, _)| lo <= r.pid) - 1;
                writers[i].write(r)?;
            }
        }
        for w in writers {
            w.finish()?;
        }
        untrack((ranges.len() * bucket_cap) as u64);

        // Pass 2: per bucket — load (budget-sized reader), stable-sort
        // by pid, append to the copy. One bucket's records plus one
        // reader buffer and the (tracked) pdata writer buffer resident.
        let mut w = SeqWriter::create_with_capacity(&pdata_path, read_cap * RECORD_BYTES)?;
        track(read_bytes); // pdata writer buffer
        let mut fnv = FNV1A64_INIT;
        for (i, &(_, n_range)) in ranges.iter().enumerate() {
            track(n_range * RECORD_BYTES as u64 + read_bytes);
            let mut recs = vec![ZERO_REC; n_range as usize];
            {
                let mut br = SeqReader::open_with_capacity(
                    &bucket_paths[i],
                    read_cap * RECORD_BYTES,
                )?;
                if br.total() != n_range {
                    untrack(n_range * RECORD_BYTES as u64 + read_bytes);
                    return Err(QueryError::Artifact(format!(
                        "{}: pid bucket holds {} records, expected {n_range}",
                        bucket_paths[i].display(),
                        br.total()
                    )));
                }
                let mut filled = 0usize;
                while filled < recs.len() {
                    let got = br.read_batch(&mut recs[filled..])?;
                    if got == 0 {
                        break;
                    }
                    filled += got;
                }
            }
            recs.sort_by_key(|r| r.pid); // stable: (seq, duration) kept per pid
            for &r in &recs {
                w.write(r)?;
                fnv = fnv1a64(fnv, &seqstore::encode_record(r));
            }
            untrack(n_range * RECORD_BYTES as u64 + read_bytes);
            let _ = std::fs::remove_file(&bucket_paths[i]);
        }
        let written = w.finish()?;
        untrack(read_bytes); // pdata writer buffer
        if written != total_records {
            return Err(QueryError::Artifact(format!(
                "pid-major copy holds {written} records, expected {total_records}"
            )));
        }
        Ok(checksum_hex(fnv))
    })();
    untrack(read_bytes);
    for p in &bucket_paths {
        let _ = std::fs::remove_file(p);
    }
    Ok((entries, result?))
}

// ---------------------------------------------------------------------------
// Parsing helpers
// ---------------------------------------------------------------------------

fn field_err(path: &Path, field: &str) -> QueryError {
    QueryError::Artifact(format!("{}: missing or invalid field {field:?}", path.display()))
}

fn req_u64(j: &Json, field: &str, path: &Path) -> Result<u64, QueryError> {
    j.get(field).and_then(Json::as_u64).ok_or_else(|| field_err(path, field))
}

/// Parse + gate a manifest file on `format` and a supported version
/// range; returns the document and the version it declares.
fn read_manifest_json(
    path: &Path,
    want_format: &str,
    min_version: u64,
    max_version: u64,
) -> Result<(Json, u64), QueryError> {
    let text = std::fs::read_to_string(path).map_err(|e| {
        QueryError::Io(io::Error::new(e.kind(), format!("{}: {e}", path.display())))
    })?;
    let j = Json::parse(&text)
        .map_err(|e| QueryError::Artifact(format!("{}: {e}", path.display())))?;
    let format = j.get("format").and_then(Json::as_str).unwrap_or("");
    if format != want_format {
        return Err(QueryError::Artifact(format!(
            "{}: format is {format:?}, expected {want_format:?}",
            path.display()
        )));
    }
    let version = j.get("version").and_then(Json::as_u64).unwrap_or(0);
    if !(min_version..=max_version).contains(&version) {
        return Err(QueryError::Artifact(format!(
            "{}: unsupported {want_format} version {version} (this build reads \
             versions {min_version}..={max_version})",
            path.display()
        )));
    }
    Ok((j, version))
}

/// `(name, count, checksum)` of a manifest file section.
fn file_section(j: &Json, key: &str, path: &Path) -> Result<(String, u64, String), QueryError> {
    let sect = j.get(key).ok_or_else(|| field_err(path, key))?;
    let name = sect
        .get("name")
        .and_then(Json::as_str)
        .ok_or_else(|| field_err(path, key))?;
    let count = sect
        .get("records")
        .or_else(|| sect.get("count"))
        .and_then(Json::as_u64)
        .ok_or_else(|| field_err(path, key))?;
    let checksum = sect
        .get("checksum")
        .and_then(Json::as_str)
        .ok_or_else(|| field_err(path, key))?;
    Ok((name.to_string(), count, checksum.to_string()))
}

/// Read one binary table file, validating magic, entry count, exact
/// size, and checksum against the manifest.
fn read_table_file(
    path: &Path,
    magic: &[u8; 8],
    want_count: u64,
    entry_bytes: usize,
    want_checksum: &str,
) -> Result<Vec<u8>, QueryError> {
    let bytes = std::fs::read(path).map_err(|e| {
        QueryError::Io(io::Error::new(e.kind(), format!("{}: {e}", path.display())))
    })?;
    if checksum_hex(fnv1a64(FNV1A64_INIT, &bytes)) != want_checksum {
        return Err(QueryError::Artifact(format!(
            "{}: checksum mismatch — the artifact is corrupt or was modified",
            path.display()
        )));
    }
    if bytes.len() < TABLE_HEADER_BYTES || &bytes[..8] != magic {
        return Err(QueryError::Artifact(format!(
            "{}: bad table magic",
            path.display()
        )));
    }
    let count = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
    if count != want_count {
        return Err(QueryError::Artifact(format!(
            "{}: table holds {count} entries but the manifest claims {want_count}",
            path.display()
        )));
    }
    let expected = TABLE_HEADER_BYTES as u64 + count * entry_bytes as u64;
    if bytes.len() as u64 != expected {
        return Err(QueryError::Artifact(format!(
            "{}: table is {} bytes, expected {expected} for {count} entries",
            path.display(),
            bytes.len()
        )));
    }
    Ok(bytes)
}

fn read_u64(bytes: &[u8], off: &mut usize) -> u64 {
    let v = u64::from_le_bytes(bytes[*off..*off + 8].try_into().unwrap());
    *off += 8;
    v
}

fn read_u32(bytes: &[u8], off: &mut usize) -> u32 {
    let v = u32::from_le_bytes(bytes[*off..*off + 4].try_into().unwrap());
    *off += 4;
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("tspm_query_index_{}", std::process::id()))
            .join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn sorted_fixture() -> Vec<SeqRecord> {
        // 3 sequences, pid runs with duplicates, varied durations.
        let mut v = Vec::new();
        for (seq, pids) in [(5u64, 0u32..6), (9, 2..3), (40, 0..20)] {
            for pid in pids {
                for d in [10u32, 200, 10 + pid] {
                    v.push(SeqRecord { seq, pid, duration: d });
                }
            }
        }
        v.sort_unstable_by_key(|r| (r.seq, r.pid, r.duration));
        v
    }

    fn fileset(dir: &Path, records: &[SeqRecord], n_files: usize) -> SeqFileSet {
        std::fs::create_dir_all(dir).unwrap();
        let chunk = records.len().div_ceil(n_files.max(1)).max(1);
        let mut files = Vec::new();
        for (i, part) in records.chunks(chunk).enumerate() {
            let p = dir.join(format!("in_{i}.tspm"));
            seqstore::write_file(&p, part).unwrap();
            files.push(p);
        }
        if files.is_empty() {
            let p = dir.join("in_0.tspm");
            seqstore::write_file(&p, &[]).unwrap();
            files.push(p);
        }
        SeqFileSet {
            files,
            total_records: records.len() as u64,
            num_patients: 20,
            num_phenx: 7,
        }
    }

    #[test]
    fn build_then_open_round_trips_tables() {
        let dir = tmpdir("roundtrip");
        let data = sorted_fixture();
        let input = fileset(&dir, &data, 2);
        let built = build(
            &input,
            &dir.join("idx"),
            &IndexConfig { block_records: 7, ..Default::default() },
            None,
        )
        .unwrap();
        assert_eq!(built.total_records, data.len() as u64);
        assert_eq!(built.distinct_seqs(), 3);
        assert_eq!(built.blocks.len(), data.len().div_ceil(7));
        assert_eq!(built.version, INDEX_FORMAT_VERSION);
        // Reopening yields the identical tables and metadata.
        let opened = SeqIndex::open(&dir.join("idx")).unwrap();
        assert_eq!(opened.blocks, built.blocks);
        assert_eq!(opened.seqs, built.seqs);
        assert_eq!(opened.total_records, built.total_records);
        assert_eq!(opened.block_records, 7);
        assert_eq!(opened.data_checksum, built.data_checksum);
        assert_eq!(opened.version, built.version);
        opened.verify_data().unwrap();
        // The pid-major secondary index round-trips too, tiles the copy
        // contiguously, and the copy holds every pid's records in
        // (seq, duration) order.
        let built_pids = built.pids.as_ref().expect("v2 build has a pid table");
        let opened_pids = opened.pids.as_ref().expect("v2 open has a pid table");
        assert_eq!(opened_pids.entries, built_pids.entries);
        assert_eq!(opened_pids.data_checksum, built_pids.data_checksum);
        assert_eq!(opened_pids.entries.len(), input.num_patients as usize);
        let pdata = seqstore::read_file(&opened_pids.data_path).unwrap();
        assert_eq!(pdata.len(), data.len());
        for (pid, e) in opened_pids.entries.iter().enumerate() {
            let run = &pdata[e.start as usize..(e.start + e.count) as usize];
            let expect: Vec<SeqRecord> =
                data.iter().copied().filter(|r| r.pid == pid as u32).collect();
            assert_eq!(run, &expect[..], "pid {pid}");
        }
        // The copied data file is byte-faithful to the input records.
        assert_eq!(seqstore::read_file(&opened.data_path).unwrap(), data);
        // Per-seq entries are exact.
        let e = opened.seq_entry(5).unwrap();
        assert_eq!(e.count, 18);
        assert_eq!(e.patients, 6);
        assert_eq!((e.dur_min, e.dur_max), (10, 200));
        assert!(opened.seq_entry(6).is_none());
        // Block offsets tile the data file.
        let mut expect_start = 0u64;
        for b in &opened.blocks {
            assert_eq!(b.start, expect_start);
            expect_start += b.len as u64;
        }
        assert_eq!(expect_start, opened.total_records);
    }

    #[test]
    fn empty_input_builds_an_empty_artifact() {
        let dir = tmpdir("empty");
        let input = fileset(&dir, &[], 1);
        let built = build(&input, &dir.join("idx"), &IndexConfig::default(), None).unwrap();
        assert_eq!(built.total_records, 0);
        assert!(built.blocks.is_empty() && built.seqs.is_empty());
        let opened = SeqIndex::open(&dir.join("idx")).unwrap();
        assert_eq!(opened.total_records, 0);
        assert!(opened.seq_entry(1).is_none());
    }

    #[test]
    fn unsorted_input_is_rejected_and_leaves_no_partial_artifact() {
        let dir = tmpdir("unsorted");
        let mut data = sorted_fixture();
        data.swap(0, 10);
        let input = fileset(&dir, &data, 1);
        let idx_dir = dir.join("idx");
        let err = build(&input, &idx_dir, &IndexConfig::default(), None).unwrap_err();
        assert!(err.to_string().contains("not sorted"), "got {err}");
        // Failed builds clean up after themselves: no half-written data
        // file, no stale manifest.
        assert!(!idx_dir.join(DATA_FILE).exists());
        assert!(!idx_dir.join(MANIFEST_FILE).exists());
    }

    #[test]
    fn build_verified_checks_checksums_in_the_streaming_pass() {
        let dir = tmpdir("build_verified");
        let data = sorted_fixture();
        let input = fileset(&dir, &data, 2);
        write_spill_manifest(&dir, &input, true).unwrap();
        let manifest = read_spill_manifest(&dir).unwrap();

        // Clean input builds fine (no separate verify pass needed).
        let idx_dir = dir.join("idx");
        let built =
            build_verified(&manifest, &idx_dir, &IndexConfig { block_records: 16, ..Default::default() }, None)
                .unwrap();
        assert_eq!(built.total_records, data.len() as u64);

        // Corrupting one spill file is caught mid-build, and the failed
        // build removes the partial artifact.
        let victim = &manifest.files.files[1];
        let mut recs = seqstore::read_file(victim).unwrap();
        recs[0].duration ^= 1;
        seqstore::write_file(victim, &recs).unwrap();
        let idx_dir2 = dir.join("idx2");
        let err =
            build_verified(&manifest, &idx_dir2, &IndexConfig { block_records: 16, ..Default::default() }, None)
                .unwrap_err();
        assert!(err.to_string().contains("does not match"), "got {err}");
        assert!(!idx_dir2.join(DATA_FILE).exists());
    }

    #[test]
    fn spill_manifest_resolves_files_in_subdirectories() {
        // Unscreened runs leave their spill files under `<out-dir>/mine/`;
        // the manifest must record dir-relative paths, not bare names.
        let dir = tmpdir("subdir_manifest");
        let data = sorted_fixture();
        let sub = dir.join("mine");
        let input = fileset(&sub, &data, 2);
        write_spill_manifest(&dir, &input, false).unwrap();
        let m = read_spill_manifest(&dir).unwrap();
        assert!(!m.sorted);
        assert_eq!(m.files.files, input.files, "paths must resolve to the subdirectory");
        m.verify().unwrap();
    }

    #[test]
    fn zero_block_size_is_rejected() {
        let dir = tmpdir("zeroblock");
        let input = fileset(&dir, &sorted_fixture(), 1);
        let err =
            build(&input, &dir.join("idx"), &IndexConfig { block_records: 0, ..Default::default() }, None).unwrap_err();
        assert!(matches!(err, QueryError::Invalid(_)), "got {err}");
    }

    #[test]
    fn tampered_artifacts_are_refused() {
        let dir = tmpdir("tamper");
        let data = sorted_fixture();
        let input = fileset(&dir, &data, 1);
        let idx_dir = dir.join("idx");
        build(&input, &idx_dir, &IndexConfig { block_records: 8, ..Default::default() }, None).unwrap();

        // Flip one byte of the block table → checksum mismatch.
        let bpath = idx_dir.join(BLOCKS_FILE);
        let mut bytes = std::fs::read(&bpath).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        std::fs::write(&bpath, &bytes).unwrap();
        let err = SeqIndex::open(&idx_dir).unwrap_err();
        assert!(err.to_string().contains("checksum"), "got {err}");
        bytes[last] ^= 0xFF;
        std::fs::write(&bpath, &bytes).unwrap();
        SeqIndex::open(&idx_dir).unwrap();

        // A future version is refused with a version message.
        let mpath = idx_dir.join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&mpath).unwrap();
        std::fs::write(&mpath, text.replace("\"version\": 2", "\"version\": 99")).unwrap();
        let err = SeqIndex::open(&idx_dir).unwrap_err();
        assert!(err.to_string().contains("version 99"), "got {err}");
        std::fs::write(&mpath, text).unwrap();

        // Tampering with the pid table → checksum mismatch.
        let ppath = idx_dir.join(PIDS_FILE);
        let mut pbytes = std::fs::read(&ppath).unwrap();
        let last = pbytes.len() - 1;
        pbytes[last] ^= 0xFF;
        std::fs::write(&ppath, &pbytes).unwrap();
        let err = SeqIndex::open(&idx_dir).unwrap_err();
        assert!(err.to_string().contains("checksum"), "got {err}");
        pbytes[last] ^= 0xFF;
        std::fs::write(&ppath, &pbytes).unwrap();
        SeqIndex::open(&idx_dir).unwrap();

        // Truncating the pid-major copy is caught at open (count
        // mismatch); a silently doctored record is caught by
        // verify_data's checksum pass.
        let pdpath = idx_dir.join(PDATA_FILE);
        let pd_bytes = std::fs::read(&pdpath).unwrap();
        std::fs::write(&pdpath, &pd_bytes[..pd_bytes.len() - 16]).unwrap();
        assert!(SeqIndex::open(&idx_dir).is_err());
        let mut doctored = pd_bytes.clone();
        let last = doctored.len() - 1;
        doctored[last] ^= 0xFF;
        std::fs::write(&pdpath, &doctored).unwrap();
        let err = SeqIndex::open(&idx_dir).unwrap().verify_data().unwrap_err();
        assert!(err.to_string().contains("pid-major"), "got {err}");
        std::fs::write(&pdpath, &pd_bytes).unwrap();

        // Truncating the data file is caught at open (count mismatch).
        let opened = SeqIndex::open(&idx_dir).unwrap();
        let data_bytes = std::fs::read(&opened.data_path).unwrap();
        std::fs::write(&opened.data_path, &data_bytes[..data_bytes.len() - 16]).unwrap();
        assert!(SeqIndex::open(&idx_dir).is_err());
        std::fs::write(&opened.data_path, &data_bytes).unwrap();
        SeqIndex::open(&idx_dir).unwrap().verify_data().unwrap();
    }

    #[test]
    fn spill_manifest_round_trips_and_verifies() {
        let dir = tmpdir("spill_manifest");
        let data = sorted_fixture();
        let input = fileset(&dir, &data, 3);
        write_spill_manifest(&dir, &input, true).unwrap();
        let m = read_spill_manifest(&dir).unwrap();
        assert!(m.sorted);
        assert_eq!(m.files.total_records, data.len() as u64);
        assert_eq!(m.files.files, input.files);
        assert_eq!(m.files.num_patients, 20);
        m.verify().unwrap();

        // Appending a record to one spill file breaks verification.
        let victim = &input.files[1];
        let mut recs = seqstore::read_file(victim).unwrap();
        recs.push(SeqRecord { seq: 999, pid: 1, duration: 1 });
        seqstore::write_file(victim, &recs).unwrap();
        let err = read_spill_manifest(&dir).unwrap().verify().unwrap_err();
        assert!(err.to_string().contains("changed"), "got {err}");

        // A deleted spill file surfaces as a typed io error with the path.
        std::fs::remove_file(victim).unwrap();
        let err = read_spill_manifest(&dir).unwrap().verify().unwrap_err();
        assert!(err.to_string().contains("in_1.tspm"), "got {err}");
    }

    #[test]
    fn v1_artifact_without_pid_table_opens_and_round_trips() {
        // `pid_index: false` writes a bit-compatible v1 artifact: no
        // pids.bin / pdata, manifest version 1 — and open() still reads
        // it (the backward-compatibility contract for pre-v2 artifacts).
        let dir = tmpdir("v1_compat");
        let data = sorted_fixture();
        let input = fileset(&dir, &data, 2);
        let cfg = IndexConfig { block_records: 8, pid_index: false, ..Default::default() };
        let built = build(&input, &dir.join("idx"), &cfg, None).unwrap();
        assert_eq!(built.version, 1);
        assert!(built.pids.is_none());
        assert!(!dir.join("idx").join(PIDS_FILE).exists());
        assert!(!dir.join("idx").join(PDATA_FILE).exists());
        let text = std::fs::read_to_string(dir.join("idx").join(MANIFEST_FILE)).unwrap();
        assert!(text.contains("\"version\": 1"), "{text}");
        let opened = SeqIndex::open(&dir.join("idx")).unwrap();
        assert_eq!(opened.version, 1);
        assert!(opened.pids.is_none());
        assert_eq!(opened.seqs, built.seqs);
        opened.verify_data().unwrap();
    }

    #[test]
    fn empty_input_gets_an_empty_pid_table() {
        let dir = tmpdir("empty_pids");
        let input = fileset(&dir, &[], 1);
        let built = build(&input, &dir.join("idx"), &IndexConfig::default(), None).unwrap();
        let pids = built.pids.as_ref().expect("v2 build");
        assert_eq!(pids.entries.len(), 20);
        assert!(pids.entries.iter().all(|e| e.count == 0));
        let opened = SeqIndex::open(&dir.join("idx")).unwrap();
        assert_eq!(opened.pids.unwrap().entries, pids.entries);
    }

    #[test]
    fn pid_beyond_the_patient_count_is_rejected_for_v2_builds() {
        // The pid table is indexed by dense pid, so a record outside the
        // declared patient space cannot be placed — typed error, not a
        // bogus artifact. A v1 build (no pid table) still tolerates it.
        let dir = tmpdir("pid_range");
        let data = vec![SeqRecord { seq: 1, pid: 25, duration: 3 }];
        let input = fileset(&dir, &data, 1); // fileset claims 20 patients
        let err = build(&input, &dir.join("idx"), &IndexConfig::default(), None).unwrap_err();
        assert!(err.to_string().contains("pid 25"), "got {err}");
        assert!(!dir.join("idx").join(MANIFEST_FILE).exists(), "failed build cleans up");
        build(
            &input,
            &dir.join("idx_v1"),
            &IndexConfig { pid_index: false, ..Default::default() },
            None,
        )
        .unwrap();
    }

    #[test]
    fn pid_shuffle_is_correct_across_bucket_counts() {
        // A tiny block size forces many pid-range buckets; a huge one
        // collapses to a single bucket. Both must produce the identical
        // pid-major copy.
        let dir = tmpdir("buckets");
        let data = sorted_fixture();
        let input = fileset(&dir, &data, 1);
        let mut copies = Vec::new();
        for (name, block) in [("small", 1usize), ("large", 1 << 20)] {
            let idx_dir = dir.join(name);
            let built = build(
                &input,
                &idx_dir,
                &IndexConfig { block_records: block, ..Default::default() },
                None,
            )
            .unwrap();
            let pt = built.pids.as_ref().unwrap();
            let pdata = seqstore::read_file(&pt.data_path).unwrap();
            // Globally sorted by (pid, seq, duration).
            assert!(pdata
                .windows(2)
                .all(|w| (w[0].pid, w[0].seq, w[0].duration)
                    <= (w[1].pid, w[1].seq, w[1].duration)));
            copies.push((pdata, pt.entries.clone()));
        }
        assert_eq!(copies[0], copies[1]);
        // No shuffle temp files survive.
        for name in ["small", "large"] {
            assert!(std::fs::read_dir(dir.join(name))
                .unwrap()
                .flatten()
                .all(|e| !e.file_name().to_string_lossy().starts_with("pidsort_")));
        }
    }

    #[test]
    fn target_key_round_trips_without_a_version_bump() {
        let dir = tmpdir("target_key");
        let data = sorted_fixture();
        let input = fileset(&dir, &data, 1);

        // Untargeted build: NO target key in the manifest — byte-level
        // compatibility class unchanged.
        let plain_dir = dir.join("plain");
        let plain = build(&input, &plain_dir, &IndexConfig::default(), None).unwrap();
        assert!(plain.target.is_none());
        let text = std::fs::read_to_string(plain_dir.join(MANIFEST_FILE)).unwrap();
        assert!(!text.contains("\"target\""), "{text}");

        // Targeted build: key present, version untouched, spec reopens
        // identically (canonical form survives the JSON round trip).
        let spec = crate::target::TargetSpec::for_codes([4, 1, 4])
            .with_pos(crate::target::TargetPos::First)
            .with_duration_band(Some(2), Some(90));
        let t_dir = dir.join("targeted");
        let built = build(
            &input,
            &t_dir,
            &IndexConfig { target: Some(spec.clone()), ..Default::default() },
            None,
        )
        .unwrap();
        assert_eq!(built.version, plain.version, "append-only key must not bump");
        assert_eq!(built.target.as_ref(), Some(&spec));
        let text = std::fs::read_to_string(t_dir.join(MANIFEST_FILE)).unwrap();
        assert!(text.contains("\"target\""), "{text}");
        let opened = SeqIndex::open(&t_dir).unwrap();
        assert_eq!(opened.target.as_ref(), Some(&spec));

        // An all() spec is normalized away — same manifest as untargeted.
        let all_dir = dir.join("all");
        let built = build(
            &input,
            &all_dir,
            &IndexConfig { target: Some(crate::target::TargetSpec::all()), ..Default::default() },
            None,
        )
        .unwrap();
        assert!(built.target.is_none());
        assert_eq!(
            std::fs::read_to_string(all_dir.join(MANIFEST_FILE)).unwrap(),
            std::fs::read_to_string(plain_dir.join(MANIFEST_FILE)).unwrap(),
            "all() must write the byte-identical manifest"
        );

        // A manifest whose target value is malformed is a typed error.
        let text = std::fs::read_to_string(t_dir.join(MANIFEST_FILE)).unwrap();
        std::fs::write(
            t_dir.join(MANIFEST_FILE),
            text.replace("\"pos\": \"first\"", "\"pos\": \"sideways\""),
        )
        .unwrap();
        let err = SeqIndex::open(&t_dir).unwrap_err();
        assert!(err.to_string().contains("target"), "got {err}");
    }

    #[test]
    fn fnv_is_order_sensitive_and_stable() {
        let a = fnv1a64(FNV1A64_INIT, b"ab");
        let b = fnv1a64(FNV1A64_INIT, b"ba");
        assert_ne!(a, b);
        assert_eq!(a, fnv1a64(fnv1a64(FNV1A64_INIT, b"a"), b"b"));
        // Known FNV-1a 64 vector: empty input is the offset basis.
        assert_eq!(fnv1a64(FNV1A64_INIT, b""), FNV1A64_INIT);
    }
}
