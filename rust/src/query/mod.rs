//! The query subsystem — indexed sequence artifacts and a cached query
//! service over spilled run results.
//!
//! Every subsystem before this one works in units of a *run*: mine a
//! cohort, screen it, leave the result in memory or in spill files
//! ([`crate::seqstore::SeqFileSet`]). Downstream consumers, however,
//! mostly ask for a small slice of the pattern space — one sequence's
//! records, one patient's history, the top-k sequences by support — and
//! answering those by re-scanning (or worse, materialising) the full
//! multiset wastes both IO and memory. This module turns a spilled run
//! into an **immutable, versioned, random-access artifact** and serves
//! point/range queries from it with bounded memory:
//!
//! * [`index::build`] streams a *sorted* [`crate::seqstore::SeqFileSet`]
//!   exactly once and writes a [`SeqIndex`] artifact;
//! * [`QueryService`] opens an artifact and answers
//!   [`by_sequence`](QueryService::by_sequence),
//!   [`by_patient`](QueryService::by_patient),
//!   [`patients_with`](QueryService::patients_with),
//!   [`top_k_by_support`](QueryService::top_k_by_support) and
//!   [`duration_histogram`](QueryService::duration_histogram) via
//!   block-bounded positioned reads
//!   ([`crate::seqstore::SeqReader::seek_record`]), with a size-bounded
//!   LRU result cache in front ([`LruCache`]; hits/misses observable via
//!   [`QueryService::stats`]);
//! * the surfaces: `tspm index` / `tspm query` on the CLI, and
//!   `.index(dir)` as an [`crate::engine::Engine`] plan stage after a
//!   spilled screen.
//!
//! ## The artifact format (v2)
//!
//! An index directory holds six files:
//!
//! ```text
//! manifest.json    versioned manifest: format ("tspm-seqindex"), version,
//!                  block size, record/patient/phenX counts, and the name +
//!                  count + FNV-1a checksum of each sibling file
//! data_0000.tspm   the records, TSPMSEQ1-encoded, globally sorted by
//!                  (seq, pid, duration) — the screen's spill order
//! blocks.bin       sparse block index: for every block of `block_records`
//!                  records, its start offset, length, first/last (seq, pid)
//!                  key, pid min/max and duration min/max (for pruning)
//! seqs.bin         per-sequence table: record offset + count, distinct
//!                  patient count (the support), duration min/max
//! pdata_0000.tspm  v2: the pid-major copy — the same records re-sorted by
//!                  (pid, seq, duration), so one patient's history is one
//!                  contiguous run
//! pids.bin         v2: per-pid table — for every dense pid, the (start,
//!                  count) of its run in the pid-major copy; the entries
//!                  tile the copy contiguously
//! ```
//!
//! The tables are small next to the data (one block entry per
//! `block_records` records, one seq entry per distinct sequence, one
//! 16-byte pid entry per patient) and are held resident by the service;
//! the data files are only ever read one block at a time. The pid-major
//! copy doubles the artifact's record payload on disk — the price of
//! [`QueryService::by_patient`] reading exactly the patient's own
//! records instead of scanning the sequence-major file (pass
//! `pid_index: false` in [`IndexConfig`] to trade that back for a v1
//! artifact). The pid-major copy serves `by_patient` only; the
//! out-of-core matrix builder
//! ([`crate::matrix::SeqMatrix::from_index`]) streams the **seq-major**
//! data file block-at-a-time — it works on v1 artifacts too — so
//! engine chains `mine → screen → index → matrix → msmr` never
//! materialize the record multiset.
//!
//! ## Compatibility guarantee
//!
//! The manifest's `(format, version)` pair gates every read:
//! [`SeqIndex::open`] reads versions
//! [`INDEX_MIN_FORMAT_VERSION`]`..=`[`INDEX_FORMAT_VERSION`] and refuses
//! anything else, so a future layout change bumps the version and old
//! readers fail loudly instead of misreading. **v1 artifacts stay
//! readable**: they simply have no pid table, and `by_patient` falls
//! back to the v1 block-pruned scan with byte-identical answers. Within
//! one version the layout is frozen: files are little-endian,
//! checksummed (FNV-1a 64 over the file bytes; over the 16-byte record
//! encodings for the data files), and never rewritten in place — an
//! artifact, once built, is immutable. The spill manifest
//! `tspm mine --out-dir` writes next to `lookup.json` uses the same
//! scheme (`"tspm-spill"`, [`SPILL_FORMAT_VERSION`]) so `tspm index` can
//! verify its input before building.
//!
//! ## Beyond one artifact: segment sets
//!
//! An artifact never changes after it is built, which makes it a natural
//! **segment** of a growing dataset: [`crate::ingest`] groups several
//! artifacts under a segment-set manifest (`segments.json`, format
//! `"tspm-segset"`, same versioned + checksummed + atomically-swapped
//! scheme as the manifests above) and answers the full query surface
//! over all of them at once. The [`QuerySurface`] trait in this module
//! is the seam: [`QueryService`] implements it over one artifact,
//! [`crate::ingest::MergedView`] over a whole set, and the serving layer
//! routes to either through `Arc<dyn QuerySurface>`.

pub mod cache;
pub mod index;
pub mod service;
pub mod surface;

pub use cache::{CacheSnapshot, LruCache, SharedCache};
pub use index::{
    checksum_records, read_spill_manifest, write_spill_manifest, BlockMeta, IndexConfig,
    PidEntry, PidTable, SeqIndex, SeqTableEntry, SpillManifest, DEFAULT_BLOCK_RECORDS,
    INDEX_FORMAT_VERSION, INDEX_MIN_FORMAT_VERSION, SPILL_FORMAT_VERSION,
};
pub use service::{
    Histogram, HistogramBucket, QueryResult, QueryService, QueryStats, SeqSupport,
    DEFAULT_CACHE_BYTES,
};
pub use surface::{QuerySurface, SurfaceInfo};

use std::fmt;

/// Errors of the query subsystem.
#[derive(Debug)]
pub enum QueryError {
    /// Filesystem failures while building or reading an artifact.
    Io(std::io::Error),
    /// A corrupt or incompatible artifact: bad magic, version mismatch,
    /// checksum mismatch, unsorted input, index/data disagreement.
    Artifact(String),
    /// A structurally invalid request (zero buckets, zero block size…).
    Invalid(String),
}

impl fmt::Display for QueryError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            QueryError::Io(e) => write!(f, "query io error: {e}"),
            QueryError::Artifact(msg) => write!(f, "query artifact error: {msg}"),
            QueryError::Invalid(msg) => write!(f, "invalid query: {msg}"),
        }
    }
}

impl std::error::Error for QueryError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            QueryError::Io(e) => Some(e),
            QueryError::Artifact(_) | QueryError::Invalid(_) => None,
        }
    }
}

impl From<std::io::Error> for QueryError {
    fn from(e: std::io::Error) -> Self {
        QueryError::Io(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_display_and_source() {
        use std::error::Error;
        let io: QueryError = std::io::Error::new(std::io::ErrorKind::Other, "disk").into();
        assert!(io.to_string().contains("disk"));
        assert!(io.source().is_some());
        let a = QueryError::Artifact("bad checksum".into());
        assert!(a.to_string().contains("bad checksum"));
        assert!(a.source().is_none());
        assert!(QueryError::Invalid("zero buckets".into()).to_string().contains("invalid"));
    }
}
