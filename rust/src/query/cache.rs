//! Size-bounded LRU cache keyed by canonicalized query strings.
//!
//! The cache is deliberately tiny and dependency-free: a `HashMap` for
//! lookup plus a `BTreeMap<stamp, key>` recency list, bounded by an
//! approximate byte budget rather than an entry count (query results
//! range from a 16-byte top-k row to a multi-megabyte record list, so
//! counting entries would let one giant answer evict nothing while a
//! thousand tiny ones thrash). Eviction is strict LRU: every `get` hit
//! re-stamps the entry; `put` evicts oldest-first until the new entry
//! fits. A value larger than the whole budget is simply not cached.

use std::collections::{BTreeMap, HashMap};

struct Slot<V> {
    value: V,
    bytes: usize,
    stamp: u64,
}

/// A byte-bounded LRU map from canonical query keys to cloneable
/// results. Not thread-safe by itself — [`crate::query::QueryService`]
/// wraps it in a `Mutex`.
pub struct LruCache<V: Clone> {
    capacity_bytes: usize,
    map: HashMap<String, Slot<V>>,
    order: BTreeMap<u64, String>,
    next_stamp: u64,
    bytes: usize,
    evictions: u64,
}

impl<V: Clone> LruCache<V> {
    pub fn new(capacity_bytes: usize) -> LruCache<V> {
        LruCache {
            capacity_bytes,
            map: HashMap::new(),
            order: BTreeMap::new(),
            next_stamp: 0,
            bytes: 0,
            evictions: 0,
        }
    }

    /// Look a key up; a hit refreshes its recency.
    pub fn get(&mut self, key: &str) -> Option<V> {
        let slot = self.map.get_mut(key)?;
        self.order.remove(&slot.stamp);
        slot.stamp = self.next_stamp;
        self.next_stamp += 1;
        self.order.insert(slot.stamp, key.to_string());
        Some(slot.value.clone())
    }

    /// Insert (or replace) a key, evicting least-recently-used entries
    /// until `bytes` fits the budget. Oversized values are dropped.
    pub fn put(&mut self, key: String, value: V, bytes: usize) {
        if self.capacity_bytes == 0 || bytes > self.capacity_bytes {
            return;
        }
        if let Some(old) = self.map.remove(&key) {
            self.order.remove(&old.stamp);
            self.bytes -= old.bytes;
        }
        while self.bytes + bytes > self.capacity_bytes {
            let Some((_, victim)) = self.order.pop_first() else { break };
            if let Some(old) = self.map.remove(&victim) {
                self.bytes -= old.bytes;
            }
            self.evictions += 1;
        }
        let stamp = self.next_stamp;
        self.next_stamp += 1;
        self.order.insert(stamp, key.clone());
        self.bytes += bytes;
        self.map.insert(key, Slot { value, bytes, stamp });
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Approximate bytes of all cached values.
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Entries evicted to make room since construction (or since the
    /// last [`LruCache::reset_evictions`]).
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Zero the eviction counter without touching the cached entries —
    /// lets a bench harness measure a steady-state window.
    pub fn reset_evictions(&mut self) {
        self.evictions = 0;
    }

    pub fn capacity_bytes(&self) -> usize {
        self.capacity_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_after_put_round_trips() {
        let mut c: LruCache<String> = LruCache::new(1024);
        assert!(c.is_empty());
        c.put("seq:1".into(), "a".into(), 100);
        assert_eq!(c.get("seq:1").as_deref(), Some("a"));
        assert_eq!(c.get("seq:2"), None);
        assert_eq!(c.len(), 1);
        assert_eq!(c.bytes(), 100);
    }

    #[test]
    fn evicts_least_recently_used_first() {
        let mut c: LruCache<u32> = LruCache::new(300);
        c.put("a".into(), 1, 100);
        c.put("b".into(), 2, 100);
        c.put("c".into(), 3, 100);
        // Touch "a" so "b" becomes the LRU victim.
        assert_eq!(c.get("a"), Some(1));
        c.put("d".into(), 4, 100);
        assert_eq!(c.get("b"), None, "b was least recently used");
        assert_eq!(c.get("a"), Some(1));
        assert_eq!(c.get("c"), Some(3));
        assert_eq!(c.get("d"), Some(4));
        assert_eq!(c.evictions(), 1);
        assert!(c.bytes() <= 300);
    }

    #[test]
    fn replacement_updates_bytes_without_duplication() {
        let mut c: LruCache<u32> = LruCache::new(300);
        c.put("a".into(), 1, 100);
        c.put("a".into(), 2, 250);
        assert_eq!(c.len(), 1);
        assert_eq!(c.bytes(), 250);
        assert_eq!(c.get("a"), Some(2));
    }

    #[test]
    fn oversized_and_zero_capacity_are_no_ops() {
        let mut c: LruCache<u32> = LruCache::new(100);
        c.put("huge".into(), 1, 101);
        assert!(c.is_empty());
        let mut z: LruCache<u32> = LruCache::new(0);
        z.put("a".into(), 1, 1);
        assert!(z.is_empty());
        assert_eq!(z.get("a"), None);
    }

    #[test]
    fn eviction_frees_enough_for_a_large_entry() {
        let mut c: LruCache<u32> = LruCache::new(100);
        for i in 0..10 {
            c.put(format!("k{i}"), i, 10);
        }
        assert_eq!(c.len(), 10);
        c.put("big".into(), 99, 95);
        assert_eq!(c.get("big"), Some(99));
        assert!(c.bytes() <= 100, "bytes {}", c.bytes());
        assert!(c.evictions() >= 9);
    }

    #[test]
    fn reset_evictions_keeps_entries() {
        let mut c: LruCache<u32> = LruCache::new(100);
        c.put("a".into(), 1, 60);
        c.put("b".into(), 2, 60); // evicts a
        assert_eq!(c.evictions(), 1);
        c.reset_evictions();
        assert_eq!(c.evictions(), 0);
        assert_eq!(c.get("b"), Some(2), "entries survive the counter reset");
    }
}
