//! Size-bounded LRU cache keyed by canonicalized query strings.
//!
//! The cache is deliberately tiny and dependency-free: a `HashMap` for
//! lookup plus a `BTreeMap<stamp, key>` recency list, bounded by an
//! approximate byte budget rather than an entry count (query results
//! range from a 16-byte top-k row to a multi-megabyte record list, so
//! counting entries would let one giant answer evict nothing while a
//! thousand tiny ones thrash). Eviction is strict LRU: every `get` hit
//! re-stamps the entry; `put` evicts oldest-first until the new entry
//! fits. A value larger than the whole budget is simply not cached.
//!
//! [`SharedCache`] is the thread-safe face: the LRU plus its hit/miss
//! counters behind **one** mutex (from the [`crate::sync`] shim, so the
//! protocol is loom-model-checked), which is what makes a
//! [`SharedCache::snapshot`] internally consistent — `hits + misses`
//! always equals the number of completed lookups, never a torn pair.

use crate::sync::{lock_ignore_poison, Mutex};
use std::collections::{BTreeMap, HashMap};

struct Slot<V> {
    value: V,
    bytes: usize,
    stamp: u64,
}

/// A byte-bounded LRU map from canonical query keys to cloneable
/// results. Not thread-safe by itself — [`crate::query::QueryService`]
/// wraps it in a `Mutex`.
pub struct LruCache<V: Clone> {
    capacity_bytes: usize,
    map: HashMap<String, Slot<V>>,
    order: BTreeMap<u64, String>,
    next_stamp: u64,
    bytes: usize,
    evictions: u64,
}

impl<V: Clone> LruCache<V> {
    pub fn new(capacity_bytes: usize) -> LruCache<V> {
        LruCache {
            capacity_bytes,
            map: HashMap::new(),
            order: BTreeMap::new(),
            next_stamp: 0,
            bytes: 0,
            evictions: 0,
        }
    }

    /// Look a key up; a hit refreshes its recency.
    pub fn get(&mut self, key: &str) -> Option<V> {
        let slot = self.map.get_mut(key)?;
        self.order.remove(&slot.stamp);
        slot.stamp = self.next_stamp;
        self.next_stamp += 1;
        self.order.insert(slot.stamp, key.to_string());
        Some(slot.value.clone())
    }

    /// Insert (or replace) a key, evicting least-recently-used entries
    /// until `bytes` fits the budget. Oversized values are dropped.
    pub fn put(&mut self, key: String, value: V, bytes: usize) {
        if self.capacity_bytes == 0 || bytes > self.capacity_bytes {
            return;
        }
        if let Some(old) = self.map.remove(&key) {
            self.order.remove(&old.stamp);
            self.bytes -= old.bytes;
        }
        while self.bytes + bytes > self.capacity_bytes {
            let Some((_, victim)) = self.order.pop_first() else { break };
            if let Some(old) = self.map.remove(&victim) {
                self.bytes -= old.bytes;
            }
            self.evictions += 1;
        }
        let stamp = self.next_stamp;
        self.next_stamp += 1;
        self.order.insert(stamp, key.clone());
        self.bytes += bytes;
        self.map.insert(key, Slot { value, bytes, stamp });
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Approximate bytes of all cached values.
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Entries evicted to make room since construction (or since the
    /// last [`LruCache::reset_evictions`]).
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Zero the eviction counter without touching the cached entries —
    /// lets a bench harness measure a steady-state window.
    pub fn reset_evictions(&mut self) {
        self.evictions = 0;
    }

    pub fn capacity_bytes(&self) -> usize {
        self.capacity_bytes
    }
}

/// One internally-consistent view of a [`SharedCache`]'s counters: all
/// five fields were read under the same lock acquisition that guards
/// their updates.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheSnapshot {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub entries: usize,
    pub bytes: usize,
}

struct Counted<V: Clone> {
    lru: LruCache<V>,
    hits: u64,
    misses: u64,
}

/// A counted, thread-safe LRU: the cache **and** its hit/miss counters
/// behind a single mutex, so `hits + misses == lookups` holds at every
/// instant a [`SharedCache::snapshot`] can observe.
///
/// Lock acquisition recovers from poisoning
/// ([`crate::sync::lock_ignore_poison`]): the LRU's bookkeeping is fully
/// consistent before the only caller-controlled code (the value's
/// `Clone`) runs, so a panicking clone strands at most one uncounted
/// lookup — it never corrupts the map or wedges later callers.
pub struct SharedCache<V: Clone> {
    state: Mutex<Counted<V>>,
    capacity_bytes: usize,
}

impl<V: Clone> SharedCache<V> {
    /// A shared cache with an approximate byte budget (0 disables
    /// caching — every lookup is a counted miss).
    pub fn new(capacity_bytes: usize) -> SharedCache<V> {
        SharedCache {
            state: Mutex::new(Counted { lru: LruCache::new(capacity_bytes), hits: 0, misses: 0 }),
            capacity_bytes,
        }
    }

    /// Look a key up and count the outcome — hit or miss is decided and
    /// recorded under the same lock the snapshot reads. The outcome is
    /// additionally fed to the process-wide [`crate::obs`] registry
    /// (dual-feed: this cache's snapshot stays the per-service view,
    /// the registry aggregates every cache in the process).
    pub fn get(&self, key: &str) -> Option<V> {
        let mut st = lock_ignore_poison(&self.state);
        if self.capacity_bytes == 0 {
            st.misses += 1;
            record_global_lookup(false);
            return None;
        }
        match st.lru.get(key) {
            Some(v) => {
                st.hits += 1;
                record_global_lookup(true);
                Some(v)
            }
            None => {
                st.misses += 1;
                record_global_lookup(false);
                None
            }
        }
    }

    /// Insert (or replace) a key; see [`LruCache::put`] for the
    /// eviction/oversize semantics.
    pub fn put(&self, key: String, value: V, bytes: usize) {
        if self.capacity_bytes == 0 {
            return;
        }
        let mut st = lock_ignore_poison(&self.state);
        let before = st.lru.evictions();
        st.lru.put(key, value, bytes);
        let evicted = st.lru.evictions().saturating_sub(before);
        drop(st);
        if evicted > 0 {
            record_global_evictions(evicted);
        }
    }

    /// All counters in one consistent read (see [`CacheSnapshot`]).
    pub fn snapshot(&self) -> CacheSnapshot {
        let st = lock_ignore_poison(&self.state);
        CacheSnapshot {
            hits: st.hits,
            misses: st.misses,
            evictions: st.lru.evictions(),
            entries: st.lru.len(),
            bytes: st.lru.bytes(),
        }
    }

    /// Zero hits/misses/evictions without dropping cached entries — the
    /// bench-harness steady-state window.
    pub fn reset(&self) {
        let mut st = lock_ignore_poison(&self.state);
        st.hits = 0;
        st.misses = 0;
        st.lru.reset_evictions();
    }

    pub fn capacity_bytes(&self) -> usize {
        self.capacity_bytes
    }
}

/// Feed the process-wide registry's cache pair. Compiled out under
/// loom: the global registry lives outside any loom model, and loom
/// primitives must not be touched from within one.
#[cfg(not(loom))]
fn record_global_lookup(hit: bool) {
    crate::obs::metrics::global().cache().record_lookup(hit);
}

#[cfg(loom)]
fn record_global_lookup(_hit: bool) {}

#[cfg(not(loom))]
fn record_global_evictions(n: u64) {
    crate::obs::metrics::global().cache().record_evictions(n);
}

#[cfg(loom)]
fn record_global_evictions(_n: u64) {}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn get_after_put_round_trips() {
        let mut c: LruCache<String> = LruCache::new(1024);
        assert!(c.is_empty());
        c.put("seq:1".into(), "a".into(), 100);
        assert_eq!(c.get("seq:1").as_deref(), Some("a"));
        assert_eq!(c.get("seq:2"), None);
        assert_eq!(c.len(), 1);
        assert_eq!(c.bytes(), 100);
    }

    #[test]
    fn evicts_least_recently_used_first() {
        let mut c: LruCache<u32> = LruCache::new(300);
        c.put("a".into(), 1, 100);
        c.put("b".into(), 2, 100);
        c.put("c".into(), 3, 100);
        // Touch "a" so "b" becomes the LRU victim.
        assert_eq!(c.get("a"), Some(1));
        c.put("d".into(), 4, 100);
        assert_eq!(c.get("b"), None, "b was least recently used");
        assert_eq!(c.get("a"), Some(1));
        assert_eq!(c.get("c"), Some(3));
        assert_eq!(c.get("d"), Some(4));
        assert_eq!(c.evictions(), 1);
        assert!(c.bytes() <= 300);
    }

    #[test]
    fn replacement_updates_bytes_without_duplication() {
        let mut c: LruCache<u32> = LruCache::new(300);
        c.put("a".into(), 1, 100);
        c.put("a".into(), 2, 250);
        assert_eq!(c.len(), 1);
        assert_eq!(c.bytes(), 250);
        assert_eq!(c.get("a"), Some(2));
    }

    #[test]
    fn oversized_and_zero_capacity_are_no_ops() {
        let mut c: LruCache<u32> = LruCache::new(100);
        c.put("huge".into(), 1, 101);
        assert!(c.is_empty());
        let mut z: LruCache<u32> = LruCache::new(0);
        z.put("a".into(), 1, 1);
        assert!(z.is_empty());
        assert_eq!(z.get("a"), None);
    }

    #[test]
    fn eviction_frees_enough_for_a_large_entry() {
        let mut c: LruCache<u32> = LruCache::new(100);
        for i in 0..10 {
            c.put(format!("k{i}"), i, 10);
        }
        assert_eq!(c.len(), 10);
        c.put("big".into(), 99, 95);
        assert_eq!(c.get("big"), Some(99));
        assert!(c.bytes() <= 100, "bytes {}", c.bytes());
        assert!(c.evictions() >= 9);
    }

    #[test]
    fn reset_evictions_keeps_entries() {
        let mut c: LruCache<u32> = LruCache::new(100);
        c.put("a".into(), 1, 60);
        c.put("b".into(), 2, 60); // evicts a
        assert_eq!(c.evictions(), 1);
        c.reset_evictions();
        assert_eq!(c.evictions(), 0);
        assert_eq!(c.get("b"), Some(2), "entries survive the counter reset");
    }

    #[test]
    fn shared_cache_counts_every_lookup_exactly_once() {
        let c: SharedCache<u32> = SharedCache::new(1024);
        assert_eq!(c.get("a"), None); // miss
        c.put("a".into(), 1, 10);
        assert_eq!(c.get("a"), Some(1)); // hit
        assert_eq!(c.get("b"), None); // miss
        let s = c.snapshot();
        assert_eq!((s.hits, s.misses), (1, 2));
        assert_eq!(s.hits + s.misses, 3, "every lookup counted once");
        assert_eq!((s.entries, s.bytes), (1, 10));
        c.reset();
        let s = c.snapshot();
        assert_eq!((s.hits, s.misses, s.entries), (0, 0, 1));
        // zero capacity: every lookup is a counted miss, puts are no-ops
        let z: SharedCache<u32> = SharedCache::new(0);
        z.put("a".into(), 1, 1);
        assert_eq!(z.get("a"), None);
        assert_eq!(z.snapshot().misses, 1);
    }

    /// A value whose `Clone` panics on demand — the only caller-supplied
    /// code that runs inside the cache's critical section.
    struct Grenade {
        armed: std::sync::Arc<std::sync::atomic::AtomicBool>,
    }

    impl Clone for Grenade {
        fn clone(&self) -> Grenade {
            if self.armed.load(std::sync::atomic::Ordering::SeqCst) {
                panic!("clone panics while the cache lock is held");
            }
            Grenade { armed: self.armed.clone() }
        }
    }

    #[test]
    fn shared_cache_survives_a_panicking_clone_under_the_lock() {
        use std::sync::atomic::{AtomicBool, Ordering};
        let armed = std::sync::Arc::new(AtomicBool::new(false));
        let c: SharedCache<Grenade> = SharedCache::new(1024);
        c.put("k".into(), Grenade { armed: armed.clone() }, 10);
        assert!(c.get("k").is_some(), "disarmed clone works");
        // Arm it: the next hit panics inside the critical section and
        // poisons the mutex.
        armed.store(true, Ordering::SeqCst);
        let res = std::thread::scope(|s| s.spawn(|| c.get("k")).join());
        assert!(res.is_err(), "the clone did panic");
        armed.store(false, Ordering::SeqCst);
        // Poison recovery: the cache still answers, counts, and accepts
        // new entries; the interrupted lookup is simply uncounted.
        assert!(c.get("k").is_some(), "recovered after poisoning");
        c.put("k2".into(), Grenade { armed: armed.clone() }, 10);
        assert!(c.get("k2").is_some());
        let s = c.snapshot();
        assert_eq!(s.entries, 2);
        assert!(s.hits >= 3, "counters still advance after recovery");
    }
}

/// Exhaustive-interleaving check of the torn-snapshot contract. The
/// workload performs miss → put → hit on one key; on *every* schedule an
/// observer snapshot must satisfy `hits <= misses` (a hit can only exist
/// after its preceding miss), which only holds because the counters and
/// the LRU share a single lock. See the crate "Verification" docs.
#[cfg(all(test, loom))]
mod loom_tests {
    use super::*;
    use crate::sync::Arc;

    #[test]
    fn loom_snapshot_is_never_torn() {
        loom::model(|| {
            let c: Arc<SharedCache<u32>> = Arc::new(SharedCache::new(1024));
            let worker = {
                let c = Arc::clone(&c);
                loom::thread::spawn(move || {
                    assert_eq!(c.get("k"), None); // miss
                    c.put("k".into(), 7, 8);
                    assert_eq!(c.get("k"), Some(7)); // hit
                })
            };
            let s = c.snapshot();
            assert!(
                s.hits <= s.misses,
                "torn snapshot: hit visible without its preceding miss ({s:?})"
            );
            assert!(s.hits + s.misses <= 2, "over-counted lookups ({s:?})");
            worker.join().unwrap();
            let end = c.snapshot();
            assert_eq!((end.hits, end.misses), (1, 1));
        });
    }
}
