//! The query engine: answers point/range queries over a [`SeqIndex`]
//! artifact with block-bounded reads and an LRU result cache.
//!
//! Memory contract: aside from the resident index tables and the
//! returned results themselves, a query's working set is **one block of
//! records plus one block-sized reader buffer** (`block_records × 16`
//! bytes each) — never the data file. Every buffer is accounted against
//! an optional [`MemTracker`] so tests can assert the bound.
//!
//! Caching: results are cached under a canonicalized key (range bounds
//! normalized, `k` clamped to the distinct-sequence count) in a
//! size-bounded counted LRU ([`crate::query::cache::SharedCache`]);
//! results are shared as `Arc`s, so a cache hit clones a pointer, not
//! the records. Hit/miss counts are observable via
//! [`QueryService::stats`]. The service is `&self` throughout (cache
//! behind a mutex, counters atomic), so a serving layer can share one
//! instance across threads.

use super::cache::SharedCache;
use super::index::SeqIndex;
use super::QueryError;
use crate::metrics::MemTracker;
use crate::mining::SeqRecord;
use crate::seqstore::{SeqReader, RECORD_BYTES};
use crate::sync::atomic::{AtomicU64, Ordering};
use std::path::Path;
use std::sync::Arc;

/// Default result-cache budget (32 MiB).
pub const DEFAULT_CACHE_BYTES: usize = 32 << 20;

const ZERO_REC: SeqRecord = SeqRecord { seq: 0, pid: 0, duration: 0 };

/// One row of a [`QueryService::top_k_by_support`] answer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SeqSupport {
    pub seq: u64,
    /// Distinct patients (the support the sparsity screen thresholds on).
    pub patients: u32,
    /// Total records of the sequence.
    pub records: u64,
}

/// One bucket of a [`QueryService::duration_histogram`] answer
/// (inclusive bounds).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HistogramBucket {
    pub lo: u32,
    pub hi: u32,
    pub count: u64,
}

/// A duration histogram over one sequence's records.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Histogram {
    pub seq: u64,
    pub dur_min: u32,
    pub dur_max: u32,
    /// Total records bucketed (the sequence's record count; 0 when the
    /// sequence is absent).
    pub total: u64,
    pub buckets: Vec<HistogramBucket>,
}

/// A cached query answer. `Arc`-wrapped so hits share, never copy.
#[derive(Clone, Debug)]
pub enum QueryResult {
    Records(Arc<Vec<SeqRecord>>),
    Patients(Arc<Vec<u32>>),
    TopK(Arc<Vec<SeqSupport>>),
    Histogram(Arc<Histogram>),
}

fn result_bytes(r: &QueryResult) -> usize {
    const OVERHEAD: usize = 64;
    match r {
        QueryResult::Records(v) => v.len() * std::mem::size_of::<SeqRecord>() + OVERHEAD,
        QueryResult::Patients(v) => v.len() * std::mem::size_of::<u32>() + OVERHEAD,
        QueryResult::TopK(v) => v.len() * std::mem::size_of::<SeqSupport>() + OVERHEAD,
        QueryResult::Histogram(h) => {
            h.buckets.len() * std::mem::size_of::<HistogramBucket>() + OVERHEAD
        }
    }
}

/// Cache/traffic counters of one service instance.
///
/// A [`QueryService::stats`] snapshot is **internally consistent** for
/// the cache-side counters: `hits`, `misses`, `evictions`,
/// `cached_entries` and `cached_bytes` are all read under the one lock
/// that guards their updates, so concurrent readers never observe a
/// torn pair — `hits + misses` always equals the number of cache
/// lookups completed at the snapshot instant. `logical_bytes_read` is a
/// separate monotone counter updated outside the lock (scans are
/// lock-free) and is only guaranteed to be *some* value between two
/// quiescent points.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct QueryStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub cached_entries: usize,
    pub cached_bytes: usize,
    /// Logical record bytes streamed from the data files (records read ×
    /// 16) since the service opened — the IO-bound observable: a
    /// pid-indexed `by_patient` adds exactly the patient's own records,
    /// a v1 scan adds every candidate block.
    pub logical_bytes_read: u64,
}

/// The query engine over one immutable index artifact. The cache and
/// its hit/miss counters live in one [`SharedCache`] (a single mutex,
/// from the [`crate::sync`] shim), which is what makes the stats
/// guarantee above model-checkable under loom.
pub struct QueryService {
    index: SeqIndex,
    cache: SharedCache<QueryResult>,
    bytes_read: AtomicU64,
    tracker: Option<Arc<MemTracker>>,
}

impl QueryService {
    /// Open an artifact directory with the default cache budget.
    pub fn open(dir: &Path) -> Result<QueryService, QueryError> {
        Ok(QueryService::from_index(SeqIndex::open(dir)?, DEFAULT_CACHE_BYTES))
    }

    /// [`QueryService::open`] with an explicit cache budget in bytes
    /// (0 disables caching entirely — every query recomputes).
    pub fn open_with_cache(dir: &Path, cache_bytes: usize) -> Result<QueryService, QueryError> {
        Ok(QueryService::from_index(SeqIndex::open(dir)?, cache_bytes))
    }

    /// Wrap an already-loaded index.
    pub fn from_index(index: SeqIndex, cache_bytes: usize) -> QueryService {
        QueryService {
            index,
            cache: SharedCache::new(cache_bytes),
            bytes_read: AtomicU64::new(0),
            tracker: None,
        }
    }

    /// Account every read buffer against `tracker` (for budget proofs).
    pub fn set_tracker(&mut self, tracker: Arc<MemTracker>) {
        self.tracker = Some(tracker);
    }

    /// The underlying artifact.
    pub fn index(&self) -> &SeqIndex {
        &self.index
    }

    /// Cache hit/miss/size and IO counters — one consistent snapshot
    /// (see [`QueryStats`] for the exact guarantee).
    pub fn stats(&self) -> QueryStats {
        let s = self.cache.snapshot();
        QueryStats {
            hits: s.hits,
            misses: s.misses,
            evictions: s.evictions,
            cached_entries: s.entries,
            cached_bytes: s.bytes,
            logical_bytes_read: self.bytes_read.load(Ordering::Relaxed),
        }
    }

    /// Zero every traffic counter (hits, misses, evictions,
    /// `logical_bytes_read`) **without dropping the cached entries**, so
    /// a bench harness can warm the cache and then measure a clean
    /// steady-state window. `cached_entries`/`cached_bytes` reflect
    /// retained state and are untouched.
    pub fn reset_stats(&self) {
        self.cache.reset();
        self.bytes_read.store(0, Ordering::Relaxed);
    }

    // --- queries -----------------------------------------------------------

    /// All records of `seq`, in `(pid, duration)` order (empty when the
    /// sequence is absent).
    pub fn by_sequence(&self, seq: u64) -> Result<Arc<Vec<SeqRecord>>, QueryError> {
        let key = format!("seq:{seq}");
        if let Some(QueryResult::Records(v)) = self.cache_get(&key) {
            return Ok(v);
        }
        let mut out = Vec::new();
        if let Some(e) = self.index.seq_entry(seq).copied() {
            out.reserve(e.count as usize);
            self.scan_range(e.start, e.start + e.count, |r| out.push(r))?;
        }
        let v = Arc::new(out);
        self.cache_put(key, QueryResult::Records(v.clone()));
        Ok(v)
    }

    /// All records of patient `pid`, in `(seq, duration)` order.
    ///
    /// On a v2 artifact this is the **pid-indexed fast path**: the
    /// resident per-pid table gives the patient's contiguous run in the
    /// pid-major copy, so the query reads exactly the patient's own
    /// records — IO scales with the answer, not the artifact. v1
    /// artifacts (no pid table) fall back to the block-pruned scan
    /// ([`QueryService::by_patient_scan`]); both paths return
    /// byte-identical answers.
    pub fn by_patient(&self, pid: u32) -> Result<Arc<Vec<SeqRecord>>, QueryError> {
        let key = format!("pid:{pid}");
        if let Some(QueryResult::Records(v)) = self.cache_get(&key) {
            return Ok(v);
        }
        let out = match &self.index.pids {
            Some(pt) => {
                let mut out = Vec::new();
                if let Some(e) = pt.entries.get(pid as usize) {
                    out.reserve(e.count as usize);
                    self.scan_file(
                        &pt.data_path,
                        e.start,
                        e.start + e.count,
                        |r| out.push(r),
                    )?;
                }
                out
            }
            None => self.by_patient_scan(pid)?,
        };
        let v = Arc::new(out);
        self.cache_put(key, QueryResult::Records(v.clone()));
        Ok(v)
    }

    /// The v1 `by_patient` path: scan the sequence-major data file block
    /// by block, pruned by per-block pid bounds. Uncached — public so
    /// the conformance tests (and curious benchmarks) can diff it
    /// against the pid-indexed fast path; [`QueryService::by_patient`]
    /// dispatches here automatically for v1 artifacts.
    pub fn by_patient_scan(&self, pid: u32) -> Result<Vec<SeqRecord>, QueryError> {
        let mut out = Vec::new();
        let blocks = &self.index.blocks;
        let candidate = |b: &super::index::BlockMeta| (b.pid_min..=b.pid_max).contains(&pid);
        let mut i = 0;
        while i < blocks.len() {
            if !candidate(&blocks[i]) {
                i += 1;
                continue;
            }
            // Coalesce adjacent candidate blocks into one scan.
            let mut j = i;
            while j + 1 < blocks.len() && candidate(&blocks[j + 1]) {
                j += 1;
            }
            let start = blocks[i].start;
            let end = blocks[j].start + blocks[j].len as u64;
            self.scan_range(start, end, |r| {
                if r.pid == pid {
                    out.push(r);
                }
            })?;
            i = j + 1;
        }
        Ok(out)
    }

    /// Stream patient `pid`'s records through `f` **one block at a
    /// time**, in the same `(seq, duration)` order
    /// [`QueryService::by_patient`] returns — without ever materializing
    /// the patient and without touching the result cache. This is the
    /// serving-layer path: a daemon writing a heavy patient to a socket
    /// holds one block of records resident, not `O(patient)`.
    ///
    /// Chunks passed to `f` hold at most `block_records` records each.
    /// The callback's error type is generic (any `E: From<QueryError>`),
    /// so a caller can abort the stream with its own error — e.g. a
    /// socket write failure — and get it back unchanged. Returns the
    /// total number of records streamed.
    ///
    /// Memory contract: on a v2 artifact the working set is the shared
    /// scan buffers (2 × block); on a v1 fallback one extra block-sized
    /// carry buffer filters the block-pruned scan — all
    /// tracker-accounted, never proportional to the patient.
    pub fn by_patient_visit<E: From<QueryError>>(
        &self,
        pid: u32,
        mut f: impl FnMut(&[SeqRecord]) -> Result<(), E>,
    ) -> Result<u64, E> {
        if let Some(pt) = &self.index.pids {
            let mut total = 0u64;
            if let Some(e) = pt.entries.get(pid as usize) {
                self.scan_blocks(&pt.data_path, e.start, e.start + e.count, |chunk| {
                    total += chunk.len() as u64;
                    f(chunk)
                })?;
            }
            return Ok(total);
        }
        // v1 fallback: block-pruned scan of the seq-major file with a
        // bounded carry buffer — flushed every time it fills, so the
        // resident set stays one block even for a very heavy patient.
        let cap = self.index.block_records.max(1);
        let carry_bytes = (cap * RECORD_BYTES) as u64;
        self.track(carry_bytes);
        let result = (|| -> Result<u64, E> {
            let mut carry: Vec<SeqRecord> = Vec::with_capacity(cap);
            let mut total = 0u64;
            let blocks = &self.index.blocks;
            let candidate = |b: &super::index::BlockMeta| (b.pid_min..=b.pid_max).contains(&pid);
            let mut i = 0;
            while i < blocks.len() {
                if !candidate(&blocks[i]) {
                    i += 1;
                    continue;
                }
                let mut j = i;
                while j + 1 < blocks.len() && candidate(&blocks[j + 1]) {
                    j += 1;
                }
                let start = blocks[i].start;
                let end = blocks[j].start + blocks[j].len as u64;
                self.scan_blocks(&self.index.data_path, start, end, |chunk| {
                    for &r in chunk {
                        if r.pid == pid {
                            carry.push(r);
                            if carry.len() == cap {
                                total += carry.len() as u64;
                                f(&carry)?;
                                carry.clear();
                            }
                        }
                    }
                    Ok(())
                })?;
                i = j + 1;
            }
            if !carry.is_empty() {
                total += carry.len() as u64;
                f(&carry)?;
            }
            Ok(total)
        })();
        self.untrack(carry_bytes);
        result
    }

    /// Distinct patients having `seq` with a duration in the inclusive
    /// range — the targeted-mining shape (TaTIRP-style "who had A→B
    /// within N days"). Bounds are canonicalized (swapped if reversed);
    /// blocks whose duration range misses the query are skipped without
    /// being read.
    pub fn patients_with(
        &self,
        seq: u64,
        dur_min: u32,
        dur_max: u32,
    ) -> Result<Arc<Vec<u32>>, QueryError> {
        let (lo, hi) = if dur_min <= dur_max { (dur_min, dur_max) } else { (dur_max, dur_min) };
        let key = format!("pw:{seq}:{lo}:{hi}");
        if let Some(QueryResult::Patients(v)) = self.cache_get(&key) {
            return Ok(v);
        }
        let mut out: Vec<u32> = Vec::new();
        if let Some(e) = self.index.seq_entry(seq).copied() {
            let (s, t) = (e.start, e.start + e.count);
            for bi in self.block_span(s, t) {
                let b = self.index.blocks[bi];
                if b.dur_max < lo || b.dur_min > hi {
                    continue; // the whole block misses the duration range
                }
                let bs = b.start.max(s);
                let be = (b.start + b.len as u64).min(t);
                self.scan_range(bs, be, |r| {
                    if (lo..=hi).contains(&r.duration) {
                        out.push(r.pid);
                    }
                })?;
            }
            // Within a sequence run the records are pid-sorted, and
            // skipping blocks preserves order, so adjacent dedup is a
            // full dedup.
            out.dedup();
        }
        let v = Arc::new(out);
        self.cache_put(key, QueryResult::Patients(v.clone()));
        Ok(v)
    }

    /// The `k` sequences with the most distinct patients (ties broken
    /// by ascending seq — fully deterministic). Answered from the
    /// resident per-sequence table: no IO at all.
    pub fn top_k_by_support(&self, k: usize) -> Result<Arc<Vec<SeqSupport>>, QueryError> {
        let k = k.min(self.index.seqs.len());
        let key = format!("topk:{k}");
        if let Some(QueryResult::TopK(v)) = self.cache_get(&key) {
            return Ok(v);
        }
        let mut all: Vec<SeqSupport> = self
            .index
            .seqs
            .iter()
            .map(|e| SeqSupport { seq: e.seq, patients: e.patients, records: e.count })
            .collect();
        all.sort_unstable_by(|a, b| b.patients.cmp(&a.patients).then(a.seq.cmp(&b.seq)));
        all.truncate(k);
        let v = Arc::new(all);
        self.cache_put(key, QueryResult::TopK(v.clone()));
        Ok(v)
    }

    /// Histogram of `seq`'s durations over `n_buckets` equal-width
    /// buckets spanning its `[dur_min, dur_max]` (from the index; the
    /// trailing bucket is clipped to `dur_max`). Fewer than `n_buckets`
    /// buckets come back when the duration span is narrower than the
    /// bucket count. An absent sequence yields an empty histogram.
    pub fn duration_histogram(
        &self,
        seq: u64,
        n_buckets: usize,
    ) -> Result<Arc<Histogram>, QueryError> {
        if n_buckets == 0 {
            return Err(QueryError::Invalid("histogram needs at least one bucket".into()));
        }
        let key = format!("hist:{seq}:{n_buckets}");
        if let Some(QueryResult::Histogram(v)) = self.cache_get(&key) {
            return Ok(v);
        }
        let hist = match self.index.seq_entry(seq).copied() {
            None => Histogram { seq, dur_min: 0, dur_max: 0, total: 0, buckets: Vec::new() },
            Some(e) => {
                if e.dur_max < e.dur_min {
                    return Err(QueryError::Artifact(format!(
                        "{}: sequence {seq} has duration bounds [{}, {}] — the \
                         sequence table is corrupt",
                        self.index.data_path.display(),
                        e.dur_min,
                        e.dur_max
                    )));
                }
                let span = (e.dur_max - e.dur_min) as u64 + 1;
                let width = span.div_ceil(n_buckets as u64).max(1);
                let used = span.div_ceil(width) as usize;
                let mut counts = vec![0u64; used];
                // A record whose duration falls outside the index
                // entry's [dur_min, dur_max] means the data file and the
                // sequence table disagree (a corrupt or hand-edited
                // artifact — verify_data() is opt-in, so it can reach
                // here). Computing `r.duration - e.dur_min` in u32 would
                // wrap in release and panic on the bucket index; surface
                // a typed error naming the offender instead.
                let mut pos = e.start;
                let mut bad: Option<(u64, u32)> = None;
                self.scan_range(e.start, e.start + e.count, |r| {
                    if r.duration < e.dur_min || r.duration > e.dur_max {
                        bad.get_or_insert((pos, r.duration));
                    } else {
                        let i = ((r.duration - e.dur_min) as u64 / width) as usize;
                        counts[i] += 1;
                    }
                    pos += 1;
                })?;
                if let Some((record, duration)) = bad {
                    return Err(QueryError::Artifact(format!(
                        "{}: record {record} of sequence {seq} has duration \
                         {duration}, outside the index entry's [{}, {}] — the \
                         artifact is corrupt (run verify_data() to confirm)",
                        self.index.data_path.display(),
                        e.dur_min,
                        e.dur_max
                    )));
                }
                let buckets = counts
                    .iter()
                    .enumerate()
                    .map(|(i, &count)| {
                        let lo = e.dur_min as u64 + i as u64 * width;
                        let hi = (lo + width - 1).min(e.dur_max as u64);
                        HistogramBucket { lo: lo as u32, hi: hi as u32, count }
                    })
                    .collect();
                Histogram {
                    seq,
                    dur_min: e.dur_min,
                    dur_max: e.dur_max,
                    total: e.count,
                    buckets,
                }
            }
        };
        let v = Arc::new(hist);
        self.cache_put(key, QueryResult::Histogram(v.clone()));
        Ok(v)
    }

    // --- internals ---------------------------------------------------------

    fn cache_get(&self, key: &str) -> Option<QueryResult> {
        // SharedCache counts the outcome under the same lock the
        // snapshot reads, so `hits + misses == lookups` at every instant.
        self.cache.get(key)
    }

    fn cache_put(&self, key: String, value: QueryResult) {
        if self.cache.capacity_bytes() == 0 {
            return;
        }
        let bytes = result_bytes(&value);
        self.cache.put(key, value, bytes);
    }

    fn track(&self, bytes: u64) {
        if let Some(t) = &self.tracker {
            t.add(bytes);
        }
    }

    fn untrack(&self, bytes: u64) {
        if let Some(t) = &self.tracker {
            t.sub(bytes);
        }
    }

    /// Block ids whose records overlap `[start, end)` — pure arithmetic,
    /// since blocks tile the data file in `block_records` strides.
    fn block_span(&self, start: u64, end: u64) -> std::ops::Range<usize> {
        if start >= end {
            return 0..0;
        }
        let b = self.index.block_records.max(1) as u64;
        (start / b) as usize..((end - 1) / b) as usize + 1
    }

    /// Stream records `[start, end)` of the sequence-major data file
    /// through `f` — see [`QueryService::scan_file`].
    fn scan_range(
        &self,
        start: u64,
        end: u64,
        f: impl FnMut(SeqRecord),
    ) -> Result<(), QueryError> {
        self.scan_file(&self.index.data_path, start, end, f)
    }

    /// Per-record wrapper over [`QueryService::scan_blocks`].
    fn scan_file(
        &self,
        path: &Path,
        start: u64,
        end: u64,
        mut f: impl FnMut(SeqRecord),
    ) -> Result<(), QueryError> {
        self.scan_blocks::<QueryError>(path, start, end, |chunk| {
            for &r in chunk {
                f(r);
            }
            Ok(())
        })
    }

    /// Stream records `[start, end)` of one artifact data file through
    /// `f` one block at a time, holding exactly one block-sized record
    /// buffer and one block-sized reader buffer resident (both
    /// tracker-accounted). Every record streamed is added to the
    /// `logical_bytes_read` counter, so tests can prove a query's IO
    /// bound. Generic over the callback's error type so a serving layer
    /// can abort a scan with its own error (e.g. a dead socket) without
    /// round-tripping through [`QueryError`].
    fn scan_blocks<E: From<QueryError>>(
        &self,
        path: &Path,
        start: u64,
        end: u64,
        mut f: impl FnMut(&[SeqRecord]) -> Result<(), E>,
    ) -> Result<(), E> {
        if start >= end {
            return Ok(());
        }
        let scan_bytes = (end - start) * RECORD_BYTES as u64;
        self.bytes_read.fetch_add(scan_bytes, Ordering::Relaxed);
        // Process-wide aggregates (counters — atomic adds only) plus an
        // ambient child span when a tracer is active on this thread
        // (the serve request path pushes one), so a traced request's
        // JSONL shows exactly which block scans answered it.
        let reg = crate::obs::metrics::global();
        reg.counter(crate::obs::names::QUERY_BLOCK_READS).inc();
        reg.counter(crate::obs::names::QUERY_BYTES_READ).add(scan_bytes);
        let mut scan_span = crate::obs::trace::current_span("query.block_scan");
        if let Some(s) = scan_span.as_mut() {
            s.attr("records", end - start);
            s.attr("bytes", scan_bytes);
        }
        let cap = self.index.block_records.max(1);
        let buf_bytes = (cap * RECORD_BYTES) as u64 * 2;
        self.track(buf_bytes);
        let result = (|| -> Result<(), E> {
            let mut reader =
                SeqReader::open_with_capacity(path, cap * RECORD_BYTES).map_err(QueryError::from)?;
            reader.seek_record(start).map_err(QueryError::from)?;
            let mut buf = vec![ZERO_REC; cap];
            let mut left = end - start;
            while left > 0 {
                let want = left.min(buf.len() as u64) as usize;
                let got = reader.read_batch(&mut buf[..want]).map_err(QueryError::from)?;
                if got == 0 {
                    return Err(QueryError::Artifact(format!(
                        "{}: data file ends before record {end} the index references",
                        path.display()
                    ))
                    .into());
                }
                f(&buf[..got])?;
                left -= got as u64;
            }
            Ok(())
        })();
        self.untrack(buf_bytes);
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::index::{build, IndexConfig};
    use crate::seqstore::{self, SeqFileSet};
    use std::path::PathBuf;

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("tspm_query_service_{}", std::process::id()))
            .join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn fixture() -> Vec<SeqRecord> {
        let mut v = Vec::new();
        for (seq, n_pids) in [(3u64, 4u32), (17, 2), (90, 9)] {
            for pid in 0..n_pids {
                for d in [5u32, 30, 500] {
                    v.push(SeqRecord { seq, pid, duration: d });
                }
            }
        }
        v.sort_unstable_by_key(|r| (r.seq, r.pid, r.duration));
        v
    }

    fn service_with(
        name: &str,
        block: usize,
        cache: usize,
        pid_index: bool,
    ) -> (QueryService, Vec<SeqRecord>) {
        let dir = tmpdir(name);
        let data = fixture();
        let path = dir.join("in.tspm");
        seqstore::write_file(&path, &data).unwrap();
        let input = SeqFileSet {
            files: vec![path],
            total_records: data.len() as u64,
            num_patients: 9,
            num_phenx: 4,
        };
        let idx = build(
            &input,
            &dir.join("idx"),
            &IndexConfig { block_records: block, pid_index, ..Default::default() },
            None,
        )
        .unwrap();
        (QueryService::from_index(idx, cache), data)
    }

    fn service(name: &str, block: usize, cache: usize) -> (QueryService, Vec<SeqRecord>) {
        service_with(name, block, cache, true)
    }

    #[test]
    fn by_sequence_exact_and_missing() {
        let (svc, data) = service("by_seq", 5, DEFAULT_CACHE_BYTES);
        let got = svc.by_sequence(17).unwrap();
        let expect: Vec<SeqRecord> = data.iter().copied().filter(|r| r.seq == 17).collect();
        assert_eq!(*got, expect);
        assert!(svc.by_sequence(4).unwrap().is_empty());
    }

    #[test]
    fn by_patient_crosses_sequences() {
        let (svc, data) = service("by_pid", 4, DEFAULT_CACHE_BYTES);
        let got = svc.by_patient(1).unwrap();
        let expect: Vec<SeqRecord> = data.iter().copied().filter(|r| r.pid == 1).collect();
        assert_eq!(*got, expect);
        assert!(svc.by_patient(1000).unwrap().is_empty());
    }

    #[test]
    fn by_patient_fast_path_equals_scan_path_and_v1_service() {
        let (v2, data) = service("by_pid_fast", 4, 0);
        let (v1, _) = service_with("by_pid_v1", 4, 0, false);
        assert!(v2.index().pids.is_some());
        assert!(v1.index().pids.is_none());
        for pid in 0..10u32 {
            let expect: Vec<SeqRecord> =
                data.iter().copied().filter(|r| r.pid == pid).collect();
            assert_eq!(*v2.by_patient(pid).unwrap(), expect, "fast path, pid {pid}");
            assert_eq!(v2.by_patient_scan(pid).unwrap(), expect, "scan path, pid {pid}");
            assert_eq!(*v1.by_patient(pid).unwrap(), expect, "v1 fallback, pid {pid}");
        }
    }

    #[test]
    fn by_patient_io_scales_with_the_answer_not_the_artifact() {
        let (svc, data) = service("by_pid_io", 4, 0);
        let before = svc.stats().logical_bytes_read;
        let got = svc.by_patient(1).unwrap();
        let delta = svc.stats().logical_bytes_read - before;
        // Fast path: exactly the patient's own records are streamed.
        assert_eq!(delta, got.len() as u64 * RECORD_BYTES as u64);
        assert!(delta < (data.len() * RECORD_BYTES) as u64 / 2, "read ~everything");
        // The scan path on the same artifact reads strictly more.
        let before = svc.stats().logical_bytes_read;
        svc.by_patient_scan(1).unwrap();
        let scan_delta = svc.stats().logical_bytes_read - before;
        assert!(scan_delta > delta, "scan {scan_delta} vs indexed {delta}");
    }

    #[test]
    fn histogram_on_doctored_artifact_is_a_typed_error_not_a_panic() {
        // Rewrite one record's duration to a value far outside the
        // sequence entry's [dur_min, dur_max] — exactly what an opt-in
        // verify_data() permits to go unnoticed. The u32 subtraction
        // would wrap in release; it must surface as QueryError::Artifact.
        let (svc, data) = service("hist_doctored", 4, 0);
        let idx = svc.index();
        let target = idx.seq_entry(3).unwrap();
        let victim = target.start; // first record of seq 3
        let mut recs = seqstore::read_file(&idx.data_path).unwrap();
        recs[victim as usize].duration = 1_000_000; // dur_max is 500
        seqstore::write_file(&idx.data_path, &recs).unwrap();
        let err = svc.duration_histogram(3, 4).unwrap_err();
        assert!(
            matches!(&err, QueryError::Artifact(m) if m.contains("1000000")
                && m.contains(&format!("record {victim}"))),
            "got {err}"
        );
        // A duration *below* dur_min wraps too — same typed error.
        recs[victim as usize].duration = 1; // dur_min is 5
        seqstore::write_file(&idx.data_path, &recs).unwrap();
        let err = svc.duration_histogram(3, 4).unwrap_err();
        assert!(matches!(err, QueryError::Artifact(_)), "got {err}");
        // Untouched sequences still answer.
        let expect: Vec<SeqRecord> = data.iter().copied().filter(|r| r.seq == 90).collect();
        assert_eq!(svc.duration_histogram(90, 4).unwrap().total, expect.len() as u64);
    }

    #[test]
    fn patients_with_filters_and_dedups() {
        let (svc, _) = service("pw", 3, DEFAULT_CACHE_BYTES);
        // Durations are {5, 30, 500} for every pid; [10, 100] matches only 30.
        let got = svc.patients_with(90, 10, 100).unwrap();
        assert_eq!(*got, (0..9).collect::<Vec<u32>>());
        // Reversed bounds canonicalize to the same answer (and cache key).
        let rev = svc.patients_with(90, 100, 10).unwrap();
        assert_eq!(*rev, *got);
        assert_eq!(svc.stats().hits, 1, "reversed bounds must hit the cache");
        // A range matching nothing.
        assert!(svc.patients_with(90, 501, 600).unwrap().is_empty());
        assert!(svc.patients_with(12345, 0, u32::MAX).unwrap().is_empty());
    }

    #[test]
    fn top_k_orders_by_support_then_seq() {
        let (svc, _) = service("topk", 4, DEFAULT_CACHE_BYTES);
        let got = svc.top_k_by_support(2).unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0], SeqSupport { seq: 90, patients: 9, records: 27 });
        assert_eq!(got[1], SeqSupport { seq: 3, patients: 4, records: 12 });
        // k beyond the table clamps (and shares the clamped cache key).
        let all = svc.top_k_by_support(100).unwrap();
        assert_eq!(all.len(), 3);
        let again = svc.top_k_by_support(usize::MAX).unwrap();
        assert_eq!(*again, *all);
    }

    #[test]
    fn histogram_covers_all_records() {
        let (svc, _) = service("hist", 4, DEFAULT_CACHE_BYTES);
        let h = svc.duration_histogram(3, 4).unwrap();
        assert_eq!((h.dur_min, h.dur_max, h.total), (5, 500, 12));
        assert_eq!(h.buckets.iter().map(|b| b.count).sum::<u64>(), 12);
        assert_eq!(h.buckets.first().unwrap().lo, 5);
        assert_eq!(h.buckets.last().unwrap().hi, 500);
        // One bucket degenerates to "everything".
        let h1 = svc.duration_histogram(3, 1).unwrap();
        assert_eq!(h1.buckets.len(), 1);
        assert_eq!(h1.buckets[0].count, 12);
        // Absent sequence → empty histogram; zero buckets → typed error.
        assert!(svc.duration_histogram(4, 3).unwrap().buckets.is_empty());
        assert!(matches!(
            svc.duration_histogram(3, 0).unwrap_err(),
            QueryError::Invalid(_)
        ));
    }

    #[test]
    fn cache_hits_share_results_and_are_observable() {
        let (svc, _) = service("cache_on", 4, DEFAULT_CACHE_BYTES);
        let a = svc.by_sequence(90).unwrap();
        let b = svc.by_sequence(90).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "a hit shares the cached Arc");
        let st = svc.stats();
        assert_eq!((st.hits, st.misses), (1, 1));
        assert_eq!(st.cached_entries, 1);
        assert!(st.cached_bytes > 0);
    }

    #[test]
    fn disabled_cache_still_answers_identically() {
        let (svc, data) = service("cache_off", 4, 0);
        let expect: Vec<SeqRecord> = data.iter().copied().filter(|r| r.seq == 90).collect();
        let a = svc.by_sequence(90).unwrap();
        let b = svc.by_sequence(90).unwrap();
        assert_eq!(*a, expect);
        assert_eq!(*b, expect);
        assert!(!Arc::ptr_eq(&a, &b), "nothing is cached at budget 0");
        let st = svc.stats();
        assert_eq!(st.hits, 0);
        assert_eq!(st.misses, 2);
        assert_eq!(st.cached_entries, 0);
    }

    #[test]
    fn by_patient_visit_streams_the_same_records_in_blocks() {
        for (name, pid_index) in [("visit_v2", true), ("visit_v1", false)] {
            let (svc, data) = service_with(name, 4, 0, pid_index);
            for pid in 0..10u32 {
                let expect: Vec<SeqRecord> =
                    data.iter().copied().filter(|r| r.pid == pid).collect();
                let mut streamed = Vec::new();
                let mut chunks = 0usize;
                let total = svc
                    .by_patient_visit::<QueryError>(pid, |chunk| {
                        assert!(chunk.len() <= 4, "chunk exceeds block_records");
                        assert!(!chunk.is_empty(), "empty chunks are never emitted");
                        chunks += 1;
                        streamed.extend_from_slice(chunk);
                        Ok(())
                    })
                    .unwrap();
                assert_eq!(streamed, expect, "{name}, pid {pid}");
                assert_eq!(total, expect.len() as u64);
                if expect.len() > 4 {
                    assert!(chunks > 1, "heavy patient must arrive in several blocks");
                }
            }
        }
    }

    #[test]
    fn by_patient_visit_propagates_the_caller_error_type() {
        #[derive(Debug)]
        enum SocketDead {
            Query(QueryError),
            Dead,
        }
        impl From<QueryError> for SocketDead {
            fn from(e: QueryError) -> Self {
                SocketDead::Query(e)
            }
        }
        let (svc, _) = service("visit_err", 2, 0);
        let mut seen = 0usize;
        let err = svc
            .by_patient_visit(1, |chunk| {
                seen += chunk.len();
                Err(SocketDead::Dead)
            })
            .unwrap_err();
        assert!(matches!(err, SocketDead::Dead), "got {err:?}");
        assert!(seen > 0 && seen <= 2, "aborted after the first chunk, saw {seen}");
    }

    #[test]
    fn by_patient_visit_memory_is_block_bounded_not_patient_bounded() {
        // One very heavy patient: pid 0 owns ~all of a 6k-record file.
        let dir = tmpdir("visit_heavy");
        let mut data: Vec<SeqRecord> = (0..6000u32)
            .map(|i| SeqRecord { seq: (i % 13) as u64, pid: 0, duration: i })
            .collect();
        data.push(SeqRecord { seq: 14, pid: 1, duration: 1 });
        data.sort_unstable_by_key(|r| (r.seq, r.pid, r.duration));
        let path = dir.join("in.tspm");
        seqstore::write_file(&path, &data).unwrap();
        let input = SeqFileSet {
            files: vec![path],
            total_records: data.len() as u64,
            num_patients: 2,
            num_phenx: 0,
        };
        for pid_index in [true, false] {
            let sub = dir.join(if pid_index { "v2" } else { "v1" });
            let idx = build(
                &input,
                &sub,
                &IndexConfig { block_records: 8, pid_index, ..Default::default() },
                None,
            )
            .unwrap();
            let mut svc = QueryService::from_index(idx, 0);
            let tracker = Arc::new(MemTracker::new());
            svc.set_tracker(tracker.clone());
            let mut n = 0u64;
            svc.by_patient_visit::<QueryError>(0, |chunk| {
                n += chunk.len() as u64;
                Ok(())
            })
            .unwrap();
            assert_eq!(n, 6000);
            // 2 scan buffers (+1 carry buffer on the v1 path) of 8
            // records each — nowhere near the 6000-record patient.
            let bound = 3 * 8 * RECORD_BYTES as u64;
            assert!(
                tracker.peak() <= bound,
                "pid_index={pid_index}: peak {} > bound {bound}",
                tracker.peak()
            );
            assert!(tracker.peak() < 6000 * RECORD_BYTES as u64 / 10);
            assert_eq!(tracker.live(), 0);
        }
    }

    #[test]
    fn reset_stats_zeroes_counters_but_keeps_the_cache() {
        let (svc, _) = service("reset", 4, DEFAULT_CACHE_BYTES);
        svc.by_sequence(90).unwrap();
        svc.by_sequence(90).unwrap();
        let st = svc.stats();
        assert_eq!((st.hits, st.misses), (1, 1));
        assert!(st.logical_bytes_read > 0);
        svc.reset_stats();
        let st = svc.stats();
        assert_eq!((st.hits, st.misses, st.evictions, st.logical_bytes_read), (0, 0, 0, 0));
        assert_eq!(st.cached_entries, 1, "cached entries survive the reset");
        // The retained entry answers as a hit against the fresh counters.
        svc.by_sequence(90).unwrap();
        let st = svc.stats();
        assert_eq!((st.hits, st.misses), (1, 0));
    }

    #[test]
    fn stats_lookup_identity_holds_under_concurrent_readers() {
        let (svc, _) = service("torn", 4, DEFAULT_CACHE_BYTES);
        let svc = Arc::new(svc);
        std::thread::scope(|s| {
            for t in 0..4 {
                let svc = svc.clone();
                s.spawn(move || {
                    for i in 0..200u64 {
                        let _ = svc.by_sequence([3u64, 17, 90][((t + i) % 3) as usize]);
                        // Every snapshot taken mid-hammering must balance.
                        let st = svc.stats();
                        assert!(st.hits + st.misses <= 4 * 200);
                    }
                });
            }
        });
        let st = svc.stats();
        assert_eq!(st.hits + st.misses, 4 * 200, "every lookup counted exactly once");
    }

    #[test]
    fn working_memory_is_block_bounded() {
        let (mut svc, data) = service("bounded", 4, 0);
        let tracker = Arc::new(MemTracker::new());
        svc.set_tracker(tracker.clone());
        svc.by_sequence(90).unwrap();
        svc.by_patient(1).unwrap();
        svc.duration_histogram(90, 8).unwrap();
        svc.patients_with(90, 0, u32::MAX).unwrap();
        // One record buffer + one reader buffer per active scan, 4
        // records each → 128 bytes; far below the 1.3 KiB data payload.
        let bound = 2 * 4 * RECORD_BYTES as u64;
        assert!(tracker.peak() <= bound, "peak {} > bound {bound}", tracker.peak());
        assert!(tracker.peak() < (data.len() * RECORD_BYTES) as u64);
        assert_eq!(tracker.live(), 0, "all buffers released");
    }
}
