//! The object-safe query surface — one trait over every answer source.
//!
//! [`crate::query::QueryService`] answers over a single artifact;
//! [`crate::ingest::MergedView`] answers over a whole segment set. The
//! serving layer ([`crate::serve::Registry`]) and the CLI want to route
//! to either without caring which, so both implement [`QuerySurface`]:
//! the full query surface plus a [`describe`](QuerySurface::describe)
//! summary, all through `&self` (implementations are `Send + Sync`, so
//! one instance is shared across serving threads behind an `Arc`).
//!
//! The trait is deliberately **object-safe** — registries hold
//! `Arc<dyn QuerySurface>` — which is why streaming uses
//! [`visit_patient`](QuerySurface::visit_patient) with a `&mut dyn
//! FnMut` callback over [`QueryError`] instead of the generic
//! [`crate::query::QueryService::by_patient_visit`]: a caller that must
//! abort with its own error (a dead socket, say) stashes it, returns a
//! `QueryError` to stop the scan, and re-raises the stashed error
//! afterwards (see `serve::server`).

use super::service::{Histogram, QueryService, QueryStats, SeqSupport};
use super::QueryError;
use crate::mining::SeqRecord;
use std::sync::Arc;

/// Size/shape summary of one query surface — what `tspm client --list`
/// reports per registered artifact or segment set.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SurfaceInfo {
    /// Total records behind the surface (summed across segments).
    pub records: u64,
    /// Distinct sequences (for a merged view: of the union).
    pub sequences: u64,
    /// Patients in the dense pid space.
    pub patients: u32,
    /// Artifact format version (for a merged view: the maximum across
    /// its segments).
    pub version: u64,
    /// Rendered [`crate::target::TargetSpec`] the surface's records were
    /// mined under (`None` = untargeted). For a merged view this is the
    /// segments' *unanimous* spec; segments that disagree report `None`,
    /// because their union is not the output of any single targeted run.
    pub target: Option<String>,
}

/// The query surface shared by [`QueryService`] (one artifact) and
/// [`crate::ingest::MergedView`] (a segment set). Answer semantics are
/// pinned by the single-artifact service and the ingest conformance
/// suite: **every method must return byte-identical answers no matter
/// how the records are split into segments.**
pub trait QuerySurface: Send + Sync {
    /// All records of `seq`, in `(pid, duration)` order.
    fn by_sequence(&self, seq: u64) -> Result<Arc<Vec<SeqRecord>>, QueryError>;

    /// All records of patient `pid`, in `(seq, duration)` order.
    fn by_patient(&self, pid: u32) -> Result<Arc<Vec<SeqRecord>>, QueryError>;

    /// Stream patient `pid`'s records through `f` in bounded chunks, in
    /// the same order [`QuerySurface::by_patient`] returns; returns the
    /// total streamed. Implementations bound the chunk size (one index
    /// block for a service; one patient for a merged view, whose merge
    /// must see the whole patient anyway).
    fn visit_patient(
        &self,
        pid: u32,
        f: &mut dyn FnMut(&[SeqRecord]) -> Result<(), QueryError>,
    ) -> Result<u64, QueryError>;

    /// Distinct patients having `seq` with a duration in the inclusive
    /// range (bounds canonicalized), ascending pid.
    fn patients_with(
        &self,
        seq: u64,
        dur_min: u32,
        dur_max: u32,
    ) -> Result<Arc<Vec<u32>>, QueryError>;

    /// The `k` sequences with the most distinct patients. Total order:
    /// support descending, then seq ascending — for a merged view the
    /// supports are summed across segments *before* ranking, so the
    /// result never depends on the segment layout.
    fn top_k_by_support(&self, k: usize) -> Result<Arc<Vec<SeqSupport>>, QueryError>;

    /// Histogram of `seq`'s durations over `n_buckets` equal-width
    /// buckets spanning its global `[dur_min, dur_max]`.
    fn duration_histogram(
        &self,
        seq: u64,
        n_buckets: usize,
    ) -> Result<Arc<Histogram>, QueryError>;

    /// Cache/traffic counters (summed across segments for a merged
    /// view).
    fn stats(&self) -> QueryStats;

    /// Size/shape summary for listings.
    fn describe(&self) -> SurfaceInfo;
}

impl QuerySurface for QueryService {
    fn by_sequence(&self, seq: u64) -> Result<Arc<Vec<SeqRecord>>, QueryError> {
        QueryService::by_sequence(self, seq)
    }

    fn by_patient(&self, pid: u32) -> Result<Arc<Vec<SeqRecord>>, QueryError> {
        QueryService::by_patient(self, pid)
    }

    fn visit_patient(
        &self,
        pid: u32,
        f: &mut dyn FnMut(&[SeqRecord]) -> Result<(), QueryError>,
    ) -> Result<u64, QueryError> {
        self.by_patient_visit::<QueryError>(pid, |chunk| f(chunk))
    }

    fn patients_with(
        &self,
        seq: u64,
        dur_min: u32,
        dur_max: u32,
    ) -> Result<Arc<Vec<u32>>, QueryError> {
        QueryService::patients_with(self, seq, dur_min, dur_max)
    }

    fn top_k_by_support(&self, k: usize) -> Result<Arc<Vec<SeqSupport>>, QueryError> {
        QueryService::top_k_by_support(self, k)
    }

    fn duration_histogram(
        &self,
        seq: u64,
        n_buckets: usize,
    ) -> Result<Arc<Histogram>, QueryError> {
        QueryService::duration_histogram(self, seq, n_buckets)
    }

    fn stats(&self) -> QueryStats {
        QueryService::stats(self)
    }

    fn describe(&self) -> SurfaceInfo {
        let idx = self.index();
        SurfaceInfo {
            records: idx.total_records,
            sequences: idx.distinct_seqs(),
            patients: idx.num_patients,
            version: idx.version,
            target: idx.target.as_ref().map(|t| t.render()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::index::{build, IndexConfig};
    use crate::seqstore::{self, SeqFileSet};

    fn fixture_service(name: &str) -> (QueryService, Vec<SeqRecord>) {
        let dir = std::env::temp_dir()
            .join(format!("tspm_surface_{}", std::process::id()))
            .join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mut data = Vec::new();
        for (seq, n_pids) in [(4u64, 3u32), (11, 5)] {
            for pid in 0..n_pids {
                for d in [1u32, 9] {
                    data.push(SeqRecord { seq, pid, duration: d });
                }
            }
        }
        data.sort_unstable_by_key(|r| (r.seq, r.pid, r.duration));
        let path = dir.join("in.tspm");
        seqstore::write_file(&path, &data).unwrap();
        let input = SeqFileSet {
            files: vec![path],
            total_records: data.len() as u64,
            num_patients: 5,
            num_phenx: 2,
        };
        let idx = build(
            &input,
            &dir.join("idx"),
            &IndexConfig {
                block_records: 4,
                target: Some(crate::target::TargetSpec::for_codes([0, 1])),
                ..Default::default()
            },
            None,
        )
        .unwrap();
        (QueryService::from_index(idx, 0), data)
    }

    #[test]
    fn trait_object_answers_match_the_inherent_methods() {
        let (svc, data) = fixture_service("dyn_equiv");
        let dynamic: &dyn QuerySurface = &svc;
        assert_eq!(*dynamic.by_sequence(11).unwrap(), *svc.by_sequence(11).unwrap());
        assert_eq!(*dynamic.by_patient(2).unwrap(), *svc.by_patient(2).unwrap());
        assert_eq!(
            *dynamic.patients_with(11, 0, 5).unwrap(),
            *svc.patients_with(11, 0, 5).unwrap()
        );
        assert_eq!(
            *dynamic.top_k_by_support(2).unwrap(),
            *svc.top_k_by_support(2).unwrap()
        );
        assert_eq!(
            *dynamic.duration_histogram(4, 3).unwrap(),
            *svc.duration_histogram(4, 3).unwrap()
        );
        let mut streamed = Vec::new();
        let total = dynamic
            .visit_patient(2, &mut |chunk| {
                streamed.extend_from_slice(chunk);
                Ok(())
            })
            .unwrap();
        let expect: Vec<SeqRecord> = data.iter().copied().filter(|r| r.pid == 2).collect();
        assert_eq!(streamed, expect);
        assert_eq!(total, expect.len() as u64);
        let info = dynamic.describe();
        assert_eq!(info.records, data.len() as u64);
        assert_eq!(info.sequences, 2);
        assert_eq!(info.patients, 5);
        assert_eq!(info.version, 2);
        assert_eq!(
            info.target.as_deref(),
            Some(crate::target::TargetSpec::for_codes([0, 1]).render().as_str()),
            "describe surfaces the manifest's target spec"
        );
    }
}
