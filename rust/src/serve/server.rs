//! The serve loop: thread-per-connection with admission control.
//!
//! A [`Server`] owns a `TcpListener`, a shared [`Registry`], and a
//! [`Semaphore`] of `max_conns` permits. The accept loop tries to take
//! a permit for every incoming connection; when none is free the
//! connection is **shed** — one `busy` frame, then closed — rather than
//! queued, so a saturated daemon degrades with bounded latency instead
//! of an unbounded backlog. Each admitted connection runs on its own
//! thread, releasing the permit on exit (including panics, via a drop
//! guard).
//!
//! Reads are polled: the handler waits for the first header byte with a
//! short [`ServeConfig::poll_interval`] timeout so it can notice idle
//! expiry and shutdown between requests, then switches to the full
//! [`ServeConfig::idle_timeout`] for the frame remainder — a frame is
//! never abandoned halfway, which would desynchronize the stream.
//!
//! Graceful shutdown ([`ServerHandle::shutdown`] or a `shutdown`
//! request): set the flag, self-connect to wake the blocking
//! `accept()`, stop admitting, then drain by acquiring every permit —
//! which blocks until all in-flight handlers have finished their
//! current request and exited.

use crate::obs::{self, names, TraceId, Tracer};
use crate::par::Semaphore;
use crate::query::{QueryError, QuerySurface};
use crate::serve::protocol::{
    read_frame_resume, write_frame, ErrorCode, FrameError, Request, Response,
};
use crate::serve::registry::Registry;
use crate::serve::ServeError;
use std::io::Read;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// `tspm_serve_request_duration_us` histogram layout: 100µs → 10s.
const REQUEST_BUCKETS_US: &[u64] =
    &[100, 1_000, 10_000, 100_000, 1_000_000, 10_000_000];

/// Tunables for one serve loop.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Admission limit: concurrent connections beyond this are shed
    /// with a `busy` frame.
    pub max_conns: usize,
    /// A connection idle longer than this is closed.
    pub idle_timeout: Duration,
    /// Granularity of the idle/shutdown poll between requests.
    pub poll_interval: Duration,
    /// Frame-size guard for reads and writes.
    pub max_frame_bytes: usize,
    /// Tracer for server-side spans. `None` builds one from the
    /// environment at bind time (`TSPM_TRACE`, `TSPM_SLOW_QUERY_MS`).
    pub tracer: Option<Tracer>,
    /// Slow-query threshold applied to the tracer at bind time; `None`
    /// keeps whatever the tracer (or environment) already set.
    pub slow_query_threshold: Option<Duration>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_conns: 64,
            idle_timeout: Duration::from_secs(30),
            poll_interval: Duration::from_millis(100),
            max_frame_bytes: crate::serve::protocol::DEFAULT_MAX_FRAME_BYTES,
            tracer: None,
            slow_query_threshold: None,
        }
    }
}

/// Counters + shutdown flag shared by the accept loop, the handlers,
/// and every [`ServerHandle`].
struct ServerState {
    shutdown: AtomicBool,
    conns: Semaphore,
    addr: SocketAddr,
    served: AtomicU64,
    shed: AtomicU64,
    requests: AtomicU64,
    tracer: Tracer,
}

impl ServerState {
    fn begin_shutdown(&self) {
        self.shutdown.store(true, Ordering::Release);
        // Wake the blocking accept() so the loop observes the flag.
        let _ = TcpStream::connect(self.addr);
    }
}

/// What one serve loop did, returned by [`Server::run`] after drain.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServeSummary {
    /// Connections admitted.
    pub served: u64,
    /// Connections shed with `busy`.
    pub shed: u64,
    /// Requests answered (including error answers).
    pub requests: u64,
}

/// Remote control for a running server: trigger shutdown from another
/// thread, inspect the bound address.
#[derive(Clone)]
pub struct ServerHandle {
    state: Arc<ServerState>,
}

impl ServerHandle {
    pub fn local_addr(&self) -> SocketAddr {
        self.state.addr
    }

    /// Stop admitting, wake the accept loop, let in-flight requests
    /// finish. Idempotent.
    pub fn shutdown(&self) {
        self.state.begin_shutdown();
    }

    pub fn is_shutting_down(&self) -> bool {
        self.state.shutdown.load(Ordering::Acquire)
    }
}

/// A bound (not yet running) serve loop.
pub struct Server {
    listener: TcpListener,
    registry: Arc<Registry>,
    cfg: ServeConfig,
    state: Arc<ServerState>,
}

impl Server {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral test port).
    pub fn bind(addr: &str, registry: Arc<Registry>, cfg: ServeConfig) -> Result<Server, ServeError> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let tracer = cfg.tracer.clone().unwrap_or_else(Tracer::from_env);
        if let Some(t) = cfg.slow_query_threshold {
            tracer.set_slow_threshold_us(t.as_micros() as u64);
        }
        let state = Arc::new(ServerState {
            shutdown: AtomicBool::new(false),
            conns: Semaphore::new(cfg.max_conns),
            addr: local,
            served: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            tracer,
        });
        Ok(Server { listener, registry, cfg, state })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.state.addr
    }

    pub fn handle(&self) -> ServerHandle {
        ServerHandle { state: Arc::clone(&self.state) }
    }

    /// Run the accept loop until shutdown, then drain and report.
    pub fn run(self) -> Result<ServeSummary, ServeError> {
        for incoming in self.listener.incoming() {
            if self.state.shutdown.load(Ordering::Acquire) {
                break; // drop the (possibly wake-up) connection unanswered
            }
            let mut stream = match incoming {
                Ok(s) => s,
                Err(_) => continue, // transient accept failure
            };
            if !self.state.conns.try_acquire() {
                self.state.shed.fetch_add(1, Ordering::Relaxed);
                obs::metrics::global().counter(names::SERVE_SHED).inc();
                // Best-effort: tell the peer it was shed, then close.
                let _ = write_frame(&mut stream, &Response::Busy.encode(), self.cfg.max_frame_bytes);
                continue;
            }
            self.state.served.fetch_add(1, Ordering::Relaxed);
            obs::metrics::global().counter(names::SERVE_CONNS).inc();
            let admitted_us = self.state.tracer.now_micros();
            let registry = Arc::clone(&self.registry);
            let state = Arc::clone(&self.state);
            let cfg = self.cfg.clone();
            std::thread::spawn(move || {
                let _permit = PermitGuard { state: &state };
                handle_conn(stream, &registry, &cfg, &state, admitted_us);
            });
        }
        // Drain: every permit reacquired == every handler exited.
        for _ in 0..self.cfg.max_conns {
            self.state.conns.acquire();
        }
        Ok(ServeSummary {
            served: self.state.served.load(Ordering::Relaxed),
            shed: self.state.shed.load(Ordering::Relaxed),
            requests: self.state.requests.load(Ordering::Relaxed),
        })
    }

    /// Run on a background thread — the in-process form the tests and
    /// the loopback benchmark use.
    pub fn spawn(self) -> (ServerHandle, std::thread::JoinHandle<Result<ServeSummary, ServeError>>) {
        let handle = self.handle();
        let join = std::thread::spawn(move || self.run());
        (handle, join)
    }
}

/// Releases one admission permit when the handler thread exits, even on
/// panic.
struct PermitGuard<'a> {
    state: &'a ServerState,
}

impl Drop for PermitGuard<'_> {
    fn drop(&mut self) {
        self.state.conns.release();
    }
}

fn handle_conn(
    mut stream: TcpStream,
    registry: &Registry,
    cfg: &ServeConfig,
    state: &ServerState,
    admitted_us: u64,
) {
    let _ = stream.set_nodelay(true);
    let mut idle = Duration::ZERO;
    // Admission wait is reported once, attached to the connection's
    // first request (whose trace id does not exist until then).
    let mut admission = Some(admitted_us);
    loop {
        if state.shutdown.load(Ordering::Acquire) {
            break; // in-flight request already finished; admit no more
        }
        // Phase 1: wait for the first header byte with a short timeout
        // so idle expiry and shutdown are noticed between requests.
        let _ = stream.set_read_timeout(Some(cfg.poll_interval));
        let mut first = [0u8; 1];
        match stream.read(&mut first) {
            Ok(0) => break, // peer closed
            Ok(_) => {}
            Err(e) if matches!(
                e.kind(),
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
            ) =>
            {
                idle += cfg.poll_interval;
                if idle >= cfg.idle_timeout {
                    break;
                }
                continue;
            }
            Err(_) => break,
        }
        idle = Duration::ZERO;
        // Phase 2: a frame has started — commit to reading it whole
        // under the full timeout (abandoning a frame midway would
        // desynchronize the stream).
        let _ = stream.set_read_timeout(Some(cfg.idle_timeout));
        let payload = match read_frame_resume(first[0], &mut stream, cfg.max_frame_bytes) {
            Ok(p) => p,
            Err(e) => {
                let code = match &e {
                    FrameError::BadMagic(_) => Some(ErrorCode::BadFrame),
                    FrameError::UnsupportedVersion(_) => Some(ErrorCode::UnsupportedVersion),
                    FrameError::TooLarge { .. } => Some(ErrorCode::FrameTooLarge),
                    FrameError::Io(_) => None,
                };
                if let Some(code) = code {
                    let resp = Response::Error { code, message: e.to_string() };
                    let _ = write_frame(&mut stream, &resp.encode(), cfg.max_frame_bytes);
                }
                break; // framing errors close the connection
            }
        };
        match answer(&mut stream, &payload, registry, cfg, state, admission.take()) {
            Ok(true) => {}
            Ok(false) | Err(_) => break,
        }
    }
}

/// Decode one request, open its `serve.request` root span (adopting
/// the client's trace id when the frame carried one), and dispatch;
/// `Ok(true)` keeps the connection.
fn answer(
    stream: &mut TcpStream,
    payload: &[u8],
    registry: &Registry,
    cfg: &ServeConfig,
    state: &ServerState,
    admission_us: Option<u64>,
) -> Result<bool, FrameError> {
    let (req, wire_trace) = match Request::decode_traced(payload) {
        Ok(r) => r,
        Err(m) => {
            // A malformed payload inside a well-formed frame: the
            // stream is still in sync, so answer and keep going.
            send(stream, &Response::Error { code: ErrorCode::BadRequest, message: m }, cfg)?;
            return Ok(true);
        }
    };
    state.requests.fetch_add(1, Ordering::Relaxed);
    let reg = obs::metrics::global();
    reg.counter(names::SERVE_REQUESTS).inc();
    let mut span = match wire_trace.as_deref().and_then(TraceId::from_hex) {
        Some(tid) => state.tracer.span_in(tid, "serve.request"),
        None => state.tracer.span("serve.request"),
    };
    span.attr("kind", req.kind());
    span.mark_slow_eligible();
    if let Some(start) = admission_us {
        // Accept → first request byte, measured before the trace id
        // existed and attached retroactively as a sibling span.
        let now = state.tracer.now_micros();
        state.tracer.emit_manual(
            span.trace_id(),
            Some(span.id()),
            "serve.admission",
            start,
            now.saturating_sub(start),
        );
    }
    // While the request span is on the thread's context stack, spans
    // opened deeper in the stack (routing, cache, block scans) become
    // its children — that is the whole propagation chain.
    let keep = {
        let _ctx = obs::trace::push_current(&span);
        dispatch(stream, req, registry, cfg, state)
    };
    let elapsed = span.finish();
    reg.histogram(names::SERVE_REQUEST_DURATION_US, REQUEST_BUCKETS_US)
        .observe(elapsed.as_micros() as u64);
    keep
}

/// Answer one decoded request; `Ok(true)` keeps the connection.
fn dispatch(
    stream: &mut TcpStream,
    req: Request,
    registry: &Registry,
    cfg: &ServeConfig,
    state: &ServerState,
) -> Result<bool, FrameError> {
    match req {
        Request::Ping => send(stream, &Response::Pong, cfg)?,
        Request::List => send(stream, &Response::Artifacts(registry.describe()), cfg)?,
        Request::Stats { artifact } => {
            let resp = match registry.route_entry(artifact.as_deref()) {
                Ok((id, svc)) => Response::Stats { artifact: id, stats: svc.stats() },
                Err(e) => error_response(&e),
            };
            send(stream, &resp, cfg)?;
        }
        Request::BySequence { artifact, seq, limit } => {
            let resp = traced_route(registry, artifact.as_deref())
                .and_then(|svc| svc.by_sequence(seq).map_err(ServeError::from))
                .map(|recs| {
                    let total = recs.len() as u64;
                    let records = match limit {
                        Some(l) if recs.len() > l => recs[..l].to_vec(),
                        _ => recs.as_ref().clone(),
                    };
                    Response::Records { records, total }
                })
                .unwrap_or_else(|e| error_response(&e));
            send(stream, &resp, cfg)?;
        }
        Request::ByPatient { artifact, pid } => {
            stream_by_patient(stream, registry, artifact.as_deref(), pid, cfg)?;
        }
        Request::PatientsWith { artifact, seq, dur_min, dur_max, limit } => {
            let resp = traced_route(registry, artifact.as_deref())
                .and_then(|svc| {
                    svc.patients_with(seq, dur_min, dur_max).map_err(ServeError::from)
                })
                .map(|pids| {
                    let total = pids.len() as u64;
                    let patients = match limit {
                        Some(l) if pids.len() > l => pids[..l].to_vec(),
                        _ => pids.as_ref().clone(),
                    };
                    Response::Patients { patients, total }
                })
                .unwrap_or_else(|e| error_response(&e));
            send(stream, &resp, cfg)?;
        }
        Request::TopK { artifact, k } => {
            let resp = traced_route(registry, artifact.as_deref())
                .and_then(|svc| svc.top_k_by_support(k).map_err(ServeError::from))
                .map(|rows| Response::TopK(rows.as_ref().clone()))
                .unwrap_or_else(|e| error_response(&e));
            send(stream, &resp, cfg)?;
        }
        Request::Histogram { artifact, seq, buckets } => {
            let resp = traced_route(registry, artifact.as_deref())
                .and_then(|svc| svc.duration_histogram(seq, buckets).map_err(ServeError::from))
                .map(|h| Response::Histogram(h.as_ref().clone()))
                .unwrap_or_else(|e| error_response(&e));
            send(stream, &resp, cfg)?;
        }
        Request::Register { id, dir } => {
            // A directory holding a segment-set manifest registers as a
            // merged view; anything else as a single artifact. This is
            // how a daemon hot-swaps a segment set mid-workload:
            // retire the old id, register the set's directory again.
            let path = std::path::Path::new(&dir);
            let result = if path.join("segments.json").is_file() {
                registry.open_and_register_set(&id, path)
            } else {
                registry.open_and_register(&id, path)
            };
            let resp = match result {
                Ok(()) => Response::Ok,
                Err(e) => error_response(&e),
            };
            send(stream, &resp, cfg)?;
        }
        Request::Retire { id } => {
            let resp = if registry.retire(&id) {
                Response::Ok
            } else {
                error_response(&ServeError::NotFound(format!("no artifact {id:?} to retire")))
            };
            send(stream, &resp, cfg)?;
        }
        Request::Shutdown => {
            send(stream, &Response::Ok, cfg)?;
            state.begin_shutdown();
            return Ok(false);
        }
        Request::Metrics => {
            // Answered from the process-wide registry without routing —
            // scraping works even when no artifact is registered.
            let text = obs::metrics::global().render_prometheus();
            send(stream, &Response::Metrics { text }, cfg)?;
        }
    }
    Ok(true)
}

/// [`Registry::route`] under a `serve.route` child span, when a
/// request span is on the thread's context stack.
fn traced_route(
    registry: &Registry,
    artifact: Option<&str>,
) -> Result<Arc<dyn QuerySurface>, ServeError> {
    let span = obs::trace::current_span("serve.route");
    let result = registry.route(artifact);
    if let Some(mut s) = span {
        if let Some(a) = artifact {
            s.attr("artifact", a);
        }
        s.attr("ok", result.is_ok());
    }
    result
}

/// Stream a `by_patient` answer block-at-a-time: the handler's live
/// memory stays bounded by the artifact's block size however many
/// records the patient has.
fn stream_by_patient(
    stream: &mut TcpStream,
    registry: &Registry,
    artifact: Option<&str>,
    pid: u32,
    cfg: &ServeConfig,
) -> Result<(), FrameError> {
    let svc = match traced_route(registry, artifact) {
        Ok(s) => s,
        Err(e) => return send(stream, &error_response(&e), cfg),
    };
    // Socket failures are fatal for the connection; query failures are
    // reported in-band as a stream-terminating error frame. The
    // object-safe visit_patient callback can only carry a QueryError,
    // so a frame error is stashed here, the scan aborted with a
    // synthetic io error, and the stash re-raised on the way out.
    let mut frame_err: Option<FrameError> = None;
    let result = svc.visit_patient(pid, &mut |chunk| {
        let part =
            Response::RecordsPart { records: chunk.to_vec(), last: false, total: None };
        match write_frame(stream, &part.encode(), cfg.max_frame_bytes) {
            Ok(()) => Ok(()),
            Err(e) => {
                frame_err = Some(e);
                Err(QueryError::Io(std::io::Error::new(
                    std::io::ErrorKind::Other,
                    "stream aborted by a connection failure",
                )))
            }
        }
    });
    if let Some(e) = frame_err {
        return Err(e);
    }
    match result {
        Ok(total) => send(
            stream,
            &Response::RecordsPart { records: Vec::new(), last: true, total: Some(total) },
            cfg,
        ),
        // In-band terminator: the client treats an error frame in place
        // of a records_part as the end of the (failed) stream.
        Err(e) => send(stream, &error_response(&ServeError::Query(e)), cfg),
    }
}

/// Write a response, substituting a typed `frame_too_large` error when
/// the encoded payload would exceed the guard.
fn send(stream: &mut TcpStream, resp: &Response, cfg: &ServeConfig) -> Result<(), FrameError> {
    let payload = resp.encode();
    match write_frame(stream, &payload, cfg.max_frame_bytes) {
        Err(FrameError::TooLarge { len, max }) => {
            let err = Response::Error {
                code: ErrorCode::FrameTooLarge,
                message: format!(
                    "response of {len} bytes exceeds the {max} byte frame guard; \
                     narrow the query or pass a \"limit\""
                ),
            };
            write_frame(stream, &err.encode(), cfg.max_frame_bytes)
        }
        other => other,
    }
}

fn error_response(e: &ServeError) -> Response {
    Response::Error { code: e.code(), message: e.to_string() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mining::SeqRecord;
    use crate::query::index::{build, IndexConfig};
    use crate::seqstore::{self, SeqFileSet};
    use crate::serve::client::Client;
    use std::path::{Path, PathBuf};

    fn tmpdir(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("tspm_server_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn fixture_index(dir: &Path) -> PathBuf {
        let mut records = Vec::new();
        for pid in 0..5u32 {
            for s in [3u64, 17, 90] {
                records.push(SeqRecord { seq: s, pid, duration: (s as u32) * 3 + pid });
            }
        }
        records.sort_unstable_by_key(|r| (r.seq, r.pid, r.duration));
        let path = dir.join("in.tspm");
        seqstore::write_file(&path, &records).unwrap();
        let input = SeqFileSet {
            files: vec![path],
            total_records: records.len() as u64,
            num_patients: 5,
            num_phenx: 4,
        };
        let out = dir.join("index");
        build(&input, &out, &IndexConfig { block_records: 4, ..Default::default() }, None)
            .unwrap();
        out
    }

    fn fast_cfg(max_conns: usize) -> ServeConfig {
        ServeConfig {
            max_conns,
            idle_timeout: Duration::from_secs(5),
            poll_interval: Duration::from_millis(5),
            ..ServeConfig::default()
        }
    }

    #[test]
    fn ping_list_query_shutdown_round_trip() {
        let dir = tmpdir("smoke");
        let idx = fixture_index(&dir);
        let registry = Arc::new(Registry::new(1 << 16));
        registry.open_and_register("idx", &idx).unwrap();
        let server = Server::bind("127.0.0.1:0", registry, fast_cfg(4)).unwrap();
        let addr = server.local_addr();
        let (_handle, join) = server.spawn();

        let mut c = Client::connect(&addr.to_string()).unwrap();
        c.ping().unwrap();
        let arts = c.list().unwrap();
        assert_eq!(arts.len(), 1);
        assert_eq!(arts[0].id, "idx");
        assert_eq!(arts[0].records, 15);
        // Default routing works with a single artifact.
        let (recs, total) = c.by_sequence(None, 17, None).unwrap();
        assert_eq!(total, 5);
        assert_eq!(recs.len(), 5);
        assert!(recs.iter().all(|r| r.seq == 17));
        // limit truncates the frame but reports the full total.
        let (recs, total) = c.by_sequence(Some("idx"), 17, Some(2)).unwrap();
        assert_eq!((recs.len(), total), (2, 5));
        // Streaming by_patient equals the flat answer.
        let streamed = c.by_patient(None, 2).unwrap();
        assert_eq!(streamed.len(), 3);
        assert!(streamed.iter().all(|r| r.pid == 2));
        let rows = c.top_k(None, 2).unwrap();
        assert_eq!(rows.len(), 2);
        let hist = c.histogram(None, 3, 4).unwrap();
        assert_eq!(hist.total, 5);
        let (pids, ptotal) = c.patients_with(None, 90, 0, u32::MAX, None).unwrap();
        assert_eq!((pids.len() as u64, ptotal), (5, 5));
        let (name, stats) = c.stats(None).unwrap();
        assert_eq!(name, "idx");
        assert!(stats.hits + stats.misses > 0);

        c.shutdown().unwrap();
        let summary = join.join().unwrap().unwrap();
        assert!(summary.served >= 1);
        assert!(summary.requests >= 10);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn serves_a_segment_set_like_one_artifact() {
        use crate::ingest::SegmentSet;
        let dir = tmpdir("segset");
        let set_dir = dir.join("set");
        let mut set = SegmentSet::open_or_init(&set_dir).unwrap();
        for (i, pids) in [&[0u32, 1][..], &[2, 3, 4][..]].iter().enumerate() {
            let mut records = Vec::new();
            for &pid in pids.iter() {
                for s in [3u64, 17, 90] {
                    records.push(SeqRecord { seq: s, pid, duration: (s as u32) * 3 + pid });
                }
            }
            records.sort_unstable_by_key(|r| (r.seq, r.pid, r.duration));
            let path = dir.join(format!("in_{i}.tspm"));
            seqstore::write_file(&path, &records).unwrap();
            let input = SeqFileSet {
                files: vec![path],
                total_records: records.len() as u64,
                num_patients: 5,
                num_phenx: 4,
            };
            set.add_segment(&input, &IndexConfig { block_records: 4, ..Default::default() }, None)
                .unwrap();
        }
        let registry = Arc::new(Registry::new(1 << 16));
        registry.open_and_register_set("set", &set_dir).unwrap();
        let server = Server::bind("127.0.0.1:0", registry, fast_cfg(4)).unwrap();
        let addr = server.local_addr();
        let (handle, join) = server.spawn();

        // Same wire answers the single-artifact smoke test gets.
        let mut c = Client::connect(&addr.to_string()).unwrap();
        let arts = c.list().unwrap();
        assert_eq!((arts.len(), arts[0].records), (1, 15));
        let (recs, total) = c.by_sequence(None, 17, None).unwrap();
        assert_eq!((recs.len() as u64, total), (5, 5));
        assert!(recs.windows(2).all(|w| w[0].pid <= w[1].pid), "merged (pid, dur) order");
        let streamed = c.by_patient(None, 2).unwrap();
        assert_eq!(streamed.len(), 3);
        assert!(streamed.iter().all(|r| r.pid == 2));
        let hist = c.histogram(None, 3, 4).unwrap();
        assert_eq!(hist.total, 5);
        handle.shutdown();
        join.join().unwrap().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unknown_artifact_is_a_typed_not_found() {
        let dir = tmpdir("notfound");
        let idx = fixture_index(&dir);
        let registry = Arc::new(Registry::new(1 << 16));
        registry.open_and_register("idx", &idx).unwrap();
        let server = Server::bind("127.0.0.1:0", registry, fast_cfg(2)).unwrap();
        let addr = server.local_addr();
        let (handle, join) = server.spawn();

        let mut c = Client::connect(&addr.to_string()).unwrap();
        let err = c.by_sequence(Some("ghost"), 17, None).unwrap_err();
        match err {
            ServeError::Remote { code, message } => {
                assert_eq!(code, ErrorCode::NotFound);
                assert!(message.contains("ghost"), "{message}");
            }
            other => panic!("expected typed remote NotFound, got {other}"),
        }
        // The connection survived the error answer.
        c.ping().unwrap();
        handle.shutdown();
        join.join().unwrap().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn malformed_payload_keeps_the_connection_garbled_frame_closes_it() {
        let dir = tmpdir("badreq");
        let idx = fixture_index(&dir);
        let registry = Arc::new(Registry::new(1 << 16));
        registry.open_and_register("idx", &idx).unwrap();
        let server = Server::bind("127.0.0.1:0", registry, fast_cfg(2)).unwrap();
        let addr = server.local_addr();
        let (handle, join) = server.spawn();

        // A well-formed frame with a nonsense payload answers
        // bad_request and keeps the stream usable.
        let mut raw = TcpStream::connect(addr).unwrap();
        write_frame(&mut raw, b"{\"type\":\"warp\"}", 1024).unwrap();
        let payload =
            crate::serve::protocol::read_frame(&mut raw, DEFAULT_TEST_FRAME).unwrap();
        match Response::decode(&payload).unwrap() {
            Response::Error { code, .. } => assert_eq!(code, ErrorCode::BadRequest),
            other => panic!("expected bad_request, got {other:?}"),
        }
        write_frame(&mut raw, &Request::Ping.encode(), 1024).unwrap();
        let payload =
            crate::serve::protocol::read_frame(&mut raw, DEFAULT_TEST_FRAME).unwrap();
        assert_eq!(Response::decode(&payload).unwrap(), Response::Pong);

        // Garbage bytes (bad magic) get a typed answer, then the server
        // closes the connection.
        use std::io::Write;
        raw.write_all(b"XXXXYYYYZZZZ").unwrap();
        raw.flush().unwrap();
        let payload =
            crate::serve::protocol::read_frame(&mut raw, DEFAULT_TEST_FRAME).unwrap();
        match Response::decode(&payload).unwrap() {
            Response::Error { code, .. } => assert_eq!(code, ErrorCode::BadFrame),
            other => panic!("expected bad_frame, got {other:?}"),
        }
        let mut rest = Vec::new();
        raw.read_to_end(&mut rest).unwrap();
        assert!(rest.is_empty(), "server closed after the framing error");

        handle.shutdown();
        join.join().unwrap().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    const DEFAULT_TEST_FRAME: usize = 1 << 20;
}
