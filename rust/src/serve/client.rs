//! Blocking client for the serve protocol, plus the loopback workload
//! harness shared by `tspm client --workload`, the e2e suite, and
//! `examples/perf_probe.rs`.

use crate::json::Json;
use crate::mining::SeqRecord;
use crate::obs::TraceId;
use crate::query::{Histogram, QueryStats, SeqSupport};
use crate::rng::Rng;
use crate::serve::protocol::{
    read_frame, write_frame, ArtifactInfo, Request, Response, DEFAULT_MAX_FRAME_BYTES,
};
use crate::serve::ServeError;
use std::net::TcpStream;
use std::time::Instant;

/// One connection to a serve daemon. Methods are request/response;
/// reuse the client across calls to amortize the TCP handshake.
pub struct Client {
    stream: TcpStream,
    max_frame: usize,
    trace_id: Option<String>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client, ServeError> {
        Client::connect_with(addr, DEFAULT_MAX_FRAME_BYTES)
    }

    pub fn connect_with(addr: &str, max_frame: usize) -> Result<Client, ServeError> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        Ok(Client { stream, max_frame, trace_id: None })
    }

    /// Stamp every subsequent request with `id` (the `"trace_id"`
    /// envelope key): the server adopts it as the trace of its
    /// server-side spans, so one grep over the daemon's trace output
    /// finds everything this client caused.
    pub fn set_trace_id(&mut self, id: TraceId) {
        self.trace_id = Some(id.to_hex());
    }

    fn encode_request(&self, req: &Request) -> Vec<u8> {
        req.encode_traced(self.trace_id.as_deref())
    }

    /// Send one request and read one non-error response. `busy` and
    /// `error` frames come back as typed [`ServeError`]s.
    fn call(&mut self, req: &Request) -> Result<Response, ServeError> {
        let payload = self.encode_request(req);
        if let Err(e) = write_frame(&mut self.stream, &payload, self.max_frame) {
            // The write can fail because admission control already shed
            // us: the server wrote one `busy` frame and closed. Prefer
            // that typed answer over the raw broken-pipe error.
            if let Ok(Response::Busy) = self.read_raw() {
                return Err(ServeError::Busy);
            }
            return Err(e.into());
        }
        self.read_response()
    }

    fn read_raw(&mut self) -> Result<Response, ServeError> {
        let payload = read_frame(&mut self.stream, self.max_frame)?;
        Response::decode(&payload).map_err(ServeError::Protocol)
    }

    fn read_response(&mut self) -> Result<Response, ServeError> {
        match self.read_raw()? {
            Response::Busy => Err(ServeError::Busy),
            Response::Error { code, message } => Err(ServeError::Remote { code, message }),
            other => Ok(other),
        }
    }

    pub fn ping(&mut self) -> Result<(), ServeError> {
        match self.call(&Request::Ping)? {
            Response::Pong => Ok(()),
            other => Err(unexpected("pong", &other)),
        }
    }

    pub fn list(&mut self) -> Result<Vec<ArtifactInfo>, ServeError> {
        match self.call(&Request::List)? {
            Response::Artifacts(a) => Ok(a),
            other => Err(unexpected("artifacts", &other)),
        }
    }

    pub fn stats(&mut self, artifact: Option<&str>) -> Result<(String, QueryStats), ServeError> {
        let req = Request::Stats { artifact: artifact.map(str::to_string) };
        match self.call(&req)? {
            Response::Stats { artifact, stats } => Ok((artifact, stats)),
            other => Err(unexpected("stats", &other)),
        }
    }

    /// Returns `(records, total)`; `records` is truncated to `limit`
    /// while `total` counts the whole answer.
    pub fn by_sequence(
        &mut self,
        artifact: Option<&str>,
        seq: u64,
        limit: Option<usize>,
    ) -> Result<(Vec<SeqRecord>, u64), ServeError> {
        let req =
            Request::BySequence { artifact: artifact.map(str::to_string), seq, limit };
        match self.call(&req)? {
            Response::Records { records, total } => Ok((records, total)),
            other => Err(unexpected("records", &other)),
        }
    }

    /// Consume a streamed `by_patient` answer chunk-at-a-time without
    /// ever holding the whole patient; returns the total record count.
    pub fn by_patient_visit(
        &mut self,
        artifact: Option<&str>,
        pid: u32,
        mut f: impl FnMut(&[SeqRecord]),
    ) -> Result<u64, ServeError> {
        let req = Request::ByPatient { artifact: artifact.map(str::to_string), pid };
        let payload = self.encode_request(&req);
        write_frame(&mut self.stream, &payload, self.max_frame)
            .map_err(ServeError::from)?;
        loop {
            match self.read_response()? {
                Response::RecordsPart { records, last, total } => {
                    if !records.is_empty() {
                        f(&records);
                    }
                    if last {
                        return Ok(total.unwrap_or(0));
                    }
                }
                other => return Err(unexpected("records_part", &other)),
            }
        }
    }

    /// The buffered convenience form of [`Client::by_patient_visit`].
    pub fn by_patient(
        &mut self,
        artifact: Option<&str>,
        pid: u32,
    ) -> Result<Vec<SeqRecord>, ServeError> {
        let mut out = Vec::new();
        self.by_patient_visit(artifact, pid, |chunk| out.extend_from_slice(chunk))?;
        Ok(out)
    }

    pub fn patients_with(
        &mut self,
        artifact: Option<&str>,
        seq: u64,
        dur_min: u32,
        dur_max: u32,
        limit: Option<usize>,
    ) -> Result<(Vec<u32>, u64), ServeError> {
        let req = Request::PatientsWith {
            artifact: artifact.map(str::to_string),
            seq,
            dur_min,
            dur_max,
            limit,
        };
        match self.call(&req)? {
            Response::Patients { patients, total } => Ok((patients, total)),
            other => Err(unexpected("patients", &other)),
        }
    }

    pub fn top_k(
        &mut self,
        artifact: Option<&str>,
        k: usize,
    ) -> Result<Vec<SeqSupport>, ServeError> {
        let req = Request::TopK { artifact: artifact.map(str::to_string), k };
        match self.call(&req)? {
            Response::TopK(rows) => Ok(rows),
            other => Err(unexpected("top_k", &other)),
        }
    }

    pub fn histogram(
        &mut self,
        artifact: Option<&str>,
        seq: u64,
        buckets: usize,
    ) -> Result<Histogram, ServeError> {
        let req = Request::Histogram { artifact: artifact.map(str::to_string), seq, buckets };
        match self.call(&req)? {
            Response::Histogram(h) => Ok(h),
            other => Err(unexpected("histogram", &other)),
        }
    }

    pub fn register(&mut self, id: &str, dir: &str) -> Result<(), ServeError> {
        let req = Request::Register { id: id.to_string(), dir: dir.to_string() };
        match self.call(&req)? {
            Response::Ok => Ok(()),
            other => Err(unexpected("ok", &other)),
        }
    }

    pub fn retire(&mut self, id: &str) -> Result<(), ServeError> {
        match self.call(&Request::Retire { id: id.to_string() })? {
            Response::Ok => Ok(()),
            other => Err(unexpected("ok", &other)),
        }
    }

    /// The daemon's metrics registry in Prometheus text exposition
    /// format — the same bytes its `--metrics-addr` endpoint serves.
    pub fn metrics(&mut self) -> Result<String, ServeError> {
        match self.call(&Request::Metrics)? {
            Response::Metrics { text } => Ok(text),
            other => Err(unexpected("metrics", &other)),
        }
    }

    /// Ask the daemon to drain and exit.
    pub fn shutdown(&mut self) -> Result<(), ServeError> {
        match self.call(&Request::Shutdown)? {
            Response::Ok => Ok(()),
            other => Err(unexpected("ok", &other)),
        }
    }
}

fn unexpected(wanted: &str, got: &Response) -> ServeError {
    ServeError::Protocol(format!("expected a {wanted} response, got {got:?}"))
}

// ---------------------------------------------------------------------------
// mixed workload harness
// ---------------------------------------------------------------------------

/// Shape of a loopback benchmark run.
#[derive(Clone, Debug)]
pub struct WorkloadConfig {
    /// Total requests across all client threads.
    pub requests: usize,
    /// Concurrent client connections.
    pub concurrency: usize,
    /// Workload mix seed — same seed, same request stream.
    pub seed: u64,
    /// Artifact to target; `None` uses default routing.
    pub artifact: Option<String>,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig { requests: 2000, concurrency: 4, seed: 42, artifact: None }
    }
}

/// Per-kind latency summary of one workload run.
#[derive(Clone, Debug)]
pub struct KindStats {
    pub kind: &'static str,
    pub count: u64,
    pub p50_us: u64,
    pub p99_us: u64,
}

/// Outcome of [`run_mixed_workload`].
#[derive(Clone, Debug)]
pub struct WorkloadReport {
    pub total_requests: u64,
    pub errors: u64,
    pub busy: u64,
    pub elapsed_secs: f64,
    pub qps: f64,
    pub kinds: Vec<KindStats>,
}

impl WorkloadReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("total_requests", Json::from(self.total_requests)),
            ("errors", Json::from(self.errors)),
            ("busy", Json::from(self.busy)),
            ("elapsed_secs", Json::from(self.elapsed_secs)),
            ("qps", Json::from(self.qps)),
            (
                "kinds",
                Json::Obj(
                    self.kinds
                        .iter()
                        .map(|k| {
                            (
                                k.kind.to_string(),
                                Json::obj(vec![
                                    ("count", Json::from(k.count)),
                                    ("p50_us", Json::from(k.p50_us)),
                                    ("p99_us", Json::from(k.p99_us)),
                                ]),
                            )
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

const KINDS: [&str; 5] = ["by_sequence", "by_patient", "patients_with", "top_k", "histogram"];

/// Drive a deterministic mixed query workload against a running daemon
/// (40% by_sequence, 25% by_patient, 15% patients_with, 10% top_k, 10%
/// histogram) from `concurrency` persistent connections, and summarize
/// sustained QPS plus per-kind p50/p99 latency.
///
/// Self-priming: a scout connection asks `top_k` for the hot sequences
/// and samples one sequence's records for patient ids, so the workload
/// needs no out-of-band knowledge of the artifact.
pub fn run_mixed_workload(
    addr: &str,
    cfg: &WorkloadConfig,
) -> Result<WorkloadReport, ServeError> {
    let artifact = cfg.artifact.as_deref();
    // Prime: discover hot sequences and real patient ids.
    let mut scout = Client::connect(addr)?;
    let rows = scout.top_k(artifact, 32)?;
    let seqs: Vec<u64> = if rows.is_empty() { vec![0] } else { rows.iter().map(|r| r.seq).collect() };
    let (sample, _) = scout.by_sequence(artifact, seqs[0], Some(256))?;
    let pids: Vec<u32> =
        if sample.is_empty() { vec![0] } else { sample.iter().map(|r| r.pid).collect() };
    drop(scout);

    let threads = cfg.concurrency.max(1);
    let per_thread = cfg.requests.div_ceil(threads);
    let started = Instant::now();
    // (kind index, micros) samples per thread, merged after the join.
    let mut merged: Vec<Vec<(usize, u64)>> = Vec::new();
    let mut errors = 0u64;
    let mut busy = 0u64;
    let results: Vec<(Vec<(usize, u64)>, u64, u64)> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for t in 0..threads {
            let seqs = &seqs;
            let pids = &pids;
            handles.push(scope.spawn(move || {
                let mut rng = Rng::new(cfg.seed ^ (0x9e37_79b9_7f4a_7c15u64.wrapping_mul(t as u64 + 1)));
                let mut samples = Vec::with_capacity(per_thread);
                let (mut errs, mut busies) = (0u64, 0u64);
                let Ok(mut client) = Client::connect(addr) else {
                    return (samples, 1, 0);
                };
                for _ in 0..per_thread {
                    let roll = rng.gen_range(100);
                    let seq = seqs[rng.gen_range(seqs.len() as u64) as usize];
                    let pid = pids[rng.gen_range(pids.len() as u64) as usize];
                    let kind = match roll {
                        0..=39 => 0,
                        40..=64 => 1,
                        65..=79 => 2,
                        80..=89 => 3,
                        _ => 4,
                    };
                    let t0 = Instant::now();
                    let res: Result<(), ServeError> = match kind {
                        0 => client.by_sequence(artifact, seq, Some(1024)).map(|_| ()),
                        1 => client.by_patient_visit(artifact, pid, |_| {}).map(|_| ()),
                        2 => client
                            .patients_with(artifact, seq, 0, u32::MAX, Some(4096))
                            .map(|_| ()),
                        3 => client.top_k(artifact, 16).map(|_| ()),
                        _ => client.histogram(artifact, seq, 8).map(|_| ()),
                    };
                    match res {
                        Ok(()) => samples.push((kind, t0.elapsed().as_micros() as u64)),
                        Err(ServeError::Busy) => busies += 1,
                        Err(ServeError::Io(_)) => {
                            errs += 1;
                            break; // connection gone — stop this thread
                        }
                        Err(_) => errs += 1,
                    }
                }
                (samples, errs, busies)
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (samples, errs, busies) in results {
        errors += errs;
        busy += busies;
        merged.push(samples);
    }
    let elapsed = started.elapsed().as_secs_f64().max(1e-9);

    let mut per_kind: Vec<Vec<u64>> = vec![Vec::new(); KINDS.len()];
    for samples in &merged {
        for &(kind, us) in samples {
            per_kind[kind].push(us);
        }
    }
    let mut kinds = Vec::new();
    for (i, mut lat) in per_kind.into_iter().enumerate() {
        if let Some(stats) = kind_stats(KINDS[i], &mut lat) {
            kinds.push(stats);
        }
    }
    let total: u64 = kinds.iter().map(|k| k.count).sum();
    Ok(WorkloadReport {
        total_requests: total,
        errors,
        busy,
        elapsed_secs: elapsed,
        qps: total as f64 / elapsed,
        kinds,
    })
}

/// Summarize one request kind's latency samples (sorting in place);
/// `None` when the kind saw no successful request, so it is omitted
/// from the report rather than reported as a zero-latency row.
fn kind_stats(kind: &'static str, lat_us: &mut Vec<u64>) -> Option<KindStats> {
    if lat_us.is_empty() {
        return None;
    }
    lat_us.sort_unstable();
    Some(KindStats {
        kind,
        count: lat_us.len() as u64,
        p50_us: percentile(lat_us, 0.50),
        p99_us: percentile(lat_us, 0.99),
    })
}

/// Nearest-rank percentile of an ascending-sorted slice.
fn percentile(sorted: &[u64], q: f64) -> u64 {
    debug_assert!(!sorted.is_empty());
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_is_nearest_rank() {
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&v, 0.50), 50);
        assert_eq!(percentile(&v, 0.99), 99);
        assert_eq!(percentile(&[7], 0.99), 7);
    }

    #[test]
    fn empty_kind_is_omitted_not_zeroed() {
        assert!(kind_stats("by_sequence", &mut Vec::new()).is_none());
    }

    #[test]
    fn single_sample_is_both_percentiles() {
        let s = kind_stats("top_k", &mut vec![42]).unwrap();
        assert_eq!((s.count, s.p50_us, s.p99_us), (1, 42, 42));
    }

    #[test]
    fn identical_latencies_collapse_to_one_value() {
        let s = kind_stats("histogram", &mut vec![9; 1000]).unwrap();
        assert_eq!((s.count, s.p50_us, s.p99_us), (1000, 9, 9));
    }

    #[test]
    fn p50_never_exceeds_p99() {
        // Unsorted input with a heavy tail; kind_stats sorts in place.
        let mut lat: Vec<u64> = (0..500).map(|i| (i * 7919) % 10_000).collect();
        lat.push(1_000_000);
        let s = kind_stats("by_patient", &mut lat).unwrap();
        assert!(s.p50_us <= s.p99_us, "p50 {} > p99 {}", s.p50_us, s.p99_us);
        assert_eq!(s.count, 501);
    }

    #[test]
    fn workload_report_serializes_per_kind_stats() {
        let report = WorkloadReport {
            total_requests: 10,
            errors: 0,
            busy: 1,
            elapsed_secs: 2.0,
            qps: 5.0,
            kinds: vec![KindStats { kind: "by_sequence", count: 10, p50_us: 3, p99_us: 9 }],
        };
        let j = report.to_json();
        assert_eq!(j.get("qps").and_then(Json::as_f64), Some(5.0));
        let by_seq = j.get("kinds").and_then(|k| k.get("by_sequence")).unwrap();
        assert_eq!(by_seq.get("p99_us").and_then(Json::as_u64), Some(9));
    }
}
