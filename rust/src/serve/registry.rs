//! Multi-artifact registry with refcounted hot-swap.
//!
//! The daemon serves several query surfaces at once — single index
//! artifacts behind a [`QueryService`], whole segment sets behind a
//! [`MergedView`] — each keeping its own caches and stats. The registry
//! is a `RwLock<BTreeMap<id, Arc<dyn QuerySurface>>>`:
//!
//! * **route** takes the read lock just long enough to clone one `Arc`,
//!   then answers the query entirely outside the lock;
//! * **register / retire** take the write lock only to mutate the map.
//!
//! Retiring therefore never interrupts an in-flight reader: the reader
//! holds its own `Arc` clone, and the service (plus its mmap-free file
//! handles) is dropped only when the last clone goes away. A freshly
//! registered artifact is visible to the *next* `route` call — there is
//! no epoch machinery because the surfaces are immutable once opened.
//! That same contract is the segment-set hot-swap story: after `tspm
//! ingest` or `tspm compact` changes a set on disk, retire the old id
//! and register the set again — readers mid-query drain on the old
//! segments, new queries see the new ones.

use crate::ingest::MergedView;
use crate::query::{QueryError, QueryService, QuerySurface};
use crate::serve::protocol::ArtifactInfo;
use crate::serve::ServeError;
use crate::sync::{read_ignore_poison, write_ignore_poison, RwLock};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
// The surfaces stay behind std's Arc (not the shim's): `Arc<dyn
// QuerySurface>` needs unsized coercion, which loom's Arc does not
// model, and the refcount is not what the loom suite checks — the
// lock-guarded map is. The loom test below models the map with a
// payload type it can own.
use std::sync::Arc;

/// An artifact directory that could not be opened — keeps the path so
/// callers (the `tspm query` CLI, serve's `register` handler) can name
/// it in the user-facing message and exit-code mapping.
#[derive(Debug)]
pub struct ArtifactOpenError {
    pub dir: PathBuf,
    pub source: QueryError,
}

impl std::fmt::Display for ArtifactOpenError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "cannot open index artifact at {}: {}", self.dir.display(), self.source)
    }
}

impl std::error::Error for ArtifactOpenError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        Some(&self.source)
    }
}

/// Open one artifact directory as a [`QueryService`], tagging failures
/// with the offending path. `cache_bytes` sizes the result cache.
pub fn open_service(dir: &Path, cache_bytes: usize) -> Result<QueryService, ArtifactOpenError> {
    QueryService::open_with_cache(dir, cache_bytes)
        .map_err(|source| ArtifactOpenError { dir: dir.to_path_buf(), source })
}

/// Routes requests to registered artifacts; see the module docs for the
/// hot-swap contract.
pub struct Registry {
    services: RwLock<BTreeMap<String, Arc<dyn QuerySurface>>>,
    cache_bytes: usize,
}

impl Registry {
    /// An empty registry whose future `open_and_register` calls size
    /// each service's cache at `cache_bytes`.
    pub fn new(cache_bytes: usize) -> Registry {
        Registry { services: RwLock::new(BTreeMap::new()), cache_bytes }
    }

    /// Open `dir` and register it under `id`.
    pub fn open_and_register(&self, id: &str, dir: &Path) -> Result<(), ServeError> {
        let svc = open_service(dir, self.cache_bytes)?;
        self.register(id, Arc::new(svc))
    }

    /// Open the segment set at `set_dir` as a [`MergedView`] and
    /// register it under `id` — one id answers over every live segment.
    /// Each segment's service gets its own `cache_bytes`-sized cache.
    pub fn open_and_register_set(&self, id: &str, set_dir: &Path) -> Result<(), ServeError> {
        let view = MergedView::open(set_dir, self.cache_bytes)
            .map_err(|source| ArtifactOpenError { dir: set_dir.to_path_buf(), source })?;
        self.register(id, Arc::new(view))
    }

    /// Register an already-open query surface (a [`QueryService`], a
    /// [`MergedView`], …). Duplicate ids are refused (use
    /// retire-then-register to replace an artifact).
    pub fn register(&self, id: &str, svc: Arc<dyn QuerySurface>) -> Result<(), ServeError> {
        let mut map = write_ignore_poison(&self.services);
        if map.contains_key(id) {
            return Err(ServeError::Artifact(format!(
                "artifact id {id:?} is already registered"
            )));
        }
        map.insert(id.to_string(), svc);
        Ok(())
    }

    /// Unregister `id`; returns whether it was present. In-flight
    /// readers holding the `Arc` finish undisturbed.
    pub fn retire(&self, id: &str) -> bool {
        write_ignore_poison(&self.services).remove(id).is_some()
    }

    /// Resolve a request's artifact id to a query surface. `None`
    /// routes to the sole registered artifact; when zero or several are
    /// registered the caller must name one, and the error lists the
    /// known ids so a client can self-correct.
    pub fn route(&self, id: Option<&str>) -> Result<Arc<dyn QuerySurface>, ServeError> {
        self.route_entry(id).map(|(_, svc)| svc)
    }

    /// [`Registry::route`] plus the resolved id — for responses that
    /// echo the artifact name (`stats`).
    pub fn route_entry(
        &self,
        id: Option<&str>,
    ) -> Result<(String, Arc<dyn QuerySurface>), ServeError> {
        let map = read_ignore_poison(&self.services);
        match id {
            Some(id) => map.get_key_value(id).map(|(k, v)| (k.clone(), v.clone())).ok_or_else(
                || {
                    ServeError::NotFound(format!(
                        "no artifact {id:?} (registered: {})",
                        ids_for_display(&map)
                    ))
                },
            ),
            None => {
                if map.len() == 1 {
                    let (k, v) = map.iter().next().unwrap();
                    Ok((k.clone(), v.clone()))
                } else {
                    Err(ServeError::NotFound(format!(
                        "request names no artifact and {} are registered \
                         (registered: {})",
                        map.len(),
                        ids_for_display(&map)
                    )))
                }
            }
        }
    }

    /// Registered ids, sorted.
    pub fn ids(&self) -> Vec<String> {
        read_ignore_poison(&self.services).keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        read_ignore_poison(&self.services).len()
    }

    pub fn is_empty(&self) -> bool {
        read_ignore_poison(&self.services).is_empty()
    }

    /// Identity rows for the `list` response.
    pub fn describe(&self) -> Vec<ArtifactInfo> {
        read_ignore_poison(&self.services)
            .iter()
            .map(|(id, svc)| {
                let info = svc.describe();
                ArtifactInfo {
                    id: id.clone(),
                    records: info.records,
                    sequences: info.sequences,
                    patients: info.patients,
                    version: info.version,
                    target: info.target,
                }
            })
            .collect()
    }
}

fn ids_for_display(map: &BTreeMap<String, Arc<dyn QuerySurface>>) -> String {
    if map.is_empty() {
        "none".to_string()
    } else {
        map.keys().cloned().collect::<Vec<_>>().join(", ")
    }
}

/// Exhaustive-interleaving check of the hot-swap protocol the registry
/// implements: clone one `Arc` under the read lock, answer outside it;
/// retire removes under the write lock. On every schedule the reader's
/// surface stays fully usable after retirement (the refcount — modeled
/// by loom's `Arc` — keeps it alive until the clone drops, and loom's
/// leak checker proves it *is* dropped at the end), while the next
/// route observes the retirement. Compiled only under
/// `RUSTFLAGS="--cfg loom"`; see the crate "Verification" docs.
#[cfg(all(test, loom))]
mod loom_tests {
    use crate::sync::{read_ignore_poison, write_ignore_poison, Arc, RwLock};
    use std::collections::BTreeMap;

    #[test]
    fn loom_no_reader_observes_a_retired_artifact_mid_swap() {
        loom::model(|| {
            // The registry protocol over a payload loom's Arc can own:
            // the "artifact" is its generation number.
            let map: Arc<RwLock<BTreeMap<&'static str, Arc<u32>>>> = {
                let mut m = BTreeMap::new();
                m.insert("a", Arc::new(1u32));
                Arc::new(RwLock::new(m))
            };
            let reader = {
                let map = Arc::clone(&map);
                loom::thread::spawn(move || {
                    // route(): clone under the read lock, drop the lock,
                    // then answer from the clone.
                    let svc = read_ignore_poison(&map).get("a").cloned();
                    match svc {
                        // The held clone answers after any concurrent
                        // retire/register — always a whole generation
                        // (old or new), never a torn or freed value.
                        Some(svc) => assert!(*svc == 1 || *svc == 2),
                        // Or the route landed in the retire→register
                        // window and correctly saw no artifact.
                        None => {}
                    }
                })
            };
            // Hot-swap: retire, then register generation 2.
            let old = write_ignore_poison(&map).remove("a");
            drop(old); // the reader's clone, if any, still owns gen 1
            write_ignore_poison(&map).insert("a", Arc::new(2u32));
            reader.join().unwrap();
            // Post-swap route sees exactly the new generation.
            assert_eq!(**read_ignore_poison(&map).get("a").unwrap(), 2);
        });
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use crate::mining::SeqRecord;
    use crate::query::index::{build, IndexConfig};
    use crate::seqstore::{self, SeqFileSet};
    use crate::serve::protocol::ErrorCode;
    use std::sync::atomic::{AtomicBool, Ordering};

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("tspm_registry_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn fixture(dir: &Path, n_pids: u32) -> PathBuf {
        let mut records = Vec::new();
        for pid in 0..n_pids {
            for s in 0..4u64 {
                records.push(SeqRecord { seq: s * 10 + 1, pid, duration: s as u32 * 7 });
            }
        }
        records.sort_unstable_by_key(|r| (r.seq, r.pid, r.duration));
        let path = dir.join("in.tspm");
        seqstore::write_file(&path, &records).unwrap();
        let input = SeqFileSet {
            files: vec![path],
            total_records: records.len() as u64,
            num_patients: n_pids,
            num_phenx: 4,
        };
        let out = dir.join("index");
        build(&input, &out, &IndexConfig { block_records: 64, ..Default::default() }, None)
            .unwrap();
        out
    }

    #[test]
    fn route_by_id_and_default_routing() {
        let dir = tmpdir("route");
        let idx = fixture(&dir, 3);
        let reg = Registry::new(1 << 16);
        reg.open_and_register("a", &idx).unwrap();
        // Sole artifact: None routes to it.
        assert!(reg.route(None).is_ok());
        assert!(reg.route(Some("a")).is_ok());
        let err = reg.route(Some("ghost")).unwrap_err();
        assert_eq!(err.code(), ErrorCode::NotFound);
        assert!(err.to_string().contains("ghost"), "{err}");
        assert!(err.to_string().contains('a'), "lists known ids: {err}");
        // Second artifact: None becomes ambiguous.
        reg.open_and_register("b", &idx).unwrap();
        assert_eq!(reg.route(None).unwrap_err().code(), ErrorCode::NotFound);
        assert_eq!(reg.ids(), vec!["a".to_string(), "b".to_string()]);
        assert_eq!(reg.describe().len(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn duplicate_register_is_refused() {
        let dir = tmpdir("dup");
        let idx = fixture(&dir, 2);
        let reg = Registry::new(1 << 16);
        reg.open_and_register("a", &idx).unwrap();
        let err = reg.open_and_register("a", &idx).unwrap_err();
        assert_eq!(err.code(), ErrorCode::Artifact);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn open_failure_names_the_path() {
        let missing = std::env::temp_dir().join("tspm_registry_no_such_artifact");
        let _ = std::fs::remove_dir_all(&missing);
        let err = open_service(&missing, 0).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("tspm_registry_no_such_artifact"), "names the path: {msg}");
        // Registering it surfaces the same message through ServeError.
        let reg = Registry::new(0);
        let serr = reg.open_and_register("x", &missing).unwrap_err();
        assert_eq!(serr.code(), ErrorCode::Artifact);
        assert!(serr.to_string().contains("tspm_registry_no_such_artifact"), "{serr}");
        assert!(reg.is_empty(), "failed register leaves the registry untouched");
    }

    #[test]
    fn segment_set_registers_as_one_surface() {
        use crate::ingest::SegmentSet;
        let dir = tmpdir("segset");
        let set_dir = dir.join("set");
        let mut set = SegmentSet::open_or_init(&set_dir).unwrap();
        for (lo, hi) in [(0u32, 2u32), (2, 5)] {
            let mut records = Vec::new();
            for pid in lo..hi {
                for s in 0..4u64 {
                    records.push(SeqRecord { seq: s * 10 + 1, pid, duration: s as u32 * 7 });
                }
            }
            records.sort_unstable_by_key(|r| (r.seq, r.pid, r.duration));
            let path = dir.join(format!("in_{lo}.tspm"));
            seqstore::write_file(&path, &records).unwrap();
            let input = SeqFileSet {
                files: vec![path],
                total_records: records.len() as u64,
                num_patients: 5,
                num_phenx: 4,
            };
            set.add_segment(&input, &IndexConfig { block_records: 64, ..Default::default() }, None)
                .unwrap();
        }
        let reg = Registry::new(1 << 16);
        reg.open_and_register_set("set", &set_dir).unwrap();
        let rows = reg.describe();
        assert_eq!((rows[0].records, rows[0].patients, rows[0].sequences), (20, 5, 4));
        let svc = reg.route(Some("set")).unwrap();
        assert_eq!(svc.by_sequence(11).unwrap().len(), 5);
        assert_eq!(svc.by_patient(3).unwrap().len(), 4);
        // Hot-swap: retire and re-register after the set changed on disk.
        assert!(reg.retire("set"));
        reg.open_and_register_set("set", &set_dir).unwrap();
        assert!(reg.route(Some("set")).is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn retire_never_interrupts_in_flight_readers() {
        let dir = tmpdir("hotswap");
        let idx = fixture(&dir, 4);
        let reg = Registry::new(1 << 16);
        reg.open_and_register("a", &idx).unwrap();

        // A "reader" grabs its Arc, then the artifact is retired while
        // the reader is mid-query.
        let svc = reg.route(Some("a")).unwrap();
        let retired = AtomicBool::new(false);
        std::thread::scope(|scope| {
            scope.spawn(|| {
                while !retired.load(Ordering::Acquire) {
                    std::hint::spin_loop();
                }
                // Post-retire: the held Arc still answers, byte-identically.
                let rows = svc.top_k_by_support(4).unwrap();
                assert_eq!(rows.len(), 4);
                let recs = svc.by_patient(2).unwrap();
                assert_eq!(recs.len(), 4);
            });
            assert!(reg.retire("a"));
            assert!(!reg.retire("a"), "second retire is a no-op");
            retired.store(true, Ordering::Release);
        });
        // New lookups see the retirement.
        assert_eq!(reg.route(Some("a")).unwrap_err().code(), ErrorCode::NotFound);
        // Re-register under the same id works after retirement.
        reg.open_and_register("a", &idx).unwrap();
        assert!(reg.route(Some("a")).is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
