//! The wire protocol: versioned length-prefixed JSON frames.
//!
//! One **frame** is a 9-byte header followed by a UTF-8 JSON payload:
//!
//! ```text
//! offset  size  field
//! 0       4     magic  b"TSPC"
//! 4       1     protocol version (currently 1)
//! 5       4     payload length, u32 little-endian
//! 9       len   payload: one JSON object (a Request or a Response)
//! ```
//!
//! Both sides enforce a **max-frame-size guard** ([`DEFAULT_MAX_FRAME_BYTES`]
//! unless configured otherwise): a header announcing a larger payload is
//! rejected *before* any payload byte is read, so a malicious or corrupt
//! peer can never make the other side allocate unboundedly. The version
//! byte gates every frame the same way the index-artifact manifest gates
//! reads: a reader that sees a version outside
//! [`MIN_PROTOCOL_VERSION`]`..=`[`PROTOCOL_VERSION`] refuses the frame
//! with a typed error instead of misparsing it. See the
//! [`crate::serve`] module docs for the full compatibility contract.
//!
//! [`Request`] mirrors the [`crate::query::QueryService`] surface
//! one-for-one (`by_sequence` / `by_patient` / `patients_with` /
//! `top_k` / `histogram`) plus registry administration (`register` /
//! `retire` / `list` / `stats`), lifecycle (`ping` / `shutdown`) and
//! observability (`metrics`, answered with the server's Prometheus
//! text exposition). Every response is a single frame except
//! `by_patient`, which streams: zero or more `records_part` frames
//! with `"last": false` followed by exactly one with `"last": true`
//! carrying the total count.
//!
//! Any request may additionally carry a top-level `"trace_id"` key —
//! an **envelope** field that rides outside the request enum (see
//! [`Request::encode_traced`] / [`Request::decode_traced`]). Readers
//! ignore unknown JSON keys, so the envelope needs no version bump:
//! old servers silently drop it, new servers adopt the client's trace
//! id as the root of their server-side spans.

use crate::json::Json;
use crate::mining::SeqRecord;
use crate::query::{Histogram, HistogramBucket, QueryStats, SeqSupport};
use std::io::{Read, Write};

/// First four bytes of every frame.
pub const FRAME_MAGIC: [u8; 4] = *b"TSPC";
/// The protocol version this build speaks (and stamps on every frame).
pub const PROTOCOL_VERSION: u8 = 1;
/// Oldest version this build still accepts.
pub const MIN_PROTOCOL_VERSION: u8 = 1;
/// Frame header size: magic + version + payload length.
pub const HEADER_BYTES: usize = 9;
/// Default payload-size guard (16 MiB) — applied to reads *and* writes.
pub const DEFAULT_MAX_FRAME_BYTES: usize = 16 << 20;

/// Typed framing failures, distinguished so the server can answer each
/// with the right [`ErrorCode`] before closing the stream.
#[derive(Debug)]
pub enum FrameError {
    Io(std::io::Error),
    /// The first four bytes were not [`FRAME_MAGIC`].
    BadMagic([u8; 4]),
    /// The version byte is outside the supported range.
    UnsupportedVersion(u8),
    /// The announced payload exceeds the configured guard.
    TooLarge { len: usize, max: usize },
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Io(e) => write!(f, "frame io error: {e}"),
            FrameError::BadMagic(m) => {
                write!(f, "bad frame magic {m:?} (expected {FRAME_MAGIC:?})")
            }
            FrameError::UnsupportedVersion(v) => write!(
                f,
                "unsupported protocol version {v} (this build speaks \
                 {MIN_PROTOCOL_VERSION}..={PROTOCOL_VERSION})"
            ),
            FrameError::TooLarge { len, max } => {
                write!(f, "frame payload of {len} bytes exceeds the {max} byte guard")
            }
        }
    }
}

impl std::error::Error for FrameError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            FrameError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for FrameError {
    fn from(e: std::io::Error) -> Self {
        FrameError::Io(e)
    }
}

/// Write one frame. Fails (without writing anything) when `payload`
/// exceeds `max_frame` — the caller decides whether to substitute a
/// typed error response instead.
pub fn write_frame(w: &mut impl Write, payload: &[u8], max_frame: usize) -> Result<(), FrameError> {
    if payload.len() > max_frame {
        return Err(FrameError::TooLarge { len: payload.len(), max: max_frame });
    }
    let mut hdr = [0u8; HEADER_BYTES];
    hdr[..4].copy_from_slice(&FRAME_MAGIC);
    hdr[4] = PROTOCOL_VERSION;
    hdr[5..9].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    w.write_all(&hdr)?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame's payload, validating magic, version and size guard.
pub fn read_frame(r: &mut impl Read, max_frame: usize) -> Result<Vec<u8>, FrameError> {
    let mut first = [0u8; 1];
    r.read_exact(&mut first)?;
    read_frame_resume(first[0], r, max_frame)
}

/// [`read_frame`] when the first header byte has already been read —
/// the server's poll loop reads one byte with a short timeout (so it
/// can notice idle connections and shutdown) and resumes here.
pub fn read_frame_resume(
    first: u8,
    r: &mut impl Read,
    max_frame: usize,
) -> Result<Vec<u8>, FrameError> {
    let mut hdr = [0u8; HEADER_BYTES];
    hdr[0] = first;
    r.read_exact(&mut hdr[1..])?;
    if hdr[..4] != FRAME_MAGIC {
        return Err(FrameError::BadMagic([hdr[0], hdr[1], hdr[2], hdr[3]]));
    }
    let version = hdr[4];
    if !(MIN_PROTOCOL_VERSION..=PROTOCOL_VERSION).contains(&version) {
        return Err(FrameError::UnsupportedVersion(version));
    }
    let len = u32::from_le_bytes([hdr[5], hdr[6], hdr[7], hdr[8]]) as usize;
    if len > max_frame {
        return Err(FrameError::TooLarge { len, max: max_frame });
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(payload)
}

// ---------------------------------------------------------------------------
// error codes
// ---------------------------------------------------------------------------

/// Machine-readable error codes carried by `{"type":"error"}` frames.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    /// The frame itself was malformed (bad magic, truncated header…).
    BadFrame,
    /// The frame's protocol version is outside the supported range.
    UnsupportedVersion,
    /// A frame (request or response) exceeded the size guard.
    FrameTooLarge,
    /// The payload was not a well-formed request.
    BadRequest,
    /// The named artifact is not registered (or the request named none
    /// while several are registered).
    NotFound,
    /// The artifact exists but is corrupt / failed to answer
    /// ([`crate::query::QueryError::Artifact`], or a registry open
    /// failure on `register`).
    Artifact,
    /// A structurally invalid query (zero histogram buckets, …).
    Invalid,
    /// A server-side IO failure while answering.
    Io,
    /// The server is draining and accepts no new requests.
    ShuttingDown,
    /// Anything else — a bug, by contract.
    Internal,
}

impl ErrorCode {
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorCode::BadFrame => "bad_frame",
            ErrorCode::UnsupportedVersion => "unsupported_version",
            ErrorCode::FrameTooLarge => "frame_too_large",
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::NotFound => "not_found",
            ErrorCode::Artifact => "artifact",
            ErrorCode::Invalid => "invalid",
            ErrorCode::Io => "io",
            ErrorCode::ShuttingDown => "shutting_down",
            ErrorCode::Internal => "internal",
        }
    }

    pub fn parse(s: &str) -> Option<ErrorCode> {
        Some(match s {
            "bad_frame" => ErrorCode::BadFrame,
            "unsupported_version" => ErrorCode::UnsupportedVersion,
            "frame_too_large" => ErrorCode::FrameTooLarge,
            "bad_request" => ErrorCode::BadRequest,
            "not_found" => ErrorCode::NotFound,
            "artifact" => ErrorCode::Artifact,
            "invalid" => ErrorCode::Invalid,
            "io" => ErrorCode::Io,
            "shutting_down" => ErrorCode::ShuttingDown,
            "internal" => ErrorCode::Internal,
            _ => return None,
        })
    }
}

impl std::fmt::Display for ErrorCode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

// ---------------------------------------------------------------------------
// requests
// ---------------------------------------------------------------------------

/// One request frame. `artifact: None` routes to the only registered
/// artifact (an error when several are registered).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Request {
    Ping,
    /// Enumerate registered artifacts.
    List,
    /// Cache/IO counters of one artifact's service.
    Stats { artifact: Option<String> },
    /// All records of a sequence (optionally truncated to `limit` so
    /// the single response frame stays under the size guard).
    BySequence { artifact: Option<String>, seq: u64, limit: Option<usize> },
    /// All records of a patient — the **streaming** query: the answer
    /// arrives as `records_part` frames, never one buffer.
    ByPatient { artifact: Option<String>, pid: u32 },
    /// Distinct patients having `seq` within a duration range.
    PatientsWith {
        artifact: Option<String>,
        seq: u64,
        dur_min: u32,
        dur_max: u32,
        limit: Option<usize>,
    },
    /// The `k` sequences with the most distinct patients.
    TopK { artifact: Option<String>, k: usize },
    /// Duration histogram of one sequence.
    Histogram { artifact: Option<String>, seq: u64, buckets: usize },
    /// Open an index directory and register it under `id` (hot-add).
    Register { id: String, dir: String },
    /// Unregister an artifact; in-flight readers finish undisturbed.
    Retire { id: String },
    /// Drain in-flight requests and exit the serve loop.
    Shutdown,
    /// The server's metrics registry in Prometheus text exposition
    /// format — answered without touching any artifact, so it works
    /// even when nothing is registered.
    Metrics,
}

impl Request {
    /// Stable label for metrics / workload reports.
    pub fn kind(&self) -> &'static str {
        match self {
            Request::Ping => "ping",
            Request::List => "list",
            Request::Stats { .. } => "stats",
            Request::BySequence { .. } => "by_sequence",
            Request::ByPatient { .. } => "by_patient",
            Request::PatientsWith { .. } => "patients_with",
            Request::TopK { .. } => "top_k",
            Request::Histogram { .. } => "histogram",
            Request::Register { .. } => "register",
            Request::Retire { .. } => "retire",
            Request::Shutdown => "shutdown",
            Request::Metrics => "metrics",
        }
    }

    pub fn to_json(&self) -> Json {
        let artifact = |a: &Option<String>| match a {
            Some(s) => Json::from(s.clone()),
            None => Json::Null,
        };
        match self {
            Request::Ping => Json::obj(vec![("type", Json::from("ping"))]),
            Request::List => Json::obj(vec![("type", Json::from("list"))]),
            Request::Stats { artifact: a } => {
                Json::obj(vec![("type", Json::from("stats")), ("artifact", artifact(a))])
            }
            Request::BySequence { artifact: a, seq, limit } => Json::obj(vec![
                ("type", Json::from("by_sequence")),
                ("artifact", artifact(a)),
                ("seq", Json::from(*seq)),
                ("limit", opt_num(*limit)),
            ]),
            Request::ByPatient { artifact: a, pid } => Json::obj(vec![
                ("type", Json::from("by_patient")),
                ("artifact", artifact(a)),
                ("pid", Json::from(*pid as u64)),
            ]),
            Request::PatientsWith { artifact: a, seq, dur_min, dur_max, limit } => Json::obj(vec![
                ("type", Json::from("patients_with")),
                ("artifact", artifact(a)),
                ("seq", Json::from(*seq)),
                ("dur_min", Json::from(*dur_min as u64)),
                ("dur_max", Json::from(*dur_max as u64)),
                ("limit", opt_num(*limit)),
            ]),
            Request::TopK { artifact: a, k } => Json::obj(vec![
                ("type", Json::from("top_k")),
                ("artifact", artifact(a)),
                ("k", Json::from(*k)),
            ]),
            Request::Histogram { artifact: a, seq, buckets } => Json::obj(vec![
                ("type", Json::from("histogram")),
                ("artifact", artifact(a)),
                ("seq", Json::from(*seq)),
                ("buckets", Json::from(*buckets)),
            ]),
            Request::Register { id, dir } => Json::obj(vec![
                ("type", Json::from("register")),
                ("id", Json::from(id.clone())),
                ("dir", Json::from(dir.clone())),
            ]),
            Request::Retire { id } => {
                Json::obj(vec![("type", Json::from("retire")), ("id", Json::from(id.clone()))])
            }
            Request::Shutdown => Json::obj(vec![("type", Json::from("shutdown"))]),
            Request::Metrics => Json::obj(vec![("type", Json::from("metrics"))]),
        }
    }

    pub fn from_json(j: &Json) -> Result<Request, String> {
        let ty = j.get("type").and_then(Json::as_str).ok_or("request has no \"type\"")?;
        let artifact = || -> Option<String> {
            j.get("artifact").and_then(Json::as_str).map(str::to_string)
        };
        Ok(match ty {
            "ping" => Request::Ping,
            "list" => Request::List,
            "stats" => Request::Stats { artifact: artifact() },
            "by_sequence" => Request::BySequence {
                artifact: artifact(),
                seq: req_u64(j, "seq")?,
                limit: opt_usize(j, "limit")?,
            },
            "by_patient" => Request::ByPatient {
                artifact: artifact(),
                pid: req_u64(j, "pid")? as u32,
            },
            "patients_with" => Request::PatientsWith {
                artifact: artifact(),
                seq: req_u64(j, "seq")?,
                dur_min: req_u64(j, "dur_min")? as u32,
                dur_max: req_u64(j, "dur_max")? as u32,
                limit: opt_usize(j, "limit")?,
            },
            "top_k" => Request::TopK { artifact: artifact(), k: req_u64(j, "k")? as usize },
            "histogram" => Request::Histogram {
                artifact: artifact(),
                seq: req_u64(j, "seq")?,
                buckets: req_u64(j, "buckets")? as usize,
            },
            "register" => Request::Register {
                id: req_str(j, "id")?,
                dir: req_str(j, "dir")?,
            },
            "retire" => Request::Retire { id: req_str(j, "id")? },
            "shutdown" => Request::Shutdown,
            "metrics" => Request::Metrics,
            other => return Err(format!("unknown request type {other:?}")),
        })
    }

    pub fn encode(&self) -> Vec<u8> {
        self.to_json().to_string_compact().into_bytes()
    }

    pub fn decode(payload: &[u8]) -> Result<Request, String> {
        let text = std::str::from_utf8(payload).map_err(|e| format!("payload not UTF-8: {e}"))?;
        let j = Json::parse(text).map_err(|e| format!("payload not JSON: {e}"))?;
        Request::from_json(&j)
    }

    /// [`encode`](Request::encode) plus the optional top-level
    /// `"trace_id"` envelope key. With `None` the output is
    /// byte-identical to the plain encoding; with `Some`, readers that
    /// predate the key ignore it (unknown keys are dropped), so the
    /// envelope is append-only at the JSON level — no version bump.
    pub fn encode_traced(&self, trace_id: Option<&str>) -> Vec<u8> {
        let mut j = self.to_json();
        if let (Json::Obj(map), Some(id)) = (&mut j, trace_id) {
            map.insert("trace_id".to_string(), Json::from(id));
        }
        j.to_string_compact().into_bytes()
    }

    /// [`decode`](Request::decode) that also surfaces the optional
    /// top-level `"trace_id"` envelope key (`None` when absent or not
    /// a string — a malformed trace id never fails the request).
    pub fn decode_traced(payload: &[u8]) -> Result<(Request, Option<String>), String> {
        let text = std::str::from_utf8(payload).map_err(|e| format!("payload not UTF-8: {e}"))?;
        let j = Json::parse(text).map_err(|e| format!("payload not JSON: {e}"))?;
        let trace_id = j.get("trace_id").and_then(Json::as_str).map(str::to_string);
        Ok((Request::from_json(&j)?, trace_id))
    }
}

// ---------------------------------------------------------------------------
// responses
// ---------------------------------------------------------------------------

/// One registered artifact's identity row in a `list` answer.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactInfo {
    pub id: String,
    pub records: u64,
    pub sequences: u64,
    pub patients: u32,
    pub version: u64,
    /// Rendered target spec the artifact was mined under, when its
    /// manifest records one. Carried as an **optional** wire key (same
    /// append-only rule as `trace_id`): absent for untargeted artifacts,
    /// ignored by readers that predate it — no protocol version bump.
    pub target: Option<String>,
}

/// One response frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    Pong,
    /// Acknowledgement of `register` / `retire` / `shutdown`.
    Ok,
    /// Admission control shed this connection — retry later.
    Busy,
    Error { code: ErrorCode, message: String },
    Artifacts(Vec<ArtifactInfo>),
    Stats { artifact: String, stats: QueryStats },
    /// Complete `by_sequence` / truncated answer; `total` is the full
    /// count before any `limit` was applied.
    Records { records: Vec<SeqRecord>, total: u64 },
    /// One chunk of a streaming `by_patient` answer. The final frame has
    /// `last: true`, an empty record list and the stream's total count.
    RecordsPart { records: Vec<SeqRecord>, last: bool, total: Option<u64> },
    Patients { patients: Vec<u32>, total: u64 },
    TopK(Vec<SeqSupport>),
    Histogram(Histogram),
    /// Prometheus text exposition, verbatim — the same bytes the
    /// `--metrics-addr` HTTP endpoint serves.
    Metrics { text: String },
}

impl Response {
    pub fn to_json(&self) -> Json {
        match self {
            Response::Pong => Json::obj(vec![("type", Json::from("pong"))]),
            Response::Ok => Json::obj(vec![("type", Json::from("ok"))]),
            Response::Busy => Json::obj(vec![("type", Json::from("busy"))]),
            Response::Error { code, message } => Json::obj(vec![
                ("type", Json::from("error")),
                ("code", Json::from(code.as_str())),
                ("message", Json::from(message.clone())),
            ]),
            Response::Artifacts(infos) => Json::obj(vec![
                ("type", Json::from("artifacts")),
                (
                    "artifacts",
                    Json::Arr(
                        infos
                            .iter()
                            .map(|a| {
                                let mut fields = vec![
                                    ("id", Json::from(a.id.clone())),
                                    ("records", Json::from(a.records)),
                                    ("sequences", Json::from(a.sequences)),
                                    ("patients", Json::from(a.patients as u64)),
                                    ("version", Json::from(a.version)),
                                ];
                                if let Some(t) = &a.target {
                                    fields.push(("target", Json::from(t.clone())));
                                }
                                Json::obj(fields)
                            })
                            .collect(),
                    ),
                ),
            ]),
            Response::Stats { artifact, stats } => Json::obj(vec![
                ("type", Json::from("stats")),
                ("artifact", Json::from(artifact.clone())),
                ("hits", Json::from(stats.hits)),
                ("misses", Json::from(stats.misses)),
                ("evictions", Json::from(stats.evictions)),
                ("cached_entries", Json::from(stats.cached_entries)),
                ("cached_bytes", Json::from(stats.cached_bytes)),
                ("logical_bytes_read", Json::from(stats.logical_bytes_read)),
            ]),
            Response::Records { records, total } => Json::obj(vec![
                ("type", Json::from("records")),
                ("records", records_json(records)),
                ("total", Json::from(*total)),
            ]),
            Response::RecordsPart { records, last, total } => Json::obj(vec![
                ("type", Json::from("records_part")),
                ("records", records_json(records)),
                ("last", Json::Bool(*last)),
                (
                    "total",
                    match total {
                        Some(t) => Json::from(*t),
                        None => Json::Null,
                    },
                ),
            ]),
            Response::Patients { patients, total } => Json::obj(vec![
                ("type", Json::from("patients")),
                ("patients", Json::Arr(patients.iter().map(|&p| Json::from(p as u64)).collect())),
                ("total", Json::from(*total)),
            ]),
            Response::TopK(rows) => Json::obj(vec![
                ("type", Json::from("top_k")),
                (
                    "rows",
                    Json::Arr(
                        rows.iter()
                            .map(|r| {
                                Json::Arr(vec![
                                    Json::from(r.seq),
                                    Json::from(r.patients as u64),
                                    Json::from(r.records),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
            Response::Histogram(h) => Json::obj(vec![
                ("type", Json::from("histogram")),
                ("seq", Json::from(h.seq)),
                ("dur_min", Json::from(h.dur_min as u64)),
                ("dur_max", Json::from(h.dur_max as u64)),
                ("total", Json::from(h.total)),
                (
                    "buckets",
                    Json::Arr(
                        h.buckets
                            .iter()
                            .map(|b| {
                                Json::Arr(vec![
                                    Json::from(b.lo as u64),
                                    Json::from(b.hi as u64),
                                    Json::from(b.count),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
            Response::Metrics { text } => Json::obj(vec![
                ("type", Json::from("metrics")),
                ("text", Json::from(text.clone())),
            ]),
        }
    }

    pub fn from_json(j: &Json) -> Result<Response, String> {
        let ty = j.get("type").and_then(Json::as_str).ok_or("response has no \"type\"")?;
        Ok(match ty {
            "pong" => Response::Pong,
            "ok" => Response::Ok,
            "busy" => Response::Busy,
            "error" => {
                let code_str = req_str(j, "code")?;
                Response::Error {
                    code: ErrorCode::parse(&code_str)
                        .ok_or_else(|| format!("unknown error code {code_str:?}"))?,
                    message: req_str(j, "message")?,
                }
            }
            "artifacts" => {
                let arr = j.get("artifacts").and_then(Json::as_arr).ok_or("no artifacts")?;
                let mut infos = Vec::with_capacity(arr.len());
                for a in arr {
                    infos.push(ArtifactInfo {
                        id: req_str(a, "id")?,
                        records: req_u64(a, "records")?,
                        sequences: req_u64(a, "sequences")?,
                        patients: req_u64(a, "patients")? as u32,
                        version: req_u64(a, "version")?,
                        target: a.get("target").and_then(Json::as_str).map(str::to_string),
                    });
                }
                Response::Artifacts(infos)
            }
            "stats" => Response::Stats {
                artifact: req_str(j, "artifact")?,
                stats: QueryStats {
                    hits: req_u64(j, "hits")?,
                    misses: req_u64(j, "misses")?,
                    evictions: req_u64(j, "evictions")?,
                    cached_entries: req_u64(j, "cached_entries")? as usize,
                    cached_bytes: req_u64(j, "cached_bytes")? as usize,
                    logical_bytes_read: req_u64(j, "logical_bytes_read")?,
                },
            },
            "records" => Response::Records {
                records: records_from_json(j.get("records"))?,
                total: req_u64(j, "total")?,
            },
            "records_part" => Response::RecordsPart {
                records: records_from_json(j.get("records"))?,
                last: j.get("last").and_then(Json::as_bool).ok_or("no \"last\"")?,
                total: match j.get("total") {
                    Some(Json::Null) | None => None,
                    Some(t) => Some(t.as_u64().ok_or("bad \"total\"")?),
                },
            },
            "patients" => {
                let arr = j.get("patients").and_then(Json::as_arr).ok_or("no patients")?;
                let mut patients = Vec::with_capacity(arr.len());
                for p in arr {
                    patients.push(p.as_u64().ok_or("bad patient id")? as u32);
                }
                Response::Patients { patients, total: req_u64(j, "total")? }
            }
            "top_k" => {
                let arr = j.get("rows").and_then(Json::as_arr).ok_or("no rows")?;
                let mut rows = Vec::with_capacity(arr.len());
                for r in arr {
                    let t = r.as_arr().filter(|t| t.len() == 3).ok_or("bad top_k row")?;
                    rows.push(SeqSupport {
                        seq: t[0].as_u64().ok_or("bad seq")?,
                        patients: t[1].as_u64().ok_or("bad patients")? as u32,
                        records: t[2].as_u64().ok_or("bad records")?,
                    });
                }
                Response::TopK(rows)
            }
            "histogram" => {
                let arr = j.get("buckets").and_then(Json::as_arr).ok_or("no buckets")?;
                let mut buckets = Vec::with_capacity(arr.len());
                for b in arr {
                    let t = b.as_arr().filter(|t| t.len() == 3).ok_or("bad bucket")?;
                    buckets.push(HistogramBucket {
                        lo: t[0].as_u64().ok_or("bad lo")? as u32,
                        hi: t[1].as_u64().ok_or("bad hi")? as u32,
                        count: t[2].as_u64().ok_or("bad count")?,
                    });
                }
                Response::Histogram(Histogram {
                    seq: req_u64(j, "seq")?,
                    dur_min: req_u64(j, "dur_min")? as u32,
                    dur_max: req_u64(j, "dur_max")? as u32,
                    total: req_u64(j, "total")?,
                    buckets,
                })
            }
            "metrics" => Response::Metrics { text: req_str(j, "text")? },
            other => return Err(format!("unknown response type {other:?}")),
        })
    }

    pub fn encode(&self) -> Vec<u8> {
        self.to_json().to_string_compact().into_bytes()
    }

    pub fn decode(payload: &[u8]) -> Result<Response, String> {
        let text = std::str::from_utf8(payload).map_err(|e| format!("payload not UTF-8: {e}"))?;
        let j = Json::parse(text).map_err(|e| format!("payload not JSON: {e}"))?;
        Response::from_json(&j)
    }
}

// ---------------------------------------------------------------------------
// JSON helpers
// ---------------------------------------------------------------------------

fn opt_num(v: Option<usize>) -> Json {
    match v {
        Some(n) => Json::from(n),
        None => Json::Null,
    }
}

fn req_u64(j: &Json, key: &str) -> Result<u64, String> {
    j.get(key).and_then(Json::as_u64).ok_or_else(|| format!("missing or bad \"{key}\""))
}

fn req_str(j: &Json, key: &str) -> Result<String, String> {
    j.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| format!("missing or bad \"{key}\""))
}

fn opt_usize(j: &Json, key: &str) -> Result<Option<usize>, String> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => Ok(Some(v.as_u64().ok_or_else(|| format!("bad \"{key}\""))? as usize)),
    }
}

/// Records travel as compact `[seq, pid, duration]` triples. `seq`
/// values are bounded by the `encode_seq` pairing (< 10^14), well under
/// the 2^53 JSON-number precision limit [`Json::as_u64`] enforces.
fn records_json(records: &[SeqRecord]) -> Json {
    Json::Arr(
        records
            .iter()
            .map(|r| {
                Json::Arr(vec![
                    Json::from(r.seq),
                    Json::from(r.pid as u64),
                    Json::from(r.duration as u64),
                ])
            })
            .collect(),
    )
}

fn records_from_json(j: Option<&Json>) -> Result<Vec<SeqRecord>, String> {
    let arr = j.and_then(Json::as_arr).ok_or("missing or bad \"records\"")?;
    let mut out = Vec::with_capacity(arr.len());
    for r in arr {
        let t = r.as_arr().filter(|t| t.len() == 3).ok_or("bad record triple")?;
        out.push(SeqRecord {
            seq: t[0].as_u64().ok_or("bad record seq")?,
            pid: t[1].as_u64().ok_or("bad record pid")? as u32,
            duration: t[2].as_u64().ok_or("bad record duration")? as u32,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip_req(r: Request) {
        let bytes = r.encode();
        assert_eq!(Request::decode(&bytes).unwrap(), r);
    }

    fn round_trip_resp(r: Response) {
        let bytes = r.encode();
        assert_eq!(Response::decode(&bytes).unwrap(), r);
    }

    #[test]
    fn every_request_round_trips() {
        round_trip_req(Request::Ping);
        round_trip_req(Request::List);
        round_trip_req(Request::Stats { artifact: None });
        round_trip_req(Request::Stats { artifact: Some("idx".into()) });
        round_trip_req(Request::BySequence { artifact: None, seq: 120_000_042, limit: None });
        round_trip_req(Request::BySequence {
            artifact: Some("a".into()),
            seq: 7,
            limit: Some(100),
        });
        round_trip_req(Request::ByPatient { artifact: Some("a".into()), pid: 42 });
        round_trip_req(Request::PatientsWith {
            artifact: None,
            seq: 3,
            dur_min: 0,
            dur_max: u32::MAX,
            limit: Some(5),
        });
        round_trip_req(Request::TopK { artifact: None, k: 10 });
        round_trip_req(Request::Histogram { artifact: None, seq: 9, buckets: 4 });
        round_trip_req(Request::Register { id: "b".into(), dir: "/tmp/idx".into() });
        round_trip_req(Request::Retire { id: "b".into() });
        round_trip_req(Request::Shutdown);
        round_trip_req(Request::Metrics);
    }

    #[test]
    fn trace_id_envelope_rides_outside_the_enum() {
        let traced = Request::Ping.encode_traced(Some("00ab"));
        let (req, tid) = Request::decode_traced(&traced).unwrap();
        assert_eq!(req, Request::Ping);
        assert_eq!(tid.as_deref(), Some("00ab"));
        // A reader that predates the envelope ignores the unknown key.
        assert_eq!(Request::decode(&traced).unwrap(), Request::Ping);
        // No trace id → byte-identical to the plain encoding, and the
        // traced decoder reports None rather than inventing one.
        assert_eq!(Request::Ping.encode_traced(None), Request::Ping.encode());
        let (req, tid) = Request::decode_traced(&Request::Ping.encode()).unwrap();
        assert_eq!(req, Request::Ping);
        assert_eq!(tid, None);
    }

    #[test]
    fn every_response_round_trips() {
        let rec = SeqRecord { seq: 120_000_042, pid: 7, duration: 365 };
        round_trip_resp(Response::Pong);
        round_trip_resp(Response::Ok);
        round_trip_resp(Response::Busy);
        round_trip_resp(Response::Error {
            code: ErrorCode::NotFound,
            message: "no artifact \"x\"".into(),
        });
        round_trip_resp(Response::Artifacts(vec![
            ArtifactInfo {
                id: "idx".into(),
                records: 100,
                sequences: 10,
                patients: 5,
                version: 2,
                target: None,
            },
            ArtifactInfo {
                id: "idx2".into(),
                records: 7,
                sequences: 3,
                patients: 2,
                version: 2,
                target: Some("codes[3,9]@first".into()),
            },
        ]));
        round_trip_resp(Response::Stats {
            artifact: "idx".into(),
            stats: QueryStats {
                hits: 1,
                misses: 2,
                evictions: 3,
                cached_entries: 4,
                cached_bytes: 5,
                logical_bytes_read: 6,
            },
        });
        round_trip_resp(Response::Records { records: vec![rec, rec], total: 2 });
        round_trip_resp(Response::RecordsPart { records: vec![rec], last: false, total: None });
        round_trip_resp(Response::RecordsPart { records: vec![], last: true, total: Some(9) });
        round_trip_resp(Response::Patients { patients: vec![1, 2, 3], total: 3 });
        round_trip_resp(Response::TopK(vec![SeqSupport { seq: 9, patients: 4, records: 12 }]));
        round_trip_resp(Response::Histogram(Histogram {
            seq: 9,
            dur_min: 5,
            dur_max: 500,
            total: 12,
            buckets: vec![HistogramBucket { lo: 5, hi: 128, count: 4 }],
        }));
        round_trip_resp(Response::Metrics {
            text: "# TYPE tspm_cache_hits counter\ntspm_cache_hits 3\n".into(),
        });
    }

    #[test]
    fn frames_round_trip_over_a_byte_pipe() {
        let payload = Request::Ping.encode();
        let mut wire = Vec::new();
        write_frame(&mut wire, &payload, 1024).unwrap();
        assert_eq!(wire.len(), HEADER_BYTES + payload.len());
        let mut r = &wire[..];
        let got = read_frame(&mut r, 1024).unwrap();
        assert_eq!(got, payload);
        assert!(r.is_empty(), "nothing left on the wire");
    }

    #[test]
    fn oversized_frames_are_refused_on_both_sides() {
        let payload = vec![b'x'; 100];
        let mut wire = Vec::new();
        assert!(matches!(
            write_frame(&mut wire, &payload, 99),
            Err(FrameError::TooLarge { len: 100, max: 99 })
        ));
        assert!(wire.is_empty(), "nothing was written");
        // A header announcing more than the guard is rejected before the
        // payload is read (or allocated).
        write_frame(&mut wire, &payload, 1024).unwrap();
        let mut r = &wire[..];
        assert!(matches!(
            read_frame(&mut r, 99),
            Err(FrameError::TooLarge { len: 100, max: 99 })
        ));
    }

    #[test]
    fn bad_magic_and_version_are_typed() {
        let mut wire = Vec::new();
        write_frame(&mut wire, b"{}", 1024).unwrap();
        let mut garbled = wire.clone();
        garbled[0] = b'X';
        assert!(matches!(read_frame(&mut &garbled[..], 1024), Err(FrameError::BadMagic(_))));
        let mut future = wire.clone();
        future[4] = PROTOCOL_VERSION + 1;
        assert!(matches!(
            read_frame(&mut &future[..], 1024),
            Err(FrameError::UnsupportedVersion(v)) if v == PROTOCOL_VERSION + 1
        ));
        let mut ancient = wire;
        ancient[4] = 0;
        assert!(matches!(
            read_frame(&mut &ancient[..], 1024),
            Err(FrameError::UnsupportedVersion(0))
        ));
    }

    #[test]
    fn truncated_frame_is_an_io_error() {
        let mut wire = Vec::new();
        write_frame(&mut wire, b"{\"type\":\"ping\"}", 1024).unwrap();
        wire.truncate(wire.len() - 3);
        assert!(matches!(read_frame(&mut &wire[..], 1024), Err(FrameError::Io(_))));
    }

    #[test]
    fn malformed_payloads_are_decode_errors() {
        assert!(Request::decode(b"not json").is_err());
        assert!(Request::decode(b"{\"type\":\"warp\"}").is_err());
        assert!(Request::decode(b"{\"no_type\":1}").is_err());
        assert!(Response::decode(b"{\"type\":\"error\",\"code\":\"weird\",\"message\":\"m\"}")
            .is_err());
        // by_sequence without its seq
        assert!(Request::decode(b"{\"type\":\"by_sequence\"}").is_err());
    }

    #[test]
    fn error_codes_round_trip() {
        for code in [
            ErrorCode::BadFrame,
            ErrorCode::UnsupportedVersion,
            ErrorCode::FrameTooLarge,
            ErrorCode::BadRequest,
            ErrorCode::NotFound,
            ErrorCode::Artifact,
            ErrorCode::Invalid,
            ErrorCode::Io,
            ErrorCode::ShuttingDown,
            ErrorCode::Internal,
        ] {
            assert_eq!(ErrorCode::parse(code.as_str()), Some(code));
        }
        assert_eq!(ErrorCode::parse("nope"), None);
    }
}
