//! `tspm serve`: a concurrent query daemon over index artifacts.
//!
//! The query subsystem ([`crate::query`]) answers one question per
//! process launch; this module keeps the artifacts open in a long-lived
//! daemon so many focused questions against one mined corpus — the
//! access shape targeted time-interval pattern mining motivates — cost
//! a socket round-trip instead of a cold open. The pieces:
//!
//! * [`protocol`] — the wire format (below) and typed request/response
//!   enums mirroring the [`crate::query::QueryService`] surface;
//! * [`registry`] — several artifacts at once, routed by id, with
//!   refcounted hot-swap (`register`/`retire` never interrupts a reader
//!   that already holds its service);
//! * [`server`] — thread-per-connection on `std::net`, bounded by a
//!   [`crate::par::Semaphore`]: excess connections are *shed* with a
//!   typed `busy` frame rather than queued unboundedly, idle
//!   connections time out, and shutdown drains in-flight requests;
//! * [`client`] — the blocking client used by `tspm client`, the e2e
//!   suite, and the loopback benchmark workload.
//!
//! # Wire protocol — compatibility contract
//!
//! Like the on-disk artifact format documented in [`crate::query`],
//! the wire protocol is a compatibility surface: independently built
//! clients and servers interoperate as long as they honour the rules
//! below. Breaking any of them requires bumping
//! [`protocol::PROTOCOL_VERSION`].
//!
//! **Frame layout.** Every message in either direction is one frame:
//!
//! ```text
//! bytes 0..4   magic          b"TSPC"
//! byte  4      version        currently 1
//! bytes 5..9   payload_len    u32, little-endian
//! bytes 9..    payload        payload_len bytes of UTF-8 JSON
//! ```
//!
//! **Version gate.** A receiver accepts versions in
//! `MIN_PROTOCOL_VERSION..=PROTOCOL_VERSION` and refuses anything else
//! with an `unsupported_version` error before reading the payload.
//! Within a version, unknown *object keys* must be ignored by readers
//! (fields may be added without a bump); unknown request/response
//! `"type"` values are errors.
//!
//! **Size guard.** Both sides bound `payload_len`
//! ([`protocol::DEFAULT_MAX_FRAME_BYTES`] = 16 MiB by default) and
//! refuse larger frames *before* allocating — a server whose answer
//! would exceed the guard replies `frame_too_large` and suggests the
//! request's `limit` field instead of sending the frame.
//!
//! **Requests.** The payload is an object with a `"type"` tag:
//! `ping`, `list`, `stats`, `by_sequence`, `by_patient`,
//! `patients_with`, `top_k`, `histogram`, `register`, `retire`,
//! `shutdown`, `metrics`. Query requests carry an optional
//! `"artifact"` id; `null`/absent routes to the sole registered
//! artifact and is a `not_found` error when zero or several are
//! registered. A `metrics` request returns the server's metrics
//! registry rendered in Prometheus text exposition format.
//!
//! **Trace envelope.** Any request object may additionally carry an
//! optional top-level `"trace_id"` key (1–32 hex characters). It rides
//! *outside* the request enum — added by
//! [`protocol::Request::encode_traced`], recovered by
//! [`protocol::Request::decode_traced`] — so it needed no version bump:
//! readers ignore unknown object keys. A server that receives one
//! adopts it as the trace id of the server-side `serve.request` span,
//! stitching client and server traces together; absent, the server
//! generates its own.
//!
//! **Responses.** One frame per request — except `by_patient`, which
//! streams `records_part` frames (`"last": false`) block-at-a-time and
//! terminates with a `"last": true` frame carrying the total record
//! count. Records travel as `[seq, pid, duration]` triples; `seq` fits
//! JSON's 2^53 integer window by construction (`encode_seq < 10^14`).
//! A connection that was shed by admission control receives exactly one
//! `busy` frame and is closed.
//!
//! **Error codes.** `error` responses carry a machine-readable
//! `"code"`: `bad_frame`, `unsupported_version`, `frame_too_large`,
//! `bad_request`, `not_found`, `artifact`, `invalid`, `io`,
//! `shutting_down`, `internal` (see [`protocol::ErrorCode`]). Codes are
//! append-only: a code, once shipped, never changes meaning. After a
//! `bad_request`, `not_found`, `artifact` or `invalid` error the
//! connection stays usable; framing-level errors close it.

pub mod client;
pub mod protocol;
pub mod registry;
pub mod server;

pub use client::{Client, WorkloadConfig, WorkloadReport};
pub use protocol::{ErrorCode, FrameError, Request, Response};
pub use registry::{ArtifactOpenError, Registry};
pub use server::{ServeConfig, Server, ServerHandle};

use crate::query::QueryError;

/// Errors of the serving layer — wraps transport failures, typed remote
/// errors, and the query layer's own failures.
#[derive(Debug)]
pub enum ServeError {
    /// Socket-level failure.
    Io(std::io::Error),
    /// The peer violated the framing or JSON contract.
    Protocol(String),
    /// The server answered with a typed `error` frame.
    Remote { code: ErrorCode, message: String },
    /// Admission control shed this connection.
    Busy,
    /// Unknown artifact id (or ambiguous default routing).
    NotFound(String),
    /// An artifact failed to open or answer.
    Artifact(String),
    /// A query-layer failure while answering locally.
    Query(QueryError),
}

impl ServeError {
    /// The [`ErrorCode`] this error maps to on the wire.
    pub fn code(&self) -> ErrorCode {
        match self {
            ServeError::Io(_) => ErrorCode::Io,
            ServeError::Protocol(_) => ErrorCode::BadFrame,
            ServeError::Remote { code, .. } => *code,
            ServeError::Busy => ErrorCode::Internal, // busy is its own frame type
            ServeError::NotFound(_) => ErrorCode::NotFound,
            ServeError::Artifact(_) => ErrorCode::Artifact,
            ServeError::Query(QueryError::Io(_)) => ErrorCode::Io,
            ServeError::Query(QueryError::Artifact(_)) => ErrorCode::Artifact,
            ServeError::Query(QueryError::Invalid(_)) => ErrorCode::Invalid,
        }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Io(e) => write!(f, "serve io error: {e}"),
            ServeError::Protocol(m) => write!(f, "protocol error: {m}"),
            ServeError::Remote { code, message } => write!(f, "server error [{code}]: {message}"),
            ServeError::Busy => write!(f, "server busy: connection shed by admission control"),
            ServeError::NotFound(m) => write!(f, "not found: {m}"),
            ServeError::Artifact(m) => write!(f, "artifact error: {m}"),
            ServeError::Query(e) => write!(f, "query error: {e}"),
        }
    }
}

impl std::error::Error for ServeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ServeError::Io(e) => Some(e),
            ServeError::Query(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for ServeError {
    fn from(e: std::io::Error) -> Self {
        ServeError::Io(e)
    }
}

impl From<QueryError> for ServeError {
    fn from(e: QueryError) -> Self {
        ServeError::Query(e)
    }
}

impl From<FrameError> for ServeError {
    fn from(e: FrameError) -> Self {
        match e {
            FrameError::Io(io) => ServeError::Io(io),
            other => ServeError::Protocol(other.to_string()),
        }
    }
}

impl From<ArtifactOpenError> for ServeError {
    fn from(e: ArtifactOpenError) -> Self {
        ServeError::Artifact(e.to_string())
    }
}
