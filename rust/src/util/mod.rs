//! Sequence utilities — the C++ library's "broad array of additional
//! utility functions allowing fast operations on the sequences".
//!
//! Everything here operates on `&[SeqRecord]` slices, exploiting the
//! `(seq, pid)` sort order the sparsity screen leaves behind where
//! possible. The paper calls out, specifically:
//!
//! * extraction by **start phenX**, **end phenX**, and **minimum
//!   duration** ([`filter_by_start`], [`filter_by_end`],
//!   [`filter_min_duration`]);
//! * the composed *transitive end-set* operation used by the Post-COVID
//!   vignette: "extract all sequences that end with a phenX which is an
//!   end phenX of all sequences with a given start phenX"
//!   ([`transitive_end_sequences`]);
//! * duration bucketing for the correlation step ([`duration_bucket`],
//!   [`bucket_counts`]).

use crate::dbmart::{decode_seq, encode_seq};
use crate::mining::SeqRecord;
use std::collections::BTreeSet;

/// All records whose sequence starts with `start`.
///
/// On `(seq, pid)`-sorted input this is a binary-search range slice;
/// unsorted input is handled by a linear fallback.
pub fn filter_by_start(records: &[SeqRecord], start: u32) -> Vec<SeqRecord> {
    let lo_key = encode_seq(start, 0);
    let hi_key = encode_seq(start, crate::dbmart::MAX_PHENX - 1);
    if is_seq_sorted(records) {
        let lo = records.partition_point(|r| r.seq < lo_key);
        let hi = records.partition_point(|r| r.seq <= hi_key);
        records[lo..hi].to_vec()
    } else {
        records.iter().filter(|r| decode_seq(r.seq).0 == start).copied().collect()
    }
}

/// All records whose sequence ends with `end`.
pub fn filter_by_end(records: &[SeqRecord], end: u32) -> Vec<SeqRecord> {
    records.iter().filter(|r| decode_seq(r.seq).1 == end).copied().collect()
}

/// All records with duration ≥ `min_duration`.
pub fn filter_min_duration(records: &[SeqRecord], min_duration: u32) -> Vec<SeqRecord> {
    records.iter().filter(|r| r.duration >= min_duration).copied().collect()
}

/// Distinct end phenX of all sequences starting with `start`.
pub fn end_set_of(records: &[SeqRecord], start: u32) -> BTreeSet<u32> {
    filter_by_start(records, start).iter().map(|r| decode_seq(r.seq).1).collect()
}

/// The paper's composed utility: all sequences that **end** with any
/// phenX that is an end phenX of at least one sequence **starting** with
/// `start` (used to pull every candidate trajectory downstream of a
/// COVID infection).
pub fn transitive_end_sequences(records: &[SeqRecord], start: u32) -> Vec<SeqRecord> {
    let ends = end_set_of(records, start);
    records.iter().filter(|r| ends.contains(&decode_seq(r.seq).1)).copied().collect()
}

/// Records of one patient.
pub fn filter_by_patient(records: &[SeqRecord], pid: u32) -> Vec<SeqRecord> {
    records.iter().filter(|r| r.pid == pid).copied().collect()
}

/// Duration bucket index for bucket width `width` (in duration units).
#[inline]
pub fn duration_bucket(duration: u32, width: u32) -> u32 {
    duration / width.max(1)
}

/// Histogram of duration buckets for the given records.
pub fn bucket_counts(records: &[SeqRecord], width: u32) -> std::collections::BTreeMap<u32, u64> {
    let mut out = std::collections::BTreeMap::new();
    for r in records {
        *out.entry(duration_bucket(r.duration, width)).or_insert(0) += 1;
    }
    out
}

/// Distinct patients among the records.
pub fn distinct_patients(records: &[SeqRecord]) -> BTreeSet<u32> {
    records.iter().map(|r| r.pid).collect()
}

/// Distinct sequence ids among the records.
pub fn distinct_sequences(records: &[SeqRecord]) -> BTreeSet<u64> {
    records.iter().map(|r| r.seq).collect()
}

/// Per-patient span (max − min duration) of a specific sequence id —
/// the Post-COVID vignette's "maximal difference of the duration of the
/// sequences with the same end phenX" primitive, generalised.
pub fn duration_span_per_patient(
    records: &[SeqRecord],
    seq: u64,
) -> std::collections::BTreeMap<u32, u32> {
    let mut minmax: std::collections::BTreeMap<u32, (u32, u32)> = Default::default();
    for r in records.iter().filter(|r| r.seq == seq) {
        let e = minmax.entry(r.pid).or_insert((r.duration, r.duration));
        e.0 = e.0.min(r.duration);
        e.1 = e.1.max(r.duration);
    }
    minmax.into_iter().map(|(p, (lo, hi))| (p, hi - lo)).collect()
}

/// All records for the exact `(start, end)` pair.
pub fn filter_by_pair(records: &[SeqRecord], start: u32, end: u32) -> Vec<SeqRecord> {
    let target = encode_seq(start, end);
    if is_seq_sorted(records) {
        let lo = records.partition_point(|r| r.seq < target);
        let hi = records.partition_point(|r| r.seq <= target);
        records[lo..hi].to_vec()
    } else {
        records.iter().filter(|r| r.seq == target).copied().collect()
    }
}

/// The `k` most frequent sequences by record count, descending
/// (ties broken by sequence id for determinism).
pub fn top_k_sequences(records: &[SeqRecord], k: usize) -> Vec<(u64, u64)> {
    let mut counts: std::collections::HashMap<u64, u64> = Default::default();
    for r in records {
        *counts.entry(r.seq).or_insert(0) += 1;
    }
    let mut pairs: Vec<(u64, u64)> = counts.into_iter().collect();
    pairs.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    pairs.truncate(k);
    pairs
}

/// Per-patient record counts (dense, indexed by pid).
pub fn records_per_patient(records: &[SeqRecord], num_patients: u32) -> Vec<u64> {
    let mut out = vec![0u64; num_patients as usize];
    for r in records {
        out[r.pid as usize] += 1;
    }
    out
}

/// Summary statistics of the duration distribution of a record set.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DurationStats {
    pub min: u32,
    pub max: u32,
    pub mean: f64,
    pub count: u64,
}

/// Duration summary over the given records (`None` when empty).
pub fn duration_stats(records: &[SeqRecord]) -> Option<DurationStats> {
    if records.is_empty() {
        return None;
    }
    let mut s = DurationStats { min: u32::MAX, max: 0, mean: 0.0, count: records.len() as u64 };
    let mut sum = 0u64;
    for r in records {
        s.min = s.min.min(r.duration);
        s.max = s.max.max(r.duration);
        sum += r.duration as u64;
    }
    s.mean = sum as f64 / s.count as f64;
    Some(s)
}

fn is_seq_sorted(records: &[SeqRecord]) -> bool {
    records.windows(2).all(|w| w[0].seq <= w[1].seq)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(start: u32, end: u32, pid: u32, duration: u32) -> SeqRecord {
        SeqRecord { seq: encode_seq(start, end), pid, duration }
    }

    fn sample() -> Vec<SeqRecord> {
        let mut v = vec![
            rec(1, 2, 0, 10),
            rec(1, 3, 0, 90),
            rec(1, 3, 1, 30),
            rec(2, 3, 1, 5),
            rec(4, 2, 2, 61),
            rec(5, 3, 0, 100),
        ];
        v.sort_unstable_by_key(|r| (r.seq, r.pid));
        v
    }

    #[test]
    fn start_filter_sorted_and_unsorted_agree() {
        let sorted = sample();
        let mut unsorted = sorted.clone();
        unsorted.swap(0, 5);
        let mut a = filter_by_start(&sorted, 1);
        let mut b = filter_by_start(&unsorted, 1);
        a.sort_unstable_by_key(|r| (r.seq, r.pid));
        b.sort_unstable_by_key(|r| (r.seq, r.pid));
        assert_eq!(a, b);
        assert_eq!(a.len(), 3);
    }

    #[test]
    fn end_filter() {
        let got = filter_by_end(&sample(), 3);
        assert_eq!(got.len(), 4);
        assert!(got.iter().all(|r| decode_seq(r.seq).1 == 3));
    }

    #[test]
    fn min_duration_filter() {
        let got = filter_min_duration(&sample(), 61);
        assert_eq!(got.len(), 3);
    }

    #[test]
    fn end_set() {
        let ends = end_set_of(&sample(), 1);
        assert_eq!(ends, BTreeSet::from([2, 3]));
    }

    #[test]
    fn transitive_end_sequences_matches_paper_description() {
        // starts with 1 → ends {2, 3}; sequences ending in 2 or 3:
        // (1,2),(1,3),(1,3),(2,3),(4,2),(5,3) = all 6 here.
        let got = transitive_end_sequences(&sample(), 1);
        assert_eq!(got.len(), 6);
        // starts with 4 → ends {2}; sequences ending in 2: (1,2),(4,2).
        let got = transitive_end_sequences(&sample(), 4);
        assert_eq!(got.len(), 2);
    }

    #[test]
    fn empty_start_yields_empty() {
        assert!(filter_by_start(&sample(), 99).is_empty());
        assert!(transitive_end_sequences(&sample(), 99).is_empty());
    }

    #[test]
    fn duration_buckets() {
        assert_eq!(duration_bucket(0, 30), 0);
        assert_eq!(duration_bucket(29, 30), 0);
        assert_eq!(duration_bucket(30, 30), 1);
        assert_eq!(duration_bucket(100, 0), 100); // width clamps to 1
        let counts = bucket_counts(&sample(), 50);
        assert_eq!(counts.get(&0), Some(&3)); // 10, 30, 5
        assert_eq!(counts.get(&1), Some(&2)); // 90, 61
        assert_eq!(counts.get(&2), Some(&1)); // 100
    }

    #[test]
    fn span_per_patient() {
        let spans = duration_span_per_patient(&sample(), encode_seq(1, 3));
        assert_eq!(spans.get(&0), Some(&0)); // single occurrence (90)
        assert_eq!(spans.get(&1), Some(&0)); // single occurrence (30)
        let mut recs = sample();
        recs.push(rec(1, 3, 0, 20));
        let spans = duration_span_per_patient(&recs, encode_seq(1, 3));
        assert_eq!(spans.get(&0), Some(&70)); // 90 − 20
    }

    #[test]
    fn pair_filter_sorted_and_unsorted() {
        let sorted = sample();
        let mut shuffled = sorted.clone();
        shuffled.reverse();
        let a = filter_by_pair(&sorted, 1, 3);
        let mut b = filter_by_pair(&shuffled, 1, 3);
        b.sort_unstable_by_key(|r| (r.seq, r.pid));
        assert_eq!(a.len(), 2);
        assert_eq!(a, b);
        assert!(filter_by_pair(&sorted, 9, 9).is_empty());
    }

    #[test]
    fn top_k_orders_by_count_then_id() {
        let recs = sample(); // (1,3) appears twice, others once
        let top = top_k_sequences(&recs, 2);
        assert_eq!(top[0], (encode_seq(1, 3), 2));
        assert_eq!(top[1].1, 1);
        assert_eq!(top_k_sequences(&recs, 100).len(), 5);
        assert!(top_k_sequences(&[], 3).is_empty());
    }

    #[test]
    fn per_patient_counts() {
        let counts = records_per_patient(&sample(), 4);
        assert_eq!(counts, vec![3, 2, 1, 0]);
    }

    #[test]
    fn duration_summary() {
        let s = duration_stats(&sample()).unwrap();
        assert_eq!(s.min, 5);
        assert_eq!(s.max, 100);
        assert_eq!(s.count, 6);
        assert!((s.mean - (10 + 90 + 30 + 5 + 61 + 100) as f64 / 6.0).abs() < 1e-9);
        assert_eq!(duration_stats(&[]), None);
    }

    #[test]
    fn distinct_helpers() {
        assert_eq!(distinct_patients(&sample()), BTreeSet::from([0, 1, 2]));
        assert_eq!(distinct_sequences(&sample()).len(), 5);
    }
}
