//! Instrumentation substrate: phase timers and memory accounting.
//!
//! The paper's benchmark protocol measures, per run, the **total wall-clock
//! runtime**, the **peak memory consumption** (via GNU `time`), and a
//! per-phase breakdown (data loading, sequencing, sparsity screening).
//! This module reproduces that protocol in-process:
//!
//! * [`PhaseTimer`] — named phase measurements with a formatted report,
//! * [`peak_rss_bytes`] — the process high-water-mark RSS from
//!   `/proc/self/status` (`VmHWM`), falling back to `getrusage(2)`;
//!   `None` (rendered "unavailable", never a misleading `0 B`) when no
//!   probe works on the platform,
//! * [`current_rss_bytes`] — instantaneous RSS (`VmRSS`), same contract,
//! * [`MemTracker`] — byte-accurate logical accounting of the engine's own
//!   major allocations (what the paper reports as the algorithm's memory),
//!   useful on machines where RSS is polluted by the allocator or runtime.

use std::time::{Duration, Instant};

/// Minimal hand-rolled `getrusage(2)` FFI. The crate is deliberately
/// dependency-free, so the usual `libc` crate is not available; only
/// the one call and the fields the fallback reads are declared. Layout
/// matches the LP64 Unix `struct rusage` (two `timeval`s, then 14
/// longs, `ru_maxrss` first among them).
#[cfg(unix)]
mod libc {
    #[allow(dead_code)]
    #[repr(C)]
    pub struct Timeval {
        pub tv_sec: i64,
        pub tv_usec: i64,
    }

    // Named after the C type it mirrors; the padding fields exist only
    // to make the layout exact and are never read.
    #[allow(non_camel_case_types, dead_code)]
    #[repr(C)]
    pub struct rusage {
        pub ru_utime: Timeval,
        pub ru_stime: Timeval,
        pub ru_maxrss: i64,
        pub ru_ixrss: i64,
        pub ru_idrss: i64,
        pub ru_isrss: i64,
        pub ru_minflt: i64,
        pub ru_majflt: i64,
        pub ru_nswap: i64,
        pub ru_inblock: i64,
        pub ru_oublock: i64,
        pub ru_msgsnd: i64,
        pub ru_msgrcv: i64,
        pub ru_nsignals: i64,
        pub ru_nvcsw: i64,
        pub ru_nivcsw: i64,
    }

    pub const RUSAGE_SELF: i32 = 0;

    extern "C" {
        pub fn getrusage(who: i32, usage: *mut rusage) -> i32;
    }
}

/// High-water-mark RSS of this process in bytes.
///
/// Reads `VmHWM` from `/proc/self/status`; falls back to
/// `getrusage(RUSAGE_SELF).ru_maxrss` (kilobytes on Linux). `None` when
/// neither probe works — callers must render "unavailable" rather than
/// treating the old `0` sentinel as a real measurement.
pub fn peak_rss_bytes() -> Option<u64> {
    if let Some(v) = read_status_kb("VmHWM:") {
        return Some(v * 1024);
    }
    #[cfg(unix)]
    // SAFETY: `usage` is a live, properly aligned out-parameter;
    // all-zero bytes are a valid `rusage` (plain old C data), and
    // getrusage(2) writes only within the struct it is handed.
    // `ru_maxrss` is read only after the call reports success.
    unsafe {
        let mut usage: libc::rusage = std::mem::zeroed();
        if libc::getrusage(libc::RUSAGE_SELF, &mut usage) == 0 && usage.ru_maxrss > 0 {
            return Some((usage.ru_maxrss as u64) * 1024);
        }
    }
    None
}

/// Instantaneous RSS of this process in bytes (`VmRSS`), `None` if the
/// `/proc` probe is unavailable on the platform.
pub fn current_rss_bytes() -> Option<u64> {
    read_status_kb("VmRSS:").map(|v| v * 1024)
}

fn read_status_kb(field: &str) -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix(field) {
            let kb: u64 = rest.trim().trim_end_matches(" kB").trim().parse().ok()?;
            return Some(kb);
        }
    }
    None
}

/// Render an optional probe reading: the measurement when available,
/// the word `unavailable` otherwise — never a misleading `0 B`.
pub fn fmt_opt_bytes(bytes: Option<u64>) -> String {
    match bytes {
        Some(b) => fmt_bytes(b),
        None => "unavailable".to_string(),
    }
}

/// Format a byte count as a human-readable string (GiB/MiB/KiB/B).
pub fn fmt_bytes(bytes: u64) -> String {
    const GIB: f64 = (1u64 << 30) as f64;
    const MIB: f64 = (1u64 << 20) as f64;
    const KIB: f64 = (1u64 << 10) as f64;
    let b = bytes as f64;
    if b >= GIB {
        format!("{:.2} GiB", b / GIB)
    } else if b >= MIB {
        format!("{:.2} MiB", b / MIB)
    } else if b >= KIB {
        format!("{:.2} KiB", b / KIB)
    } else {
        format!("{bytes} B")
    }
}

/// Format a duration as `hh:mm:ss.mmm` (the paper prints `hh:mm:ss`).
pub fn fmt_duration(d: Duration) -> String {
    let total_ms = d.as_millis();
    let ms = total_ms % 1000;
    let s = (total_ms / 1000) % 60;
    let m = (total_ms / 60_000) % 60;
    let h = total_ms / 3_600_000;
    format!("{h:02}:{m:02}:{s:02}.{ms:03}")
}

/// A single recorded phase.
#[derive(Clone, Debug)]
pub struct Phase {
    pub name: String,
    pub elapsed: Duration,
    /// RSS delta across the phase (can be negative when memory is freed).
    pub rss_delta: i64,
}

/// Named phase timer producing the paper-style per-phase breakdown
/// (load / encode / sort / sequence / screen ...).
#[derive(Default)]
pub struct PhaseTimer {
    phases: Vec<Phase>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Run `f` as the named phase, recording wall time and RSS delta.
    pub fn run<R>(&mut self, name: &str, f: impl FnOnce() -> R) -> R {
        // A delta of two unavailable probes degrades to 0, which the
        // report prints as "+0 B" — acceptable for the per-phase
        // breakdown; absolute readings go through [`fmt_opt_bytes`].
        let rss_before = current_rss_bytes().unwrap_or(0) as i64;
        let start = Instant::now();
        let out = f();
        let elapsed = start.elapsed();
        let rss_after = current_rss_bytes().unwrap_or(0) as i64;
        self.phases.push(Phase {
            name: name.to_string(),
            elapsed,
            rss_delta: rss_after - rss_before,
        });
        out
    }

    pub fn phases(&self) -> &[Phase] {
        &self.phases
    }

    pub fn total(&self) -> Duration {
        self.phases.iter().map(|p| p.elapsed).sum()
    }

    /// Elapsed time of a phase by name (first match).
    pub fn elapsed(&self, name: &str) -> Option<Duration> {
        self.phases.iter().find(|p| p.name == name).map(|p| p.elapsed)
    }

    /// Multi-line report of all phases plus total.
    pub fn report(&self) -> String {
        let mut out = String::new();
        let width = self.phases.iter().map(|p| p.name.len()).max().unwrap_or(5).max(5);
        for p in &self.phases {
            let sign = if p.rss_delta >= 0 { "+" } else { "-" };
            out.push_str(&format!(
                "  {:<width$}  {}  (rss {}{})\n",
                p.name,
                fmt_duration(p.elapsed),
                sign,
                fmt_bytes(p.rss_delta.unsigned_abs()),
                width = width
            ));
        }
        out.push_str(&format!(
            "  {:<width$}  {}\n",
            "TOTAL",
            fmt_duration(self.total()),
            width = width
        ));
        out
    }
}

/// Logical memory accounting for the engine's own major buffers.
///
/// RSS on a shared box includes the allocator's retained pages, the PJRT
/// runtime, etc.; the paper's memory numbers are effectively "bytes the
/// algorithm holds live". `MemTracker` counts exactly that: modules call
/// [`MemTracker::add`]/[`MemTracker::sub`] around their big allocations and
/// the high-water mark is reported next to RSS.
#[derive(Default, Debug)]
pub struct MemTracker {
    live: std::sync::atomic::AtomicU64,
    peak: std::sync::atomic::AtomicU64,
}

impl MemTracker {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&self, bytes: u64) {
        use std::sync::atomic::Ordering;
        let now = self.live.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.peak.fetch_max(now, Ordering::Relaxed);
    }

    pub fn sub(&self, bytes: u64) {
        use std::sync::atomic::Ordering;
        // Saturate, never wrap: a mismatched add/sub pair must not send
        // `live` to ~u64::MAX and poison every later peak. The counter
        // is updated *before* the debug assertion so the accounting is
        // already consistent if the assertion unwinds.
        let mut underflow = false;
        let _ = self.live.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |live| {
            underflow = live < bytes;
            Some(live.saturating_sub(bytes))
        });
        debug_assert!(
            !underflow,
            "MemTracker::sub({bytes}) exceeds live bytes — mismatched add/sub pair"
        );
    }

    pub fn live(&self) -> u64 {
        self.live.load(std::sync::atomic::Ordering::Relaxed)
    }

    pub fn peak(&self) -> u64 {
        self.peak.load(std::sync::atomic::Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The probes read /proc/self/status; only Linux guarantees them.
    #[cfg(target_os = "linux")]
    #[test]
    fn peak_rss_positive_on_linux() {
        let peak = peak_rss_bytes().expect("VmHWM readable on Linux");
        let current = current_rss_bytes().expect("VmRSS readable on Linux");
        assert!(peak > 0);
        assert!(current > 0);
        assert!(peak >= current / 2);
    }

    #[test]
    fn opt_bytes_renders_unavailable() {
        assert_eq!(fmt_opt_bytes(None), "unavailable");
        assert_eq!(fmt_opt_bytes(Some(512)), "512 B");
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 << 20), "3.00 MiB");
        assert_eq!(fmt_bytes(5 << 30), "5.00 GiB");
    }

    #[test]
    fn fmt_duration_fields() {
        let d = Duration::from_millis(3_600_000 + 23 * 60_000 + 45_000 + 678);
        assert_eq!(fmt_duration(d), "01:23:45.678");
        assert_eq!(fmt_duration(Duration::from_millis(14)), "00:00:00.014");
    }

    #[test]
    fn phase_timer_records_in_order() {
        let mut t = PhaseTimer::new();
        let v = t.run("load", || 40);
        let w = t.run("mine", || 2);
        assert_eq!(v + w, 42);
        assert_eq!(t.phases().len(), 2);
        assert_eq!(t.phases()[0].name, "load");
        assert_eq!(t.phases()[1].name, "mine");
        assert!(t.elapsed("load").is_some());
        assert!(t.elapsed("nope").is_none());
        assert!(t.report().contains("TOTAL"));
    }

    #[test]
    fn mem_tracker_high_water() {
        let m = MemTracker::new();
        m.add(100);
        m.add(50);
        m.sub(120);
        m.add(10);
        assert_eq!(m.live(), 40);
        assert_eq!(m.peak(), 150);
    }

    /// Regression: a mismatched sub used to wrap `live` to ~u64::MAX,
    /// poisoning every later peak. It now saturates to 0 (flagged by a
    /// debug assertion) and subsequent accounting stays sane.
    #[test]
    fn mem_tracker_sub_underflow_saturates() {
        let m = MemTracker::new();
        m.add(10);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| m.sub(25)));
        if cfg!(debug_assertions) {
            assert!(result.is_err(), "debug builds flag the mismatched pair");
        } else {
            assert!(result.is_ok(), "release builds saturate silently");
        }
        assert_eq!(m.live(), 0, "saturated, not wrapped");
        m.add(7);
        assert_eq!(m.live(), 7);
        assert_eq!(m.peak(), 10, "peak survives the bad sub");
    }
}
