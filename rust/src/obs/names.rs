//! The exposition names — the append-only metric-name contract.
//!
//! Every metric the crate exposes is named by a constant here, and
//! nowhere else: instrumentation sites pass these constants to
//! [`crate::obs::metrics::MetricsRegistry`], and `cargo xtask lint`
//! parses this file, enforces the `[a-z][a-z0-9_]*` naming rule, and
//! diffs the list against `xtask/snapshots/metrics.txt` with the same
//! append-only discipline as the wire-protocol snapshot. Renaming or
//! removing a constant breaks scrapers and fails the lint; append new
//! names at the end and re-bless with `cargo xtask lint --bless`.

/// Result-cache lookups that hit, process-wide across every cache.
pub const CACHE_HITS: &str = "tspm_cache_hits";
/// Result-cache lookups that missed.
pub const CACHE_MISSES: &str = "tspm_cache_misses";
/// Total result-cache lookups; a scrape always sees
/// `tspm_cache_hits + tspm_cache_misses == tspm_cache_lookups` because
/// all three are rendered from one locked snapshot.
pub const CACHE_LOOKUPS: &str = "tspm_cache_lookups";
/// Entries evicted from result caches to respect their byte budgets.
pub const CACHE_EVICTIONS: &str = "tspm_cache_evictions";
/// Index blocks scanned by `QueryService` (the single IO choke point).
pub const QUERY_BLOCK_READS: &str = "tspm_query_block_reads";
/// Logical bytes those block scans read.
pub const QUERY_BYTES_READ: &str = "tspm_query_bytes_read";
/// Mining shards dynamically claimed by workers.
pub const MINE_SHARDS_CLAIMED: &str = "tspm_mine_shards_claimed";
/// Mining shards merged (in stable shard order) into the output.
pub const MINE_SHARDS_MERGED: &str = "tspm_mine_shards_merged";
/// Sorted spill runs opened by `screen_spilled`'s external merge.
pub const SCREEN_SPILL_RUNS_OPENED: &str = "tspm_screen_spill_runs_opened";
/// Bytes streamed through `screen_spilled` merge passes.
pub const SCREEN_SPILL_BYTES_MERGED: &str = "tspm_screen_spill_bytes_merged";
/// Merge passes (fan-in reductions) `screen_spilled` performed.
pub const SCREEN_SPILL_MERGE_PASSES: &str = "tspm_screen_spill_merge_passes";
/// Segments committed to segment sets by incremental ingest.
pub const INGEST_SEGMENTS_COMMITTED: &str = "tspm_ingest_segments_committed";
/// Compactions run over segment sets.
pub const COMPACT_RUNS: &str = "tspm_compact_runs";
/// Segments folded away by those compactions (the fan-in).
pub const COMPACT_SEGMENTS_FOLDED: &str = "tspm_compact_segments_folded";
/// Requests the serve daemon answered (any outcome).
pub const SERVE_REQUESTS: &str = "tspm_serve_requests";
/// Connections shed by admission control with a typed `busy` frame.
pub const SERVE_SHED: &str = "tspm_serve_shed";
/// Connections admitted and served to completion.
pub const SERVE_CONNS: &str = "tspm_serve_conns";
/// Request service time in microseconds (fixed-bucket histogram).
pub const SERVE_REQUEST_DURATION_US: &str = "tspm_serve_request_duration_us";
/// Engine stage wall time in microseconds (fixed-bucket histogram).
pub const ENGINE_STAGE_DURATION_US: &str = "tspm_engine_stage_duration_us";
/// Live logical bytes tracked by the engine's `MemTracker` view.
pub const MEM_LIVE_BYTES: &str = "tspm_mem_live_bytes";
/// Peak logical bytes tracked by the engine's `MemTracker` view.
pub const MEM_PEAK_BYTES: &str = "tspm_mem_peak_bytes";
/// Process high-water-mark RSS, when the platform probe is available.
pub const PROCESS_PEAK_RSS_BYTES: &str = "tspm_process_peak_rss_bytes";
/// Process instantaneous RSS, when the platform probe is available.
pub const PROCESS_CURRENT_RSS_BYTES: &str = "tspm_process_current_rss_bytes";
