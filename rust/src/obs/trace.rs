//! Structured tracing: spans, trace IDs, sinks, and the injectable
//! clock.
//!
//! A [`Span`] measures one named operation. Finishing (or dropping) it
//! emits a single JSONL line through the tracer's [`TraceSink`]:
//!
//! ```text
//! {"attrs":{"kind":"top_k"},"dur_us":181,"name":"serve.request",
//!  "parent":3,"span":4,"start_us":91422,"trace":"00…0a7f"}
//! ```
//!
//! Design constraints, in order:
//!
//! * **Determinism under observation.** Spans read an injectable
//!   monotonic [`Clock`] (an `Instant` anchor by default, a
//!   [`ManualClock`] in tests) — never `SystemTime::now` — so the
//!   deterministic-output modules can be instrumented without tripping
//!   `cargo xtask lint`, and tracing cannot perturb any data-path byte:
//!   the JSONL stream goes to stderr or a side file, never stdout.
//! * **Free when off.** A disabled tracer still times spans (the engine
//!   feeds `RunReport` from them), but allocates no strings and emits
//!   nothing.
//! * **Cross-process propagation.** [`TraceId`] round-trips as a hex
//!   string; the serve protocol carries it as an optional `trace_id`
//!   request field so server-side spans join the client's trace.
//! * **Slow-query log.** A span marked
//!   [`slow_eligible`](Span::mark_slow_eligible) whose duration crosses
//!   the tracer's threshold is dumped (with `"slow":true`) to the slow
//!   sink even when tracing is otherwise disabled.
//!
//! Spans also propagate *within* a thread without API churn:
//! [`push_current`] installs a span as the thread's ambient parent and
//! [`current_span`] opens a child of it from anywhere downstream (the
//! query cache and block scanner use this, so a served request's trace
//! shows its cache lookups and block reads without threading a span
//! through every signature).

use crate::json::Json;
use std::cell::RefCell;
use std::io::Write;
use std::path::Path;
// std::sync deliberately, not the crate::sync shim: the tracer holds
// `Arc<dyn TraceSink>` trait objects (unsized coercion, which loom's
// Arc does not model) and is not one of the loom-checked protocols —
// the metrics registry is the loom-facing pillar.
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Trace IDs
// ---------------------------------------------------------------------------

/// A 128-bit trace identifier, wire-encoded as 32 lowercase hex chars.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct TraceId(pub u128);

impl TraceId {
    /// The zero id — used by disabled tracers, never emitted.
    pub const NONE: TraceId = TraceId(0);

    pub fn to_hex(self) -> String {
        format!("{:032x}", self.0)
    }

    /// Parse 1–32 hex chars (client-supplied ids may be short).
    pub fn from_hex(s: &str) -> Option<TraceId> {
        if s.is_empty() || s.len() > 32 || !s.bytes().all(|b| b.is_ascii_hexdigit()) {
            return None;
        }
        u128::from_str_radix(s, 16).ok().map(TraceId)
    }

    /// A fresh id: wall-clock nanos mixed with the process id and a
    /// process-local counter (collision-resistant, not cryptographic).
    pub fn generate() -> TraceId {
        static COUNTER: AtomicU64 = AtomicU64::new(0);
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos())
            .unwrap_or(0);
        let count = COUNTER.fetch_add(1, Ordering::Relaxed);
        let mixed = (count ^ u64::from(std::process::id()))
            .wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let id = nanos ^ (u128::from(mixed) << 64) ^ u128::from(mixed);
        TraceId(if id == 0 { 1 } else { id })
    }
}

// ---------------------------------------------------------------------------
// Clock
// ---------------------------------------------------------------------------

/// Monotonic time source for span timing. Implementations must be
/// monotonic per instance; absolute epoch is irrelevant (only offsets
/// and durations are emitted).
pub trait Clock: Send + Sync {
    fn now_micros(&self) -> u64;
}

/// The production clock: microseconds since the clock was created,
/// from a monotonic [`Instant`] anchor.
pub struct MonotonicClock {
    origin: Instant,
}

impl MonotonicClock {
    pub fn new() -> MonotonicClock {
        MonotonicClock { origin: Instant::now() }
    }
}

impl Default for MonotonicClock {
    fn default() -> Self {
        MonotonicClock::new()
    }
}

impl Clock for MonotonicClock {
    fn now_micros(&self) -> u64 {
        u64::try_from(self.origin.elapsed().as_micros()).unwrap_or(u64::MAX)
    }
}

/// A hand-cranked clock for deterministic tests.
#[derive(Default)]
pub struct ManualClock {
    now: AtomicU64,
}

impl ManualClock {
    pub fn new() -> ManualClock {
        ManualClock::default()
    }

    pub fn advance_micros(&self, us: u64) {
        self.now.fetch_add(us, Ordering::Relaxed);
    }
}

impl Clock for ManualClock {
    fn now_micros(&self) -> u64 {
        self.now.load(Ordering::Relaxed)
    }
}

// ---------------------------------------------------------------------------
// Sinks
// ---------------------------------------------------------------------------

/// Receives finished spans as single JSONL lines. Implementations must
/// tolerate concurrent `emit` calls and never panic on IO failure —
/// observability must not take the process down.
pub trait TraceSink: Send + Sync {
    fn emit(&self, line: &str);
}

/// Emits to stderr, one line per span, never stdout (stdout carries
/// query answers and must stay byte-identical with tracing on or off).
pub struct StderrSink;

impl TraceSink for StderrSink {
    fn emit(&self, line: &str) {
        let mut err = std::io::stderr().lock();
        let _ = writeln!(err, "{line}");
    }
}

/// Appends to a file, creating it on first use.
pub struct FileSink {
    file: Mutex<std::fs::File>,
}

impl FileSink {
    pub fn create(path: &Path) -> std::io::Result<FileSink> {
        let file = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        Ok(FileSink { file: Mutex::new(file) })
    }
}

impl TraceSink for FileSink {
    fn emit(&self, line: &str) {
        if let Ok(mut f) = self.file.lock() {
            let _ = writeln!(f, "{line}");
            let _ = f.flush();
        }
    }
}

/// Collects lines in memory — the test sink.
#[derive(Default)]
pub struct MemorySink {
    lines: Mutex<Vec<String>>,
}

impl MemorySink {
    pub fn new() -> MemorySink {
        MemorySink::default()
    }

    pub fn lines(&self) -> Vec<String> {
        self.lines.lock().map(|l| l.clone()).unwrap_or_default()
    }
}

impl TraceSink for MemorySink {
    fn emit(&self, line: &str) {
        if let Ok(mut l) = self.lines.lock() {
            l.push(line.to_string());
        }
    }
}

// ---------------------------------------------------------------------------
// Tracer
// ---------------------------------------------------------------------------

struct TracerInner {
    sink: Option<Arc<dyn TraceSink>>,
    /// Where threshold-crossing spans go when tracing is off (and
    /// additionally when it is on). Stderr unless overridden.
    slow_sink: Arc<dyn TraceSink>,
    /// Slow-span threshold in µs; 0 disables the slow-query log.
    slow_threshold_us: AtomicU64,
    clock: Arc<dyn Clock>,
    next_span: AtomicU64,
}

/// Cheap-to-clone handle (one `Arc`) owning the sink, clock, and span
/// id allocator. All spans from clones of one tracer share an id space.
#[derive(Clone)]
pub struct Tracer {
    inner: Arc<TracerInner>,
}

impl std::fmt::Debug for Tracer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Tracer")
            .field("enabled", &self.enabled())
            .field("slow_threshold_us", &self.slow_threshold_us())
            .finish()
    }
}

impl Tracer {
    /// Full constructor: optional main sink, slow-log sink, and clock.
    pub fn with_sinks(
        sink: Option<Arc<dyn TraceSink>>,
        slow_sink: Arc<dyn TraceSink>,
        clock: Arc<dyn Clock>,
    ) -> Tracer {
        Tracer {
            inner: Arc::new(TracerInner {
                sink,
                slow_sink,
                slow_threshold_us: AtomicU64::new(0),
                clock,
                next_span: AtomicU64::new(0),
            }),
        }
    }

    /// A tracer that emits `sink` with the production clock.
    pub fn new(sink: Arc<dyn TraceSink>) -> Tracer {
        Tracer::with_sinks(Some(sink), Arc::new(StderrSink), Arc::new(MonotonicClock::new()))
    }

    /// A tracer that times spans but emits nothing (unless a slow
    /// threshold is later set).
    pub fn disabled() -> Tracer {
        Tracer::with_sinks(None, Arc::new(StderrSink), Arc::new(MonotonicClock::new()))
    }

    /// Build from the environment: `TSPM_TRACE` unset/`0` → disabled,
    /// `1`/`stderr` → stderr JSONL, anything else → append to that file
    /// (falling back to stderr if it cannot be opened). An optional
    /// `TSPM_SLOW_QUERY_MS` arms the slow-query log.
    pub fn from_env() -> Tracer {
        let tracer = match std::env::var("TSPM_TRACE") {
            Err(_) => Tracer::disabled(),
            Ok(v) if v.is_empty() || v == "0" => Tracer::disabled(),
            Ok(v) if v == "1" || v == "stderr" => Tracer::new(Arc::new(StderrSink)),
            Ok(path) => match FileSink::create(Path::new(&path)) {
                Ok(sink) => Tracer::new(Arc::new(sink)),
                Err(_) => Tracer::new(Arc::new(StderrSink)),
            },
        };
        if let Ok(ms) = std::env::var("TSPM_SLOW_QUERY_MS") {
            if let Ok(ms) = ms.parse::<u64>() {
                tracer.set_slow_threshold_us(ms.saturating_mul(1000));
            }
        }
        tracer
    }

    /// Whether spans are emitted to the main sink.
    pub fn enabled(&self) -> bool {
        self.inner.sink.is_some()
    }

    pub fn set_slow_threshold_us(&self, us: u64) {
        self.inner.slow_threshold_us.store(us, Ordering::Relaxed);
    }

    /// Read the tracer's clock — for intervals that start before a
    /// span (and its trace id) exists, paired with
    /// [`emit_manual`](Tracer::emit_manual).
    pub fn now_micros(&self) -> u64 {
        self.inner.clock.now_micros()
    }

    pub fn slow_threshold_us(&self) -> u64 {
        self.inner.slow_threshold_us.load(Ordering::Relaxed)
    }

    /// Anything to do at all? (Main sink or armed slow log.)
    fn active(&self) -> bool {
        self.enabled() || self.slow_threshold_us() > 0
    }

    fn next_id(&self) -> u64 {
        self.inner.next_span.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Open a root span under a fresh trace id (or [`TraceId::NONE`]
    /// when nothing would be emitted — no entropy is burned).
    pub fn span(&self, name: &'static str) -> Span {
        let trace = if self.active() { TraceId::generate() } else { TraceId::NONE };
        self.span_in(trace, name)
    }

    /// Open a root span inside an existing trace (e.g. one supplied by
    /// a client over the wire).
    pub fn span_in(&self, trace: TraceId, name: &'static str) -> Span {
        Span {
            tracer: self.clone(),
            trace,
            id: self.next_id(),
            parent: None,
            name,
            start_us: self.inner.clock.now_micros(),
            attrs: Vec::new(),
            slow_eligible: false,
            done: false,
        }
    }

    /// Emit a span whose timing was measured externally (e.g. the
    /// admission wait, observed before the request — and its trace id —
    /// existed). No-op when tracing is disabled.
    pub fn emit_manual(
        &self,
        trace: TraceId,
        parent: Option<u64>,
        name: &str,
        start_us: u64,
        dur_us: u64,
    ) {
        let Some(sink) = &self.inner.sink else { return };
        let id = self.next_id();
        let mut pairs = vec![
            ("trace", Json::Str(trace.to_hex())),
            ("span", Json::from(id)),
            ("name", Json::str(name)),
            ("start_us", Json::from(start_us)),
            ("dur_us", Json::from(dur_us)),
        ];
        if let Some(p) = parent {
            pairs.push(("parent", Json::from(p)));
        }
        sink.emit(&Json::obj(pairs).to_string_compact());
    }
}

// ---------------------------------------------------------------------------
// Spans
// ---------------------------------------------------------------------------

/// One timed operation. Emits on [`finish`](Span::finish) or drop;
/// `finish` additionally returns the measured wall time, which is how
/// the engine feeds `RunReport` from spans whether or not a sink is
/// attached.
pub struct Span {
    tracer: Tracer,
    trace: TraceId,
    id: u64,
    parent: Option<u64>,
    name: &'static str,
    start_us: u64,
    attrs: Vec<(&'static str, Json)>,
    slow_eligible: bool,
    done: bool,
}

impl Span {
    pub fn trace_id(&self) -> TraceId {
        self.trace
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Attach a key-value attribute (kept only when something will be
    /// emitted, so disabled tracing allocates nothing).
    pub fn attr(&mut self, key: &'static str, value: impl Into<Json>) {
        if self.tracer.active() {
            self.attrs.push((key, value.into()));
        }
    }

    /// Open a child span: same trace, `parent` linked to this span.
    pub fn child(&self, name: &'static str) -> Span {
        Span {
            tracer: self.tracer.clone(),
            trace: self.trace,
            id: self.tracer.next_id(),
            parent: Some(self.id),
            name,
            start_us: self.tracer.inner.clock.now_micros(),
            attrs: Vec::new(),
            slow_eligible: false,
            done: false,
        }
    }

    /// Opt this span into the slow-query log (request spans only — the
    /// gate keeps inner spans from triple-reporting one slow request).
    pub fn mark_slow_eligible(&mut self) {
        self.slow_eligible = true;
    }

    /// Finish now; returns the span's wall time.
    pub fn finish(mut self) -> Duration {
        self.record()
    }

    fn record(&mut self) -> Duration {
        self.done = true;
        let end = self.tracer.inner.clock.now_micros();
        let dur_us = end.saturating_sub(self.start_us);
        let threshold = self.tracer.slow_threshold_us();
        let slow = self.slow_eligible && threshold > 0 && dur_us >= threshold;
        if self.tracer.enabled() || slow {
            let line = self.render(dur_us, slow);
            if let Some(sink) = &self.tracer.inner.sink {
                sink.emit(&line);
            }
            if slow {
                self.tracer.inner.slow_sink.emit(&line);
            }
        }
        Duration::from_micros(dur_us)
    }

    fn render(&mut self, dur_us: u64, slow: bool) -> String {
        let mut pairs = vec![
            ("trace", Json::Str(self.trace.to_hex())),
            ("span", Json::from(self.id)),
            ("name", Json::str(self.name)),
            ("start_us", Json::from(self.start_us)),
            ("dur_us", Json::from(dur_us)),
        ];
        if let Some(p) = self.parent {
            pairs.push(("parent", Json::from(p)));
        }
        if slow {
            pairs.push(("slow", Json::from(true)));
        }
        if !self.attrs.is_empty() {
            pairs.push((
                "attrs",
                Json::Obj(
                    self.attrs.drain(..).map(|(k, v)| (k.to_string(), v)).collect(),
                ),
            ));
        }
        Json::obj(pairs).to_string_compact()
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if !self.done {
            self.record();
        }
    }
}

// ---------------------------------------------------------------------------
// Ambient (thread-local) span context
// ---------------------------------------------------------------------------

thread_local! {
    static CURRENT: RefCell<Vec<(Tracer, TraceId, u64)>> = RefCell::new(Vec::new());
}

/// Pops the ambient context it pushed when dropped.
pub struct CtxGuard {
    _priv: (),
}

impl Drop for CtxGuard {
    fn drop(&mut self) {
        CURRENT.with(|c| {
            c.borrow_mut().pop();
        });
    }
}

/// Install `span` as this thread's ambient parent until the guard
/// drops. Nesting is supported (a stack); the innermost wins.
pub fn push_current(span: &Span) -> CtxGuard {
    CURRENT.with(|c| c.borrow_mut().push((span.tracer.clone(), span.trace, span.id)));
    CtxGuard { _priv: () }
}

/// Open a child of the ambient span, if one is installed and its tracer
/// is emitting. Instrumentation deep in the query path uses this so a
/// request's trace includes cache lookups and block scans without any
/// signature changes; costs one thread-local read when tracing is off.
pub fn current_span(name: &'static str) -> Option<Span> {
    CURRENT.with(|c| {
        let stack = c.borrow();
        let (tracer, trace, parent) = stack.last()?.clone();
        if !tracer.enabled() {
            return None;
        }
        let mut span = tracer.span_in(trace, name);
        span.parent = Some(parent);
        Some(span)
    })
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    fn manual_tracer() -> (Tracer, Arc<MemorySink>, Arc<ManualClock>) {
        let sink = Arc::new(MemorySink::new());
        let clock = Arc::new(ManualClock::new());
        let tracer = Tracer::with_sinks(
            Some(sink.clone() as Arc<dyn TraceSink>),
            Arc::new(MemorySink::new()),
            clock.clone() as Arc<dyn Clock>,
        );
        (tracer, sink, clock)
    }

    #[test]
    fn trace_id_hex_round_trip() {
        let id = TraceId(0x00ab_cdef_0123_4567_89ab_cdef_0123_4567);
        assert_eq!(id.to_hex().len(), 32);
        assert_eq!(TraceId::from_hex(&id.to_hex()), Some(id));
        assert_eq!(TraceId::from_hex("ff"), Some(TraceId(255)));
        assert_eq!(TraceId::from_hex(""), None);
        assert_eq!(TraceId::from_hex("xyz"), None);
        assert_eq!(TraceId::from_hex(&"a".repeat(33)), None);
        assert_ne!(TraceId::generate(), TraceId::NONE);
        assert_ne!(TraceId::generate(), TraceId::generate());
    }

    #[test]
    fn span_emits_jsonl_with_attrs_and_duration() {
        let (tracer, sink, clock) = manual_tracer();
        let mut span = tracer.span_in(TraceId(7), "mine");
        span.attr("records", 42u64);
        clock.advance_micros(1500);
        let dur = span.finish();
        assert_eq!(dur, Duration::from_micros(1500));
        let lines = sink.lines();
        assert_eq!(lines.len(), 1);
        let v = Json::parse(&lines[0]).unwrap();
        assert_eq!(v.get("name").and_then(Json::as_str), Some("mine"));
        assert_eq!(v.get("trace").and_then(Json::as_str), Some(TraceId(7).to_hex().as_str()));
        assert_eq!(v.get("dur_us").and_then(Json::as_u64), Some(1500));
        assert_eq!(
            v.get("attrs").and_then(|a| a.get("records")).and_then(Json::as_u64),
            Some(42)
        );
        assert!(v.get("parent").is_none(), "root spans carry no parent");
    }

    #[test]
    fn child_spans_share_the_trace_and_link_the_parent() {
        let (tracer, sink, clock) = manual_tracer();
        let root = tracer.span_in(TraceId(9), "request");
        let child = root.child("route");
        clock.advance_micros(10);
        drop(child); // drop emits too
        root.finish();
        let lines = sink.lines();
        assert_eq!(lines.len(), 2);
        let child_v = Json::parse(&lines[0]).unwrap();
        let root_v = Json::parse(&lines[1]).unwrap();
        assert_eq!(child_v.get("trace"), root_v.get("trace"));
        assert_eq!(child_v.get("parent"), root_v.get("span"));
        assert_ne!(child_v.get("span"), root_v.get("span"));
    }

    #[test]
    fn disabled_tracer_times_but_emits_nothing() {
        let tracer = Tracer::disabled();
        assert!(!tracer.enabled());
        let mut span = tracer.span("stage");
        assert_eq!(span.trace_id(), TraceId::NONE, "no entropy burned when off");
        span.attr("k", "v");
        assert!(span.attrs.is_empty(), "attrs not retained when off");
        let _ = span.finish();
    }

    #[test]
    fn slow_spans_dump_even_when_tracing_is_off() {
        let slow = Arc::new(MemorySink::new());
        let clock = Arc::new(ManualClock::new());
        let tracer = Tracer::with_sinks(
            None,
            slow.clone() as Arc<dyn TraceSink>,
            clock.clone() as Arc<dyn Clock>,
        );
        tracer.set_slow_threshold_us(1000);
        // Below threshold: silent.
        let mut fast = tracer.span("request");
        fast.mark_slow_eligible();
        clock.advance_micros(999);
        fast.finish();
        assert!(slow.lines().is_empty());
        // Above threshold but not opted in: silent.
        let inner = tracer.span("cache.lookup");
        clock.advance_micros(5000);
        inner.finish();
        assert!(slow.lines().is_empty());
        // Eligible and above threshold: dumped with the slow flag.
        let mut req = tracer.span("request");
        req.mark_slow_eligible();
        clock.advance_micros(1000);
        req.finish();
        let lines = slow.lines();
        assert_eq!(lines.len(), 1);
        let v = Json::parse(&lines[0]).unwrap();
        assert_eq!(v.get("slow").and_then(Json::as_bool), Some(true));
        assert_eq!(v.get("dur_us").and_then(Json::as_u64), Some(1000));
    }

    #[test]
    fn ambient_context_opens_linked_children() {
        let (tracer, sink, _clock) = manual_tracer();
        assert!(current_span("orphan").is_none(), "no ambient context installed");
        let root = tracer.span_in(TraceId(5), "request");
        let root_id = root.id();
        {
            let _guard = push_current(&root);
            let inner = current_span("query.block_scan").expect("ambient context live");
            assert_eq!(inner.trace_id(), TraceId(5));
            inner.finish();
        }
        assert!(current_span("after").is_none(), "guard pops the context");
        root.finish();
        let lines = sink.lines();
        assert_eq!(lines.len(), 2);
        let inner_v = Json::parse(&lines[0]).unwrap();
        assert_eq!(inner_v.get("name").and_then(Json::as_str), Some("query.block_scan"));
        assert_eq!(inner_v.get("parent").and_then(Json::as_u64), Some(root_id));
    }

    #[test]
    fn disabled_ambient_context_yields_no_spans() {
        let tracer = Tracer::disabled();
        let root = tracer.span("request");
        let _guard = push_current(&root);
        assert!(current_span("query.block_scan").is_none());
    }

    #[test]
    fn emit_manual_renders_the_external_measurement() {
        let (tracer, sink, _clock) = manual_tracer();
        tracer.emit_manual(TraceId(3), Some(17), "serve.admission", 10, 250);
        let lines = sink.lines();
        assert_eq!(lines.len(), 1);
        let v = Json::parse(&lines[0]).unwrap();
        assert_eq!(v.get("name").and_then(Json::as_str), Some("serve.admission"));
        assert_eq!(v.get("parent").and_then(Json::as_u64), Some(17));
        assert_eq!(v.get("dur_us").and_then(Json::as_u64), Some(250));
        // Disabled: nothing.
        let off = Tracer::disabled();
        off.emit_manual(TraceId(3), None, "x", 0, 0);
    }

    #[test]
    fn from_env_defaults_to_disabled() {
        // The suite must not depend on ambient TSPM_TRACE; this only
        // asserts the constructor is callable and well-formed.
        let t = Tracer::from_env();
        let _ = t.enabled();
    }
}
