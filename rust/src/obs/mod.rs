//! Unified observability: structured tracing, a metrics registry, and
//! Prometheus-style exposition — dependency-free, like everything else
//! in the crate.
//!
//! The paper's claims are performance claims; the ROADMAP north star is
//! a production daemon. Both need more than a per-run [`crate::metrics::PhaseTimer`]:
//! a live `tspm serve` process must be scrapeable, and a single query
//! must be traceable from `tspm client` through admission, registry
//! routing, the result cache, and the block reads that answered it.
//! This module is that layer, in three pillars:
//!
//! 1. **Tracing** ([`trace`]) — [`trace::Span`]s with a 128-bit
//!    [`trace::TraceId`], parent links, and key-value attributes,
//!    emitted as JSONL through a pluggable [`trace::TraceSink`] (file,
//!    stderr, or an in-memory sink for tests). Time comes from an
//!    injectable monotonic [`trace::Clock`] — never `SystemTime::now` —
//!    so instrumented code inside the deterministic-output modules
//!    stays `cargo xtask lint`-clean, and mined/screened/indexed output
//!    is byte-identical with tracing on or off (the trace stream rides
//!    on stderr or a side file, never on the data path). Enable with
//!    `TSPM_TRACE=1` (stderr) or `TSPM_TRACE=/path/to/trace.jsonl`.
//!    A *slow-query log* rides on the same spans: request spans above a
//!    threshold (`TSPM_SLOW_QUERY_MS`, or `tspm serve --slow-query-ms`)
//!    are dumped even when tracing is otherwise off.
//! 2. **Metrics** ([`metrics`]) — a process-wide registry of named
//!    counters, gauges, and fixed-bucket histograms, built on the
//!    [`crate::sync`] shim so the same code is loom-model-checkable and
//!    recovers from poisoned locks. The existing per-artifact
//!    [`crate::query::QueryStats`] / cache snapshots remain the
//!    per-service view; the registry aggregates the same update sites
//!    process-wide (cache lookups are recorded under one lock so a
//!    scrape always sees `hits + misses == lookups`).
//! 3. **Exposition** ([`expo`]) — Prometheus-text-format rendering
//!    (`# TYPE` lines, `_bucket`/`_sum`/`_count` histogram series),
//!    served by a plain-HTTP scrape endpoint (`tspm serve
//!    --metrics-addr HOST:PORT`) and over the serve wire protocol as a
//!    `metrics` request frame.
//!
//! ## Metric-naming contract
//!
//! Every exposition name is a `pub const` in [`names`], matches
//! `[a-z][a-z0-9_]*`, and is **append-only**: `cargo xtask lint` checks
//! the constants against `xtask/snapshots/metrics.txt` exactly like the
//! wire-protocol snapshot, so a rename or removal (which would silently
//! break every dashboard scraping the old name) fails CI. New metrics
//! are added by appending a constant and re-blessing with
//! `cargo xtask lint --bless` in the same commit.
//!
//! ## Exposition format
//!
//! The scrape body is Prometheus text format: one `# TYPE <name>
//! <counter|gauge|histogram>` line per family followed by its samples,
//! families sorted by name, histograms rendered as cumulative
//! `<name>_bucket{le="..."}` series plus `<name>_sum` / `<name>_count`.
//! All values are integers. This format is part of the compatibility
//! surface pinned by the snapshot above.

pub mod expo;
pub mod metrics;
pub mod names;
pub mod trace;

pub use metrics::{global, CacheTotals, Counter, Gauge, Histogram, MetricsRegistry};
pub use trace::{
    Clock, FileSink, ManualClock, MemorySink, MonotonicClock, Span, StderrSink, TraceId,
    TraceSink, Tracer,
};
