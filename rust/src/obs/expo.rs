//! The scrape endpoint: a minimal plain-HTTP server that answers every
//! request with the Prometheus text exposition of a
//! [`MetricsRegistry`].
//!
//! This is deliberately not a web framework: one listener thread,
//! non-blocking accept polled against a shutdown flag, and a
//! fixed-form `HTTP/1.1 200 OK` response with a `Content-Length` and
//! `Connection: close`. That is everything a Prometheus-compatible
//! scraper (or `curl`) needs, and nothing the dependency-free crate
//! would have to maintain. The serve daemon starts one with
//! `tspm serve --metrics-addr HOST:PORT`; the same body is also
//! available in-band via the wire protocol's `metrics` request.

use crate::obs::metrics::MetricsRegistry;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// How often the accept loop re-checks the shutdown flag.
const POLL_INTERVAL: Duration = Duration::from_millis(10);
/// Per-connection read timeout: scrapers send a one-line request.
const READ_TIMEOUT: Duration = Duration::from_secs(2);
/// Request-head cap; a scrape request is a few hundred bytes.
const MAX_REQUEST_BYTES: usize = 8 * 1024;

/// A running scrape endpoint. Dropping it (or calling
/// [`MetricsServer::shutdown`]) stops the listener thread.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9187`, port 0 for ephemeral) and
    /// serve `registry`'s exposition until shutdown.
    pub fn bind(addr: &str, registry: &'static MetricsRegistry) -> io::Result<MetricsServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let thread_stop = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("tspm-metrics".into())
            .spawn(move || accept_loop(listener, registry, thread_stop))?;
        Ok(MetricsServer { addr: local, stop, handle: Some(handle) })
    }

    /// The bound address (resolves port 0 to the actual port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the listener thread. Idempotent.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(handle) = self.handle.take() {
            let _ = handle.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: TcpListener, registry: &'static MetricsRegistry, stop: Arc<AtomicBool>) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                // Scrapes are tiny and rare (seconds apart); serve them
                // inline rather than spawning per connection.
                let _ = serve_scrape(stream, registry);
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(POLL_INTERVAL);
            }
            Err(_) => std::thread::sleep(POLL_INTERVAL),
        }
    }
}

/// Read the request head (we answer every path identically), then write
/// one self-delimiting response and close.
fn serve_scrape(mut stream: TcpStream, registry: &'static MetricsRegistry) -> io::Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(READ_TIMEOUT))?;
    let mut head = Vec::new();
    let mut buf = [0u8; 1024];
    loop {
        match stream.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => {
                head.extend_from_slice(&buf[..n]);
                if head.windows(4).any(|w| w == b"\r\n\r\n") || head.len() >= MAX_REQUEST_BYTES {
                    break;
                }
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => break,
        }
    }
    let body = registry.render_prometheus();
    write_http_ok(&mut stream, &body)
}

/// The fixed-form scrape response; exposed for the in-band wire path's
/// tests to share the body format.
fn write_http_ok(stream: &mut TcpStream, body: &str) -> io::Result<()> {
    let head = format!(
        "HTTP/1.1 200 OK\r\n\
         Content-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
         Content-Length: {}\r\n\
         Connection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use crate::obs::metrics::global;

    fn scrape(addr: SocketAddr) -> String {
        let mut stream = TcpStream::connect(addr).unwrap();
        stream
            .write_all(b"GET /metrics HTTP/1.1\r\nHost: test\r\n\r\n")
            .unwrap();
        let mut out = String::new();
        stream.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn scrape_endpoint_serves_exposition() {
        global().counter("tspm_test_expo_counter").add(5);
        let mut server = MetricsServer::bind("127.0.0.1:0", global()).unwrap();
        let response = scrape(server.local_addr());
        assert!(response.starts_with("HTTP/1.1 200 OK\r\n"), "{response}");
        assert!(response.contains("Content-Type: text/plain"), "{response}");
        let (head, body) = response.split_once("\r\n\r\n").unwrap();
        let len: usize = head
            .lines()
            .find_map(|l| l.strip_prefix("Content-Length: "))
            .unwrap()
            .parse()
            .unwrap();
        assert_eq!(len, body.len());
        assert!(body.contains("tspm_test_expo_counter 5\n"), "{body}");
        // Consecutive scrapes observe monotone counters.
        global().counter("tspm_test_expo_counter").add(2);
        let second = scrape(server.local_addr());
        assert!(second.contains("tspm_test_expo_counter 7\n"), "{second}");
        // shutdown() joins the listener thread; returning proves the
        // accept loop honoured the stop flag.
        server.shutdown();
    }
}
