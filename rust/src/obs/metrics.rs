//! The metrics registry: named counters, gauges, and fixed-bucket
//! histograms, plus the consistent cache-counter pair.
//!
//! Built on the [`crate::sync`] shim so the registry participates in
//! the loom verification gate and recovers from poisoned locks (a
//! panicking instrumented thread must never wedge the scrape endpoint).
//! Handles are `Arc`s: registration is get-or-create by name, so any
//! module can say `obs::metrics::global().counter(names::…)` and hold
//! the handle for lock-free updates.
//!
//! Two consistency notes, both load-bearing for the CI scrape checks:
//!
//! * **Cache counters** (`tspm_cache_hits` / `_misses` / `_lookups`)
//!   are kept as one mutex-protected pair ([`CacheCounters`]) and
//!   rendered from a single locked snapshot, so every exposition
//!   satisfies `hits + misses == lookups` exactly — no torn reads
//!   between separately-loaded atomics.
//! * **Counters are monotone**: there is no reset. Process-wide totals
//!   only ever grow, which is what lets a scraper `rate()` them.
//!
//! Rendering is deterministic: families sort by name (the maps are
//! `BTreeMap`s), histograms emit cumulative `_bucket{le="…"}` series
//! plus `_sum`/`_count`.

use crate::obs::names;
use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::{lock_ignore_poison, read_ignore_poison, write_ignore_poison, Mutex, RwLock};
use std::collections::BTreeMap;
// Handles are shared as plain `std::sync::Arc` (like the serve
// registry's surfaces): the refcount is not what loom checks here —
// the locked maps and the cache pair are — and loom's Arc does not
// model every std API the handles need.
use std::sync::Arc;

/// `[a-z][a-z0-9_]*` — the naming rule `cargo xtask lint` enforces
/// statically on [`names`]; checked dynamically (debug builds) at
/// registration too.
pub fn valid_metric_name(name: &str) -> bool {
    let mut bytes = name.bytes();
    match bytes.next() {
        Some(b) if b.is_ascii_lowercase() => {}
        _ => return false,
    }
    bytes.all(|b| b.is_ascii_lowercase() || b.is_ascii_digit() || b == b'_')
}

/// Monotone event count.
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    fn new() -> Counter {
        Counter { value: AtomicU64::new(0) }
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Instantaneous value (set-to-latest).
pub struct Gauge {
    value: AtomicU64,
}

impl Gauge {
    fn new() -> Gauge {
        Gauge { value: AtomicU64::new(0) }
    }

    pub fn set(&self, v: u64) {
        self.value.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Fixed-bucket histogram: `bounds` are inclusive upper edges; one
/// implicit `+Inf` bucket catches the rest.
pub struct Histogram {
    bounds: Vec<u64>,
    /// `bounds.len() + 1` slots; the last is the overflow bucket.
    counts: Vec<AtomicU64>,
    sum: AtomicU64,
    count: AtomicU64,
}

impl Histogram {
    fn new(bounds: &[u64]) -> Histogram {
        debug_assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds must ascend");
        Histogram {
            bounds: bounds.to_vec(),
            counts: (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect(),
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    pub fn observe(&self, v: u64) {
        let idx = self.bounds.iter().position(|&b| v <= b).unwrap_or(self.bounds.len());
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }
}

/// One consistent scrape of the cache pair.
#[derive(Clone, Copy, Default, Debug, PartialEq, Eq)]
pub struct CacheTotals {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl CacheTotals {
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }
}

/// Process-wide cache counters under one lock, so a scrape can never
/// observe `hits + misses != lookups`. Every [`crate::query`] cache
/// feeds this in addition to its own per-service snapshot.
pub struct CacheCounters {
    inner: Mutex<CacheTotals>,
}

impl CacheCounters {
    fn new() -> CacheCounters {
        CacheCounters { inner: Mutex::new(CacheTotals::default()) }
    }

    pub fn record_lookup(&self, hit: bool) {
        let mut t = lock_ignore_poison(&self.inner);
        if hit {
            t.hits += 1;
        } else {
            t.misses += 1;
        }
    }

    pub fn record_evictions(&self, n: u64) {
        lock_ignore_poison(&self.inner).evictions += n;
    }

    pub fn totals(&self) -> CacheTotals {
        *lock_ignore_poison(&self.inner)
    }
}

/// A sample contributed by a registered collector (values computed at
/// scrape time — RSS probes, per-artifact stats, …).
pub struct Sample {
    pub name: String,
    pub kind: SampleKind,
    pub value: u64,
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum SampleKind {
    Counter,
    Gauge,
}

type Collector = Box<dyn Fn(&mut Vec<Sample>) + Send + Sync>;

/// The registry. Usually accessed through [`global`]; tests build their
/// own.
pub struct MetricsRegistry {
    counters: RwLock<BTreeMap<&'static str, Arc<Counter>>>,
    gauges: RwLock<BTreeMap<&'static str, Arc<Gauge>>>,
    histograms: RwLock<BTreeMap<&'static str, Arc<Histogram>>>,
    collectors: Mutex<Vec<Collector>>,
    cache: CacheCounters,
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        MetricsRegistry::new()
    }
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry {
            counters: RwLock::new(BTreeMap::new()),
            gauges: RwLock::new(BTreeMap::new()),
            histograms: RwLock::new(BTreeMap::new()),
            collectors: Mutex::new(Vec::new()),
            cache: CacheCounters::new(),
        }
    }

    /// Get-or-create the named counter.
    pub fn counter(&self, name: &'static str) -> Arc<Counter> {
        debug_assert!(valid_metric_name(name), "invalid metric name {name:?}");
        if let Some(c) = read_ignore_poison(&self.counters).get(name) {
            return Arc::clone(c);
        }
        let mut map = write_ignore_poison(&self.counters);
        Arc::clone(map.entry(name).or_insert_with(|| Arc::new(Counter::new())))
    }

    /// Get-or-create the named gauge.
    pub fn gauge(&self, name: &'static str) -> Arc<Gauge> {
        debug_assert!(valid_metric_name(name), "invalid metric name {name:?}");
        if let Some(g) = read_ignore_poison(&self.gauges).get(name) {
            return Arc::clone(g);
        }
        let mut map = write_ignore_poison(&self.gauges);
        Arc::clone(map.entry(name).or_insert_with(|| Arc::new(Gauge::new())))
    }

    /// Get-or-create the named histogram. The first registration wins
    /// the bucket layout; later callers share it.
    pub fn histogram(&self, name: &'static str, bounds: &[u64]) -> Arc<Histogram> {
        debug_assert!(valid_metric_name(name), "invalid metric name {name:?}");
        if let Some(h) = read_ignore_poison(&self.histograms).get(name) {
            return Arc::clone(h);
        }
        let mut map = write_ignore_poison(&self.histograms);
        Arc::clone(map.entry(name).or_insert_with(|| Arc::new(Histogram::new(bounds))))
    }

    /// The consistent cache pair (see the module docs).
    pub fn cache(&self) -> &CacheCounters {
        &self.cache
    }

    /// Register a scrape-time collector; its samples are merged (and
    /// sorted) into every rendering.
    pub fn register_collector(&self, f: Collector) {
        lock_ignore_poison(&self.collectors).push(f);
    }

    /// Prometheus text exposition — the format pinned by the
    /// [`crate::obs`] module docs.
    pub fn render_prometheus(&self) -> String {
        let mut blocks: Vec<(String, String)> = Vec::new();
        {
            let map = read_ignore_poison(&self.counters);
            for (name, c) in map.iter() {
                blocks.push((
                    (*name).to_string(),
                    format!("# TYPE {name} counter\n{name} {}\n", c.get()),
                ));
            }
        }
        {
            let map = read_ignore_poison(&self.gauges);
            for (name, g) in map.iter() {
                blocks.push((
                    (*name).to_string(),
                    format!("# TYPE {name} gauge\n{name} {}\n", g.get()),
                ));
            }
        }
        {
            let map = read_ignore_poison(&self.histograms);
            for (name, h) in map.iter() {
                let mut b = format!("# TYPE {name} histogram\n");
                let mut cum = 0u64;
                for (i, bound) in h.bounds.iter().enumerate() {
                    cum += h.counts[i].load(Ordering::Relaxed);
                    b.push_str(&format!("{name}_bucket{{le=\"{bound}\"}} {cum}\n"));
                }
                cum += h.counts[h.bounds.len()].load(Ordering::Relaxed);
                b.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {cum}\n"));
                b.push_str(&format!("{name}_sum {}\n", h.sum()));
                b.push_str(&format!("{name}_count {}\n", h.count()));
                blocks.push(((*name).to_string(), b));
            }
        }
        // One locked snapshot → the three cache lines always agree.
        let t = self.cache.totals();
        for (name, value) in [
            (names::CACHE_HITS, t.hits),
            (names::CACHE_MISSES, t.misses),
            (names::CACHE_LOOKUPS, t.lookups()),
            (names::CACHE_EVICTIONS, t.evictions),
        ] {
            blocks.push((
                name.to_string(),
                format!("# TYPE {name} counter\n{name} {value}\n"),
            ));
        }
        let mut samples = Vec::new();
        for f in lock_ignore_poison(&self.collectors).iter() {
            f(&mut samples);
        }
        for s in samples {
            let kind = match s.kind {
                SampleKind::Counter => "counter",
                SampleKind::Gauge => "gauge",
            };
            blocks.push((
                s.name.clone(),
                format!("# TYPE {} {kind}\n{} {}\n", s.name, s.name, s.value),
            ));
        }
        blocks.sort_by(|a, b| a.0.cmp(&b.0));
        blocks.into_iter().map(|(_, b)| b).collect()
    }
}

/// The process-wide registry every instrumentation site feeds.
pub fn global() -> &'static MetricsRegistry {
    // std's OnceLock regardless of cfg(loom): the global is never what
    // a loom model checks (loom suites build their own registries).
    static GLOBAL: std::sync::OnceLock<MetricsRegistry> = std::sync::OnceLock::new();
    GLOBAL.get_or_init(MetricsRegistry::new)
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn metric_names_validate() {
        assert!(valid_metric_name("tspm_cache_hits"));
        assert!(valid_metric_name("a"));
        assert!(valid_metric_name("ab_c123"));
        assert!(!valid_metric_name(""));
        assert!(!valid_metric_name("Tspm_x"));
        assert!(!valid_metric_name("1abc"));
        assert!(!valid_metric_name("_x"));
        assert!(!valid_metric_name("tspm-cache"));
        assert!(!valid_metric_name("tspm cache"));
    }

    #[test]
    fn every_declared_name_is_valid() {
        for name in [
            names::CACHE_HITS,
            names::CACHE_MISSES,
            names::CACHE_LOOKUPS,
            names::CACHE_EVICTIONS,
            names::QUERY_BLOCK_READS,
            names::QUERY_BYTES_READ,
            names::MINE_SHARDS_CLAIMED,
            names::MINE_SHARDS_MERGED,
            names::SCREEN_SPILL_RUNS_OPENED,
            names::SCREEN_SPILL_BYTES_MERGED,
            names::SCREEN_SPILL_MERGE_PASSES,
            names::INGEST_SEGMENTS_COMMITTED,
            names::COMPACT_RUNS,
            names::COMPACT_SEGMENTS_FOLDED,
            names::SERVE_REQUESTS,
            names::SERVE_SHED,
            names::SERVE_CONNS,
            names::SERVE_REQUEST_DURATION_US,
            names::ENGINE_STAGE_DURATION_US,
            names::MEM_LIVE_BYTES,
            names::MEM_PEAK_BYTES,
            names::PROCESS_PEAK_RSS_BYTES,
            names::PROCESS_CURRENT_RSS_BYTES,
        ] {
            assert!(valid_metric_name(name), "{name}");
        }
    }

    #[test]
    fn registration_is_get_or_create() {
        let reg = MetricsRegistry::new();
        let a = reg.counter("tspm_test_counter");
        let b = reg.counter("tspm_test_counter");
        assert!(Arc::ptr_eq(&a, &b));
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3);
        let g = reg.gauge("tspm_test_gauge");
        g.set(7);
        assert_eq!(reg.gauge("tspm_test_gauge").get(), 7);
        let h1 = reg.histogram("tspm_test_hist", &[10, 100]);
        let h2 = reg.histogram("tspm_test_hist", &[1]); // layout: first wins
        assert!(Arc::ptr_eq(&h1, &h2));
    }

    #[test]
    fn histogram_buckets_are_cumulative() {
        let reg = MetricsRegistry::new();
        let h = reg.histogram("tspm_test_hist", &[10, 100, 1000]);
        for v in [5, 10, 11, 500, 5000] {
            h.observe(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 5526);
        let text = reg.render_prometheus();
        assert!(text.contains("tspm_test_hist_bucket{le=\"10\"} 2\n"), "{text}");
        assert!(text.contains("tspm_test_hist_bucket{le=\"100\"} 3\n"), "{text}");
        assert!(text.contains("tspm_test_hist_bucket{le=\"1000\"} 4\n"), "{text}");
        assert!(text.contains("tspm_test_hist_bucket{le=\"+Inf\"} 5\n"), "{text}");
        assert!(text.contains("tspm_test_hist_sum 5526\n"), "{text}");
        assert!(text.contains("tspm_test_hist_count 5\n"), "{text}");
    }

    #[test]
    fn render_is_sorted_and_typed() {
        let reg = MetricsRegistry::new();
        reg.counter("tspm_zz").add(1);
        reg.gauge("tspm_aa").set(2);
        let text = reg.render_prometheus();
        let aa = text.find("tspm_aa 2").unwrap();
        let zz = text.find("tspm_zz 1").unwrap();
        assert!(aa < zz, "families sort by name:\n{text}");
        assert!(text.contains("# TYPE tspm_aa gauge\n"));
        assert!(text.contains("# TYPE tspm_zz counter\n"));
        // The cache pair renders even when untouched.
        assert!(text.contains("tspm_cache_hits 0\n"));
        assert!(text.contains("tspm_cache_lookups 0\n"));
    }

    #[test]
    fn collectors_contribute_scrape_time_samples() {
        let reg = MetricsRegistry::new();
        reg.register_collector(Box::new(|out| {
            out.push(Sample {
                name: "tspm_test_rss_bytes".into(),
                kind: SampleKind::Gauge,
                value: 4096,
            });
        }));
        let text = reg.render_prometheus();
        assert!(text.contains("# TYPE tspm_test_rss_bytes gauge\ntspm_test_rss_bytes 4096\n"));
    }

    /// The equality the serve-e2e CI job asserts on live scrapes: with
    /// a writer hammering lookups from another thread, every rendering
    /// still satisfies hits + misses == lookups.
    #[test]
    fn cache_pair_is_never_torn_under_concurrent_scrapes() {
        let reg = Arc::new(MetricsRegistry::new());
        let stop = Arc::new(crate::sync::atomic::AtomicBool::new(false));
        let writer = {
            let reg = Arc::clone(&reg);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut i = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    reg.cache().record_lookup(i % 3 == 0);
                    i += 1;
                }
            })
        };
        let parse = |text: &str, name: &str| -> u64 {
            text.lines()
                .find(|l| l.starts_with(&format!("{name} ")))
                .and_then(|l| l.split_whitespace().nth(1))
                .and_then(|v| v.parse().ok())
                .unwrap()
        };
        for _ in 0..200 {
            let text = reg.render_prometheus();
            let h = parse(&text, names::CACHE_HITS);
            let m = parse(&text, names::CACHE_MISSES);
            let l = parse(&text, names::CACHE_LOOKUPS);
            assert_eq!(h + m, l, "torn scrape: {h} + {m} != {l}");
        }
        stop.store(true, Ordering::Relaxed);
        writer.join().unwrap();
        let t = reg.cache().totals();
        assert_eq!(t.hits + t.misses, t.lookups());
    }

    #[test]
    fn global_registry_is_one_instance() {
        let a = global() as *const MetricsRegistry;
        let b = global() as *const MetricsRegistry;
        assert_eq!(a, b);
    }
}

/// Exhaustive-interleaving check of the registry's two concurrency
/// protocols: get-or-create registration racing an increment, and the
/// cache pair racing a snapshot — on every schedule the counter loses
/// no update and the snapshot is internally consistent. Compiled only
/// under `RUSTFLAGS="--cfg loom"`.
#[cfg(all(test, loom))]
mod loom_tests {
    use super::*;
    use crate::sync::Arc as LoomArc;

    #[test]
    fn loom_counter_and_cache_pair_lose_no_updates() {
        loom::model(|| {
            let reg = LoomArc::new(MetricsRegistry::new());
            let t1 = {
                let reg = LoomArc::clone(&reg);
                loom::thread::spawn(move || {
                    reg.counter("tspm_loom_counter").inc();
                    reg.cache().record_lookup(true);
                })
            };
            let t2 = {
                let reg = LoomArc::clone(&reg);
                loom::thread::spawn(move || {
                    reg.counter("tspm_loom_counter").inc();
                    reg.cache().record_lookup(false);
                })
            };
            // A concurrent snapshot is always consistent, whatever the
            // interleaving admitted so far.
            let t = reg.cache().totals();
            assert_eq!(t.hits + t.misses, t.lookups());
            assert!(t.hits <= 1 && t.misses <= 1);
            t1.join().unwrap();
            t2.join().unwrap();
            assert_eq!(reg.counter("tspm_loom_counter").get(), 2);
            let t = reg.cache().totals();
            assert_eq!((t.hits, t.misses), (1, 1));
        });
    }
}
