//! # tSPM+ — transitive Sequential Pattern Mining, plus durations
//!
//! A production-grade Rust reproduction of the tSPM+ system (Hügel, Sax,
//! Murphy, Estiri, 2023): a high-performance engine for mining *transitive
//! sequential patterns* — all ordered pairs of clinical observations per
//! patient, annotated with their duration in days — from time-stamped
//! clinical data in the MLHO `dbmart` format.
//!
//! ## Quickstart — the engine façade
//!
//! The supported entry point is [`engine::Engine`]: a fluent builder that
//! assembles a validated stage chain (mine → screen → matrix → msmr),
//! dispatches mining to an interchangeable execution backend (in-memory,
//! sharded, file-backed, or streaming — auto-selected from a memory
//! forecast and the worker count), and reports one unified error type
//! ([`engine::TspmError`]) plus per-stage timings
//! ([`engine::RunReport`]). The result is **spill-aware**
//! ([`engine::SequenceOutput`]): runs whose (post-screen) output may not
//! fit the memory budget come back as durable on-disk spill files
//! instead of one giant vector, with
//! [`materialize()`](engine::SequenceOutput::materialize) as the
//! explicit escape hatch back to memory:
//!
//! ```no_run
//! use tspm_plus::prelude::*;
//!
//! // Generate a small synthetic cohort and run the paper's pipeline.
//! let cohort = SyntheaConfig::small().generate();
//! let out = Engine::from_raw(&cohort)?
//!     .mine(MiningConfig::default())
//!     .screen(SparsityConfig { min_patients: 5, threads: 0 })
//!     .matrix()
//!     .run()?;
//! println!(
//!     "{} screened sequences, {}×{} matrix, via the {} backend",
//!     out.sequences.len(),
//!     out.matrix.as_ref().unwrap().num_patients,
//!     out.matrix.as_ref().unwrap().num_cols(),
//!     out.report.backend,
//! );
//! # Ok::<(), tspm_plus::engine::TspmError>(())
//! ```
//!
//! See `examples/quickstart.rs` for the 60-second tour and
//! `examples/e2e_pipeline.rs` for the full workflow including MSMR and
//! classification.
//!
//! ### Mine a target
//!
//! When only sequences touching a handful of codes matter (a drug–outcome
//! question, one phenotype's neighbourhood), pass a [`target::TargetSpec`]
//! and the predicate is **pushed down** into every backend's per-patient
//! inner loop — non-matching pairs are pruned before duration encoding,
//! so time and memory scale with the targeted slice, not the full
//! `Σ n·(n−1)/2` multiset. The output is byte-identical to mining
//! everything and filtering afterwards (the conformance harness proves
//! it across all four backends), and an index built from a targeted run
//! records the spec in its manifest so `tspm query --list` can answer
//! "what was this artifact targeted to":
//!
//! ```no_run
//! use tspm_plus::prelude::*;
//!
//! let cohort = SyntheaConfig::small().generate();
//! let numeric = tspm_plus::dbmart::NumericDbMart::encode(&cohort);
//! // Sequences that *start* at code 3 or 9, lasting at most 90 days.
//! let spec = TargetSpec::for_codes([3, 9])
//!     .with_pos(TargetPos::First)
//!     .with_duration_band(None, Some(90));
//! let out = Engine::from_dbmart(numeric)
//!     .mine(MiningConfig::default())
//!     .target(spec)
//!     .screen(SparsityConfig { min_patients: 5, threads: 0 })
//!     .run()?;
//! println!("{} targeted sequences", out.sequences.len());
//! # Ok::<(), tspm_plus::engine::TspmError>(())
//! ```
//!
//! On the CLI the same spec is `tspm mine --target-code C3 --target-code C9
//! --target-pos first --target-dur-max 90` (codes are given by *name* and
//! resolved against the cohort's vocabulary; unknown names are rejected
//! before mining starts). `TargetSpec::all()` — and omitting the flags —
//! is the identity: output bytes match an untargeted run exactly.
//!
//! ### Picking a backend
//!
//! With `BackendChoice::Auto` (the default), the engine forecasts the
//! exact mining output (`Σ n·(n−1)/2` per patient) and picks:
//!
//! * output fits the memory budget, >1 worker → **sharded**
//!   (`--backend sharded`): patients grouped into cost-balanced shards,
//!   claimed dynamically by workers, merged in stable shard order. Its
//!   output is **deterministic** — identical for every thread count and
//!   `TSPM_THREADS` setting, because the merge never depends on
//!   completion order.
//! * output fits, 1 worker → **in-memory** (no scheduling to win).
//! * output too big, but every partition chunk fits → **streaming**
//!   (bounded queues + backpressure).
//! * a single patient alone overflows a chunk → **file-backed**
//!   (per-worker spill files).
//!
//! All four backends produce the same sequence multiset; the
//! cross-backend conformance harness (`rust/tests/conformance.rs`)
//! asserts byte-identical sorted output on adversarial cohort shapes.
//!
//! ### Results larger than memory
//!
//! Residency is resolved separately from the backend
//! ([`engine::OutputChoice`], default `Auto`): when the forecast
//! post-screen footprint exceeds the budget on a file-backed or
//! streaming run, the engine leaves the multiset in spill files and
//! screens it **out of core** ([`sparsity::screen_spilled`] — external
//! merge by `(seq, pid, duration)` with bounded buffers), so an
//! end-to-end run finishes even when the screened output alone
//! overflows RAM. `tspm mine --out-dir DIR` exposes the same contract
//! on the CLI; [`engine::RunOutput::sequences`] then carries the
//! [`seqstore::SeqFileSet`] a caching or serving layer can consume
//! directly.
//!
//! ### Query the results
//!
//! A spilled run becomes a **servable artifact**: [`query::index::build`]
//! streams the sorted spill files exactly once into an immutable,
//! versioned, block-indexed artifact (manifest + data + block index +
//! per-sequence table — see the [`query`] module docs for the format
//! and its compatibility guarantee), and [`query::QueryService`]
//! answers point/range queries over it — `by_sequence`, `by_patient`,
//! `patients_with(seq, duration range)`, `top_k_by_support`,
//! `duration_histogram` — reading one block at a time, never the whole
//! set, with a size-bounded LRU result cache in front (hits/misses
//! observable via [`query::QueryService::stats`]). v2 artifacts carry a
//! **pid-major secondary index** (`pids.bin` + a pid-major record
//! copy), so `by_patient` reads exactly the patient's own records —
//! IO scales with the answer, not the artifact (v1 artifacts still
//! open; they fall back to the block-pruned scan). On the engine,
//! chain `.index(dir)` after a spilled screen and the artifact is built
//! as a pipeline stage ([`engine::RunOutput::index`]); on the CLI:
//!
//! ```text
//! tspm mine   --input db.csv --sparsity 50 --out-dir run/
//! tspm index  --in-dir run/  --out-dir idx/
//! tspm query  --index-dir idx/ --seq 420000012
//! tspm query  --index-dir idx/ --pid 42          # pid-indexed fast path
//! tspm matrix --index-dir idx/                   # CSR straight from the artifact
//! ```
//!
//! ### Serve the results
//!
//! For many focused questions against one mined corpus, `tspm query`'s
//! per-question process launch is the bottleneck — [`serve`] keeps the
//! artifacts open in a long-lived daemon instead. `tspm serve` opens
//! one or more index directories behind a [`serve::Registry`] (each
//! with its own cache and stats, routed by artifact id, hot-swappable
//! via `register`/`retire` without interrupting in-flight readers) and
//! answers the same query surface over a versioned, length-prefixed
//! JSON protocol on TCP — thread-per-connection, bounded by a
//! connection semaphore that **sheds** excess load with a typed `busy`
//! frame instead of queueing unboundedly. Heavy `by_patient` answers
//! stream block-at-a-time ([`query::QueryService::by_patient_visit`]),
//! so daemon memory stays bounded by the artifact's block size, not the
//! patient. [`serve::Client`] is the matching blocking client, also
//! exposed as `tspm client` (the e2e harness):
//!
//! ```text
//! tspm serve  --index-dir idxA/ --index-dir idxB/ --addr 127.0.0.1:7878 --max-conns 64
//! tspm client --addr 127.0.0.1:7878 --list
//! tspm client --addr 127.0.0.1:7878 --artifact idxA --seq 420000012
//! tspm client --addr 127.0.0.1:7878 --artifact idxA --workload 2000
//! tspm client --addr 127.0.0.1:7878 --retire idxB   # hot-swap
//! tspm client --addr 127.0.0.1:7878 --shutdown      # graceful drain
//! ```
//!
//! The wire protocol (frame layout, version gate, error codes) is a
//! compatibility contract documented in the [`serve`] module.
//!
//! ### Ingest continuously
//!
//! Cohorts grow; re-mining everything per delta does not scale. The
//! [`ingest`] subsystem treats index artifacts as **immutable segments**
//! under a versioned, checksummed, atomically-swapped segment-set
//! manifest (`segments.json` — format documented in the [`ingest`]
//! module): `tspm ingest` (or `.ingest(set_dir)` on the engine) mines
//! *only the delta cohort* — encoded against the set's persisted
//! vocabulary so every segment shares one id space — and commits it as
//! a new segment. [`ingest::MergedView`] answers the **full query
//! surface** over all segments by bounded k-way merge, byte-identical
//! to a single artifact of the union cohort as long as segments hold
//! disjoint patients (the set's correctness contract), and
//! [`ingest::compact`] folds the segments back into one artifact in a
//! single bounded-memory merge pass — bit-identical to a fresh
//! `tspm index` of the union, crash-safe at every step. The daemon
//! serves a set as one artifact (`tspm serve --set-dir`, hot-swappable
//! mid-workload). [`query::QuerySurface`] is the shared seam: one
//! artifact and a merged set answer through the same trait object.
//!
//! ```text
//! tspm ingest  --input delta1.csv --set-dir set/   # seg_0000
//! tspm ingest  --input delta2.csv --set-dir set/   # seg_0001
//! tspm query   --set-dir set/ --top-k 10           # merged view
//! tspm compact --set-dir set/                      # fold to one segment
//! tspm serve   --set-dir set/ --addr 127.0.0.1:7878
//! ```
//!
//! ### Observe the system
//!
//! The [`obs`] subsystem makes a live run inspectable without touching
//! its output (tracing rides on stderr or a side file, never the data
//! path — mined/screened/indexed bytes are identical with tracing on or
//! off). Three switches:
//!
//! * **Tracing** — set `TSPM_TRACE=1` (JSONL spans to stderr) or
//!   `TSPM_TRACE=/tmp/trace.jsonl` (to a file) on any command. Spans
//!   carry a 128-bit trace id, parent links, and attributes; `tspm
//!   client --trace-id <hex>` stamps requests so the *server-side*
//!   spans (admission → routing → cache → block reads) share the
//!   client's id and one `grep` reconstructs the request tree.
//! * **Metrics** — `tspm serve --metrics-addr 127.0.0.1:9187` opens a
//!   plain-HTTP Prometheus scrape endpoint
//!   (`curl 127.0.0.1:9187/metrics`); the same exposition is available
//!   in-band via `tspm client --metrics`. Names are pinned by the
//!   append-only snapshot `xtask/snapshots/metrics.txt` (see the
//!   [`obs`] docs for the contract).
//! * **Slow-query log** — `tspm serve --slow-query-ms 50` (or
//!   `TSPM_SLOW_QUERY_MS=50`) dumps the span of any request slower
//!   than the threshold, even when tracing is otherwise off.
//!
//! ### The out-of-core ML chain
//!
//! The index also feeds the ML layer without materialization:
//! `.matrix()` / `.msmr(k)` chained after `.index(dir)` build the
//! patient×sequence CSR **straight from the artifact**
//! ([`matrix::SeqMatrix::from_index`] — bit-identical to the in-memory
//! [`matrix::SeqMatrix::build`], resident set one read block + the CSR),
//! so the paper's full pipeline runs end-to-end under a budget far
//! below the mined record multiset:
//!
//! ```no_run
//! use tspm_plus::prelude::*;
//! # let cohort = SyntheaConfig::small().generate();
//! # let labels = vec![0.0f32; 500];
//! let out = Engine::from_raw(&cohort)?
//!     .mine(MiningConfig::default())
//!     .screen(SparsityConfig { min_patients: 5, threads: 0 })
//!     .index(std::path::PathBuf::from("idx"))
//!     .matrix()
//!     .msmr(200)
//!     .labels(labels)
//!     .memory_budget(64 << 20) // ≪ the record multiset
//!     .run()?;
//! # Ok::<(), tspm_plus::engine::TspmError>(())
//! ```
//!
//! ## The expert layer
//!
//! Every stage remains callable directly for fine-grained control — the
//! façade is composition sugar over these, not a replacement:
//!
//! 1. **Substrates** — from-scratch building blocks the engine depends on:
//!    [`rng`] (deterministic PRNG), [`json`] (config/lookup-table
//!    serialization), [`par`] (scoped-thread parallel map, the OpenMP
//!    stand-in), [`psort`] (parallel in-place samplesort, the ips4o
//!    stand-in), [`metrics`] (wall-clock + peak-RSS instrumentation),
//!    [`cli`] (argument parsing), [`bench_util`] (paper-style benchmark
//!    tables).
//! 2. **The mining engine** — [`dbmart`] (numeric encoding + lookup tables),
//!    [`synthea`] (synthetic clinical data with a COVID-19 scenario),
//!    [`mining`] (the tSPM+ sequencer, in-memory and file-based),
//!    [`seqstore`] (binary on-disk sequence format), [`sparsity`]
//!    (sort-then-scan screening), [`baseline`] (the original tSPM for
//!    comparison), [`partition`] (adaptive memory partitioning),
//!    [`pipeline`] (streaming orchestrator with backpressure).
//! 3. **Analytics on mined sequences** — [`query`] (indexed artifacts +
//!    cached query service over spilled results), [`ingest`] (incremental
//!    segment sets, merged views, compaction), [`serve`] (the
//!    concurrent query daemon + wire protocol), [`util`] (sequence
//!    filters and transitive end-sets), [`matrix`] (patient×sequence matrices),
//!    [`msmr`] (MSMR feature selection via joint mutual information),
//!    [`ml`] (MLHO-style classification workflow), [`postcovid`] (the WHO
//!    Post COVID-19 definition), all optionally accelerated through
//!    [`runtime`] — AOT-compiled JAX/Pallas artifacts executed via PJRT
//!    (behind the `pjrt` cargo feature; pure-Rust fallbacks otherwise).
//!
//! For example, in-memory mining without the façade:
//!
//! ```no_run
//! let dbmart = tspm_plus::synthea::SyntheaConfig::small().generate();
//! let numeric = tspm_plus::dbmart::NumericDbMart::encode(&dbmart);
//! let cfg = tspm_plus::mining::MiningConfig::default();
//! let mined = tspm_plus::mining::mine_sequences(&numeric, &cfg).unwrap();
//! println!("mined {} sequences", mined.records.len());
//! ```
//!
//! ## Verification
//!
//! Beyond the differential test wall, four static/dynamic gates guard
//! the contracts the tests can only sample:
//!
//! 1. **Loom model checking** — every concurrency-bearing module takes
//!    its primitives from the [`sync`] shim (`std::sync` normally,
//!    `loom::sync` under `cfg(loom)`), and `#[cfg(loom)]` suites
//!    exhaustively check the semaphore (no lost wakeups, exact permit
//!    accounting), the dynamic scheduler (no double-claimed work), the
//!    cache stats (`hits + misses == lookups`, never torn), the
//!    write-once shard-merge slots, and the registry hot-swap (no
//!    reader observes a retired artifact mid-swap). Run:
//!    `cargo add loom@0.7 --dev` then
//!    `RUSTFLAGS="--cfg loom" cargo test --release --lib loom`
//!    (the loom dependency is CI-lane-only; the committed manifest
//!    stays dependency-free).
//! 2. **Miri** — the crate is strict-provenance clean (the one
//!    pointer-through-`usize` laundering in `sparsity` was replaced by
//!    safe disjoint `split_at_mut` partitioning). Run the curated fast
//!    subset: `MIRIFLAGS="-Zmiri-strict-provenance" cargo +nightly miri
//!    test --lib`.
//! 3. **Sanitizers** — TSan/ASan lanes exercise `serve_concurrency`
//!    and small-shape conformance:
//!    `RUSTFLAGS="-Zsanitizer=thread" cargo +nightly test -Zbuild-std
//!    --target x86_64-unknown-linux-gnu --test serve_concurrency`.
//! 4. **Invariant lint** — `cargo xtask lint` statically enforces the
//!    repo contracts: the wire protocol (`serve::protocol` `ErrorCode`
//!    / `Request` variants) is append-only versus the committed
//!    snapshot `xtask/snapshots/wire.txt`; artifact `FORMAT`/`VERSION`
//!    constants agree across `query::index`, `ingest`, and module
//!    docs; deterministic-output modules (mining/sparsity/query/
//!    ingest) never iterate a `HashMap` or call `SystemTime::now`
//!    (annotate provably order-insensitive sites with
//!    `// lint:allow(hashmap_iter)` on the preceding line); and every
//!    `unsafe` block sits in `xtask/snapshots/unsafe_allowlist.txt`
//!    AND carries a `// SAFETY:` comment; and the exposition metric
//!    names in [`obs::names`] are well-formed (`[a-z][a-z0-9_]*`) and
//!    append-only versus `xtask/snapshots/metrics.txt`, so dashboards
//!    never break from a silent rename. To *intentionally* extend the
//!    wire protocol or the metric set, append new variants/constants
//!    at the end and re-bless the snapshots with
//!    `cargo xtask lint --bless` in the same commit.

pub mod baseline;
pub mod bench_util;
pub mod cli;
pub mod config;
pub mod dbmart;
pub mod engine;
pub mod ingest;
pub mod json;
pub mod matrix;
pub mod metrics;
pub mod mining;
pub mod ml;
pub mod msmr;
pub mod obs;
pub mod par;
pub mod partition;
pub mod pipeline;
pub mod postcovid;
pub mod psort;
pub mod query;
pub mod rng;
pub mod runtime;
pub mod seqstore;
pub mod serve;
pub mod sparsity;
pub mod sync;
pub mod synthea;
pub mod target;
pub mod util;

/// Commonly used types, re-exported for convenience.
pub mod prelude {
    pub use crate::dbmart::{DbMart, DbMartEntry, NumericDbMart, NumericEntry};
    pub use crate::engine::{
        BackendChoice, BackendKind, Engine, OutputChoice, OutputKind, Plan, RunOutput,
        RunReport, SequenceOutput, Stage, TspmError,
    };
    pub use crate::ingest::{compact, CompactConfig, MergedView, SegmentSet};
    pub use crate::matrix::{MatrixError, SeqMatrix};
    pub use crate::mining::{MiningConfig, MiningMode, SeqRecord, SequenceSet};
    pub use crate::msmr::MsmrConfig;
    pub use crate::query::{QueryService, QuerySurface, SeqIndex, SurfaceInfo};
    pub use crate::serve::{Client, Registry, ServeConfig, ServeError, Server};
    pub use crate::sparsity::SparsityConfig;
    pub use crate::synthea::SyntheaConfig;
    pub use crate::target::{TargetPos, TargetSpec};
}
