//! PJRT runtime — loading and executing the AOT-compiled JAX/Pallas
//! artifacts from the Rust hot path.
//!
//! `make artifacts` (build-time Python, never on the request path) lowers
//! every L2 function to HLO text under `artifacts/`, described by
//! `manifest.json`. With the **`pjrt` cargo feature** enabled this module
//! wraps the published `xla` crate:
//!
//! ```text
//! PjRtClient::cpu() → HloModuleProto::from_text_file → client.compile
//!                   → exe.execute(&[Literal]) → tuple outputs
//! ```
//!
//! One [`Artifact`] per HLO module (compiled once, executed many times);
//! an [`ArtifactSet`] loads the whole manifest. All tensors are f32
//! row-major, shapes fixed at lowering time (`tile_rows` × `tile_features`
//! in the manifest) — [`crate::matrix::SeqMatrix::dense_tile`] produces
//! exactly these tiles.
//!
//! **Without the feature** (the default — the `xla` crate is not vendored
//! here) every entry point compiles to a stub that returns a descriptive
//! [`RuntimeError`]; callers fall back to the pure-Rust analytics paths,
//! which compute the same numbers and are parity-tested against the
//! artifacts in `rust/tests/e2e_artifacts.rs` (itself gated on `pjrt`).

use crate::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Runtime errors (manifest, XLA, shape mismatches, feature gating).
#[derive(Debug)]
pub struct RuntimeError(pub String);

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "runtime error: {}", self.0)
    }
}

impl std::error::Error for RuntimeError {}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError(format!("xla: {e}"))
    }
}

/// A dense f32 tensor travelling between Rust and PJRT.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0f32; n] }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![1, 1], data: vec![v] }
    }

    #[cfg(feature = "pjrt")]
    fn to_literal(&self) -> Result<xla::Literal, RuntimeError> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(&self.data).reshape(&dims)?)
    }

    #[cfg(feature = "pjrt")]
    fn from_literal(lit: &xla::Literal) -> Result<Tensor, RuntimeError> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        Ok(Tensor { shape: dims, data })
    }
}

/// One compiled artifact.
pub struct Artifact {
    pub name: String,
    pub input_shapes: Vec<Vec<usize>>,
    pub num_outputs: usize,
    #[cfg(feature = "pjrt")]
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Execute with shape-checked inputs; returns the unpacked tuple.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>, RuntimeError> {
        if inputs.len() != self.input_shapes.len() {
            return Err(RuntimeError(format!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.input_shapes.len(),
                inputs.len()
            )));
        }
        for (i, (t, want)) in inputs.iter().zip(&self.input_shapes).enumerate() {
            if &t.shape != want {
                return Err(RuntimeError(format!(
                    "{}: input {i} shape {:?} != artifact shape {:?}",
                    self.name, t.shape, want
                )));
            }
        }
        self.execute(inputs)
    }

    #[cfg(feature = "pjrt")]
    fn execute(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>, RuntimeError> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_, _>>()?;
        let mut result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True → root is always a tuple.
        let elements = result.decompose_tuple()?;
        if elements.len() != self.num_outputs {
            return Err(RuntimeError(format!(
                "{}: expected {} outputs, got {}",
                self.name,
                self.num_outputs,
                elements.len()
            )));
        }
        elements.iter().map(Tensor::from_literal).collect()
    }

    #[cfg(not(feature = "pjrt"))]
    fn execute(&self, _inputs: &[Tensor]) -> Result<Vec<Tensor>, RuntimeError> {
        Err(RuntimeError(format!(
            "{}: binary compiled without the `pjrt` feature; rebuild with \
             `--features pjrt` and a vendored `xla` dependency",
            self.name
        )))
    }
}

/// The full artifact registry of one `artifacts/` directory.
pub struct ArtifactSet {
    pub tile_rows: usize,
    pub tile_features: usize,
    artifacts: BTreeMap<String, Artifact>,
}

/// One parsed manifest entry (file name, shapes, arity).
#[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
struct ManifestEntry {
    name: String,
    file: String,
    input_shapes: Vec<Vec<usize>>,
    num_outputs: usize,
}

/// Parsed `manifest.json`: tile geometry plus per-artifact entries.
/// Shared by the real PJRT loader and the stub (which uses it only to
/// produce precise error messages).
fn parse_manifest(dir: &Path) -> Result<(usize, usize, Vec<ManifestEntry>), RuntimeError> {
    let manifest_path = dir.join("manifest.json");
    let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
        RuntimeError(format!(
            "cannot read {} — run `make artifacts` first: {e}",
            manifest_path.display()
        ))
    })?;
    let manifest = Json::parse(&text).map_err(|e| RuntimeError(format!("manifest: {e}")))?;
    let tile_rows = manifest
        .get("tile_rows")
        .and_then(Json::as_u64)
        .ok_or_else(|| RuntimeError("manifest missing tile_rows".into()))? as usize;
    let tile_features = manifest
        .get("tile_features")
        .and_then(Json::as_u64)
        .ok_or_else(|| RuntimeError("manifest missing tile_features".into()))?
        as usize;
    let entries = manifest
        .get("artifacts")
        .and_then(Json::as_obj)
        .ok_or_else(|| RuntimeError("manifest missing artifacts".into()))?;

    let mut parsed = Vec::new();
    for (name, entry) in entries {
        let file = entry
            .get("file")
            .and_then(Json::as_str)
            .ok_or_else(|| RuntimeError(format!("{name}: missing file")))?;
        let input_shapes: Vec<Vec<usize>> = entry
            .get("input_shapes")
            .and_then(Json::as_arr)
            .ok_or_else(|| RuntimeError(format!("{name}: missing input_shapes")))?
            .iter()
            .map(|s| {
                s.as_arr()
                    .map(|dims| {
                        dims.iter().filter_map(Json::as_u64).map(|d| d as usize).collect()
                    })
                    .ok_or_else(|| RuntimeError(format!("{name}: bad shape")))
            })
            .collect::<Result<_, _>>()?;
        let num_outputs = entry
            .get("num_outputs")
            .and_then(Json::as_u64)
            .ok_or_else(|| RuntimeError(format!("{name}: missing num_outputs")))?
            as usize;
        parsed.push(ManifestEntry {
            name: name.clone(),
            file: file.to_string(),
            input_shapes,
            num_outputs,
        });
    }
    Ok((tile_rows, tile_features, parsed))
}

impl ArtifactSet {
    /// Create the PJRT CPU client and compile every artifact in the
    /// manifest. Compilation happens once per process.
    ///
    /// Without the `pjrt` feature this returns an error immediately (the
    /// manifest is still parsed so configuration problems surface first).
    #[cfg(feature = "pjrt")]
    pub fn load(dir: &Path) -> Result<ArtifactSet, RuntimeError> {
        let client = xla::PjRtClient::cpu()?;
        Self::load_with_client(dir, &client)
    }

    /// Stub loader: the manifest is validated, then the missing PJRT
    /// backend is reported. Keeps `ArtifactSet::load` callable from every
    /// configuration so callers can fall back to pure Rust uniformly.
    #[cfg(not(feature = "pjrt"))]
    pub fn load(dir: &Path) -> Result<ArtifactSet, RuntimeError> {
        let _ = parse_manifest(dir)?;
        Err(RuntimeError(
            "PJRT support not compiled in — rebuild with `--features pjrt` \
             (requires a vendored `xla` dependency); continuing callers \
             should fall back to the pure-Rust analytics paths"
                .into(),
        ))
    }

    /// [`ArtifactSet::load`] with a caller-owned client.
    #[cfg(feature = "pjrt")]
    pub fn load_with_client(
        dir: &Path,
        client: &xla::PjRtClient,
    ) -> Result<ArtifactSet, RuntimeError> {
        let (tile_rows, tile_features, entries) = parse_manifest(dir)?;
        let mut artifacts = BTreeMap::new();
        for entry in entries {
            let path = dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| RuntimeError("non-utf8 path".into()))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            artifacts.insert(
                entry.name.clone(),
                Artifact {
                    name: entry.name,
                    input_shapes: entry.input_shapes,
                    num_outputs: entry.num_outputs,
                    exe,
                },
            );
        }
        Ok(ArtifactSet { tile_rows, tile_features, artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&Artifact, RuntimeError> {
        self.artifacts
            .get(name)
            .ok_or_else(|| RuntimeError(format!("artifact {name:?} not in manifest")))
    }

    pub fn names(&self) -> Vec<&str> {
        self.artifacts.keys().map(|s| s.as_str()).collect()
    }
}

/// Default artifacts directory: `$TSPM_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("TSPM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_checks() {
        let t = Tensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.shape, vec![2, 3]);
        assert_eq!(Tensor::zeros(vec![4, 4]).data.len(), 16);
        assert_eq!(Tensor::scalar(5.0).data, vec![5.0]);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn tensor_rejects_bad_shape() {
        Tensor::new(vec![2, 2], vec![0.0; 3]);
    }

    #[test]
    fn missing_manifest_is_a_clear_error() {
        let dir = std::env::temp_dir().join("tspm_no_artifacts_here");
        let err = ArtifactSet::load(&dir).unwrap_err();
        assert!(err.0.contains("manifest") || err.0.contains("pjrt") || err.0.contains("PJRT"));
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_load_reports_missing_feature() {
        // With a syntactically valid manifest present the stub must fail
        // on the missing backend, not on the manifest.
        let dir = std::env::temp_dir().join("tspm_stub_manifest");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"tile_rows": 8, "tile_features": 8, "artifacts": {}}"#,
        )
        .unwrap();
        let err = ArtifactSet::load(&dir).unwrap_err();
        assert!(err.0.contains("pjrt"), "got: {err}");
    }

    #[cfg(feature = "pjrt")]
    mod with_artifacts {
        use super::super::*;

        fn artifacts_available() -> Option<ArtifactSet> {
            let dir = default_artifacts_dir();
            if dir.join("manifest.json").exists() {
                Some(ArtifactSet::load(&dir).expect("artifact load"))
            } else {
                eprintln!("skipping runtime tests: run `make artifacts` first");
                None
            }
        }

        #[test]
        fn loads_manifest_and_runs_cooc() {
            let Some(set) = artifacts_available() else { return };
            assert!(set.names().contains(&"cooc"));
            let (p, f) = (set.tile_rows, set.tile_features);
            // X with a single 1 at (0, 0) and (0, 1) → cooc[0,1] = 1.
            let mut x = Tensor::zeros(vec![p, f]);
            x.data[0] = 1.0;
            x.data[1] = 1.0;
            let out = set.get("cooc").unwrap().run(&[x.clone(), x]).unwrap();
            assert_eq!(out.len(), 1);
            assert_eq!(out[0].shape, vec![f, f]);
            assert_eq!(out[0].data[0], 1.0); // (0,0)
            assert_eq!(out[0].data[1], 1.0); // (0,1)
            assert_eq!(out[0].data[f + 1], 1.0); // (1,1)
            assert_eq!(out[0].data[2], 0.0);
        }

        #[test]
        fn cooc_matches_rust_reference_on_random_tile() {
            let Some(set) = artifacts_available() else { return };
            let (p, f) = (set.tile_rows, set.tile_features);
            let mut rng = crate::rng::Rng::new(33);
            let x = Tensor::new(
                vec![p, f],
                (0..p * f).map(|_| f32::from(rng.gen_bool(0.2))).collect(),
            );
            let out = &set.get("cooc").unwrap().run(&[x.clone(), x.clone()]).unwrap()[0];
            // spot-check 20 random cells against a direct dot product
            for _ in 0..20 {
                let a = rng.gen_range(f as u64) as usize;
                let b = rng.gen_range(f as u64) as usize;
                let want: f32 = (0..p).map(|r| x.data[r * f + a] * x.data[r * f + b]).sum();
                assert_eq!(out.data[a * f + b], want, "cell ({a},{b})");
            }
        }

        #[test]
        fn logreg_grad_runs_and_shapes_match() {
            let Some(set) = artifacts_available() else { return };
            let (p, f) = (set.tile_rows, set.tile_features);
            let w = Tensor::zeros(vec![f, 1]);
            let b = Tensor::zeros(vec![1, 1]);
            let x = Tensor::zeros(vec![p, f]);
            let y = Tensor::zeros(vec![p, 1]);
            let mask = Tensor::new(vec![p, 1], vec![1.0; p]);
            let out = set.get("logreg_grad").unwrap().run(&[w, b, x, y, mask]).unwrap();
            assert_eq!(out.len(), 3);
            assert_eq!(out[0].shape, vec![f, 1]);
            assert_eq!(out[1].shape, vec![1, 1]);
            assert_eq!(out[2].shape, vec![1, 1]);
            // all-zero inputs: p = 0.5, loss = P·ln2
            let want_loss = p as f32 * std::f32::consts::LN_2;
            assert!((out[2].data[0] - want_loss).abs() < 1e-2);
        }

        #[test]
        fn shape_mismatch_is_rejected() {
            let Some(set) = artifacts_available() else { return };
            let bad = Tensor::zeros(vec![3, 3]);
            let err = set.get("cooc").unwrap().run(&[bad.clone(), bad]).unwrap_err();
            assert!(err.0.contains("shape"));
        }

        #[test]
        fn unknown_artifact_is_an_error() {
            let Some(set) = artifacts_available() else { return };
            assert!(set.get("nonexistent").is_err());
        }
    }
}
