//! The `dbmart` data model: MLHO-format clinical tables, numeric encoding
//! with lookup tables, and the paper's reversible sequence hash.
//!
//! A dbmart (MLHO format) is a table of `(patient_num, date, phenx)` rows
//! — `phenx` being any clinical representation (diagnosis code, medication,
//! lab bucket…). tSPM+ interns patients and phenX codes to dense `u32`
//! ids starting at 0 and works exclusively on the numeric form; lookup
//! tables translate results back to the original strings (paper §Methods).
//!
//! The sequence hash (paper Fig. 2): a pair `(start, end)` of phenX ids is
//! encoded as the decimal concatenation `start * 10^7 + end` in a `u64` —
//! reversible, human-readable, and totally ordered first by start then by
//! end. phenX ids must therefore be `< 10^7` ([`MAX_PHENX`]).

use std::collections::HashMap;
use std::fmt;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

pub mod discretize;

/// Exclusive upper bound on phenX ids: the end id is zero-padded to 7
/// decimal digits inside the sequence hash.
pub const MAX_PHENX: u32 = 10_000_000;

/// Multiplier that shifts the start phenX left of the 7 end digits.
pub const SEQ_SHIFT: u64 = 10_000_000;

/// One raw (string-typed) dbmart row in MLHO format.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DbMartEntry {
    pub patient_id: String,
    /// Days since an arbitrary epoch (MLHO stores dates; days keep the
    /// model simple and match the paper's day-denominated durations).
    pub date: i32,
    pub phenx: String,
    /// Optional human description; discarded in preprocessing (paper:
    /// "the tSPM algorithm either discards the description column…").
    pub description: Option<String>,
}

/// A raw dbmart: rows plus optional provenance.
#[derive(Clone, Debug, Default)]
pub struct DbMart {
    pub entries: Vec<DbMartEntry>,
}

impl DbMart {
    pub fn new(entries: Vec<DbMartEntry>) -> Self {
        DbMart { entries }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Read a CSV file with header `patient_num,start_date,phenx[,description]`.
    /// Dates are integer day offsets or `YYYY-MM-DD`.
    pub fn read_csv(path: &Path) -> std::io::Result<DbMart> {
        let f = std::fs::File::open(path)?;
        let mut reader = BufReader::new(f);
        let mut header = String::new();
        reader.read_line(&mut header)?;
        let cols: Vec<&str> = header.trim().split(',').collect();
        let find = |name: &str| cols.iter().position(|c| c.eq_ignore_ascii_case(name));
        let pi = find("patient_num")
            .ok_or_else(|| bad_data("missing patient_num column"))?;
        let di = find("start_date")
            .or_else(|| find("date"))
            .ok_or_else(|| bad_data("missing start_date column"))?;
        let xi = find("phenx").ok_or_else(|| bad_data("missing phenx column"))?;
        let desci = find("description");
        let mut entries = Vec::new();
        for (lineno, line) in reader.lines().enumerate() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            let fields: Vec<&str> = line.split(',').collect();
            let need = pi.max(di).max(xi);
            if fields.len() <= need {
                return Err(bad_data(&format!("line {}: too few fields", lineno + 2)));
            }
            let date = parse_date(fields[di].trim())
                .ok_or_else(|| bad_data(&format!("line {}: bad date {:?}", lineno + 2, fields[di])))?;
            entries.push(DbMartEntry {
                patient_id: fields[pi].trim().to_string(),
                date,
                phenx: fields[xi].trim().to_string(),
                description: desci
                    .and_then(|i| fields.get(i))
                    .map(|s| s.trim().to_string())
                    .filter(|s| !s.is_empty()),
            });
        }
        Ok(DbMart { entries })
    }

    /// Write as CSV (descriptions included when present).
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        let f = std::fs::File::create(path)?;
        let mut w = BufWriter::new(f);
        writeln!(w, "patient_num,start_date,phenx,description")?;
        for e in &self.entries {
            writeln!(
                w,
                "{},{},{},{}",
                e.patient_id,
                e.date,
                e.phenx,
                e.description.as_deref().unwrap_or("")
            )?;
        }
        w.flush()
    }
}

fn bad_data(msg: &str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
}

/// Parse an integer day offset or an ISO `YYYY-MM-DD` date to days since
/// 1970-01-01 (proleptic Gregorian, civil-days algorithm).
pub fn parse_date(s: &str) -> Option<i32> {
    if let Ok(v) = s.parse::<i32>() {
        return Some(v);
    }
    let mut parts = s.split('-');
    let y: i64 = parts.next()?.parse().ok()?;
    let m: u32 = parts.next()?.parse().ok()?;
    let d: u32 = parts.next()?.parse().ok()?;
    if parts.next().is_some() || !(1..=12).contains(&m) || !(1..=31).contains(&d) {
        return None;
    }
    Some(days_from_civil(y, m, d))
}

/// Howard Hinnant's `days_from_civil`: days since 1970-01-01.
pub fn days_from_civil(y: i64, m: u32, d: u32) -> i32 {
    let y = if m <= 2 { y - 1 } else { y };
    let era = if y >= 0 { y } else { y - 399 } / 400;
    let yoe = (y - era * 400) as i64;
    let mp = ((m + 9) % 12) as i64;
    let doy = (153 * mp + 2) / 5 + d as i64 - 1;
    let doe = yoe * 365 + yoe / 4 - yoe / 100 + doy;
    (era * 146_097 + doe - 719_468) as i32
}

/// One numeric dbmart row (the working representation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NumericEntry {
    pub patient: u32,
    pub date: i32,
    pub phenx: u32,
}

/// Lookup tables mapping dense numeric ids back to the original strings.
#[derive(Clone, Debug, Default)]
pub struct LookupTables {
    pub patients: Vec<String>,
    pub phenx: Vec<String>,
    /// Optional phenX descriptions aligned with `phenx`.
    pub descriptions: Vec<Option<String>>,
    /// Reverse index `phenX name → dense id`, built during interning so
    /// [`LookupTables::phenx_id`] is O(1). Resolving a WHO-style code
    /// list used to do one O(vocab) scan per code — quadratic on large
    /// vocabularies.
    pub phenx_index: HashMap<String, u32>,
}

impl LookupTables {
    pub fn patient_name(&self, id: u32) -> &str {
        &self.patients[id as usize]
    }

    pub fn phenx_name(&self, id: u32) -> &str {
        &self.phenx[id as usize]
    }

    pub fn phenx_description(&self, id: u32) -> Option<&str> {
        self.descriptions.get(id as usize).and_then(|d| d.as_deref())
    }

    /// Reverse lookup via the interning-time hash index (O(1)).
    pub fn phenx_id(&self, name: &str) -> Option<u32> {
        self.phenx_index.get(name).copied()
    }

    /// Serialize to JSON (the R package writes lookup tables next to the
    /// mined sequences so results stay translatable).
    pub fn to_json(&self) -> crate::json::Json {
        use crate::json::Json;
        Json::obj(vec![
            (
                "patients",
                Json::Arr(self.patients.iter().map(|p| Json::from(p.clone())).collect()),
            ),
            (
                "phenx",
                Json::Arr(self.phenx.iter().map(|p| Json::from(p.clone())).collect()),
            ),
            (
                "descriptions",
                Json::Arr(
                    self.descriptions
                        .iter()
                        .map(|d| match d {
                            Some(s) => Json::from(s.clone()),
                            None => Json::Null,
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &crate::json::Json) -> Option<LookupTables> {
        let patients = j
            .get("patients")?
            .as_arr()?
            .iter()
            .map(|v| v.as_str().map(|s| s.to_string()))
            .collect::<Option<Vec<_>>>()?;
        let phenx = j
            .get("phenx")?
            .as_arr()?
            .iter()
            .map(|v| v.as_str().map(|s| s.to_string()))
            .collect::<Option<Vec<_>>>()?;
        let descriptions = match j.get("descriptions") {
            Some(arr) => arr
                .as_arr()?
                .iter()
                .map(|v| match v {
                    crate::json::Json::Null => Some(None),
                    other => other.as_str().map(|s| Some(s.to_string())),
                })
                .collect::<Option<Vec<_>>>()?,
            None => vec![None; phenx.len()],
        };
        let phenx_index =
            phenx.iter().enumerate().map(|(i, p)| (p.clone(), i as u32)).collect();
        Some(LookupTables { patients, phenx, descriptions, phenx_index })
    }
}

/// A fully numeric dbmart: interned entries plus lookup tables.
#[derive(Clone, Debug, Default)]
pub struct NumericDbMart {
    pub entries: Vec<NumericEntry>,
    pub lookup: LookupTables,
}

/// Error for encoding failures (phenX vocabulary overflow).
#[derive(Debug)]
pub struct EncodeError(pub String);

impl fmt::Display for EncodeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "encode error: {}", self.0)
    }
}

impl std::error::Error for EncodeError {}

impl NumericDbMart {
    /// Intern a raw dbmart to the numeric representation.
    ///
    /// Ids are assigned in first-appearance order starting at 0 (paper:
    /// "we assign a running number, starting from 0, to each unique phenX
    /// and patient ID"). Descriptions, when present, are captured into the
    /// lookup table and dropped from the working set.
    pub fn encode(raw: &DbMart) -> NumericDbMart {
        Self::try_encode(raw).expect("dbmart fails encoding validation")
    }

    /// Like [`NumericDbMart::encode`] but surfaces the vocabulary-overflow
    /// and date-validation errors instead of panicking.
    pub fn try_encode(raw: &DbMart) -> Result<NumericDbMart, EncodeError> {
        let mut patient_ids: HashMap<&str, u32> = HashMap::new();
        let mut lookup = LookupTables::default();
        let mut entries = Vec::with_capacity(raw.entries.len());
        for e in &raw.entries {
            // Date-range validation at ingestion: i32::MIN is the classic
            // missing-value sentinel in exported clinical tables, and any
            // row carrying it would mine garbage durations. Reject it
            // here with a precise row reference instead.
            if e.date == i32::MIN {
                return Err(EncodeError(format!(
                    "patient {:?} has date i32::MIN ({}) — a missing-value sentinel, \
                     not a real date; clean or re-date the row before encoding",
                    e.patient_id,
                    i32::MIN
                )));
            }
            let pid = *patient_ids.entry(&e.patient_id).or_insert_with(|| {
                lookup.patients.push(e.patient_id.clone());
                (lookup.patients.len() - 1) as u32
            });
            let xid = match lookup.phenx_index.get(e.phenx.as_str()) {
                Some(&x) => {
                    // Backfill a description if an earlier row lacked one.
                    if lookup.descriptions[x as usize].is_none() {
                        if let Some(d) = &e.description {
                            lookup.descriptions[x as usize] = Some(d.clone());
                        }
                    }
                    x
                }
                None => {
                    let x = lookup.phenx.len() as u32;
                    if x >= MAX_PHENX {
                        return Err(EncodeError(format!(
                            "more than {MAX_PHENX} distinct phenX codes; the 7-digit sequence hash cannot represent this vocabulary"
                        )));
                    }
                    lookup.phenx_index.insert(e.phenx.clone(), x);
                    lookup.phenx.push(e.phenx.clone());
                    lookup.descriptions.push(e.description.clone());
                    x
                }
            };
            entries.push(NumericEntry { patient: pid, date: e.date, phenx: xid });
        }
        Ok(NumericDbMart { entries, lookup })
    }

    /// Like [`NumericDbMart::try_encode`] but seeded from an existing
    /// vocabulary: patients and phenX codes already in `base` keep their
    /// dense ids, new ones continue after them in first-appearance
    /// order. The delta-ingest path uses this so every segment of a
    /// segment set shares one id space (the set-level `lookup.json`);
    /// ids from `base` never move, which is what keeps previously
    /// committed segments translatable. The returned lookup is the
    /// *union* vocabulary — persist it as the new base.
    pub fn try_encode_with(
        raw: &DbMart,
        base: &LookupTables,
    ) -> Result<NumericDbMart, EncodeError> {
        let mut lookup = base.clone();
        // Tolerate bases whose descriptions were trimmed or absent.
        if lookup.descriptions.len() < lookup.phenx.len() {
            lookup.descriptions.resize(lookup.phenx.len(), None);
        }
        // Owned keys: the map must outlive both the base strings and the
        // delta rows it interns, so borrowing either is off the table.
        let mut patient_ids: HashMap<String, u32> = lookup
            .patients
            .iter()
            .enumerate()
            .map(|(i, p)| (p.clone(), i as u32))
            .collect();
        let mut entries = Vec::with_capacity(raw.entries.len());
        for e in &raw.entries {
            if e.date == i32::MIN {
                return Err(EncodeError(format!(
                    "patient {:?} has date i32::MIN ({}) — a missing-value sentinel, \
                     not a real date; clean or re-date the row before encoding",
                    e.patient_id,
                    i32::MIN
                )));
            }
            let pid = match patient_ids.get(e.patient_id.as_str()) {
                Some(&p) => p,
                None => {
                    let p = lookup.patients.len() as u32;
                    patient_ids.insert(e.patient_id.clone(), p);
                    lookup.patients.push(e.patient_id.clone());
                    p
                }
            };
            let xid = match lookup.phenx_index.get(e.phenx.as_str()) {
                Some(&x) => {
                    if lookup.descriptions[x as usize].is_none() {
                        if let Some(d) = &e.description {
                            lookup.descriptions[x as usize] = Some(d.clone());
                        }
                    }
                    x
                }
                None => {
                    let x = lookup.phenx.len() as u32;
                    if x >= MAX_PHENX {
                        return Err(EncodeError(format!(
                            "more than {MAX_PHENX} distinct phenX codes; the 7-digit \
                             sequence hash cannot represent this vocabulary"
                        )));
                    }
                    lookup.phenx_index.insert(e.phenx.clone(), x);
                    lookup.phenx.push(e.phenx.clone());
                    lookup.descriptions.push(e.description.clone());
                    x
                }
            };
            entries.push(NumericEntry { patient: pid, date: e.date, phenx: xid });
        }
        Ok(NumericDbMart { entries, lookup })
    }

    pub fn num_patients(&self) -> usize {
        self.lookup.patients.len()
    }

    pub fn num_phenx(&self) -> usize {
        self.lookup.phenx.len()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Logical size in bytes of the numeric working set.
    pub fn byte_size(&self) -> u64 {
        (self.entries.len() * std::mem::size_of::<NumericEntry>()) as u64
    }
}

// ---------------------------------------------------------------------------
// Sequence hash (paper Fig. 2)
// ---------------------------------------------------------------------------

/// Encode a (start, end) phenX pair as the paper's reversible decimal hash.
#[inline]
pub fn encode_seq(start: u32, end: u32) -> u64 {
    debug_assert!(start < MAX_PHENX && end < MAX_PHENX);
    start as u64 * SEQ_SHIFT + end as u64
}

/// Decode a sequence hash back to its (start, end) phenX pair.
#[inline]
pub fn decode_seq(seq: u64) -> (u32, u32) {
    ((seq / SEQ_SHIFT) as u32, (seq % SEQ_SHIFT) as u32)
}

/// Pack a duration (in the configured unit) into the low bits of a
/// combined value: `seq << DUR_BITS | min(duration, DUR_MASK)`.
///
/// The paper: "we utilize cheap bitshift operations to shift the duration
/// on the last bits of the sequence" for duration-aware helpers. 14 bits
/// hold durations up to ~44.8 years in days.
pub const DUR_BITS: u32 = 14;
pub const DUR_MASK: u64 = (1 << DUR_BITS) - 1;

#[inline]
pub fn pack_duration(seq: u64, duration: u32) -> u64 {
    debug_assert!(seq < (1u64 << (64 - DUR_BITS)), "sequence hash too large to pack");
    (seq << DUR_BITS) | (duration as u64).min(DUR_MASK)
}

#[inline]
pub fn unpack_duration(packed: u64) -> (u64, u32) {
    (packed >> DUR_BITS, (packed & DUR_MASK) as u32)
}

/// Render a sequence hash in the paper's human-readable zero-padded form,
/// e.g. `42 → 0000042` gives `"12-0000042"` for start 12, end 42.
pub fn format_seq(seq: u64) -> String {
    let (s, e) = decode_seq(seq);
    format!("{s}-{e:07}")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(p: &str, date: i32, x: &str) -> DbMartEntry {
        DbMartEntry {
            patient_id: p.to_string(),
            date,
            phenx: x.to_string(),
            description: None,
        }
    }

    #[test]
    fn encode_assigns_running_numbers_from_zero() {
        let raw = DbMart::new(vec![
            entry("alice", 10, "covid"),
            entry("bob", 11, "fatigue"),
            entry("alice", 12, "covid"),
            entry("carol", 13, "cough"),
        ]);
        let n = NumericDbMart::encode(&raw);
        assert_eq!(n.lookup.patients, vec!["alice", "bob", "carol"]);
        assert_eq!(n.lookup.phenx, vec!["covid", "fatigue", "cough"]);
        assert_eq!(n.entries[0], NumericEntry { patient: 0, date: 10, phenx: 0 });
        assert_eq!(n.entries[2], NumericEntry { patient: 0, date: 12, phenx: 0 });
        assert_eq!(n.entries[3], NumericEntry { patient: 2, date: 13, phenx: 2 });
    }

    #[test]
    fn encode_captures_descriptions() {
        let mut e1 = entry("p", 1, "x");
        e1.description = None;
        let mut e2 = entry("p", 2, "x");
        e2.description = Some("a code".into());
        let n = NumericDbMart::encode(&DbMart::new(vec![e1, e2]));
        assert_eq!(n.lookup.phenx_description(0), Some("a code"));
    }

    #[test]
    fn seq_hash_roundtrip() {
        for (s, e) in [(0u32, 0u32), (1, 2), (42, 9_999_999), (9_999_999, 3)] {
            let h = encode_seq(s, e);
            assert_eq!(decode_seq(h), (s, e));
        }
    }

    #[test]
    fn seq_hash_is_decimal_concatenation() {
        // paper Fig.2: start 12, end 42 → "12" + "0000042"
        assert_eq!(encode_seq(12, 42), 120_000_042);
        assert_eq!(format_seq(encode_seq(12, 42)), "12-0000042");
    }

    #[test]
    fn seq_hash_orders_by_start_then_end() {
        assert!(encode_seq(1, 9_999_999) < encode_seq(2, 0));
        assert!(encode_seq(5, 1) < encode_seq(5, 2));
    }

    #[test]
    fn duration_packing_roundtrip() {
        let seq = encode_seq(123, 456);
        let packed = pack_duration(seq, 365);
        let (s2, d2) = unpack_duration(packed);
        assert_eq!(s2, seq);
        assert_eq!(d2, 365);
    }

    #[test]
    fn duration_packing_saturates() {
        let (_, d) = unpack_duration(pack_duration(1, u32::MAX));
        assert_eq!(d as u64, DUR_MASK);
    }

    #[test]
    fn date_parsing_iso_and_offsets() {
        assert_eq!(parse_date("0"), Some(0));
        assert_eq!(parse_date("-5"), Some(-5));
        assert_eq!(parse_date("1970-01-01"), Some(0));
        assert_eq!(parse_date("1970-01-02"), Some(1));
        assert_eq!(parse_date("2000-03-01"), Some(11017));
        assert_eq!(parse_date("2020-01-01"), Some(18262));
        assert_eq!(parse_date("not-a-date"), None);
        assert_eq!(parse_date("2020-13-01"), None);
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("tspm_dbmart_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mart.csv");
        let mut raw = DbMart::new(vec![
            entry("p1", 100, "icd:U09.9"),
            entry("p2", 101, "med:paxlovid"),
        ]);
        raw.entries[0].description = Some("post covid".into());
        raw.write_csv(&path).unwrap();
        let back = DbMart::read_csv(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back.entries[0].patient_id, "p1");
        assert_eq!(back.entries[0].description.as_deref(), Some("post covid"));
        assert_eq!(back.entries[1].date, 101);
    }

    #[test]
    fn csv_rejects_missing_columns() {
        let dir = std::env::temp_dir().join("tspm_dbmart_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        std::fs::write(&path, "a,b,c\n1,2,3\n").unwrap();
        assert!(DbMart::read_csv(&path).is_err());
    }

    #[test]
    fn lookup_json_roundtrip() {
        let raw = DbMart::new(vec![entry("p", 1, "x"), entry("q", 2, "y")]);
        let n = NumericDbMart::encode(&raw);
        let j = n.lookup.to_json();
        let back = LookupTables::from_json(&j).unwrap();
        assert_eq!(back.patients, n.lookup.patients);
        assert_eq!(back.phenx, n.lookup.phenx);
        // The reverse index is rebuilt on deserialization, not persisted.
        assert_eq!(back.phenx_id("x"), Some(0));
        assert_eq!(back.phenx_id("y"), Some(1));
        assert_eq!(back.phenx_id("z"), None);
    }

    #[test]
    fn phenx_id_uses_the_interning_index() {
        let raw = DbMart::new(
            (0..500).map(|i| entry("p", i, &format!("code{i}"))).collect(),
        );
        let n = NumericDbMart::encode(&raw);
        assert_eq!(n.lookup.phenx_index.len(), 500);
        for i in [0u32, 17, 499] {
            assert_eq!(n.lookup.phenx_id(&format!("code{i}")), Some(i));
        }
        assert_eq!(n.lookup.phenx_id("nope"), None);
    }

    #[test]
    fn sentinel_date_rejected_at_ingestion() {
        let raw = DbMart::new(vec![entry("p", i32::MIN, "x")]);
        let err = NumericDbMart::try_encode(&raw).unwrap_err();
        assert!(err.to_string().contains("sentinel"), "got {err}");
        // The neighbouring value is a real (if extreme) date and passes.
        let ok = DbMart::new(vec![entry("p", i32::MIN + 1, "x")]);
        assert!(NumericDbMart::try_encode(&ok).is_ok());
    }

    #[test]
    fn try_encode_with_extends_a_base_vocabulary() {
        let base_raw =
            DbMart::new(vec![entry("alice", 1, "covid"), entry("bob", 2, "cough")]);
        let base = NumericDbMart::encode(&base_raw);
        let delta = DbMart::new(vec![
            entry("bob", 3, "fatigue"), // known patient, new code
            entry("carol", 4, "covid"), // new patient, known code
        ]);
        let n = NumericDbMart::try_encode_with(&delta, &base.lookup).unwrap();
        assert_eq!(n.lookup.patients, vec!["alice", "bob", "carol"]);
        assert_eq!(n.lookup.phenx, vec!["covid", "cough", "fatigue"]);
        assert_eq!(n.entries[0], NumericEntry { patient: 1, date: 3, phenx: 2 });
        assert_eq!(n.entries[1], NumericEntry { patient: 2, date: 4, phenx: 0 });
        // The union vocabulary counts base patients the delta never saw.
        assert_eq!(n.num_patients(), 3);

        // An empty base degenerates to plain try_encode.
        let solo =
            NumericDbMart::try_encode_with(&base_raw, &LookupTables::default()).unwrap();
        assert_eq!(solo.lookup.patients, base.lookup.patients);
        assert_eq!(solo.entries, base.entries);

        // The sentinel-date check still applies.
        let bad = DbMart::new(vec![entry("p", i32::MIN, "x")]);
        assert!(NumericDbMart::try_encode_with(&bad, &base.lookup).is_err());

        // A delta row can backfill a description the base lacked.
        let mut d = entry("alice", 5, "covid");
        d.description = Some("post covid".into());
        let n2 =
            NumericDbMart::try_encode_with(&DbMart::new(vec![d]), &base.lookup).unwrap();
        assert_eq!(n2.lookup.phenx_description(0), Some("post covid"));
    }

    #[test]
    fn byte_size_matches_entry_layout() {
        let raw = DbMart::new(vec![entry("p", 1, "x")]);
        let n = NumericDbMart::encode(&raw);
        assert_eq!(n.byte_size(), 12); // u32 + i32 + u32
    }
}
