//! Discretization of continuous clinical values into phenX range codes.
//!
//! The paper lists non-discrete data as tSPM+'s main limitation and
//! suggests the standard workaround: "creating a new phenX for different
//! ranges". This module implements that workaround as a first-class
//! feature (the paper's future-work item): fixed-width, quantile and
//! custom-boundary binning of `(patient, date, value)` measurements into
//! synthetic phenX codes like `weight[75,80)`.

use super::{DbMart, DbMartEntry};

/// Binning strategy for one continuous variable.
#[derive(Clone, Debug)]
pub enum Binning {
    /// `k` equal-width bins between observed min and max.
    EqualWidth(usize),
    /// `k` (approximate) equal-population bins from sample quantiles.
    Quantile(usize),
    /// Explicit ascending interior boundaries; values below the first go
    /// to bin 0, above the last to bin `len`.
    Boundaries(Vec<f64>),
}

/// A continuous measurement to discretize.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub patient_id: String,
    pub date: i32,
    pub value: f64,
}

/// Compute the interior bin boundaries for `values` under `binning`.
pub fn boundaries(values: &[f64], binning: &Binning) -> Vec<f64> {
    match binning {
        Binning::Boundaries(b) => {
            assert!(
                b.windows(2).all(|w| w[0] < w[1]),
                "custom boundaries must be strictly ascending"
            );
            b.clone()
        }
        Binning::EqualWidth(k) => {
            assert!(*k >= 1, "need at least one bin");
            if values.is_empty() || *k == 1 {
                return Vec::new();
            }
            let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
            for &v in values {
                lo = lo.min(v);
                hi = hi.max(v);
            }
            if lo >= hi {
                return Vec::new();
            }
            let w = (hi - lo) / *k as f64;
            (1..*k).map(|i| lo + w * i as f64).collect()
        }
        Binning::Quantile(k) => {
            assert!(*k >= 1, "need at least one bin");
            if values.is_empty() || *k == 1 {
                return Vec::new();
            }
            let mut sorted = values.to_vec();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let mut out = Vec::with_capacity(k - 1);
            for i in 1..*k {
                let pos = i * sorted.len() / k;
                let b = sorted[pos.min(sorted.len() - 1)];
                if out.last().map_or(true, |&prev| b > prev) {
                    out.push(b);
                }
            }
            out
        }
    }
}

/// Bin index of `value` given interior `bounds` (ascending).
pub fn bin_index(value: f64, bounds: &[f64]) -> usize {
    bounds.partition_point(|&b| b <= value)
}

/// Human-readable phenX code for bin `idx` of variable `name`.
pub fn bin_phenx(name: &str, idx: usize, bounds: &[f64]) -> String {
    let lo = if idx == 0 { "-inf".to_string() } else { format!("{:.4}", bounds[idx - 1]) };
    let hi = if idx == bounds.len() { "inf".to_string() } else { format!("{:.4}", bounds[idx]) };
    format!("{name}[{lo},{hi})")
}

/// Discretize measurements of variable `name` and append them to `mart`
/// as synthetic phenX rows. Returns the boundaries used.
pub fn discretize_into(
    mart: &mut DbMart,
    name: &str,
    measurements: &[Measurement],
    binning: &Binning,
) -> Vec<f64> {
    let values: Vec<f64> = measurements.iter().map(|m| m.value).collect();
    let bounds = boundaries(&values, binning);
    for m in measurements {
        let idx = bin_index(m.value, &bounds);
        mart.entries.push(DbMartEntry {
            patient_id: m.patient_id.clone(),
            date: m.date,
            phenx: bin_phenx(name, idx, &bounds),
            description: Some(format!("{name} measurement bin {idx}")),
        });
    }
    bounds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_width_bounds() {
        let vals = [0.0, 10.0];
        let b = boundaries(&vals, &Binning::EqualWidth(4));
        assert_eq!(b, vec![2.5, 5.0, 7.5]);
    }

    #[test]
    fn equal_width_degenerate() {
        assert!(boundaries(&[5.0, 5.0], &Binning::EqualWidth(4)).is_empty());
        assert!(boundaries(&[], &Binning::EqualWidth(4)).is_empty());
        assert!(boundaries(&[1.0, 2.0], &Binning::EqualWidth(1)).is_empty());
    }

    #[test]
    fn quantile_bounds_split_population() {
        let vals: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let b = boundaries(&vals, &Binning::Quantile(4));
        assert_eq!(b.len(), 3);
        // Counts per bin should be near 25.
        let mut counts = vec![0usize; 4];
        for &v in &vals {
            counts[bin_index(v, &b)] += 1;
        }
        for c in counts {
            assert!((20..=30).contains(&c), "unbalanced bin: {c}");
        }
    }

    #[test]
    fn quantile_dedups_on_ties() {
        let vals = vec![1.0; 50];
        let b = boundaries(&vals, &Binning::Quantile(5));
        assert!(b.len() <= 1);
    }

    #[test]
    fn bin_index_edges() {
        let b = vec![10.0, 20.0];
        assert_eq!(bin_index(5.0, &b), 0);
        assert_eq!(bin_index(10.0, &b), 1); // boundary goes right
        assert_eq!(bin_index(15.0, &b), 1);
        assert_eq!(bin_index(25.0, &b), 2);
    }

    #[test]
    #[should_panic(expected = "ascending")]
    fn custom_bounds_must_ascend() {
        boundaries(&[1.0], &Binning::Boundaries(vec![5.0, 3.0]));
    }

    #[test]
    fn discretize_appends_phenx_rows() {
        let mut mart = DbMart::default();
        let ms = vec![
            Measurement { patient_id: "p1".into(), date: 1, value: 72.0 },
            Measurement { patient_id: "p1".into(), date: 30, value: 81.0 },
            Measurement { patient_id: "p2".into(), date: 2, value: 95.0 },
        ];
        let bounds =
            discretize_into(&mut mart, "weight", &ms, &Binning::Boundaries(vec![75.0, 90.0]));
        assert_eq!(bounds, vec![75.0, 90.0]);
        assert_eq!(mart.len(), 3);
        assert_eq!(mart.entries[0].phenx, "weight[-inf,75.0000)");
        assert_eq!(mart.entries[1].phenx, "weight[75.0000,90.0000)");
        assert_eq!(mart.entries[2].phenx, "weight[90.0000,inf)");
        // Same variable+bin maps to the same phenX string → interns to one id.
        let n = crate::dbmart::NumericDbMart::encode(&mart);
        assert_eq!(n.num_phenx(), 3);
    }
}
